"""Render the §Roofline table for EXPERIMENTS.md from dry-run artifacts."""
from __future__ import annotations

import glob
import json
import os

ARTIFACT_DIR = "experiments/artifacts"


def load_all(art_dir: str = ARTIFACT_DIR) -> list[dict]:
    rows = []
    for path in sorted(glob.glob(os.path.join(art_dir, "*.json"))):
        with open(path) as f:
            rows.append(json.load(f))
    return rows


def fmt_row(r: dict) -> str:
    ro = r.get("roofline", {})
    mem = r["bytes_per_device"]["peak_est"] / 2**30
    return ("| {arch} | {shape} | {mesh} | {mem:.1f} | {fits} | "
            "{c:.3f} | {m:.3f} | {l:.3f} | {dom} | {mf:.2e} | {ur:.2f} |"
            .format(arch=r["arch"], shape=r["shape"], mesh=r["mesh"],
                    mem=mem, fits="y" if r["fits_hbm"] else "N",
                    c=ro.get("compute_s", float("nan")),
                    m=ro.get("memory_s", float("nan")),
                    l=ro.get("collective_s", float("nan")),
                    dom=ro.get("dominant", "-"),
                    mf=ro.get("model_flops", float("nan")),
                    ur=ro.get("useful_ratio", float("nan"))))


HEADER = ("| arch | shape | mesh | peak GiB/dev | fits | compute s | "
          "memory s | collective s | dominant | MODEL_FLOPS | useful |\n"
          "|---|---|---|---|---|---|---|---|---|---|---|")


def run() -> None:
    rows = load_all()
    print(HEADER)
    for r in rows:
        print(fmt_row(r))
    print(f"# {len(rows)} cells")


if __name__ == "__main__":
    run()
