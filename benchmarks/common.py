"""Shared benchmark helpers: timing, CSV emission, and structured records.

Every ``emit`` also appends a structured record to ``RECORDS`` so the
driver (benchmarks/run.py) can write machine-readable ``BENCH_*.json``
artifacts — the perf trajectory tracked from PR 1 onward.
"""
from __future__ import annotations

import time

import jax

# Reduced sweep for CI smoke runs (set by run.py --quick).
QUICK = False

# Structured results of the current process: list of dicts with at least
# {"name", "us_per_call"}; extra numeric fields (coalescing, ratios) ride
# along verbatim.
RECORDS: list[dict] = []


def time_jit(fn, *args, iters: int = 20, warmup: int = 3) -> float:
    """Median wall time (us) of a jitted call on this host."""
    if QUICK:
        iters, warmup = 5, 1
    f = jax.jit(fn)
    for _ in range(warmup):
        jax.block_until_ready(f(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(f(*args))
        times.append((time.perf_counter() - t0) * 1e6)
    times.sort()
    return times[len(times) // 2]


def emit(name: str, us: float, derived: str, **fields) -> None:
    print(f"{name},{us:.1f},{derived}")
    RECORDS.append({"name": name, "us_per_call": round(us, 2), **fields})
