"""Shared benchmark helpers: timing, CSV emission, and structured records.

Every ``emit`` also appends a structured record to ``RECORDS`` so the
driver (benchmarks/run.py) can write machine-readable ``BENCH_*.json``
artifacts — the perf trajectory tracked from PR 1 onward.
"""
from __future__ import annotations

import time

import jax
import numpy as np

# Reduced sweep for CI smoke runs (set by run.py --quick).
QUICK = False

# Structured results of the current process: list of dicts with at least
# {"name", "us_per_call"}; extra numeric fields (coalescing, ratios) ride
# along verbatim.
RECORDS: list[dict] = []


def time_jit(fn, *args, iters: int = 20, warmup: int = 3) -> float:
    """Median wall time (us) of a jitted call on this host."""
    if QUICK:
        iters, warmup = 5, 1
    f = jax.jit(fn)
    for _ in range(warmup):
        jax.block_until_ready(f(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(f(*args))
        times.append((time.perf_counter() - t0) * 1e6)
    times.sort()
    return times[len(times) // 2]


def median_iqr(samples) -> tuple[float, float]:
    """(median, interquartile range) of a list of repeated measurements.

    The IQR is the spread the row must quote next to any wall-clock
    number: on a shared runner a wall median whose IQR overlaps the
    comparator's is NOISE, not a regression signal.
    """
    xs = sorted(float(x) for x in samples)
    if not xs:
        return 0.0, 0.0
    return (float(np.percentile(xs, 50)),
            float(np.percentile(xs, 75) - np.percentile(xs, 25)))


def measure(fn, repeats: int = 5) -> tuple[float, float, list[float]]:
    """Run ``fn() -> float`` (one full measurement, e.g. a trace replay's
    wall seconds) ``repeats`` times and return (median, iqr, samples).
    Callers warm their jits BEFORE calling this."""
    if QUICK:
        repeats = max(2, repeats // 2)
    xs = [float(fn()) for _ in range(repeats)]
    med, iqr = median_iqr(xs)
    return med, iqr, xs


def emit(name: str, us: float, derived: str, *, tracked: str | None = None,
         noise_bound: tuple | list = (), **fields) -> None:
    """Print one CSV row and append the structured record.

    ``tracked`` names the field that IS the row's claim (the number the
    perf trajectory gates on); ``noise_bound`` lists fields reported for
    context only because they are host-wall measurements whose run-to-run
    spread (IQR) can swallow the effect.  Every serve row states both
    explicitly — a ratio that rides under a bare "noise" flag reads like
    a regression when it is weather.
    """
    rec = {"name": name, "us_per_call": round(us, 2), **fields}
    if tracked is not None:
        rec["tracked"] = tracked
        derived = f"{derived} tracked={tracked}"
    if noise_bound:
        rec["noise_bound"] = list(noise_bound)
        derived = f"{derived} noise_bound={','.join(noise_bound)}"
    print(f"{name},{us:.1f},{derived}")
    RECORDS.append(rec)
