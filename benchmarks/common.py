"""Shared benchmark helpers: timing and CSV emission."""
from __future__ import annotations

import time

import jax


def time_jit(fn, *args, iters: int = 20, warmup: int = 3) -> float:
    """Median wall time (us) of a jitted call on this host."""
    f = jax.jit(fn)
    for _ in range(warmup):
        jax.block_until_ready(f(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(f(*args))
        times.append((time.perf_counter() - t0) * 1e6)
    times.sort()
    return times[len(times) // 2]


def emit(name: str, us: float, derived: str) -> None:
    print(f"{name},{us:.1f},{derived}")
