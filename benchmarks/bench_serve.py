"""Paged serving suite — the continuous-batching runtime's scoreboard.

Two measurements, same-run (relative, XLA CPU):

  * ``serve/step_paged`` — one steady-state decode step, all slots
    active: the jit'd PAGED step (per-slot positions, page-table reads
    through ``vx.Paged``, fused page gather + fused FIELD=2 split) vs the
    jit'd DENSE step (fixed-slot cache, shared position counter — the
    pre-PR 5 engine).  Wall medians plus the gather-equation drop.
  * ``serve/trace_mixed`` — a seeded MIXED-LENGTH request trace (varied
    prompt lengths, varied generation lengths, staggered arrivals) driven
    through the paged ``Scheduler`` (admission, prefill, active-set
    batching, reclamation on finish) vs the dense fixed-slot server
    replayed on the same trace.  Tracked claims: tokens/s parity and PEAK
    CACHE BYTES — the paged runtime's peak scales with concurrently
    ACTIVE tokens (pages in use), the dense cache is a constant
    ``slots * max_len`` allocation regardless of traffic.
"""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from benchmarks.common import emit, time_jit
from repro.kernels._common import pytree_nbytes
from repro.models import decode as dec
from repro.models.transformer import ModelConfig, init_params
from repro.serve.scheduler import Scheduler


def _cfg() -> ModelConfig:
    # two attn positions x two superblocks, unrolled: the per-access path
    # pays 4 page gathers per step, the fused path ONE (countable claim).
    # d_model 256 keeps the step compute-dominant, so the tokens/s
    # comparison is not a pure dispatch-overhead race.
    return ModelConfig(
        name="bench-serve", d_model=256, n_layers=4, n_heads=4,
        n_kv_heads=2, d_ff=512, vocab=512, head_dim=64, mlp="swiglu",
        block_pattern=("attn", "attn"), window_pattern=(None, None),
        moe_pattern=(False, False),
        scan_layers=False, kernel_impl="ref", remat="none")


class _DenseServer:
    """The pre-PR 5 dense fixed-slot server (shared position counter,
    single-token admission) — the trace comparator."""

    def __init__(self, cfg, params, *, slots, max_len):
        self.cfg, self.params = cfg, params
        self.slots = slots
        self.cache = dec.init_cache(cfg, slots, max_len, jnp.float32)
        self.step_fn = jax.jit(
            lambda p, c, t: dec.decode_step(p, c, t, cfg, None),
            donate_argnums=1)
        self.active = [False] * slots
        self.tokens = [[] for _ in range(slots)]

    def add_request(self, prompt):
        toks = prompt if isinstance(prompt, list) else [prompt]
        for s in range(self.slots):
            if not self.active[s]:
                self.active[s] = True
                # dense engine has no prefill path: prompt collapses to
                # its last token (the old single-token limitation)
                self.tokens[s] = [toks[-1]]
                return s
        raise RuntimeError("no free slot")

    def step(self):
        cur = jnp.asarray([self.tokens[s][-1] if self.active[s] else 0
                           for s in range(self.slots)], jnp.int32)
        logits, self.cache = self.step_fn(self.params, self.cache, cur)
        nxt = np.asarray(jnp.argmax(logits.astype(jnp.float32), axis=-1))
        for s in range(self.slots):
            if self.active[s]:
                self.tokens[s].append(int(nxt[s]))

    def finish(self, slot):
        self.active[slot] = False
        return self.tokens[slot]


def _trace(slots: int, n_requests: int, max_len: int, seed: int = 0):
    """(arrival_step, prompt, gen_len) mixed-length request trace."""
    rng = np.random.default_rng(seed)
    out = []
    for r in range(n_requests):
        plen = int(rng.integers(1, max(2, max_len // 4)))
        prompt = rng.integers(0, 500, plen).tolist()
        gen = int(rng.integers(4, max(5, max_len // 3)))
        out.append((int(rng.integers(0, 3)) + 2 * r // slots, prompt, gen))
    return out


def _run_trace(server, trace, peak_bytes_fn) -> tuple[float, int, int]:
    """(wall_s, generated_tokens, peak_cache_bytes) of a trace replay."""
    pending = sorted(trace, key=lambda t: t[0])
    live: dict[int, int] = {}          # slot -> remaining tokens
    done = 0
    generated = 0
    peak = 0
    step_no = 0
    t0 = time.perf_counter()
    while done < len(trace):
        while pending and pending[0][0] <= step_no and \
                len(live) < server.slots:
            _, prompt, gen = pending.pop(0)
            slot = server.add_request(prompt)
            live[slot] = gen
        if live:
            server.step()
            generated += len(live)
            for slot in list(live):
                live[slot] -= 1
                if live[slot] == 0:
                    server.finish(slot)
                    del live[slot]
                    done += 1
        peak = max(peak, peak_bytes_fn())
        step_no += 1
    return time.perf_counter() - t0, generated, peak


def _count_gathers(fn, *args) -> int:
    def rec(jaxpr):
        c = 0
        for eqn in jaxpr.eqns:
            if eqn.primitive.name == "gather":
                c += 1
            for v in eqn.params.values():
                vs = v if isinstance(v, (list, tuple)) else [v]
                for x in vs:
                    sub = x if hasattr(x, "eqns") else (
                        x.jaxpr if hasattr(x, "jaxpr")
                        and hasattr(x.jaxpr, "eqns") else None)
                    if sub is not None:
                        c += rec(sub)
        return c
    return rec(jax.make_jaxpr(lambda *a: fn(*a))(*args).jaxpr)


def _bench_step() -> None:
    cfg = _cfg()
    params = init_params(cfg, jax.random.key(0))
    slots, max_len, ps = 4, 128, 16
    dense = dec.init_cache(cfg, slots, max_len, jnp.float32)
    paged = dec.init_paged_cache(cfg, slots, max_len, ps, jnp.float32)
    tok = jnp.arange(slots, dtype=jnp.int32) % cfg.vocab

    def paged_fused(p, c, t):
        return dec.paged_decode_step(p, c, t, cfg, None, fuse=True)

    def paged_per_access(p, c, t):
        return dec.paged_decode_step(p, c, t, cfg, None, fuse=False)

    def dense_step(p, c, t):
        return dec.decode_step(p, c, t, cfg, None, fuse=True)

    t_paged = time_jit(paged_fused, params, paged, tok)
    t_dense = time_jit(dense_step, params, dense, tok)
    # the tracked claim is deterministic: the fused paged step collapses
    # every layer's page-table read into ONE gather program (wall ratios
    # on shared XLA-CPU runners sit in the dispatch-noise floor)
    gf = _count_gathers(paged_fused, params, paged, tok)
    gp = _count_gathers(paged_per_access, params, paged, tok)
    emit("serve/step_paged", t_paged,
         f"dense_us={t_dense:.1f} ratio={t_paged / max(t_dense, 1e-9):.2f}x "
         f"paged_gathers={gf}vs{gp} slots={slots} max_len={max_len} "
         f"page={ps}",
         tracked="gathers_fused",
         noise_bound=("us_per_call", "dense_us", "vs_dense"),
         dense_us=round(t_dense, 2),
         vs_dense=round(t_paged / max(t_dense, 1e-9), 3),
         gathers_fused=gf, gathers_per_access=gp,
         slots=slots, max_len=max_len, page_size=ps)


def _bench_trace() -> None:
    cfg = _cfg()
    params = init_params(cfg, jax.random.key(0))
    slots = 4
    max_len = 64 if common.QUICK else 128
    n_req = 8 if common.QUICK else 24
    ps = 16
    trace = _trace(slots, n_req, max_len)

    # jits are per-instance closures: warm each server by replaying the
    # whole trace once (drains back to empty — every request finishes),
    # then take the median of N replays with IQR so the wall numbers
    # carry their own noise bar
    repeats = 3 if common.QUICK else 5
    sched = Scheduler(cfg, params, slots=slots, max_len=max_len,
                      page_size=ps)
    _run_trace(sched, trace, sched.cache.used_cache_bytes)       # warm
    runs_p = [_run_trace(sched, trace, sched.cache.used_cache_bytes)
              for _ in range(repeats)]
    gen_p = runs_p[0][1]
    peak_p = max(r[2] for r in runs_p)

    dense = _DenseServer(cfg, params, slots=slots, max_len=max_len)
    dense_bytes = pytree_nbytes(dense.cache)
    _run_trace(dense, trace, lambda: dense_bytes)                # warm
    runs_d = []
    for _ in range(repeats):
        dense.cache = dec.init_cache(cfg, slots, max_len, jnp.float32)
        runs_d.append(_run_trace(dense, trace, lambda: dense_bytes))
    gen_d = runs_d[0][1]

    tps_p, tps_p_iqr = common.median_iqr(
        [g / max(w, 1e-9) for w, g, _ in runs_p])
    tps_d, tps_d_iqr = common.median_iqr(
        [g / max(w, 1e-9) for w, g, _ in runs_d])
    # tracked claim: PEAK CACHE BYTES follow the trace's concurrently
    # active tokens (pages in use), not the constant slots x max_len
    # dense allocation; tokens/s medians carry their IQR and are marked
    # noise-bound — a ratio whose IQRs overlap is weather, not signal
    emit("serve/trace_mixed", 1e6 / max(tps_p, 1e-9),
         f"paged_tok_s={tps_p:.1f}±{tps_p_iqr:.1f} "
         f"dense_tok_s={tps_d:.1f}±{tps_d_iqr:.1f} "
         f"peak_paged_bytes={peak_p} dense_bytes={dense_bytes} "
         f"mem_ratio={dense_bytes / max(peak_p, 1):.2f}x requests={n_req}",
         tracked="mem_ratio",
         noise_bound=("tok_s_ratio", "paged_tok_s", "dense_tok_s"),
         paged_tok_s=round(tps_p, 2), dense_tok_s=round(tps_d, 2),
         paged_tok_s_iqr=round(tps_p_iqr, 2),
         dense_tok_s_iqr=round(tps_d_iqr, 2),
         tok_s_ratio=round(tps_p / max(tps_d, 1e-9), 3),
         wall_repeats=repeats,
         peak_cache_bytes_paged=int(peak_p),
         cache_bytes_dense=int(dense_bytes),
         mem_ratio=round(dense_bytes / max(peak_p, 1), 3),
         requests=n_req, slots=slots, max_len=max_len, page_size=ps)


def _bench_chaos() -> None:
    """``serve/chaos_degradation`` — tokens/s of the hardened runtime
    under a seeded fault plan (preemptions, NaN injection, slot death,
    spikes, malformed traffic) with ``check_invariants()`` forced on
    every tick, vs the same engine geometry serving the same workload
    clean on the untouched fast path.  The ratio bounds what the
    robustness machinery costs WHEN FAULTS FIRE; the clean path costs
    nothing (tests/test_chaos.py gates a single jit trace)."""
    from repro.serve.chaos import ChaosConfig, FaultPlan, run_plan

    cfg = _cfg()
    params = init_params(cfg, jax.random.key(0))
    slots, max_len, ps = 4, 64, 16
    n_req = 6 if common.QUICK else 12
    ccfg = ChaosConfig(seed=0, requests=n_req, steps=24, max_ticks=512,
                       max_prompt=max(2, max_len // 8), max_new_tokens=12)
    plan = FaultPlan(ccfg)

    def mk():
        return Scheduler(cfg, params, slots=slots, max_len=max_len,
                         page_size=ps, guard_nan=True)

    def clean_run(sched):
        """The plan's workload with NO faults and NO forced audits."""
        pending = list(plan.workload)
        reqs, tick = [], 0
        t0 = time.perf_counter()
        while tick < ccfg.max_ticks:
            while pending and pending[0][0] <= tick:
                try:
                    reqs.append(sched.submit(pending[0][1],
                                             max_new_tokens=pending[0][2]))
                    pending.pop(0)
                except Exception:      # noqa: BLE001 — backpressure: retry
                    pending[0] = (tick + 1, *pending[0][1:])
                    break
            sched.tick()
            tick += 1
            if not pending and sched.drained():
                break
        return time.perf_counter() - t0, sum(r.generated for r in reqs)

    clean = mk()
    clean_run(clean)                             # warm the per-instance jits
    wall_c, gen_c = clean_run(clean)

    chaotic = mk()
    run_plan(chaotic, plan)                      # warm
    t0 = time.perf_counter()
    rep = run_plan(chaotic, plan)
    wall_f = time.perf_counter() - t0
    gen_f = sum(r.generated for r in rep.submitted)

    tps_c = gen_c / max(wall_c, 1e-9)
    tps_f = gen_f / max(wall_f, 1e-9)
    emit("serve/chaos_degradation", wall_f * 1e6 / max(gen_f, 1),
         f"clean_tok_s={tps_c:.1f} chaos_tok_s={tps_f:.1f} "
         f"degradation={tps_f / max(tps_c, 1e-9):.2f}x ticks={rep.ticks} "
         f"preemptions={rep.preemptions} nan_failures={rep.nan_failures} "
         f"invariant_checks={rep.invariant_checks} "
         f"all_terminal={rep.all_terminal}",
         tracked="all_terminal",
         noise_bound=("degradation", "clean_tok_s", "chaos_tok_s"),
         clean_tok_s=round(tps_c, 2), chaos_tok_s=round(tps_f, 2),
         degradation=round(tps_f / max(tps_c, 1e-9), 3),
         ticks=rep.ticks, preemptions=rep.preemptions,
         nan_failures=rep.nan_failures,
         invariant_checks=rep.invariant_checks,
         all_terminal=bool(rep.all_terminal),
         requests=n_req, slots=slots, max_len=max_len, page_size=ps)


def _bench_fleet_failover() -> None:
    """``serve/fleet_failover`` — tokens/s of a 3-replica fleet serving
    a fixed workload CLEAN vs the same workload with one replica killed
    mid-decode (resident work migrates via the replay cursor and resumes
    elsewhere; the dead replica respawns with an empty pool).  The ratio
    prices a failover: re-prefill + replay on the target replica plus
    the respawned replica's jit re-trace — the clean path is untouched.
    Also reports how many requests migrated AND finished (recovered)."""
    from repro.serve.fleet import FleetRouter
    from repro.serve.lifecycle import RequestState

    cfg = _cfg()
    params = init_params(cfg, jax.random.key(0))
    replicas, slots, max_len, ps = 3, 2, 64, 16
    n_req = 6 if common.QUICK else 12
    rng = np.random.default_rng(0)
    workload = [(rng.integers(0, 500, int(rng.integers(2, 8))).tolist(),
                 int(rng.integers(6, 14))) for _ in range(n_req)]

    def mk():
        return FleetRouter(cfg, params, replicas=replicas, slots=slots,
                           max_len=max_len, page_size=ps)

    def drive(router, *, kill_at=None):
        reqs = []
        for prompt, gen in workload:
            for _ in range(64):
                try:
                    reqs.append(router.submit(prompt, max_new_tokens=gen))
                    break
                except Exception:      # noqa: BLE001 — backpressure: tick
                    router.tick()
        t0 = time.perf_counter()
        while not (router.drained() and all(r.terminal for r in reqs)):
            if kill_at is not None and router.tick_no + 1 == kill_at:
                router.kill_replica(0, reason="bench kill")
            router.tick()
        wall = time.perf_counter() - t0
        gen_n = sum(r.generated for r in reqs)
        rec = sum(1 for r in reqs if r.migrations > 0
                  and r.state is RequestState.FINISHED)
        return wall, gen_n, rec

    drive(mk())                                  # warm a clean fleet's jits
    wall_c, gen_c, _ = drive(mk())
    drive(mk(), kill_at=4)                       # warm the failover path
    wall_k, gen_k, recovered = drive(mk(), kill_at=4)

    tps_c = gen_c / max(wall_c, 1e-9)
    tps_k = gen_k / max(wall_k, 1e-9)
    emit("serve/fleet_failover", wall_k * 1e6 / max(gen_k, 1),
         f"clean_tok_s={tps_c:.1f} one_kill_tok_s={tps_k:.1f} "
         f"degradation={tps_k / max(tps_c, 1e-9):.2f}x "
         f"recovered={recovered} replicas={replicas}",
         tracked="recovered_requests",
         noise_bound=("degradation", "clean_tok_s", "one_kill_tok_s"),
         clean_tok_s=round(tps_c, 2), one_kill_tok_s=round(tps_k, 2),
         degradation=round(tps_k / max(tps_c, 1e-9), 3),
         recovered_requests=int(recovered), replicas=replicas,
         requests=n_req, slots=slots, max_len=max_len, page_size=ps)


def _bench_prefix_share() -> None:
    """``serve/prefix_share`` — a shared-system-prompt trace (every
    request opens with the same multi-page prefix, arrivals staggered so
    the first prefill publishes before the rest admit) served with the
    radix prefix cache ON vs OFF.  Tracked claims: PEAK CACHE BYTES drop
    (borrowers point their tables at the donor's pages instead of
    refilling them — ``mem_ratio`` > 1 is the win over PR 5 paged) and
    the trie hit rate / tokens reused.  Decode over shared pages is
    bit-exact vs private copies (tests/test_prefix.py gates it), so this
    row prices memory only."""
    cfg = _cfg()
    params = init_params(cfg, jax.random.key(0))
    slots, max_len, ps = 4, 128, 16
    n_req = slots                   # all concurrently live at peak
    sys_len = 4 * ps                # 4 shared full pages per request
    rng = np.random.default_rng(0)
    sys_prompt = rng.integers(0, 500, sys_len).tolist()
    prompts = [sys_prompt + rng.integers(0, 500, 6).tolist()
               for _ in range(n_req)]

    def drive(prefix: bool):
        sched = Scheduler(cfg, params, slots=slots, max_len=max_len,
                          page_size=ps, prefix_cache=prefix)
        peak = 0
        reqs = []
        for p in prompts:           # sequential sync admission: request
            sched.add_request(p)    # 0 publishes, 1..n-1 adopt
            peak = max(peak, sched.cache.used_cache_bytes())
        for _ in range(8):
            sched.step()
            peak = max(peak, sched.cache.used_cache_bytes())
        return peak, sched.stats()

    peak_on, st = drive(True)
    peak_off, _ = drive(False)
    px = st["prefix"]
    emit("serve/prefix_share", float(peak_on) / 1e3,
         f"peak_shared_bytes={peak_on} peak_private_bytes={peak_off} "
         f"mem_ratio={peak_off / max(peak_on, 1):.2f}x "
         f"hit_rate={px['hit_rate']:.2f} "
         f"tokens_reused={px['tokens_reused']} "
         f"shared_pages={st['shared_pages']} requests={n_req}",
         tracked="mem_ratio",
         peak_cache_bytes_shared=int(peak_on),
         peak_cache_bytes_private=int(peak_off),
         mem_ratio=round(peak_off / max(peak_on, 1), 3),
         hit_rate=round(px["hit_rate"], 3),
         tokens_reused=int(px["tokens_reused"]),
         shared_pages=int(st["shared_pages"]),
         requests=n_req, slots=slots, max_len=max_len, page_size=ps)


def _bench_chunked_admission() -> None:
    """``serve/chunked_admission`` — inter-token latency of an already-
    running decode stream when a LONG prompt is admitted mid-flight:
    BLOCKING admission (the whole prefill runs inside one admission
    call, the pre-PR 8 schedule) vs CHUNKED (one page-sized chunk per
    tick interleaved with decode steps).  Tracked claim: the worst-case
    inter-token gap no longer spikes by the full prefill cost — it is
    bounded by ONE chunk."""
    cfg = _cfg()
    params = init_params(cfg, jax.random.key(0))
    slots, max_len, ps = 2, 128, 16
    long_len = 6 * ps               # 96-token prompt = 6 prefill chunks
    rng = np.random.default_rng(1)
    long_prompt = rng.integers(0, 500, long_len).tolist()
    ticks, admit_at = 18, 4

    def drive(chunked: bool):
        sched = Scheduler(cfg, params, slots=slots, max_len=max_len,
                          page_size=ps, chunk_pages=1)
        # warm THIS instance's jits (chunk prefill, step, sample,
        # release) — the measured gaps must price scheduling, not XLA
        # compiles; the chunk jit is one fixed-width trace, so one
        # warmup chunk covers every later chunk
        w = sched.add_request(long_prompt[:ps + 1])
        sched.step()
        sched.finish(w)
        bg = sched.submit([7], max_new_tokens=ticks + 4)
        sched.tick()
        gaps, lr = [], None
        for i in range(ticks):
            t0 = time.perf_counter()
            if i == admit_at:
                if chunked:
                    lr = sched.submit(long_prompt, max_new_tokens=2)
                else:
                    lr = sched.add_request(long_prompt)   # blocks here
            sched.tick()
            gaps.append(time.perf_counter() - t0)
        del bg, lr
        return gaps

    g_chunk, g_block = drive(True), drive(False)

    def p99(g):
        return sorted(g)[min(len(g) - 1, int(0.99 * len(g)))]

    emit("serve/chunked_admission", p99(g_chunk) * 1e6,
         f"p99_chunked_us={p99(g_chunk) * 1e6:.0f} "
         f"p99_blocking_us={p99(g_block) * 1e6:.0f} "
         f"spike_ratio={p99(g_block) / max(p99(g_chunk), 1e-9):.2f}x "
         f"prompt_pages={long_len // ps} chunk_pages=1",
         tracked="spike_ratio",
         noise_bound=("p99_chunked_us", "p99_blocking_us"),
         p99_chunked_us=round(p99(g_chunk) * 1e6, 1),
         p99_blocking_us=round(p99(g_block) * 1e6, 1),
         spike_ratio=round(p99(g_block) / max(p99(g_chunk), 1e-9), 3),
         prompt_pages=long_len // ps, chunk_pages=1,
         slots=slots, max_len=max_len, page_size=ps)


def _bench_quantized_pool() -> None:
    """``serve/quantized_pool`` — the PR 9 memory claim: the int8 page
    pool (per-page scales, dequant fused into the one page-gather
    program) serving the SAME mixed trace as the float32 paged runtime.
    Tracked claims: PEAK CACHE BYTES vs the dense float32 allocation
    (>= ~4x — the scale side tensor is the only overhead) and vs the
    float32 paged peak (~4x at equal pages in use), tokens/s parity
    (the dequant adds zero gather equations and zero launches —
    tests/test_quant_pool.py gates it), and the bounded-error sweep:
    worst |quant - float| logit gap over page_size x slots forced-
    teacher decodes, reported relative to the float logit scale."""
    cfg = _cfg()
    params = init_params(cfg, jax.random.key(0))
    slots = 4
    max_len = 64 if common.QUICK else 128
    n_req = 8 if common.QUICK else 24
    ps = 16
    trace = _trace(slots, n_req, max_len)

    repeats = 3 if common.QUICK else 5

    def replay(kv_quant):
        sched = Scheduler(cfg, params, slots=slots, max_len=max_len,
                          page_size=ps, kv_quant=kv_quant)
        _run_trace(sched, trace, sched.cache.used_cache_bytes)   # warm
        runs = [_run_trace(sched, trace, sched.cache.used_cache_bytes)
                for _ in range(repeats)]
        tps, tps_iqr = common.median_iqr(
            [g / max(w, 1e-9) for w, g, _ in runs])
        return tps, tps_iqr, runs[0][1], max(r[2] for r in runs)

    tps_q, tps_q_iqr, gen_q, peak_q = replay("int8")
    tps_f, tps_f_iqr, gen_f, peak_f = replay(None)
    dense_bytes = pytree_nbytes(dec.init_cache(cfg, slots, max_len,
                                               jnp.float32))

    # bounded-error sweep: forced-teacher (both pools fed the FLOAT
    # stream's argmax) so the gap measures quantization, not divergence
    worst_rel = 0.0
    for sps, sslots in ((8, 2), (16, 4)):
        cf = dec.init_paged_cache(cfg, sslots, 64, sps, jnp.float32)
        cq = dec.init_paged_cache(cfg, sslots, 64, sps, jnp.float32,
                                  quantize="int8")
        step = jax.jit(lambda p, c, t: dec.paged_decode_step(
            p, c, t, cfg, None, fuse=True))
        tok = jnp.arange(sslots, dtype=jnp.int32) + 3
        for _ in range(8):
            lf, cf = step(params, cf, tok)
            lq, cq = step(params, cq, tok)
            gap = float(jnp.max(jnp.abs(lf - lq)))
            scale = max(float(jnp.max(jnp.abs(lf))), 1e-9)
            worst_rel = max(worst_rel, gap / scale)
            tok = jnp.argmax(lf, axis=-1).astype(jnp.int32)

    emit("serve/quantized_pool", 1e6 / max(tps_q, 1e-9),
         f"int8_tok_s={tps_q:.1f}±{tps_q_iqr:.1f} "
         f"f32_tok_s={tps_f:.1f}±{tps_f_iqr:.1f} "
         f"peak_int8_bytes={peak_q} peak_f32_bytes={peak_f} "
         f"dense_f32_bytes={dense_bytes} "
         f"mem_ratio={dense_bytes / max(peak_q, 1):.2f}x "
         f"vs_paged_f32={peak_f / max(peak_q, 1):.2f}x "
         f"max_rel_logit_err={worst_rel:.4f}",
         tracked="mem_ratio",
         noise_bound=("tok_s_ratio", "int8_tok_s", "f32_tok_s"),
         int8_tok_s=round(tps_q, 2), f32_tok_s=round(tps_f, 2),
         int8_tok_s_iqr=round(tps_q_iqr, 2),
         f32_tok_s_iqr=round(tps_f_iqr, 2),
         tok_s_ratio=round(tps_q / max(tps_f, 1e-9), 3),
         wall_repeats=repeats,
         peak_cache_bytes_int8=int(peak_q),
         peak_cache_bytes_f32=int(peak_f),
         cache_bytes_dense_f32=int(dense_bytes),
         mem_ratio=round(dense_bytes / max(peak_q, 1), 3),
         mem_ratio_vs_paged_f32=round(peak_f / max(peak_q, 1), 3),
         max_rel_logit_err=round(worst_rel, 5),
         requests=n_req, slots=slots, max_len=max_len, page_size=ps)


def _spec_pair(n_layers: int, dl: int, d_model: int):
    """Target/draft pair with STRUCTURALLY exact agreement, for measuring
    speculative decode at a controlled acceptance rate.

    The target's superblocks ``>= dl`` get their attention and FFN
    output projections zeroed — a zeroed ``wo`` turns the residual
    branch into ``x + 0``, so those layers are exact identities.  The
    draft is then the LIVE prefix of the same stack (blocks sliced
    ``[:dl]``, shared embed / final norm): greedy(draft) == greedy(target)
    at every position by construction, acceptance is ~1.0, and the row
    isolates the RUNTIME claim — how much full-depth target compute the
    one fused K-wide gather/verify program amortizes per accepted token —
    from draft quality, which is a modelling question, not a runtime one.
    The target still PAYS full-depth compute: XLA cannot see that the
    zeroed matmuls are dead."""
    tcfg = ModelConfig(
        name="bench-spec-target", d_model=d_model, n_layers=n_layers,
        n_heads=8, n_kv_heads=4, d_ff=2 * d_model, vocab=512,
        head_dim=d_model // 8, mlp="swiglu",
        block_pattern=("attn",), window_pattern=(None,),
        moe_pattern=(False,), scan_layers=False, kernel_impl="ref",
        remat="none")
    dcfg = dataclasses.replace(tcfg, name="bench-spec-draft", n_layers=dl)
    tparams = init_params(tcfg, jax.random.key(0))

    def _ident(path, x):
        if any(getattr(k, "key", None) == "wo" for k in path):
            return x.at[dl:].set(0.0)
        return x

    tparams["blocks"] = jax.tree_util.tree_map_with_path(
        _ident, tparams["blocks"])
    dparams = {"embed": tparams["embed"],
               "final_norm": tparams["final_norm"],
               "blocks": jax.tree.map(lambda x: x[:dl], tparams["blocks"])}
    return tcfg, tparams, dcfg, dparams


def _bench_speculative() -> None:
    """``serve/speculative`` — PR 10: K-token speculative decode through
    ONE fused page-gather/verify program per step vs the same scheduler
    decoding one token per step, on the same mixed workload.  The
    draft/target pair is built for acceptance ~1.0 (see _spec_pair), so
    the tracked claim is the tokens/s RATIO at that acceptance: K
    accepted tokens share one full-depth launch's weight streaming and
    one page gather — the serve-side analogue of EARTH amortizing one
    memory transaction across lanes.  The spec and plain token streams
    are asserted equal (greedy, pad-safe stacks) and the row carries the
    speculative scheduler's TTFT / inter-token percentiles."""
    n_layers, dl, k = (6, 1, 4) if common.QUICK else (8, 1, 4)
    d_model = 256 if common.QUICK else 512
    tcfg, tparams, dcfg, dparams = _spec_pair(n_layers, dl, d_model)
    slots, max_len, ps = 4, 64, 16
    n_req = 6 if common.QUICK else 8
    rng = np.random.default_rng(0)
    # generation-heavy workload: decode steps dominate, so the ratio
    # reflects steady-state verify amortization, not prefill overhead
    workload = [(rng.integers(0, 500, int(rng.integers(3, 10))).tolist(),
                 int(rng.integers(24, 40))) for _ in range(n_req)]
    repeats = 2 if common.QUICK else 5

    def drive(spec: bool):
        kw = (dict(speculate=k, draft_cfg=dcfg, draft_params=dparams)
              if spec else {})
        sched = Scheduler(tcfg, tparams, slots=slots, max_len=max_len,
                          page_size=ps, **kw)

        def one():
            reqs = [sched.submit(p, max_new_tokens=g) for p, g in workload]
            t0 = time.perf_counter()
            for _ in range(4096):
                sched.tick()
                if sched.drained():
                    break
            wall = time.perf_counter() - t0
            return wall, sum(r.generated for r in reqs), reqs

        one()                           # warm this instance's jits
        runs = [one() for _ in range(repeats)]
        tps, tps_iqr = common.median_iqr(
            [g / max(w, 1e-9) for w, g, _ in runs])
        streams = [list(r.tokens) for r in runs[-1][2]]
        return tps, tps_iqr, streams, sched

    tps_s, tps_s_iqr, streams_s, sspec = drive(True)
    tps_p, tps_p_iqr, streams_p, _ = drive(False)
    st = sspec.stats()
    sp = st["speculative"]
    lat = st["latency"]
    ratio = tps_s / max(tps_p, 1e-9)
    match = streams_s == streams_p

    emit("serve/speculative", 1e6 / max(tps_s, 1e-9),
         f"spec_tok_s={tps_s:.1f}±{tps_s_iqr:.1f} "
         f"plain_tok_s={tps_p:.1f}±{tps_p_iqr:.1f} "
         f"tok_s_ratio={ratio:.2f}x k={k} "
         f"acceptance={sp['acceptance']:.2f} "
         f"verify_steps={sp['verify_steps']} streams_match={match} "
         f"draft_layers={dl}of{n_layers} "
         f"itl_p50_ms={lat.get('itl_p50_s', 0.0) * 1e3:.2f}",
         tracked="tok_s_ratio",
         noise_bound=("spec_tok_s", "plain_tok_s"),
         spec_tok_s=round(tps_s, 2), plain_tok_s=round(tps_p, 2),
         spec_tok_s_iqr=round(tps_s_iqr, 2),
         plain_tok_s_iqr=round(tps_p_iqr, 2),
         tok_s_ratio=round(ratio, 3), k=k,
         acceptance=round(sp["acceptance"], 3),
         proposed=int(sp["proposed"]), accepted=int(sp["accepted"]),
         verify_steps=int(sp["verify_steps"]),
         streams_match=bool(match),
         draft_layers=dl, target_layers=n_layers,
         ttft_p50_ms=round(lat.get("ttft_p50_s", 0.0) * 1e3, 2),
         ttft_p99_ms=round(lat.get("ttft_p99_s", 0.0) * 1e3, 2),
         itl_p50_ms=round(lat.get("itl_p50_s", 0.0) * 1e3, 3),
         itl_p99_ms=round(lat.get("itl_p99_s", 0.0) * 1e3, 3),
         wall_repeats=repeats,
         requests=n_req, slots=slots, max_len=max_len, page_size=ps)


def run() -> None:
    _bench_step()
    _bench_trace()
    _bench_chaos()
    _bench_fleet_failover()
    _bench_prefix_share()
    _bench_chunked_admission()
    _bench_quantized_pool()
    _bench_speculative()


if __name__ == "__main__":
    run()
