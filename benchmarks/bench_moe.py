"""MoE dispatch benchmark — EARTH shift-network compaction vs argsort.

The routing step packs each device's owned (token, slot) units into a
fixed-capacity buffer. EARTH's order-preserving compaction does it with
log2(n) static shifts; the XLA-native alternative is a stable argsort.
Both feed the same ragged grouped GEMM; correctness is asserted equal.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from benchmarks.common import emit, time_jit
from repro.models.moe import MoESpec, init_moe, moe_ffn_local


def run() -> None:
    d, E, k = 256, 16, 2
    for T in (1024,) if common.QUICK else (1024, 4096):
        spec_e = MoESpec(n_experts=E, top_k=k, d_ff=512, dispatch="earth")
        spec_s = MoESpec(n_experts=E, top_k=k, d_ff=512, dispatch="sort")
        params = init_moe(jax.random.key(0), d, spec_e, jnp.float32)
        x = jax.random.normal(jax.random.key(1), (T, d))

        def run_spec(spec):
            return lambda *a: moe_ffn_local(
                a[0], a[1], a[2], a[3], a[4], spec, model_axis=None,
                data_axes=(), n_shards=1)[0]

        args = (params["router"], params["wg"], params["wu"], params["wo"], x)
        t_earth = time_jit(run_spec(spec_e), *args)
        t_sort = time_jit(run_spec(spec_s), *args)
        ye = jax.jit(run_spec(spec_e))(*args)
        ys = jax.jit(run_spec(spec_s))(*args)
        np.testing.assert_allclose(np.asarray(ye), np.asarray(ys),
                                   rtol=2e-4, atol=2e-4)
        emit(f"moe/dispatch_T{T}", t_earth,
             f"argsort_us={t_sort:.1f} equal_outputs=true "
             f"units={T*k} experts={E}")


if __name__ == "__main__":
    run()
