"""Whole-step access fusion suite — the step-level scheduler's scoreboard.

Three measurements, all same-run (relative, XLA CPU):

  * ``step/decode_*`` — a 4-layer decode step, FUSED (one hoisted segment
    load splits every layer's KV cache, single-token reorganizations
    inlined) vs PER-ACCESS (every layer launches its own kernels, the PR 1
    path).  Also reports the jaxpr-level kernel-launch and mask-operand
    counts (jax.make_jaxpr — no timing in the regression-gated numbers).
  * ``step/decode_longctx`` — the PR 4 newly-unlocked path: a seq-sharded
    long-context (B=1) decode step, FUSED via the sharding-aware vx
    lowering (shard-local KV split under shard_map) vs PER-ACCESS, run on
    8 fake devices in a subprocess.  Wall time there is SPMD-simulation
    bound; the tracked claim is the jaxpr launch/mask-operand drop.
  * ``step/pipeline`` — input pipeline with the pack+unpack segment round
    trip elided by plan composition vs materializing the AoS buffer.
  * ``step/bank_s{±k}`` — runtime-stride dispatch through the plan bank's
    ``lax.switch`` (compiled constant masks) vs the dynamic-count Pallas
    kernel (impl="pallas_dynamic"), per banked stride; negative strides
    wrap the dynamic kernel in the Reverser (plan on |s|, flip output).
  * ``step/lsdo_many`` — whole-step LSDO: several strided loads through ONE
    multi-access (sum_T, mlen) plan vs one batched plan per access.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks import common
from benchmarks.common import emit, time_jit
from repro import vx
from repro.core import accessfuse, lsdo
from repro.models import decode as dec
from repro.models.transformer import ModelConfig, init_params


def _decode_setup(layers: int, batch: int, seq: int, hd: int):
    cfg = ModelConfig(
        name=f"bench-step-L{layers}", d_model=2 * hd, n_layers=layers,
        n_heads=2, n_kv_heads=2, d_ff=0, vocab=256, head_dim=hd,
        mlp="none", scan_layers=False, kernel_impl="pallas", remat="none")
    params = init_params(cfg, jax.random.key(0))
    cache = dec.init_cache(cfg, batch, seq, jnp.float32)
    tok = jnp.arange(batch, dtype=jnp.int32) % cfg.vocab
    return cfg, params, cache, tok


def _bench_decode() -> None:
    layers, batch, seq, hd = 4, 4, 128, 64
    cfg, params, cache, tok = _decode_setup(layers, batch, seq, hd)

    def fused(p, c, t):
        return dec.decode_step(p, c, t, cfg, None, fuse=True)

    def per_access(p, c, t):
        return dec.decode_step(p, c, t, cfg, None, fuse=False)

    t_f = time_jit(fused, params, cache, tok)
    t_p = time_jit(per_access, params, cache, tok)
    # launch accounting under the TPU lowering decision (off-TPU the
    # scheduler would inline the merged group on the XLA path)
    with accessfuse.pinned_kernel_lowering():
        lf, mf = accessfuse.jaxpr_access_counts(fused, params, cache, tok)
    lp, mp = accessfuse.jaxpr_access_counts(per_access, params, cache, tok)
    emit(f"step/decode_L{layers}", t_f,
         f"per_access_us={t_p:.1f} speedup={t_p / max(t_f, 1e-9):.2f}x "
         f"launches={lf}vs{lp} mask_ops={mf}vs{mp}",
         per_access_us=round(t_p, 2),
         speedup=round(t_p / max(t_f, 1e-9), 3),
         launches_fused=lf, launches_per_access=lp,
         mask_ops_fused=mf, mask_ops_per_access=mp)


def _bench_decode_long_context() -> None:
    """The PR 4 newly-unlocked path: seq-sharded (long-context) decode
    with step fusion vs the per-access path it was pinned to before.

    Runs in a subprocess on 8 fake devices (this process must keep seeing
    1 device — the dry-run contract); same-run medians plus jaxpr-level
    launch/mask counts, all measured INSIDE the one child."""
    import json
    import os
    import subprocess
    import sys

    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(root, "src") + os.pathsep + root
    cmd = [sys.executable,
           os.path.join(root, "benchmarks", "_bench_longctx.py")]
    if common.QUICK:
        cmd.append("--quick")
    r = subprocess.run(cmd, capture_output=True, text=True, timeout=900,
                       env=env, cwd=root)
    if r.returncode != 0:
        raise RuntimeError(f"longctx child failed:\n{r.stdout[-2000:]}\n"
                           f"{r.stderr[-3000:]}")
    rec = json.loads(r.stdout.strip().splitlines()[-1])
    t_f, t_p = rec.pop("fused_us"), rec["per_access_us"]
    emit("step/decode_longctx", t_f,
         f"per_access_us={t_p:.1f} speedup={t_p / max(t_f, 1e-9):.2f}x "
         f"launches={rec['launches_fused']}vs{rec['launches_per_access']} "
         f"mask_ops={rec['mask_ops_fused']}vs{rec['mask_ops_per_access']} "
         f"nshards={rec['nshards']} seq={rec['seq']} spmd_sim_bound=true",
         speedup=round(t_p / max(t_f, 1e-9), 3), **rec)


def _bench_pipeline() -> None:
    from repro.data.pipeline import DataConfig, SyntheticAoSPipeline
    iters = 11 if common.QUICK else 31
    cfg = DataConfig(vocab=1000, seq_len=256 if common.QUICK else 1024,
                     global_batch=8)

    def median_wall(fused: bool) -> float:
        pipe = SyntheticAoSPipeline(cfg)
        times = []
        for _ in range(iters):
            t0 = time.perf_counter()
            batch = pipe.next_batch(fused=fused)
            jax.block_until_ready(batch["tokens"])
            times.append((time.perf_counter() - t0) * 1e6)
        times.sort()
        return times[len(times) // 2]

    t_f = median_wall(True)
    t_u = median_wall(False)
    emit("step/pipeline", t_f,
         f"unfused_us={t_u:.1f} speedup={t_u / max(t_f, 1e-9):.2f}x",
         unfused_us=round(t_u, 2),
         speedup=round(t_u / max(t_f, 1e-9), 3))


def _median_us(fn, *args, iters: int = 15) -> float:
    """Local fixed-iteration timer: the bank cells are small (~100us) and
    the QUICK 5-iteration median is too noisy for a per-stride claim."""
    f = jax.jit(fn)
    jax.block_until_ready(f(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(f(*args))
        times.append((time.perf_counter() - t0) * 1e6)
    times.sort()
    return times[len(times) // 2]


def _bench_bank() -> None:
    n, vl, rows = 256, 16, 64
    offset = n // 2
    win = jnp.broadcast_to(jnp.arange(n, dtype=jnp.float32), (rows, n))
    strides = ((1, 2, 4, -2) if common.QUICK
               else tuple(range(1, 9)) + tuple(-s for s in range(1, 9)))

    bank_spec = vx.Strided(n=n, stride=vx.BANK, offset=offset, vl=vl)

    def bank_fn(w, s):
        return vx.gather(bank_spec, w, stride=s)

    for stride in strides:
        t_bank = _median_us(bank_fn, win, jnp.int32(stride))
        s = abs(stride)
        base = offset + (vl - 1) * stride if stride < 0 else offset
        dyn_spec = vx.Strided(n=n, stride=s, offset=base, vl=vl)
        if stride < 0:   # Reverser around the dynamic kernel
            t_dyn = _median_us(
                lambda w, sp=dyn_spec: jnp.flip(vx.gather(
                    sp, w, policy="pallas_dynamic"), -1), win)
        else:
            t_dyn = _median_us(
                lambda w, sp=dyn_spec: vx.gather(
                    sp, w, policy="pallas_dynamic"), win)
        emit(f"step/bank_s{stride}", t_bank,
             f"dynamic_us={t_dyn:.1f} "
             f"vs_dynamic={t_dyn / max(t_bank, 1e-9):.1f}x",
             dynamic_us=round(t_dyn, 2),
             vs_dynamic=round(t_dyn / max(t_bank, 1e-9), 3))


def _bench_lsdo_many() -> None:
    from repro.core import shiftplan
    buf = jnp.arange(1 << 14, dtype=jnp.float32)
    mlen = 128
    specs = [(0, 2, 64), (7, 3, 40), (513, 4, 32), (1025, 1, 100),
             (2048, 8, 16), (100, -4, 50)]
    plans = [lsdo.plan_strided(b, s, v, mlen) for b, s, v in specs]

    def fused(b):
        return lsdo.load_strided_many(b, plans)

    def per_access(b):
        return [lsdo.load_strided(b, p) for p in plans]

    # wide-op accounting (the TPU dispatch metric): ONE multi-access plan
    # applies <= log2(mlen) union layers to the whole stack; per-access
    # batched plans each re-apply their own layer chain
    rows = []
    wide_per = 0
    for p in plans:
        s = abs(p.stride) if p.stride != 0 else 1
        offs = tuple(t.offset for t in p.transactions)
        cnts = tuple(t.count for t in p.transactions)
        wide_per += shiftplan.batched_gather_plan(mlen, s, offs,
                                                 cnts).wide_ops
        rows.extend((s, o, c) for o, c in zip(offs, cnts))
    wide_fused = shiftplan.multi_gather_plan(mlen, tuple(rows)).wide_ops

    # both paths land in the ~100us dispatch-noise floor on XLA CPU, so the
    # wall-clock ratio is not a stable claim — the asserted metric is the
    # wide-op count (one union-layer plan vs per-access chains), which is
    # what survives on TPU where dispatch is not the bound
    t_f = _median_us(fused, buf, iters=101)
    t_p = _median_us(per_access, buf, iters=101)
    emit("step/lsdo_many", t_f,
         f"per_access_us={t_p:.1f} speedup={t_p / max(t_f, 1e-9):.2f}x "
         f"accesses={len(plans)} wide_ops={wide_fused}vs{wide_per} "
         f"dispatch_noise_bound=true",
         per_access_us=round(t_p, 2),
         speedup=round(t_p / max(t_f, 1e-9), 3),
         dispatch_noise_bound=True,
         wide_ops_fused=wide_fused, wide_ops_per_access=wide_per)


def run() -> None:
    _bench_decode()
    _bench_decode_long_context()
    _bench_pipeline()
    _bench_bank()
    _bench_lsdo_many()


if __name__ == "__main__":
    run()
