"""Fig. 13 analogue — segment (AoS<->SoA) handling, fused vs unfused vs buffer.

EARTH claims parity in performance with a segment buffer while removing the
2 x 8 x MLEN buffer. We compare, per FIELDS in 2..8:

  * FUSED path: ONE compiled-permutation shift-network pass emitting all
    fields (the RCVRF bulk-transposition analogue, core/shiftplan.py),
  * unfused path: ``fields`` sequential dynamic-count gather networks
    (the seed path, measured in the same run),
  * buffer path: materialized (FIELDS, m) transpose scratch then row reads
    (the Saturn segment-buffer dataflow),
and report wall time + scratch bytes (the Fig. 14 area claim analogue).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks import common
from benchmarks.common import emit, time_jit
from repro import vx
from repro.core import scg, shiftnet, shiftplan

MLEN = 128


def buffer_path(aos, fields):
    m = aos.shape[-1] // fields
    buf = aos.reshape(aos.shape[:-1] + (m, fields))      # segment buffer
    buf = jnp.swapaxes(buf, -1, -2)                      # bulk transpose
    return [buf[..., f, :] for f in range(fields)]


def fused_path(aos, fields):
    from repro.kernels import segment as seg
    n = aos.shape[-1]
    mode, plans = shiftplan.segment_deinterleave_plans(n, fields)
    masks, spans = seg._stack_masks(plans)
    return seg.route_deinterleave(aos, jnp.asarray(masks), mode, plans,
                                  spans, fields)


def unfused_path(aos, fields):
    n = aos.shape[-1]
    m = n // fields
    outs = []
    for f in range(fields):
        shift, valid = scg.gather_counts(n, fields, f, m)
        res = shiftnet.gather_network(aos, shift[None, :], valid[None, :],
                                      axis=-1)
        outs.append(jax.lax.slice(res.payload, (0, 0), (aos.shape[0], m)))
    return outs


def run() -> None:
    rows = 64
    field_sweep = (2, 4) if common.QUICK else (2, 3, 4, 5, 6, 7, 8)
    for fields in field_sweep:
        m = MLEN
        aos = jnp.arange(rows * fields * m,
                         dtype=jnp.float32).reshape(rows, fields * m)
        mode, plans = shiftplan.segment_deinterleave_plans(fields * m,
                                                           fields)
        wide_ops = sum(p.num_shifts for p in plans)
        passes = 1 if mode == "fused" else fields
        t_fused = time_jit(lambda a, f=fields: fused_path(a, f), aos)
        t_unfused = time_jit(lambda a, f=fields: unfused_path(a, f), aos)
        t_buf = time_jit(lambda a, f=fields: buffer_path(a, f), aos)
        scratch_buffer = 2 * 8 * MLEN * 4  # dual 8xMLEN f32 buffers (paper)
        emit(f"segment/f{fields}", t_fused,
             f"unfused_us={t_unfused:.1f} buffer_us={t_buf:.1f} "
             f"vs_unfused={t_unfused/max(t_fused,1e-9):.2f}x "
             f"mode={mode} passes={passes}(seed {fields}) "
             f"wide_ops={wide_ops} "
             f"scratch_bytes_earth=0 scratch_bytes_buffer={scratch_buffer}",
             coalescing=float(fields),   # one transaction serves all fields
             unfused_us=round(t_unfused, 2),
             buffer_us=round(t_buf, 2),
             mode=mode,
             wide_ops=wide_ops,
             fields=fields)
        # round-trip (segment store) parity check through the real kernels
        spec = vx.Segment(n=aos.shape[-1], fields=fields)
        with vx.use("pallas"):
            back = vx.transpose(spec, vx.transpose(spec, aos))
        assert bool(jnp.all(back == aos))


if __name__ == "__main__":
    run()
