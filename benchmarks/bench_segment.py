"""Fig. 13 analogue — segment (AoS<->SoA) handling, buffer-free vs buffer.

EARTH claims parity in performance with a segment buffer while removing the
2 x 8 x MLEN buffer. We compare, per FIELDS in 2..8:

  * EARTH path: in-place field-wise shift-network deinterleave,
  * buffer path: materialized (FIELDS, m) transpose scratch then row reads
    (the Saturn segment-buffer dataflow),
and report wall time + scratch bytes (the Fig. 14 area claim analogue).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_jit
from repro.kernels import ops

MLEN = 128


def buffer_path(aos, fields):
    m = aos.shape[-1] // fields
    buf = aos.reshape(aos.shape[:-1] + (m, fields))      # segment buffer
    buf = jnp.swapaxes(buf, -1, -2)                      # bulk transpose
    return [buf[..., f, :] for f in range(fields)]


def run() -> None:
    rows = 64
    for fields in (2, 3, 4, 5, 6, 7, 8):
        m = MLEN
        aos = jnp.arange(rows * fields * m,
                         dtype=jnp.float32).reshape(rows, fields * m)
        t_earth = time_jit(lambda a: ops.deinterleave(a, fields), aos)
        t_buf = time_jit(lambda a: buffer_path(a, fields), aos)
        scratch_buffer = 2 * 8 * MLEN * 4  # dual 8xMLEN f32 buffers (paper)
        emit(f"segment/f{fields}", t_earth,
             f"buffer_us={t_buf:.1f} ratio={t_buf/max(t_earth,1e-9):.2f}x "
             f"scratch_bytes_earth=0 scratch_bytes_buffer={scratch_buffer}")
        # round-trip (segment store) parity check
        parts = ops.deinterleave(aos, fields)
        back = ops.interleave(parts)
        assert bool(jnp.all(back == aos))


if __name__ == "__main__":
    run()
