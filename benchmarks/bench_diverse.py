"""Fig. 11 analogue — diverse memory-access-pattern micro-workloads.

Mirrors the paper's benchmark mix: unit-stride (sgemm-like), strided
(cgemm/ctpmv-like interleaved complex), segment (yuv2rgb-like FIELD=3),
indexed (LUT4-like). EARTH is expected to match unit-stride, win on
strided/segment-adjacent, and be neutral-to-slightly-worse on indexed
(the paper reports -6.5% there).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_jit
from repro import vx
from repro.core import lsdo


def run() -> None:
    n = 1 << 14
    buf = jnp.arange(n, dtype=jnp.float32)

    # unit-stride: plain contiguous copy — both designs coalesce (parity)
    t = time_jit(lambda b: b[:4096] * 2.0, buf)
    emit("diverse/unit_stride_sgemm", t, "parity_with_baseline=expected")

    # strided: complex-interleaved real extraction (cgemm: stride-2)
    t_e = time_jit(lambda b: vx.gather(
        vx.Strided(n=8192, stride=2, vl=4096), b[:8192]), buf)
    plan = lsdo.plan_strided(0, 2, 4096, 128)
    emit("diverse/strided_cgemm_real", t_e,
         f"coalesce={plan.coalescing_factor:.0f}x "
         f"transactions={plan.num_transactions}/4096")

    # strided large-stride (ctpmv-like packed triangular row walk)
    t_e = time_jit(lambda b: vx.gather(
        vx.Strided(n=n, stride=33, vl=256), b), buf)
    plan = lsdo.plan_strided(0, 33, 256, 128)
    emit("diverse/strided_ctpmv", t_e,
         f"coalesce={plan.coalescing_factor:.2f}x")

    # segment FIELD=3 (yuv2rgb)
    yuv = jnp.arange(3 * 4096, dtype=jnp.float32).reshape(8, 1536)
    t_e = time_jit(lambda a: vx.transpose(
        vx.Segment(n=1536, fields=3), a), yuv)
    emit("diverse/segment_yuv2rgb", t_e, "fields=3 buffer_free=true")

    # indexed (LUT4): element-wise gather — EARTH adds pipeline stages,
    # paper reports a small regression; we keep XLA-native gather
    idx = jax.random.randint(jax.random.key(0), (4096,), 0, n)
    t_e = time_jit(lambda b, i: b[i], buf, idx)
    emit("diverse/indexed_lut4", t_e, "no_earth_optimization=by_design")

    # batched matmul with strided batch layout (BatchMatMul_SCF)
    a = jnp.arange(16 * 64 * 64, dtype=jnp.float32).reshape(16, 64, 64)
    t_e = time_jit(lambda x: jnp.einsum("bij,bjk->bik", x, x), a)
    emit("diverse/batch_matmul_scf", t_e, "unit_stride_inner=coalesced")


if __name__ == "__main__":
    run()
