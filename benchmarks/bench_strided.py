"""Fig. 12 analogue — stride-intensive workloads, EARTH vs element-wise.

The paper's speedup driver is transaction coalescing: EARTH turns
vl strided element requests into #distinct-aligned-regions requests and
reorganizes on chip. We report, per (intensity x stride):

  * coalescing factor C (transactions saved) from the LSDO planner,
  * modeled speedup  1 / (1 - I + I/C)  (strided fraction I of memory ops
    accelerated by C — the Fig. 12 shape),
  * measured wall time of the XLA-lowered gather path vs an element-wise
    dynamic-slice loop (CPU; relative, not TPU-absolute).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_jit
from repro.core import lsdo
from repro.kernels import ops

MLEN = 128  # elements per transaction


def element_wise_gather(buf, stride, offset, vl):
    def body(i, acc):
        return acc.at[i].set(jax.lax.dynamic_index_in_dim(
            buf, offset + i * stride, keepdims=False))
    return jax.lax.fori_loop(0, vl, body, jnp.zeros((vl,), buf.dtype))


def run() -> None:
    buf = jnp.arange(1 << 16, dtype=jnp.float32)
    for intensity in (0.2, 0.4, 0.8, 0.95):
        for stride in (2, 4, 8, 16, 32, 64):
            vl = MLEN // 2
            plan = lsdo.plan_strided(0, stride, vl, MLEN)
            C = plan.coalescing_factor
            speedup = 1.0 / (1.0 - intensity + intensity / C)
            n = stride * vl
            win = buf[:n]
            t_earth = time_jit(
                lambda w: ops.gather_strided(w, stride, 0, vl), win)
            t_elem = time_jit(
                lambda w: element_wise_gather(w, stride, 0, vl), win)
            emit(f"strided/i{int(intensity*100)}/s{stride}", t_earth,
                 f"coalesce={C:.1f}x modeled_speedup={speedup:.2f}x "
                 f"elementwise_us={t_elem:.1f} "
                 f"measured_ratio={t_elem/max(t_earth,1e-9):.1f}x")


if __name__ == "__main__":
    run()
