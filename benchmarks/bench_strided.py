"""Fig. 12 analogue — stride-intensive workloads, EARTH vs element-wise.

The paper's speedup driver is transaction coalescing: EARTH turns
vl strided element requests into #distinct-aligned-regions requests and
reorganizes on chip. We report, per (intensity x stride):

  * coalescing factor C (transactions saved) from the LSDO planner,
  * modeled speedup  1 / (1 - I + I/C)  (strided fraction I of memory ops
    accelerated by C — the Fig. 12 shape),
  * measured wall time of the COMPILED static-plan shift network (pruned
    layers, constant masks — core/shiftplan.py) vs the dynamic-count
    network it replaced (the seed path, same run, same shapes) vs an
    element-wise dynamic-slice loop (CPU; relative, not TPU-absolute).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks import common
from benchmarks.common import emit, time_jit
from repro.core import lsdo, scg, shiftnet, shiftplan

MLEN = 128  # elements per transaction
ROWS = 64   # simulated beat rows (one VMEM tile worth)


def element_wise_gather(buf, stride, offset, vl):
    def body(i, acc):
        return acc.at[:, i].set(jax.lax.dynamic_index_in_dim(
            buf, offset + i * stride, axis=-1, keepdims=False))
    return jax.lax.fori_loop(0, vl, body,
                             jnp.zeros(buf.shape[:-1] + (vl,), buf.dtype))


def compiled_gather(win, masks, stride, vl):
    # operand-form masks: the same lowering the Pallas kernels use
    plan = shiftplan.gather_plan(win.shape[-1], stride, 0, vl)
    routed = shiftnet.apply_plan_operand(win, masks, plan)
    return jax.lax.slice(routed, (0, 0), (win.shape[0], vl))


def dynamic_gather(win, stride, vl):
    shift, valid = scg.gather_counts(win.shape[-1], stride, 0, vl)
    res = shiftnet.gather_network(win, shift[None, :], valid[None, :],
                                  axis=-1)
    return jax.lax.slice(res.payload, (0, 0), (win.shape[0], vl))


def run() -> None:
    intensities = (0.4,) if common.QUICK else (0.2, 0.4, 0.8, 0.95)
    strides = (2, 8) if common.QUICK else (2, 4, 8, 16, 32, 64)
    for intensity in intensities:
        for stride in strides:
            vl = MLEN // 2
            plan = lsdo.plan_strided(0, stride, vl, MLEN)
            C = plan.coalescing_factor
            speedup = 1.0 / (1.0 - intensity + intensity / C)
            n = stride * vl
            win = jnp.broadcast_to(
                jnp.arange(n, dtype=jnp.float32), (ROWS, n))
            splan = shiftplan.gather_plan(n, stride, 0, vl)
            masks = jnp.asarray(shiftnet.plan_mask_stack(splan))
            t_plan = time_jit(
                lambda w, m, s=stride: compiled_gather(w, m, s, vl),
                win, masks)
            t_dyn = time_jit(
                lambda w, s=stride: dynamic_gather(w, s, vl), win)
            t_elem = time_jit(
                lambda w, s=stride: element_wise_gather(w, s, 0, vl), win)
            emit(f"strided/i{int(intensity*100)}/s{stride}", t_plan,
                 f"coalesce={C:.1f}x modeled_speedup={speedup:.2f}x "
                 f"dynamic_us={t_dyn:.1f} elementwise_us={t_elem:.1f} "
                 f"vs_dynamic={t_dyn/max(t_plan,1e-9):.1f}x "
                 f"layers={splan.active_layers}/{splan.total_layers}",
                 coalescing=round(C, 2),
                 dynamic_us=round(t_dyn, 2),
                 elementwise_us=round(t_elem, 2),
                 active_layers=splan.active_layers,
                 total_layers=splan.total_layers)


if __name__ == "__main__":
    run()
