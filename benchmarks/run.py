"""Benchmark harness — one suite per paper table/figure.

Prints ``name,us_per_call,derived`` CSV, writes machine-readable
``BENCH_strided.json`` / ``BENCH_segment.json`` artifacts (name,
us_per_call, coalescing factor, compiled-vs-dynamic ratios) so the perf
trajectory is tracked across PRs, and appends the roofline table (from
dry-run artifacts, if present).

  Fig. 11 -> bench_diverse      Fig. 12 -> bench_strided
  Fig. 13 -> bench_segment      Table 2 / Fig. 14/15 -> bench_hw_cost
  (framework) MoE dispatch -> bench_moe

``--quick`` runs a reduced sweep (the CI smoke: < 60 s on a laptop core).
"""
from __future__ import annotations

import argparse
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

BENCH_JSON = {
    "strided/": "BENCH_strided.json",
    "segment/": "BENCH_segment.json",
    "moe/": "BENCH_moe.json",
    "step/": "BENCH_step.json",
    "serve/": "BENCH_serve.json",
}


def _write_artifacts(records, out_dir: str) -> None:
    os.makedirs(out_dir, exist_ok=True)
    for prefix, fname in BENCH_JSON.items():
        rows = [r for r in records if r["name"].startswith(prefix)]
        if not rows:
            continue
        path = os.path.join(out_dir, fname)
        with open(path, "w") as f:
            json.dump(rows, f, indent=1)
        print(f"# wrote {path} ({len(rows)} records)")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="reduced sweep (CI smoke)")
    ap.add_argument("--out", default=os.path.dirname(os.path.abspath(
        __file__)), help="directory for BENCH_*.json artifacts")
    ap.add_argument("--suites", default="all",
                    help="comma list: diverse,strided,segment,hw_cost,"
                         "moe,step,serve")
    args = ap.parse_args()

    from benchmarks import common
    common.QUICK = args.quick

    from benchmarks import (bench_diverse, bench_hw_cost, bench_moe,
                            bench_segment, bench_serve, bench_step,
                            bench_strided, roofline_table)
    suites = {
        "diverse": bench_diverse, "strided": bench_strided,
        "segment": bench_segment, "hw_cost": bench_hw_cost,
        "moe": bench_moe, "step": bench_step, "serve": bench_serve,
    }
    if args.suites == "all":
        # the whole registry; --quick reduces each suite's sweep via
        # common.QUICK rather than dropping suites, so the CI smoke
        # exercises every dispatch path end to end
        picked = list(suites)
    else:
        picked = [s.strip() for s in args.suites.split(",")]
    unknown = [s for s in picked if s not in suites]
    if unknown:
        ap.error(f"unknown suites {unknown}; choose from {sorted(suites)}")

    print("name,us_per_call,derived")
    for name in picked:
        suites[name].run()
    _write_artifacts(common.RECORDS, args.out)
    if not args.quick:
        print()
        print("# Roofline table (from experiments/artifacts, if populated):")
        try:
            roofline_table.run()
        except Exception as e:  # noqa: BLE001
            print(f"# (no artifacts: {e})")


if __name__ == "__main__":
    main()
