"""Benchmark harness — one suite per paper table/figure.

Prints ``name,us_per_call,derived`` CSV. The roofline table (from dry-run
artifacts, if present) is appended at the end.

  Fig. 11 -> bench_diverse      Fig. 12 -> bench_strided
  Fig. 13 -> bench_segment      Table 2 / Fig. 14/15 -> bench_hw_cost
  (framework) MoE dispatch -> bench_moe
"""
from __future__ import annotations

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))


def main() -> None:
    from benchmarks import (bench_diverse, bench_hw_cost, bench_moe,
                            bench_segment, bench_strided, roofline_table)
    print("name,us_per_call,derived")
    for mod in (bench_diverse, bench_strided, bench_segment, bench_hw_cost,
                bench_moe):
        mod.run()
    print()
    print("# Roofline table (from experiments/artifacts, if populated):")
    try:
        roofline_table.run()
    except Exception as e:  # noqa: BLE001
        print(f"# (no artifacts: {e})")


if __name__ == "__main__":
    main()
