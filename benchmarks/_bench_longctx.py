"""Child process for the long-context serving row of bench_step.py.

Runs under ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (the
parent sets it before spawning): a seq-sharded (B=1-style) decode step,
FUSED (shard-local KV split through the PR 4 sharding-aware vx lowering)
vs PER-ACCESS (the path long_context was pinned to before), same-run
medians plus the jaxpr-level launch/mask counts.  Prints ONE JSON line on
stdout; the parent parses it and emits the ``step/decode_longctx`` record.
"""
from __future__ import annotations

import json
import os
import sys
import time

if "--xla_force_host_platform_device_count" not in os.environ.get(
        "XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402


def _median_us(step, args, iters: int) -> float:
    jax.block_until_ready(step(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(step(*args))
        times.append((time.perf_counter() - t0) * 1e6)
    times.sort()
    return times[len(times) // 2]


def main() -> None:
    quick = "--quick" in sys.argv
    from jax.sharding import PartitionSpec as P
    from repro import vx
    from repro.configs import get_arch
    from repro.configs.base import decode_inputs
    from repro.core import accessfuse
    from repro.launch.mesh import make_ctx, make_test_mesh
    from repro.models import decode as dec
    from repro.models.transformer import init_params
    from repro.serve.engine import ServeConfig, cache_specs

    cfg = get_arch("qwen3-0.6b").smoke
    params = init_params(cfg, jax.random.key(0))
    # B=1 (the long_500k cell shape); seq keeps the merged KV split above
    # the fusion threshold so the fused group stays a kernel transaction
    seq = 128 if quick else 512
    cache, token = decode_inputs(cfg, seq=seq, batch=1, specs=False,
                                 cache_dtype=jnp.float32)
    cache["len"] = jnp.asarray(seq // 2, jnp.int32)
    mesh = make_test_mesh((2, 4), ("data", "model"))
    ctx = make_ctx(mesh, long_context=True)
    shard = ctx.vx_seq_shard(-3)

    # identical placement for BOTH paths: the serve-path cache shardings
    # (seq-parallel leaves), params/token replicated — so the comparison
    # is fused-vs-per-access under the same SPMD program, not
    # single-device vs 8-device
    scfg = ServeConfig(max_len=seq, long_context=True)
    cspecs = cache_specs(cfg, ctx, scfg, cache)
    csh = jax.tree.map(lambda s: ctx.sharding(s), cspecs,
                       is_leaf=lambda x: isinstance(x, P))
    cache = jax.tree.map(jax.device_put, cache, csh)
    params = jax.tree.map(lambda a: jax.device_put(a, ctx.sharding(P())),
                          params)
    token = jax.device_put(token, ctx.sharding(P()))

    def fused(p, c, t):
        return dec.decode_step(p, c, t, cfg, ctx, fuse=True,
                               kv_shard=shard)

    def per_access(p, c, t):
        return dec.decode_step(p, c, t, cfg, ctx, fuse=False)

    iters = 5 if quick else 20
    t_f = _median_us(jax.jit(fused), (params, cache, token), iters)
    t_p = _median_us(jax.jit(per_access), (params, cache, token), iters)
    with vx.use("pallas"), accessfuse.pinned_kernel_lowering():
        lf, mf = accessfuse.jaxpr_access_counts(fused, params, cache, token)
    with vx.use("pallas"):
        lp, mp = accessfuse.jaxpr_access_counts(per_access, params, cache,
                                                token)
    # 8 fake devices on one host serialize every shard: wall time here is
    # SPMD-simulation-bound, not a dispatch claim (same caveat as the
    # lsdo_many row) — the tracked metrics are the launch/mask counts
    print(json.dumps({
        "fused_us": round(t_f, 2), "per_access_us": round(t_p, 2),
        "seq": seq, "nshards": shard.nshards, "spmd_sim_bound": True,
        "launches_fused": lf, "launches_per_access": lp,
        "mask_ops_fused": mf, "mask_ops_per_access": mp,
    }))


if __name__ == "__main__":
    main()
