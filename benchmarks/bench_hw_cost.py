"""Table 2 / Fig. 14 / Fig. 15 analogue — structural cost comparison.

No silicon here, so the paper's area/power numbers map to the costs a
compiler system can count (DESIGN.md §2):

  * routing-resource analogue: crossbar needs O(n^2) switch points;
    EARTH's layered shift network needs n*log2(n) 2:1 selects,
  * bytes-moved analogue: one-hot-matmul "crossbar" data reorganization
    moves n^2 matrix bytes; the shift network moves n*log2(n),
  * scratch analogue: segment buffer 2x8xMLEN vs 0 (RCVRF in place),
and cross-checks wall time of both reorganization strategies under XLA.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_jit
from repro.core import scg, shiftnet


def crossbar_gather(window, onehot):
    """Arbitrary byte remap as a one-hot matmul — the 'crossbar'."""
    return onehot @ window


def run() -> None:
    for n in (128, 256, 512, 1024):
        layers = max(1, math.ceil(math.log2(n)))
        emit(f"hwcost/switches_n{n}", 0.0,
             f"crossbar={n*n} shiftnet={n*layers} "
             f"ratio={n*n/(n*layers):.1f}x")

    # bytes moved + wall time for an actual strided reorganization
    for n, stride in ((512, 4), (1024, 8)):
        vl = n // stride
        window = jnp.arange(n, dtype=jnp.float32)
        shift, valid = scg.gather_counts(n, stride, 0, vl)
        onehot = jnp.zeros((vl, n), jnp.float32).at[
            jnp.arange(vl), jnp.arange(vl) * stride].set(1.0)
        t_net = time_jit(
            lambda w: shiftnet.gather_network(w, shift, valid).payload,
            window)
        t_xbar = time_jit(crossbar_gather, window, onehot)
        layers = math.ceil(math.log2(n))
        emit(f"hwcost/reorg_n{n}_s{stride}", t_net,
             f"crossbar_us={t_xbar:.1f} "
             f"bytes_net={4*n*layers} bytes_xbar={4*n*vl} "
             f"flops_xbar={2*n*vl}")

    emit("hwcost/segment_scratch", 0.0,
         "earth_bytes=0 saturn_dual_buffer_bytes=" + str(2 * 8 * 512))


if __name__ == "__main__":
    run()
