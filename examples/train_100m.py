"""End-to-end driver: train a ~100M-param qwen3-family model for a few
hundred steps on CPU with the full substrate (AoS pipeline, AdamW,
checkpoint/restart, EARTH segment ops in the input path).

Run:  PYTHONPATH=src python examples/train_100m.py [--steps 300]
"""
import argparse
import dataclasses
import time

import jax

from repro.configs import get_arch
from repro.data.pipeline import DataConfig, SyntheticAoSPipeline
from repro.ft.checkpoint import CheckpointManager
from repro.optim.adamw import AdamWConfig
from repro.train.step import TrainConfig, init_full_state, jit_train_step


def build_cfg():
    """~100M params: d=512, 8 layers, vocab 32k, GQA + qk-norm."""
    base = get_arch("qwen3-0.6b").model
    return dataclasses.replace(
        base, name="qwen3-100m", d_model=512, n_layers=8, n_heads=8,
        n_kv_heads=4, head_dim=64, d_ff=1536, vocab=32768,
        compute_dtype="float32", remat="none")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt", default="/tmp/earth_jax_100m_ckpt")
    args = ap.parse_args()

    cfg = build_cfg()
    tcfg = TrainConfig(optimizer=AdamWConfig(
        lr=1e-3, warmup_steps=20, total_steps=args.steps))
    state = init_full_state(cfg, tcfg, jax.random.key(0))
    n = sum(x.size for x in jax.tree.leaves(state["params"]))
    print(f"model: {cfg.name} params={n/1e6:.1f}M")

    pipe = SyntheticAoSPipeline(DataConfig(
        vocab=cfg.vocab, seq_len=args.seq, global_batch=args.batch))
    mgr = CheckpointManager(args.ckpt)
    batch = pipe.next_batch()
    step_fn = jit_train_step(cfg, tcfg, None, state, batch)

    losses = []
    t0 = time.time()
    for step in range(args.steps):
        state, metrics = step_fn(state, batch)
        losses.append(float(metrics["loss"]))
        batch = pipe.next_batch()
        if step % 20 == 0:
            print(f"step {step:4d} loss={losses[-1]:.4f} "
                  f"gnorm={float(metrics['grad_norm']):.2f} "
                  f"({(time.time()-t0)/(step+1):.2f}s/step)", flush=True)
        if (step + 1) % 100 == 0:
            mgr.save(step + 1, state, extra={"pipeline": pipe.state_dict(),
                                             "step": step + 1})
    mgr.wait()
    first, last = sum(losses[:20]) / 20, sum(losses[-20:]) / 20
    print(f"loss {first:.3f} -> {last:.3f} "
          f"({'LEARNING' if last < first - 0.5 else 'check setup'})")


if __name__ == "__main__":
    main()
