"""Serving example: continuous batching over the PAGED KV runtime.

Multi-token prompts prefill into whole pages, requests join and leave
slots mid-flight (pages reclaimed on finish), and sampling is seeded
per slot — the EARTH access machinery handles the page gathers and the
KV interleave/split.

Run:  PYTHONPATH=src python examples/serve_batch.py
"""
import time

import jax

from repro.configs import get_arch
from repro.models.transformer import init_params
from repro.serve import BatchedServer


def main() -> None:
    cfg = get_arch("qwen3-0.6b").smoke
    params = init_params(cfg, jax.random.key(0))
    server = BatchedServer(cfg, params, slots=4, max_len=64, page_size=16,
                           temperature=0.8, top_k=40, seed=7)

    # requests arrive at different times with different prompt lengths
    # (continuous batching over per-slot positions)
    s0 = server.add_request(prompt=[11, 12, 13, 14, 15])
    s1 = server.add_request(22)
    for _ in range(4):
        server.step()
    s2 = server.add_request(prompt=[33, 34, 35])   # joins mid-flight
    t0 = time.time()
    for _ in range(8):
        server.step()
    dt = time.time() - t0
    cache = server.scheduler.cache
    print(f"slot outputs after 12/8 steps ({dt*1e3:.0f} ms):")
    print(f"pages in use at peak load: {cache.pages_in_use()} of "
          f"{cache.num_pages}")
    for s in (s0, s1, s2):
        print(f"  slot {s}: {server.finish(s)}")
    print("throughput:", f"{3*8/dt:.1f} tok/s (CPU)")
    print(f"pages after finish: {cache.pages_in_use()} in use, "
          f"{cache.free_pages()} free")


if __name__ == "__main__":
    main()
