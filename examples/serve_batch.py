"""Serving example: continuous batching over the interleaved KV cache.

Prefill a prompt per slot, then decode greedily with requests joining and
leaving slots — the EARTH segment ops handle KV interleave/split.

Run:  PYTHONPATH=src python examples/serve_batch.py
"""
import time

import jax
import jax.numpy as jnp

from repro.configs import get_arch
from repro.models.transformer import init_params
from repro.serve.engine import BatchedServer


def main() -> None:
    cfg = get_arch("qwen3-0.6b").smoke
    params = init_params(cfg, jax.random.key(0))
    server = BatchedServer(cfg, params, slots=4, max_len=64)

    # requests arrive at different times (continuous batching)
    s0 = server.add_request(prompt_token=11)
    s1 = server.add_request(prompt_token=22)
    for _ in range(4):
        server.step()
    s2 = server.add_request(prompt_token=33)   # joins mid-flight
    t0 = time.time()
    for _ in range(8):
        toks = server.step()
    dt = time.time() - t0
    print(f"slot outputs after 12/8 steps ({dt*1e3:.0f} ms):")
    for s in (s0, s1, s2):
        print(f"  slot {s}: {server.finish(s)}")
    print("throughput:", f"{3*8/dt:.1f} tok/s (CPU)")


if __name__ == "__main__":
    main()
