"""Quickstart: the EARTH data-movement core in 60 lines.

Shows the paper's three mechanisms as JAX ops:
  1. LSDO   — coalesced strided load (plan + shift-network gather),
  2. DROM   — raw gather/scatter through the log-depth shift network,
  3. RCVRF  — buffer-free segment (AoS<->SoA) access,
then uses them for a real task: unpacking an AoS training record.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.core import drom, lsdo
from repro.data import aos

# --- 1. LSDO: strided access with transaction coalescing --------------------
buf = jnp.arange(1024, dtype=jnp.float32)
plan = lsdo.plan_strided(base=8, stride=6, vl=40, mlen=128)
print(f"strided vl=40 stride=6: {plan.num_transactions} coalesced "
      f"transactions instead of {plan.element_wise_transactions} "
      f"({plan.coalescing_factor:.1f}x)")
dense = lsdo.load_strided(buf, plan)
print("loaded:", dense[:8], "...")

# --- 2. DROM: gather/scatter through the shift network -----------------------
x = jnp.arange(32, dtype=jnp.float32) * 10
out = drom.gather_strided(x[None, :], stride=4, offset=2, vl=8)[0]
print("gathered every 4th from offset 2:", out)
back = drom.scatter_strided(jnp.zeros((1, 32)), out[None, :], 4, 2)[0]
print("scattered back:", back[:12], "...")

# --- 3. RCVRF: segment access without a segment buffer ----------------------
fields = drom.deinterleave(jnp.arange(24, dtype=jnp.float32)[None, :], 3)
print("AoS [x0,y0,z0,x1,...] -> SoA:",
      [list(map(int, f[0])) for f in fields])

# --- 4. All together: the AoS training-record pipeline ----------------------
tokens = jnp.array([[5, 6, 7, 8]]); labels = jnp.array([[6, 7, 8, 9]])
w = jnp.ones((1, 4)); docs = jnp.zeros((1, 4), jnp.int32)
record = aos.pack_records(tokens, labels, w, docs)
print("AoS record:", record[0])
batch = aos.unpack_records(record)
print("unpacked tokens:", batch["tokens"][0], "labels:", batch["labels"][0])

# Everything above is jit-able and TPU-ready (Pallas kernels via impl='pallas')
fast = jax.jit(lambda a: drom.deinterleave(a, 2, impl="pallas"))
print("pallas deinterleave ok:", fast(jnp.arange(64.0)[None, :])[0][0, :4])
