"""Quickstart: the EARTH data-movement core through the `vx` API.

One spec type, four verbs, one policy — every vector memory access in the
framework goes through `repro.vx`:
  1. LSDO   — coalesced strided load (plan + shift-network gather),
  2. vx     — declarative gather/scatter/transpose/compact verbs,
  3. Policy — scoped lowering control (no per-call impl strings),
then uses them for a real task: unpacking an AoS training record.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro import vx
from repro.core import lsdo
from repro.data import aos

# --- 1. LSDO: strided access with transaction coalescing --------------------
buf = jnp.arange(1024, dtype=jnp.float32)
plan = lsdo.plan_strided(base=8, stride=6, vl=40, mlen=128)
print(f"strided vl=40 stride=6: {plan.num_transactions} coalesced "
      f"transactions instead of {plan.element_wise_transactions} "
      f"({plan.coalescing_factor:.1f}x)")
dense = lsdo.load_strided(buf, plan)
print("loaded:", dense[:8], "...")

# --- 2. vx verbs: one declarative API for every access pattern ---------------
x = jnp.arange(32, dtype=jnp.float32) * 10
spec = vx.Strided(n=32, stride=4, offset=2, vl=8)
out = vx.gather(spec, x[None, :])[0]
print("gathered every 4th from offset 2:", out)
back = vx.scatter(spec, jnp.zeros((1, 32)), out[None, :])[0]
print("scattered back:", back[:12], "...")

# segment access (AoS <-> SoA) is a transpose over a Segment spec
fields = vx.transpose(vx.Segment(n=24, fields=3),
                      jnp.arange(24, dtype=jnp.float32)[None, :])
print("AoS [x0,y0,z0,x1,...] -> SoA:",
      [list(map(int, f[0])) for f in fields])

# masked compaction (the MoE dispatch primitive)
mask = jnp.array([1, 0, 1, 1, 0, 0, 1, 0], bool)
print("packed indices of set bits:",
      vx.compact(vx.Compact(n=8, cap=4), mask))

# runtime (traced) stride: the plan-bank lax.switch picks a compiled plan
rt = vx.Strided(n=32, stride=vx.BANK, offset=2, vl=8)
fast_rt = jax.jit(lambda w, s: vx.gather(rt, w, stride=s))
print("runtime stride 3:", fast_rt(x[None, :], jnp.int32(3))[0])

# --- 3. Policy: scoped lowering, no per-call impl strings --------------------
# default: REPRO_VX_IMPL env var, else platform (pallas on TPU, ref off-TPU)
print("default policy:", vx.Policy.default())
with vx.use("pallas"):              # everything in scope lowers to Pallas
    fast = jax.jit(lambda a: vx.transpose(vx.Segment(n=64, fields=2), a))
    print("pallas deinterleave ok:", fast(jnp.arange(64.0)[None, :])[0][0, :4])

# --- 4. All together: the AoS training-record pipeline ----------------------
tokens = jnp.array([[5, 6, 7, 8]]); labels = jnp.array([[6, 7, 8, 9]])
w = jnp.ones((1, 4)); docs = jnp.zeros((1, 4), jnp.int32)
record = aos.pack_records(tokens, labels, w, docs)
print("AoS record:", record[0])
batch = aos.unpack_records(record)
print("unpacked tokens:", batch["tokens"][0], "labels:", batch["labels"][0])
