"""EARTH MoE dispatch, visualized: routing -> compaction -> grouped GEMM.

Shows the shift-network token compaction (the paper's GSN with prefix-sum
SCG) packing each expert's tokens, and verifies against argsort dispatch.

Run:  PYTHONPATH=src python examples/moe_dispatch_demo.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import scg, shiftnet
from repro.models.moe import MoESpec, init_moe, moe_ffn_local

T, d, E, k = 16, 32, 4, 2
key = jax.random.key(0)
params = init_moe(key, d, MoESpec(n_experts=E, top_k=k, d_ff=64),
                  jnp.float32)
x = jax.random.normal(jax.random.key(1), (T, d))

# --- routing ---------------------------------------------------------------
logits = x @ params["router"]
topw, topi = jax.lax.top_k(jax.nn.softmax(logits, -1), k)
print("expert assignment per token (top-2):")
print(np.asarray(topi).T)

# --- EARTH compaction for expert 0 ----------------------------------------
units = topi.reshape(-1)
mine = units == 0
shift, valid = scg.compaction_counts(mine)
ids = jnp.arange(T * k, dtype=jnp.int32)
res = shiftnet.gather_network(ids, shift, valid)
n0 = int(mine.sum())
print(f"\nexpert 0 owns {n0} (token,slot) units; "
      f"compacted unit ids: {np.asarray(res.payload[:n0])}")
print("conflict-free routing:", not bool(res.conflict))

# the same compaction through the public vx API (what moe.py calls)
from repro import vx
packed = vx.compact(vx.Compact(n=T * k, cap=T * k), mine)
print("vx.compact agrees:",
      bool(jnp.all(packed[:n0] == res.payload[:n0])))

# --- full MoE layer: earth vs argsort dispatch ------------------------------
for dispatch in ("earth", "sort"):
    spec = MoESpec(n_experts=E, top_k=k, d_ff=64, dispatch=dispatch)
    y, aux = moe_ffn_local(params["router"], params["wg"], params["wu"],
                           params["wo"], x, spec, model_axis=None,
                           data_axes=(), n_shards=1)
    print(f"{dispatch:6s}: |y|={float(jnp.linalg.norm(y)):.4f} "
          f"aux={float(aux):.4f}")
