"""Quantized paged KV pool (PR 9): int8/fp8 pages + per-page scales.

Lowering level — dequant rides the SAME single page-gather program
(zero extra gather equations, one pinned kernel launch for the fused
step), scatter quantizes on write with a monotone scale widen that is
duplicate-physical-page safe; decode level — fused == unfused
bit-exact, logits track the float32 oracle within the quantization
bound over a page_size x slots sweep; serve level — scales travel with
physical pages through prefix adoption and CoW fork (bit-exact vs the
non-shared quantized oracle), the invariant audit covers scale
liveness, memory accounting counts the scale side tensor, and the
chaos / fleet gates hold at int8."""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import vx
from repro.configs import get_arch
from repro.core import accessfuse, quant
from repro.models import decode as dec
from repro.models.transformer import ModelConfig, init_params
from repro.serve.paged_cache import PagedCache
from repro.serve.scheduler import Scheduler


def _cfg(layers=2, hd=16, scan=False, impl="ref", positions=2,
         mlp="none", d_ff=0):
    return ModelConfig(
        name="quant-test", d_model=2 * hd, n_layers=layers, n_heads=2,
        n_kv_heads=2, d_ff=d_ff, vocab=97, head_dim=hd, mlp=mlp,
        block_pattern=("attn",) * positions,
        window_pattern=(None,) * positions,
        moe_pattern=(False,) * positions,
        scan_layers=scan, kernel_impl=impl, remat="none")


def _count_gathers(fn, *args) -> int:
    def rec(jaxpr):
        c = 0
        for eqn in jaxpr.eqns:
            if eqn.primitive.name == "gather":
                c += 1
            for v in eqn.params.values():
                for sub in accessfuse._child_jaxprs(v):
                    c += rec(sub)
        return c
    return rec(jax.make_jaxpr(lambda *a: fn(*a))(*args).jaxpr)


@functools.lru_cache(maxsize=None)
def _arch_cfg_params(arch="qwen3-0.6b"):
    cfg = get_arch(arch).smoke
    return cfg, init_params(cfg, jax.random.key(0))


# ---------------------------------------------------------------------------
# vx lowering: quantized gather / scatter
# ---------------------------------------------------------------------------

def test_quantized_gather_matches_manual_dequant():
    """out = pool[table-indexed].astype(f32) * per-page-per-head scale,
    zeros through unallocated (-1) entries — fp8 garbage in untouched
    pages must never leak through the mask."""
    rng = np.random.default_rng(0)
    ps, pages, P, K, D = 4, 3, 8, 2, 6
    pool = jnp.asarray(rng.integers(-127, 128, (P, ps, K, D)), jnp.int8)
    scales = jnp.asarray(rng.uniform(0.01, 2.0, (P, K)), jnp.float32)
    spec = vx.Paged(page_size=ps, pages=pages, trail=2)
    table = jnp.asarray([[2, 0, -1], [5, -1, -1]], np.int32)
    out = vx.gather(spec, pool, table=table, scales=scales)
    assert out.shape == (2, pages * ps, K, D)
    assert out.dtype == jnp.float32
    pn = np.asarray(pool, np.float32) * np.asarray(scales)[:, None, :, None]
    want = np.zeros((2, pages * ps, K, D), np.float32)
    want[0, :4], want[0, 4:8] = pn[2], pn[0]
    want[1, :4] = pn[5]
    np.testing.assert_allclose(np.asarray(out), want, rtol=1e-6)


def test_quantized_gather_adds_zero_gather_eqns():
    """The scale lookup is a one-hot contraction, NOT a second gather:
    the quantized program must cost exactly as many gather equations as
    the float one — the fused-dequant acceptance gate at the jaxpr
    level."""
    ps, pages, P, K, D = 4, 3, 8, 2, 6
    poolf = jnp.zeros((P, ps, K, D), jnp.float32)
    poolq = jnp.zeros((P, ps, K, D), jnp.int8)
    scales = jnp.ones((P, K), jnp.float32)
    spec = vx.Paged(page_size=ps, pages=pages, trail=2)
    table = jnp.asarray([[2, 0, -1]], np.int32)
    gf = _count_gathers(lambda p, t: vx.gather(spec, p, table=t),
                        poolf, table)
    gq = _count_gathers(
        lambda p, s, t: vx.gather(spec, p, table=t, scales=s),
        poolq, scales, table)
    assert gq == gf, (gq, gf)


def test_quantized_scatter_roundtrips_within_bound():
    ps, pages, P, K, D = 4, 2, 6, 2, 3
    pool = jnp.zeros((P, ps, K, D), jnp.int8)
    scales = jnp.zeros((P, K), jnp.float32)
    spec = vx.Paged(page_size=ps, pages=pages, trail=2)
    table = jnp.asarray([[1, -1], [3, 0], [-1, -1]], np.int32)
    vals = jnp.asarray(np.random.default_rng(1).normal(size=(3, K, D)),
                       jnp.float32)
    pos = jnp.asarray([2, 5, -1], np.int32)
    npool, nscl = vx.scatter(spec, pool, vals, table=table, pos=pos,
                             scales=scales)
    assert npool.dtype == jnp.int8 and nscl.shape == (P, K)
    got = np.asarray(npool, np.float32) * np.asarray(nscl)[:, None, :, None]
    vn = np.asarray(vals)
    for row, (pg, off) in ((0, (1, 2)), (1, (0, 1))):
        bound = quant.error_bound("int8", float(np.abs(vn[row]).max()))
        assert np.abs(got[pg, off] - vn[row]).max() <= bound * 1.001
    # dropped rows / unallocated pages leave pool AND scales untouched
    assert float(np.abs(got[1, 3]).max()) == 0.0
    untouched = np.delete(np.asarray(nscl), [0, 1, 3], axis=0)
    np.testing.assert_array_equal(untouched, 0.0)


def test_quantized_scatter_duplicate_physical_page_is_safe():
    """Two batch rows landing in the SAME physical page the same step
    (adopted prefixes make this real): the scale must widen to cover
    both beats and BOTH land within bound — a read-modify-write race
    here would corrupt one of them."""
    ps, pages, P, K, D = 4, 2, 4, 2, 3
    pool = jnp.zeros((P, ps, K, D), jnp.int8)
    scales = jnp.zeros((P, K), jnp.float32)
    spec = vx.Paged(page_size=ps, pages=pages, trail=2)
    table = jnp.asarray([[2, -1], [2, -1]], np.int32)   # same phys page
    vals = jnp.asarray([[[0.1] * D] * K, [[50.0] * D] * K], jnp.float32)
    pos = jnp.asarray([0, 1], np.int32)                 # offsets 0, 1
    npool, nscl = vx.scatter(spec, pool, vals, table=table, pos=pos,
                             scales=scales)
    got = np.asarray(npool, np.float32) * np.asarray(nscl)[:, None, :, None]
    np.testing.assert_allclose(got[2, 1], 50.0, rtol=1e-2)
    bound = quant.error_bound("int8", 50.0)             # widened scale
    assert np.abs(got[2, 0] - 0.1).max() <= bound * 1.001


def test_quantized_scatter_scale_widens_monotonically():
    """Append small then large into one page: the scale only WIDENS
    (never shrinks — shared CoW pages are immutable, so a shrink would
    need a rewrite), residents are rescaled and stay within ~one extra
    half-step of error per widen event."""
    ps, pages, P, K, D = 4, 1, 2, 1, 2
    pool = jnp.zeros((P, ps, K, D), jnp.int8)
    scales = jnp.zeros((P, K), jnp.float32)
    spec = vx.Paged(page_size=ps, pages=pages, trail=2)
    table = jnp.asarray([[0]], np.int32)
    s_hist = []
    for step, mag in enumerate([0.5, 8.0, 2.0]):
        vals = jnp.full((1, K, D), mag, jnp.float32)
        pool, scales = vx.scatter(spec, pool, vals, table=table,
                                  pos=jnp.asarray([step], np.int32),
                                  scales=scales)
        s_hist.append(float(scales[0, 0]))
    assert s_hist == sorted(s_hist)                     # monotone
    assert s_hist[-1] == pytest.approx(8.0 / 127.0)     # never shrank
    got = np.asarray(pool, np.float32)[0, :, 0, 0] * s_hist[-1]
    # resident 0.5 was rescaled through one widen: <= 2 half-steps
    assert abs(got[0] - 0.5) <= 2 * quant.error_bound("int8", 8.0)
    assert abs(got[1] - 8.0) <= quant.error_bound("int8", 8.0) * 1.001
    assert abs(got[2] - 2.0) <= quant.error_bound("int8", 8.0) * 1.001


# ---------------------------------------------------------------------------
# decode: fused step semantics
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("ps,slots", [(4, 1), (4, 3), (8, 3), (16, 1)])
def test_quantized_decode_tracks_float_oracle(ps, slots):
    """Forced-teacher sweep: step the quantized and float32 pools on the
    SAME token stream (the float stream's argmax) and require the
    quantized logits to stay within the quantization error bound of the
    float oracle at every step — across page sizes (many small pages =
    many widen events) and batch widths."""
    cfg = _cfg()
    params = init_params(cfg, jax.random.key(0))
    cf = dec.init_paged_cache(cfg, slots, 32, ps, jnp.float32)
    cq = dec.init_paged_cache(cfg, slots, 32, ps, jnp.float32,
                              quantize="int8")
    stepf = jax.jit(lambda p, c, t: dec.paged_decode_step(
        p, c, t, cfg, None, fuse=True))
    tok = jnp.asarray(np.arange(3, 3 + slots), jnp.int32)
    worst = 0.0
    for _ in range(9):
        lf, cf = stepf(params, cf, tok)
        lq, cq = stepf(params, cq, tok)
        worst = max(worst, float(jnp.max(jnp.abs(lf - lq))))
        tok = jnp.argmax(lf, axis=-1).astype(jnp.int32)
    scale = float(jnp.max(jnp.abs(lf)))
    assert worst <= max(0.08, 0.03 * scale), (worst, scale)
    assert not dec.paged_invariants(cfg, cq)


def test_quantized_fused_equals_unfused_bit_exact():
    """fuse=True vs fuse=False must agree BIT-EXACTLY on the quantized
    pool — both arms read pre-append pages plus the fresh float beat, so
    any divergence is a lowering bug, not quantization noise."""
    cfg = _cfg()
    params = init_params(cfg, jax.random.key(0))
    cm = {f: dec.init_paged_cache(cfg, 2, 32, 8, jnp.float32,
                                  quantize="int8") for f in (True, False)}
    tok = jnp.asarray([3, 5], jnp.int32)
    for step in range(6):
        outs = {}
        for f in (True, False):
            outs[f], cm[f] = dec.paged_decode_step(
                params, cm[f], tok, cfg, None, fuse=f)
        np.testing.assert_array_equal(np.asarray(outs[True]),
                                      np.asarray(outs[False]))
        tok = jnp.argmax(outs[True], axis=-1).astype(jnp.int32)
    # the two pools took identical int-level writes
    for k, leaf in cm[True]["blocks"].items():
        np.testing.assert_array_equal(np.asarray(leaf),
                                      np.asarray(cm[False]["blocks"][k]))


def test_quantized_fused_step_one_gather_one_launch():
    """The quantized acceptance gate mirrors the float one: fusing the
    step saves the same (leaves x superblocks - 1) page gathers, and the
    pinned-kernel fused step still issues ONE launch with ONE mask —
    dequant rides the existing program instead of adding a pass."""
    cfg_ref = _cfg(layers=4, hd=64)
    params = init_params(cfg_ref, jax.random.key(0))
    cache = dec.init_paged_cache(cfg_ref, 2, 64, 16, jnp.float32,
                                 quantize="int8")
    tok = jnp.asarray([3, 5], jnp.int32)
    gf = _count_gathers(
        lambda p, c, t: dec.paged_decode_step(p, c, t, cfg_ref, None,
                                              fuse=True),
        params, cache, tok)
    gp = _count_gathers(
        lambda p, c, t: dec.paged_decode_step(p, c, t, cfg_ref, None,
                                              fuse=False),
        params, cache, tok)
    assert gp - gf == 2 * 2 - 1, (gf, gp)

    cfg = _cfg(layers=4, hd=64, impl="pallas")
    cache = dec.init_paged_cache(cfg, 2, 64, 16, jnp.float32,
                                 quantize="int8")

    def fused(p, c, t):
        return dec.paged_decode_step(p, c, t, cfg, None, fuse=True)

    with accessfuse.pinned_kernel_lowering():
        lf, mf = accessfuse.jaxpr_access_counts(fused, params, cache, tok)
    assert lf == 1 and mf == 1, (lf, mf)


def test_quantized_plan_cache_steady_state_under_jit():
    """scale_dtype keys the plan (a distinct entry from the float
    program), and steady-state quantized stepping must not re-miss."""
    cfg = _cfg()
    params = init_params(cfg, jax.random.key(0))
    cache = dec.init_paged_cache(cfg, 2, 16, 4, jnp.float32,
                                 quantize="int8")
    tok = jnp.asarray([3, 5], jnp.int32)
    jp = jax.jit(lambda p, c, t: dec.paged_decode_step(p, c, t, cfg, None))
    _, cache = jp(params, cache, tok)
    warm = vx.PLANS.stats()["misses"]
    for _ in range(4):
        _, cache = jp(params, cache, tok)
    assert vx.PLANS.stats()["misses"] == warm


def test_invariants_cover_scale_liveness():
    """The audit extends to the scale side tensor: a NaN or negative
    scale (a poisoned page every gather would spread) and a missing scl
    leaf must both trip."""
    cfg = _cfg()
    cache = dec.init_paged_cache(cfg, 2, 16, 4, jnp.float32,
                                 quantize="int8")
    assert not dec.paged_invariants(cfg, cache)
    bad = dict(cache, blocks=dict(
        cache["blocks"], scl0=cache["blocks"]["scl0"].at[0, 0, 0].set(
            jnp.nan)))
    assert any("scl" in v or "scale" in v
               for v in dec.paged_invariants(cfg, bad))
    neg = dict(cache, blocks=dict(
        cache["blocks"], scl0=cache["blocks"]["scl0"].at[0, 1, 0].set(
            -1.0)))
    assert dec.paged_invariants(cfg, neg)
    missing = dict(cache, blocks={k: v for k, v in
                                  cache["blocks"].items() if k != "scl1"})
    assert dec.paged_invariants(cfg, missing)


# ---------------------------------------------------------------------------
# serve: accounting, prefix interop, chaos
# ---------------------------------------------------------------------------

def test_page_bytes_counts_scale_side_tensor():
    """Satellite accounting fix: page_bytes is dtype-aware AND includes
    the per-page scale rows; used_cache_bytes scales those with pages in
    use instead of charging the whole side tensor as recurrent state."""
    cfg, _ = _arch_cfg_params()
    pcf = PagedCache(cfg, 2, 32, 8)
    pcq = PagedCache(cfg, 2, 32, 8, kv_quant="int8")
    scl_pp = sum((leaf.size // leaf.shape[1]) * leaf.dtype.itemsize
                 for k, leaf in pcq.state["blocks"].items()
                 if k.startswith("scl"))
    pool_pp = sum((leaf.size // leaf.shape[1]) * leaf.dtype.itemsize
                  for leaf in pcq.state["blocks"].values()
                  if hasattr(leaf, "ndim") and leaf.ndim == 5)
    assert scl_pp > 0
    assert pcq.page_bytes() == pool_pp + scl_pp
    # int8 pool page = 1/4 the float page; scale overhead keeps the
    # ratio just under 4x, still well past the ~3.5x acceptance floor
    ratio = pcf.page_bytes() / pcq.page_bytes()
    assert 3.5 <= ratio <= 4.0, ratio
    # used bytes scale with pages in use, not allocation
    base_q, base_f = pcq.used_cache_bytes(), pcf.used_cache_bytes()
    assert pcq.total_cache_bytes() < pcf.total_cache_bytes()
    assert base_q <= base_f


def test_quantized_prefix_sharing_bit_exact_vs_nonshared():
    """Adopted pages carry their scales (same physical page, same scale
    row, same ints): a prefix-sharing quantized scheduler must be
    BIT-EXACT vs a non-sharing quantized one — including a partial-tail
    CoW fork, which copies the source page's scale into the fork."""
    cfg, params = _arch_cfg_params()
    shared_full = [3, 5, 7, 9, 2, 4, 6, 8] + [11, 13]
    forked_tail = [3, 5, 7, 9, 2, 4, 9, 9, 12]   # diverges mid-page-2
    for pa, pb in ((shared_full, shared_full[:-2] + [12, 10]),
                   (shared_full, forked_tail)):
        outs = {}
        for name, pc in (("shared", True), ("oracle", False)):
            s = Scheduler(cfg, params, slots=2, max_len=32, page_size=4,
                          num_pages=16, kv_quant="int8", prefix_cache=pc,
                          debug_invariants=True)
            a, b = s.add_request(list(pa)), s.add_request(list(pb))
            outs[name] = [(step[a], step[b]) for step in
                          (s.step() for _ in range(6))]
            s.cache.check_invariants()
        assert outs["shared"] == outs["oracle"]


def test_fork_copies_scale_and_isolates_source():
    """dec-level CoW audit: the fork's page gets the SOURCE's scale row
    (its resident ints only decode correctly under it), and appending
    into the fork afterwards widens the FORK's scale only — the shared
    source page and scale stay byte-identical."""
    cfg = _cfg()
    cache = dec.init_paged_cache(cfg, 2, 16, 4, jnp.float32,
                                 quantize="int8")
    params = init_params(cfg, jax.random.key(0))
    tok = jnp.asarray([3, 5], jnp.int32)
    for _ in range(3):                       # slot pages get real beats
        _, cache = dec.paged_decode_step(params, cache, tok, cfg, None)
    src = int(cache["table"][0, 0])
    cache = dec.paged_fork_page(cfg, cache, jnp.int32(1), jnp.int32(0),
                                jnp.int32(src), pos_to=jnp.int32(2))
    dst = int(cache["table"][1, 0])
    assert dst != src
    blocks = cache["blocks"]
    for i in range(2):
        np.testing.assert_array_equal(
            np.asarray(blocks[f"scl{i}"][:, dst]),
            np.asarray(blocks[f"scl{i}"][:, src]))
        np.testing.assert_array_equal(
            np.asarray(blocks[f"pos{i}"][:, dst, :2]),
            np.asarray(blocks[f"pos{i}"][:, src, :2]))
    before = {i: (np.asarray(blocks[f"pos{i}"][:, src]).copy(),
                  np.asarray(blocks[f"scl{i}"][:, src]).copy())
              for i in range(2)}
    # ONLY the borrower steps (slot 0 masked inactive — it still owns
    # src and would legitimately append there): the write lands in the
    # fork, and the shared source page + scale stay byte-identical
    _, cache = dec.paged_decode_step(params, cache,
                                     jnp.asarray([7, 7], jnp.int32),
                                     cfg, None,
                                     active=jnp.asarray([False, True]))
    blocks = cache["blocks"]
    for i in range(2):
        np.testing.assert_array_equal(np.asarray(blocks[f"pos{i}"][:, src]),
                                      before[i][0])
        np.testing.assert_array_equal(np.asarray(blocks[f"scl{i}"][:, src]),
                                      before[i][1])


@pytest.mark.parametrize("seed", (0, 1))
def test_chaos_preempt_replay_holds_at_int8(seed):
    """The PR 6 chaos gate re-run on the quantized pool: preemption
    replays a request's tokens through quantize-on-write from scratch —
    every request terminates typed and the (scale-extended) invariant
    audit holds every tick."""
    from repro.ft.straggler import StepWatchdog
    from repro.serve.chaos import ChaosConfig, FaultPlan, run_plan
    from repro.serve.lifecycle import TERMINAL_STATES

    class _StepClock:
        def __init__(self):
            self.t = 0.0

        def __call__(self):
            self.t += 0.01
            return self.t

    cfg, params = _arch_cfg_params()
    sched = Scheduler(cfg, params, slots=2, max_len=16, page_size=4,
                      num_pages=6, kv_quant="int8", guard_nan=True,
                      queue_depth=3, watchdog=StepWatchdog(),
                      clock=_StepClock())
    plan = FaultPlan(ChaosConfig(seed=seed, requests=6, steps=32,
                                 max_ticks=256))
    report = run_plan(sched, plan)
    assert report.ticks < plan.cfg.max_ticks
    assert sched.drained()
    assert report.all_terminal, report.states
    for r in report.submitted:
        assert r.state in TERMINAL_STATES
    assert report.invariant_checks >= report.ticks


def test_fleet_migration_holds_at_int8():
    """The PR 7 fleet gate at int8: replica death migrates requests by
    replay into a fresh quantized pool; the fleet audit (which runs the
    per-replica scale-extended invariants) holds every tick."""
    from repro.serve.chaos import (FleetChaosConfig, FleetFaultPlan,
                                   StepClock, run_fleet_plan)
    from repro.serve.fleet import FleetRouter
    from repro.serve.lifecycle import TERMINAL_STATES

    cfg, params = _arch_cfg_params()
    fl = FleetRouter(cfg, params, replicas=2, slots=2, max_len=16,
                     page_size=4, num_pages=6, kv_quant="int8",
                     queue_depth=3, guard_nan=True, clock=StepClock(),
                     watchdog_hard_limit=30.0, hard_breach_limit=1,
                     heartbeat_ticks=4)
    plan = FleetFaultPlan(FleetChaosConfig(seed=1, requests=6, steps=24,
                                           max_ticks=512))
    report = run_fleet_plan(fl, plan)
    assert report.ticks < plan.cfg.max_ticks
    assert fl.drained()
    assert report.all_terminal, report.states
    for r in report.submitted:
        assert r.state in TERMINAL_STATES
    assert report.audits == report.ticks
