"""Fleet router property suite: least-loaded admission with aggregated
backpressure, health states (heartbeat death, hard-limit DEGRADED drain
+ rejoin), replay-based failover (kill -> MIGRATING -> resume elsewhere,
bit-exact vs the uninterrupted oracle on pad-safe stacks), respawn, the
fleet residency audit, and the seeded fleet chaos gate: under plans that
kill replicas mid-decode, every admitted request terminates typed, no
request is lost or double-resident, and per-replica pool invariants
never trip."""
import functools

import jax
import numpy as np
import pytest

from repro.configs import get_arch
from repro.models.transformer import init_params
from repro.serve.chaos import (FleetChaosConfig, FleetFaultPlan, StepClock,
                               run_fleet_plan)
from repro.serve.fleet import (FleetAuditError, FleetRouter, ReplicaState)
from repro.serve.lifecycle import (AdmissionError, RequestState,
                                   TERMINAL_STATES)

SEEDS = (0, 1, 2)


@functools.lru_cache(maxsize=None)
def _cfg_params(arch="qwen3-0.6b"):
    cfg = get_arch(arch).smoke
    return cfg, init_params(cfg, jax.random.key(0))


def _fleet(replicas=3, arch="qwen3-0.6b", **kw):
    cfg, params = _cfg_params(arch)
    kw.setdefault("slots", 2)
    kw.setdefault("max_len", 16)
    kw.setdefault("page_size", 4)
    kw.setdefault("clock", StepClock())
    kw.setdefault("watchdog_hard_limit", 30.0)
    return FleetRouter(cfg, params, replicas=replicas, **kw)


def _drain(fl, reqs, *, cap=256, audit=True):
    ticks = 0
    while not all(r.terminal for r in reqs) and ticks < cap:
        fl.tick()
        if audit:
            fl.audit()
        ticks += 1
    assert ticks < cap, "fleet failed to drain the requests"
    return ticks


# --------------------------- admission routing -------------------------------

def test_least_loaded_routing_spreads_and_breaks_ties_on_index():
    fl = _fleet(replicas=3)
    reqs = [fl.submit([2, 3], max_new_tokens=4) for _ in range(3)]
    assert sorted(r.replica for r in reqs) == [0, 1, 2]
    # all equally loaded again: the tie breaks on the lowest index
    r4 = fl.submit([2, 3], max_new_tokens=4)
    assert r4.replica == 0
    _drain(fl, reqs + [r4])


def test_backpressure_aggregates_across_replicas():
    fl = _fleet(replicas=2, slots=1, queue_depth=1)
    # slots fill on tick, so pre-tick capacity is queue_depth per replica
    ok = [fl.submit([1, 2], max_new_tokens=4) for _ in range(2)]
    with pytest.raises(AdmissionError) as ei:
        fl.submit([1, 2], max_new_tokens=4)
    msg = str(ei.value)
    assert "r0" in msg and "r1" in msg       # every replica's refusal
    assert ei.value.retry_after >= 0.0
    _drain(fl, ok)


def test_pinned_submit_to_unhealthy_replica_is_backpressure():
    fl = _fleet(replicas=2)
    fl.kill_replica(1)
    with pytest.raises(AdmissionError, match="replica 1 is dead"):
        fl.submit([1, 2], max_new_tokens=2, replica=1)
    # unpinned routing still works around the dead replica
    r = fl.submit([1, 2], max_new_tokens=2)
    assert r.replica == 0
    _drain(fl, [r])


def test_malformed_traffic_comes_back_typed_failed():
    fl = _fleet(replicas=2)
    r = fl.submit([], max_new_tokens=2)
    assert r.state is RequestState.FAILED and r.error
    fl.audit()                               # terminal, never resident


# --------------------------- failover ----------------------------------------

def test_kill_migrates_resident_requests_and_respawns():
    fl = _fleet(replicas=3)
    reqs = [fl.submit([3 + i, 5, 7], max_new_tokens=8) for i in range(3)]
    for _ in range(2):
        fl.tick()
        fl.audit()
    victim = reqs[0].replica
    gen_before = fl.replicas[victim].generation
    fl.kill_replica(victim)
    fl.audit()                               # nothing lost at the boundary
    assert reqs[0].migrations == 1
    assert reqs[0].replica != victim
    _drain(fl, reqs)
    assert all(r.state is RequestState.FINISHED for r in reqs)
    respawned = fl.replicas[victim]
    assert respawned.state is ReplicaState.HEALTHY
    assert respawned.generation == gen_before + 1
    assert respawned.sched.cache.pages_in_use() == 0   # empty pool rejoin
    assert fl.deaths == 1 and fl.respawns == 1


def test_queued_work_on_dead_replica_migrates_too():
    fl = _fleet(replicas=2, slots=1, queue_depth=4)
    # replica 0: one running + one queued behind it
    reqs = [fl.submit([5, 6], max_new_tokens=6, replica=0)
            for _ in range(2)]
    fl.tick()
    fl.audit()
    assert reqs[0].state is RequestState.RUNNING
    assert reqs[1].state is RequestState.QUEUED
    fl.kill_replica(0)
    fl.audit()
    assert {r.replica for r in reqs} == {1}
    assert all(r.migrations == 1 for r in reqs)
    _drain(fl, reqs)
    assert all(r.state is RequestState.FINISHED for r in reqs)


def test_no_live_replica_fails_typed_never_lost():
    fl = _fleet(replicas=1, respawn=False)
    r = fl.submit([4, 5, 6], max_new_tokens=8)
    fl.tick()
    assert r.state is RequestState.RUNNING
    fl.kill_replica(0)
    assert r.state is RequestState.FAILED
    assert "no live replica" in r.error
    fl.audit()
    assert fl.drained()


# --------------------------- health: heartbeat + degraded --------------------

def test_hang_past_heartbeat_bound_is_dead_and_work_survives():
    fl = _fleet(replicas=2, heartbeat_ticks=3)
    reqs = [fl.submit([7, 8, 9], max_new_tokens=8, replica=i)
            for i in range(2)]
    fl.tick()
    victim = reqs[0].replica
    fl.hang_replica(victim, ticks=10)        # way past the bound
    for _ in range(5):
        fl.tick()
        fl.audit()
    assert fl.deaths >= 1
    assert fl.replicas[victim].generation >= 1      # respawned
    _drain(fl, reqs)
    assert all(r.state is RequestState.FINISHED for r in reqs)
    assert reqs[0].migrations >= 1


def test_short_hang_wakes_degrades_and_rejoins_after_drain():
    fl = _fleet(replicas=2, heartbeat_ticks=6, hard_breach_limit=1)
    # replica 0: a running request AND queued work to migrate on drain
    reqs = [fl.submit([9, 8], max_new_tokens=10, replica=0)
            for _ in range(3)]
    other = fl.submit([4, 4], max_new_tokens=4, replica=1)
    fl.tick()
    fl.audit()
    fl.hang_replica(0, ticks=2)              # wakes before the bound
    for _ in range(3):
        fl.tick()
        fl.audit()
    # the stall was observed as one giant step -> hard breach -> DEGRADED
    assert fl.drains == 1
    rep0 = fl.replicas[0]
    assert fl.deaths == 0
    # queued work migrated off; running finishes in place
    resident0 = rep0.sched.resident_rids()
    assert all(r.rid not in resident0 or r.slot is not None
               for r in reqs)
    _drain(fl, reqs + [other])
    assert all(r.state is RequestState.FINISHED for r in reqs + [other])
    assert fl.rejoins == 1
    assert fl.replicas[0].state is ReplicaState.HEALTHY


def test_degraded_replica_admits_nothing():
    fl = _fleet(replicas=2, hard_breach_limit=1)
    r0 = fl.submit([3, 3], max_new_tokens=12, replica=0)
    fl.tick()
    fl.replicas[0].sched.watchdog.observe(1e9)      # hard-limit breach
    fl.tick()
    assert fl.replicas[0].state is ReplicaState.DEGRADED
    with pytest.raises(AdmissionError, match="replica 0 is degraded"):
        fl.submit([1, 2], max_new_tokens=2, replica=0)
    r = fl.submit([1, 2], max_new_tokens=2)
    assert r.replica == 1                     # routed around the drain
    _drain(fl, [r0, r])


# --------------------------- determinism oracles -----------------------------

def _trace(n=6, seed=7):
    rng = np.random.default_rng(seed)
    out = []
    for _ in range(n):
        plen = int(rng.integers(1, 5))
        out.append(([int(t) for t in rng.integers(0, 97, plen)],
                    int(rng.integers(2, 8))))
    return out


def _run_trace_through(fl, trace):
    reqs = []
    for prompt, gen in trace:
        req = None
        for _ in range(64):                  # backpressure: tick and retry
            try:
                req = fl.submit(prompt, max_new_tokens=gen)
                break
            except AdmissionError:
                fl.tick()
        assert req is not None
        reqs.append(req)
    _drain(fl, reqs)
    return [tuple(r.tokens) for r in reqs]


def test_fleet_determinism_one_vs_n_replicas():
    """Same trace, no faults: 1 replica vs 3 replicas produce IDENTICAL
    per-request token streams (greedy decode; slot rows are independent
    of batch composition), and every replica's step stays a single jit
    trace — the router adds nothing to the device fast path."""
    trace = _trace()
    one = _run_trace_through(_fleet(replicas=1), trace)
    fl3 = _fleet(replicas=3)
    three = _run_trace_through(fl3, trace)
    assert one == three
    for rep in fl3.replicas:
        assert rep.sched._step._cache_size() == 1


def _logits_drive(fl, req, *, kill_at=None, preempt_at=None, cap=64):
    """Tick the fleet until ``req`` terminates, recording its slot's
    logits keyed by replay-cursor position; optionally kill its replica
    (migration) or preempt it in place after N ticks."""
    logits_by_pos = {}
    for t in range(cap):
        if req.terminal:
            break
        if kill_at is not None and t == kill_at and \
                req.state is RequestState.RUNNING:
            fl.kill_replica(req.replica, reason="oracle kill")
        if preempt_at is not None and t == preempt_at and \
                req.state is RequestState.RUNNING:
            fl.replicas[req.replica].sched.preempt(req.slot)
        fl.tick()
        fl.audit()
        rep = fl.replicas[req.replica] if req.replica is not None else None
        if rep is not None and rep.alive and req.slot is not None and \
                rep.sched.active[req.slot] and \
                rep.sched.last_logits is not None:
            pos = rep.sched._fed[req.slot]
            logits_by_pos[pos] = np.asarray(
                rep.sched.last_logits[req.slot], np.float32)
    return logits_by_pos


def _migration_oracle(arch, *, comparer):
    cfg_prompt, gen = [3, 5, 7, 9, 2], 8
    # uninterrupted single-replica oracle
    fa = _fleet(replicas=1, arch=arch)
    ra = fa.submit(cfg_prompt, max_new_tokens=gen)
    la = _logits_drive(fa, ra)
    assert ra.state is RequestState.FINISHED

    # kill-then-migrate on a 2-replica fleet
    fb = _fleet(replicas=2, arch=arch)
    rb = fb.submit(cfg_prompt, max_new_tokens=gen)
    lb = _logits_drive(fb, rb, kill_at=3)
    assert rb.state is RequestState.FINISHED
    assert rb.migrations == 1

    # preempt-then-resume on the SAME replica (the PR 6 path)
    fc = _fleet(replicas=1, arch=arch)
    rc = fc.submit(cfg_prompt, max_new_tokens=gen)
    lc = _logits_drive(fc, rc, preempt_at=3)
    assert rc.state is RequestState.FINISHED
    assert rc.preemptions == 1

    # the full stream survives both failure modes
    assert rb.tokens == ra.tokens
    assert rc.tokens == ra.tokens
    shared = sorted(set(la) & set(lb) & set(lc))
    assert len(shared) >= gen - 1
    for pos in shared:
        comparer(la[pos], lb[pos], pos)      # migrate == uninterrupted
        comparer(lc[pos], lb[pos], pos)      # migrate == preempt-resume


def test_migration_equals_preemption_bit_exact_pad_safe():
    """Kill-then-migrate == preempt-then-resume == uninterrupted, at the
    LOGITS level, bit-exact: migration is the same replay cursor pointed
    at a different page pool, and both replicas run the same jit'd
    computation over the same params."""
    def bit_exact(x, y, pos):
        assert np.array_equal(x, y), \
            f"pos {pos}: maxdiff {np.abs(x - y).max()}"
    _migration_oracle("qwen3-0.6b", comparer=bit_exact)


def test_migration_allclose_windowed():
    """Windowed stack: prefill runs at true length, so the bar is
    allclose (same bar as PR 6 preempt-resume)."""
    def close(x, y, pos):
        np.testing.assert_allclose(x, y, rtol=2e-4, atol=2e-5,
                                   err_msg=f"pos {pos}")
    _migration_oracle("gemma3-12b", comparer=close)


def test_midprefill_failover_resumes_bit_exact():
    """PR 8: kill a replica while its resident request is still
    PREFILLING (chunked prefill in flight) — the request migrates
    BETWEEN chunks, re-prefills on the target replica through the same
    chunk jit, and the final stream is bit-exact vs the uninterrupted
    single-replica oracle."""
    prompt = [3, 5, 7, 9, 2, 4, 6, 8, 1, 3, 5, 7, 9]   # pre = 3 chunks
    gen = 6

    fa = _fleet(replicas=1, max_len=32, chunk_pages=1)
    ra = fa.submit(prompt, max_new_tokens=gen)
    _drain(fa, [ra])
    assert ra.state is RequestState.FINISHED

    fb = _fleet(replicas=2, max_len=32, chunk_pages=1)
    rb = fb.submit(prompt, max_new_tokens=gen)
    killed = False
    for _ in range(64):
        if rb.terminal:
            break
        if not killed and rb.state is RequestState.PREFILLING:
            fb.kill_replica(rb.replica, reason="mid-prefill kill")
            killed = True
        fb.tick()
        fb.audit()
    assert killed, "request never observed mid-prefill"
    assert rb.state is RequestState.FINISHED
    assert rb.migrations == 1
    assert rb.tokens == ra.tokens


# --------------------------- fleet audit negatives ---------------------------

def test_audit_catches_double_residency():
    fl = _fleet(replicas=2)
    r = fl.submit([2, 3], max_new_tokens=8)
    fl.tick()
    # corrupt the control plane: the same request queued on BOTH replicas
    fl.replicas[1 - r.replica].sched.queue._q.append(r)
    with pytest.raises(FleetAuditError, match="double-resident"):
        fl.audit()


def test_audit_catches_lost_request():
    fl = _fleet(replicas=2, slots=1)
    a = fl.submit([2, 3], max_new_tokens=8, replica=0)
    b = fl.submit([2, 3], max_new_tokens=8, replica=0)   # queued behind a
    fl.tick()
    assert b.state is RequestState.QUEUED
    fl.replicas[0].sched.queue.drain()       # drop it on the floor
    with pytest.raises(FleetAuditError, match="lost"):
        fl.audit()


# --------------------------- the chaos gate ----------------------------------

@pytest.mark.parametrize("seed", SEEDS)
def test_fleet_chaos_terminates_typed_and_invariants_hold(seed):
    """The PR 7 acceptance gate: under a seeded fault plan over 3
    replicas, every admitted request reaches a terminal typed state,
    the fleet audit (no lost / double-resident request, per-replica
    pool invariants) passes EVERY tick, and the fleet drains."""
    fl = _fleet(replicas=3, num_pages=6, queue_depth=3, guard_nan=True,
                hard_breach_limit=1, heartbeat_ticks=4)
    plan = FleetFaultPlan(FleetChaosConfig(seed=seed, requests=8,
                                           steps=32, max_ticks=512))
    report = run_fleet_plan(fl, plan)
    assert report.ticks < plan.cfg.max_ticks        # liveness
    assert fl.drained()
    assert report.all_terminal, report.states
    assert sum(report.states.values()) == len(report.submitted)
    for r in report.submitted:
        assert r.state in TERMINAL_STATES
        assert r.state is not RequestState.FAILED or r.error
    assert report.audits == report.ticks            # audited every tick


def test_fleet_chaos_exercises_kills_and_migration():
    """The seeded plans must actually kill replicas mid-decode and
    migrate work — a fleet chaos suite that never fails over is
    vacuous."""
    deaths = migrated = recovered = respawns = 0
    for seed in SEEDS:
        fl = _fleet(replicas=3, num_pages=6, queue_depth=3,
                    guard_nan=True, hard_breach_limit=1,
                    heartbeat_ticks=4)
        plan = FleetFaultPlan(FleetChaosConfig(seed=seed, requests=8,
                                               steps=32, max_ticks=512))
        rep = run_fleet_plan(fl, plan)
        deaths += rep.deaths
        migrated += rep.migrated
        recovered += rep.recovered
        respawns += rep.respawns
    assert deaths > 0
    assert respawns > 0
    assert migrated > 0
    assert recovered > 0          # some migrated request FINISHED


def test_fleet_chaos_is_reproducible():
    outs = []
    for _ in range(2):
        fl = _fleet(replicas=3, num_pages=6, queue_depth=3,
                    guard_nan=True, hard_breach_limit=1)
        rep = run_fleet_plan(fl, FleetFaultPlan(
            FleetChaosConfig(seed=1, requests=6, steps=24,
                             max_ticks=512)))
        outs.append([(r.state.value, tuple(r.tokens))
                     for r in rep.submitted])
    assert outs[0] == outs[1]
