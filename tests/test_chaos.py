"""Chaos harness property suite: under seeded fault plans with pool
invariant auditing ALWAYS on, every admitted request reaches a terminal
typed state, the page pool never corrupts, preempt->requeue->resume is
bit-exact vs an uninterrupted oracle for pad-safe stacks (allclose for
windowed / recurrent), and the NaN guard fails only the offending slot."""
import functools

import jax
import numpy as np
import pytest

from repro.configs import get_arch
from repro.ft.straggler import StepWatchdog
from repro.models.transformer import init_params
from repro.serve.chaos import ChaosConfig, FaultPlan, run_plan
from repro.serve.lifecycle import TERMINAL_STATES, RequestState
from repro.serve.scheduler import Scheduler

SEEDS = (0, 1, 2)


@functools.lru_cache(maxsize=None)
def _cfg_params(arch="qwen3-0.6b"):
    cfg = get_arch(arch).smoke
    return cfg, init_params(cfg, jax.random.key(0))


def _chaos_sched(clock, **kw):
    cfg, params = _cfg_params()
    kw.setdefault("queue_depth", 3)     # tight: backpressure gets exercised
    return Scheduler(cfg, params, slots=2, max_len=16, page_size=4,
                     num_pages=6, guard_nan=True,
                     watchdog=StepWatchdog(), clock=clock, **kw)


class _StepClock:
    """Deterministic clock: each call advances a fixed quantum, so
    deadline logic runs without wall time."""

    def __init__(self, dt=0.01):
        self.t, self.dt = 0.0, dt

    def __call__(self):
        self.t += self.dt
        return self.t


# --------------------------- plan determinism -------------------------------

def test_fault_plan_is_deterministic():
    a = FaultPlan(ChaosConfig(seed=3))
    b = FaultPlan(ChaosConfig(seed=3))
    assert a.faults == b.faults
    assert a.workload == b.workload
    c = FaultPlan(ChaosConfig(seed=4))
    assert a.faults != c.faults or a.workload != c.workload


def test_fault_plan_covers_the_vocabulary():
    kinds = set()
    for seed in range(8):
        kinds |= {f.kind for f in FaultPlan(ChaosConfig(seed=seed)).faults}
    assert kinds == {"preempt", "nan", "kill", "spike", "bad_prompt"}


# --------------------------- the property suite -----------------------------

@pytest.mark.parametrize("seed", SEEDS)
def test_chaos_every_request_terminates_and_invariants_hold(seed):
    sched = _chaos_sched(_StepClock())
    plan = FaultPlan(ChaosConfig(seed=seed, requests=6, steps=32,
                                 max_ticks=256))
    report = run_plan(sched, plan)
    # liveness: the engine drained before the tick cap
    assert report.ticks < plan.cfg.max_ticks
    assert sched.drained()
    # every submitted request reached a terminal typed state
    assert report.all_terminal, report.states
    assert sum(report.states.values()) == len(report.submitted)
    for r in report.submitted:
        assert r.state in TERMINAL_STATES
        assert r.state is not RequestState.FAILED or r.error
    # invariants audited EVERY tick and never tripped (run_plan raises
    # InvariantViolation otherwise — reaching here is the assertion)
    assert report.invariant_checks >= report.ticks


def test_chaos_exercises_faults_and_backpressure():
    """The seeded plans must actually hit the interesting paths —
    a chaos suite that never preempts or never injects NaN is vacuous."""
    preempts = nans = backpressured = 0
    for seed in SEEDS:
        sched = _chaos_sched(_StepClock())
        plan = FaultPlan(ChaosConfig(seed=seed, requests=6, steps=32,
                                     max_ticks=256))
        rep = run_plan(sched, plan)
        preempts += rep.preemptions
        nans += rep.nan_failures
        backpressured += rep.backpressured
    assert preempts > 0
    assert nans > 0
    assert backpressured > 0


def test_chaos_is_reproducible():
    """Same seed, fresh schedulers: identical terminal states and token
    streams (greedy decode + materialized fault plan = full replay)."""
    outs = []
    for _ in range(2):
        sched = _chaos_sched(_StepClock())
        rep = run_plan(sched, FaultPlan(ChaosConfig(seed=1, requests=5,
                                                    steps=24,
                                                    max_ticks=256)))
        outs.append([(r.state.value, tuple(r.tokens))
                     for r in rep.submitted])
    assert outs[0] == outs[1]


# --------------------------- preempt/resume oracle --------------------------

def _drive(sched, req, *, preempt_at=None, cap=64):
    """Tick until the request terminates; optionally preempt it once
    after ``preempt_at`` ticks.  Records the slot's logits row keyed by
    the replay cursor (the position whose logits these are), so two runs
    can be compared position-by-position."""
    logits_by_pos = {}
    for t in range(cap):
        if req.terminal:
            break
        if preempt_at is not None and t == preempt_at and \
                req.state is RequestState.RUNNING:
            sched.preempt(req.slot)
        sched.tick()
        if req.slot is not None and sched.active[req.slot] and \
                sched.last_logits is not None:
            pos = sched._fed[req.slot]
            logits_by_pos[pos] = np.asarray(
                sched.last_logits[req.slot], np.float32)
    return logits_by_pos


def _resume_oracle(arch, *, comparer):
    cfg, params = _cfg_params(arch)
    prompt, gen = [3, 5, 7, 9, 2], 8

    def mk():
        return Scheduler(cfg, params, slots=2, max_len=32, page_size=4)

    a = mk()
    ra = a.submit(prompt, max_new_tokens=gen)
    la = _drive(a, ra)
    assert ra.state is RequestState.FINISHED

    b = mk()
    rb = b.submit(prompt, max_new_tokens=gen)
    lb = _drive(b, rb, preempt_at=3)
    assert rb.state is RequestState.FINISHED
    assert rb.preemptions == 1

    # the full stream — prompt AND generated — survives the preemption
    assert rb.tokens == ra.tokens
    shared = sorted(set(la) & set(lb))
    assert len(shared) >= gen - 1          # replay re-visits the positions
    for pos in shared:
        comparer(la[pos], lb[pos], pos)


def test_preempt_resume_bit_exact_pad_safe():
    """Pad-safe stack (windowless attention-only): resume decode must be
    BIT-EXACT vs the uninterrupted oracle — re-prefilling the original
    prompt and replaying generated tokens through the ordinary decode
    step is literally the same computation the oracle performed."""
    def bit_exact(x, y, pos):
        assert np.array_equal(x, y), \
            f"pos {pos}: maxdiff {np.abs(x - y).max()}"
    _resume_oracle("qwen3-0.6b", comparer=bit_exact)


def test_preempt_resume_allclose_windowed():
    """Windowed stack (gemma3 smoke, ring-window layers): the acceptance
    bar is allclose — prefill runs at TRUE length so resume state can
    differ at the ULP level from the padded pad-safe path."""
    def close(x, y, pos):
        np.testing.assert_allclose(x, y, rtol=2e-4, atol=2e-5,
                                   err_msg=f"pos {pos}")
    _resume_oracle("gemma3-12b", comparer=close)


def test_preempt_resume_allclose_recurrent():
    """Recurrent stack (xlstm smoke): no page pool to restore — resume
    rebuilds the state by re-prefill + replay; allclose is the bar."""
    def close(x, y, pos):
        np.testing.assert_allclose(x, y, rtol=2e-4, atol=2e-5,
                                   err_msg=f"pos {pos}")
    _resume_oracle("xlstm-125m", comparer=close)


# --------------------------- NaN guard isolation ----------------------------

def test_nan_guard_fails_only_offending_slot():
    cfg, params = _cfg_params()

    def mk():
        return Scheduler(cfg, params, slots=2, max_len=16, page_size=4,
                         guard_nan=True)

    # solo oracle: the neighbour's stream with NO chaos anywhere
    solo = mk()
    r_solo = solo.submit([11, 13], max_new_tokens=6)
    while not r_solo.terminal:
        solo.tick()

    chaotic = mk()
    victim = chaotic.submit([2, 4, 6], max_new_tokens=6)
    neighbour = chaotic.submit([11, 13], max_new_tokens=6)
    chaotic.tick()                        # both admitted + first step
    taint = np.zeros(2, bool)
    taint[victim.slot] = True
    chaotic._taint = taint                # NaN logits for victim, once
    while not (victim.terminal and neighbour.terminal):
        chaotic.tick()
    assert victim.state is RequestState.FAILED
    assert "non-finite" in victim.error
    assert chaotic.nan_failures == 1
    # neighbour unharmed: FINISHED with the bit-identical stream
    assert neighbour.state is RequestState.FINISHED
    assert neighbour.tokens == r_solo.tokens
    # victim's pages reclaimed
    assert chaotic.cache.pages_in_use() == \
        chaotic.cache.pages_needed(len(neighbour.tokens)) or \
        chaotic.cache.pages_in_use() == 0


# --------------------------- fast path untouched ----------------------------

def test_no_fault_clean_run_single_trace():
    """Lifecycle machinery on (queue, deadlines available, watchdog) but
    no fault fired: the jit'd step must compile exactly once across the
    whole run — the hardened runtime must not touch the steady-state
    fast path."""
    cfg, params = _cfg_params()
    sched = Scheduler(cfg, params, slots=2, max_len=16, page_size=4,
                      watchdog=StepWatchdog())
    r1 = sched.submit([3, 5, 7], max_new_tokens=8)
    r2 = sched.submit([2], max_new_tokens=8)
    while not (r1.terminal and r2.terminal):
        sched.tick()
    assert sched._step._cache_size() == 1
    assert r1.state is RequestState.FINISHED
    assert r2.state is RequestState.FINISHED
