"""Prefix-sharing property suite (PR 8): two requests sharing a K-page
prompt prefix occupy exactly K shared pages (asserted through the device
refcount audit: donor + borrower + trie pin), partial-tail overlap forks
ONE copy-on-write page, decode stays BIT-EXACT vs the non-shared paged
oracle on pad-safe stacks, mid-prefill preemption resumes bit-exactly,
the trie evicts LRU orphans under pool pressure, refcount conservation
holds under seeded chaos plans that preempt/kill/evict during chunked
prefill, and the steady-state device path is untouched (zero plan-cache
misses, single decode jit trace)."""
import functools

import jax
import numpy as np
import pytest

from repro.configs import get_arch
from repro.models.transformer import init_params
from repro.serve.chaos import ChaosConfig, FaultPlan, run_plan
from repro.serve.lifecycle import (AdmissionError, RequestState,
                                   TERMINAL_STATES)
from repro.serve.scheduler import Scheduler

SEEDS = (0, 1, 2)

# 12 tokens = exactly 3 pages at page_size=4 — the shared system prompt
SHARED = [3, 5, 7, 9, 2, 4, 6, 8, 1, 3, 5, 7]


@functools.lru_cache(maxsize=None)
def _cfg_params(arch="qwen3-0.6b"):
    cfg = get_arch(arch).smoke
    return cfg, init_params(cfg, jax.random.key(0))


def _sched(arch="qwen3-0.6b", **kw):
    cfg, params = _cfg_params(arch)
    kw.setdefault("slots", 2)
    kw.setdefault("max_len", 32)
    kw.setdefault("page_size", 4)
    kw.setdefault("num_pages", 16)
    kw.setdefault("debug_invariants", True)
    kw.setdefault("prefix_cache", True)
    return Scheduler(cfg, params, **kw)


class _StepClock:
    def __init__(self, dt=0.01):
        self.t, self.dt = 0.0, dt

    def __call__(self):
        self.t += self.dt
        return self.t


# --------------------------- the sharing property ---------------------------

def test_k_page_prefix_occupies_k_shared_pages():
    """The headline property: after the donor publishes a 3-page prefix
    and the borrower adopts it, the pool holds the prefix ONCE — each
    shared page's refcount is exactly donor + borrower + trie pin, and
    every other allocated page is private (ref == 1)."""
    sched = _sched()
    sched.add_request(SHARED + [11, 13])
    ref = sched.cache.page_refcounts()
    # donor published: each full prefix page is pinned by donor + trie
    assert int((ref == 2).sum()) == 3, ref[ref > 0]
    sb = sched.add_request(SHARED + [12, 10])
    st = sched.stats()
    assert st["prefix"]["hits"] >= 1
    assert st["prefix"]["tokens_reused"] >= 12
    ref = sched.cache.page_refcounts()
    assert int((ref == 3).sum()) == 3, ref[ref > 0]   # K shared pages
    assert int((ref > 3).sum()) == 0                   # and no more
    assert st["shared_pages"] == 3
    # the borrower's tail (the token past the shared prefix) is private
    row = sched.cache.table_row(sb)
    tail = int(row[3])
    assert tail >= 0 and int(ref[tail]) == 1
    sched.cache.check_invariants()


def test_shared_pages_survive_donor_release():
    """Finishing the donor must NOT reclaim the shared pages out from
    under the borrower: refcounts drop by one, the trie pin keeps the
    prefix cached, and the borrower keeps decoding on intact KV."""
    sched = _sched()
    sa = sched.add_request(SHARED + [11, 13])
    sb = sched.add_request(SHARED + [12, 10])
    first = sched.step()[sb]
    sched.finish(sa)
    ref = sched.cache.page_refcounts()
    assert int((ref == 2).sum()) == 3        # borrower + trie remain
    sched.cache.check_invariants()
    # the borrower still decodes greedily off the shared KV
    nxt = sched.step()[sb]
    assert int(first) >= 0 and int(nxt) >= 0


def test_prefix_decode_bit_exact_vs_nonshared_oracle():
    """Borrowed pages are the SAME physical KV the donor wrote, so the
    borrower's greedy stream must be bit-exact vs a scheduler that never
    shares (prefix_cache=False) — on a pad-safe stack."""
    pa = SHARED + [11, 13]
    pb = SHARED + [12, 10]
    shared, oracle = _sched(), _sched(prefix_cache=False)
    outs = {}
    for name, s in (("shared", shared), ("oracle", oracle)):
        a, b = s.add_request(list(pa)), s.add_request(list(pb))
        outs[name] = [(step[a], step[b]) for step in
                      (s.step() for _ in range(6))]
        s.cache.check_invariants()
    assert shared.stats()["prefix"]["hits"] >= 1
    assert oracle.prefix is None
    assert outs["shared"] == outs["oracle"]


def test_partial_tail_fork_is_copy_on_write_and_bit_exact():
    """Share 6 tokens = 1 full page + 2 tokens into the donor's second
    page: admission adopts page one, FORKS the partially-matching page
    (copy-on-write — the donor's page is never written through), and the
    borrower's stream is bit-exact vs the non-shared oracle."""
    pa = [3, 5, 7, 9, 2, 4, 6, 8, 11]      # pre = 8 tokens -> 2 pages
    pb = [3, 5, 7, 9, 2, 4, 9, 9, 12]      # diverges 2 tokens into page 2
    shared, oracle = _sched(), _sched(prefix_cache=False)
    outs = {}
    for name, s in (("shared", shared), ("oracle", oracle)):
        a, b = s.add_request(list(pa)), s.add_request(list(pb))
        outs[name] = [(step[a], step[b]) for step in
                      (s.step() for _ in range(6))]
        s.cache.check_invariants()
    st = shared.stats()
    assert st["prefix"]["tokens_reused"] == 6     # 1 page + 2-token fork
    ref = shared.cache.page_refcounts()
    assert int((ref == 3).sum()) == 1             # the one fully-shared page
    assert outs["shared"] == outs["oracle"]


# --------------------------- chunked prefill --------------------------------

def test_mid_prefill_preempt_then_resume_bit_exact():
    """Preempt a slot BETWEEN prefill chunks (the new PREFILLING ->
    PREEMPTED edge): pages are released, the request requeues carrying
    its prompt, and resume re-prefills through the same chunk jit —
    the final stream is bit-exact vs an uninterrupted oracle."""
    prompt = SHARED + [11]                  # pre = 12 tokens = 3 chunks
    oracle = _sched(slots=1, chunk_pages=1, clock=_StepClock())
    ra = oracle.submit(list(prompt), max_new_tokens=4)
    for _ in range(32):
        if ra.terminal:
            break
        oracle.tick()
    assert ra.state is RequestState.FINISHED

    sched = _sched(slots=1, chunk_pages=1, clock=_StepClock())
    rb = sched.submit(list(prompt), max_new_tokens=4)
    preempted = False
    for _ in range(64):
        if rb.terminal:
            break
        if not preempted and rb.state is RequestState.PREFILLING:
            sched.preempt(rb.slot)
            preempted = True
            # PREFILLING -> PREEMPTED fired; the queue re-enqueues it
            assert rb.state is RequestState.QUEUED
        sched.tick()
    assert preempted, "request never observed mid-prefill"
    assert rb.state is RequestState.FINISHED
    assert rb.preemptions == 1
    assert rb.tokens == ra.tokens
    sched.cache.check_invariants()


def test_retry_after_accounts_for_pending_prefill_chunks():
    """Satellite 2: the backpressure hint scales with the queued prefill
    backlog in per-tick chunk budgets — a long queued prompt pushes the
    hint out by its chunk count, not by one decode step."""
    sched = _sched(slots=1, chunk_pages=1, queue_depth=2,
                   clock=_StepClock())
    sched.add_request([3, 5, 7])            # occupy the only slot
    sched._step_ewma = 0.01                 # a known decode-step EWMA
    h0 = sched._retry_after()
    assert h0 == pytest.approx(0.01)        # nothing pending: plain EWMA
    sched.submit(SHARED + [11, 13], max_new_tokens=2)   # 13-token prefill
    h1 = sched._retry_after()
    assert h1 > h0
    sched.submit(SHARED + [12, 10], max_new_tokens=2)
    h2 = sched._retry_after()
    assert h2 > h1
    with pytest.raises(AdmissionError) as ei:           # queue full
        sched.submit(SHARED + [10, 14], max_new_tokens=2)
    # the typed error folds the chunk backlog in (scaled further by
    # queue occupancy) — strictly more honest than the plain EWMA
    assert ei.value.retry_after >= h2 > 0.01


# --------------------------- eviction under pressure -------------------------

def test_trie_evicts_orphans_under_pool_pressure():
    """Orphaned trie pages (cached prefix, no live user) are EVICTABLE
    capacity: an admission that would otherwise exhaust the pool evicts
    LRU leaves instead of refusing, and the refcount audit stays clean."""
    sched = _sched(slots=2, max_len=16, num_pages=8)
    sa = sched.add_request(SHARED + [11])   # publishes 3 trie pages
    sched.finish(sa)                        # orphans them (trie-only pins)
    ref = sched.cache.page_refcounts()
    assert int((ref == 1).sum()) == 3       # cached, no user
    # fresh prompts prefill 3 + 3 pages; only 5 are free -> the last
    # chunk runs the free list dry and must evict an orphan to proceed
    sched.add_request([21, 22, 23, 24, 25, 26, 27, 28, 29, 21, 22, 23, 24])
    sched.add_request([31, 32, 33, 34, 35, 36, 37, 38, 31, 32])
    st = sched.stats()
    assert st["prefix"]["evicted"] >= 1
    sched.cache.check_invariants()


# --------------------------- chaos: refcount conservation --------------------

@pytest.mark.parametrize("seed", SEEDS)
def test_chaos_refcount_conservation_with_prefix_and_chunks(seed):
    """Seeded chaos over the prefix-sharing pool with chunked prefill:
    preempt / kill / evict faults land mid-prefill, yet every request
    terminates typed and the refcount conservation audit (table counts +
    trie pins == device refcounts) passes EVERY tick — run_plan raises
    InvariantViolation otherwise."""
    cfg, params = _cfg_params()
    sched = Scheduler(cfg, params, slots=2, max_len=16, page_size=4,
                      num_pages=8, guard_nan=True, queue_depth=3,
                      prefix_cache=True, chunk_pages=1,
                      debug_invariants=True, clock=_StepClock())
    plan = FaultPlan(ChaosConfig(seed=seed, requests=6, steps=32,
                                 max_ticks=256, p_evict=0.15))
    report = run_plan(sched, plan)
    assert report.ticks < plan.cfg.max_ticks
    assert sched.drained()
    assert report.all_terminal, report.states
    for r in report.submitted:
        assert r.state in TERMINAL_STATES
    assert report.invariant_checks >= report.ticks


def test_chaos_plans_actually_evict():
    """The evict fault must fire somewhere across the seed set — a chaos
    suite that never exercises trie eviction is vacuous."""
    kinds = set()
    for seed in SEEDS:
        kinds |= {f.kind for f in FaultPlan(
            ChaosConfig(seed=seed, p_evict=0.15)).faults}
    assert "evict" in kinds


# --------------------------- device fast path unchanged ----------------------

def test_zero_steady_state_misses_single_trace_with_prefix_on():
    """Prefix sharing and chunked prefill are ADMISSION-time machinery:
    once slots are decoding, repeated steps must never miss the plan
    cache, and the decode step stays ONE jit trace."""
    from repro import vx
    sched = _sched()
    sched.add_request(SHARED + [11, 13])
    sched.add_request(SHARED + [12, 10])
    sched.step()                            # warmup
    warm = vx.PLANS.stats()
    for _ in range(4):
        sched.step()
    steady = vx.PLANS.stats()
    assert steady["misses"] == warm["misses"], (warm, steady)
    assert steady["evictions"] == warm["evictions"], (warm, steady)
    assert sched._step._cache_size() == 1
