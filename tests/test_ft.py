"""Fault tolerance: checkpoint atomicity/roundtrip, elastic restore,
straggler policy behaviour."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ft.checkpoint import CheckpointError, CheckpointManager
from repro.ft.straggler import (StepWatchdog, StragglerConfig,
                                StragglerPolicy)


def _tree(key=0):
    k = jax.random.key(key)
    return {"params": {"w": jax.random.normal(k, (8, 4)),
                       "ln": jnp.ones((4,))},
            "opt": {"m": jnp.zeros((8, 4)), "step": jnp.asarray(7)}}


def test_save_restore_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    tree = _tree()
    mgr.save(7, tree, extra={"pipeline": {"step": 3, "seed": 0}},
             blocking=True)
    restored, extra = mgr.restore(jax.tree.map(jnp.zeros_like, tree))
    jax.tree.map(lambda a, b: np.testing.assert_allclose(
        np.asarray(a), np.asarray(b)), tree, restored)
    assert extra["pipeline"]["step"] == 3


def test_latest_and_gc(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    tree = _tree()
    for s in (1, 2, 3, 4):
        mgr.save(s, tree, blocking=True)
    assert mgr.latest_step() == 4
    assert mgr.all_steps() == [3, 4]  # older GC'd


def test_atomic_no_partial_checkpoint(tmp_path):
    """A leftover .tmp dir is never listed as a valid step."""
    mgr = CheckpointManager(str(tmp_path))
    os.makedirs(os.path.join(str(tmp_path), "step_00000009.tmp0"))
    assert mgr.all_steps() == []
    mgr.save(1, _tree(), blocking=True)
    assert mgr.latest_step() == 1


def test_async_save_then_wait(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(5, _tree(), blocking=False)
    mgr.wait()
    assert mgr.latest_step() == 5


def test_restore_onto_different_value_template(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    tree = _tree()
    mgr.save(1, tree, blocking=True)
    template = jax.tree.map(jnp.zeros_like, tree)
    restored, _ = mgr.restore(template)
    assert float(jnp.sum(jnp.abs(restored["params"]["w"]))) > 0


# --------------------------- straggler policy -------------------------------

def test_straggler_detection_and_cooldown():
    pol = StragglerPolicy(8, StragglerConfig(window=8, factor=2.0,
                                             cooldown_steps=3,
                                             min_history=2))
    for step in range(4):
        d = {h: 1.0 for h in range(8)}
        d[3] = 10.0  # host 3 straggles
        pol.record_step(d)
    assert 3 in pol.excluded()
    assert pol.gradient_scale() == 8 / 7
    # recovery: host 3 becomes fast again; after cooldown it rejoins
    for _ in range(6):
        pol.record_step({h: 1.0 for h in range(8)})
    assert 3 not in pol.excluded()
    assert pol.gradient_scale() == 1.0


def test_straggler_budget_cap():
    """Never excludes more than max_excluded_frac of the fleet."""
    pol = StragglerPolicy(8, StragglerConfig(min_history=2, factor=1.5,
                                             max_excluded_frac=0.25))
    for _ in range(4):
        d = {h: 1.0 for h in range(4)}
        d.update({h: 50.0 for h in range(4, 8)})  # half the fleet "slow"
        pol.record_step(d)
    assert len(pol.excluded()) <= 2


def test_missing_report_treated_as_slow():
    pol = StragglerPolicy(4, StragglerConfig(min_history=2, factor=2.0))
    for _ in range(4):
        pol.record_step({0: 1.0, 1: 1.0, 2: 1.0})  # host 3 never reports
    assert 3 in pol.excluded()


def test_crash_mid_save_previous_checkpoint_restores(tmp_path, monkeypatch):
    """Kill between the tmp write and the rename: the tmp dir is left
    behind, the previous checkpoint stays the latest, and restore reads
    it cleanly — a crash mid-save never corrupts the newest step."""
    import repro.ft.checkpoint as ckpt_mod

    mgr = CheckpointManager(str(tmp_path))
    tree = _tree()
    mgr.save(1, tree, extra={"mark": "good"}, blocking=True)

    def crash(src, dst):
        raise OSError("simulated kill before rename")

    monkeypatch.setattr(ckpt_mod.os, "rename", crash)
    newer = jax.tree.map(lambda a: a + 1.0, tree)
    mgr.save(2, newer, blocking=True)     # dies after tmp write
    monkeypatch.undo()

    # the crash artifact exists, but is never visible as a step
    assert os.path.isdir(os.path.join(str(tmp_path), "step_00000002.tmp0"))
    assert mgr.all_steps() == [1]
    restored, extra = mgr.restore(jax.tree.map(jnp.zeros_like, tree))
    jax.tree.map(lambda a, b: np.testing.assert_allclose(
        np.asarray(a), np.asarray(b)), tree, restored)
    assert extra["mark"] == "good"

    # a retried save of the same step succeeds over the stale tmp dir
    mgr.save(2, newer, blocking=True)
    assert mgr.latest_step() == 2
    restored2, _ = mgr.restore(jax.tree.map(jnp.zeros_like, tree))
    np.testing.assert_allclose(np.asarray(restored2["params"]["w"]),
                               np.asarray(newer["params"]["w"]))


# --------------------------- torn-checkpoint recovery ------------------------

def _corrupt_manifest(tmp_path, step):
    path = os.path.join(str(tmp_path), f"step_{step:08d}", "manifest.json")
    with open(path, "w") as f:
        f.write('{"step": 2, "paths": [truncated')


def _truncate_npz(tmp_path, step):
    path = os.path.join(str(tmp_path), f"step_{step:08d}", "proc0.npz")
    with open(path, "r+b") as f:
        f.truncate(20)          # a few bytes of zip header, nothing else


def test_restore_falls_back_to_newest_intact_step(tmp_path):
    """Corrupt the LATEST step's manifest post-rename (bad disk, partial
    fsync on a dying node): ``restore(step=None)`` recovers step N-1
    instead of raising a raw JSONDecodeError."""
    mgr = CheckpointManager(str(tmp_path))
    tree = _tree()
    mgr.save(1, tree, extra={"mark": "good"}, blocking=True)
    mgr.save(2, jax.tree.map(lambda a: a + 1.0, tree), blocking=True)
    _corrupt_manifest(tmp_path, 2)
    restored, extra = mgr.restore(jax.tree.map(jnp.zeros_like, tree))
    jax.tree.map(lambda a, b: np.testing.assert_allclose(
        np.asarray(a), np.asarray(b)), tree, restored)
    assert extra["mark"] == "good"
    assert mgr.latest_step() == 2                 # listing is unchanged
    assert mgr.latest_step(intact=True) == 1      # but only 1 loads


def test_restore_falls_back_on_truncated_npz(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    tree = _tree()
    mgr.save(3, tree, blocking=True)
    mgr.save(4, jax.tree.map(lambda a: a * 2.0, tree), blocking=True)
    _truncate_npz(tmp_path, 4)
    restored, _ = mgr.restore(jax.tree.map(jnp.zeros_like, tree))
    jax.tree.map(lambda a, b: np.testing.assert_allclose(
        np.asarray(a), np.asarray(b)), tree, restored)


def test_restore_explicit_corrupt_step_raises_typed(tmp_path):
    """An explicitly named step is restored exactly or fails TYPED —
    never a silent fallback to a different step."""
    mgr = CheckpointManager(str(tmp_path))
    tree = _tree()
    mgr.save(1, tree, blocking=True)
    mgr.save(2, tree, blocking=True)
    _corrupt_manifest(tmp_path, 2)
    with pytest.raises(CheckpointError, match="step 2.*torn or corrupted"):
        mgr.restore(jax.tree.map(jnp.zeros_like, tree), step=2)
    with pytest.raises(CheckpointError):
        mgr.restore(jax.tree.map(jnp.zeros_like, tree), step=99)


def test_restore_empty_dir_raises_typed(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    with pytest.raises(CheckpointError, match="no checkpoint found"):
        mgr.restore(_tree())


def test_restore_all_steps_torn_raises_typed(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    tree = _tree()
    for s in (1, 2):
        mgr.save(s, tree, blocking=True)
        _corrupt_manifest(tmp_path, s)
    with pytest.raises(CheckpointError, match="no intact checkpoint"):
        mgr.restore(jax.tree.map(jnp.zeros_like, tree))
    assert mgr.latest_step(intact=True) is None


# --------------------------- watchdog re-baseline ----------------------------

def test_watchdog_rebaselines_after_sustained_regime_shift():
    """A DELIBERATE slowdown (longer context arrives, a bigger batch) is
    a new normal, not an endless breach storm: after K consecutive
    median breaches the window re-baselines onto the new durations and
    stops flagging."""
    wd = StepWatchdog(StragglerConfig(window=8, factor=2.0,
                                      min_history=4), rebaseline_after=4)
    for _ in range(8):
        assert not wd.observe(1.0)
    # regime shift: steps now take 5x — first K breach, then re-baseline
    flagged = [wd.observe(5.0) for _ in range(12)]
    assert flagged[:4] == [True] * 4        # the shift is loud at first
    assert wd.regime_shifts == 1
    assert not any(flagged[6:])             # then 5.0 is the new normal
    assert wd.deadline() == pytest.approx(10.0)   # 2x the new median


def test_watchdog_transient_spikes_do_not_rebaseline():
    """Breaches must be CONSECUTIVE to re-baseline — isolated spikes
    keep flagging forever."""
    wd = StepWatchdog(StragglerConfig(window=8, factor=2.0,
                                      min_history=4), rebaseline_after=3)
    for _ in range(8):
        wd.observe(1.0)
    for _ in range(6):                      # spike / normal alternating
        assert wd.observe(9.0)
        assert not wd.observe(1.0)
    assert wd.regime_shifts == 0
    assert wd.breaches == 6


def test_watchdog_hard_limit_never_rebaselines():
    """The hard limit is an absolute SLO: sustained hard breaches keep
    firing and never become the baseline."""
    wd = StepWatchdog(StragglerConfig(window=8, factor=2.0,
                                      min_history=4),
                      hard_limit=30.0, rebaseline_after=3)
    for _ in range(8):
        wd.observe(1.0)
    for _ in range(10):
        assert wd.observe(100.0)            # every one flags
    assert wd.hard_breaches == 10
    assert wd.deadline() == 30.0            # SLO unchanged


def test_watchdog_rebaseline_requires_positive_k():
    with pytest.raises(ValueError):
        StepWatchdog(StragglerConfig(), rebaseline_after=0)
