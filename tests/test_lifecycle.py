"""Request lifecycle: state machine, bounded admission queue with
backpressure, backoff policy, step watchdog, pool invariant auditing,
and the scheduler's preemption-and-restore surface."""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.ft.straggler import StepWatchdog, StragglerConfig
from repro.models.transformer import init_params
from repro.serve.lifecycle import (AdmissionError, AdmissionQueue,
                                   LifecycleError, Request, RequestState,
                                   backoff_delays, retry_with_backoff,
                                   summarize)
from repro.serve.paged_cache import InvariantViolation
from repro.serve.scheduler import Scheduler


@functools.lru_cache(maxsize=None)
def _cfg_params():
    cfg = get_arch("qwen3-0.6b").smoke
    return cfg, init_params(cfg, jax.random.key(0))


def _sched(slots=2, max_len=16, **kw):
    cfg, params = _cfg_params()
    kw.setdefault("page_size", 4)
    return Scheduler(cfg, params, slots=slots, max_len=max_len, **kw)


# --------------------------- state machine ----------------------------------

def test_request_state_machine_legal_path():
    r = Request(prompt=[1, 2, 3], max_new_tokens=4)
    assert r.state is RequestState.QUEUED
    r.to(RequestState.PREFILLING)
    r.to(RequestState.RUNNING)
    r.to(RequestState.PREEMPTED)
    assert r.preemptions == 1
    r.to(RequestState.QUEUED)
    r.to(RequestState.PREFILLING)
    r.to(RequestState.RUNNING)
    r.to(RequestState.FINISHED)
    assert r.terminal


def test_request_state_machine_rejects_illegal_edges():
    r = Request(prompt=[1])
    with pytest.raises(LifecycleError, match="illegal transition"):
        r.to(RequestState.RUNNING)          # must prefill first
    r.to(RequestState.PREFILLING)
    r.to(RequestState.RUNNING)
    r.to(RequestState.FINISHED)
    for s in RequestState:                  # terminal states are absorbing
        with pytest.raises(LifecycleError):
            r.to(s)


def test_prefilling_preempt_edge_legal_but_not_a_shortcut():
    """PR 8 regression: chunked prefill makes PREFILLING -> PREEMPTED a
    legal edge (a mid-prefill slot can be evicted between chunks and
    resumed later), but prefill still cannot short-circuit the machine —
    FINISHED or a direct hop back to QUEUED stays illegal."""
    r = Request(prompt=[1, 2, 3])
    r.to(RequestState.PREFILLING)
    r.to(RequestState.PREEMPTED)            # evicted between chunks
    r.to(RequestState.QUEUED)               # requeued for resume
    r.to(RequestState.PREFILLING)
    for bad in (RequestState.FINISHED, RequestState.QUEUED):
        with pytest.raises(LifecycleError, match="illegal transition"):
            r.to(bad)


def test_request_generated_and_expiry():
    r = Request(prompt=[1, 2], deadline=5.0)
    assert r.generated == 0
    r.tokens += [7, 8, 9]
    assert r.generated == 3
    assert not r.expired(4.9) and r.expired(5.0)


# --------------------------- admission queue --------------------------------

def test_queue_priority_then_fifo_order():
    q = AdmissionQueue(8)
    lo1 = Request(prompt=[1], priority=0)
    hi = Request(prompt=[2], priority=5)
    lo2 = Request(prompt=[3], priority=0)
    for r in (lo1, hi, lo2):
        q.push(r)
    assert q.pop() is hi
    assert q.pop() is lo1                   # FIFO within a priority
    assert q.pop() is lo2
    assert q.pop() is None


def test_queue_backpressure_is_typed_with_retry_after():
    q = AdmissionQueue(2, retry_after_hint=lambda: 0.5)
    q.push(Request(prompt=[1]))
    q.push(Request(prompt=[2]))
    with pytest.raises(AdmissionError) as ei:
        q.push(Request(prompt=[3]))
    assert ei.value.retry_after == pytest.approx(0.5 * 3)
    assert q.rejected == 1
    # forced push (preemption requeue) bypasses the bound
    q.push(Request(prompt=[4]), force=True)
    assert len(q) == 3


def test_queue_preempted_requeue_keeps_arrival_order():
    q = AdmissionQueue(8)
    a = Request(prompt=[1])
    b = Request(prompt=[2])
    q.push(a), q.push(b)
    got = q.pop()
    assert got is a
    got.to(RequestState.PREFILLING)
    got.to(RequestState.RUNNING)
    got.to(RequestState.PREEMPTED)
    q.push(got, force=True)                 # resumes AHEAD of b
    assert q.pop() is a


def test_queue_expire_times_out_stale_requests():
    q = AdmissionQueue(8)
    fresh = Request(prompt=[1], deadline=10.0)
    stale = Request(prompt=[2], deadline=1.0)
    q.push(fresh), q.push(stale)
    dead = q.expire(now=5.0)
    assert dead == [stale] and stale.state is RequestState.TIMED_OUT
    assert len(q) == 1


# --------------------------- backoff policy ---------------------------------

def test_backoff_deterministic_and_bounded():
    d1 = backoff_delays(6, base=0.05, cap=0.4, seed=7)
    d2 = backoff_delays(6, base=0.05, cap=0.4, seed=7)
    assert d1 == d2                         # seeded: replays exactly
    assert d1 != backoff_delays(6, base=0.05, cap=0.4, seed=8)
    assert all(d <= 0.4 for d in d1)        # capped
    assert all(d > 0 for d in d1)


def test_retry_with_backoff_honours_retry_after_and_gives_up():
    slept = []
    calls = []

    def flaky():
        calls.append(1)
        if len(calls) < 3:
            raise AdmissionError("full", retry_after=0.75)
        return "ok"

    out = retry_with_backoff(flaky, retries=5, base=0.01, seed=0,
                             sleep=slept.append)
    assert out == "ok" and len(calls) == 3
    assert all(s >= 0.75 for s in slept)    # server hint is a floor

    def always():
        raise AdmissionError("full")

    with pytest.raises(AdmissionError):
        retry_with_backoff(always, retries=2, base=1e-6,
                           sleep=slept.append)


# --------------------------- step watchdog ----------------------------------

def test_watchdog_flags_deadline_breach_after_history():
    wd = StepWatchdog(StragglerConfig(window=8, factor=2.0, min_history=3))
    assert wd.observe(10.0) is False        # no history yet: no judgement
    for _ in range(3):
        assert wd.observe(1.0) is False
    assert wd.deadline() == pytest.approx(2.0 * wd.median())
    assert wd.observe(50.0) is True
    assert wd.breaches == 1 and wd.last_breach == 50.0
    # the breach is excluded from history — the stall cannot mask itself
    assert wd.median() <= 10.0


def test_watchdog_hard_limit():
    wd = StepWatchdog(hard_limit=0.5)
    assert wd.observe(0.4) is False
    assert wd.observe(0.6) is True
    assert wd.breaches == 1


# --------------------------- invariant auditing -----------------------------

def test_check_invariants_clean_engine():
    sched = _sched(slots=2, debug_invariants=True)
    sched.add_request([3, 5, 7])
    for _ in range(4):
        sched.step()
    sched.cache.check_invariants()          # never trips on a live engine
    sched.finish(0)
    sched.cache.check_invariants()
    assert sched.cache.invariant_checks > 4


def test_check_invariants_catches_page_aliasing():
    sched = _sched(slots=2)
    sched.add_request(3)
    sched.add_request(5)
    sched.step()
    st = dict(sched.cache.state)
    tbl = np.asarray(st["table"]).copy()
    owned = tbl[tbl >= 0]
    tbl[1, 0] = owned[0]                    # alias slot 0's page into slot 1
    st["table"] = jnp.asarray(tbl)
    sched.cache.state = st
    with pytest.raises(InvariantViolation, match="aliased"):
        sched.cache.check_invariants()


def test_check_invariants_catches_free_stack_corruption():
    sched = _sched(slots=2)
    sched.add_request(3)
    sched.step()
    st = dict(sched.cache.state)
    free = np.asarray(st["free"]).copy()
    tbl = np.asarray(st["table"])
    owned = int(tbl[tbl >= 0][0])
    free[int(st["free_top"]) - 1] = owned   # allocated page also "free"
    st["free"] = jnp.asarray(free)
    sched.cache.state = st
    with pytest.raises(InvariantViolation, match="both allocated and free"):
        sched.cache.check_invariants()


def test_check_invariants_catches_pos_table_divergence():
    sched = _sched(slots=2)
    sched.add_request(3)
    sched.step()
    st = dict(sched.cache.state)
    st["pos"] = jnp.zeros_like(st["pos"])   # pages owned beyond pos extent
    sched.cache.state = st
    with pytest.raises(InvariantViolation, match="pos"):
        sched.cache.check_invariants()


# --------------------------- lifecycle over the engine ----------------------

def test_submit_tick_finishes_at_max_new_tokens():
    sched = _sched(slots=2)
    r = sched.submit([3, 5, 7], max_new_tokens=4)
    ticks = 0
    while not r.terminal and ticks < 20:
        sched.tick()
        ticks += 1
    assert r.state is RequestState.FINISHED
    assert r.generated == 4
    assert r.tokens[:3] == [3, 5, 7]
    assert sched.drained()


def test_submit_malformed_prompts_fail_typed():
    sched = _sched()
    empty = sched.submit([], max_new_tokens=2)
    assert empty.state is RequestState.FAILED and "empty" in empty.error
    big = sched.submit([0] * 99, max_new_tokens=2)
    assert big.state is RequestState.FAILED and "exceeds" in big.error
    bad_budget = sched.submit([1], max_new_tokens=0)
    assert bad_budget.state is RequestState.FAILED


def test_deadline_times_out_queued_and_running(monkeypatch):
    now = [0.0]
    sched = _sched(slots=1, clock=lambda: now[0])
    running = sched.submit([3, 5], max_new_tokens=50, deadline=3.0)
    queued = sched.submit([7], max_new_tokens=2, deadline=2.0)
    sched.tick()                            # admits `running`; queued waits
    assert running.state is RequestState.RUNNING
    now[0] = 2.5                            # queued's deadline passes
    sched.tick()
    assert queued.state is RequestState.TIMED_OUT
    now[0] = 3.5                            # running's deadline passes
    sched.tick()
    assert running.state is RequestState.TIMED_OUT
    assert running.generated > 0            # partial work is returned
    assert sched.drained()


def test_admission_error_carries_retry_after():
    sched = _sched(slots=1)
    sched.add_request(3)
    sched.step()                            # establishes a step-time EWMA
    with pytest.raises(AdmissionError, match="no free slot") as ei:
        sched.add_request(5)
    assert ei.value.retry_after >= 0.0
    # pool exhaustion is the same typed error (and a RuntimeError, so
    # pre-lifecycle callers still catch it)
    assert issubclass(AdmissionError, RuntimeError)


def test_preemption_victim_policy_priority_then_pages():
    sched = _sched(slots=3, max_len=16)
    lo_small = sched.submit([1], max_new_tokens=50, priority=0)
    lo_big = sched.submit([2, 3, 4, 5, 6, 7, 8, 9], max_new_tokens=50,
                          priority=0)
    hi = sched.submit([9, 8], max_new_tokens=50, priority=5)
    # chunked prefill (PR 8): lo_big's 7 prefill tokens = 2 page-sized
    # chunks at the default chunk_pages=1 budget -> RUNNING on tick 2
    sched.tick()
    sched.tick()
    assert all(r.state is RequestState.RUNNING
               for r in (lo_small, lo_big, hi))
    # lowest priority first, most pages held breaks the tie
    victim = sched._victim()
    assert sched._slot_req[victim] is lo_big
    # a priority-floor excludes the high-priority slot entirely
    floor_victim = sched._victim(below_priority=1)
    assert sched._slot_req[floor_victim] is not hi


def test_preempt_requeues_with_accumulated_tokens():
    sched = _sched(slots=1)
    r = sched.submit([3, 5, 7], max_new_tokens=30)
    sched.tick()
    sched.tick()
    had = list(r.tokens) if r.tokens else None
    got = sched.preempt(0)
    assert got is r
    assert r.state is RequestState.QUEUED and r.preemptions == 1
    assert len(r.tokens) > len(r.prompt)    # generated work preserved
    assert not sched.active[0]
    assert sched.cache.pages_in_use() == 0  # pages reclaimed
    sched.tick()                            # resumes
    assert r.state is RequestState.RUNNING
    del had


def test_double_finish_returns_empty_and_clears_tokens():
    """Satellite regression: finish on an already-idle slot must NOT
    return the previous occupant's stale tokens."""
    sched = _sched(slots=1)
    sched.add_request(42)
    sched.step()
    first = sched.finish(0)
    assert len(first) == 2
    assert sched.finish(0) == []            # explicit double-finish
    assert sched.tokens[0] == []            # token list cleared on release


def test_sampling_knob_validation_at_construction():
    """Satellite: top_k <= 0 / negative temperature must fail loudly at
    construction, not silently corrupt sample_tokens."""
    with pytest.raises(ValueError, match="top_k"):
        _sched(top_k=0)
    with pytest.raises(ValueError, match="top_k"):
        _sched(top_k=-3, temperature=0.5)
    with pytest.raises(ValueError, match="temperature"):
        _sched(temperature=-0.1)
    _sched(top_k=1, temperature=0.0)        # valid edge cases still fine
    _sched(top_k=None, temperature=1.5)


def test_summarize_histogram():
    rs = [Request(prompt=[1]), Request(prompt=[2])]
    rs[0].to(RequestState.PREFILLING)
    rs[0].to(RequestState.RUNNING)
    rs[0].to(RequestState.FINISHED)
    h = summarize(rs)
    assert h["finished"] == 1 and h["queued"] == 1
    assert h["preemptions"] == 0
