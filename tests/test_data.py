"""Data pipeline: AoS pack/unpack roundtrip, determinism, host sharding,
checkpoint/rescale exactness (Hypothesis where it pays)."""
import jax
import jax.numpy as jnp
import numpy as np
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # container without hypothesis: deterministic fallback
    import os, sys
    sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
    from _hypcompat import given, settings, strategies as st

from repro.data.aos import FIELDS, pack_records, unpack_records
from repro.data.pipeline import DataConfig, SyntheticAoSPipeline

settings.register_profile("fast3", max_examples=25, deadline=None)
settings.load_profile("fast3")


def test_aos_roundtrip():
    B, S = 4, 32
    key = jax.random.key(0)
    toks = jax.random.randint(key, (B, S), 0, 1000, jnp.int32)
    labels = jnp.roll(toks, -1, axis=1)
    w = jnp.ones((B, S), jnp.float32).at[:, -1].set(0.0)
    docs = jnp.full((B, S), 7, jnp.int32)
    for impl in ("ref", "pallas"):
        aos = pack_records(toks, labels, w, docs, policy=impl)
        assert aos.shape == (B, FIELDS * S)
        out = unpack_records(aos, policy=impl)
        np.testing.assert_array_equal(np.asarray(out["tokens"]),
                                      np.asarray(toks))
        np.testing.assert_array_equal(np.asarray(out["labels"]),
                                      np.asarray(labels))
        np.testing.assert_allclose(np.asarray(out["loss_weight"]),
                                   np.asarray(w))
        np.testing.assert_array_equal(np.asarray(out["doc_id"]),
                                      np.asarray(docs))


def test_aos_layout_is_interleaved():
    """The buffer really is AoS: fields of token j adjacent at 4j..4j+3."""
    toks = jnp.array([[10, 20]]); labels = jnp.array([[11, 21]])
    w = jnp.array([[1.0, 1.0]]); docs = jnp.array([[5, 5]])
    aos = np.asarray(pack_records(toks, labels, w, docs))
    assert list(aos[0, :4]) == [10, 11, 1024, 5]
    assert list(aos[0, 4:]) == [20, 21, 1024, 5]


def test_determinism_across_instances():
    cfg = DataConfig(vocab=100, seq_len=16, global_batch=8, seed=3)
    a = SyntheticAoSPipeline(cfg)
    b = SyntheticAoSPipeline(cfg)
    for _ in range(3):
        np.testing.assert_array_equal(a.next_host_aos(), b.next_host_aos())


@given(st.integers(1, 4).map(lambda k: 2 ** k))
def test_host_sharding_partitions_global_batch(nproc):
    cfg = DataConfig(vocab=100, seq_len=8, global_batch=16, seed=1)
    full = SyntheticAoSPipeline(cfg)._global_batch_np(0)
    shards = []
    for p in range(nproc):
        pipe = SyntheticAoSPipeline(cfg, process_index=p,
                                    process_count=nproc)
        shards.append(pipe.next_host_aos())
    np.testing.assert_array_equal(np.concatenate(shards, axis=0), full)


def test_checkpoint_restore_resumes_exactly():
    cfg = DataConfig(vocab=100, seq_len=8, global_batch=4, seed=2)
    a = SyntheticAoSPipeline(cfg)
    a.next_host_aos(); a.next_host_aos()
    saved = a.state_dict()
    want = a.next_host_aos()
    b = SyntheticAoSPipeline(cfg)
    b.load_state_dict(saved)
    np.testing.assert_array_equal(b.next_host_aos(), want)


def test_elastic_rescale_preserves_global_stream():
    """Restarting with a different host count continues the same global
    batch sequence."""
    cfg = DataConfig(vocab=50, seq_len=8, global_batch=8, seed=4)
    one = SyntheticAoSPipeline(cfg)
    one.next_host_aos()
    saved = one.state_dict()
    want = one.next_host_aos()  # global batch @ step 1
    parts = []
    for p in range(4):
        pipe = SyntheticAoSPipeline(cfg, process_index=p, process_count=4)
        pipe.load_state_dict(saved)
        parts.append(pipe.next_host_aos())
    np.testing.assert_array_equal(np.concatenate(parts, axis=0), want)


def test_batch_feeds_model_loss():
    from repro.configs import get_arch
    from repro.models.transformer import init_params, loss_fn
    arch = get_arch("qwen3-0.6b")
    cfg = arch.smoke
    pipe = SyntheticAoSPipeline(DataConfig(vocab=cfg.vocab, seq_len=32,
                                           global_batch=2))
    params = init_params(cfg, jax.random.key(0))
    loss, _ = jax.jit(lambda p, b: loss_fn(p, b, cfg, None))(
        params, pipe.next_batch())
    assert bool(jnp.isfinite(loss))
