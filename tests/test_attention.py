"""Flash attention (chunked, custom VJP) vs naive reference — outputs AND
gradients, across causal/window/GQA/ragged variants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.attention import decode_attention, flash_attention


def naive_attention(q, k, v, *, causal, window, q_offset=0):
    B, Sq, H, D = q.shape
    Sk, K = k.shape[1], k.shape[2]
    G = H // K
    qt = q.reshape(B, Sq, K, G, D)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qt, k).astype(jnp.float32) * D**-0.5
    qpos = (q_offset + jnp.arange(Sq))[:, None]
    kpos = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= qpos >= kpos
    if window is not None:
        mask &= (qpos - kpos) < window
    s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bskd->bkgqd", p, v.astype(jnp.float32))
    return jnp.moveaxis(o, 3, 1).reshape(B, Sq, H, D).astype(q.dtype)


CASES = [
    # (Sq, Sk, H, K, D, causal, window, q_chunk, kv_chunk)
    (64, 64, 4, 2, 16, True, None, 16, 16),
    (64, 64, 4, 1, 16, True, None, 32, 16),     # MQA
    (64, 64, 4, 4, 16, False, None, 16, 32),    # bidirectional (encoder)
    (128, 128, 2, 2, 8, True, 32, 32, 16),      # sliding window (banded)
    (48, 48, 4, 2, 16, True, None, 16, 16),     # ragged-ish
    (50, 70, 4, 2, 16, False, None, 16, 16),    # ragged + cross shapes
]


@pytest.mark.parametrize("case", CASES)
def test_flash_matches_naive_fwd_and_grad(case):
    Sq, Sk, H, K, D, causal, window, qc, kc = case
    keys = jax.random.split(jax.random.key(0), 3)
    q = jax.random.normal(keys[0], (2, Sq, H, D))
    k = jax.random.normal(keys[1], (2, Sk, K, D))
    v = jax.random.normal(keys[2], (2, Sk, K, D))

    def f_flash(q, k, v):
        o = flash_attention(q, k, v, causal=causal, window=window,
                            q_chunk=qc, kv_chunk=kc)
        return jnp.sum(jnp.sin(o))

    def f_naive(q, k, v):
        o = naive_attention(q, k, v, causal=causal, window=window)
        return jnp.sum(jnp.sin(o))

    o1 = flash_attention(q, k, v, causal=causal, window=window,
                         q_chunk=qc, kv_chunk=kc)
    o2 = naive_attention(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                               rtol=2e-4, atol=2e-4)

    g1 = jax.grad(f_flash, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(f_naive, argnums=(0, 1, 2))(q, k, v)
    for a, b, name in zip(g1, g2, "qkv"):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=5e-4, atol=5e-4,
                                   err_msg=f"d{name} mismatch: {case}")


def test_flash_under_remat_and_jit():
    q = jax.random.normal(jax.random.key(1), (2, 64, 4, 16))
    k = jax.random.normal(jax.random.key(2), (2, 64, 2, 16))
    v = jax.random.normal(jax.random.key(3), (2, 64, 2, 16))

    @jax.jit
    def f(q, k, v):
        g = jax.checkpoint(lambda *a: jnp.sum(
            flash_attention(*a, causal=True, q_chunk=16, kv_chunk=16) ** 2))
        return jax.grad(g, argnums=0)(q, k, v)

    out = f(q, k, v)
    assert bool(jnp.all(jnp.isfinite(out)))


def test_decode_matches_flash_last_row():
    """decode_attention(q_t, cache) == flash row for the last position."""
    B, S, H, K, D = 2, 32, 4, 2, 16
    keys = jax.random.split(jax.random.key(4), 3)
    q = jax.random.normal(keys[0], (B, S, H, D))
    k = jax.random.normal(keys[1], (B, S, K, D))
    v = jax.random.normal(keys[2], (B, S, K, D))
    full = flash_attention(q, k, v, causal=True, q_chunk=8, kv_chunk=8)
    dec = decode_attention(q[:, -1], k, v, jnp.asarray(S))
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full[:, -1]),
                               rtol=2e-4, atol=2e-4)


def test_decode_window_masking():
    B, S, H, K, D = 1, 16, 2, 2, 8
    keys = jax.random.split(jax.random.key(5), 3)
    q = jax.random.normal(keys[0], (B, H, D))
    k = jax.random.normal(keys[1], (B, S, K, D))
    v = jax.random.normal(keys[2], (B, S, K, D))
    # window=4 at cache_len=10 must equal full attention over keys 6..9
    dec_w = decode_attention(q, k, v, jnp.asarray(10), window=4)
    dec_f = decode_attention(q, k[:, 6:10], v[:, 6:10], jnp.asarray(4))
    np.testing.assert_allclose(np.asarray(dec_w), np.asarray(dec_f),
                               rtol=1e-5, atol=1e-5)
