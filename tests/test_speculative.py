"""Speculative K-token decode (PR 10): one fused page-gather/verify program.

Decode level — ``paged_verify_step`` commits exactly the non-speculative
oracle's stream: K=1 degenerates to the single-token step bit-exact
(logits included), accept-all catches up K tokens per launch, reject-all
commits one per launch, and a rejection exactly on a page boundary hands
the speculatively-allocated page straight back to the free stack (page
table + per-slot pos are the ONLY rollback state).  Windowed and
recurrent mixes track the oracle under the allclose contract; the int8
pool stays invariant-green with bounded-error divergence allowed.

Lowering level — the verify program keeps the fused-step shape: fusing
removes the same three gather equations as the single-token step on the
2-superblock x 2-position ref cfg, lowers to ONE pinned pallas launch +
ONE mask program, and the plan cache takes ZERO steady-state misses
across mixed per-slot ``n_draft`` (the verify width is static; per-slot
effective widths are traced operands).

Serve level — the speculative scheduler's token streams are bit-exact vs
the plain scheduler: uniform K, mixed per-request K, ``max_new_tokens``
clamping (a K-wide commit must not overshoot the budget by K-1),
preempt-resume replay THROUGH the verify batch (recorded tokens are
perfect drafts), and prefix sharing with the refcount audit on.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro import vx
from repro.core import accessfuse
from repro.models import decode as dec
from repro.models.transformer import ModelConfig, init_params
from repro.serve.scheduler import Scheduler


def _cfg(layers=2, hd=16, scan=True, impl="ref", pattern=("attn",),
         window=None, mlp="swiglu", d_ff=64, name="spec-test"):
    n = len(pattern)
    kw = {}
    if "mamba" in pattern:
        from repro.models.ssm import MambaSpec
        kw["mamba"] = MambaSpec(d_model=2 * hd)
    return ModelConfig(
        name=name, d_model=2 * hd, n_layers=layers, n_heads=2,
        n_kv_heads=2, d_ff=d_ff, vocab=97, head_dim=hd, mlp=mlp,
        block_pattern=pattern, window_pattern=(window,) * n,
        moe_pattern=(False,) * n,
        scan_layers=scan, kernel_impl=impl, remat="none", **kw)


def _jits(cfg):
    jd = jax.jit(lambda p, c, t, a: dec.paged_decode_step(
        p, c, t, cfg, None, active=a))
    jv = jax.jit(lambda p, c, t, n, a: dec.paged_verify_step(
        p, c, t, cfg, None, n_draft=n, active=a))
    return jd, jv


def _oracle(cfg, params, jd, slots, ps, max_len, steps):
    """Greedy single-token streams + per-step logits (the ground truth)."""
    oc = dec.init_paged_cache(cfg, slots, max_len, ps, jnp.float32)
    act = jnp.ones((slots,), bool)
    cur = (jnp.arange(slots, dtype=jnp.int32) * 7 + 3) % cfg.vocab
    stream = [[int(cur[s])] for s in range(slots)]
    logits = [[] for _ in range(slots)]
    for _ in range(steps):
        lg, oc = jd(params, oc, cur, act)
        cur = jnp.argmax(lg, axis=-1).astype(jnp.int32)
        for s in range(slots):
            stream[s].append(int(cur[s]))
            logits[s].append(np.asarray(lg[s]))
    return stream, logits


def _spec_replay(cfg, params, jv, oracle, K, steps, slots, ps, max_len,
                 corrupt_at=frozenset(), check_logits=True):
    """Drive paged_verify_step with oracle-perfect drafts (optionally
    corrupted at (round, slot, j) to force rejections) and return the
    committed streams plus the per-round commit counts."""
    sc = dec.init_paged_cache(cfg, slots, max_len, ps, jnp.float32)
    act = jnp.ones((slots,), bool)
    stream = [[oracle[0][s][0]] for s in range(slots)]
    nd = jnp.full((slots,), K, jnp.int32)
    commits = []
    rnd = 0
    while min(len(t) for t in stream) < steps and rnd < 80:
        toks = np.zeros((slots, K), np.int32)
        for s in range(slots):
            fed = len(stream[s])
            toks[s, 0] = stream[s][-1]
            for j in range(1, K):
                t = oracle[0][s][fed - 1 + j]
                if (rnd, s, j) in corrupt_at:
                    t = (t + 1) % cfg.vocab
                toks[s, j] = t
        lg, o, commit, sc = jv(params, sc, jnp.asarray(toks), nd, act)
        o, cm = np.asarray(o), np.asarray(commit)
        commits.append([int(c) for c in cm])
        for s in range(slots):
            fed = len(stream[s])
            for j in range(int(cm[s])):
                stream[s].append(int(o[s, j]))
                if check_logits:
                    # committed logits track the oracle's to float32
                    # reduction-order tolerance (the K-wide batch shape
                    # changes XLA's contraction order); the TOKEN stream
                    # is the bit-exact contract
                    np.testing.assert_allclose(
                        np.asarray(lg[s, j]), oracle[1][s][fed - 1 + j],
                        rtol=5e-4, atol=1e-5)
        assert not dec.paged_invariants(cfg, sc), \
            dec.paged_invariants(cfg, sc)
        rnd += 1
    return stream, commits, sc


def _streams_equal(spec, oracle, steps):
    for s, (a, b) in enumerate(zip(spec, oracle)):
        n = min(steps, len(a))
        assert a[:n] == b[:n], f"slot {s}: {a[:n]} != {b[:n]}"


# ---------------------------------------------------------------------------
# decode level


def test_k1_degenerates_to_single_step_bit_exact():
    cfg = _cfg()
    params = init_params(cfg, jax.random.key(0))
    jd, jv = _jits(cfg)
    slots, ps, max_len = 2, 4, 32
    dc = dec.init_paged_cache(cfg, slots, max_len, ps, jnp.float32)
    vc = dec.init_paged_cache(cfg, slots, max_len, ps, jnp.float32)
    act = jnp.ones((slots,), bool)
    nd = jnp.ones((slots,), jnp.int32)
    cur = (jnp.arange(slots, dtype=jnp.int32) * 7 + 3) % cfg.vocab
    for _ in range(8):
        lg_d, dc = jd(params, dc, cur, act)
        lg_v, o, cm, vc = jv(params, vc, cur[:, None], nd, act)
        # logits to reduction-order tolerance (the beat axis changes
        # XLA's contraction order even at K=1); argmax tokens and cache
        # positions are the bit-exact contract
        np.testing.assert_allclose(np.asarray(lg_v[:, 0]),
                                   np.asarray(lg_d), rtol=5e-4, atol=1e-5)
        assert np.asarray(cm).tolist() == [1, 1]
        nxt = jnp.argmax(lg_d, axis=-1).astype(jnp.int32)
        np.testing.assert_array_equal(np.asarray(o[:, 0]), np.asarray(nxt))
        np.testing.assert_array_equal(np.asarray(vc["pos"]),
                                      np.asarray(dc["pos"]))
        cur = nxt


def test_accept_all_catches_oracle_k_per_launch():
    cfg = _cfg()
    params = init_params(cfg, jax.random.key(0))
    jd, jv = _jits(cfg)
    K, steps, slots, ps, max_len = 4, 12, 2, 4, 64
    oracle = _oracle(cfg, params, jd, slots, ps, max_len, steps + K + 2)
    stream, commits, _ = _spec_replay(cfg, params, jv, oracle, K, steps,
                                      slots, ps, max_len)
    _streams_equal(stream, oracle[0], steps)
    # perfect drafts: every verify commits the full width
    assert all(c == K for row in commits[:-1] for c in row), commits


def test_reject_all_commits_one_per_launch():
    cfg = _cfg()
    params = init_params(cfg, jax.random.key(0))
    jd, jv = _jits(cfg)
    K, steps, slots, ps, max_len = 4, 10, 2, 4, 64
    oracle = _oracle(cfg, params, jd, slots, ps, max_len, steps + K + 2)
    corrupt = {(r, s, 1) for r in range(80) for s in range(slots)}
    stream, commits, _ = _spec_replay(cfg, params, jv, oracle, K, steps,
                                      slots, ps, max_len,
                                      corrupt_at=corrupt)
    _streams_equal(stream, oracle[0], steps)
    # first draft always wrong: the head token is the only commit
    assert all(c == 1 for row in commits for c in row), commits


def test_mixed_rejections_track_oracle():
    cfg = _cfg()
    params = init_params(cfg, jax.random.key(0))
    jd, jv = _jits(cfg)
    K, steps, slots, ps, max_len = 4, 12, 2, 4, 64
    oracle = _oracle(cfg, params, jd, slots, ps, max_len, steps + K + 2)
    stream, _, _ = _spec_replay(
        cfg, params, jv, oracle, K, steps, slots, ps, max_len,
        corrupt_at={(0, 0, 1), (1, 1, 2), (2, 0, 3), (4, 1, 1)})
    _streams_equal(stream, oracle[0], steps)


def test_rejection_on_page_boundary_returns_page_to_free_stack():
    """Slot sits one token before a page boundary; the K-wide verify
    speculatively appends across it (allocating a fresh page inside the
    jit) but every draft is rejected — commit lands EXACTLY on the
    boundary.  The overflow page must come straight back: the free stack
    is unchanged and the invariant audit stays green."""
    cfg = _cfg()
    params = init_params(cfg, jax.random.key(0))
    jd, jv = _jits(cfg)
    K, slots, ps, max_len = 4, 1, 4, 32
    oracle = _oracle(cfg, params, jd, slots, ps, max_len, 2 * ps + K + 2)
    sc = dec.init_paged_cache(cfg, slots, max_len, ps, jnp.float32)
    act = jnp.ones((slots,), bool)
    # single-token steps up to pos == ps - 1 (one before the boundary)
    cur = jnp.asarray([oracle[0][0][0]], jnp.int32)
    for i in range(ps - 1):
        lg, sc = jd(params, sc, cur, act)
        cur = jnp.argmax(lg, axis=-1).astype(jnp.int32)
    assert int(np.asarray(sc["pos"])[0]) == ps - 1
    free_before = int(sc["free_top"])
    # head + K-1 corrupted drafts: commit == 1 -> pos == ps exactly;
    # the verify wrote positions ps..ps+K-2 into a freshly-allocated
    # page that the rollback must return
    toks = np.zeros((slots, K), np.int32)
    toks[0, 0] = int(cur[0])
    fed = ps
    for j in range(1, K):
        toks[0, j] = (oracle[0][0][fed - 1 + j] + 1) % cfg.vocab
    lg, o, cm, sc = jv(params, sc, jnp.asarray(toks),
                       jnp.full((slots,), K, jnp.int32), act)
    assert int(np.asarray(cm)[0]) == 1
    assert int(np.asarray(sc["pos"])[0]) == ps
    assert int(sc["free_top"]) == free_before, \
        "rolled-back page did not return to the free stack"
    assert not dec.paged_invariants(cfg, sc), dec.paged_invariants(cfg, sc)
    # committed token still the oracle's
    assert int(np.asarray(o)[0, 0]) == oracle[0][0][ps]


def _allclose_replay(cfg, K=3, steps=10, slots=2, ps=4, max_len=64,
                     quantize=None):
    """Stream-tracking harness for allclose-contract stacks: returns the
    number of slots whose committed stream diverged from the oracle."""
    params = init_params(cfg, jax.random.key(0))
    jd, jv = _jits(cfg)
    oc = dec.init_paged_cache(cfg, slots, max_len, ps, jnp.float32,
                              quantize=quantize)
    act = jnp.ones((slots,), bool)
    cur = (jnp.arange(slots, dtype=jnp.int32) * 7 + 3) % cfg.vocab
    ostream = [[int(cur[s])] for s in range(slots)]
    for _ in range(steps + K + 2):
        lg, oc = jd(params, oc, cur, act)
        cur = jnp.argmax(lg, axis=-1).astype(jnp.int32)
        for s in range(slots):
            ostream[s].append(int(cur[s]))
    sc = dec.init_paged_cache(cfg, slots, max_len, ps, jnp.float32,
                              quantize=quantize)
    sstream = [[ostream[s][0]] for s in range(slots)]
    nd = jnp.asarray([K, max(1, K - 1)], jnp.int32)[:slots]
    rnd = 0
    while min(len(t) for t in sstream) < steps and rnd < 60:
        toks = np.zeros((slots, K), np.int32)
        for s in range(slots):
            fed = len(sstream[s])
            toks[s, 0] = sstream[s][-1]
            for j in range(1, K):
                toks[s, j] = ostream[s][fed - 1 + j] \
                    if fed - 1 + j < len(ostream[s]) else 0
        lg, o, commit, sc = jv(params, sc, jnp.asarray(toks), nd, act)
        o, cm = np.asarray(o), np.asarray(commit)
        for s in range(slots):
            for j in range(int(cm[s])):
                sstream[s].append(int(o[s, j]))
        assert not dec.paged_invariants(cfg, sc), \
            dec.paged_invariants(cfg, sc)
        rnd += 1
    mism = 0
    for s in range(slots):
        n = min(steps, len(sstream[s]))
        if sstream[s][:n] != ostream[s][:n]:
            mism += 1
    return mism


def test_windowed_stream_tracks_oracle():
    assert _allclose_replay(_cfg(window=8), K=3, steps=12, max_len=32) == 0


def test_recurrent_mix_stream_tracks_oracle():
    assert _allclose_replay(_cfg(pattern=("attn", "mamba")),
                            K=3, steps=10) == 0


def test_int8_pool_invariant_green_under_speculation():
    # bounded-error contract: the int8 stream MAY diverge from the f32
    # oracle; the gate is that rollback keeps the quantized pool's
    # invariants (scale liveness included) green every round
    _allclose_replay(_cfg(), K=3, steps=10, quantize="int8")


# ---------------------------------------------------------------------------
# lowering level


def _count_gathers(fn, *args) -> int:
    def rec(jaxpr):
        c = 0
        for eqn in jaxpr.eqns:
            if eqn.primitive.name == "gather":
                c += 1
            for v in eqn.params.values():
                for sub in accessfuse._child_jaxprs(v):
                    c += rec(sub)
        return c
    return rec(jax.make_jaxpr(lambda *a: fn(*a))(*args).jaxpr)


def _gate_cfg(impl):
    return _cfg(layers=4, hd=64, scan=False, impl=impl,
                pattern=("attn", "attn"), mlp="none", d_ff=0,
                name=f"spec-gate-{impl}")


def test_verify_fuses_page_gathers_ref():
    """Fusing the verify program removes the same three page-table
    gathers as the single-token fused step on the 2sb x 2pos cfg —
    K stacks along the beat axis of ONE vx.Paged spec, it does not
    multiply gather programs."""
    cfg = _gate_cfg("ref")
    params = init_params(cfg, jax.random.key(0))
    cache = dec.init_paged_cache(cfg, 2, 64, 16, jnp.float32)
    toks = jnp.zeros((2, 4), jnp.int32)
    nd = jnp.full((2,), 4, jnp.int32)
    gf = _count_gathers(lambda p, c, t, n: dec.paged_verify_step(
        p, c, t, cfg, None, n_draft=n, fuse=True), params, cache, toks, nd)
    gp = _count_gathers(lambda p, c, t, n: dec.paged_verify_step(
        p, c, t, cfg, None, n_draft=n, fuse=False), params, cache, toks, nd)
    assert gp - gf == 3, (gf, gp)


def test_verify_single_pinned_launch_pallas():
    cfg = _gate_cfg("pallas")
    params = init_params(cfg, jax.random.key(0))
    cache = dec.init_paged_cache(cfg, 2, 64, 16, jnp.float32)
    toks = jnp.zeros((2, 4), jnp.int32)
    nd = jnp.full((2,), 4, jnp.int32)
    with accessfuse.pinned_kernel_lowering():
        launches, masks = accessfuse.jaxpr_access_counts(
            lambda p, c, t, n: dec.paged_verify_step(
                p, c, t, cfg, None, n_draft=n, fuse=True),
            params, cache, toks, nd)
    assert (launches, masks) == (1, 1), (launches, masks)


def test_plans_steady_across_mixed_n_draft():
    cfg = _cfg()
    params = init_params(cfg, jax.random.key(0))
    cache = dec.init_paged_cache(cfg, 2, 16, 4, jnp.float32)
    jv = jax.jit(lambda p, c, t, n: dec.paged_verify_step(
        p, c, t, cfg, None, n_draft=n))
    toks = jnp.zeros((2, 4), jnp.int32)
    _, _, _, cache = jv(params, cache, toks, jnp.asarray([4, 4], jnp.int32))
    warm = vx.PLANS.stats()["misses"]
    for nd_ in ([1, 4], [2, 3], [4, 1], [3, 3]):
        _, _, _, cache = jv(params, cache, toks,
                            jnp.asarray(nd_, jnp.int32))
    assert vx.PLANS.stats()["misses"] == warm, \
        "plan cache missed across mixed per-slot verify widths"


# ---------------------------------------------------------------------------
# serve level


_PROMPTS = [[3, 5, 7, 11, 13], [2, 4], [17, 19, 23, 29, 31, 37, 41, 2, 3]]


def _sched_pair():
    cfg = _cfg(layers=2, hd=16)
    dcfg = _cfg(layers=1, hd=8, name="spec-draft")
    params = init_params(cfg, jax.random.key(0))
    dparams = init_params(dcfg, jax.random.key(1))
    return cfg, params, dcfg, dparams


def _drain(sched, reqs, ticks=120):
    for _ in range(ticks):
        sched.tick()
        if sched.drained():
            return
    raise AssertionError("scheduler did not drain")


def _plain_streams(cfg, params, max_new=12, **kw):
    so = Scheduler(cfg, params, slots=3, max_len=64, page_size=4,
                   debug_invariants=True, **kw)
    ro = [so.submit(p, max_new_tokens=max_new) for p in _PROMPTS]
    _drain(so, ro)
    assert all(r.state.value == "finished" for r in ro)
    return [list(r.tokens) for r in ro]


def test_scheduler_stream_equality_uniform_k():
    cfg, params, dcfg, dparams = _sched_pair()
    oracle = _plain_streams(cfg, params)
    ss = Scheduler(cfg, params, slots=3, max_len=64, page_size=4,
                   speculate=4, draft_cfg=dcfg, draft_params=dparams,
                   debug_invariants=True)
    rs = [ss.submit(p, max_new_tokens=12) for p in _PROMPTS]
    _drain(ss, rs)
    assert [list(r.tokens) for r in rs] == oracle
    st = ss.stats()
    assert st["speculative"]["proposed"] > 0
    assert st["speculative"]["accepted"] > 0
    assert {"ttft_p50_s", "ttft_p99_s", "itl_p50_s",
            "itl_p99_s"} <= set(st["latency"])


def test_scheduler_mixed_per_request_k():
    cfg, params, dcfg, dparams = _sched_pair()
    oracle = _plain_streams(cfg, params)
    ss = Scheduler(cfg, params, slots=3, max_len=64, page_size=4,
                   speculate=4, draft_cfg=dcfg, draft_params=dparams,
                   debug_invariants=True)
    rs = [ss.submit(_PROMPTS[0], max_new_tokens=12, speculate=1),
          ss.submit(_PROMPTS[1], max_new_tokens=12, speculate=2),
          ss.submit(_PROMPTS[2], max_new_tokens=12)]
    _drain(ss, rs)
    assert [list(r.tokens) for r in rs] == oracle


def test_scheduler_no_overshoot_of_max_new_tokens():
    """A K-wide commit must stop exactly at the budget — budgets not
    divisible by K previously overshot by up to K-1 tokens."""
    cfg, params, dcfg, dparams = _sched_pair()
    for budget in (5, 7, 10):
        oracle = _plain_streams(cfg, params, max_new=budget)
        ss = Scheduler(cfg, params, slots=3, max_len=64, page_size=4,
                       speculate=4, draft_cfg=dcfg, draft_params=dparams,
                       debug_invariants=True)
        rs = [ss.submit(p, max_new_tokens=budget) for p in _PROMPTS]
        _drain(ss, rs)
        assert [r.generated for r in rs] == [budget] * len(rs)
        assert [list(r.tokens) for r in rs] == oracle


def test_scheduler_preempt_resume_replays_through_verify():
    cfg, params, dcfg, dparams = _sched_pair()
    oracle = _plain_streams(cfg, params)
    ss = Scheduler(cfg, params, slots=1, max_len=64, page_size=4,
                   speculate=4, draft_cfg=dcfg, draft_params=dparams,
                   debug_invariants=True)
    r = ss.submit(_PROMPTS[0], max_new_tokens=12)
    for _ in range(3):
        ss.tick()
    ss.preempt(0)
    _drain(ss, [r])
    assert list(r.tokens) == oracle[0]
    assert r.preemptions == 1


def test_scheduler_prefix_sharing_under_speculation():
    """Shared multi-page prefix + speculation: borrowers adopt the
    donor's pages, verify/rollback runs over shared tables with the
    refcount audit on every tick, and the streams match the plain
    prefix-sharing scheduler exactly."""
    cfg, params, dcfg, dparams = _sched_pair()
    shared = [5, 9, 2, 7, 1, 8, 3, 6]            # two full pages at ps=4
    prompts = [shared + [11], shared + [13], shared + [17]]

    def drive(**kw):
        s = Scheduler(cfg, params, slots=3, max_len=64, page_size=4,
                      prefix_cache=True, debug_invariants=True, **kw)
        reqs = [s.submit(prompts[0], max_new_tokens=10)]
        for _ in range(4):                        # let the donor publish
            s.tick()
        reqs += [s.submit(p, max_new_tokens=10) for p in prompts[1:]]
        _drain(s, reqs)
        assert all(r.state.value == "finished" for r in reqs)
        return [list(r.tokens) for r in reqs], s.stats()

    plain, _ = drive()
    spec, st = drive(speculate=4, draft_cfg=dcfg, draft_params=dparams)
    assert spec == plain
    assert st["prefix"]["tokens_reused"] > 0
    assert st["speculative"]["accepted"] > 0
