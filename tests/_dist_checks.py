"""Distributed-semantics checks, run in a subprocess with 8 fake devices.

Invoked by tests/test_dist_8dev.py as:
    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        python -m tests._dist_checks <check_name>
Each check prints CHECK_OK on success.
"""
from __future__ import annotations

import os
import sys

if "--xla_force_host_platform_device_count" not in os.environ.get(
        "XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402


def check_moe_ep_equivalence():
    """Expert-parallel MoE on a (2,4) mesh == single-device MoE."""
    from repro.dist.sharding import ShardCtx
    from repro.launch.mesh import make_test_mesh
    from repro.models.moe import MoESpec, init_moe, moe_layer

    d = 64
    spec = MoESpec(n_experts=8, top_k=2, d_ff=96, capacity_slack=8.0)
    params = init_moe(jax.random.key(0), d, spec, jnp.float32)
    x = jax.random.normal(jax.random.key(1), (4, 16, d))
    y_local, aux_local = jax.jit(
        lambda p, x: moe_layer(p, x, spec, None))(params, x)
    mesh = make_test_mesh((2, 4), ("data", "model"))
    ctx = ShardCtx(mesh=mesh, data_axes=("data",), model_axis="model")
    y_ep, aux_ep = jax.jit(
        lambda p, x: moe_layer(p, x, spec, ctx))(params, x)
    np.testing.assert_allclose(np.asarray(y_local), np.asarray(y_ep),
                               rtol=2e-4, atol=2e-4)
    # aux is a per-data-shard load-balance estimator averaged with pmean;
    # it is nonlinear in the token partition, so only approximately equal
    np.testing.assert_allclose(float(aux_local), float(aux_ep), rtol=0.1)
    print("CHECK_OK")


def check_sharded_train_step():
    """Sharded train step on (2,4): finite loss, state keeps shardings."""
    from repro.configs import get_arch
    from repro.configs.base import train_batch
    from repro.launch.mesh import make_ctx, make_test_mesh
    from repro.train.step import TrainConfig, init_full_state, jit_train_step

    arch = get_arch("qwen3-0.6b")
    import dataclasses
    cfg = dataclasses.replace(arch.smoke, compute_dtype="bfloat16")
    mesh = make_test_mesh((2, 4), ("data", "model"))
    ctx = make_ctx(mesh)
    tcfg = TrainConfig()
    state = init_full_state(cfg, tcfg, jax.random.key(0))
    batch = train_batch(cfg, 64, 4, specs=False)
    step = jit_train_step(cfg, tcfg, ctx, state, batch)
    state, m1 = step(state, batch)
    state, m2 = step(state, batch)
    assert np.isfinite(float(m1["loss"])) and np.isfinite(float(m2["loss"]))
    assert float(m2["loss"]) < float(m1["loss"]) + 0.5
    # a model-sharded leaf should really be distributed
    wq = state["params"]["blocks"]["pos0"]["attn"]["wq"]
    assert len(wq.sharding.device_set) == 8 or not wq.sharding.is_fully_replicated
    print("CHECK_OK")


def check_pipeline_equivalence():
    """GPipe over pod axis == plain forward (loss equality)."""
    import dataclasses
    from repro.configs import get_arch
    from repro.configs.base import train_batch
    from repro.dist.pipeline_par import PipelineConfig, pipeline_loss_fn
    from repro.launch.mesh import make_ctx
    from repro.models.transformer import loss_fn

    cfg = get_arch("qwen3-0.6b").smoke  # 2 layers -> 2 stages x 1
    from repro.dist.sharding import make_mesh
    mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
    ctx = make_ctx(mesh)
    from repro.models.transformer import init_params
    params = init_params(cfg, jax.random.key(0))
    batch = train_batch(cfg, 32, 4, specs=False)
    l_ref, _ = jax.jit(lambda p, b: loss_fn(p, b, cfg, None))(params, batch)
    pcfg = PipelineConfig(axis="pod", n_microbatches=2)
    l_pp, _ = jax.jit(lambda p, b: pipeline_loss_fn(p, b, cfg, ctx, pcfg))(
        params, batch)
    np.testing.assert_allclose(float(l_ref), float(l_pp), rtol=2e-3)
    # gradients flow through ppermute
    g = jax.jit(jax.grad(lambda p, b: pipeline_loss_fn(
        p, b, cfg, ctx, pcfg)[0]))(params, batch)
    gn = sum(float(jnp.sum(jnp.abs(x))) for x in jax.tree.leaves(g))
    assert np.isfinite(gn) and gn > 0
    print("CHECK_OK")


def check_elastic_reshard():
    """Checkpoint from a (2,4) mesh restores onto (4,2)."""
    import tempfile
    from repro.dist.sharding import ShardCtx
    from repro.ft.checkpoint import CheckpointManager
    from repro.ft.elastic import restore_elastic
    from repro.launch.mesh import make_test_mesh

    tree = {"blocks": {"pos0": {"attn": {
        "wq": jax.random.normal(jax.random.key(0), (4, 64, 64))}}},
        "embed": jax.random.normal(jax.random.key(1), (128, 64))}
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d)
        mgr.save(1, tree, blocking=True)
        mesh2 = make_test_mesh((4, 2), ("data", "model"))
        ctx2 = ShardCtx(mesh=mesh2, data_axes=("data",), model_axis="model")
        restored, _ = restore_elastic(mgr, tree, ctx2)
        jax.tree.map(lambda a, b: np.testing.assert_allclose(
            np.asarray(a), np.asarray(b)), tree, restored)
        wq = restored["blocks"]["pos0"]["attn"]["wq"]
        assert wq.sharding.mesh.shape["model"] == 2
    print("CHECK_OK")


def check_seq_parallel_decode():
    """Decode with KV cache sharded over the sequence axis == unsharded."""
    import dataclasses
    from repro.configs import get_arch
    from repro.configs.base import decode_inputs
    from repro.launch.mesh import make_ctx, make_test_mesh
    from repro.models import decode as dec
    from repro.models.transformer import init_params
    from repro.serve.engine import ServeConfig, jit_decode_step

    cfg = get_arch("qwen3-0.6b").smoke
    params = init_params(cfg, jax.random.key(0))
    cache, token = decode_inputs(cfg, seq=32, batch=8, specs=False,
                                 cache_dtype=jnp.float32)
    cache["len"] = jnp.asarray(16, jnp.int32)
    # fill cache with noise so attention actually reads it
    cache["blocks"] = jax.tree.map(
        lambda a: jax.random.normal(jax.random.key(2), a.shape, a.dtype)
        if a.dtype != jnp.int32 else a, cache["blocks"])
    logits_ref, _ = jax.jit(
        lambda p, c, t: dec.decode_step(p, c, t, cfg, None))(
            params, cache, token)
    mesh = make_test_mesh((2, 4), ("data", "model"))
    ctx = make_ctx(mesh, long_context=True)
    scfg = ServeConfig(max_len=32, long_context=True)
    step = jit_decode_step(cfg, ctx, scfg, params, cache)
    logits_sp, _ = step(params, dict(cache), token)
    np.testing.assert_allclose(np.asarray(logits_ref, np.float32),
                               np.asarray(logits_sp, np.float32),
                               rtol=3e-3, atol=3e-3)
    print("CHECK_OK")


def _longctx_setup(seq=32, batch=8):
    import dataclasses
    from repro.configs import get_arch
    from repro.configs.base import decode_inputs
    from repro.models.transformer import init_params

    cfg = get_arch("qwen3-0.6b").smoke
    params = init_params(cfg, jax.random.key(0))

    def fresh_cache():
        cache, token = decode_inputs(cfg, seq=seq, batch=batch, specs=False,
                                     cache_dtype=jnp.float32)
        cache["len"] = jnp.asarray(seq // 2, jnp.int32)
        cache["blocks"] = jax.tree.map(
            lambda a: jax.random.normal(jax.random.key(2), a.shape, a.dtype)
            if a.dtype != jnp.int32 else a, cache["blocks"])
        return cache, token

    return cfg, params, fresh_cache


def check_longctx_fused_decode():
    """PR 4 headline: the seq-sharded long-context decode step runs WITH
    step fusion — bit-exact vs the per-access oracle on the same
    placement, close to the unsharded oracle, and the fused path
    introduces no cache-sized all-gather (the old involuntary SPMD
    rematerialization)."""
    import re
    from repro.launch.mesh import make_ctx, make_test_mesh
    from repro.models import decode as dec
    from repro.serve.engine import ServeConfig, jit_decode_step

    cfg, params, fresh_cache = _longctx_setup()
    cache, token = fresh_cache()
    logits_ref, _ = jax.jit(
        lambda p, c, t: dec.decode_step(p, c, t, cfg, None, fuse=False))(
            params, cache, token)

    mesh = make_test_mesh((2, 4), ("data", "model"))
    ctx = make_ctx(mesh, long_context=True)
    texts, logits, caches = {}, {}, {}
    for fuse in (True, False):
        scfg = ServeConfig(max_len=32, long_context=True, step_fusion=fuse)
        cache, token = fresh_cache()
        step = jit_decode_step(cfg, ctx, scfg, params, cache)
        texts[fuse] = step.lower(params, cache, token).compile().as_text()
        logits[fuse], caches[fuse] = step(params, cache, token)

    np.testing.assert_array_equal(np.asarray(logits[True]),
                                  np.asarray(logits[False]))
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), caches[True], caches[False])
    np.testing.assert_allclose(np.asarray(logits[True], np.float32),
                               np.asarray(logits_ref, np.float32),
                               rtol=3e-3, atol=3e-3)

    # No involuntary full-cache rematerialization: fusion must not add
    # all-gathers, and none of the fused step's all-gathers may span a
    # full KV-cache leaf (global slice of the seq-sharded pre-split
    # leaves — the exact failure mode that forced per-access before).
    leaf_elems = {int(np.prod(a.shape))
                  for a in jax.tree.leaves(fresh_cache()[0]["blocks"])}
    for name, txt in (("fused", texts[True]), ("per", texts[False])):
        ag = [np.prod([int(d) for d in dims.split(",") if d])
              for dims in re.findall(r"\S+\[([\d,]*)\][^\n]*all-gather",
                                     txt)]
        big = [int(e) for e in ag if e in leaf_elems]
        assert not big, (name, big)
    assert texts[True].count("all-gather") <= texts[False].count(
        "all-gather")
    print("CHECK_OK")


def check_longctx_launch_gate():
    """Sharded mirror of tests/test_step_fusion.py's jaxpr-level gate:
    the seq-sharded fused decode step must issue >= 2x fewer kernel
    launches AND mask operands than the sharded per-access path (counts
    include shard_map bodies)."""
    from repro import vx
    from repro.core import accessfuse
    from repro.launch.mesh import make_ctx, make_test_mesh
    from repro.models import decode as dec

    cfg, params, fresh_cache = _longctx_setup()
    cache, token = fresh_cache()
    mesh = make_test_mesh((2, 4), ("data", "model"))
    ctx = make_ctx(mesh, long_context=True)
    shard = ctx.vx_seq_shard(-3)
    assert shard is not None and shard.nshards == 8

    def fused(p, c, t):
        return dec.decode_step(p, c, t, cfg, ctx, fuse=True,
                               kv_shard=shard)

    def per_access(p, c, t):
        return dec.decode_step(p, c, t, cfg, ctx, fuse=False)

    with vx.use("pallas"), accessfuse.pinned_kernel_lowering():
        lf, mf = accessfuse.jaxpr_access_counts(fused, params, cache, token)
    with vx.use("pallas"):
        lp, mp = accessfuse.jaxpr_access_counts(per_access, params, cache,
                                                token)
    assert lf >= 1 and mf >= 1, (lf, mf)
    assert 2 * lf <= lp, (lf, lp)
    assert 2 * mf <= mp, (mf, mp)
    print("CHECK_OK")


def check_sharded_vx_property():
    """Property sweep: shard-local gather/scatter/transpose match the
    unsharded oracle bit-exactly across layouts (1- and 2-axis meshes),
    strides of either sign, offsets, and field counts."""
    from repro import vx
    from repro.dist.sharding import make_mesh

    rng = np.random.default_rng(0)
    layouts = [((8,), ("s",)), ((2, 4), ("a", "b")), ((4, 2), ("a", "b"))]
    for shape, axes in layouts:
        mesh = make_mesh(shape, axes)
        lane = vx.Shard(axes=axes, axis=-1, mesh=mesh)
        outer = vx.Shard(axes=axes, axis=-2, mesh=mesh)
        n = 64
        w = jnp.asarray(rng.normal(size=(3, n)), jnp.float32)
        for stride, offset in [(1, 0), (2, 3), (3, 1), (5, 2), (7, 1),
                               (-1, 63), (-2, 50), (-4, 40)]:
            vl = 8
            spec = vx.Strided(n=n, stride=stride, offset=offset, vl=vl)
            want = vx.gather(spec, w, policy="ref")
            got = jax.jit(lambda x: vx.gather(spec, x, policy="ref",
                                              shard=lane))(w)
            np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
            vals = jnp.asarray(rng.normal(size=(3, vl)), jnp.float32)
            want_s = vx.scatter(spec, w, vals, policy="ref")
            got_s = jax.jit(lambda x, v: vx.scatter(spec, x, v,
                                                    policy="ref",
                                                    shard=lane))(w, vals)
            np.testing.assert_array_equal(np.asarray(got_s),
                                          np.asarray(want_s))
        for fields in (2, 4):
            aos = jnp.asarray(rng.normal(size=(2, 16, 8 * fields)),
                              jnp.float32)
            spec = vx.Segment(n=8 * fields, fields=fields)
            want = vx.transpose(spec, aos, policy="ref")
            got = jax.jit(lambda x: vx.transpose(spec, x, policy="ref",
                                                 shard=outer))(aos)
            for g, ww in zip(got, want):
                np.testing.assert_array_equal(np.asarray(g),
                                              np.asarray(ww))
            back = jax.jit(lambda parts: vx.transpose(
                spec, list(parts), policy="ref", shard=outer))(tuple(got))
            np.testing.assert_array_equal(np.asarray(back), np.asarray(aos))
    print("CHECK_OK")


def check_paged_pool_shard():
    """Sharded paged-pool gathers: the pool sharded on its page axis over
    1- and 2-axis meshes, gathered shard-locally (owned page block only,
    one psum merge) — bit-exact vs the replicated lowering, for full,
    partial, and unallocated tables, fused multi-pool form included.
    Also: the compiled HLO of the sharded gather contains no all-gather
    of a pool-sized operand (the no-global-slice invariant); and the full
    serving path — paged_decode_step with the pool sharded via
    ShardCtx.vx_pool_shard(-4) — is bit-exact vs the replicated step."""
    from repro import vx
    from repro.dist.sharding import ShardCtx, make_mesh
    from repro.models import decode as dec
    from repro.models.transformer import ModelConfig, init_params

    rng = np.random.default_rng(0)
    ps, pages, P, K, D2 = 4, 6, 16, 2, 8
    pool = jnp.asarray(rng.normal(size=(2, P, ps, K, D2)), jnp.float32)
    spec = vx.Paged(page_size=ps, pages=pages, trail=2)
    tables = np.full((3, pages), -1, np.int32)
    tables[0, :pages] = rng.permutation(P)[:pages]        # full
    tables[1, :3] = [15, 0, 7]                            # partial
    table = jnp.asarray(tables)
    want = vx.gather(spec, pool, table=table, policy="ref")

    for shape, axes in [((8,), ("s",)), ((2, 4), ("a", "b")),
                        ((4, 2), ("a", "b"))]:
        mesh = make_mesh(shape, axes)
        shard = vx.Shard(axes=axes, axis=-4, mesh=mesh)
        got = jax.jit(lambda pl, tb: vx.gather(
            spec, pl, table=tb, policy="ref", shard=shard))(pool, table)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
        outs = jax.jit(lambda pl, tb: vx.gather_many(
            spec, [pl, pl * 2], table=tb, policy="ref",
            shard=shard))(pool, table)
        np.testing.assert_array_equal(np.asarray(outs[0]), np.asarray(want))
        np.testing.assert_array_equal(np.asarray(outs[1]),
                                      np.asarray(want) * 2)
        # no pool-sized all-gather in the compiled sharded gather
        hlo = jax.jit(lambda pl, tb: vx.gather(
            spec, pl, table=tb, policy="ref",
            shard=shard)).lower(pool, table).compile().as_text()
        pool_elems = P * ps * K * D2
        for line in hlo.splitlines():
            if "all-gather" in line and f"{pool_elems}" in line:
                raise AssertionError(f"pool-sized all-gather:\n{line}")

    # the serving path: paged decode with the pool sharded through
    # ShardCtx.vx_pool_shard — bit-exact vs the replicated step
    mesh = make_mesh((8,), ("s",))
    ctx = ShardCtx(mesh=mesh, data_axes=(), model_axis=None,
                   seq_axes=("s",))
    pool_shard = ctx.vx_pool_shard(-4)
    assert pool_shard is not None and pool_shard.axes == ("s",)
    cfg = ModelConfig(name="paged-shard", d_model=32, n_layers=2,
                      n_heads=2, n_kv_heads=2, d_ff=64, vocab=97,
                      head_dim=16, mlp="swiglu", scan_layers=True,
                      kernel_impl="ref", remat="none")
    params = init_params(cfg, jax.random.key(0))
    # num_pages = 2 slots x 8 pages: divides the 8 shards
    rep = dec.init_paged_cache(cfg, 2, 32, 4, jnp.float32)
    shd = rep
    tok = jnp.asarray([3, 9], jnp.int32)
    jrep = jax.jit(lambda p, c, t: dec.paged_decode_step(p, c, t, cfg,
                                                         None))
    jshd = jax.jit(lambda p, c, t: dec.paged_decode_step(
        p, c, t, cfg, None, pool_shard=pool_shard))
    for _ in range(5):
        lr, rep = jrep(params, rep, tok)
        ls, shd = jshd(params, shd, tok)
        np.testing.assert_array_equal(np.asarray(lr), np.asarray(ls))
        tok = jnp.argmax(lr.astype(jnp.float32), -1).astype(jnp.int32)
    print("CHECK_OK")


def check_quantized_pool_shard():
    """Sharded QUANTIZED paged gather (PR 9): int8 pool + per-page
    scales sharded on the page axis, dequant fused shard-locally — the
    scale one-hot contraction runs against the rebased local table, so
    the sharded lowering must be bit-exact vs the replicated one across
    mesh layouts, for full / partial / unallocated tables."""
    from repro import vx
    from repro.dist.sharding import make_mesh

    rng = np.random.default_rng(0)
    ps, pages, P, K, D2 = 4, 6, 16, 2, 8
    pool = jnp.asarray(rng.integers(-127, 128, (2, P, ps, K, D2)),
                       jnp.int8)
    scales = jnp.asarray(rng.uniform(0.01, 2.0, (2, P, K)), jnp.float32)
    spec = vx.Paged(page_size=ps, pages=pages, trail=2)
    tables = np.full((3, pages), -1, np.int32)
    tables[0, :pages] = rng.permutation(P)[:pages]
    tables[1, :3] = [15, 0, 7]
    table = jnp.asarray(tables)
    want = vx.gather(spec, pool, table=table, scales=scales, policy="ref")
    for shape, axes in [((8,), ("s",)), ((2, 4), ("a", "b")),
                        ((4, 2), ("a", "b"))]:
        mesh = make_mesh(shape, axes)
        shard = vx.Shard(axes=axes, axis=-4, mesh=mesh)
        got = jax.jit(lambda pl, sc, tb: vx.gather(
            spec, pl, table=tb, scales=sc, policy="ref",
            shard=shard))(pool, scales, table)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    print("CHECK_OK")


CHECKS = {
    "moe_ep_equivalence": check_moe_ep_equivalence,
    "sharded_train_step": check_sharded_train_step,
    "pipeline_equivalence": check_pipeline_equivalence,
    "elastic_reshard": check_elastic_reshard,
    "seq_parallel_decode": check_seq_parallel_decode,
    "longctx_fused_decode": check_longctx_fused_decode,
    "longctx_launch_gate": check_longctx_launch_gate,
    "sharded_vx_property": check_sharded_vx_property,
    "paged_pool_shard": check_paged_pool_shard,
    "quantized_pool_shard": check_quantized_pool_shard,
}

if __name__ == "__main__":
    CHECKS[sys.argv[1]]()
