"""Distributed-semantics checks, run in a subprocess with 8 fake devices.

Invoked by tests/test_dist_8dev.py as:
    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        python -m tests._dist_checks <check_name>
Each check prints CHECK_OK on success.
"""
from __future__ import annotations

import os
import sys

if "--xla_force_host_platform_device_count" not in os.environ.get(
        "XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402


def check_moe_ep_equivalence():
    """Expert-parallel MoE on a (2,4) mesh == single-device MoE."""
    from repro.dist.sharding import ShardCtx
    from repro.launch.mesh import make_test_mesh
    from repro.models.moe import MoESpec, init_moe, moe_layer

    d = 64
    spec = MoESpec(n_experts=8, top_k=2, d_ff=96, capacity_slack=8.0)
    params = init_moe(jax.random.key(0), d, spec, jnp.float32)
    x = jax.random.normal(jax.random.key(1), (4, 16, d))
    y_local, aux_local = jax.jit(
        lambda p, x: moe_layer(p, x, spec, None))(params, x)
    mesh = make_test_mesh((2, 4), ("data", "model"))
    ctx = ShardCtx(mesh=mesh, data_axes=("data",), model_axis="model")
    y_ep, aux_ep = jax.jit(
        lambda p, x: moe_layer(p, x, spec, ctx))(params, x)
    np.testing.assert_allclose(np.asarray(y_local), np.asarray(y_ep),
                               rtol=2e-4, atol=2e-4)
    # aux is a per-data-shard load-balance estimator averaged with pmean;
    # it is nonlinear in the token partition, so only approximately equal
    np.testing.assert_allclose(float(aux_local), float(aux_ep), rtol=0.1)
    print("CHECK_OK")


def check_sharded_train_step():
    """Sharded train step on (2,4): finite loss, state keeps shardings."""
    from repro.configs import get_arch
    from repro.configs.base import train_batch
    from repro.launch.mesh import make_ctx, make_test_mesh
    from repro.train.step import TrainConfig, init_full_state, jit_train_step

    arch = get_arch("qwen3-0.6b")
    import dataclasses
    cfg = dataclasses.replace(arch.smoke, compute_dtype="bfloat16")
    mesh = make_test_mesh((2, 4), ("data", "model"))
    ctx = make_ctx(mesh)
    tcfg = TrainConfig()
    state = init_full_state(cfg, tcfg, jax.random.key(0))
    batch = train_batch(cfg, 64, 4, specs=False)
    step = jit_train_step(cfg, tcfg, ctx, state, batch)
    state, m1 = step(state, batch)
    state, m2 = step(state, batch)
    assert np.isfinite(float(m1["loss"])) and np.isfinite(float(m2["loss"]))
    assert float(m2["loss"]) < float(m1["loss"]) + 0.5
    # a model-sharded leaf should really be distributed
    wq = state["params"]["blocks"]["pos0"]["attn"]["wq"]
    assert len(wq.sharding.device_set) == 8 or not wq.sharding.is_fully_replicated
    print("CHECK_OK")


def check_pipeline_equivalence():
    """GPipe over pod axis == plain forward (loss equality)."""
    import dataclasses
    from repro.configs import get_arch
    from repro.configs.base import train_batch
    from repro.dist.pipeline_par import PipelineConfig, pipeline_loss_fn
    from repro.launch.mesh import make_ctx
    from repro.models.transformer import loss_fn

    cfg = get_arch("qwen3-0.6b").smoke  # 2 layers -> 2 stages x 1
    from repro.dist.sharding import make_mesh
    mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
    ctx = make_ctx(mesh)
    from repro.models.transformer import init_params
    params = init_params(cfg, jax.random.key(0))
    batch = train_batch(cfg, 32, 4, specs=False)
    l_ref, _ = jax.jit(lambda p, b: loss_fn(p, b, cfg, None))(params, batch)
    pcfg = PipelineConfig(axis="pod", n_microbatches=2)
    l_pp, _ = jax.jit(lambda p, b: pipeline_loss_fn(p, b, cfg, ctx, pcfg))(
        params, batch)
    np.testing.assert_allclose(float(l_ref), float(l_pp), rtol=2e-3)
    # gradients flow through ppermute
    g = jax.jit(jax.grad(lambda p, b: pipeline_loss_fn(
        p, b, cfg, ctx, pcfg)[0]))(params, batch)
    gn = sum(float(jnp.sum(jnp.abs(x))) for x in jax.tree.leaves(g))
    assert np.isfinite(gn) and gn > 0
    print("CHECK_OK")


def check_elastic_reshard():
    """Checkpoint from a (2,4) mesh restores onto (4,2)."""
    import tempfile
    from repro.dist.sharding import ShardCtx
    from repro.ft.checkpoint import CheckpointManager
    from repro.ft.elastic import restore_elastic
    from repro.launch.mesh import make_test_mesh

    tree = {"blocks": {"pos0": {"attn": {
        "wq": jax.random.normal(jax.random.key(0), (4, 64, 64))}}},
        "embed": jax.random.normal(jax.random.key(1), (128, 64))}
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d)
        mgr.save(1, tree, blocking=True)
        mesh2 = make_test_mesh((4, 2), ("data", "model"))
        ctx2 = ShardCtx(mesh=mesh2, data_axes=("data",), model_axis="model")
        restored, _ = restore_elastic(mgr, tree, ctx2)
        jax.tree.map(lambda a, b: np.testing.assert_allclose(
            np.asarray(a), np.asarray(b)), tree, restored)
        wq = restored["blocks"]["pos0"]["attn"]["wq"]
        assert wq.sharding.mesh.shape["model"] == 2
    print("CHECK_OK")


def check_seq_parallel_decode():
    """Decode with KV cache sharded over the sequence axis == unsharded."""
    import dataclasses
    from repro.configs import get_arch
    from repro.configs.base import decode_inputs
    from repro.launch.mesh import make_ctx, make_test_mesh
    from repro.models import decode as dec
    from repro.models.transformer import init_params
    from repro.serve.engine import ServeConfig, jit_decode_step

    cfg = get_arch("qwen3-0.6b").smoke
    params = init_params(cfg, jax.random.key(0))
    cache, token = decode_inputs(cfg, seq=32, batch=8, specs=False,
                                 cache_dtype=jnp.float32)
    cache["len"] = jnp.asarray(16, jnp.int32)
    # fill cache with noise so attention actually reads it
    cache["blocks"] = jax.tree.map(
        lambda a: jax.random.normal(jax.random.key(2), a.shape, a.dtype)
        if a.dtype != jnp.int32 else a, cache["blocks"])
    logits_ref, _ = jax.jit(
        lambda p, c, t: dec.decode_step(p, c, t, cfg, None))(
            params, cache, token)
    mesh = make_test_mesh((2, 4), ("data", "model"))
    ctx = make_ctx(mesh, long_context=True)
    scfg = ServeConfig(max_len=32, long_context=True)
    step = jit_decode_step(cfg, ctx, scfg, params, cache)
    logits_sp, _ = step(params, dict(cache), token)
    np.testing.assert_allclose(np.asarray(logits_ref, np.float32),
                               np.asarray(logits_sp, np.float32),
                               rtol=3e-3, atol=3e-3)
    print("CHECK_OK")


CHECKS = {
    "moe_ep_equivalence": check_moe_ep_equivalence,
    "sharded_train_step": check_sharded_train_step,
    "pipeline_equivalence": check_pipeline_equivalence,
    "elastic_reshard": check_elastic_reshard,
    "seq_parallel_decode": check_seq_parallel_decode,
}

if __name__ == "__main__":
    CHECKS[sys.argv[1]]()
