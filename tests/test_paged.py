"""Paged KV runtime: vx.Paged lowering, paged decode vs the dense-cache
oracle, page reclamation, and the fused-paged-gather jaxpr gates.

Bit-exactness contract: for windowless attention layers the paged decode
step must reproduce the dense decode step's logits BIT-EXACTLY (the page
gather reconstructs the same (B, S, K, 2D) array the dense cache holds;
everything downstream is the identical computation).  Sliding-window
layers trade the ring buffer for an attention-time mask — same attended
set, different storage order — and are checked with allclose.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import vx
from repro.core import accessfuse, scg
from repro.models import decode as dec
from repro.models.transformer import ModelConfig, init_params


def _cfg(layers=2, hd=16, scan=False, impl="ref", positions=1, window=None,
         mlp="swiglu", d_ff=64):
    return ModelConfig(
        name="paged-test", d_model=2 * hd, n_layers=layers, n_heads=2,
        n_kv_heads=2, d_ff=d_ff, vocab=97, head_dim=hd, mlp=mlp,
        block_pattern=("attn",) * positions,
        window_pattern=(window,) * positions,
        moe_pattern=(False,) * positions,
        scan_layers=scan, kernel_impl=impl, remat="none")


def _count_gathers(fn, *args) -> int:
    """`gather` equations anywhere in the jaxpr (page-table takes; also
    counts embed/table lookups — callers compare paths, not absolutes)."""
    def rec(jaxpr):
        c = 0
        for eqn in jaxpr.eqns:
            if eqn.primitive.name == "gather":
                c += 1
            for v in eqn.params.values():
                for sub in accessfuse._child_jaxprs(v):
                    c += rec(sub)
        return c
    return rec(jax.make_jaxpr(lambda *a: fn(*a))(*args).jaxpr)


# ---------------------------------------------------------------------------
# vx.Paged lowering
# ---------------------------------------------------------------------------

def test_paged_gather_matches_manual_take():
    rng = np.random.default_rng(0)
    ps, pages, P = 4, 3, 8
    pool = jnp.asarray(rng.normal(size=(2, P, ps, 2, 6)), jnp.float32)
    spec = vx.Paged(page_size=ps, pages=pages, trail=2)
    table = jnp.asarray([[2, 0, -1], [5, -1, -1]], np.int32)
    out = vx.gather(spec, pool, table=table)
    assert out.shape == (2, 2, pages * ps, 2, 6)
    pn = np.asarray(pool)
    want = np.zeros((2, 2, pages * ps, 2, 6), np.float32)
    want[:, 0, :4], want[:, 0, 4:8] = pn[:, 2], pn[:, 0]
    want[:, 1, :4] = pn[:, 5]
    np.testing.assert_array_equal(np.asarray(out), want)


def test_paged_scatter_appends_and_drops():
    ps, pages, P = 4, 2, 4
    pool = jnp.zeros((P, ps, 3), jnp.float32)
    spec = vx.Paged(page_size=ps, pages=pages, trail=1)
    table = jnp.asarray([[1, -1], [3, 0], [-1, -1]], np.int32)
    vals = jnp.asarray([[1., 1, 1], [2, 2, 2], [3, 3, 3]])
    # row0 pos 2 -> page 1 off 2; row1 pos 5 -> logical page 1 = phys 0,
    # off 1; row2 dropped (pos < 0); unallocated pages drop too
    pos = jnp.asarray([2, 5, -1], np.int32)
    out = np.asarray(vx.scatter(spec, pool, vals, table=table, pos=pos))
    want = np.zeros((P, ps, 3), np.float32)
    want[1, 2] = 1.0
    want[0, 1] = 2.0
    np.testing.assert_array_equal(out, want)
    # writes through an UNALLOCATED entry or past the logical capacity
    # are dropped (never clamped into a wrong page)
    out2 = np.asarray(vx.scatter(spec, pool, vals, table=table,
                                 pos=jnp.asarray([6, -1, 99], np.int32)))
    np.testing.assert_array_equal(out2, np.zeros_like(out2))


def test_append_paged_token_interleaves_beat():
    from repro.kernels import kv_interleaved
    ps, pages, P, H, d = 4, 2, 4, 2, 3
    pool = jnp.zeros((P, ps, H, 2 * d), jnp.float32)
    table = jnp.asarray([[2, -1]], np.int32)
    k = jnp.arange(H * d, dtype=jnp.float32).reshape(1, H, d)
    v = k + 100
    out = np.asarray(kv_interleaved.append_paged_token(
        pool, k, v, table, jnp.asarray([1], np.int32)))
    beat = np.asarray(kv_interleaved.interleave_kv(k, v))[0]
    want = np.zeros_like(out)
    want[2, 1] = beat
    np.testing.assert_array_equal(out, want)


def test_paged_program_cached_by_geometry_not_table():
    """One compiled program per page GEOMETRY, reused across requests
    (different runtime tables); a different page size is a new entry."""
    pool = jnp.zeros((4, 4, 2), jnp.float32)
    spec = vx.Paged(page_size=4, pages=2, trail=1)
    t1 = jnp.asarray([[0, 1]], np.int32)
    t2 = jnp.asarray([[3, -1]], np.int32)
    vx.PLANS.clear()
    vx.gather(spec, pool, table=t1, policy="ref")
    m1 = vx.PLANS.stats()["misses"]
    vx.gather(spec, pool, table=t2, policy="ref")   # table is runtime
    assert vx.PLANS.stats()["misses"] == m1
    pool2 = jnp.zeros((8, 2, 2), jnp.float32)
    vx.gather(vx.Paged(page_size=2, pages=2, trail=1), pool2, table=t1,
              policy="ref")
    assert vx.PLANS.stats()["misses"] > m1
    # dtype participates too (the PR 3 collision rule)
    vx.gather(spec, pool.astype(jnp.bfloat16), table=t1, policy="ref")
    assert vx.PLANS.stats()["misses"] > m1 + 1


def test_paged_gather_many_is_one_program():
    """The whole-step fused paged read is ONE gather over the stacked
    pools; the per-leaf path pays one per pool."""
    rng = np.random.default_rng(1)
    ps, pages, P = 4, 4, 8
    pools = [jnp.asarray(rng.normal(size=(2, P, ps, 2, 6)), jnp.float32)
             for _ in range(3)]
    table = jnp.asarray([[0, 3, -1, -1], [7, 2, 5, 1]], np.int32)
    spec = vx.Paged(page_size=ps, pages=pages, trail=2)

    fused = lambda a, b, c, t: vx.gather_many(spec, [a, b, c], table=t)
    per = lambda a, b, c, t: [vx.gather(spec, p, table=t)
                              for p in (a, b, c)]
    assert _count_gathers(fused, *pools, table) == 1
    assert _count_gathers(per, *pools, table) == 3
    got = fused(*pools, table)
    want = per(*pools, table)
    for g, w in zip(got, want):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))


def test_paged_shard_axis_validation():
    pool = jnp.zeros((4, 4, 2), jnp.float32)
    spec = vx.Paged(page_size=4, pages=2, trail=1)
    mesh = jax.sharding.Mesh(np.array(jax.devices()[:1]), ("x",))
    bad = vx.Shard(axes=("x",), axis=-1, mesh=mesh)
    with pytest.raises(ValueError, match="page-pool axis"):
        vx.gather(spec, pool, table=jnp.zeros((1, 2), jnp.int32),
                  shard=bad)


def test_indexed_static_routing_promotes_to_plan():
    """Host-known (shift, valid) fold into the spec, compile through the
    plan stage, and match the dynamic network bit-exactly."""
    n = 32
    shift, valid = scg.gather_counts(n, 3, 2, 7)
    buf = jnp.arange(n, dtype=jnp.float32) * 2 + 1
    dyn = vx.gather(vx.Indexed(n=n), buf, shift=jnp.asarray(shift),
                    valid=jnp.asarray(valid))
    vx.PLANS.clear()
    static = vx.gather(vx.Indexed(n=n), buf, shift=np.asarray(shift),
                       valid=np.asarray(valid))
    np.testing.assert_array_equal(np.asarray(static), np.asarray(dyn))
    m = vx.PLANS.stats()["misses"]
    assert m >= 1
    vx.gather(vx.Indexed(n=n), buf, shift=np.asarray(shift),
              valid=np.asarray(valid))          # same routing: cache hit
    assert vx.PLANS.stats()["misses"] == m
    # spec-folded form is equivalent to operand promotion
    spec = vx.Indexed(n=n, routing=(tuple(np.asarray(shift).tolist()),
                                    tuple(np.asarray(valid).tolist())))
    np.testing.assert_array_equal(
        np.asarray(vx.gather(spec, buf)), np.asarray(dyn))


# ---------------------------------------------------------------------------
# Paged decode vs the dense-cache oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("page_size", (4, 8, 16))
@pytest.mark.parametrize("slots", (1, 3))
def test_paged_decode_bit_exact_vs_dense_sweep(page_size, slots):
    """Property sweep over (page_size, slots): fused AND per-access paged
    decode reproduce the dense decode step bit-exactly, step by step."""
    cfg = _cfg(layers=2, hd=16, scan=True)
    params = init_params(cfg, jax.random.key(0))
    max_len = 16
    dense = dec.init_cache(cfg, slots, max_len, jnp.float32)
    paged = dec.init_paged_cache(cfg, slots, max_len, page_size,
                                 jnp.float32)
    tok = (jnp.arange(slots, dtype=jnp.int32) * 7 + 3) % cfg.vocab
    jd = jax.jit(lambda p, c, t: dec.decode_step(p, c, t, cfg, None,
                                                 fuse=False))
    jf = jax.jit(lambda p, c, t: dec.paged_decode_step(p, c, t, cfg, None,
                                                       fuse=True))
    ju = jax.jit(lambda p, c, t: dec.paged_decode_step(p, c, t, cfg, None,
                                                       fuse=False))
    cd, cf, cu = dense, paged, paged
    for _ in range(6):
        ld, cd = jd(params, cd, tok)
        lf, cf = jf(params, cf, tok)
        lu, cu = ju(params, cu, tok)
        np.testing.assert_array_equal(np.asarray(ld), np.asarray(lu))
        np.testing.assert_array_equal(np.asarray(ld), np.asarray(lf))
        tok = jnp.argmax(ld.astype(jnp.float32), axis=-1).astype(jnp.int32)
    # memory accounting: pages allocated == ceil(tokens/page) per slot
    used = int(paged["free_top"]) - int(cf["free_top"])
    assert used == slots * -(-6 // page_size)


def test_paged_heterogeneous_lengths_match_solo_dense():
    """Mixed request lengths in one paged batch (late joiner via the
    active mask): every ACTIVE slot's logits are bit-exact vs a dense
    decode of the same forced token stream run fresh in that slot (same
    batch width, so the compiled program is identical row-for-row)."""
    cfg = _cfg(layers=2, hd=16, scan=False)
    params = init_params(cfg, jax.random.key(1))
    max_len, ps = 16, 4
    paged = dec.init_paged_cache(cfg, 2, max_len, ps, jnp.float32)
    jp = jax.jit(lambda p, c, t, a: dec.paged_decode_step(
        p, c, t, cfg, None, active=a))
    jd = jax.jit(lambda p, c, t: dec.decode_step(p, c, t, cfg, None))

    streams = {0: [5], 1: [11]}        # slot 1 joins at step 2
    joins = {0: 0, 1: 2}
    paged_logits = {0: [], 1: []}
    for step in range(6):
        act = jnp.asarray([joins[s] <= step for s in (0, 1)])
        tok = jnp.asarray([streams[s][-1] if joins[s] <= step else 0
                           for s in (0, 1)], jnp.int32)
        lg, paged = jp(params, paged, tok, act)
        for s in (0, 1):
            if joins[s] <= step:
                paged_logits[s].append(np.asarray(lg[s]))
                streams[s].append(int(jnp.argmax(
                    lg[s].astype(jnp.float32))))
    for s in (0, 1):
        solo = dec.init_cache(cfg, 2, max_len, jnp.float32)
        toks = [[5, 11][s]]
        for want in paged_logits[s]:
            cur = [0, 0]
            cur[s] = toks[-1]
            lg, solo = jd(params, solo, jnp.asarray(cur, jnp.int32))
            np.testing.assert_array_equal(np.asarray(lg[s]), want)
            toks.append(int(jnp.argmax(lg[s].astype(jnp.float32))))


def test_paged_windowed_layers_allclose_vs_dense_ring():
    """Sliding-window layers: paged full-length + attention-time mask vs
    the dense ring buffer — same attended set, different storage order."""
    cfg = _cfg(layers=2, hd=16, scan=True, window=8)
    params = init_params(cfg, jax.random.key(2))
    dense = dec.init_cache(cfg, 2, 32, jnp.float32)
    paged = dec.init_paged_cache(cfg, 2, 32, 4, jnp.float32)
    tok = jnp.asarray([3, 9], jnp.int32)
    jd = jax.jit(lambda p, c, t: dec.decode_step(p, c, t, cfg, None))
    jp = jax.jit(lambda p, c, t: dec.paged_decode_step(p, c, t, cfg, None))
    cd, cp = dense, paged
    for _ in range(12):                # crosses the window boundary at 8
        ld, cd = jd(params, cd, tok)
        lp, cp = jp(params, cp, tok)
        np.testing.assert_allclose(np.asarray(ld, np.float32),
                                   np.asarray(lp, np.float32),
                                   rtol=2e-5, atol=2e-5)
        tok = jnp.argmax(ld.astype(jnp.float32), axis=-1).astype(jnp.int32)


def test_paged_pool_exhaustion_degrades_locally():
    """An empty free stack must never alias a page between slots or push
    free_top negative: starved slots simply stop storing (appends drop,
    table entries stay -1) and reclamation stays exact."""
    cfg = _cfg(layers=2, hd=16, scan=True)
    params = init_params(cfg, jax.random.key(4))
    # 2 slots x 4 logical pages each, but only 2 physical pages
    cache = dec.init_paged_cache(cfg, 2, 8, 2, jnp.float32, num_pages=2)
    jp = jax.jit(lambda p, c, t: dec.paged_decode_step(p, c, t, cfg, None))
    tok = jnp.asarray([3, 9], jnp.int32)
    for _ in range(6):
        lg, cache = jp(params, cache, tok)
        assert np.isfinite(np.asarray(lg, np.float32)).all()
        tok = jnp.argmax(lg.astype(jnp.float32), -1).astype(jnp.int32)
    assert int(cache["free_top"]) == 0          # exhausted, never negative
    table = np.asarray(cache["table"])
    owned = table[table >= 0]
    assert sorted(owned.tolist()) == [0, 1]     # each page has ONE owner
    assert (table[:, 1:] == -1).all()           # starved entries stay -1
    cache = jax.jit(lambda c, s: dec.paged_release_slot(cfg, c, s))(
        cache, jnp.int32(0))
    assert int(cache["free_top"]) == 1          # exactly slot 0's page back


def test_paged_release_then_reuse_is_bit_exact():
    """The reclamation regression: release a slot, admit a new request
    into the SAME physical pages — outputs bit-exact vs a fresh cache."""
    cfg = _cfg(layers=2, hd=16, scan=True)
    params = init_params(cfg, jax.random.key(3))
    jp = jax.jit(lambda p, c, t: dec.paged_decode_step(p, c, t, cfg, None))
    rel = jax.jit(lambda c, s: dec.paged_release_slot(cfg, c, s))

    cache = dec.init_paged_cache(cfg, 1, 16, 4, jnp.float32)
    free0 = int(cache["free_top"])
    tok = jnp.asarray([7], jnp.int32)
    for _ in range(5):
        lg, cache = jp(params, cache, tok)
        tok = jnp.argmax(lg.astype(jnp.float32), -1).astype(jnp.int32)
    cache = rel(cache, jnp.int32(0))
    assert int(cache["free_top"]) == free0          # all pages reclaimed
    assert int(cache["pos"][0]) == 0

    fresh = dec.init_paged_cache(cfg, 1, 16, 4, jnp.float32)
    tok_r = tok_f = jnp.asarray([13], jnp.int32)
    for _ in range(5):
        lr, cache = jp(params, cache, tok_r)
        lf, fresh = jp(params, fresh, tok_f)
        np.testing.assert_array_equal(np.asarray(lr), np.asarray(lf))
        tok_r = jnp.argmax(lr.astype(jnp.float32), -1).astype(jnp.int32)
        tok_f = jnp.argmax(lf.astype(jnp.float32), -1).astype(jnp.int32)


# ---------------------------------------------------------------------------
# jaxpr gates: one fused paged-gather program / one kernel launch per step
# ---------------------------------------------------------------------------

def test_paged_fused_gather_is_one_program_per_step():
    """The fused step collapses all attention leaves' page gathers into
    ONE gather equation (the per-access path pays one per leaf per
    superblock); with the TPU lowering pinned, the whole fused step also
    issues exactly ONE kernel launch with ONE mask operand."""
    # gather accounting on the pure XLA lowering (pallas interpret-mode
    # kernels would add their own internal gather equations)
    cfg_ref = _cfg(layers=4, hd=64, scan=False, impl="ref", positions=2,
                   mlp="none", d_ff=0)
    params = init_params(cfg_ref, jax.random.key(0))
    cache = dec.init_paged_cache(cfg_ref, 2, 64, 16, jnp.float32)
    tok = jnp.asarray([3, 5], jnp.int32)
    gf = _count_gathers(
        lambda p, c, t: dec.paged_decode_step(p, c, t, cfg_ref, None,
                                              fuse=True),
        params, cache, tok)
    gp = _count_gathers(
        lambda p, c, t: dec.paged_decode_step(p, c, t, cfg_ref, None,
                                              fuse=False),
        params, cache, tok)
    # 2 leaves x 2 superblocks of page gathers collapse into ONE
    assert gp - gf == 2 * 2 - 1, (gf, gp)

    cfg = _cfg(layers=4, hd=64, scan=False, impl="pallas", positions=2,
               mlp="none", d_ff=0)

    def fused(p, c, t):
        return dec.paged_decode_step(p, c, t, cfg, None, fuse=True)

    def per_access(p, c, t):
        return dec.paged_decode_step(p, c, t, cfg, None, fuse=False)

    with accessfuse.pinned_kernel_lowering():
        lf, mf = accessfuse.jaxpr_access_counts(fused, params, cache, tok)
    lp, mp = accessfuse.jaxpr_access_counts(per_access, params, cache, tok)
    assert lf == 1 and mf == 1, (lf, mf)
    assert lp >= 4 and mp >= 4, (lp, mp)


def test_paged_plan_cache_steady_state_under_jit():
    """Stepping the jit'd paged decode must not re-miss the plan cache:
    the program key is the page geometry, never the table contents."""
    cfg = _cfg(layers=2, hd=16, scan=True)
    params = init_params(cfg, jax.random.key(0))
    cache = dec.init_paged_cache(cfg, 2, 16, 4, jnp.float32)
    tok = jnp.asarray([3, 5], jnp.int32)
    jp = jax.jit(lambda p, c, t: dec.paged_decode_step(p, c, t, cfg, None))
    _, cache = jp(params, cache, tok)
    warm = vx.PLANS.stats()["misses"]
    for _ in range(4):
        _, cache = jp(params, cache, tok)
    assert vx.PLANS.stats()["misses"] == warm
