"""Shared quantization helpers (repro/core/quant.py): round-trip error
bounds per dtype, zero-scale safety, the compression delegation staying
bit-exact, and the fp8 feature gate."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import quant


def _dtypes():
    out = ["int8"]
    if quant.supported("float8_e4m3fn"):
        out.append("float8_e4m3fn")
    if quant.supported("float8_e5m2"):
        out.append("float8_e5m2")
    return out


# --------------------------- name plumbing ----------------------------------

def test_canonical_aliases_and_rejection():
    assert quant.canonical("fp8") == "float8_e4m3fn"
    assert quant.canonical("e5m2") == "float8_e5m2"
    assert quant.canonical("int8") == "int8"
    assert quant.canonical(np.dtype(np.int8)) == "int8"
    with pytest.raises(ValueError, match="unsupported quantized dtype"):
        quant.canonical("int4")
    assert not quant.supported("int4")
    assert quant.supported("int8")


def test_qmax_values():
    assert quant.qmax("int8") == 127.0            # symmetric, not -128
    assert quant.qmax("float8_e4m3fn") == 448.0   # max finite of e4m3fn
    assert quant.qmax(jnp.int8) == 127.0          # dtype objects too
    if quant.supported("fp8"):
        # the bound must agree with what the dtype actually encodes
        assert float(jnp.finfo(quant.pool_dtype("fp8")).max) == 448.0


# --------------------------- round-trip bound -------------------------------

@pytest.mark.parametrize("dt", _dtypes())
@pytest.mark.parametrize("scale", [1e-6, 1.0, 3e3])
def test_roundtrip_error_within_per_dtype_bound(dt, scale):
    """|x - roundtrip(x)| <= error_bound(dt, max|x|) for every element —
    the worst-case half-step (int8) / half-ulp (fp8) bound, at any
    tensor magnitude (the scale is max-abs, so the bound is relative)."""
    x = scale * jax.random.normal(jax.random.key(0), (512,), jnp.float32)
    y = quant.roundtrip(x, quant.pool_dtype(dt))
    bound = quant.error_bound(dt, float(jnp.max(jnp.abs(x))))
    err = float(jnp.max(jnp.abs(x - y)))
    assert np.isfinite(err)
    assert err <= bound * (1 + 1e-6), (dt, scale, err, bound)


@pytest.mark.parametrize("dt", _dtypes())
def test_roundtrip_extremes_map_exactly(dt):
    """The max-magnitude elements sit exactly at +-qmax, which every
    quantized dtype encodes exactly — so the extremes round-trip with
    zero error and nothing saturates to inf/NaN."""
    x = jnp.asarray([-7.5, 0.0, 7.5], jnp.float32)
    y = quant.roundtrip(x, quant.pool_dtype(dt))
    np.testing.assert_allclose(np.asarray(y)[[0, 2]], [-7.5, 7.5],
                               rtol=1e-6)
    assert float(y[1]) == 0.0


@pytest.mark.parametrize("dt", _dtypes())
def test_zero_scale_writes_zero_never_nan(dt):
    """scale == 0 means "nothing written": quantize must emit 0 (not
    0/0 = NaN — fp8 HAS NaN encodings and one NaN page poisons every
    later gather), and the all-zero tensor round-trips exactly."""
    z = jnp.zeros((8,), jnp.float32)
    q = quant.quantize(z, jnp.float32(0.0), dt)
    assert not bool(jnp.any(jnp.isnan(q.astype(jnp.float32))))
    np.testing.assert_array_equal(np.asarray(quant.roundtrip(
        z, quant.pool_dtype(dt))), np.zeros(8, np.float32))


def test_scale_for_axis_and_eps():
    x = jnp.asarray([[1.0, -4.0], [0.0, 0.0]], jnp.float32)
    s = quant.scale_for(x, "int8", axis=1)
    np.testing.assert_allclose(np.asarray(s), [4.0 / 127.0, 0.0])
    s_eps = quant.scale_for(x, "int8", axis=1, eps=1e-12)
    assert float(s_eps[1]) == pytest.approx(1e-12 / 127.0)


# --------------------------- compression delegation -------------------------

def test_compression_int8_roundtrip_delegates_bit_exact():
    """optim/compression.py's _int8_roundtrip is now quant.roundtrip —
    the delegation must be bit-exact vs the original inline formula
    (scale = max|g|/127, round, dequant) across magnitudes, or the
    error-feedback residuals drift from every pre-refactor run."""
    from repro.optim.compression import _int8_roundtrip
    for i, mag in enumerate([1e-15, 1e-3, 1.0, 1e4]):
        g = mag * jax.random.normal(jax.random.key(i), (257,), jnp.float32)
        scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
        ref = jnp.round(jnp.clip(g / scale, -127, 127)).astype(
            jnp.int8).astype(jnp.float32) * scale
        np.testing.assert_array_equal(np.asarray(_int8_roundtrip(g)),
                                      np.asarray(ref))
