"""Per-arch smoke tests: reduced same-family config, one forward/train step
and one decode step on CPU; asserts output shapes + finiteness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_arch
from repro.configs.base import decode_inputs, prefill_batch, train_batch
from repro.models import decode as dec
from repro.models import encdec
from repro.models.transformer import forward, init_params, loss_fn

SEQ, BATCH = 64, 2


@pytest.fixture(scope="module")
def arch_state():
    cache = {}

    def get(name):
        if name not in cache:
            a = get_arch(name)
            params = init_params(a.smoke, jax.random.key(0))
            cache[name] = (a, params)
        return cache[name]

    return get


@pytest.mark.parametrize("name", ARCH_IDS)
def test_forward_shapes_and_finite(name, arch_state):
    a, params = arch_state(name)
    cfg = a.smoke
    batch = train_batch(cfg, SEQ, BATCH, specs=False)
    logits, aux, _ = jax.jit(
        lambda p, b: forward(p, b, cfg, None))(params, batch)
    assert logits.shape == (BATCH, SEQ, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("name", ARCH_IDS)
def test_train_step_decreases_loss_direction(name, arch_state):
    """One SGD step on the smoke config must produce finite grads that
    reduce loss along the step direction."""
    a, params = arch_state(name)
    cfg = a.smoke
    batch = train_batch(cfg, SEQ, BATCH, specs=False)

    lr = 1e-4 if "xlstm" in name else 1e-2  # recurrent nets need smaller steps

    @jax.jit
    def step(p, b):
        (loss, _), g = jax.value_and_grad(
            lambda q: loss_fn(q, b, cfg, None), has_aux=True)(p)
        p2 = jax.tree.map(lambda x, dx: x - lr * dx.astype(x.dtype), p, g)
        return loss, p2, g

    loss0, params2, grads = step(params, batch)
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                         for x in jax.tree.leaves(grads)))
    assert bool(jnp.isfinite(loss0))
    assert bool(jnp.isfinite(gnorm)) and float(gnorm) > 0
    loss1, *_ = step(params2, batch)
    assert float(loss1) < float(loss0) + 1e-3, (float(loss0), float(loss1))


@pytest.mark.parametrize("name", ARCH_IDS)
def test_decode_step_shapes(name, arch_state):
    a, params = arch_state(name)
    cfg = a.smoke
    cache, token = decode_inputs(cfg, seq=32, batch=BATCH, specs=False,
                                 cache_dtype=jnp.float32)
    step = encdec.decode_step if cfg.encoder is not None else dec.decode_step
    if cfg.encoder is not None:
        # fill encoder KV from stub frames
        frames = jnp.zeros((BATCH, cfg.encoder.context, cfg.d_model))
        cache["enc_kv"] = encdec.precompute_enc_kv(params, frames, cfg, None)
    logits, cache2 = jax.jit(
        lambda p, c, t: step(p, c, t, cfg, None))(params, cache, token)
    assert logits.shape == (BATCH, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))
    assert int(cache2["len"]) == int(cache["len"]) + 1


@pytest.mark.parametrize("name", ["qwen3-0.6b", "gemma3-12b", "xlstm-125m",
                                  "jamba-1.5-large-398b"])
def test_prefill_then_decode_matches_forward(name, arch_state):
    """Decode after prefill must agree with teacher-forced forward logits."""
    a, params = arch_state(name)
    cfg = a.smoke
    S = 32
    batch = prefill_batch(cfg, S, BATCH, specs=False)
    logits_all, _, cache_states = jax.jit(
        lambda p, b: forward(p, b, cfg, None, mode="prefill"))(params, batch)
    # build a decode cache able to hold S+4 tokens and replay token S-1
    cache = dec.cache_from_prefill(cfg, cache_states, S, S + 4, jnp.float32)
    next_tok = jnp.argmax(logits_all[:, -1].astype(jnp.float32), axis=-1)
    logits_dec, _ = jax.jit(
        lambda p, c, t: dec.decode_step(p, c, t, cfg, None))(
            params, cache, next_tok.astype(jnp.int32))
    # teacher forcing: forward on sequence extended by next_tok
    toks2 = jnp.concatenate([batch["tokens"], next_tok[:, None]], axis=1)
    b2 = dict(batch)
    b2["tokens"] = toks2
    logits2, _, _ = jax.jit(
        lambda p, b: forward(p, b, cfg, None))(params, b2)
    np.testing.assert_allclose(
        np.asarray(logits_dec, np.float32),
        np.asarray(logits2[:, -1], np.float32), rtol=2e-2, atol=2e-2)
