"""Serving engine: continuous batching over the paged KV runtime —
admission/prefill, active-set stepping, sampling, page reclamation."""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.models.transformer import init_params
from repro.serve.engine import BatchedServer


@functools.lru_cache(maxsize=None)
def _cfg_params():
    cfg = get_arch("qwen3-0.6b").smoke
    return cfg, init_params(cfg, jax.random.key(0))


def _server(slots=3, max_len=32, **kw):
    cfg, params = _cfg_params()
    return cfg, BatchedServer(cfg, params, slots=slots, max_len=max_len,
                              **kw)


def test_continuous_batching_slots():
    cfg, server = _server()
    s0 = server.add_request(5)
    s1 = server.add_request(7)
    assert {s0, s1} == {0, 1}
    for _ in range(3):
        toks = server.step()
    assert all(t >= 0 for i, t in enumerate(toks) if i in (s0, s1))
    out0 = server.finish(s0)
    assert len(out0) == 4  # prompt + 3 generated
    # slot reuse after finish
    s2 = server.add_request(9)
    assert s2 == s0


def test_greedy_decode_is_deterministic():
    cfg, server_a = _server()
    _, server_b = _server()
    sa = server_a.add_request(11)
    sb = server_b.add_request(11)
    for _ in range(5):
        server_a.step()
        server_b.step()
    assert server_a.finish(sa) == server_b.finish(sb)


def test_isolated_slots_do_not_interact():
    """A request's tokens must not depend on other slots' contents."""
    cfg, server_a = _server()
    sa = server_a.add_request(13)
    for _ in range(4):
        server_a.step()
    solo = server_a.finish(sa)

    _, server_b = _server()
    server_b.add_request(99)     # a different request in slot 0
    sb = server_b.add_request(13)
    for _ in range(4):
        server_b.step()
    shared = server_b.finish(sb)
    assert solo[1:] == shared[1:], (solo, shared)


def test_cache_len_tracks_steps():
    cfg, server = _server()
    server.add_request(3)
    assert int(server.cache["len"]) == 0
    server.step()
    server.step()
    assert int(server.cache["len"]) == 2


def test_plan_cache_zero_steady_state_misses():
    """Repeated decode steps must never miss the plan cache after warmup:
    a steady-state miss means an unstable cache key is silently
    recompiling plans (or re-lowering programs) every step."""
    from repro import vx
    cfg, server = _server()
    server.add_request(5)
    server.step()                       # warmup: traces + compiles plans
    warm = vx.PLANS.stats()
    for _ in range(4):
        server.step()
    steady = vx.PLANS.stats()
    assert steady["misses"] == warm["misses"], (warm, steady)
    assert steady["evictions"] == warm["evictions"], (warm, steady)


def test_finish_clears_slot_state_for_reuse():
    """The PR 5 reclamation regression: two sequential requests through
    ONE slot — the second must be bit-exact vs a fresh server (the old
    dense server left the previous occupant's KV and a shared position
    counter behind)."""
    cfg, server = _server(slots=1)
    s0 = server.add_request(42)
    for _ in range(4):
        server.step()
    server.finish(s0)
    s1 = server.add_request(17)
    assert s1 == s0
    for _ in range(4):
        server.step()
    reused = server.finish(s1)

    _, fresh = _server(slots=1)
    sf = fresh.add_request(17)
    for _ in range(4):
        fresh.step()
    assert reused == fresh.finish(sf)


def test_finish_reclaims_pages():
    cfg, server = _server(slots=2)
    free0 = server.scheduler.cache.free_pages()
    s0 = server.add_request(prompt=[3, 5, 7, 9, 11])
    for _ in range(3):
        server.step()
    assert server.scheduler.cache.free_pages() < free0
    assert server.scheduler.cache.active_tokens() == 7   # 4 prefill + 3
    server.finish(s0)
    assert server.scheduler.cache.free_pages() == free0
    assert server.scheduler.cache.active_tokens() == 0


def test_add_request_full_prompt_prefills():
    """Multi-token prompts run through jit_prefill into the slot's pages;
    the first generated step must agree with forced token-by-token decode
    (prefill and decode are different computations — allclose)."""
    from repro.models import decode as dec
    cfg, params = _cfg_params()
    _, server = _server(slots=2)
    prompt = [7, 11, 13, 17, 19]
    s = server.add_request(prompt=prompt)
    server.step()
    out = server.scheduler.tokens[s]
    assert out[:5] == prompt and len(out) == 6

    cache = dec.init_paged_cache(cfg, 1, 32, server.scheduler.cache.page_size,
                                 jnp.float32)
    step = jax.jit(lambda p, c, t: dec.paged_decode_step(p, c, t, cfg,
                                                         None))
    for t in prompt:
        logits, cache = step(params, cache, jnp.asarray([t], jnp.int32))
    np.testing.assert_allclose(
        np.asarray(server.scheduler.last_logits[s], np.float32),
        np.asarray(logits[0], np.float32), rtol=2e-4, atol=2e-4)


def _forced_feed_logits(cfg, params, prompt, max_len, page_size):
    """Token-by-token paged decode oracle: logits after feeding prompt."""
    from repro.models import decode as dec
    cache = dec.init_paged_cache(cfg, 1, max_len, page_size, jnp.float32)
    step = jax.jit(lambda p, c, t: dec.paged_decode_step(p, c, t, cfg,
                                                         None))
    for t in prompt:
        logits, cache = step(params, cache, jnp.asarray([t], jnp.int32))
    return logits[0]


def test_prompt_prefill_windowed_layers():
    """Prompt prefill with sliding-window layers: the prefill must run at
    the TRUE length (padding would trim the ring at the padded length,
    dropping real in-window beats)."""
    from repro.models.transformer import ModelConfig
    from repro.serve.scheduler import Scheduler
    cfg = ModelConfig(name="win-serve", d_model=32, n_layers=2, n_heads=2,
                      n_kv_heads=2, d_ff=64, vocab=97, head_dim=16,
                      mlp="swiglu", window_pattern=(8,), scan_layers=True,
                      kernel_impl="ref", remat="none")
    params = init_params(cfg, jax.random.key(7))
    prompt = [5, 9, 13, 17, 21, 25, 29, 33, 37, 41, 45]  # 10 prefilled > W
    sched = Scheduler(cfg, params, slots=1, max_len=32, page_size=16)
    s = sched.add_request(prompt)
    sched.step()
    want = _forced_feed_logits(cfg, params, prompt, 32, 16)
    np.testing.assert_allclose(
        np.asarray(sched.last_logits[s], np.float32),
        np.asarray(want, np.float32), rtol=2e-4, atol=2e-4)


def test_prompt_prefill_hybrid_recurrent_layers():
    """Prompt prefill with recurrent (mamba) blocks: pad tokens must not
    leak into the installed per-slot state."""
    from repro.models.ssm import MambaSpec
    from repro.models.transformer import ModelConfig, init_params as ip
    from repro.serve.scheduler import Scheduler
    cfg = ModelConfig(name="hyb-serve", d_model=32, n_layers=2, n_heads=2,
                      n_kv_heads=2, d_ff=64, vocab=97, head_dim=16,
                      mlp="swiglu", block_pattern=("attn", "mamba"),
                      window_pattern=(None, None),
                      moe_pattern=(False, False),
                      mamba=MambaSpec(d_model=32), scan_layers=True,
                      kernel_impl="ref", remat="none")
    params = ip(cfg, jax.random.key(8))
    prompt = [3, 7, 11, 15, 19, 23]          # 5 prefilled, not a page mult
    sched = Scheduler(cfg, params, slots=1, max_len=32, page_size=16)
    s = sched.add_request(prompt)
    sched.step()
    want = _forced_feed_logits(cfg, params, prompt, 32, 16)
    np.testing.assert_allclose(
        np.asarray(sched.last_logits[s], np.float32),
        np.asarray(want, np.float32), rtol=2e-4, atol=2e-4)


def test_admission_refused_when_pool_exhausted():
    cfg, server = _server(slots=3, max_len=32, num_pages=2)
    server.add_request(5)          # needs 1 page + 1 headroom
    with pytest.raises(RuntimeError, match="page pool exhausted"):
        server.add_request(7)


def test_sampling_seeded_and_topk1_is_greedy():
    cfg, greedy = _server(slots=1)
    _, topk1 = _server(slots=1, temperature=0.7, top_k=1, seed=3)
    _, a = _server(slots=1, temperature=0.9, top_k=8, seed=11)
    _, b = _server(slots=1, temperature=0.9, top_k=8, seed=11)
    for srv in (greedy, topk1, a, b):
        srv.add_request(23)
    for _ in range(5):
        tg, t1 = greedy.step()[0], topk1.step()[0]
        assert tg == t1                    # top-1 degenerates to argmax
        assert a.step()[0] == b.step()[0]  # same seed, same stream


def test_plan_cache_stats_counters():
    from repro import vx
    c = vx.PlanCache(maxsize=2)
    assert c.stats() == {"size": 0, "hits": 0, "misses": 0,
                         "evictions": 0, "maxsize": 2}
    c.get(("a",), lambda: 1)
    c.get(("a",), lambda: 1)
    c.get(("b",), lambda: 2)
    c.get(("c",), lambda: 3)            # evicts ("a",)
    s = c.stats()
    assert s["hits"] == 1 and s["misses"] == 3 and s["evictions"] == 1
    assert ("a",) not in c and ("c",) in c


def test_finish_on_idle_slot_returns_empty():
    """Regression: finishing an already-idle slot used to hand back the
    PREVIOUS occupant's stale token list (and never clear it)."""
    cfg, server = _server(slots=2)
    s = server.add_request(5)
    server.step()
    first = server.finish(s)
    assert len(first) == 2                 # prompt + 1 generated
    assert server.finish(s) == []          # double finish: nothing stale
    assert server.tokens[s] == []          # per-slot list actually cleared
    assert server.finish(1) == []          # never-admitted slot too


def test_sampling_knobs_validated_at_construction():
    """Regression: top_k=0 silently masked EVERY logit to -inf and a
    negative temperature inverted the distribution — both must fail at
    construction with a clear error."""
    with pytest.raises(ValueError, match="top_k"):
        _server(slots=1, top_k=0)
    with pytest.raises(ValueError, match="temperature"):
        _server(slots=1, temperature=-1.0)


def test_admission_error_is_typed_with_retry_after():
    """Pool exhaustion now raises the typed AdmissionError (still a
    RuntimeError for old callers) carrying a retry-after hint."""
    from repro.serve.lifecycle import AdmissionError
    cfg, server = _server(slots=3, max_len=32, num_pages=2)
    server.add_request(5)
    with pytest.raises(AdmissionError, match="page pool exhausted") as ei:
        server.add_request(7)
    assert ei.value.retry_after >= 0.0
