"""Serving engine: continuous batching, interleaved KV cache behaviour."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.models.transformer import init_params
from repro.serve.engine import BatchedServer


def _server(slots=3, max_len=32):
    cfg = get_arch("qwen3-0.6b").smoke
    params = init_params(cfg, jax.random.key(0))
    return cfg, BatchedServer(cfg, params, slots=slots, max_len=max_len)


def test_continuous_batching_slots():
    cfg, server = _server()
    s0 = server.add_request(5)
    s1 = server.add_request(7)
    assert {s0, s1} == {0, 1}
    for _ in range(3):
        toks = server.step()
    assert all(t >= 0 for i, t in enumerate(toks) if i in (s0, s1))
    out0 = server.finish(s0)
    assert len(out0) == 4  # prompt + 3 generated
    # slot reuse after finish
    s2 = server.add_request(9)
    assert s2 == s0


def test_greedy_decode_is_deterministic():
    cfg, server_a = _server()
    _, server_b = _server()
    sa = server_a.add_request(11)
    sb = server_b.add_request(11)
    for _ in range(5):
        server_a.step()
        server_b.step()
    assert server_a.finish(sa) == server_b.finish(sb)


def test_isolated_slots_do_not_interact():
    """A request's tokens must not depend on other slots' contents."""
    cfg, server_a = _server()
    sa = server_a.add_request(13)
    for _ in range(4):
        server_a.step()
    solo = server_a.finish(sa)

    _, server_b = _server()
    server_b.add_request(99)     # a different request in slot 0
    sb = server_b.add_request(13)
    for _ in range(4):
        server_b.step()
    shared = server_b.finish(sb)
    assert solo[1:] == shared[1:], (solo, shared)


def test_cache_len_tracks_steps():
    cfg, server = _server()
    server.add_request(3)
    assert int(server.cache["len"]) == 0
    server.step()
    server.step()
    assert int(server.cache["len"]) == 2


def test_plan_cache_zero_steady_state_misses():
    """Repeated decode steps must never miss the plan cache after warmup:
    a steady-state miss means an unstable cache key is silently
    recompiling plans (or re-lowering programs) every step."""
    from repro import vx
    cfg, server = _server()
    server.add_request(5)
    server.step()                       # warmup: traces + compiles plans
    warm = vx.PLANS.stats()
    for _ in range(4):
        server.step()
    steady = vx.PLANS.stats()
    assert steady["misses"] == warm["misses"], (warm, steady)
    assert steady["evictions"] == warm["evictions"], (warm, steady)


def test_plan_cache_stats_counters():
    from repro import vx
    c = vx.PlanCache(maxsize=2)
    assert c.stats() == {"size": 0, "hits": 0, "misses": 0,
                         "evictions": 0, "maxsize": 2}
    c.get(("a",), lambda: 1)
    c.get(("a",), lambda: 1)
    c.get(("b",), lambda: 2)
    c.get(("c",), lambda: 3)            # evicts ("a",)
    s = c.stats()
    assert s["hits"] == 1 and s["misses"] == 3 and s["evictions"] == 1
    assert ("a",) not in c and ("c",) in c
