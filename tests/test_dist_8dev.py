"""Distributed semantics on 8 fake devices (subprocess-isolated so the rest
of the suite keeps seeing 1 device, per the dry-run contract)."""
import os
import subprocess
import sys

import pytest

CHECKS = ["moe_ep_equivalence", "sharded_train_step",
          "pipeline_equivalence", "elastic_reshard", "seq_parallel_decode",
          "longctx_fused_decode", "longctx_launch_gate",
          "sharded_vx_property", "paged_pool_shard",
          "quantized_pool_shard"]


@pytest.mark.parametrize("check", CHECKS)
def test_dist_check(check):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env["PYTHONPATH"] = os.path.join(root, "src") + os.pathsep + root
    r = subprocess.run(
        [sys.executable, os.path.join(root, "tests", "_dist_checks.py"),
         check],
        capture_output=True, text=True, timeout=600, env=env, cwd=root)
    assert r.returncode == 0, f"{check}:\n{r.stdout[-2000:]}\n{r.stderr[-3000:]}"
    assert "CHECK_OK" in r.stdout
