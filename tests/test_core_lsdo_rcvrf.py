"""LSDO planner + RCVRF layout invariants (unit + Hypothesis property)."""
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # container without hypothesis: deterministic fallback
    import os, sys
    sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
    from _hypcompat import given, settings, strategies as st

from repro.core import lsdo, rcvrf

settings.register_profile("fast2", max_examples=60, deadline=None)
settings.load_profile("fast2")


# ----------------------------- LSDO -----------------------------------------

@given(st.integers(0, 40), st.integers(-12, 12), st.integers(1, 24),
       st.sampled_from([16, 32, 64]))
def test_lsdo_plan_and_load_exact(base, stride, vl, mlen):
    if stride == 0:
        stride = 1
    lo = base + min(0, (vl - 1) * stride)
    hi = base + max(0, (vl - 1) * stride)
    if lo < 0 or hi >= 512 - mlen:
        return
    buf = jnp.arange(512, dtype=jnp.float32) * 3 + 2
    plan = lsdo.plan_strided(base, stride, vl, mlen)
    out = np.asarray(lsdo.load_strided(buf, plan))
    want = np.array([(base + i * stride) * 3 + 2 for i in range(vl)],
                    dtype=np.float32)
    np.testing.assert_array_equal(out, want)


@given(st.integers(0, 40), st.integers(-12, 12), st.integers(1, 24),
       st.sampled_from([16, 32, 64]))
def test_lsdo_store_then_load_roundtrip(base, stride, vl, mlen):
    if stride == 0:
        stride = 1
    lo = base + min(0, (vl - 1) * stride)
    hi = base + max(0, (vl - 1) * stride)
    if lo < 0 or hi >= 512 - mlen:
        return
    # strided elements must be distinct addresses
    vals = jnp.arange(1, vl + 1, dtype=jnp.float32) * 11
    plan = lsdo.plan_strided(base, stride, vl, mlen)
    buf = lsdo.store_strided(jnp.zeros(512), vals, plan)
    out = np.asarray(lsdo.load_strided(buf, plan))
    np.testing.assert_array_equal(out, np.asarray(vals))


@given(st.integers(0, 100), st.integers(1, 20), st.integers(1, 32),
       st.sampled_from([16, 32, 64, 128]))
def test_lsdo_transaction_count_optimal(base, stride, vl, mlen):
    """Coalescing is optimal: #transactions == #distinct aligned regions."""
    plan = lsdo.plan_strided(base, stride, vl, mlen)
    regions = {(base + i * stride) // mlen for i in range(vl)}
    assert plan.num_transactions == len(regions)
    assert plan.coalescing_factor == vl / len(regions)


def test_lsdo_paper_headline_case():
    """EARTH §3.1: 32 x 1-elem stride-2 loads within one 64-elem region -> 1."""
    plan = lsdo.plan_strided(0, 2, 32, 64)
    assert plan.num_transactions == 1
    assert plan.element_wise_transactions == 32


def test_lsdo_segment_planning():
    plans = lsdo.plan_segment_unit(base=0, fields=4, vl=16, mlen=64)
    co, ew = lsdo.transactions_saved(plans)
    assert ew == 64
    assert co == 4  # each field covers 64 elems = exactly one region


# ----------------------------- RCVRF ----------------------------------------

SPEC = rcvrf.VRFSpec(vlen=256, elen=64, n_regs=32, n_banks=8, elem_bits=8)


def test_mapping_bijective():
    seen = set()
    for reg in range(SPEC.n_regs):
        for blk in range(SPEC.blocks_per_reg):
            loc = rcvrf.locate(SPEC, reg, blk)
            assert loc not in seen
            seen.add(loc)
    assert len(seen) == SPEC.n_regs * SPEC.blocks_per_reg


def test_paper_figure9_placement():
    # VREG0 -> Row0 Banks0..3 ; VREG4 -> Row4 Banks4..7 ; VREG8 -> Row4 Banks0..3
    assert [rcvrf.bank_of(SPEC, 0, j) for j in range(4)] == [0, 1, 2, 3]
    assert rcvrf.row_of(SPEC, 0, 0) == 0
    assert [rcvrf.bank_of(SPEC, 4, j) for j in range(4)] == [4, 5, 6, 7]
    assert rcvrf.row_of(SPEC, 4, 0) == 4
    assert rcvrf.row_of(SPEC, 8, 0) == 4
    assert [rcvrf.bank_of(SPEC, 8, j) for j in range(4)] == [0, 1, 2, 3]


@given(st.integers(0, 31))
def test_row_access_conflict_free(reg):
    banks = [rcvrf.bank_of(SPEC, reg, j) for j in range(SPEC.blocks_per_reg)]
    assert len(set(banks)) == len(banks)


@given(st.integers(0, 24), st.integers(0, 3), st.integers(1, 8))
def test_column_access_conflict_free(base, block, count):
    assert rcvrf.column_banks_distinct(SPEC, base, block, count)


@given(st.integers(0, 31))
def test_row_roundtrip(reg):
    vrf = rcvrf.empty_vrf(SPEC)
    data = (jnp.arange(32, dtype=jnp.uint8) * 5 + reg).astype(jnp.uint8)
    vrf = rcvrf.write_row(SPEC, vrf, reg, data)
    out = rcvrf.read_row(SPEC, vrf, reg)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(data))


def test_rows_do_not_clobber_each_other():
    vrf = rcvrf.empty_vrf(SPEC)
    datas = {}
    for reg in range(SPEC.n_regs):
        d = (jnp.arange(32, dtype=jnp.uint8) + 7 * reg).astype(jnp.uint8)
        vrf = rcvrf.write_row(SPEC, vrf, reg, d)
        datas[reg] = d
    for reg in range(SPEC.n_regs):
        np.testing.assert_array_equal(np.asarray(rcvrf.read_row(SPEC, vrf, reg)),
                                      np.asarray(datas[reg]))


@given(st.integers(0, 3), st.integers(0, 7), st.integers(1, 8))
def test_column_read_matches_rows(block, byte, count):
    vrf = rcvrf.empty_vrf(SPEC)
    base = 0
    rows = {}
    for i in range(count):
        d = (jnp.arange(32, dtype=jnp.uint8) * 3 + 11 * i).astype(jnp.uint8)
        vrf = rcvrf.write_row(SPEC, vrf, base + i, d)
        rows[i] = np.asarray(d)
    col = np.asarray(rcvrf.read_column(SPEC, vrf, base, block, byte, count))
    for i in range(count):
        assert col[i] == rows[i][block * SPEC.elems_per_block + byte]


@given(st.integers(0, 3), st.integers(0, 7), st.integers(1, 8))
def test_column_write_then_row_read(block, byte, count):
    """Segment-load beat: column write lands in the right register bytes."""
    vrf = rcvrf.empty_vrf(SPEC)
    vals = (jnp.arange(count, dtype=jnp.uint8) + 100).astype(jnp.uint8)
    vrf = rcvrf.write_column(SPEC, vrf, 0, block, byte, vals)
    for i in range(count):
        row = np.asarray(rcvrf.read_row(SPEC, vrf, i))
        assert row[block * SPEC.elems_per_block + byte] == 100 + i


def test_vrf_specs_other_geometries():
    for spec in [rcvrf.VRFSpec(vlen=512, elen=64, n_regs=32, n_banks=8),
                 rcvrf.VRFSpec(vlen=128, elen=32, n_regs=32, n_banks=8,
                               elem_bits=8)]:
        seen = set()
        for reg in range(spec.n_regs):
            for blk in range(spec.blocks_per_reg):
                loc = rcvrf.locate(spec, reg, blk)
                assert loc not in seen, (spec, reg, blk)
                seen.add(loc)
