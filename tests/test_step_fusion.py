"""Whole-step access fusion (core/accessfuse.py) — equivalence and the
launch-count regression gate.

The gate is jaxpr-level (jax.make_jaxpr): the fused decode step must issue
at least 2x fewer pallas kernel launches AND 2x fewer mask operands than
the per-access path for a 4-layer step.  No timing — CI-stable.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import vx
from repro.core import accessfuse, scg, shiftnet
from repro.models import decode as dec
from repro.models.transformer import ModelConfig, init_params


def _cfg(layers=4, hd=64, scan=False, impl="pallas", mlp="none", d_ff=0):
    return ModelConfig(
        name="fuse-test", d_model=2 * hd, n_layers=layers, n_heads=2,
        n_kv_heads=2, d_ff=d_ff, vocab=97, head_dim=hd, mlp=mlp,
        scan_layers=scan, kernel_impl=impl, remat="none")


# ---------------------------------------------------------------------------
# Scheduler: grouping, one launch, one concatenated mask operand
# ---------------------------------------------------------------------------

def test_scheduler_merges_same_shape_group_into_one_launch():
    # 64*512 = 32768 elements each: above MIN_FUSED_ELEMS, stays pallas
    arrays = [jnp.arange(64 * 512, dtype=jnp.float32).reshape(64, 512) + i
              for i in range(4)]

    def fused(*xs):
        # platform_policy off: exercise the merged KERNEL lowering (the
        # TPU decision) in interpret mode so launches are countable
        pairs = accessfuse.fuse_deinterleave(list(xs), 2, impl="pallas",
                                             platform_policy=False)
        return [f for pair in pairs for f in pair]

    def per_access(*xs):
        return [f for x in xs
                for f in vx.transpose(vx.Segment(n=x.shape[-1], fields=2),
                                      x, policy="pallas")]

    lf, mf = accessfuse.jaxpr_access_counts(fused, *arrays)
    lp, mp = accessfuse.jaxpr_access_counts(per_access, *arrays)
    assert lf == 1 and lp == 4, (lf, lp)
    assert mf == 1 and mp == 4, (mf, mp)
    got = jax.jit(fused)(*arrays)
    want = [f for x in arrays
            for f in vx.transpose(vx.Segment(n=x.shape[-1], fields=2), x,
                                  policy="ref")]
    for g, w in zip(got, want):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))


def test_scheduler_inlines_tiny_groups():
    tiny = [jnp.arange(16, dtype=jnp.float32).reshape(2, 8)] * 2
    launches, _ = accessfuse.jaxpr_access_counts(
        lambda *xs: accessfuse.fuse_deinterleave(list(xs), 2,
                                                 impl="pallas")[0],
        *tiny)
    assert launches == 0        # below MIN_FUSED_ELEMS -> XLA path


def test_scheduler_interleave_and_heterogeneous_gather():
    parts = [[jnp.arange(32, dtype=jnp.float32) + 10 * a,
              jnp.arange(32, dtype=jnp.float32) + 100 * a]
             for a in range(3)]
    outs = accessfuse.fuse_interleave(parts, impl="ref")
    for a, out in enumerate(outs):
        want = vx.transpose(vx.Segment(n=64, fields=2), parts[a],
                            policy="ref")
        np.testing.assert_array_equal(np.asarray(out), np.asarray(want))

    # same (shape, vl), different (stride, offset): single fused kernel
    wins = [jnp.arange(8 * 64, dtype=jnp.float32).reshape(8, 64) * (a + 1)
            for a in range(3)]
    specs = [(2, 0), (3, 1), (1, 5)]
    sched = accessfuse.StepScheduler(impl="pallas", platform_policy=False)
    hs = [sched.gather_strided(w, s, o, 16)
          for w, (s, o) in zip(wins, specs)]
    sched.flush()
    for h, w, (s, o) in zip(hs, wins, specs):
        want = vx.gather(vx.Strided(n=64, stride=s, offset=o, vl=16), w,
                         policy="ref")
        np.testing.assert_array_equal(np.asarray(h.value), np.asarray(want))


# ---------------------------------------------------------------------------
# Fused decode step: bit-exact with the per-access oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("scan", (False, True))
@pytest.mark.parametrize("impl", ("ref", "pallas"))
def test_fused_decode_matches_per_access(scan, impl):
    cfg = _cfg(layers=4, hd=16, scan=scan, impl=impl, mlp="swiglu", d_ff=64)
    params = init_params(cfg, jax.random.key(0))
    cache = dec.init_cache(cfg, 2, 16, jnp.float32)
    tok = jnp.array([3, 5], jnp.int32)
    fused = jax.jit(lambda p, c, t: dec.decode_step(p, c, t, cfg, None,
                                                    fuse=True))
    per = jax.jit(lambda p, c, t: dec.decode_step(p, c, t, cfg, None,
                                                  fuse=False))
    cf, cp = cache, cache
    for _ in range(3):      # several steps: append slot walks the ring
        lf, cf = fused(params, cf, tok)
        lp, cp = per(params, cp, tok)
        np.testing.assert_array_equal(np.asarray(lf), np.asarray(lp))
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), cf, cp)


def test_decode_launch_count_regression_gate():
    """CI gate: fused 4-layer decode step issues >= 2x fewer kernel
    launches and mask operands than the per-access compiled path."""
    cfg = _cfg(layers=4, hd=64, scan=False, impl="pallas")
    params = init_params(cfg, jax.random.key(0))
    cache = dec.init_cache(cfg, 2, 64, jnp.float32)
    tok = jnp.array([3, 5], jnp.int32)

    def fused(p, c, t):
        return dec.decode_step(p, c, t, cfg, None, fuse=True)

    def per_access(p, c, t):
        return dec.decode_step(p, c, t, cfg, None, fuse=False)

    # pin the TPU lowering decision so the merged group is countable as a
    # kernel launch even in interpret mode (the default platform policy
    # would inline it on the XLA path here, giving 0 launches)
    with accessfuse.pinned_kernel_lowering():
        lf, mf = accessfuse.jaxpr_access_counts(fused, params, cache, tok)
    lp, mp = accessfuse.jaxpr_access_counts(per_access, params, cache, tok)
    assert lf == 1 and mf == 1, (lf, mf)
    assert lp >= 4 and mp >= 4, (lp, mp)
    assert 2 * lf <= lp, (lf, lp)
    assert 2 * mf <= mp, (mf, mp)


# ---------------------------------------------------------------------------
# Plan bank (lax.switch) vs dynamic oracle — see also
# tests/test_property_shiftnet.py for the stride sweep
# ---------------------------------------------------------------------------

def test_bank_dispatch_under_jit_has_no_dynamic_cost_on_banked_path():
    # the switch carries ONE dynamic-fallback branch; banked branches use
    # compiled plans (constant masks -> no shiftcnt arithmetic operands)
    n, offset, vl = 128, 32, 8
    win = jnp.broadcast_to(jnp.arange(n, dtype=jnp.float32), (4, n))
    out = jax.jit(lambda w, s: accessfuse.bank_gather_strided(
        w, s, offset, vl))(win, jnp.int32(3))
    want = np.arange(n, dtype=np.float32)[offset + 3 * np.arange(vl)]
    np.testing.assert_array_equal(np.asarray(out),
                                  np.broadcast_to(want, (4, vl)))


# ---------------------------------------------------------------------------
# MoE compaction through the bank's runtime-count path
# ---------------------------------------------------------------------------

def test_compact_indices_matches_dynamic_network():
    rng = np.random.default_rng(0)
    for n in (8, 64, 128):
        for _ in range(5):
            mask = jnp.asarray(rng.random(n) < 0.4)
            ids = jnp.arange(n, dtype=jnp.int32)
            shift, valid = scg.compaction_counts(mask)
            res = shiftnet.gather_network(ids, shift, valid)
            want = np.asarray(res.payload)
            got = np.asarray(accessfuse.compact_indices(mask, n))
            total = int(np.asarray(mask).sum())
            np.testing.assert_array_equal(got[:total], want[:total])


def test_moe_earth_dispatch_still_matches_argsort():
    from repro.models.moe import MoESpec, init_moe, moe_ffn_local
    d, E, k, T = 32, 4, 2, 64
    x = jax.random.normal(jax.random.key(1), (T, d))
    params = init_moe(jax.random.key(0), d,
                      MoESpec(n_experts=E, top_k=k, d_ff=64), jnp.float32)

    def run(dispatch):
        spec = MoESpec(n_experts=E, top_k=k, d_ff=64, dispatch=dispatch)
        return moe_ffn_local(params["router"], params["wg"], params["wu"],
                             params["wo"], x, spec, model_axis=None,
                             data_axes=(), n_shards=1)[0]

    np.testing.assert_allclose(np.asarray(run("earth")),
                               np.asarray(run("sort")), rtol=2e-5,
                               atol=2e-5)


# ---------------------------------------------------------------------------
# Input pipeline: pack+unpack elision (plan composition = identity)
# ---------------------------------------------------------------------------

def test_segment_roundtrip_plans_compose_to_identity():
    from repro.core import shiftplan
    for fields in (2, 4):
        n = 32 * fields
        ipl = shiftplan.interleave_plan(n, fields)
        dpl = shiftplan.deinterleave_plan(n, fields)
        x = np.arange(max(ipl.n, dpl.n))
        mid = shiftplan.apply_np(ipl, x[:ipl.n])[:n]
        back = shiftplan.apply_np(dpl, np.pad(mid, (0, dpl.n - n)))[:n]
        np.testing.assert_array_equal(back, x[:n])


def test_pipeline_fused_bit_exact_and_same_state():
    from repro.data.pipeline import DataConfig, SyntheticAoSPipeline
    cfg = DataConfig(vocab=101, seq_len=16, global_batch=4, seed=3)
    a = SyntheticAoSPipeline(cfg, process_index=1, process_count=2)
    b = SyntheticAoSPipeline(cfg, process_index=1, process_count=2)
    for _ in range(3):
        ba = a.next_batch(fused=True)
        bb = b.next_batch(fused=False)
        assert set(ba) == set(bb)
        for key in ba:
            np.testing.assert_array_equal(np.asarray(ba[key]),
                                          np.asarray(bb[key]))
    assert a.state_dict() == b.state_dict()


def test_pack_unpack_fused_matches_roundtrip():
    from repro.data import aos
    rng = np.random.default_rng(2)
    toks = jnp.asarray(rng.integers(0, 50, (2, 8), dtype=np.int32))
    labels = jnp.asarray(rng.integers(0, 50, (2, 8), dtype=np.int32))
    weights = jnp.asarray(rng.random((2, 8), dtype=np.float32))
    docs = jnp.asarray(rng.integers(0, 9, (2, 8), dtype=np.int32))
    want = aos.unpack_records(aos.pack_records(toks, labels, weights, docs))
    got = aos.pack_unpack_fused(toks, labels, weights, docs)
    for key in want:
        np.testing.assert_array_equal(np.asarray(got[key]),
                                      np.asarray(want[key]))


# ---------------------------------------------------------------------------
# Whole-step LSDO (multi-access super-transaction)
# ---------------------------------------------------------------------------

def test_load_strided_many_matches_per_access():
    from repro.core import lsdo
    buf = jnp.arange(4096, dtype=jnp.float32) * 5 + 3
    plans = [lsdo.plan_strided(0, 2, 64, 128),
             lsdo.plan_strided(7, 3, 40, 128),
             lsdo.plan_strided(513, 4, 32, 128),
             lsdo.plan_strided(1, -4, 50, 128),
             lsdo.plan_strided(9, 0, 0, 128)]      # vl=0 edge
    outs = lsdo.load_strided_many(buf, plans)
    for p, o in zip(plans, outs):
        want = lsdo.load_strided(buf, p, batched=False) if p.vl > 0 \
            else np.zeros((0,), np.float32)
        np.testing.assert_array_equal(np.asarray(o), np.asarray(want))
