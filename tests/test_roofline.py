"""Roofline extraction: HLO collective parser, cost arithmetic, 6ND model."""
import jax
import jax.numpy as jnp
import pytest

from repro import hw
from repro.roofline.analysis import (CostBundle, collective_bytes,
                                     model_flops, roofline)

HLO = """
  %ag = bf16[32,256]{1,0} all-gather(%p0), dimensions={0}
  %ar = f32[1024]{0} all-reduce(%p1), to_apply=%sum
  %rs = f32[16,64]{1,0} reduce-scatter(%p2), dimensions={0}
  %aa = bf16[8,128]{1,0} all-to-all(%p3), dimensions={0}
  %cp = f32[4,4]{1,0} collective-permute(%p4), source_target_pairs={{0,1}}
  %ars = (f32[256]{0}, f32[128]{0}) all-reduce(%p5, %p6), to_apply=%sum
  %unrelated = f32[999]{0} add(%p7, %p8)
  %async = f32[512]{0} all-gather-start(%p9), dimensions={0}
"""


def test_collective_parser_counts_each_kind():
    got = collective_bytes(HLO)
    assert got["all-gather"] == 32 * 256 * 2 + 512 * 4  # incl. async start
    assert got["all-reduce"] == (1024 * 4 + 256 * 4 + 128 * 4) * 2  # 2x wire
    assert got["reduce-scatter"] == 16 * 64 * 4
    assert got["all-to-all"] == 8 * 128 * 2
    assert got["collective-permute"] == 4 * 4 * 4


def test_bundle_arithmetic():
    a = CostBundle(10.0, 100.0, 5.0, {"all-reduce": 5.0})
    b = CostBundle(4.0, 40.0, 2.0, {"all-reduce": 2.0})
    body = a - b
    assert body.flops == 6.0
    tot = b.scaled_add(body, 3)
    assert tot.flops == 4.0 + 18.0
    assert tot.coll_breakdown["all-reduce"] == 2.0 + 9.0


def test_roofline_terms_and_dominant():
    chip = hw.TPU_V5E
    b = CostBundle(flops=chip.peak_bf16_flops,        # 1 s compute
                   bytes_accessed=chip.hbm_bandwidth * 2,   # 2 s memory
                   coll_bytes=chip.ici_link_bandwidth * 0.5,
                   coll_breakdown={})
    t = roofline(b, chips=256, model_flops=chip.peak_bf16_flops * 128)
    assert t.dominant == "memory"
    assert abs(t.compute_s - 1.0) < 1e-9
    assert abs(t.memory_s - 2.0) < 1e-9
    assert abs(t.collective_s - 0.5) < 1e-9
    assert abs(t.useful_ratio - 0.5) < 1e-9


def test_model_flops_moe_counts_active_only():
    from repro.configs import get_arch
    dense = get_arch("qwen3-0.6b").smoke
    moe = get_arch("qwen3-moe-30b-a3b").smoke
    f_dense = model_flops(dense, tokens=1000, kind="train")
    f_moe_train = model_flops(moe, tokens=1000, kind="train")
    f_moe_serve = model_flops(moe, tokens=1000, kind="serve")
    assert f_dense > 0 and f_moe_train > 0
    assert abs(f_moe_train / f_moe_serve - 3.0) < 1e-6  # 6ND vs 2ND
    # active params exclude (1 - top_k/E) of expert weights
    from repro.roofline.analysis import active_param_count
    n_active = active_param_count(moe)
    import math
    total = 0
    shapes = jax.eval_shape(
        lambda: __import__("repro.models.transformer",
                           fromlist=["init_params"]).init_params(
                               moe, jax.random.key(0)))
    total = sum(math.prod(s.shape) for s in jax.tree.leaves(shapes))
    assert n_active < total


def test_hw_constants_match_assignment():
    assert hw.TPU_V5E.peak_bf16_flops == 197e12
    assert hw.TPU_V5E.hbm_bandwidth == 819e9
    assert hw.TPU_V5E.ici_link_bandwidth == 50e9
