"""Integration: full train loop (CLI path) with checkpoint resume."""
import os
import subprocess
import sys


def _run(args, timeout=600):
    env = dict(os.environ)
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env["PYTHONPATH"] = os.path.join(root, "src")
    return subprocess.run([sys.executable, "-m"] + args, env=env, cwd=root,
                          capture_output=True, text=True, timeout=timeout)


def test_train_cli_runs_and_learns(tmp_path):
    r = _run(["repro.launch.train", "--arch", "qwen3-0.6b", "--smoke",
              "--steps", "12", "--batch", "4", "--seq", "64",
              "--lr", "1e-3", "--ckpt-dir", str(tmp_path),
              "--ckpt-every", "6"])
    assert r.returncode == 0, r.stderr[-2000:]
    assert "done; final loss" in r.stdout
    # checkpoints written
    assert any(d.startswith("step_") for d in os.listdir(tmp_path))


def test_train_cli_resume(tmp_path):
    r1 = _run(["repro.launch.train", "--arch", "qwen3-0.6b", "--smoke",
               "--steps", "6", "--batch", "4", "--seq", "64",
               "--ckpt-dir", str(tmp_path), "--ckpt-every", "3"])
    assert r1.returncode == 0, r1.stderr[-2000:]
    r2 = _run(["repro.launch.train", "--arch", "qwen3-0.6b", "--smoke",
               "--steps", "9", "--batch", "4", "--seq", "64",
               "--ckpt-dir", str(tmp_path), "--ckpt-every", "3",
               "--resume"])
    assert r2.returncode == 0, r2.stderr[-2000:]
    assert "resumed from step 6" in r2.stdout


def test_serve_cli(tmp_path):
    r = _run(["repro.launch.serve", "--arch", "qwen3-0.6b", "--smoke",
              "--requests", "2", "--prompt-len", "4", "--gen", "4",
              "--max-len", "32"])
    assert r.returncode == 0, r.stderr[-2000:]
    assert "tok/s" in r.stdout
