"""Hypothesis property tests for the EARTH shift-network invariants.

The paper's §4.1.4 conflict-free theorem states that the networks route
without collision exactly when the mapping is order-preserving and
separation-monotone. We generate random legal mappings and assert:
  * no conflict flag at any layer,
  * every valid element lands at its target,
  * gather(scatter(x)) round-trips.
"""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import scg, shiftnet

settings.register_profile("fast", max_examples=60, deadline=None)
settings.load_profile("fast")


@st.composite
def monotone_gather_map(draw, n=64):
    """Random order-preserving, separation-non-increasing mapping.

    Build target positions first (sorted unique), then source positions with
    pairwise separations >= target separations (guarantees the gather
    precondition, incl. shift >= 0 for all elements).
    """
    k = draw(st.integers(min_value=1, max_value=n // 2))
    targets = sorted(draw(st.sets(st.integers(0, n - 1), min_size=k,
                                  max_size=k)))
    sources = [draw(st.integers(targets[0], n - 1 - sum(
        max(targets[i + 1] - targets[i], 1) for i in range(len(targets) - 1))
        if len(targets) > 1 else n - 1))]
    for i in range(1, len(targets)):
        gap_t = targets[i] - targets[i - 1]
        lo = sources[-1] + gap_t
        hi = n - 1
        if lo > hi:
            return ((), ())  # cannot extend without violating separation
        sources.append(draw(st.integers(lo, hi)))
    # enforce shift >= 0 and in-range
    ok = all(s >= t and s < n for s, t in zip(sources, targets))
    return (sources, targets) if ok else ((), ())


@given(monotone_gather_map())
def test_gather_conflict_free_and_exact(mapping):
    sources, targets = mapping
    if not sources:
        return
    n = 64
    payload = jnp.zeros((n,), jnp.int32)
    shift = jnp.zeros((n,), jnp.int32)
    valid = jnp.zeros((n,), bool)
    for s, t in zip(sources, targets):
        payload = payload.at[s].set(1000 + s)
        shift = shift.at[s].set(s - t)
        valid = valid.at[s].set(True)
    res = shiftnet.gather_network(payload, shift, valid)
    assert not bool(res.conflict), (sources, targets)
    out = np.asarray(res.payload)
    vmask = np.asarray(res.valid)
    for s, t in zip(sources, targets):
        assert vmask[t]
        assert out[t] == 1000 + s
    assert vmask.sum() == len(sources)


@st.composite
def monotone_scatter_map(draw, n=64):
    """Order-preserving, separation-non-decreasing mapping (scatter legal)."""
    k = draw(st.integers(min_value=1, max_value=n // 2))
    sources = sorted(draw(st.sets(st.integers(0, n // 2 - 1), min_size=k,
                                  max_size=k)))
    targets = [draw(st.integers(sources[0], n - 1 - sum(
        max(sources[i + 1] - sources[i], 1) for i in range(len(sources) - 1))
        if len(sources) > 1 else n - 1))]
    for i in range(1, len(sources)):
        gap_s = sources[i] - sources[i - 1]
        lo = targets[-1] + gap_s
        if lo > n - 1:
            return ((), ())
        targets.append(draw(st.integers(lo, n - 1)))
    ok = all(t >= s for s, t in zip(sources, targets))
    return (sources, targets) if ok else ((), ())


@given(monotone_scatter_map())
def test_scatter_conflict_free_and_exact(mapping):
    sources, targets = mapping
    if not sources:
        return
    n = 64
    payload = jnp.zeros((n,), jnp.int32)
    shift = jnp.zeros((n,), jnp.int32)
    valid = jnp.zeros((n,), bool)
    for s, t in zip(sources, targets):
        payload = payload.at[s].set(1000 + s)
        shift = shift.at[s].set(t - s)
        valid = valid.at[s].set(True)
    res = shiftnet.scatter_network(payload, shift, valid)
    assert not bool(res.conflict), (sources, targets)
    out = np.asarray(res.payload)
    vmask = np.asarray(res.valid)
    for s, t in zip(sources, targets):
        assert vmask[t]
        assert out[t] == 1000 + s
    assert vmask.sum() == len(sources)


@given(st.integers(1, 16), st.integers(0, 15), st.integers(1, 10))
def test_strided_roundtrip(stride, offset, vl):
    """scatter(gather(window)) restores strided elements exactly."""
    n = 256
    if offset + (vl - 1) * stride + 1 > n:
        return
    window = jnp.arange(n, dtype=jnp.int32) * 7 + 1
    gs, gv = scg.gather_counts(n, stride, offset, vl)
    dense = shiftnet.gather_network(window, gs, gv)
    assert not bool(dense.conflict)
    ss, sv = scg.scatter_counts(n, stride, offset, vl)
    back = shiftnet.scatter_network(dense.payload, ss, sv)
    assert not bool(back.conflict)
    out = np.asarray(back.payload)
    for i in range(vl):
        p = offset + i * stride
        assert out[p] == p * 7 + 1


@given(st.lists(st.booleans(), min_size=1, max_size=128))
def test_compaction_conflict_free(bits):
    mask = jnp.array(bits, dtype=bool)
    n = mask.shape[0]
    data = jnp.arange(n, dtype=jnp.int32) + 1
    shift, valid = scg.compaction_counts(mask)
    res = shiftnet.gather_network(data, shift, valid)
    assert not bool(res.conflict)
    want = np.asarray(data)[np.asarray(mask)]
    got = np.asarray(res.payload)[: len(want)]
    np.testing.assert_array_equal(got, want)


@given(st.lists(st.booleans(), min_size=1, max_size=128))
def test_expansion_inverts_compaction(bits):
    mask = jnp.array(bits, dtype=bool)
    n = mask.shape[0]
    data = (jnp.arange(n, dtype=jnp.int32) + 1) * jnp.asarray(mask, jnp.int32)
    cs, cv = scg.compaction_counts(mask)
    packed = shiftnet.gather_network(data, cs, cv)
    es, ev = scg.expansion_counts(mask)
    restored = shiftnet.scatter_network(packed.payload, es, ev)
    assert not bool(restored.conflict)
    got = np.where(np.asarray(restored.valid), np.asarray(restored.payload), 0)
    np.testing.assert_array_equal(got, np.asarray(data))


@given(st.integers(2, 8), st.integers(1, 32))
def test_segment_field_extraction(fields, m):
    n = fields * m
    aos = jnp.arange(n, dtype=jnp.int32)
    for f in range(fields):
        shift, valid = scg.segment_gather_counts(n, fields, f, m)
        res = shiftnet.gather_network(aos, shift, valid)
        assert not bool(res.conflict)
        np.testing.assert_array_equal(np.asarray(res.payload)[:m],
                                      np.arange(m) * fields + f)
