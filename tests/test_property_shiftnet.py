"""Hypothesis property tests for the EARTH shift-network invariants.

The paper's §4.1.4 conflict-free theorem states that the networks route
without collision exactly when the mapping is order-preserving and
separation-monotone. We generate random legal mappings and assert:
  * no conflict flag at any layer,
  * every valid element lands at its target,
  * gather(scatter(x)) round-trips.
"""
import jax
import jax.numpy as jnp
import numpy as np
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # container without hypothesis: deterministic fallback
    import os, sys
    sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
    from _hypcompat import given, settings, strategies as st

from repro.core import scg, shiftnet

settings.register_profile("fast", max_examples=60, deadline=None)
settings.load_profile("fast")


@st.composite
def monotone_gather_map(draw, n=64):
    """Random order-preserving, separation-non-increasing mapping.

    Build target positions first (sorted unique), then source positions with
    pairwise separations >= target separations (guarantees the gather
    precondition, incl. shift >= 0 for all elements).
    """
    k = draw(st.integers(min_value=1, max_value=n // 2))
    targets = sorted(draw(st.sets(st.integers(0, n - 1), min_size=k,
                                  max_size=k)))
    sources = [draw(st.integers(targets[0], n - 1 - sum(
        max(targets[i + 1] - targets[i], 1) for i in range(len(targets) - 1))
        if len(targets) > 1 else n - 1))]
    for i in range(1, len(targets)):
        gap_t = targets[i] - targets[i - 1]
        lo = sources[-1] + gap_t
        hi = n - 1
        if lo > hi:
            return ((), ())  # cannot extend without violating separation
        sources.append(draw(st.integers(lo, hi)))
    # enforce shift >= 0 and in-range
    ok = all(s >= t and s < n for s, t in zip(sources, targets))
    return (sources, targets) if ok else ((), ())


@given(monotone_gather_map())
def test_gather_conflict_free_and_exact(mapping):
    sources, targets = mapping
    if not sources:
        return
    n = 64
    payload = jnp.zeros((n,), jnp.int32)
    shift = jnp.zeros((n,), jnp.int32)
    valid = jnp.zeros((n,), bool)
    for s, t in zip(sources, targets):
        payload = payload.at[s].set(1000 + s)
        shift = shift.at[s].set(s - t)
        valid = valid.at[s].set(True)
    res = shiftnet.gather_network(payload, shift, valid)
    assert not bool(res.conflict), (sources, targets)
    out = np.asarray(res.payload)
    vmask = np.asarray(res.valid)
    for s, t in zip(sources, targets):
        assert vmask[t]
        assert out[t] == 1000 + s
    assert vmask.sum() == len(sources)


@st.composite
def monotone_scatter_map(draw, n=64):
    """Order-preserving, separation-non-decreasing mapping (scatter legal)."""
    k = draw(st.integers(min_value=1, max_value=n // 2))
    sources = sorted(draw(st.sets(st.integers(0, n // 2 - 1), min_size=k,
                                  max_size=k)))
    targets = [draw(st.integers(sources[0], n - 1 - sum(
        max(sources[i + 1] - sources[i], 1) for i in range(len(sources) - 1))
        if len(sources) > 1 else n - 1))]
    for i in range(1, len(sources)):
        gap_s = sources[i] - sources[i - 1]
        lo = targets[-1] + gap_s
        if lo > n - 1:
            return ((), ())
        targets.append(draw(st.integers(lo, n - 1)))
    ok = all(t >= s for s, t in zip(sources, targets))
    return (sources, targets) if ok else ((), ())


@given(monotone_scatter_map())
def test_scatter_conflict_free_and_exact(mapping):
    sources, targets = mapping
    if not sources:
        return
    n = 64
    payload = jnp.zeros((n,), jnp.int32)
    shift = jnp.zeros((n,), jnp.int32)
    valid = jnp.zeros((n,), bool)
    for s, t in zip(sources, targets):
        payload = payload.at[s].set(1000 + s)
        shift = shift.at[s].set(t - s)
        valid = valid.at[s].set(True)
    res = shiftnet.scatter_network(payload, shift, valid)
    assert not bool(res.conflict), (sources, targets)
    out = np.asarray(res.payload)
    vmask = np.asarray(res.valid)
    for s, t in zip(sources, targets):
        assert vmask[t]
        assert out[t] == 1000 + s
    assert vmask.sum() == len(sources)


@given(st.integers(1, 16), st.integers(0, 15), st.integers(1, 10))
def test_strided_roundtrip(stride, offset, vl):
    """scatter(gather(window)) restores strided elements exactly."""
    n = 256
    if offset + (vl - 1) * stride + 1 > n:
        return
    window = jnp.arange(n, dtype=jnp.int32) * 7 + 1
    gs, gv = scg.gather_counts(n, stride, offset, vl)
    dense = shiftnet.gather_network(window, gs, gv)
    assert not bool(dense.conflict)
    ss, sv = scg.scatter_counts(n, stride, offset, vl)
    back = shiftnet.scatter_network(dense.payload, ss, sv)
    assert not bool(back.conflict)
    out = np.asarray(back.payload)
    for i in range(vl):
        p = offset + i * stride
        assert out[p] == p * 7 + 1


@given(st.lists(st.booleans(), min_size=1, max_size=128))
def test_compaction_conflict_free(bits):
    mask = jnp.array(bits, dtype=bool)
    n = mask.shape[0]
    data = jnp.arange(n, dtype=jnp.int32) + 1
    shift, valid = scg.compaction_counts(mask)
    res = shiftnet.gather_network(data, shift, valid)
    assert not bool(res.conflict)
    want = np.asarray(data)[np.asarray(mask)]
    got = np.asarray(res.payload)[: len(want)]
    np.testing.assert_array_equal(got, want)


@given(st.lists(st.booleans(), min_size=1, max_size=128))
def test_expansion_inverts_compaction(bits):
    mask = jnp.array(bits, dtype=bool)
    n = mask.shape[0]
    data = (jnp.arange(n, dtype=jnp.int32) + 1) * jnp.asarray(mask, jnp.int32)
    cs, cv = scg.compaction_counts(mask)
    packed = shiftnet.gather_network(data, cs, cv)
    es, ev = scg.expansion_counts(mask)
    restored = shiftnet.scatter_network(packed.payload, es, ev)
    assert not bool(restored.conflict)
    got = np.where(np.asarray(restored.valid), np.asarray(restored.payload), 0)
    np.testing.assert_array_equal(got, np.asarray(data))


@given(st.integers(2, 8), st.integers(1, 32))
def test_segment_field_extraction(fields, m):
    n = fields * m
    aos = jnp.arange(n, dtype=jnp.int32)
    for f in range(fields):
        shift, valid = scg.segment_gather_counts(n, fields, f, m)
        res = shiftnet.gather_network(aos, shift, valid)
        assert not bool(res.conflict)
        np.testing.assert_array_equal(np.asarray(res.payload)[:m],
                                      np.arange(m) * fields + f)


# ---------------------------------------------------------------------------
# Compiled static-plan path (core/shiftplan.py) vs the dynamic-count oracle.
# The dynamic network above IS the oracle; the compiled plans must match it
# exactly — payload, occupancy, and conflict flag — across strides/offsets/
# vl and all segment field counts.
# ---------------------------------------------------------------------------

import itertools

import pytest

from repro.core import lsdo, shiftplan

STRIDES = (1, 2, 3, 4, 7, 8, 16)


@pytest.mark.parametrize("stride", STRIDES)
@pytest.mark.parametrize("offset", (0, 1, 5))
@pytest.mark.parametrize("n", (64, 128))
def test_compiled_gather_matches_dynamic(stride, offset, n):
    vl = (n - 1 - offset) // stride + 1
    for v in {1, max(1, vl // 2), vl}:
        window = jnp.arange(n, dtype=jnp.int32) * 13 + 7
        shift, valid = scg.gather_counts(n, stride, offset, v)
        dyn = shiftnet.gather_network(window, shift, valid)
        plan = shiftplan.gather_plan(n, stride, offset, v)
        out = shiftnet.apply_plan(window, plan)
        # conflict parity: legal strided patterns are conflict-free on both
        assert not bool(dyn.conflict) and not plan.conflict
        np.testing.assert_array_equal(
            np.asarray(dyn.valid), plan.valid)
        np.testing.assert_array_equal(
            np.where(plan.valid, np.asarray(out), 0),
            np.where(np.asarray(dyn.valid), np.asarray(dyn.payload), 0))


@pytest.mark.parametrize("stride", STRIDES)
@pytest.mark.parametrize("offset", (0, 1, 5))
@pytest.mark.parametrize("n", (64, 128))
def test_compiled_scatter_matches_dynamic(stride, offset, n):
    vl = (n - 1 - offset) // stride + 1
    for v in {1, max(1, vl // 2), vl}:
        dense = jnp.arange(n, dtype=jnp.int32) * 3 + 1
        shift, valid = scg.scatter_counts(n, stride, offset, v)
        dyn = shiftnet.scatter_network(dense, shift, valid)
        plan = shiftplan.scatter_plan(n, stride, offset, v)
        out = shiftnet.apply_plan(dense, plan)
        assert not bool(dyn.conflict) and not plan.conflict
        np.testing.assert_array_equal(np.asarray(dyn.valid), plan.valid)
        np.testing.assert_array_equal(
            np.where(plan.valid, np.asarray(out), 0),
            np.where(np.asarray(dyn.valid), np.asarray(dyn.payload), 0))


@pytest.mark.parametrize("fields", (2, 3, 4, 5, 6, 7, 8))
def test_compiled_segment_matches_dynamic(fields):
    m = 32
    n = fields * m
    aos = jnp.arange(n, dtype=jnp.int32) + 100
    plan = shiftplan.deinterleave_plan(n, fields)
    x = jnp.pad(aos, (0, plan.n - n)) if plan.n > n else aos
    routed = np.asarray(shiftnet.apply_plan(x, plan))
    for f in range(fields):
        shift, valid = scg.segment_gather_counts(n, fields, f, m)
        dyn = shiftnet.gather_network(aos, shift, valid)
        assert not bool(dyn.conflict)
        np.testing.assert_array_equal(routed[f * m:(f + 1) * m],
                                      np.asarray(dyn.payload)[:m])
    # and the fused interleave inverts it
    ipl = shiftplan.interleave_plan(n, fields)
    soa = routed[:n]
    xi = np.pad(soa, (0, ipl.n - n)) if ipl.n > n else soa
    back = np.asarray(shiftnet.apply_plan(jnp.asarray(xi), ipl))[:n]
    np.testing.assert_array_equal(back, np.asarray(aos))


def test_stride2_gather_prunes_layers():
    """Acceptance: stride-2 gather over n=128 executes < log2(n) layers."""
    plan = shiftplan.gather_plan(128, 2, 0, 64)
    assert plan.total_layers == 7
    assert plan.active_layers < 7, plan.active_layers
    assert not plan.conflict


def test_single_transaction_patterns_need_few_layers():
    """Unit-stride windows route with ZERO active layers (identity);
    offset-only windows need exactly the popcount of the offset."""
    assert shiftplan.gather_plan(128, 1, 0, 128).active_layers == 0
    p = shiftplan.gather_plan(128, 1, 4, 64)
    assert p.active_layers == 1     # all elements shift by 4 = one bit
    p = shiftplan.gather_plan(128, 1, 5, 64)
    assert p.active_layers == 2     # shift 5 = bits 0 and 2


def test_batched_plan_matches_per_transaction():
    """The (T, mlen) batched LSDO plan equals the per-transaction loop."""
    buf = jnp.arange(1024, dtype=jnp.float32) * 5 + 3
    for base, stride, vl, mlen in [(0, 2, 64, 128), (7, 3, 40, 64),
                                   (5, 16, 30, 128), (1, -4, 50, 64),
                                   (3, 1, 100, 32)]:
        plan = lsdo.plan_strided(base, stride, vl, mlen)
        got = lsdo.load_strided(buf, plan)                  # batched
        want = lsdo.load_strided(buf, plan, batched=False)  # loop oracle
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
        vals = jnp.arange(1, vl + 1, dtype=jnp.float32)
        sb = lsdo.store_strided(jnp.zeros(1024), vals, plan)
        sl = lsdo.store_strided(jnp.zeros(1024), vals, plan, batched=False)
        np.testing.assert_array_equal(np.asarray(sb), np.asarray(sl))


def test_permutation_plan_random():
    """Benes fallback routes arbitrary permutations conflict-free."""
    rng = np.random.default_rng(0)
    for n in (8, 32, 57, 128):
        perm = rng.permutation(n)
        plan = shiftplan.permutation_plan(tuple(int(x) for x in perm))
        x = np.pad(np.arange(n), (0, plan.n - n))
        out = shiftplan.apply_np(plan, x)
        for src, dst in enumerate(perm):
            assert out[dst] == src
        assert plan.active_layers <= 2 * shiftplan.num_layers(plan.n) - 1


def test_compiled_counts_plan_matches_dynamic_counts():
    """Static host-side (shift, valid) through counts_plan == dynamic net."""
    rng = np.random.default_rng(1)
    n = 64
    for _ in range(10):
        k = int(rng.integers(1, n // 2))
        targets = np.sort(rng.choice(n, size=k, replace=False))
        # order-preserving, separation-non-increasing sources
        sources = targets.copy()
        slack = n - 1 - targets[-1]
        sources = targets + rng.integers(0, slack + 1)
        shift = np.zeros(n, np.int64)
        valid = np.zeros(n, bool)
        for s, t in zip(sources, targets):
            shift[s] = s - t
            valid[s] = True
        plan = shiftplan.counts_plan(tuple(int(x) for x in shift),
                                     tuple(bool(v) for v in valid),
                                     gather=True)
        dyn = shiftnet.gather_network(jnp.arange(n), jnp.asarray(shift),
                                      jnp.asarray(valid))
        assert plan.conflict == bool(dyn.conflict) == False  # noqa: E712
        out = shiftplan.apply_np(plan, np.arange(n))
        np.testing.assert_array_equal(
            np.where(plan.valid, out, 0),
            np.where(np.asarray(dyn.valid), np.asarray(dyn.payload), 0))


def test_segment_strategy_cost_model():
    """The segment compiler picks per-field compiled passes when they are
    cheaper and the FUSED single-pass bulk transposition for wide segments;
    either choice must cost no more wide ops than the seed's dynamic path
    (fields passes x log2(n) layers x 3 shifted arrays each)."""
    for fields, m in [(2, 128), (4, 128), (8, 128), (32, 8)]:
        n = fields * m
        mode, plans = shiftplan.segment_deinterleave_plans(n, fields)
        cost = sum(p.wide_ops for p in plans)
        seed_cost = fields * shiftplan.num_layers(n) * 3
        assert cost < seed_cost, (fields, m, mode, cost, seed_cost)
        # correctness of the chosen strategy via the host-side applier
        x = np.arange(n)
        if mode == "fused":
            assert len(plans) == 1      # ONE pass handles all fields
            plan = plans[0]
            out = shiftplan.apply_np(plan, np.pad(x, (0, plan.n - n)))[:n]
            np.testing.assert_array_equal(
                out, x.reshape(m, fields).T.reshape(-1))
        else:
            for f, plan in enumerate(plans):
                out = shiftplan.apply_np(plan, x)
                np.testing.assert_array_equal(out[:m],
                                              np.arange(m) * fields + f)
    # wide segments fuse into a single O(log n) pass
    mode, plans = shiftplan.segment_deinterleave_plans(256, 32)
    assert mode == "fused" and len(plans) == 1
    assert plans[0].active_layers <= 2 * shiftplan.num_layers(plans[0].n) - 1


@pytest.mark.parametrize("stride", (-1, -2, -3, -4, -7, -8))
def test_reverser_negative_stride_load_store(stride):
    """§3.2.2 Reverser: negative strides plan on the reversed element order
    and un-reverse the assembled output — batched and loop paths both must
    match direct indexing, and store must invert load."""
    n = 512
    buf = jnp.arange(n, dtype=jnp.float32) * 3 + 1
    base, vl, mlen = 400, 40, 64
    plan = lsdo.plan_strided(base, stride, vl, mlen)
    assert plan.reversed
    want = np.asarray([3 * (base + i * stride) + 1 for i in range(vl)],
                      np.float32)
    for batched in (True, False):
        got = np.asarray(lsdo.load_strided(buf, plan, batched=batched))
        np.testing.assert_array_equal(got, want, err_msg=f"{batched=}")
        vals = jnp.arange(1, vl + 1, dtype=jnp.float32)
        out = np.asarray(lsdo.store_strided(jnp.zeros(n), vals, plan,
                                            batched=batched))
        for i in range(vl):
            assert out[base + i * stride] == i + 1
        assert np.count_nonzero(out) == vl


# ---------------------------------------------------------------------------
# Runtime-stride plan bank (core/accessfuse.py): lax.switch dispatch over
# compiled plans must match the dynamic oracle bit-exactly — every banked
# stride (±1..8), both signs (Reverser), and the out-of-bank fallback.
# ---------------------------------------------------------------------------

from repro.core import accessfuse

BANK_SWEEP = tuple(range(1, 9)) + tuple(-s for s in range(1, 9)) + (9, -9)


@pytest.mark.parametrize("stride", BANK_SWEEP)
def test_plan_bank_gather_matches_dynamic_oracle(stride):
    n, offset, vl = 128, 64, 8
    win = jnp.arange(n, dtype=jnp.int32) * 13 + 7
    win2 = jnp.broadcast_to(win, (4, n))
    traced = jax.jit(lambda w, s: accessfuse.bank_gather_strided(
        w, s, offset, vl))(win2, jnp.int32(stride))
    static = accessfuse.bank_gather_strided(win2, stride, offset, vl)
    want = np.asarray(win)[offset + stride * np.arange(vl)]
    np.testing.assert_array_equal(np.asarray(traced),
                                  np.broadcast_to(want, (4, vl)))
    np.testing.assert_array_equal(np.asarray(static), np.asarray(traced))


@pytest.mark.parametrize("stride", BANK_SWEEP)
def test_plan_bank_scatter_matches_dynamic_oracle(stride):
    n, offset, vl = 128, 64, 8
    vals = jnp.broadcast_to(jnp.arange(1, vl + 1, dtype=jnp.int32), (4, vl))
    base = jnp.zeros((4, n), jnp.int32)
    traced = jax.jit(lambda w, v, s: accessfuse.bank_scatter_strided(
        w, v, s, offset))(base, vals, jnp.int32(stride))
    static = accessfuse.bank_scatter_strided(base, vals, stride, offset)
    want = np.zeros(n, np.int64)
    want[offset + stride * np.arange(vl)] = np.arange(1, vl + 1)
    np.testing.assert_array_equal(np.asarray(traced),
                                  np.broadcast_to(want, (4, n)))
    np.testing.assert_array_equal(np.asarray(static), np.asarray(traced))


def test_plan_bank_unfittable_slot_routes_to_fallback():
    """A banked stride whose (offset, vl) does not fit the window must
    still produce oracle results via the dynamic branch."""
    n, offset, vl = 64, 0, 16
    win = jnp.arange(n, dtype=jnp.int32)
    # stride 8 needs offset + 15*8 = 120 >= n: slot is None -> fallback...
    # for an in-range request we must pick a stride that fits; stride 5
    # (75 >= 64) is also unfittable, so sweep only fitting ones and assert
    # the bank builder marked non-fitting slots None.
    slots = accessfuse._gather_bank(n, offset, vl)
    assert slots[7] is None and slots[4] is None       # strides 8 and 5
    for stride in (1, 2, 3, 4):
        got = jax.jit(lambda w, s: accessfuse.bank_gather_strided(
            w, s, offset, vl))(win, jnp.int32(stride))
        np.testing.assert_array_equal(np.asarray(got),
                                      np.asarray(win)[::stride][:vl])


def test_multi_access_plan_matches_batched_plans():
    """The whole-step multi-access plan (concatenated transactions of
    several accesses) routes identically to per-access batched plans."""
    mlen = 64
    accesses = [(2, ((0, 10), (3, 20))), (4, ((1, 8), (5, 12))),
                (1, ((0, 64),))]
    rows = tuple((s, o, c) for s, pairs in accesses for o, c in pairs)
    mplan = shiftplan.multi_gather_plan(mlen, rows)
    assert not mplan.conflict
    x = np.arange(len(rows) * mlen).reshape(len(rows), mlen)
    got = shiftplan.apply_np(mplan, x)
    r = 0
    for s, pairs in accesses:
        bplan = shiftplan.batched_gather_plan(
            mlen, s, tuple(o for o, _ in pairs), tuple(c for _, c in pairs))
        want = shiftplan.apply_np(bplan, x[r:r + len(pairs)])
        valid = bplan.valid
        np.testing.assert_array_equal(np.where(valid, got[r:r + len(pairs)], 0),
                                      np.where(valid, want, 0))
        np.testing.assert_array_equal(mplan.valid[r:r + len(pairs)], valid)
        r += len(pairs)


def test_lsdo_region_past_buffer_end():
    """A transaction whose aligned region hangs past the buffer end must
    still load/store the in-bounds strided elements exactly (per-lane
    clipping; a start-clamped dynamic_slice would shift the window)."""
    buf = jnp.arange(100, dtype=jnp.float32)
    plan = lsdo.plan_strided(30, 3, 20, 64)   # elements 30..87, region 1
    want = np.asarray([30 + 3 * i for i in range(20)], np.float32)
    for batched in (True, False):
        got = np.asarray(lsdo.load_strided(buf, plan, batched=batched))
        np.testing.assert_array_equal(got, want, err_msg=f"{batched=}")
        vals = jnp.arange(1, 21, dtype=jnp.float32)
        out = np.asarray(lsdo.store_strided(jnp.zeros(100), vals, plan,
                                            batched=batched))
        np.testing.assert_array_equal(out[30:88:3], np.asarray(vals))
        assert out.shape == (100,)
