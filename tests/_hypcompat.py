"""Minimal fallback for the tiny slice of Hypothesis this suite uses.

The container image does not ship ``hypothesis``; rather than skip every
property test, this module provides deterministic pseudo-random example
generation with the same decorator surface (``given``, ``settings``,
``strategies``: integers / booleans / sampled_from / lists / sets /
composite). Test modules do::

    try:
        from hypothesis import given, settings, strategies as st
    except ImportError:
        from _hypcompat import given, settings, strategies as st

Real Hypothesis (shrinking, coverage-guided generation) is used whenever it
is installed — this shim only keeps the properties *exercised* without it.
"""
from __future__ import annotations

import functools
import inspect
import random

_MAX_EXAMPLES = 20  # capped: examples re-trigger jit compiles


class settings:  # noqa: N801 — mirrors hypothesis.settings
    _profiles: dict[str, int] = {}

    def __init__(self, *a, **kw):
        pass

    @classmethod
    def register_profile(cls, name: str, max_examples: int = 50, **kw):
        cls._profiles[name] = max_examples

    @classmethod
    def load_profile(cls, name: str):
        global _MAX_EXAMPLES
        _MAX_EXAMPLES = min(cls._profiles.get(name, 20), 20)


class _Strategy:
    """A strategy is just a draw function rng -> value."""

    def __init__(self, fn):
        self.fn = fn

    def map(self, f):
        return _Strategy(lambda rng: f(self.fn(rng)))

    def filter(self, pred):
        def draw(rng):
            for _ in range(1000):
                v = self.fn(rng)
                if pred(v):
                    return v
            raise ValueError("filter predicate never satisfied")
        return _Strategy(draw)


class strategies:  # noqa: N801 — mirrors hypothesis.strategies
    @staticmethod
    def integers(min_value=0, max_value=0, **kw):
        lo = kw.get("min_value", min_value)
        hi = kw.get("max_value", max_value)
        return _Strategy(lambda rng: rng.randint(lo, hi))

    @staticmethod
    def booleans():
        return _Strategy(lambda rng: rng.random() < 0.5)

    @staticmethod
    def floats(min_value=0.0, max_value=1.0, **kw):
        lo = kw.get("min_value", min_value)
        hi = kw.get("max_value", max_value)
        return _Strategy(lambda rng: rng.uniform(lo, hi))

    @staticmethod
    def sampled_from(seq):
        seq = list(seq)
        return _Strategy(lambda rng: seq[rng.randrange(len(seq))])

    @staticmethod
    def lists(elem: _Strategy, min_size=0, max_size=10, **kw):
        lo = kw.get("min_size", min_size)
        hi = kw.get("max_size", max_size)
        return _Strategy(
            lambda rng: [elem.fn(rng) for _ in range(rng.randint(lo, hi))])

    @staticmethod
    def sets(elem: _Strategy, min_size=0, max_size=10, **kw):
        lo = kw.get("min_size", min_size)
        hi = kw.get("max_size", max_size)

        def draw(rng):
            want = rng.randint(lo, hi)
            out: set = set()
            for _ in range(200 * max(want, 1)):
                if len(out) >= want:
                    break
                out.add(elem.fn(rng))
            return out
        return _Strategy(draw)

    @staticmethod
    def composite(f):
        def builder(*args, **kwargs):
            return _Strategy(
                lambda rng: f(lambda s: s.fn(rng), *args, **kwargs))
        return builder


st = strategies


def given(*strats, **kwstrats):
    def deco(test):
        @functools.wraps(test)
        def wrapper(*args, **kwargs):
            rng = random.Random(12345)
            for _ in range(_MAX_EXAMPLES):
                vals = [s.fn(rng) for s in strats]
                kvals = {k: s.fn(rng) for k, s in kwstrats.items()}
                test(*args, *vals, **kwargs, **kvals)
        # hide the strategy-filled params from pytest's fixture resolution
        # (real hypothesis does the same via @impersonate)
        del wrapper.__wrapped__
        params = list(inspect.signature(test).parameters.values())
        keep = params[: len(params) - len(strats)]
        keep = [p for p in keep if p.name not in kwstrats]
        wrapper.__signature__ = inspect.Signature(keep)
        return wrapper
    return deco
