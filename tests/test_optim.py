"""Optimizer + compression invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # container without hypothesis: deterministic fallback
    import os, sys
    sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
    from _hypcompat import given, settings, strategies as st

from repro.optim.adamw import (AdamWConfig, apply_updates, clip_by_global_norm,
                               init_opt_state, schedule)
from repro.optim.compression import (CompressionConfig,
                                     compress_with_feedback,
                                     init_error_state, wire_bytes_ratio)

settings.register_profile("fast4", max_examples=25, deadline=None)
settings.load_profile("fast4")


def _params():
    k = jax.random.key(0)
    return {"w": jax.random.normal(k, (8, 16)),
            "ln": jnp.ones((16,)),
            "b": jnp.zeros((16,))}


def test_schedule_warmup_and_decay():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                      min_lr_ratio=0.1)
    assert float(schedule(cfg, jnp.asarray(0))) == 0.0
    assert abs(float(schedule(cfg, jnp.asarray(10))) - 1.0) < 0.11
    assert float(schedule(cfg, jnp.asarray(100))) <= 0.1 + 1e-6
    # monotone decay after warmup
    vals = [float(schedule(cfg, jnp.asarray(s))) for s in range(10, 101, 10)]
    assert all(a >= b - 1e-9 for a, b in zip(vals, vals[1:]))


@given(st.floats(0.1, 10.0))
def test_clip_global_norm(max_norm):
    g = {"a": jnp.full((4, 4), 3.0), "b": jnp.full((2,), -4.0)}
    clipped, norm = clip_by_global_norm(g, max_norm)
    new_norm = float(jnp.sqrt(sum(jnp.sum(jnp.square(x))
                                  for x in jax.tree.leaves(clipped))))
    assert new_norm <= max_norm + 1e-4 or new_norm <= float(norm) + 1e-4


def test_adamw_reduces_quadratic_loss():
    cfg = AdamWConfig(lr=0.05, warmup_steps=0, total_steps=1000,
                      weight_decay=0.0, grad_clip=100.0)
    params = {"w": jnp.array([3.0, -2.0])}
    opt = init_opt_state(params)
    loss = lambda p: jnp.sum(jnp.square(p["w"]))
    l0 = float(loss(params))
    for _ in range(50):
        g = jax.grad(loss)(params)
        params, opt, _ = apply_updates(params, g, opt, cfg)
    assert float(loss(params)) < 0.1 * l0


def test_weight_decay_skips_norm_params():
    cfg = AdamWConfig(lr=0.1, warmup_steps=0, weight_decay=1.0,
                      grad_clip=1e9)
    params = _params()
    zeros = jax.tree.map(jnp.zeros_like, params)
    opt = init_opt_state(params)
    new, _, _ = apply_updates(params, zeros, opt, cfg)
    # ln (norm scale) untouched by decay; w decayed toward zero
    np.testing.assert_allclose(np.asarray(new["ln"]), np.asarray(params["ln"]))
    assert float(jnp.sum(jnp.abs(new["w"]))) < \
        float(jnp.sum(jnp.abs(params["w"])))


@pytest.mark.parametrize("kind", ["int8", "topk"])
def test_error_feedback_preserves_signal(kind):
    """EF invariant: sent_total + residual == true_total exactly, and the
    residual stays BOUNDED (does not grow with steps) — the property that
    makes compressed SGD convergent."""
    cfg = CompressionConfig(kind=kind, topk_frac=0.1)
    key = jax.random.key(1)
    g = {"w": jax.random.normal(key, (64,))}
    err = init_error_state(g)
    sent_total = jnp.zeros((64,))
    resids = []
    for i in range(40):
        sent, err, _ = compress_with_feedback(g, err, cfg)
        sent_total = sent_total + sent["w"]
        resids.append(float(jnp.linalg.norm(err["w"])))
    # exactness: what was not sent is exactly the residual
    np.testing.assert_allclose(np.asarray(sent_total + err["w"]),
                               np.asarray(40 * g["w"]), rtol=1e-4, atol=1e-4)
    # boundedness: residual plateaus instead of growing linearly
    assert resids[-1] < 2.0 * max(resids[:10]) + 1e-6
    assert resids[-1] < 10 * float(jnp.linalg.norm(g["w"]))


def test_compression_none_is_identity():
    g = {"w": jnp.arange(4.0)}
    sent, err, _ = compress_with_feedback(g, init_error_state(g),
                                          CompressionConfig(kind="none"))
    np.testing.assert_array_equal(np.asarray(sent["w"]), np.asarray(g["w"]))


def test_wire_ratios():
    assert wire_bytes_ratio(CompressionConfig(kind="int8")) == 0.25
    assert wire_bytes_ratio(CompressionConfig(kind="none")) == 1.0
    assert wire_bytes_ratio(CompressionConfig(kind="topk",
                                              topk_frac=0.05)) == 0.1


def test_train_step_with_compression_and_microbatches():
    from repro.configs import get_arch
    from repro.train.step import TrainConfig, init_full_state, make_train_step
    from repro.configs.base import train_batch
    arch = get_arch("qwen3-0.6b")
    cfg = arch.smoke
    tcfg = TrainConfig(
        optimizer=AdamWConfig(lr=1e-3, warmup_steps=0, total_steps=100),
        microbatches=2,
        compression=CompressionConfig(kind="int8"))
    state = init_full_state(cfg, tcfg, jax.random.key(0))
    batch = train_batch(cfg, 32, 4, specs=False)
    step = jax.jit(make_train_step(cfg, tcfg, None))
    s1, m1 = step(state, batch)
    s2, m2 = step(s1, batch)
    assert bool(jnp.isfinite(m1["loss"])) and bool(jnp.isfinite(m2["loss"]))
    assert float(m2["loss"]) < float(m1["loss"]) + 0.5
    assert int(s2["opt"]["step"]) == 2
