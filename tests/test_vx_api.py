"""repro.vx — API contract tests.

1. Every vx verb is bit-exact with the legacy ``kernels/ops.py`` path
   across impls (``ref``, ``pallas``, ``pallas_dynamic``) and through the
   runtime-stride bank.
2. ``with vx.use(...)`` nests and restores the active policy (including
   under exceptions).
3. Plan-cache keys include dtype and vl — the int8-vs-float32 collision
   regression.
4. ``vx.Policy.default()`` is the ONE resolution point: the env var,
   ``drom.default_impl`` and ``ModelConfig.kernel_impl=None`` all agree.
5. The legacy shims still answer correctly but warn.
"""
import contextlib
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import vx

IMPLS = ("ref", "pallas", "pallas_dynamic")


@contextlib.contextmanager
def legacy():
    """Call deprecated shims without tripping the CI deprecation gate."""
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        yield


# ---------------------------------------------------------------------------
# 1. verb <-> legacy equivalence
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("impl", IMPLS)
@pytest.mark.parametrize("stride,offset", [(1, 0), (3, 2), (8, 5)])
def test_gather_scatter_strided_match_legacy(impl, stride, offset):
    from repro.kernels import ops
    n = 128
    vl = (n - 1 - offset) // stride + 1
    win = jax.random.normal(jax.random.key(0), (3, n))
    vals = jax.random.normal(jax.random.key(1), (3, vl))
    spec = vx.Strided(n=n, stride=stride, offset=offset, vl=vl)
    with legacy():
        want_g = ops.gather_strided(win, stride, offset, vl, impl=impl)
        want_s = ops.scatter_strided(win, vals, stride, offset, impl=impl)
    np.testing.assert_array_equal(
        np.asarray(vx.gather(spec, win, policy=impl)), np.asarray(want_g))
    np.testing.assert_array_equal(
        np.asarray(vx.scatter(spec, win, vals, policy=impl)),
        np.asarray(want_s))


@pytest.mark.parametrize("impl", IMPLS)
@pytest.mark.parametrize("fields", [2, 4])
def test_transpose_matches_legacy(impl, fields):
    from repro.kernels import ops
    m = 32
    spec = vx.Segment(n=fields * m, fields=fields)
    aos = jax.random.normal(jax.random.key(2), (4, fields * m))
    with legacy():
        want = ops.deinterleave(aos, fields, impl=impl)
    got = vx.transpose(spec, aos, policy=impl)
    for g, w in zip(got, want):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))
    with legacy():
        want_b = ops.interleave(got, impl=impl)
    back = vx.transpose(spec, got, policy=impl)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(want_b))
    np.testing.assert_array_equal(np.asarray(back), np.asarray(aos))


@pytest.mark.parametrize("impl", IMPLS)
def test_compact_expand_match_legacy(impl):
    from repro.kernels import ops
    n, d = 64, 16
    rows = jax.random.normal(jax.random.key(3), (n, d))
    mask = jax.random.uniform(jax.random.key(4), (n,)) < 0.4
    with legacy():
        want_p, want_v = ops.compact_rows(rows, mask, impl=impl)
    got_p, got_v = vx.compact(vx.Compact(n=n), mask, rows, policy=impl)
    np.testing.assert_array_equal(np.asarray(got_v), np.asarray(want_v))
    np.testing.assert_array_equal(np.asarray(got_p), np.asarray(want_p))
    with legacy():
        want_e = ops.expand_rows(got_p, mask, impl=impl)
    got_e = vx.scatter(vx.Compact(n=n), mask, got_p, policy=impl)
    np.testing.assert_array_equal(np.asarray(got_e), np.asarray(want_e))


@pytest.mark.parametrize("impl", ("ref", "pallas"))
def test_compact_cap_truncates_rows(impl):
    n, d, cap = 32, 8, 4
    rows = jax.random.normal(jax.random.key(20), (n, d))
    mask = jnp.arange(n) % 3 == 0            # 11 set bits > cap
    packed, valid = vx.compact(vx.Compact(n=n, cap=cap), mask, rows,
                               policy=impl)
    assert packed.shape == (cap, d) and valid.shape == (cap,)
    full, fv = vx.compact(vx.Compact(n=n), mask, rows, policy=impl)
    assert full.shape == (n, d)
    np.testing.assert_array_equal(np.asarray(packed),
                                  np.asarray(full[:cap]))
    np.testing.assert_array_equal(np.asarray(valid), np.asarray(fv[:cap]))


@pytest.mark.parametrize("impl", IMPLS)
def test_gather_many_matches_legacy(impl):
    from repro.kernels import ops
    n, vl, A = 64, 16, 3
    wins = jnp.stack([jax.random.normal(jax.random.key(5 + a), (4, n))
                      for a in range(A)])
    pairs = [(2, 0), (3, 1), (1, 5)]
    specs = [vx.Strided(n=n, stride=s, offset=o, vl=vl) for s, o in pairs]
    with legacy():
        want = ops.gather_strided_many(wins, pairs, vl, impl=impl)
    got = vx.gather_many(specs, wins, policy=impl)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("impl", IMPLS)
def test_segment_many_match_legacy(impl):
    from repro.kernels import ops
    fields, m, A = 2, 32, 3
    spec = vx.Segment(n=fields * m, fields=fields)
    aos_list = [jax.random.normal(jax.random.key(10 + a), (4, fields * m))
                for a in range(A)]
    with legacy():
        want = ops.deinterleave_many(aos_list, fields, impl=impl)
    got = vx.gather_many(spec, aos_list, policy=impl)
    for gg, ww in zip(got, want):
        for g, w in zip(gg, ww):
            np.testing.assert_array_equal(np.asarray(g), np.asarray(w))
    groups = got
    with legacy():
        want_b = ops.interleave_many(groups, impl=impl)
    back = vx.scatter_many(spec, groups, policy=impl)
    for g, w in zip(back, want_b):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))


@pytest.mark.parametrize("stride", [-3, -1, 2, 5, 11])
def test_bank_gather_matches_legacy_rt(stride):
    from repro.kernels import ops
    n, offset0, vl = 128, 0, 8
    offset = offset0 + (0 if stride > 0 else n - 1)
    win = jax.random.normal(jax.random.key(6), (2, n))
    spec = vx.Strided(n=n, stride=vx.BANK, offset=offset, vl=vl)
    with legacy():
        want = ops.gather_strided_rt(win, stride, offset, vl)
    # static stride through the BANK spec
    got = vx.gather(spec, win, stride=stride)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    # traced stride through the lax.switch dispatch
    traced = jax.jit(lambda w, s: vx.gather(spec, w, stride=s))(
        win, jnp.int32(stride))
    np.testing.assert_array_equal(np.asarray(traced), np.asarray(want))


def test_bank_scatter_traced_matches_static():
    n, vl = 64, 8
    win = jax.random.normal(jax.random.key(7), (2, n))
    vals = jax.random.normal(jax.random.key(8), (2, vl))
    spec = vx.Strided(n=n, stride=vx.BANK, offset=3, vl=vl)
    static = vx.scatter(spec, win, vals, stride=4)
    traced = jax.jit(lambda w, v, s: vx.scatter(spec, w, v, stride=s))(
        win, vals, jnp.int32(4))
    np.testing.assert_array_equal(np.asarray(traced), np.asarray(static))
    want = win.at[:, 3:3 + 4 * vl:4].set(vals)
    np.testing.assert_array_equal(np.asarray(static), np.asarray(want))


# ---------------------------------------------------------------------------
# 2. policy scoping
# ---------------------------------------------------------------------------

def test_use_nesting_restores_policy():
    base = vx.current()
    with vx.use("pallas") as outer:
        assert vx.current().impl == "pallas"
        with vx.use(impl="ref", fusion_threshold=0) as inner:
            assert vx.current() is inner
            assert inner.impl == "ref" and inner.fusion_threshold == 0
            # inner scope inherits everything else from the outer scope
            assert inner.bank_strides == outer.bank_strides
        assert vx.current() is outer
    assert vx.current() == base


def test_use_restores_on_exception():
    before = vx.current()
    with pytest.raises(RuntimeError):
        with vx.use("pallas"):
            raise RuntimeError("boom")
    assert vx.current() == before


def test_policy_arg_beats_scope():
    spec = vx.Segment(n=8, fields=2)
    aos = jnp.arange(8.0)[None]
    with vx.use("pallas"):
        # explicit arg wins over the scope
        a = vx.transpose(spec, aos, policy="ref")
        b = vx.transpose(spec, aos)
    for x, y in zip(a, b):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_static_spec_rejects_stride_operand():
    w = jnp.arange(64.0)[None]
    spec = vx.Strided(n=64, stride=2, vl=8)
    with pytest.raises(ValueError, match="already pins stride"):
        vx.gather(spec, w, stride=5)
    with pytest.raises(ValueError, match="stride=vx.BANK"):
        vx.gather(vx.Strided(n=64, stride=vx.BANK, vl=8), w)


def test_policy_validation():
    with pytest.raises(ValueError):
        vx.Policy(impl="mosaic")
    with pytest.raises(TypeError):
        vx.resolve(3.14)


# ---------------------------------------------------------------------------
# 3. plan-cache keys include dtype and vl (collision regression)
# ---------------------------------------------------------------------------

def _gather_prog_keys(n: int, offset: int) -> list:
    """Cached gather programs touching window width n at this offset."""
    out = []
    for k in vx.PLANS.keys():
        if not (isinstance(k, tuple) and k and k[0] == "prog"):
            continue
        for txn in k[1]:
            if txn.op == "gather.plan" and any(
                    n in sk and offset in sk for sk in txn.specs):
                out.append(txn)
    return out


def test_plan_cache_distinguishes_dtypes():
    n, stride, vl = 64, 2, 16
    w8 = jnp.arange(n, dtype=jnp.int8)[None] % 100
    w32 = jnp.arange(n, dtype=jnp.float32)[None]
    spec = vx.Strided(n=n, stride=stride, vl=vl, offset=11)
    got8 = vx.gather(spec, w8, policy="pallas")
    got32 = vx.gather(spec, w32, policy="pallas")
    assert got8.dtype == jnp.int8 and got32.dtype == jnp.float32
    np.testing.assert_array_equal(
        np.asarray(got8), np.asarray(w8[:, 11:11 + stride * vl:stride]))
    np.testing.assert_array_equal(
        np.asarray(got32), np.asarray(w32[:, 11:11 + stride * vl:stride]))
    # the two accesses may never share a program entry: one per dtype
    txns = _gather_prog_keys(n, 11)
    dtypes = {f for t in txns for sk in t.specs for f in sk
              if f in ("int8", "float32")}
    assert {"int8", "float32"} <= dtypes, txns


def test_same_spec_two_layouts_distinct_cached_programs():
    """PR 4 regression: vx.PLANS keys include the shard layout — the same
    spec lowered against two placements yields two distinct cached
    programs (and a third for the replicated lowering)."""
    from repro.dist.sharding import make_mesh
    from repro.vx import lower as vxlower
    mesh_a = make_mesh((1,), ("a",))
    mesh_b = make_mesh((1,), ("b",))
    spec = vx.Strided(n=48, stride=3, vl=8, offset=1, dtype="float32")
    progs = [
        vxlower.lower("gather.plan", spec, "ref"),
        vxlower.lower("gather.plan", spec, "ref",
                      vx.Shard(axes=("a",), axis=-1, mesh=mesh_a)),
        vxlower.lower("gather.plan", spec, "ref",
                      vx.Shard(axes=("b",), axis=-1, mesh=mesh_b)),
    ]
    keys = {p.key() for p in progs}
    assert len(keys) == 3, keys
    # executing all three populates three distinct cache entries, and the
    # 1-shard shard_map lowerings agree with the replicated one
    w = jnp.arange(48, dtype=jnp.float32)[None]
    shards = [None,
              vx.Shard(axes=("a",), axis=-1, mesh=mesh_a),
              vx.Shard(axes=("b",), axis=-1, mesh=mesh_b)]
    outs = [vxlower.executor(p, spec, sh)(w)
            for p, sh in zip(progs, shards)]
    for o in outs[1:]:
        np.testing.assert_array_equal(np.asarray(o), np.asarray(outs[0]))
    cached = [p.key() for p in progs if p.key() in vx.PLANS]
    assert len(cached) == 3, cached


def test_layout_key_includes_mesh():
    """The mesh is part of the layout key: the compiled sharded executor
    closes over its mesh (shard_map + shard-index flattening), so two
    unequal meshes — even with the same axis names and shard count —
    must not share an entry (e.g. a (2,4) and a (4,2) mesh over the same
    axes)."""
    from repro.dist.sharding import make_mesh
    from repro.vx import lower as vxlower
    mesh_ab = make_mesh((1, 1), ("a", "b"))
    mesh_ba = make_mesh((1, 1), ("b", "a"))   # unequal mesh, same names
    spec = vx.Strided(n=48, stride=2, vl=8, dtype="float32")
    p1 = vxlower.lower("gather.plan", spec, "ref",
                       vx.Shard(axes=("a", "b"), axis=-1, mesh=mesh_ab))
    p2 = vxlower.lower("gather.plan", spec, "ref",
                       vx.Shard(axes=("a", "b"), axis=-1, mesh=mesh_ba))
    assert p1.key() != p2.key()
    assert p1.key() == vxlower.lower(
        "gather.plan", spec, "ref",
        vx.Shard(axes=("a", "b"), axis=-1, mesh=mesh_ab)).key()


def test_sharded_gather_many_rejects_heterogeneous_specs():
    """program.fuse reaches the sharded builder with width > 1; a
    heterogeneous group must error, never apply spec 0's plan to every
    stacked row."""
    from repro.dist.sharding import make_mesh
    mesh = make_mesh((1,), ("a",))
    shard = vx.Shard(axes=("a",), axis=-1, mesh=mesh)
    wins = jnp.stack([jnp.arange(64.0)] * 2)[:, None, :]
    specs = [vx.Strided(n=64, stride=2, offset=0, vl=8),
             vx.Strided(n=64, stride=3, offset=1, vl=8)]
    with pytest.raises(NotImplementedError, match="heterogeneous"):
        vx.gather_many(specs, wins, policy="ref", shard=shard)
    # homogeneous fused groups keep their sharded lowering
    same = [vx.Strided(n=64, stride=2, offset=0, vl=8)] * 2
    got = vx.gather_many(same, wins, policy="ref", shard=shard)
    want = vx.gather_many(same, wins, policy="ref")
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_sharded_lowering_rejects_bad_placements():
    from repro.dist.sharding import make_mesh
    from repro.vx import lower as vxlower
    mesh = make_mesh((1,), ("a",))
    sh_lane = vx.Shard(axes=("a",), axis=-1, mesh=mesh)
    sh_outer = vx.Shard(axes=("a",), axis=-2, mesh=mesh)
    with pytest.raises(ValueError, match="lane axis"):
        vxlower.lower("gather.plan", vx.Strided(n=8, stride=2, vl=4),
                      "ref", sh_outer)
    with pytest.raises(ValueError, match="permutes the lane axis"):
        vxlower.lower("seg.deint", vx.Segment(n=8, fields=2), "ref",
                      sh_lane)
    with pytest.raises(NotImplementedError):
        vxlower.lower("compact.rows", vx.Compact(n=8), "ref", sh_lane)
    with pytest.raises(NotImplementedError, match="runtime-stride"):
        vxlower.lower("gather.plan", vx.Strided(n=8, stride=vx.BANK, vl=4),
                      "ref", sh_lane)
    with pytest.raises(ValueError, match="counts from the end"):
        vx.Shard(axes=("a",), axis=1, mesh=mesh)


def test_verbs_lower_through_programs():
    """The pipeline is the ONE path: a verb call lands a 'prog'-keyed
    entry whose transaction carries the spec key (dtype + vl included)."""
    spec = vx.Strided(n=40, stride=5, vl=8, offset=2)
    w = jnp.arange(40, dtype=jnp.float16)[None]
    vx.gather(spec, w, policy="ref")
    bound = spec.bind(w.dtype)
    want = vx.program.single("gather.plan", bound, "ref")
    assert want.key() in vx.PLANS


def test_plan_cache_distinguishes_vl():
    n = 64
    w = jnp.arange(n, dtype=jnp.float32)[None]
    a = vx.gather(vx.Strided(n=n, stride=2, vl=8, offset=0), w)
    b = vx.gather(vx.Strided(n=n, stride=2, vl=16, offset=0), w)
    assert a.shape == (1, 8) and b.shape == (1, 16)
    assert vx.Strided(n=n, stride=2, vl=8).key() != \
        vx.Strided(n=n, stride=2, vl=16).key()


def test_spec_hashable_and_frozen():
    s = vx.Strided(n=32, stride=4, vl=8, dtype=jnp.float32)
    assert s == vx.Strided(n=32, stride=4, vl=8, dtype="float32")
    assert hash(s) == hash(vx.Strided(n=32, stride=4, vl=8, dtype="float32"))
    with pytest.raises(Exception):
        s.n = 64  # frozen
    assert {s: 1}[s] == 1
    b = vx.Strided(n=32, stride=vx.BANK, vl=8)
    assert b.runtime and "bank" in b.key()
    with pytest.raises(ValueError):
        vx.Strided(n=32, stride=8, vl=8)      # leaves the window
    with pytest.raises(ValueError):
        vx.Segment(n=33, fields=2)            # not divisible
    p = vx.Paged(page_size=8, pages=4, trail=2, dtype=jnp.float32)
    assert p == vx.Paged(page_size=8, pages=4, trail=2, dtype="float32")
    assert p.seq_len == 32 and p.pool_axis(5) == 1
    assert {p: 2}[p] == 2
    with pytest.raises(ValueError):
        vx.Paged(page_size=0, pages=4)
    i = vx.Indexed(n=4, routing=((0, 1, 1, 2), (1, 1, 0, 1)))
    assert i.static and i.key() != vx.Indexed(n=4).key()
    with pytest.raises(ValueError):
        vx.Indexed(n=4, routing=((0, 1), (1, 1)))   # wrong arity


def test_paged_verbs_validate_operands():
    pool = jnp.zeros((4, 4, 2), jnp.float32)
    spec = vx.Paged(page_size=4, pages=2, trail=1)
    with pytest.raises(ValueError, match="table="):
        vx.gather(spec, pool)
    with pytest.raises(ValueError, match="table= and pos="):
        vx.scatter(spec, pool, jnp.zeros((1, 2)))
    with pytest.raises(ValueError, match="page_size"):
        vx.gather(vx.Paged(page_size=8, pages=2, trail=1), pool,
                  table=jnp.zeros((1, 2), jnp.int32))
    with pytest.raises(ValueError, match="shift=/valid="):
        vx.gather(vx.Indexed(n=4, routing=((0, 0, 0, 0), (1, 1, 1, 1))),
                  jnp.zeros((4,)), shift=np.zeros(4, np.int32),
                  valid=np.ones(4, bool))


# ---------------------------------------------------------------------------
# 4. one knob: env var -> Policy.default -> drom/default + ModelConfig
# ---------------------------------------------------------------------------

def test_default_policy_resolves_env(monkeypatch):
    monkeypatch.setenv(vx.policy.ENV_VAR, "pallas")
    assert vx.Policy.default().impl == "pallas"
    from repro.core import drom
    with legacy():
        assert drom.default_impl() == "pallas"
    from repro.models.transformer import ModelConfig
    cfg = ModelConfig(name="t", d_model=8, n_layers=1, n_heads=1,
                      n_kv_heads=1, d_ff=16, vocab=11)
    assert cfg.kernel_impl is None
    assert cfg.vx_policy.impl == "pallas"
    monkeypatch.delenv(vx.policy.ENV_VAR)
    assert cfg.vx_policy.impl == vx.Policy.default().impl
    # a pinned impl string still wins
    import dataclasses
    pinned = dataclasses.replace(cfg, kernel_impl="ref")
    assert pinned.vx_policy.impl == "ref"


def test_warm_resolves_policy_like_verbs():
    """vx.warm honors policy= / the vx.use scope / the env default exactly
    like the verbs, so prewarming compiles the plans the governing policy
    will actually hit — and nothing under impl='ref', whose XLA path never
    consults segment plans."""
    n = 192                      # distinctive width: nothing else warms it
    key = ("plan.segment_deint", n, 2)
    assert key not in vx.PLANS
    with vx.use("ref"):
        vx.warm(n, strided=False, fields=(2,))
    assert key not in vx.PLANS
    with vx.use("pallas"):
        vx.warm(n, strided=False, fields=(2,))
    assert key in vx.PLANS
    # explicit policy= beats the scope, like any verb
    n2 = 224
    with vx.use("ref"):
        vx.warm(n2, strided=False, fields=(2,), policy="pallas")
    assert ("plan.segment_deint", n2, 2) in vx.PLANS


# ---------------------------------------------------------------------------
# 5. shims warn (and only the shims)
# ---------------------------------------------------------------------------

def test_shims_emit_deprecation_warnings():
    from repro.core import drom
    from repro.kernels import ops
    aos = jnp.arange(8.0)[None]
    with pytest.warns(DeprecationWarning):
        ops.deinterleave(aos, 2)
    with pytest.warns(DeprecationWarning):
        drom.deinterleave(aos, 2)
    with pytest.warns(DeprecationWarning):
        drom.default_impl()


def test_vx_verbs_do_not_warn():
    spec = vx.Segment(n=8, fields=2)
    aos = jnp.arange(8.0)[None]
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        vx.transpose(spec, aos)
        vx.gather(vx.Strided(n=8, stride=2, vl=4), aos)
        vx.compact(vx.Compact(n=8), jnp.ones(8, bool),
                   jnp.ones((8, 4)))
