"""Per-kernel Pallas (interpret) vs pure-jnp oracle, sweeping shapes/dtypes.

All access goes through the declarative vx API (spec + verb + policy);
the legacy-shim equivalence sweep lives in tests/test_vx_api.py.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import vx

DTYPES = [jnp.float32, jnp.bfloat16, jnp.int32]


def rand(key, shape, dtype):
    if jnp.issubdtype(dtype, jnp.integer):
        return jax.random.randint(key, shape, -100, 100, dtype)
    return jax.random.normal(key, shape, dtype)


@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("lead,n", [((), 128), ((4,), 64), ((2, 3), 256),
                                    ((5,), 96), ((8,), 512)])
@pytest.mark.parametrize("stride,offset", [(2, 0), (3, 1), (4, 2), (7, 5),
                                           (1, 0), (16, 3)])
def test_gather_strided(dtype, lead, n, stride, offset):
    vl = (n - 1 - offset) // stride + 1
    spec = vx.Strided(n=n, stride=stride, offset=offset, vl=vl)
    win = rand(jax.random.key(0), lead + (n,), dtype)
    got = vx.gather(spec, win, policy="pallas")
    want = vx.gather(spec, win, policy="ref")
    np.testing.assert_allclose(np.asarray(got, np.float64),
                               np.asarray(want, np.float64))


@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("lead,n", [((), 128), ((4,), 64), ((3, 2), 256)])
@pytest.mark.parametrize("stride,offset", [(2, 0), (3, 1), (5, 4), (1, 0)])
def test_scatter_strided(dtype, lead, n, stride, offset):
    vl = (n - 1 - offset) // stride + 1
    spec = vx.Strided(n=n, stride=stride, offset=offset, vl=vl)
    win = rand(jax.random.key(1), lead + (n,), dtype)
    vals = rand(jax.random.key(2), lead + (vl,), dtype)
    got = vx.scatter(spec, win, vals, policy="pallas")
    want = vx.scatter(spec, win, vals, policy="ref")
    np.testing.assert_allclose(np.asarray(got, np.float64),
                               np.asarray(want, np.float64))


@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("fields", [2, 3, 4, 5, 8])
@pytest.mark.parametrize("lead,m", [((), 64), ((4,), 32), ((2, 2), 128)])
def test_deinterleave(dtype, fields, lead, m):
    spec = vx.Segment(n=fields * m, fields=fields)
    aos = rand(jax.random.key(3), lead + (fields * m,), dtype)
    got = vx.transpose(spec, aos, policy="pallas")
    want = vx.transpose(spec, aos, policy="ref")
    assert len(got) == fields
    for g, w in zip(got, want):
        np.testing.assert_allclose(np.asarray(g, np.float64),
                                   np.asarray(w, np.float64))


@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("fields", [2, 3, 4, 8])
@pytest.mark.parametrize("lead,m", [((), 64), ((4,), 32), ((2, 2), 128)])
def test_interleave(dtype, fields, lead, m):
    spec = vx.Segment(n=fields * m, fields=fields)
    soa = [rand(jax.random.key(10 + f), lead + (m,), dtype)
           for f in range(fields)]
    got = vx.transpose(spec, soa, policy="pallas")
    want = vx.transpose(spec, soa, policy="ref")
    np.testing.assert_allclose(np.asarray(got, np.float64),
                               np.asarray(want, np.float64))


@pytest.mark.parametrize("fields", [2, 3, 4, 8])
def test_segment_roundtrip(fields):
    spec = vx.Segment(n=fields * 48, fields=fields)
    aos = rand(jax.random.key(4), (6, fields * 48), jnp.float32)
    with vx.use("pallas"):
        parts = vx.transpose(spec, aos)
        back = vx.transpose(spec, parts)
    np.testing.assert_allclose(np.asarray(back), np.asarray(aos))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("n,d", [(64, 128), (128, 64), (256, 384), (32, 8)])
@pytest.mark.parametrize("density", [0.0, 0.3, 0.7, 1.0])
def test_compact_rows(dtype, n, d, density):
    key = jax.random.key(5)
    rows = rand(key, (n, d), dtype)
    mask = jax.random.uniform(jax.random.key(6), (n,)) < density
    got, gv = vx.compact(vx.Compact(n=n), mask, rows, policy="pallas")
    want, wv = vx.compact(vx.Compact(n=n), mask, rows, policy="ref")
    np.testing.assert_array_equal(np.asarray(gv), np.asarray(wv))
    np.testing.assert_allclose(np.asarray(got, np.float64),
                               np.asarray(want, np.float64))


@pytest.mark.parametrize("n,d", [(64, 128), (128, 96), (32, 8)])
@pytest.mark.parametrize("density", [0.0, 0.5, 1.0])
def test_expand_rows(n, d, density):
    mask = jax.random.uniform(jax.random.key(7), (n,)) < density
    packed = rand(jax.random.key(8), (n, d), jnp.float32)
    # zero out rows beyond the packed count, as compaction would produce
    total = int(jnp.sum(mask.astype(jnp.int32)))
    packed = packed.at[total:].set(0.0)
    got = vx.scatter(vx.Compact(n=n), mask, packed, policy="pallas")
    want = vx.scatter(vx.Compact(n=n), mask, packed, policy="ref")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("n,d", [(64, 128), (128, 256)])
def test_compact_expand_roundtrip(n, d):
    rows = rand(jax.random.key(9), (n, d), jnp.float32)
    mask = jax.random.uniform(jax.random.key(11), (n,)) < 0.5
    with vx.use("pallas"):
        packed, _ = vx.compact(vx.Compact(n=n), mask, rows)
        back = vx.scatter(vx.Compact(n=n), mask, packed)
    want = jnp.where(mask[:, None], rows, 0.0)
    np.testing.assert_allclose(np.asarray(back), np.asarray(want))


def test_raw_shift_gather_matches_ref():
    from repro.core import scg
    n = 128
    x = rand(jax.random.key(12), (3, n), jnp.float32)
    shift, valid = scg.gather_counts(n, 5, 2, (n - 3) // 5 + 1)
    got = vx.gather(vx.Indexed(n=n), x, shift=shift, valid=valid,
                    policy="pallas")
    want = vx.gather(vx.Indexed(n=n), x, shift=shift, valid=valid,
                     policy="ref")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want))


def test_raw_shift_scatter_matches_ref():
    from repro.core import scg
    n = 128
    x = rand(jax.random.key(13), (3, n), jnp.float32)
    shift, valid = scg.scatter_counts(n, 5, 2, 25)
    gp, gv = vx.scatter(vx.Indexed(n=n), None, x, shift=shift, valid=valid,
                        policy="pallas")
    wp, wv = vx.scatter(vx.Indexed(n=n), None, x, shift=shift, valid=valid,
                        policy="ref")
    np.testing.assert_array_equal(np.asarray(gv), np.asarray(wv))
    np.testing.assert_allclose(np.asarray(gp), np.asarray(wp))


def test_kv_interleaved_roundtrip():
    from repro.kernels import kv_interleaved as kvi
    k = rand(jax.random.key(14), (2, 4, 64), jnp.float32)
    v = rand(jax.random.key(15), (2, 4, 64), jnp.float32)
    for impl in ("ref", "pallas"):
        kv = kvi.interleave_kv(k, v, policy=impl)
        k2, v2 = kvi.split_kv(kv, policy=impl)
        np.testing.assert_allclose(np.asarray(k2), np.asarray(k))
        np.testing.assert_allclose(np.asarray(v2), np.asarray(v))


def test_kv_append_token():
    from repro.kernels import kv_interleaved as kvi
    cache = jnp.zeros((2, 16, 4, 128))
    k = jnp.ones((2, 4, 64))
    v = 2 * jnp.ones((2, 4, 64))
    out = kvi.append_token(cache, k, v, 3)
    beat = np.asarray(out[:, 3])
    np.testing.assert_allclose(beat[..., 0::2], 1.0)
    np.testing.assert_allclose(beat[..., 1::2], 2.0)
    assert float(jnp.sum(jnp.abs(out[:, :3]))) == 0.0
    assert float(jnp.sum(jnp.abs(out[:, 4:]))) == 0.0


def test_vx_jit_compatible():
    spec = vx.Segment(n=256, fields=2)

    @jax.jit
    def f(x):
        with vx.use("pallas"):
            return vx.transpose(spec, vx.transpose(spec, x))

    x = rand(jax.random.key(16), (4, 256), jnp.float32)
    np.testing.assert_allclose(np.asarray(f(x)), np.asarray(x))
