"""Regenerate the §Roofline table in EXPERIMENTS.md from dry-run artifacts."""
import glob
import json
import os
import re
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks.roofline_table import HEADER, fmt_row  # noqa: E402

ROOT = os.path.join(os.path.dirname(__file__), "..")


def main():
    rows = []
    for path in sorted(glob.glob(os.path.join(
            ROOT, "experiments", "artifacts", "*.json"))):
        with open(path) as f:
            rows.append(json.load(f))
    # order: arch, shape, mesh
    rows.sort(key=lambda r: (r["arch"], r["shape"], r["mesh"]))
    lines = [HEADER] + [fmt_row(r) for r in rows] + [f"", f"{len(rows)} cells."]
    table = "\n".join(lines)
    exp = os.path.join(ROOT, "EXPERIMENTS.md")
    with open(exp) as f:
        txt = f.read()
    txt = re.sub(
        r"<!-- ROOFLINE_TABLE_START -->.*<!-- ROOFLINE_TABLE_END -->",
        f"<!-- ROOFLINE_TABLE_START -->\n{table}\n<!-- ROOFLINE_TABLE_END -->",
        txt, flags=re.S)
    with open(exp, "w") as f:
        f.write(txt)
    print(f"rendered {len(rows)} cells into EXPERIMENTS.md")


if __name__ == "__main__":
    main()
