"""Dev tool: rank the largest per-device tensors in a cell's compiled HLO.

Usage: PYTHONPATH=src python tools/mem_rank.py <arch> <shape> [threshold_gib]
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import collections  # noqa: E402
import re  # noqa: E402
import sys  # noqa: E402

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402

from repro.configs import get_arch  # noqa: E402
from repro.configs.base import shape_by_name  # noqa: E402
from repro.launch.dryrun import _lower_cell, _total_params  # noqa: E402
from repro.launch.mesh import make_ctx, make_production_mesh  # noqa: E402

DT = {"f32": 4, "bf16": 2, "s32": 4, "u32": 4, "pred": 1, "s8": 1,
      "f16": 2, "u8": 1, "s64": 8, "u64": 8, "f64": 8}


def main():
    arch, shape_name = sys.argv[1], sys.argv[2]
    thresh = float(sys.argv[3]) if len(sys.argv) > 3 else 1.0
    cfg = get_arch(arch).model
    shape = shape_by_name(shape_name)
    mesh = make_production_mesh()
    fsdp = shape.kind == "train" and _total_params(cfg) > 8e9
    ctx = make_ctx(mesh, long_context=shape.name == "long_500k", fsdp=fsdp)
    lw, _ = _lower_cell(cfg, shape, ctx)
    cp = lw.compile()
    ma = cp.memory_analysis()
    print(f"args={ma.argument_size_in_bytes/2**30:.2f}G "
          f"temp={ma.temp_size_in_bytes/2**30:.2f}G")
    big = collections.Counter()
    ops = collections.defaultdict(set)
    for line in cp.as_text().splitlines():
        m = re.search(r"= ([a-z0-9]+)\[([0-9,]{6,})\][^ ]* ([a-z\-]+)\(",
                      line)
        if not m:
            continue
        dt, dims, op = m.groups()
        if dt not in DT:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        byt = n * DT[dt]
        if byt > thresh * 2**30:
            key = f"{dt}[{dims}] = {byt/2**30:.2f}G"
            big[key] += 1
            ops[key].add(op)
    for k, c in big.most_common(20):
        print(f"{c:5d}x {k}  ops={sorted(ops[k])[:6]}")


if __name__ == "__main__":
    main()
