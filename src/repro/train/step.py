"""Train-step factory: mixed precision, microbatch accumulation (the
compute/comm overlap vehicle), gradient compression w/ error feedback,
AdamW, and full in/out shardings for pjit.

Overlap note: with N>1 microbatches the DP gradient all-reduce of micro-
batch i is scheduled by XLA's latency-hiding scheduler behind the compute
of microbatch i+1 (flags documented in launch/train.py); with N=1 the
reduce serializes after the backward — measured in §Perf.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.dist.sharding import ShardCtx
from repro.models.transformer import ModelConfig, loss_fn
from repro.optim.adamw import AdamWConfig, apply_updates
from repro.optim.compression import (CompressionConfig,
                                     compress_with_feedback,
                                     init_error_state)
from repro.train.state import state_shardings


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    optimizer: AdamWConfig = AdamWConfig()
    microbatches: int = 1
    compression: CompressionConfig = CompressionConfig()
    zero1: bool = True


def _split_microbatches(batch: dict, n: int) -> dict:
    def r(x):
        b = x.shape[0]
        assert b % n == 0, (b, n)
        return x.reshape((n, b // n) + x.shape[1:])
    return jax.tree.map(r, batch)


def make_train_step(cfg: ModelConfig, tcfg: TrainConfig, ctx: ShardCtx
                    ) -> Callable:
    """Returns train_step(state, batch) -> (state, metrics)."""

    grad_fn = jax.value_and_grad(
        lambda p, b: loss_fn(p, b, cfg, ctx), has_aux=True)

    def train_step(state: dict, batch: dict) -> tuple[dict, dict]:
        params = state["params"]
        n = tcfg.microbatches
        if n == 1:
            (loss, metrics), grads = grad_fn(params, batch)
        else:
            mb = _split_microbatches(batch, n)

            def acc_fn(carry, mbi):
                g_acc, l_acc = carry
                b = jax.tree.map(lambda x: x[mbi], mb)
                (loss, _), g = grad_fn(params, b)
                g_acc = jax.tree.map(
                    lambda a, x: a + x.astype(jnp.float32) / n, g_acc, g)
                return (g_acc, l_acc + loss / n), None

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                              params)
            (grads, loss), _ = jax.lax.scan(acc_fn, (g0, 0.0),
                                            jnp.arange(n))
            metrics = {"loss": loss, "aux": jnp.zeros((), jnp.float32)}

        if tcfg.compression.kind != "none":
            grads, err, cm = compress_with_feedback(
                grads, state["grad_error"], tcfg.compression)
            metrics.update(cm)
        new_params, new_opt, om = apply_updates(params, grads, state["opt"],
                                                tcfg.optimizer)
        metrics.update(om)
        new_state = dict(state)
        new_state["params"] = new_params
        new_state["opt"] = new_opt
        if tcfg.compression.kind != "none":
            new_state["grad_error"] = err
        return new_state, metrics

    return train_step


def init_full_state(cfg: ModelConfig, tcfg: TrainConfig, key) -> dict:
    from repro.models.transformer import init_params
    from repro.train.state import init_train_state
    params = init_params(cfg, key)
    state = init_train_state(params)
    if tcfg.compression.kind != "none":
        state["grad_error"] = init_error_state(params)
    return state


def full_state_shardings(state: dict, ctx: ShardCtx, tcfg: TrainConfig):
    if ctx.mesh is None:
        return None
    sh = state_shardings({"params": state["params"], "opt": state["opt"]},
                         ctx, zero1=tcfg.zero1)
    if "grad_error" in state:
        sh["grad_error"] = sh["opt"]["m"]
    return sh


def batch_shardings(batch_template: Any, ctx: ShardCtx):
    if ctx.mesh is None:
        return None
    return jax.tree.map(
        lambda x: ctx.sharding(ctx.batch_spec(*([None] * (x.ndim - 1)))),
        batch_template)


def jit_train_step(cfg: ModelConfig, tcfg: TrainConfig, ctx: ShardCtx,
                   state: dict, batch_template: Any):
    """jit with explicit in/out shardings + donated state."""
    step = make_train_step(cfg, tcfg, ctx)
    if ctx.mesh is None:
        return jax.jit(step, donate_argnums=0)
    ssh = full_state_shardings(state, ctx, tcfg)
    bsh = batch_shardings(batch_template, ctx)
    return jax.jit(step, in_shardings=(ssh, bsh),
                   out_shardings=(ssh, None), donate_argnums=0)
