"""Training loop substrate: TrainState, step factory, microbatching."""
from repro.train.state import init_train_state, state_shardings  # noqa: F401
from repro.train.step import (TrainConfig, init_full_state, jit_train_step,  # noqa: F401
                              make_train_step)
