"""TrainState pytree + sharding spec builders (ZeRO-1 optional)."""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.dist.sharding import ShardCtx, add_data_sharding, tree_param_specs
from repro.optim.adamw import init_opt_state


def init_train_state(params: Any) -> dict:
    return {"params": params, "opt": init_opt_state(params)}


def _zero1_spec(spec: P, shape: tuple, ctx: ShardCtx) -> P:
    """ZeRO-1: optimizer moments additionally sharded over the data axes.
    Never splits the scan-stack dim of block params (>=3D leaves)."""
    return add_data_sharding(spec, shape, ctx,
                             start=1 if len(shape) >= 3 else 0)


def train_state_specs(state: dict, ctx: ShardCtx, *, zero1: bool = True):
    """Pytree of PartitionSpecs for the full TrainState."""
    pspecs = tree_param_specs(state["params"], ctx)
    mspecs = jax.tree.map(lambda s: s, pspecs,
                          is_leaf=lambda x: isinstance(x, P))
    if zero1:
        mspecs = jax.tree.map(
            lambda s, p: _zero1_spec(s, p.shape, ctx), pspecs,
            state["params"],
            is_leaf=lambda x: isinstance(x, P))
    return {
        "params": pspecs,
        "opt": {"m": mspecs, "v": mspecs, "step": P()},
    }


def state_shardings(state: dict, ctx: ShardCtx, *, zero1: bool = True):
    if ctx.mesh is None:
        return None
    specs = train_state_specs(state, ctx, zero1=zero1)
    return jax.tree.map(lambda s: ctx.sharding(s), specs,
                        is_leaf=lambda x: isinstance(x, P))
