"""Mixture-of-Experts with expert parallelism and EARTH-compaction dispatch.

Design (see DESIGN.md §6): activations enter the FFN replicated across the
``model`` mesh axis (standard TP position). Experts are sharded over that
axis, so each device:

  1. routes its data-shard tokens (top-k, renormalized),
  2. selects the (token, slot) units owned by its local experts,
  3. **compacts** their indices to a fixed-capacity buffer — this is the
     EARTH gather network with prefix-sum shift counts (an order-preserving,
     separation-non-increasing mapping; kernels/moe_compact.py),
  4. sorts by local expert and runs grouped GEMMs (lax.ragged_dot),
  5. scatter-adds weighted results and psums over the model axis.

The only collective is the same (T, d) all-reduce a dense TP FFN needs —
no all-to-all, no (T, E, C) one-hot dispatch tensor (the "crossbar" EARTH
removes). Token drop only on per-device capacity overflow (slack-bounded).
"""
from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro import vx


class MoESpec(NamedTuple):
    n_experts: int
    top_k: int
    d_ff: int
    capacity_slack: float = 2.0
    aux_coef: float = 0.01
    dispatch: str = "earth"   # "earth" (shift network) | "sort" (argsort)


def init_moe(key, d_model: int, spec: MoESpec, dtype) -> dict:
    kr, kg, ku, ko = jax.random.split(key, 4)
    E, f = spec.n_experts, spec.d_ff
    s = d_model ** -0.5
    return {
        "router": jax.random.normal(kr, (d_model, E), jnp.float32) * s,
        "wg": jax.random.normal(kg, (E, d_model, f), dtype) * s,
        "wu": jax.random.normal(ku, (E, d_model, f), dtype) * s,
        "wo": jax.random.normal(ko, (E, f, d_model), dtype) * f ** -0.5,
    }


def _capacity(T: int, k: int, n_shards: int, slack: float) -> int:
    cap = int(math.ceil(T * k / n_shards * slack))
    cap = min(max(cap, 8), T * k)
    return ((cap + 7) // 8) * 8 if cap % 8 else cap


def _compact_ids(mine: jax.Array, cap: int, dispatch: str) -> tuple[jax.Array, jax.Array]:
    """Pack indices of set bits of ``mine`` (n,) to the front; take cap."""
    n = mine.shape[0]
    if dispatch == "earth":
        # runtime-count member of the plan bank (core/accessfuse.py):
        # take-masks derived once from the prefix-sum counts, ids pay one
        # shift+select per layer, no conflict reductions
        packed = vx.compact(vx.Compact(n=n, cap=cap), mine)
    else:  # argsort baseline (the XLA-native path)
        order = jnp.argsort(~mine, stable=True)
        packed = order[:cap].astype(jnp.int32)
    total = jnp.sum(mine.astype(jnp.int32))
    pv = jnp.arange(packed.shape[0], dtype=jnp.int32) < total
    return packed, pv


def moe_ffn_local(router, wg, wu, wo, x, spec: MoESpec, *,
                  model_axis: str | None, data_axes: tuple,
                  n_shards: int) -> tuple[jax.Array, jax.Array]:
    """Per-device MoE body. x: (T, d). Returns (y (T, d), aux loss scalar)."""
    T, d = x.shape
    E, k = spec.n_experts, spec.top_k
    e_loc = E // n_shards
    my = jax.lax.axis_index(model_axis) if model_axis else 0

    logits = (x @ router.astype(x.dtype)).astype(jnp.float32)     # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    topw, topi = jax.lax.top_k(probs, k)                          # (T, k)
    topw = topw / jnp.sum(topw, axis=-1, keepdims=True)

    # ---- aux (load-balance + z) losses, identical across model shards ----
    dispatch_frac = jnp.zeros((E,), jnp.float32).at[topi.reshape(-1)].add(
        1.0 / (T * k))
    aux = E * jnp.sum(dispatch_frac * jnp.mean(probs, axis=0))
    zloss = jnp.mean(jnp.square(jax.nn.logsumexp(logits, axis=-1)))
    aux = spec.aux_coef * (aux + 1e-3 * zloss)
    if data_axes:
        aux = jax.lax.pmean(aux, data_axes)

    # ---- unit selection & EARTH compaction ----
    expert = topi.reshape(-1).astype(jnp.int32)                   # (T*k,)
    weight = topw.reshape(-1)
    mine = (expert >= my * e_loc) & (expert < (my + 1) * e_loc)
    cap = _capacity(T, k, n_shards, spec.capacity_slack)
    packed, pv = _compact_ids(mine, cap, spec.dispatch)           # (cap,)

    tok = packed // k
    xe = jnp.take(x, tok, axis=0) * pv[:, None].astype(x.dtype)   # (cap, d)
    le = jnp.take(expert, packed) - my * e_loc
    le = jnp.where(pv, le, e_loc)                                 # sentinel
    order = jnp.argsort(le, stable=True)
    xs = jnp.take(xe, order, axis=0)
    gs = jnp.bincount(jnp.take(le, order), length=e_loc + 1)[:e_loc]
    gs = gs.astype(jnp.int32)

    # grouped GEMMs accumulate fp32 on the MXU but emit x.dtype — fp32
    # (cap, d_ff) activations otherwise dominate peak memory
    gate = jax.lax.ragged_dot(xs, wg, gs, preferred_element_type=x.dtype)
    up = jax.lax.ragged_dot(xs, wu, gs, preferred_element_type=x.dtype)
    ye = jax.lax.ragged_dot(jax.nn.silu(gate) * up, wo, gs,
                            preferred_element_type=x.dtype)       # (cap, d)

    # ---- unsort + weighted combine (reduction done by the caller) ----
    w_packed = jnp.take(weight, packed) * pv.astype(weight.dtype)
    w_sorted = jnp.take(w_packed, order)
    # accumulate in x.dtype (bf16): each token receives <= top_k terms, and
    # fp32 (T, d) accumulators dominate peak memory at Jamba scale
    contrib = (ye.astype(jnp.float32)
               * w_sorted[:, None]).astype(x.dtype)
    y = jnp.zeros((T, d), x.dtype).at[jnp.take(tok, order)].add(contrib)
    return y, aux


def moe_layer(params, x: jax.Array, spec: MoESpec, ctx) -> tuple[jax.Array, jax.Array]:
    """x: (B, S, d). ctx: dist.sharding.ShardCtx or None (single device).

    The model-axis reduction of partial expert outputs uses psum_scatter
    over the sequence dim when divisible (the reduce-scatter half of the
    Megatron-SP pattern) — half the wire bytes and a seq-sharded result,
    matching the inter-block activation sharding."""
    B, S, d = x.shape
    m_ax = ctx.model_axis if ctx else None
    m_sz = ctx.model_size if ctx else 1
    seq_scatter = (m_ax is not None and S % m_sz == 0 and S >= m_sz)

    def body(router, wg, wu, wo, xl):
        Tl = xl.shape[0] * xl.shape[1]
        y, aux = moe_ffn_local(
            router, wg, wu, wo, xl.reshape(Tl, d), spec,
            model_axis=m_ax,
            data_axes=ctx.data_axes if ctx else (),
            n_shards=m_sz)
        y = y.reshape(xl.shape)
        if m_ax is not None:
            if seq_scatter:
                y = jax.lax.psum_scatter(y, m_ax, scatter_dimension=1,
                                         tiled=True)
            else:
                y = jax.lax.psum(y, m_ax)
        return y, aux

    if ctx is None or ctx.mesh is None:
        return body(params["router"], params["wg"], params["wu"],
                    params["wo"], x)

    from jax.sharding import PartitionSpec as P

    from repro.dist.sharding import shard_map
    ba = ctx.data_axes if ctx.data_axes else None
    bspec = P(ba, None, None)
    ospec = P(ba, ctx.model_axis if seq_scatter else None, None)
    sm = shard_map(
        body, mesh=ctx.mesh,
        in_specs=(P(), P(ctx.model_axis), P(ctx.model_axis),
                  P(ctx.model_axis), bspec),
        out_specs=(ospec, P()),
        check_vma=False)
    return sm(params["router"], params["wg"], params["wu"], params["wo"], x)
