"""Whisper-style encoder-decoder backbone.

The conv/mel frontend is a STUB per the assignment: ``input_specs`` feeds
precomputed frame embeddings (B, F, d_model). The encoder is a bidirectional
transformer (sinusoidal positions); the decoder adds causal self-attention
with interleaved KV cache + cross-attention over cached encoder K/V.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro import vx
from repro.models import attention, layers


def _sinusoid(positions: jax.Array, d: int) -> jax.Array:
    half = d // 2
    freqs = jnp.exp(-jnp.arange(half, dtype=jnp.float32)
                    * (jnp.log(10000.0) / max(half - 1, 1)))
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def init_encoder(key, cfg) -> dict:
    """Stacked encoder blocks (self-attn + MLP)."""
    n = cfg.encoder.n_layers
    ks = jax.random.split(key, n)

    def one(k):
        k1, k2 = jax.random.split(k)
        return {
            "ln1": jnp.ones((cfg.d_model,), cfg.pdtype),
            "attn": attention.init_attention(k1, cfg.d_model, cfg.n_heads,
                                             cfg.n_kv_heads, cfg.hd,
                                             qk_norm=False, dtype=cfg.pdtype),
            "ln2": jnp.ones((cfg.d_model,), cfg.pdtype),
            "mlp": layers.init_mlp(k2, cfg.d_model, cfg.d_ff, cfg.pdtype),
        }

    blocks = [one(k) for k in ks]
    return {"blocks": jax.tree.map(lambda *xs: jnp.stack(xs), *blocks),
            "final_norm": jnp.ones((cfg.d_model,), cfg.pdtype)}


def init_cross_stack(key, cfg) -> dict:
    """Per-decoder-layer cross-attention params, stacked like blocks."""
    ns = cfg.n_superblocks
    ks = jax.random.split(key, ns)

    def one(k):
        return {"ln": jnp.ones((cfg.d_model,), cfg.pdtype),
                "xattn": attention.init_cross_attention(
                    k, cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd,
                    cfg.pdtype)}

    xs = [one(k) for k in ks]
    return jax.tree.map(lambda *a: jnp.stack(a), *xs)


def encode(params, frames: jax.Array, cfg, ctx) -> jax.Array:
    """frames: (B, F, d) stub embeddings -> encoder output (B, F, d)."""
    B, F, _ = frames.shape
    x = frames.astype(cfg.cdtype) + _sinusoid(
        jnp.arange(F), cfg.d_model).astype(cfg.cdtype)

    def body(x, blk):
        h = layers.rms_norm(x, blk["ln1"], cfg.norm_eps)
        q = (h @ blk["attn"]["wq"]).reshape(B, F, cfg.n_heads, cfg.hd)
        kv = (h @ blk["attn"]["wkv"]).reshape(B, F, cfg.n_kv_heads,
                                              2 * cfg.hd)
        k, v = vx.transpose(vx.Segment(n=kv.shape[-1], fields=2), kv,
                            policy=cfg.vx_policy)
        out = attention.flash_attention(q, k, v, causal=False, window=None,
                                        q_chunk=min(512, F),
                                        kv_chunk=min(512, F), ctx=ctx)
        x = x + out.reshape(B, F, -1) @ blk["attn"]["wo"]
        h2 = layers.rms_norm(x, blk["ln2"], cfg.norm_eps)
        return x + layers.mlp_ffn(blk["mlp"], h2), None

    if cfg.scan_layers:
        x, _ = jax.lax.scan(body, x, params["encoder"]["blocks"])
    else:
        for li in range(cfg.encoder.n_layers):
            blk = jax.tree.map(lambda a: a[li], params["encoder"]["blocks"])
            x, _ = body(x, blk)
    return layers.rms_norm(x, params["encoder"]["final_norm"], cfg.norm_eps)


def _decoder_self_and_cross(sb_p, cross_p, x, cfg, ctx, positions, enc_kv,
                            mode):
    """One decoder superblock position (self-attn + cross + mlp)."""
    from repro.models.transformer import _ffn_apply
    p = sb_p["pos0"]
    B, S = x.shape[:2]
    h = layers.rms_norm(x, p["ln1"], cfg.norm_eps)
    q, k, v, kv = attention.qkv_project(p["attn"], h, cfg.n_heads,
                                        cfg.n_kv_heads, cfg.hd, positions,
                                        cfg.rope_theta,
                                        policy=cfg.vx_policy)
    out = attention.flash_attention(q, k, v, causal=True, window=None,
                                    q_chunk=min(512, S), kv_chunk=min(512, S),
                                    ctx=ctx)
    x = x + out.reshape(B, S, -1) @ p["attn"]["wo"]
    # cross attention over encoder K/V
    ck, cv = enc_kv
    hx = layers.rms_norm(x, cross_p["ln"], cfg.norm_eps)
    x = x + attention.cross_attention(cross_p["xattn"], hx, ck, cv,
                                      cfg.n_heads, cfg.n_kv_heads, cfg.hd,
                                      ctx=ctx)
    x, _ = _ffn_apply(p, x, cfg, ctx, 0)
    return x, (kv if mode == "prefill" else None)


def forward(params, batch, cfg, ctx, *, mode: str = "train"):
    """batch: tokens (B,S) + audio_frames (B,F,d). Returns (logits, aux, cache)."""
    tokens = batch["tokens"]
    B, S = tokens.shape
    enc_out = encode(params, batch["audio_frames"], cfg, ctx)
    x = layers.embed(tokens, params["embed"]).astype(cfg.cdtype)
    x = x + _sinusoid(jnp.arange(S), cfg.d_model).astype(cfg.cdtype)
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))

    def body(carry, inp):
        x = carry
        sb_p, cross_p = inp
        ck, cv = attention.encoder_kv(cross_p["xattn"], enc_out,
                                      cfg.n_kv_heads, cfg.hd,
                                      policy=cfg.vx_policy)
        x, kv = _decoder_self_and_cross(sb_p, cross_p, x, cfg, ctx,
                                        positions, (ck, cv), mode)
        return x, (kv if mode == "prefill" else 0)

    fn = jax.checkpoint(body) if cfg.remat != "none" else body
    if cfg.scan_layers:
        x, kvs = jax.lax.scan(fn, x, (params["blocks"], params["cross"]))
    else:
        kv_list = []
        for li in range(cfg.n_superblocks):
            inp = jax.tree.map(lambda a: a[li],
                               (params["blocks"], params["cross"]))
            x, kv = fn(x, inp)
            kv_list.append(kv)
        kvs = (jax.tree.map(lambda *xs: jnp.stack(xs), *kv_list)
               if mode == "prefill" else jnp.stack(kv_list))
    if mode == "prefill":
        x = x[:, -1:]  # serving prefill only needs next-token logits
    x = layers.rms_norm(x, params["final_norm"], cfg.norm_eps)
    aux = jnp.zeros((), jnp.float32)
    cache_states = {"pos0": kvs, "enc_out": enc_out} if mode == "prefill" \
        else {}
    if mode == "hidden":
        return x, aux, cache_states
    head = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    logits = layers.unembed(x, head.astype(cfg.cdtype))
    return logits, aux, cache_states


def init_cache(cfg, batch: int, max_len: int, dtype) -> dict:
    ns = cfg.n_superblocks
    F = cfg.encoder.context
    return {
        "len": jnp.zeros((), jnp.int32),
        "blocks": {"pos0": jnp.zeros(
            (ns, batch, max_len, cfg.n_kv_heads, 2 * cfg.hd), dtype)},
        "enc_kv": jnp.zeros((ns, batch, F, cfg.n_kv_heads, 2 * cfg.hd),
                            dtype),
    }


def precompute_enc_kv(params, frames, cfg, ctx) -> jax.Array:
    """(NS, B, F, K, 2D) interleaved encoder K/V for decode."""
    enc_out = encode(params, frames, cfg, ctx)

    def one(cross_p):
        kv = (enc_out @ cross_p["xattn"]["wkv"]).reshape(
            enc_out.shape[0], enc_out.shape[1], cfg.n_kv_heads, 2 * cfg.hd)
        return kv

    return jax.vmap(one)(params["cross"])


def decode_step(params, cache, token, cfg, ctx):
    from repro.models.transformer import cast_params
    params = cast_params(params, cfg)
    B = token.shape[0]
    pos = cache["len"]
    x = layers.embed(token, params["embed"]).astype(cfg.cdtype)
    x = x + _sinusoid(pos[None], cfg.d_model).astype(cfg.cdtype)[0]

    def sb_step(x, inp):
        sb_p, cross_p, kvc, enc_kv = inp
        p = sb_p["pos0"]
        h = layers.rms_norm(x, p["ln1"], cfg.norm_eps)
        positions = jnp.broadcast_to(pos, (B, 1))
        q, _, _, kv = attention.qkv_project(p["attn"], h[:, None],
                                            cfg.n_heads, cfg.n_kv_heads,
                                            cfg.hd, positions,
                                            cfg.rope_theta,
                                            policy=cfg.vx_policy)
        sc = kvc.shape[1]
        kvc = jax.lax.dynamic_update_slice_in_dim(
            kvc, kv.astype(kvc.dtype), jax.lax.rem(pos, sc), axis=1)
        k_all, v_all = vx.transpose(
            vx.Segment(n=kvc.shape[-1], fields=2), kvc, policy="ref")
        out = attention.decode_attention(q[:, 0], k_all, v_all,
                                         jnp.minimum(pos + 1, sc))
        x = x + (out.reshape(B, -1) @ p["attn"]["wo"]).astype(x.dtype)
        # cross attention against cached encoder K/V
        ek, ev = vx.transpose(
            vx.Segment(n=enc_kv.shape[-1], fields=2), enc_kv, policy="ref")
        hx = layers.rms_norm(x, cross_p["ln"], cfg.norm_eps)
        qx = (hx @ cross_p["xattn"]["wq"]).reshape(B, cfg.n_heads, cfg.hd)
        xo = attention.decode_attention(qx, ek, ev, ek.shape[1])
        x = x + (xo.reshape(B, -1) @ cross_p["xattn"]["wo"]).astype(x.dtype)
        from repro.models.transformer import _ffn_apply
        x2, _ = _ffn_apply(p, x[:, None], cfg, ctx, 0)
        return x2[:, 0], kvc

    xs_all = (params["blocks"], params["cross"], cache["blocks"]["pos0"],
              cache["enc_kv"])
    if cfg.scan_layers:
        x, new_kv = jax.lax.scan(sb_step, x, xs_all)
    else:
        kv_list = []
        for li in range(cfg.n_superblocks):
            x, kvc = sb_step(x, jax.tree.map(lambda a: a[li], xs_all))
            kv_list.append(kvc)
        new_kv = jnp.stack(kv_list)
    x = layers.rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    logits = layers.unembed(x, head.astype(cfg.cdtype))
    return logits, {"len": pos + 1, "blocks": {"pos0": new_kv},
                    "enc_kv": cache["enc_kv"]}
