"""Composable decoder-only transformer supporting every assigned family:
dense GQA/MQA, sliding-window:global patterns, qk-norm, MoE FFNs, Mamba
blocks (hybrid), and xLSTM (mLSTM/sLSTM) stacks.

Layer heterogeneity is expressed as a repeating *superblock*: e.g. Jamba is
(mamba x3, attn, mamba x4) with MoE every second layer; Gemma-3 is
(local x5, global). The stack scans over superblocks (keeps HLO compact at
512 devices) with configurable remat.

EARTH touchpoints: fused interleaved gate/up GLU (segment FIELD=2), fused
interleaved KV beats -> interleaved KV cache (segment FIELD=2, one
transaction per token), MoE dispatch via shift-network compaction.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.dist.sharding import ShardCtx
from repro.models import attention, layers
from repro.models.moe import MoESpec, init_moe, moe_layer
from repro.models.ssm import (MambaCache, MambaSpec, init_mamba,
                              init_mamba_cache, mamba_decode_step,
                              mamba_forward)
from repro.models.xlstm import (MLSTMState, SLSTMState, XLSTMSpec, init_mlstm,
                                init_mlstm_state, init_slstm,
                                init_slstm_state, mlstm_decode_step,
                                mlstm_forward, slstm_decode_step,
                                slstm_forward)


@dataclasses.dataclass(frozen=True)
class EncoderSpec:
    n_layers: int
    context: int          # encoder sequence length (e.g. whisper 1500 frames)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    d_model: int
    n_layers: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0                      # 0 -> d_model // n_heads
    block_pattern: tuple = ("attn",)       # kinds per superblock position
    window_pattern: tuple = (None,)        # sliding window per position
    moe_pattern: tuple = (False,)          # MoE FFN per position
    mlp: str = "swiglu"                    # "swiglu" | "mlp" | "none"
    fused_glu: bool = True                 # EARTH interleaved gate/up
    qk_norm: bool = False
    rope_theta: float = 1e4
    moe: MoESpec | None = None
    mamba: MambaSpec | None = None
    xlstm: XLSTMSpec | None = None
    encoder: EncoderSpec | None = None     # whisper
    vlm_patches: int = 0                   # llava stub: # patch embeddings
    tie_embeddings: bool = True
    norm_eps: float = 1e-6
    param_dtype: str = "float32"
    compute_dtype: str = "float32"
    remat: str = "full"                    # "none" | "full" | "dots"
    scan_layers: bool = True
    # EARTH access lowering in-model: an impl string pins it; None defers
    # to vx.Policy.default() (REPRO_VX_IMPL env var, else platform) — ONE
    # knob for the whole stack (see repro/vx/policy.py).
    kernel_impl: str | None = None
    step_fusion: bool = True               # whole-step access fusion (decode)
    ssm_chunk: int = 128

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def sb_len(self) -> int:
        return len(self.block_pattern)

    @property
    def n_superblocks(self) -> int:
        assert self.n_layers % self.sb_len == 0, (self.name, self.n_layers,
                                                  self.sb_len)
        return self.n_layers // self.sb_len

    @property
    def pdtype(self):
        return jnp.dtype(self.param_dtype)

    @property
    def cdtype(self):
        return jnp.dtype(self.compute_dtype)

    @property
    def vx_policy(self):
        """The access policy this model lowers through (vx.resolve of
        ``kernel_impl``: a pinned impl, or the ambient policy)."""
        from repro import vx
        return vx.resolve(self.kernel_impl)

    def pos_has_ffn(self, i: int) -> bool:
        kind = self.block_pattern[i]
        if kind in ("mlstm", "slstm"):
            return False
        return bool(self.moe_pattern[i]) or (self.mlp != "none"
                                             and self.d_ff > 0)


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def _init_pos(key, cfg: ModelConfig, i: int) -> dict:
    kind = cfg.block_pattern[i]
    keys = jax.random.split(key, 4)
    p: dict[str, Any] = {"ln1": jnp.ones((cfg.d_model,), cfg.pdtype)}
    if kind == "attn":
        p["attn"] = attention.init_attention(
            keys[0], cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd,
            qk_norm=cfg.qk_norm, dtype=cfg.pdtype)
    elif kind == "mamba":
        spec = cfg.mamba
        p["mamba"] = init_mamba(keys[0], spec, cfg.pdtype)
        # reshape in_proj for clean (x|z) sharding: (d, 2, ed)
        p["mamba"]["in_proj"] = p["mamba"]["in_proj"].reshape(
            cfg.d_model, 2, spec.ed)
    elif kind == "mlstm":
        p["xl"] = init_mlstm(keys[0], cfg.xlstm, cfg.pdtype)
        p["xl"]["up"] = p["xl"]["up"].reshape(cfg.d_model, 2,
                                              cfg.xlstm.m_inner)
    elif kind == "slstm":
        p["slstm"] = init_slstm(keys[0], cfg.xlstm, cfg.pdtype)
    else:
        raise ValueError(kind)
    if cfg.pos_has_ffn(i):
        p["ln2"] = jnp.ones((cfg.d_model,), cfg.pdtype)
        if cfg.moe_pattern[i]:
            p["moe"] = init_moe(keys[1], cfg.d_model, cfg.moe, cfg.pdtype)
        elif cfg.mlp == "swiglu":
            p["ffn"] = layers.init_glu(keys[1], cfg.d_model, cfg.d_ff,
                                       fused=cfg.fused_glu, dtype=cfg.pdtype)
        else:
            p["mlp"] = layers.init_mlp(keys[1], cfg.d_model, cfg.d_ff,
                                       cfg.pdtype)
    return p


def init_params(cfg: ModelConfig, key) -> dict:
    ke, kb, kh = jax.random.split(key, 3)
    params: dict[str, Any] = {
        "embed": (jax.random.normal(ke, (cfg.vocab, cfg.d_model), cfg.pdtype)
                  * cfg.d_model ** -0.5),
        "final_norm": jnp.ones((cfg.d_model,), cfg.pdtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = jax.random.normal(
            kh, (cfg.vocab, cfg.d_model), cfg.pdtype) * cfg.d_model ** -0.5

    def init_sb(k):
        ks = jax.random.split(k, cfg.sb_len)
        return {f"pos{i}": _init_pos(ks[i], cfg, i)
                for i in range(cfg.sb_len)}

    sb_keys = jax.random.split(kb, cfg.n_superblocks)
    sbs = [init_sb(k) for k in sb_keys]
    params["blocks"] = jax.tree.map(lambda *xs: jnp.stack(xs), *sbs)
    if cfg.encoder is not None:
        from repro.models import encdec
        params["encoder"] = encdec.init_encoder(
            jax.random.fold_in(key, 7), cfg)
        params["cross"] = encdec.init_cross_stack(
            jax.random.fold_in(key, 8), cfg)
    return params


def param_count(params) -> int:
    return sum(x.size for x in jax.tree.leaves(params))


# ---------------------------------------------------------------------------
# Superblock application (train / prefill / decode)
# ---------------------------------------------------------------------------

def _ffn_apply(p, x, cfg: ModelConfig, ctx, i: int, *, policy=None):
    """``policy`` overrides cfg.vx_policy for the GLU field split — the
    step scheduler (core/accessfuse.py) inlines single-token splits on the
    XLA path during fused decode instead of paying a kernel launch."""
    aux = jnp.zeros((), jnp.float32)
    if not cfg.pos_has_ffn(i):
        return x, aux
    h = layers.rms_norm(x, p["ln2"], cfg.norm_eps)
    if cfg.moe_pattern[i]:
        y, aux = moe_layer(p["moe"], h, cfg.moe, ctx)
    elif cfg.mlp == "swiglu":
        y = layers.glu_ffn(p["ffn"], h, fused=cfg.fused_glu,
                           policy=policy if policy is not None
                           else cfg.vx_policy)
    else:
        y = layers.mlp_ffn(p["mlp"], h)
    return x + y, aux


def _attn_apply(p, x, cfg: ModelConfig, ctx, i: int, positions,
                mode: str, cross_kv=None):
    """Returns (x, kv_beat or None)."""
    h = layers.rms_norm(x, p["ln1"], cfg.norm_eps)
    q, k, v, kv = attention.qkv_project(
        p["attn"], h, cfg.n_heads, cfg.n_kv_heads, cfg.hd, positions,
        cfg.rope_theta, policy=cfg.vx_policy)
    B, S = x.shape[:2]
    window = cfg.window_pattern[i]
    out = attention.flash_attention(q, k, v, causal=True, window=window,
                                    q_chunk=min(512, S),
                                    kv_chunk=min(512, S), ctx=ctx)
    x = x + out.reshape(B, S, cfg.n_heads * cfg.hd) @ p["attn"]["wo"]
    if cross_kv is not None:
        ck, cv = cross_kv
        x = x + attention.cross_attention(p["cross"], layers.rms_norm(
            x, p["ln_cross"], cfg.norm_eps), ck, cv, cfg.n_heads,
            cfg.n_kv_heads, cfg.hd, ctx=ctx)
    return x, (kv if mode == "prefill" else None)


def superblock_apply(sb_p, x, cfg: ModelConfig, ctx, positions, *,
                     mode: str = "train"):
    """Apply one superblock. Returns (x, aux, cache_updates).

    Each position is independently remat'd (nested checkpoint): during the
    superblock's backward only ONE position's residuals are live — without
    this, wide multi-position superblocks (Jamba: 8) hold every position's
    fp32 intermediates at once (~96 GiB/device measured at 398B scale)."""
    aux_total = jnp.zeros((), jnp.float32)
    updates = {}
    for i, kind in enumerate(cfg.block_pattern):
        fn = functools.partial(_position_apply, cfg=cfg, ctx=ctx, i=i,
                               kind=kind, mode=mode)
        if cfg.remat != "none" and len(cfg.block_pattern) > 1:
            fn = jax.checkpoint(fn, static_argnums=())
        x, aux, upd = fn(sb_p[f"pos{i}"], x, positions)
        aux_total = aux_total + aux
        if upd is not None:
            updates[f"pos{i}"] = upd
        if ctx is not None and ctx.mesh is not None:
            # Megatron-SP: residual stream sequence-sharded over the model
            # axis between blocks (activation memory / model_size)
            seq_ax = (ctx.model_if_divisible(x.shape[1])
                      if ctx.seq_shard_acts else None)
            x = ctx.constrain(x, ctx.batch_spec(seq_ax, None))
    return x, aux_total, updates


def _position_apply(p, x, positions, *, cfg: ModelConfig, ctx, i: int,
                    kind: str, mode: str):
    """One (mixer + FFN) position of a superblock."""
    update = None
    if kind == "attn":
        x, kv = _attn_apply(p, x, cfg, ctx, i, positions, mode)
        if mode == "prefill":
            update = _ring_trim(kv, cfg.window_pattern[i])
    elif kind == "mamba":
        h = layers.rms_norm(x, p["ln1"], cfg.norm_eps)
        pm = dict(p["mamba"])
        pm["in_proj"] = pm["in_proj"].reshape(cfg.d_model, 2 * cfg.mamba.ed)
        y, state = mamba_forward(pm, h, cfg.mamba, chunk=cfg.ssm_chunk)
        x = x + y
        if mode == "prefill":
            update = state
    elif kind == "mlstm":
        h = layers.rms_norm(x, p["ln1"], cfg.norm_eps)
        px = dict(p["xl"])
        px["up"] = px["up"].reshape(cfg.d_model, 2 * cfg.xlstm.m_inner)
        y, state = mlstm_forward(px, h, cfg.xlstm)
        x = x + y
        if mode == "prefill":
            update = state
    elif kind == "slstm":
        h = layers.rms_norm(x, p["ln1"], cfg.norm_eps)
        y, state = slstm_forward(p["slstm"], h, cfg.xlstm)
        x = x + y
        if mode == "prefill":
            update = state
    else:
        raise ValueError(kind)
    aux = jnp.zeros((), jnp.float32)
    if cfg.pos_has_ffn(i):
        x, aux = _ffn_apply(p, x, cfg, ctx, i)
    return x, aux, update


def _ring_trim(kv: jax.Array, window: int | None) -> jax.Array:
    """Prefill cache beat tensor; windowed layers keep a ring of size W."""
    B, S = kv.shape[:2]
    if window is None or S <= window:
        return kv
    last = kv[:, -window:]
    return jnp.roll(last, shift=S % window, axis=1)


# ---------------------------------------------------------------------------
# Forward / loss
# ---------------------------------------------------------------------------

def cast_params(params, cfg: ModelConfig, ctx=None):
    """Mixed precision: compute in cfg.compute_dtype, master in param_dtype.

    Router weights stay fp32 (numerically sensitive softmax logits).
    When ctx is given, each bf16 copy is pinned to the SAME sharding as its
    fp32 master — otherwise XLA may all-gather FSDP-sharded weights in fp32
    and convert after (2x wire bytes + fp32 gathered buffers; measured)."""
    if cfg.cdtype == cfg.pdtype:
        return params
    specs = None
    if ctx is not None and ctx.mesh is not None:
        from repro.dist.sharding import tree_param_specs
        specs = tree_param_specs(params, ctx)
    flat = jax.tree_util.tree_flatten_with_path(params)
    spec_leaves = (jax.tree_util.tree_flatten(
        specs, is_leaf=lambda s: isinstance(s, jax.sharding.PartitionSpec))[0]
        if specs is not None else [None] * len(flat[0]))
    leaves = []
    for (kp, leaf), spec in zip(flat[0], spec_leaves):
        path = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                        for k in kp)
        if jnp.issubdtype(leaf.dtype, jnp.floating) and \
                not path.endswith("router"):
            leaf = leaf.astype(cfg.cdtype)
            if spec is not None:
                leaf = ctx.constrain(leaf, spec)
        leaves.append(leaf)
    return jax.tree_util.tree_unflatten(flat[1], leaves)


def _embed_inputs(params, batch, cfg: ModelConfig, ctx) -> jax.Array:
    x = layers.embed(batch["tokens"], params["embed"]).astype(cfg.cdtype)
    if cfg.vlm_patches and "patch_embeds" in batch:
        pe = batch["patch_embeds"].astype(cfg.cdtype)
        x = jax.lax.dynamic_update_slice(x, pe, (0, 0, 0))
    if ctx is not None and ctx.mesh is not None:
        seq_ax = (ctx.model_if_divisible(x.shape[1])
                  if ctx.seq_shard_acts else None)
        x = ctx.constrain(x, ctx.batch_spec(seq_ax, None))
    return x


def forward(params, batch, cfg: ModelConfig, ctx: ShardCtx | None,
            *, mode: str = "train"):
    """batch: {"tokens": (B,S) int32, optional "patch_embeds"}.

    Returns (logits (B,S,V), aux, cache_states)."""
    # serve paths keep their (possibly 2D fsdp) weight placement; only the
    # train path pins bf16 copies to the master sharding
    params = cast_params(params, cfg, ctx if mode == "train" else None)
    if cfg.encoder is not None:
        from repro.models import encdec
        return encdec.forward(params, batch, cfg, ctx, mode=mode)
    x = _embed_inputs(params, batch, cfg, ctx)
    B, S = batch["tokens"].shape
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))

    def sb_fn(carry, sb_p):
        x, aux = carry
        x, aux_d, upd = superblock_apply(sb_p, x, cfg, ctx, positions,
                                         mode=mode)
        return (x, aux + aux_d), upd

    body = sb_fn
    if cfg.remat == "full":
        body = jax.checkpoint(sb_fn)
    elif cfg.remat == "dots":
        body = jax.checkpoint(
            sb_fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)

    if cfg.scan_layers:
        (x, aux), cache_states = jax.lax.scan(
            body, (x, jnp.zeros((), jnp.float32)), params["blocks"])
    else:
        aux = jnp.zeros((), jnp.float32)
        cache_states = []
        for sbi in range(cfg.n_superblocks):
            sb_p = jax.tree.map(lambda a: a[sbi], params["blocks"])
            (x, aux), upd = body((x, aux), sb_p)
            cache_states.append(upd)
        if mode == "prefill" and cache_states and cache_states[0]:
            cache_states = jax.tree.map(lambda *xs: jnp.stack(xs),
                                        *cache_states)

    if mode == "prefill":
        x = x[:, -1:]  # serving prefill only needs next-token logits
    x = layers.rms_norm(x, params["final_norm"], cfg.norm_eps)
    if mode == "hidden":
        return x, aux, cache_states
    head = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    logits = layers.unembed(x, head.astype(cfg.cdtype))
    if ctx is not None and ctx.mesh is not None:
        logits = ctx.constrain(
            logits, ctx.batch_spec(None, ctx.model_if_divisible(cfg.vocab)))
    return logits, aux, cache_states


def label_logprob_terms(logits: jax.Array, labels: jax.Array):
    """(lse, ll) computed WITHOUT gathering over the (model-sharded) vocab
    axis: reductions partition cleanly (partial + all-reduce); a
    take_along_axis here would force an all-gather of full-vocab fp32
    logits (~13 GiB/device at granite scale — measured)."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    onehot = labels[..., None] == jax.lax.broadcasted_iota(
        jnp.int32, logits.shape, logits.ndim - 1)
    ll = jnp.sum(jnp.where(onehot, logits, 0.0), axis=-1)
    return lse, ll


def chunked_xent(x, head, labels, w, ctx, *, chunk: int = 512):
    """Head matmul + cross entropy, scanned over sequence chunks with remat.

    Full-sequence logits at 262k vocab are multi-GiB fp32 per device; the
    chunked form keeps only (B, chunk, V) alive (recomputed in backward)."""
    B, S, _ = x.shape
    if S % chunk or S <= chunk:
        chunk = S
    nc = S // chunk

    @jax.checkpoint
    def body(carry, i):
        sl = lambda a: jax.lax.dynamic_slice_in_dim(a, i * chunk, chunk, 1)
        logits = layers.unembed(sl(x), head)
        if ctx is not None and ctx.mesh is not None:
            logits = ctx.constrain(logits, ctx.batch_spec(
                None, ctx.model_if_divisible(head.shape[0])))
        lse, ll = label_logprob_terms(logits, sl(labels))
        ws = sl(w)
        num, den = carry
        return (num + jnp.sum((lse - ll) * ws), den + jnp.sum(ws)), None

    (num, den), _ = jax.lax.scan(
        body, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
        jnp.arange(nc))
    return num / jnp.maximum(den, 1.0)


def loss_fn(params, batch, cfg: ModelConfig, ctx: ShardCtx | None):
    """Next-token cross entropy; batch: tokens, labels, loss_weight."""
    x, aux, _ = forward(params, batch, cfg, ctx, mode="hidden")
    labels = batch["labels"]
    w = batch.get("loss_weight")
    if w is None:
        w = jnp.ones(labels.shape, jnp.float32)
    head = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    loss = chunked_xent(x, head.astype(cfg.cdtype), labels, w, ctx)
    return loss + aux, {"loss": loss, "aux": aux}
