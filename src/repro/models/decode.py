"""Single-token decode over the interleaved KV cache (serve_step body).

Cache layout (EARTH): each attention layer stores K and V interleaved along
features — appending a token is ONE dynamic_update_slice per layer (the
coalesced segment transaction), splitting at attention time is a FIELD=2
segment load. Sliding-window layers keep a ring buffer of exactly W beats
(RoPE is applied pre-cache, so scores are storage-order independent).

SSM / xLSTM blocks carry O(1) recurrent state — no KV growth, which is why
those archs run the long_500k cell.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro import vx
from repro.kernels import kv_interleaved
from repro.models import attention, layers
from repro.models.ssm import init_mamba_cache, mamba_decode_step
from repro.models.transformer import ModelConfig, _ffn_apply
from repro.models.xlstm import (init_mlstm_state, init_slstm_state,
                                mlstm_decode_step, slstm_decode_step)


def cache_len_for_pos(cfg: ModelConfig, i: int, max_len: int) -> int:
    w = cfg.window_pattern[i]
    return min(w, max_len) if w is not None else max_len


def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype) -> dict:
    """Empty cache pytree; leaves stacked over superblocks (scan-ready)."""
    ns = cfg.n_superblocks
    blocks: dict[str, Any] = {}
    for i, kind in enumerate(cfg.block_pattern):
        if kind == "attn":
            sc = cache_len_for_pos(cfg, i, max_len)
            blocks[f"pos{i}"] = jnp.zeros(
                (ns, batch, sc, cfg.n_kv_heads, 2 * cfg.hd), dtype)
        elif kind == "mamba":
            c = init_mamba_cache(batch, cfg.mamba, dtype)
            blocks[f"pos{i}"] = jax.tree.map(
                lambda a: jnp.broadcast_to(a, (ns,) + a.shape), c)
        elif kind == "mlstm":
            s = init_mlstm_state(batch, cfg.xlstm)
            blocks[f"pos{i}"] = jax.tree.map(
                lambda a: jnp.broadcast_to(a, (ns,) + a.shape), s)
        elif kind == "slstm":
            s = init_slstm_state(batch, cfg.xlstm)
            blocks[f"pos{i}"] = jax.tree.map(
                lambda a: jnp.broadcast_to(a, (ns,) + a.shape), s)
    return {"len": jnp.zeros((), jnp.int32), "blocks": blocks}


def cache_from_prefill(cfg: ModelConfig, cache_states, seq_len: int,
                       max_len: int, dtype) -> dict:
    """Embed prefill-produced states into a max_len cache."""
    blocks = {}
    for i, kind in enumerate(cfg.block_pattern):
        st = cache_states[f"pos{i}"]
        if kind == "attn":
            sc = cache_len_for_pos(cfg, i, max_len)
            kv = st.astype(dtype)                      # (NS,B,S or W,K,2D)
            if kv.shape[2] < sc:
                kv = jnp.pad(kv, ((0, 0), (0, 0), (0, sc - kv.shape[2]),
                                  (0, 0), (0, 0)))
            elif kv.shape[2] > sc:
                kv = kv[:, :, :sc]
            blocks[f"pos{i}"] = kv
        else:
            blocks[f"pos{i}"] = st
    return {"len": jnp.asarray(seq_len, jnp.int32), "blocks": blocks}


def decode_step(params, cache: dict, token: jax.Array, cfg: ModelConfig,
                ctx, *, fuse: bool | None = None,
                kv_shard=None) -> tuple[jax.Array, dict]:
    """token: (B,) int32. Returns (logits (B, V), updated cache).

    ``fuse`` (default cfg.step_fusion) enables whole-step access fusion:
    the attention-time cache splits of EVERY layer — the step's dominant
    shift-routed traffic — are hoisted to the top of the step (they read
    the pre-append cache, which depends on nothing computed this step) and
    merged into ONE fused FIELD=2 segment load: one kernel launch and one
    mask operand per decode step instead of one per layer.  The current
    token's (k, v) is then written into the pre-split arrays at its slot
    (two one-beat updates), which is bit-exact with splitting the
    post-append cache because the segment op is a pure lane permutation.
    Single-token reorganizations (QKV beat pack/split, GLU field split)
    are inlined on the XLA path by the scheduler's launch policy.
    ``fuse=False`` keeps the per-access path (the equivalence oracle).

    ``kv_shard`` (a ``vx.Shard`` with ``axis=-3``, the cache sequence
    axis) marks the KV leaves as sequence-sharded: the fused split then
    lowers SHARD-LOCALLY under ``shard_map`` (repro.vx.lower), which is
    what lets long-context seq-parallel serving keep step fusion — the
    global split of a sharded leaf that used to force SPMD
    rematerialization is gone.  Leaves whose sequence extent does not
    divide across the shards (short sliding windows) fall back to the
    replicated lowering, each group still one fused launch.
    """
    from repro.models.transformer import cast_params
    params = cast_params(params, cfg)
    if cfg.encoder is not None:
        from repro.models import encdec
        return encdec.decode_step(params, cache, token, cfg, ctx)
    fuse = cfg.step_fusion if fuse is None else fuse
    pol = cfg.vx_policy
    B = token.shape[0]
    pos = cache["len"]
    x = layers.embed(token, params["embed"]).astype(cfg.cdtype)

    attn_pos = [i for i, k in enumerate(cfg.block_pattern) if k == "attn"]
    pre_split: dict[str, Any] = {}
    if fuse and attn_pos:
        # One fused split for all layers: leaves are stacked over
        # superblocks ((NS, B, Sc, K, 2D)), so this single call covers the
        # full depth; same-shape positions share one launch.  Sharded and
        # replicated leaves lower separately (the scheduler groups by
        # placement as well as shape).
        leaves = {i: cache["blocks"][f"pos{i}"] for i in attn_pos}
        sharded = [i for i, leaf in leaves.items()
                   if kv_shard is not None
                   and kv_shard.divides(leaf.shape[-3])]
        local = [i for i in attn_pos if i not in sharded]
        splits: dict[int, Any] = {}
        if sharded:
            outs = kv_interleaved.split_kv_step(
                [leaves[i] for i in sharded], policy=pol, shard=kv_shard)
            splits.update(dict(zip(sharded, outs)))
        if local:
            outs = kv_interleaved.split_kv_step(
                [leaves[i] for i in local], policy=pol)
            splits.update(dict(zip(local, outs)))
        pre_split = {f"pos{i}": splits[i] for i in attn_pos}
    # single-token reorganizations (QKV beat split, GLU field split) ride
    # the XLA path below the policy's fusion threshold during fused decode
    beat_pol = (pol.for_elems(B * cfg.n_kv_heads * 2 * cfg.hd)
                if fuse else pol)
    ffn_pol = pol.for_elems(B * 2 * cfg.d_ff) if fuse else pol

    def sb_step(x, inp):
        sb_p, sb_c, sb_pre = inp
        new_c = {}
        for i, kind in enumerate(cfg.block_pattern):
            p = sb_p[f"pos{i}"]
            if kind == "attn":
                h = layers.rms_norm(x, p["ln1"], cfg.norm_eps)
                positions = jnp.broadcast_to(pos, (B, 1))
                q, k, v, kv = attention.qkv_project(
                    p["attn"], h[:, None], cfg.n_heads, cfg.n_kv_heads,
                    cfg.hd, positions, cfg.rope_theta, policy=beat_pol)
                kvc = sb_c[f"pos{i}"]                      # (B, Sc, K, 2D)
                sc = kvc.shape[1]
                slot = jax.lax.rem(pos, sc)
                kvc = jax.lax.dynamic_update_slice_in_dim(
                    kvc, kv.astype(kvc.dtype), slot, axis=1)
                if fuse:
                    k_pre, v_pre = sb_pre[f"pos{i}"]
                    k_all = jax.lax.dynamic_update_slice_in_dim(
                        k_pre, k.astype(kvc.dtype), slot, axis=1)
                    v_all = jax.lax.dynamic_update_slice_in_dim(
                        v_pre, v.astype(kvc.dtype), slot, axis=1)
                else:
                    k_all, v_all = vx.transpose(
                        vx.Segment(n=kvc.shape[-1], fields=2), kvc,
                        policy=pol)
                eff_len = jnp.minimum(pos + 1, sc)
                out = attention.decode_attention(
                    q[:, 0], k_all, v_all, eff_len, window=None)
                x = x + (out.reshape(B, cfg.n_heads * cfg.hd)
                         @ p["attn"]["wo"]).astype(x.dtype)
                new_c[f"pos{i}"] = kvc
            elif kind == "mamba":
                h = layers.rms_norm(x, p["ln1"], cfg.norm_eps)
                pm = dict(p["mamba"])
                pm["in_proj"] = pm["in_proj"].reshape(cfg.d_model,
                                                      2 * cfg.mamba.ed)
                y, st = mamba_decode_step(pm, h, sb_c[f"pos{i}"], cfg.mamba)
                x = x + y
                new_c[f"pos{i}"] = st
            elif kind == "mlstm":
                h = layers.rms_norm(x, p["ln1"], cfg.norm_eps)
                px = dict(p["xl"])
                px["up"] = px["up"].reshape(cfg.d_model,
                                            2 * cfg.xlstm.m_inner)
                y, st = mlstm_decode_step(px, h, sb_c[f"pos{i}"], cfg.xlstm)
                x = x + y
                new_c[f"pos{i}"] = st
            elif kind == "slstm":
                h = layers.rms_norm(x, p["ln1"], cfg.norm_eps)
                y, st = slstm_decode_step(p["slstm"], h, sb_c[f"pos{i}"],
                                          cfg.xlstm)
                x = x + y
                new_c[f"pos{i}"] = st
            if cfg.pos_has_ffn(i):
                x2, _ = _ffn_apply(p, x[:, None], cfg, ctx, i,
                                   policy=ffn_pol)
                x = x2[:, 0]
        return x, new_c

    if cfg.scan_layers:
        x, new_blocks = jax.lax.scan(
            sb_step, x, (params["blocks"], cache["blocks"], pre_split))
    else:
        outs = []
        for sbi in range(cfg.n_superblocks):
            sb = jax.tree.map(lambda a: a[sbi], params["blocks"])
            cb = jax.tree.map(lambda a: a[sbi], cache["blocks"])
            pb = jax.tree.map(lambda a: a[sbi], pre_split)
            x, nb = sb_step(x, (sb, cb, pb))
            outs.append(nb)
        new_blocks = jax.tree.map(lambda *xs: jnp.stack(xs), *outs)

    x = layers.rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    logits = layers.unembed(x, head.astype(cfg.cdtype))
    return logits, {"len": pos + 1, "blocks": new_blocks}
