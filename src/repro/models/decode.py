"""Single-token decode over the interleaved KV cache (serve_step body).

Cache layout (EARTH): each attention layer stores K and V interleaved along
features — appending a token is ONE dynamic_update_slice per layer (the
coalesced segment transaction), splitting at attention time is a FIELD=2
segment load. Sliding-window layers keep a ring buffer of exactly W beats
(RoPE is applied pre-cache, so scores are storage-order independent).

SSM / xLSTM blocks carry O(1) recurrent state — no KV growth, which is why
those archs run the long_500k cell.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro import vx
from repro.kernels import kv_interleaved
from repro.models import attention, layers
from repro.models.ssm import init_mamba_cache, mamba_decode_step
from repro.models.transformer import ModelConfig, _ffn_apply
from repro.models.xlstm import (init_mlstm_state, init_slstm_state,
                                mlstm_decode_step, slstm_decode_step)


def cache_len_for_pos(cfg: ModelConfig, i: int, max_len: int) -> int:
    w = cfg.window_pattern[i]
    return min(w, max_len) if w is not None else max_len


def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype) -> dict:
    """Empty cache pytree; leaves stacked over superblocks (scan-ready)."""
    ns = cfg.n_superblocks
    blocks: dict[str, Any] = {}
    for i, kind in enumerate(cfg.block_pattern):
        if kind == "attn":
            sc = cache_len_for_pos(cfg, i, max_len)
            blocks[f"pos{i}"] = jnp.zeros(
                (ns, batch, sc, cfg.n_kv_heads, 2 * cfg.hd), dtype)
        elif kind == "mamba":
            c = init_mamba_cache(batch, cfg.mamba, dtype)
            blocks[f"pos{i}"] = jax.tree.map(
                lambda a: jnp.broadcast_to(a, (ns,) + a.shape), c)
        elif kind == "mlstm":
            s = init_mlstm_state(batch, cfg.xlstm)
            blocks[f"pos{i}"] = jax.tree.map(
                lambda a: jnp.broadcast_to(a, (ns,) + a.shape), s)
        elif kind == "slstm":
            s = init_slstm_state(batch, cfg.xlstm)
            blocks[f"pos{i}"] = jax.tree.map(
                lambda a: jnp.broadcast_to(a, (ns,) + a.shape), s)
    return {"len": jnp.zeros((), jnp.int32), "blocks": blocks}


def cache_from_prefill(cfg: ModelConfig, cache_states, seq_len: int,
                       max_len: int, dtype) -> dict:
    """Embed prefill-produced states into a max_len cache."""
    blocks = {}
    for i, kind in enumerate(cfg.block_pattern):
        st = cache_states[f"pos{i}"]
        if kind == "attn":
            sc = cache_len_for_pos(cfg, i, max_len)
            kv = st.astype(dtype)                      # (NS,B,S or W,K,2D)
            if kv.shape[2] < sc:
                kv = jnp.pad(kv, ((0, 0), (0, 0), (0, sc - kv.shape[2]),
                                  (0, 0), (0, 0)))
            elif kv.shape[2] > sc:
                kv = kv[:, :, :sc]
            blocks[f"pos{i}"] = kv
        else:
            blocks[f"pos{i}"] = st
    return {"len": jnp.asarray(seq_len, jnp.int32), "blocks": blocks}


# ---------------------------------------------------------------------------
# Paged KV cache: per-slot positions, a shared page pool per layer, one
# page table — memory scales with ACTIVE tokens, not slots x max_len.
# ---------------------------------------------------------------------------

def pages_per_seq(max_len: int, page_size: int) -> int:
    return -(-max_len // page_size)


def init_paged_cache(cfg: ModelConfig, slots: int, max_len: int,
                     page_size: int, dtype, *,
                     num_pages: int | None = None,
                     quantize: str | None = None) -> dict:
    """Paged cache pytree.

    Attention layers store a shared page POOL ``(NS, num_pages,
    page_size, K, 2D)`` instead of per-slot dense ``(NS, slots, max_len,
    K, 2D)`` buffers; ``table`` maps each slot's logical pages to
    physical pool pages (``-1`` = unallocated), ``pos`` is the per-slot
    position vector, and ``free``/``free_top`` form the device-side
    free-page stack (``free[:free_top]`` are free ids).  ``num_pages``
    defaults to full provisioning (``slots * pages_per_seq``); size it to
    the expected peak of active tokens to reclaim the memory.

    All attention layers page the FULL logical length (sliding windows
    become attention-time masks, not ring buffers — unattended pages of a
    finished window are reclaimable like any other).  Recurrent leaves
    (mamba/xlstm) stay per-slot O(1) state, as in :func:`init_cache`.

    ``ref`` is the device-side per-page REFERENCE COUNT: a physical page
    may back several slots' table entries at once (prefix sharing) plus
    external pins (the prefix trie, serve/prefix_cache.py).  Allocation
    sets ref=1; :func:`paged_adopt_prefix` / :func:`paged_addref` bump
    it; release/deref push a page back on the free stack only when its
    count hits zero.  The count lives on device because the decode step
    allocates inside jit — a host mirror would drift.

    ``quantize`` ("int8" / "fp8") switches the pool to the QUANTIZED
    layout: attention pool leaves store int8/fp8 and each gains a
    per-page-per-head float32 scale side tensor ``scl{i}`` of shape
    ``(NS, num_pages, K)`` living alongside ``pos{i}`` in ``blocks`` —
    scales ride the same scan/stack/fork plumbing as the pools, and the
    scale of physical page ``p`` travels with ``p`` through prefix
    adoption and CoW forks for free.  Recurrent leaves stay ``dtype``.
    """
    ns = cfg.n_superblocks
    n_seq = pages_per_seq(max_len, page_size)
    if num_pages is None:
        num_pages = slots * n_seq
    qdt = None
    if quantize is not None:
        from repro.core import quant
        qdt = quant.pool_dtype(quantize)
    blocks: dict[str, Any] = {}
    for i, kind in enumerate(cfg.block_pattern):
        if kind == "attn":
            blocks[f"pos{i}"] = jnp.zeros(
                (ns, num_pages, page_size, cfg.n_kv_heads, 2 * cfg.hd),
                qdt if qdt is not None else dtype)
            if qdt is not None:
                blocks[f"scl{i}"] = jnp.zeros(
                    (ns, num_pages, cfg.n_kv_heads), jnp.float32)
        elif kind == "mamba":
            c = init_mamba_cache(slots, cfg.mamba, dtype)
            blocks[f"pos{i}"] = jax.tree.map(
                lambda a: jnp.broadcast_to(a, (ns,) + a.shape), c)
        elif kind == "mlstm":
            s = init_mlstm_state(slots, cfg.xlstm)
            blocks[f"pos{i}"] = jax.tree.map(
                lambda a: jnp.broadcast_to(a, (ns,) + a.shape), s)
        elif kind == "slstm":
            s = init_slstm_state(slots, cfg.xlstm)
            blocks[f"pos{i}"] = jax.tree.map(
                lambda a: jnp.broadcast_to(a, (ns,) + a.shape), s)
    return {
        "pos": jnp.zeros((slots,), jnp.int32),
        "table": jnp.full((slots, n_seq), -1, jnp.int32),
        # descending so pages allocate in 0, 1, 2, ... order
        "free": jnp.arange(num_pages - 1, -1, -1, dtype=jnp.int32),
        "free_top": jnp.asarray(num_pages, jnp.int32),
        "ref": jnp.zeros((num_pages,), jnp.int32),
        "blocks": blocks,
    }


def _paged_geometry(cfg: ModelConfig, cache: dict):
    """(attn positions, page_size, pages_per_seq) from the cache leaves."""
    attn_pos = [i for i, k in enumerate(cfg.block_pattern) if k == "attn"]
    n_seq = cache["table"].shape[-1]
    ps = (cache["blocks"][f"pos{attn_pos[0]}"].shape[2] if attn_pos else 1)
    return attn_pos, ps, n_seq


def _pool_quantized(cache: dict, attn_pos) -> bool:
    """Quantized pool detection from the cache pytree itself (the scale
    side tensors are present) — every paged op switches on this, so a
    quantized cache flows through the scheduler unannotated."""
    return bool(attn_pos) and f"scl{attn_pos[0]}" in cache["blocks"]


def paged_invariants(cfg: ModelConfig, cache: dict, *,
                     external_ref=None) -> list[str]:
    """Audit the paged cache's STRUCTURAL invariants on a live pytree.

    Returns a list of human-readable violations (empty = healthy):

      * refcount conservation — every page's device refcount equals the
        number of table entries referencing it plus its EXTERNAL pins
        (``external_ref``: the prefix trie's per-page counts,
        serve/prefix_cache.py).  More table entries than refs is page
        ALIASING (a page shared without the books knowing would silently
        cross-contaminate attention and be reclaimed under a live slot);
      * free-stack consistency — ``free[:free_top]`` ids are in range and
        distinct; a page is on the free stack IFF its refcount is zero
        (an owned page on the stack is "both allocated and free"; a
        zero-ref page missing from it is leaked);
      * copy-on-write — a slot whose position ends MID-page must own its
        tail page exclusively (ref == 1): appends write that page in
        place, so a shared tail means a shared page is being written;
      * pos-vs-table occupancy — a slot at position ``p`` references at
        most ``ceil(p / page_size)`` pages, all at logical indices below
        that extent (starved slots may hold FEWER — local degradation —
        but never pages beyond their position);
      * bounds — ``0 <= free_top <= num_pages``, refcounts non-negative,
        positions within the logical capacity;
      * scale liveness (QUANTIZED pools) — every attention layer carries
        its ``scl{i}`` side tensor (all-or-none: a layer missing scales
        would gather garbage), scale geometry matches the pool, and
        every scale is finite and non-negative (the quantize safe-divide
        never writes NaN; a negative or non-finite scale means a page's
        beats can no longer be dequantized — the scale-tensor
        counterpart of refcount conservation).

    ONE device fetch (table / free / free_top / pos / ref — the small
    int state — plus the per-page scale tensors when quantized; the pool
    itself is never pulled), so the check is cheap enough to run
    per-step under the chaos harness.  The serve wrapper
    (serve/paged_cache.py ``check_invariants``) raises on violations.
    """
    import numpy as np
    attn_pos, ps, n_seq = _paged_geometry(cfg, cache)
    table, free, free_top, pos, ref = jax.device_get(
        (cache["table"], cache["free"], cache["free_top"], cache["pos"],
         cache["ref"]))
    table, free, pos, ref = (np.asarray(table), np.asarray(free),
                             np.asarray(pos), np.asarray(ref))
    free_top = int(free_top)
    num_pages = free.shape[0]
    out: list[str] = []
    if not attn_pos:
        return out                      # recurrent-only: no pool to audit
    if not (0 <= free_top <= num_pages):
        out.append(f"free_top={free_top} outside [0, {num_pages}]")
        return out                      # downstream slicing meaningless
    ext = (np.zeros(num_pages, np.int64) if external_ref is None
           else np.asarray(external_ref, np.int64))
    owned = table[table >= 0]
    if owned.size and (owned >= num_pages).any():
        out.append(f"table holds out-of-range page ids "
                   f"{sorted(set(owned[owned >= num_pages].tolist()))}")
        owned = owned[owned < num_pages]
    tc = np.bincount(owned, minlength=num_pages)     # table references
    if (ref < 0).any():
        out.append(f"page(s) {np.nonzero(ref < 0)[0].tolist()} hold "
                   f"negative refcounts (double release)")
    aliased = np.nonzero(tc > ref)[0]
    if aliased.size:
        out.append(f"page(s) {aliased.tolist()} aliased between slots "
                   f"(referenced {tc[aliased].tolist()} times, "
                   f"refcount {ref[aliased].tolist()})")
    bad_ref = np.nonzero((tc <= ref) & (ref != tc + ext))[0]
    if bad_ref.size:
        out.append(f"refcount conservation broken for page(s) "
                   f"{bad_ref.tolist()}: refcount "
                   f"{ref[bad_ref].tolist()} != table refs "
                   f"{tc[bad_ref].tolist()} + external pins "
                   f"{ext[bad_ref].tolist()}")
    stack = free[:free_top]
    uniq_f = np.unique(stack)
    if uniq_f.size != stack.size:
        out.append("free stack holds duplicate page ids")
    both = uniq_f[(tc[uniq_f] > 0) | (ref[uniq_f] > 0)] \
        if uniq_f.size else uniq_f
    if both.size:
        out.append(f"page(s) {both.tolist()} both allocated and free")
    live = np.zeros(num_pages, bool)
    live[uniq_f] = True
    leaked = np.nonzero(~live & (ref == 0) & (tc == 0))[0]
    if leaked.size:
        out.append(f"page(s) {leaked.tolist()} leaked (refcount zero "
                   f"but not on the free stack)")
    for s in range(table.shape[0]):
        p = int(pos[s])
        if p % ps != 0 and 0 <= p <= n_seq * ps:
            tail = int(table[s, p // ps])
            if tail >= 0 and ref[tail] > 1:
                out.append(f"slot {s}: partial tail page {tail} is "
                           f"SHARED (ref={int(ref[tail])}) — appends "
                           f"would write a shared page in place "
                           f"(missing copy-on-write fork)")
    for s in range(table.shape[0]):
        alloc = np.nonzero(table[s] >= 0)[0]
        p = int(pos[s])
        if not (0 <= p <= n_seq * ps):
            out.append(f"slot {s}: pos={p} outside [0, {n_seq * ps}]")
            continue
        extent = -(-p // ps)            # pages the position can reach
        if alloc.size > extent:
            out.append(f"slot {s}: owns {alloc.size} pages but "
                       f"pos={p} spans only {extent}")
        if alloc.size and alloc.max() >= extent:
            out.append(f"slot {s}: page at logical index "
                       f"{int(alloc.max())} beyond pos={p} extent "
                       f"{extent}")
    scl_layers = [i for i in attn_pos if f"scl{i}" in cache["blocks"]]
    if scl_layers:
        if len(scl_layers) != len(attn_pos):
            missing = sorted(set(attn_pos) - set(scl_layers))
            out.append(f"quantized pool missing scale tensor(s) for "
                       f"attention layer(s) {missing}")
        scls = jax.device_get([cache["blocks"][f"scl{i}"]
                               for i in scl_layers])
        for i, s in zip(scl_layers, scls):
            s = np.asarray(s)
            pool = cache["blocks"][f"pos{i}"]
            if s.shape[1] != num_pages or s.shape[0] != pool.shape[0] \
                    or s.shape[2] != pool.shape[3]:
                out.append(f"layer {i}: scale tensor shape {s.shape} "
                           f"does not match pool "
                           f"(NS={pool.shape[0]}, P={num_pages}, "
                           f"K={pool.shape[3]})")
                continue
            if not np.isfinite(s).all():
                bad = sorted(set(np.nonzero(~np.isfinite(s))[1].tolist()))
                out.append(f"layer {i}: non-finite scale on page(s) "
                           f"{bad} — beats there can never be "
                           f"dequantized")
            elif (s < 0).any():
                bad = sorted(set(np.nonzero(s < 0)[1].tolist()))
                out.append(f"layer {i}: negative scale on page(s) {bad}")
    return out


def _keep_active(new, old, active):
    """Per-slot state gate: inactive slots keep their old state."""
    def sel(n, o):
        m = active.reshape(active.shape + (1,) * (n.ndim - 1))
        return jnp.where(m, n, o)
    return jax.tree.map(sel, new, old)


def _deref_push(ref, free, free_top, ids):
    """Drop one reference from each page id in ``ids`` (pad with -1; ids
    must be distinct) and push pages whose count hits ZERO back on the
    free stack — shared pages (prefix runs still referenced by other
    slots or pinned by the trie) survive.  jit-safe."""
    ids = jnp.asarray(ids, jnp.int32)
    valid = ids >= 0
    safe = jnp.where(valid, ids, 0)
    ref = ref.at[safe].add(-valid.astype(jnp.int32))
    orphan = valid & (ref[safe] <= 0)
    rank = jnp.cumsum(orphan.astype(jnp.int32)) - orphan
    dst = jnp.where(orphan, free_top + rank, free.shape[0])
    free = free.at[dst].set(ids, mode="drop")
    free_top = free_top + jnp.sum(orphan.astype(jnp.int32))
    return ref, free, free_top


def paged_release_slot(cfg: ModelConfig, cache: dict, slot) -> dict:
    """Free a slot: drop one reference from each of its pages — ORPHANED
    pages (refcount zero) go back on the free stack, shared prefix pages
    survive for their other referents — then clear the slot's page table
    row and position and reset its recurrent state to init: a reused
    slot can never attend to (or carry) the previous occupant's state.
    Pool pages are NOT zeroed: a new occupant overwrites position ``p``
    before ``p`` ever becomes attendable (``eff_len`` masking), so stale
    beats are unreachable.  jit-safe (``slot`` may be traced)."""
    slot = jnp.asarray(slot, jnp.int32)
    table, free, free_top = cache["table"], cache["free"], cache["free_top"]
    row = jnp.take(table, slot, axis=0)                  # (pages,)
    ref, free, free_top = _deref_push(cache["ref"], free, free_top, row)
    table = table.at[slot].set(-1)
    pos = cache["pos"].at[slot].set(0)
    blocks = dict(cache["blocks"])
    for i, kind in enumerate(cfg.block_pattern):
        if kind == "attn":
            continue
        if kind == "mamba":
            ini = init_mamba_cache(1, cfg.mamba, jnp.float32)
        elif kind == "mlstm":
            ini = init_mlstm_state(1, cfg.xlstm)
        else:
            ini = init_slstm_state(1, cfg.xlstm)
        leaf = blocks[f"pos{i}"]
        blocks[f"pos{i}"] = jax.tree.map(
            lambda c, s: c.at[:, slot].set(
                jnp.broadcast_to(s[0], c.shape[2:]).astype(c.dtype)),
            leaf, ini)
    return {"pos": pos, "table": table, "free": free, "free_top": free_top,
            "ref": ref, "blocks": blocks}


def paged_insert_prefill(cfg: ModelConfig, cache: dict, slot,
                         cache_states, length, state_len: int) -> dict:
    """Embed B=1 prefill states into a slot's pages.

    ``state_len`` (static) is the sequence extent the prefill ran on —
    any length; attention beats are zero-padded here to whole pages
    (the padded tail is masked by ``eff_len`` until the decode loop
    overwrites it in place).  ``length`` is the true prompt length and
    may be TRACED, so ONE jit entry serves every prompt of the same
    ``state_len``.  NOTE on padding the PREFILL itself (running it on
    more tokens than the prompt): that is only sound for windowless
    attention-only stacks — a ring-trimmed window leaf is cut at the
    padded length (real in-window beats are lost) and recurrent state
    absorbs the pad tokens irreversibly; the serving scheduler therefore
    pads the prefill only when every block is windowless attention.
    Allocates ``ceil(state_len / page_size)`` pages off the free stack.
    jit-safe (``slot``/``length`` may be traced)."""
    attn_pos, ps, n_seq = _paged_geometry(cfg, cache)
    n_pg = -(-state_len // ps)
    sp = n_pg * ps
    if n_pg > n_seq:
        raise ValueError(f"state_len={state_len} needs {n_pg} pages, the "
                         f"table holds {n_seq}")
    slot = jnp.asarray(slot, jnp.int32)
    length = jnp.asarray(length, jnp.int32)
    free, free_top = cache["free"], cache["free_top"]
    # exhaustion degrades locally (like the decode-step allocator): pages
    # beyond the free count stay -1 in the table and their beats are
    # dropped — never an aliased page.  serve/paged_cache.py refuses the
    # insert host-side before it comes to that.
    k = jnp.arange(n_pg)
    have = k < free_top
    newp = jnp.where(have, free[jnp.clip(free_top - 1 - k, 0,
                                         free.shape[0] - 1)], -1)
    free_top = free_top - jnp.sum(have.astype(jnp.int32))
    table = cache["table"].at[slot, :n_pg].set(newp)
    pos = cache["pos"].at[slot].set(length)
    scatter_ids = jnp.where(have, newp, free.shape[0])
    ref = cache["ref"].at[scatter_ids].add(1, mode="drop")
    quantized = _pool_quantized(cache, attn_pos)
    blocks = dict(cache["blocks"])
    for i, kind in enumerate(cfg.block_pattern):
        st = cache_states[f"pos{i}"]
        leaf = blocks[f"pos{i}"]
        if kind == "attn":
            # quantized pools keep the prefill states float here and
            # quantize per page below (casting to the int leaf dtype
            # would truncate)
            kv = st.astype(jnp.float32 if quantized else leaf.dtype)
            w = cfg.window_pattern[i]
            if w is not None and kv.shape[2] < state_len:
                # prefill ring-trimmed the window at state_len: un-roll
                # to natural order and park at positions
                # [state_len - W, state_len)
                W = kv.shape[2]
                nat = jnp.roll(kv, -(state_len % W), axis=2)
                full = jnp.zeros(kv.shape[:2] + (sp,) + kv.shape[3:],
                                 kv.dtype)
                kv = full.at[:, :, state_len - W:state_len].set(nat)
            elif kv.shape[2] != state_len:
                raise ValueError(
                    f"prefill states carry {kv.shape[2]} beats; expected "
                    f"state_len={state_len}")
            if kv.shape[2] < sp:       # zero-pad to whole pages
                kv = jnp.pad(kv, ((0, 0), (0, 0), (0, sp - kv.shape[2]),
                                  (0, 0), (0, 0)))
            beats = kv[:, 0].reshape(kv.shape[0], n_pg, ps, *kv.shape[3:])
            if quantized:
                # per-(superblock, page, head) max-abs scale over the
                # in-page and feature axes, then quantize the beats
                from repro.core import quant
                s = quant.scale_for(beats, leaf.dtype, axis=(2, 4))
                beats = quant.quantize(beats, s[:, :, None, :, None],
                                       leaf.dtype)
                blocks[f"scl{i}"] = blocks[f"scl{i}"].at[
                    :, scatter_ids].set(s, mode="drop")
            blocks[f"pos{i}"] = leaf.at[:, scatter_ids].set(beats,
                                                            mode="drop")
        else:
            blocks[f"pos{i}"] = jax.tree.map(
                lambda c, s: c.at[:, slot].set(s[:, 0].astype(c.dtype)),
                leaf, st)
    return {"pos": pos, "table": table, "free": free, "free_top": free_top,
            "ref": ref, "blocks": blocks}


def paged_adopt_prefix(cfg: ModelConfig, cache: dict, slot,
                       page_ids) -> dict:
    """Point a freshly-admitted slot's page table at SHARED prefix pages.

    ``page_ids`` is ``(pages_per_seq,)`` int32 — the physical page run
    backing the slot's leading logical pages, padded with -1.  Each
    adopted page gains one reference and NO device data moves: the page
    gather already reads through the table, so a shared page costs
    nothing beyond the table row write.  The slot's position is set to
    ``(#adopted) * page_size`` (whole pages only — a partial tail is
    forked separately, :func:`paged_fork_page`).  The slot must be empty
    (released) before adoption.  jit-safe (``slot``/``page_ids`` may be
    traced)."""
    attn_pos, ps, n_seq = _paged_geometry(cfg, cache)
    slot = jnp.asarray(slot, jnp.int32)
    ids = jnp.asarray(page_ids, jnp.int32)
    valid = ids >= 0
    table = cache["table"].at[slot].set(jnp.where(valid, ids, -1))
    drop = cache["free"].shape[0]
    ref = cache["ref"].at[jnp.where(valid, ids, drop)].add(1, mode="drop")
    pos = cache["pos"].at[slot].set(
        jnp.sum(valid.astype(jnp.int32)) * ps)
    return {"pos": pos, "table": table, "free": cache["free"],
            "free_top": cache["free_top"], "ref": ref,
            "blocks": cache["blocks"]}


def paged_addref(cfg: ModelConfig, cache: dict, page_ids) -> dict:
    """Add one EXTERNAL reference (a prefix-trie pin) to each page id in
    ``page_ids`` (padded with -1).  Pinned pages survive the release of
    every slot that references them — the trie keeps published prefixes
    resident for future borrowers until it evicts them
    (:func:`paged_deref_pages`).  jit-safe."""
    ids = jnp.asarray(page_ids, jnp.int32)
    valid = ids >= 0
    drop = cache["free"].shape[0]
    ref = cache["ref"].at[jnp.where(valid, ids, drop)].add(1, mode="drop")
    out = dict(cache)
    out["ref"] = ref
    return out


def paged_deref_pages(cfg: ModelConfig, cache: dict, page_ids) -> dict:
    """Drop one reference from each page id in ``page_ids`` (padded with
    -1) — the trie-eviction counterpart of :func:`paged_addref`.  Pages
    whose count hits zero go back on the free stack.  jit-safe."""
    ref, free, free_top = _deref_push(cache["ref"], cache["free"],
                                      cache["free_top"], page_ids)
    out = dict(cache)
    out.update(ref=ref, free=free, free_top=free_top)
    return out


def paged_fork_page(cfg: ModelConfig, cache: dict, slot, logical_idx,
                    src, *, deref_src: bool = False, pos_to=None) -> dict:
    """Copy-on-write fork: pop a fresh page off the free stack, copy the
    SOURCE page's pool beats into it across every attention layer, and
    point ``table[slot, logical_idx]`` at the copy (refcount 1).

    Used at admission when a prompt's tail matches only PART of a trie
    page (the slot adopts the shared whole-page run, then forks the
    donor's next page to continue writing mid-page), and on any append
    that would land on a page with refcount > 1.  ``deref_src=True``
    additionally drops one reference on ``src`` — the append-time case,
    where the slot previously referenced the shared page; admission-time
    tail forks (the slot never referenced the donor page) leave the
    source's count alone.  ``pos_to`` (traced), when given, sets the
    slot's position (admission forks park it at ``k * page_size + m``).
    Callers check ``free_pages() >= 1`` host-side; an exhausted stack
    degrades locally (the entry stays -1, the copy drops).  jit-safe."""
    attn_pos, ps, n_seq = _paged_geometry(cfg, cache)
    slot = jnp.asarray(slot, jnp.int32)
    logical_idx = jnp.asarray(logical_idx, jnp.int32)
    src = jnp.asarray(src, jnp.int32)
    free, free_top, ref = cache["free"], cache["free_top"], cache["ref"]
    drop = free.shape[0]
    have = free_top > 0
    newp = jnp.where(have, free[jnp.clip(free_top - 1, 0, drop - 1)],
                     -1)
    free_top = free_top - have.astype(jnp.int32)
    table = cache["table"].at[slot, logical_idx].set(newp)
    ref = ref.at[jnp.where(have, newp, drop)].add(1, mode="drop")
    dst = jnp.where(have & (src >= 0), newp, drop)
    rst = jnp.where(have, newp, drop)
    srcc = jnp.clip(src, 0, drop - 1)
    blocks = dict(cache["blocks"])
    for i, kind in enumerate(cfg.block_pattern):
        if kind != "attn":
            continue
        leaf = blocks[f"pos{i}"]                  # (NS, P, ps, K, 2D)
        beat = jax.lax.dynamic_index_in_dim(leaf, srcc, axis=1)
        blocks[f"pos{i}"] = leaf.at[:, dst].set(beat[:, 0], mode="drop")
        if f"scl{i}" in blocks:
            # the scale forks WITH the page, BEFORE any write lands on
            # the copy (the monotone-widen rule then evolves the fork's
            # scale independently of the immutable shared source).  A
            # sourceless fork (src < 0: fresh empty page) resets the
            # scale instead — stale garbage would poison the first
            # widen's s_old.  Reset-then-copy: dst drops when src < 0,
            # so the reset survives exactly then.
            scl = blocks[f"scl{i}"]               # (NS, P, K)
            scl = scl.at[:, rst].set(0.0, mode="drop")
            srow = jax.lax.dynamic_index_in_dim(scl, srcc, axis=1)
            blocks[f"scl{i}"] = scl.at[:, dst].set(srow[:, 0],
                                                   mode="drop")
    if deref_src:
        ref, free, free_top = _deref_push(ref, free, free_top,
                                          jnp.where(src >= 0, src,
                                                    -1)[None])
    pos = cache["pos"]
    if pos_to is not None:
        pos = pos.at[slot].set(jnp.asarray(pos_to, jnp.int32))
    return {"pos": pos, "table": table, "free": free,
            "free_top": free_top, "ref": ref, "blocks": blocks}


def paged_prefill_chunk(params, cache: dict, tokens: jax.Array,
                        cfg: ModelConfig, ctx, *, slot, count) -> dict:
    """Prefill up to ``tokens.shape[0]`` prompt tokens into ONE slot,
    starting at the slot's current position (mid-page starts — e.g.
    after a copy-on-write tail fork — are fine).

    ``tokens`` has a FIXED width (the scheduler uses one page), so every
    chunk of a serving process shares ONE jit trace and one set of
    compiled access plans; ``count`` (traced) is the number of leading
    tokens that are real.  Pad tokens append nothing (dropped scatter
    rows), never touch recurrent state, and their garbage activations
    feed no output.

    This is the CANONICAL prefill path for the serving scheduler: every
    page's contents become a deterministic function of (the prefix
    tokens, this one trace), which is what makes prefix pages sharable
    bit-exactly — a borrower adopting a donor's pages reads exactly the
    bits it would have computed itself.  Missing pages in the touched
    range are allocated off the free stack (refcount 1), the chunk's KV
    beats scatter through the page table, C-query causal attention runs
    over the slot's gathered pages (sliding windows mask at attention
    time, as in the paged decode step), and recurrent blocks advance
    token-by-token under a scan.  No logits are computed: the scheduler
    feeds the LAST prompt token through the decode step to produce the
    first sampled token (the PR 6 replay cursor), so chunks cover
    ``prompt[:-1]`` only.  Returns the updated cache."""
    from repro.models.transformer import cast_params
    params = cast_params(params, cfg)
    if cfg.encoder is not None:
        raise NotImplementedError("paged serving covers decoder-only "
                                  "models; use encdec.decode_step")
    pol = cfg.vx_policy
    C = tokens.shape[0]
    slot = jnp.asarray(slot, jnp.int32)
    count = jnp.asarray(count, jnp.int32)
    attn_pos, ps, n_seq = _paged_geometry(cfg, cache)
    table, free, free_top = cache["table"], cache["free"], cache["free_top"]
    ref, pos = cache["ref"], cache["pos"]
    start = jnp.take(pos, slot)
    offs = jnp.arange(C)
    tpos = start + offs                          # (C,) token positions
    real = offs < count
    seq = n_seq * ps if attn_pos else (1 << 30)

    spec = None
    quantized = _pool_quantized(cache, attn_pos)
    blocks_in = cache["blocks"]
    if attn_pos:
        # allocate every missing page the chunk touches (same rank-pop as
        # the decode step; exhaustion degrades locally — entries stay -1
        # and the touched beats drop, never an aliased page)
        row = jnp.take(table, slot, axis=0)      # (n_seq,)
        idx = jnp.arange(n_seq)
        lastp = (start + jnp.maximum(count, 1) - 1) // ps
        neednew = (idx >= start // ps) & (idx <= lastp) & (row < 0)
        rank = jnp.cumsum(neednew.astype(jnp.int32)) - neednew
        have = neednew & (rank < free_top)
        newp = free[jnp.clip(free_top - 1 - rank, 0, free.shape[0] - 1)]
        row = jnp.where(have, newp, row)
        table = table.at[slot].set(row)
        free_top = free_top - jnp.sum(have.astype(jnp.int32))
        ref = ref.at[jnp.where(have, newp, free.shape[0])].add(
            1, mode="drop")
        if quantized:
            # fresh pages start at scale 0: stale garbage scales would
            # poison the monotone widen's s_old (and the rescale would
            # never zero the resident garbage ints)
            rst = jnp.where(have, newp, free.shape[0])
            blocks_in = dict(blocks_in)
            for i in attn_pos:
                blocks_in[f"scl{i}"] = blocks_in[f"scl{i}"].at[
                    :, rst].set(0.0, mode="drop")
        table_c = jnp.broadcast_to(row, (C, n_seq))
        wpos = jnp.where(real & (tpos < seq), tpos, -1)
        spec = vx.Paged(page_size=ps, pages=n_seq, trail=2)

    x = layers.embed(tokens, params["embed"]).astype(cfg.cdtype)[None]

    def _tok_scan(step_fn, state0, h, keep_dtype):
        """Advance per-slot recurrent state over the chunk's tokens; pad
        tokens are gated out of both the carry and the output."""
        def tok(st, inp):
            ht, on = inp                         # ht (1, d), on scalar
            y, st2 = step_fn(ht, st)
            st2 = _keep_active(st2, st, on[None])
            return st2, jnp.where(on, y, 0.0)
        st, ys = jax.lax.scan(tok, state0,
                              (jnp.swapaxes(h, 0, 1), real))
        return st, jnp.swapaxes(ys, 0, 1).astype(keep_dtype)

    def _slot_state(sb_state):
        return jax.tree.map(
            lambda a: jax.lax.dynamic_slice_in_dim(a, slot, 1, axis=0),
            sb_state)

    def _put_slot(sb_state, new1):
        return jax.tree.map(
            lambda full, s1: jax.lax.dynamic_update_slice_in_dim(
                full, s1.astype(full.dtype), slot, axis=0),
            sb_state, new1)

    def sb_step(x, inp):
        sb_p, sb_c = inp
        new_c = {}
        for i, kind in enumerate(cfg.block_pattern):
            p = sb_p[f"pos{i}"]
            if kind == "attn":
                h = layers.rms_norm(x, p["ln1"], cfg.norm_eps)
                q, k, v, kv = attention.qkv_project(
                    p["attn"], h, cfg.n_heads, cfg.n_kv_heads, cfg.hd,
                    tpos[None], cfg.rope_theta, policy=pol)
                pool = sb_c[f"pos{i}"]           # (P, ps, K, 2D)
                if quantized:
                    # quantize-on-write (scale widens monotonically),
                    # then the attention read dequantizes the slot's
                    # whole prefix — including the beats just written —
                    # in the same one-program gather
                    pool, scl = vx.scatter(spec, pool, kv[0],
                                           table=table_c, pos=wpos,
                                           scales=sb_c[f"scl{i}"],
                                           policy=pol)
                    full = vx.gather(spec, pool, table=row[None],
                                     scales=scl, policy=pol)
                    new_c[f"scl{i}"] = scl
                else:
                    pool = vx.scatter(spec, pool, kv[0], table=table_c,
                                      pos=wpos, policy=pol)
                    full = vx.gather(spec, pool, table=row[None],
                                     policy=pol)
                k_all, v_all = vx.transpose(
                    vx.Segment(n=full.shape[-1], fields=2), full,
                    policy=pol)
                out = attention.chunk_attention(
                    q, k_all, v_all, tpos, window=cfg.window_pattern[i])
                x = x + (out.reshape(1, C, cfg.n_heads * cfg.hd)
                         @ p["attn"]["wo"]).astype(x.dtype)
                new_c[f"pos{i}"] = pool
            elif kind == "mamba":
                h = layers.rms_norm(x, p["ln1"], cfg.norm_eps)
                pm = dict(p["mamba"])
                pm["in_proj"] = pm["in_proj"].reshape(cfg.d_model,
                                                      2 * cfg.mamba.ed)
                st, y = _tok_scan(
                    lambda ht, st: mamba_decode_step(pm, ht, st,
                                                     cfg.mamba),
                    _slot_state(sb_c[f"pos{i}"]), h, x.dtype)
                x = x + y
                new_c[f"pos{i}"] = _put_slot(sb_c[f"pos{i}"], st)
            elif kind == "mlstm":
                h = layers.rms_norm(x, p["ln1"], cfg.norm_eps)
                px = dict(p["xl"])
                px["up"] = px["up"].reshape(cfg.d_model,
                                            2 * cfg.xlstm.m_inner)
                st, y = _tok_scan(
                    lambda ht, st: mlstm_decode_step(px, ht, st,
                                                     cfg.xlstm),
                    _slot_state(sb_c[f"pos{i}"]), h, x.dtype)
                x = x + y
                new_c[f"pos{i}"] = _put_slot(sb_c[f"pos{i}"], st)
            elif kind == "slstm":
                h = layers.rms_norm(x, p["ln1"], cfg.norm_eps)
                st, y = _tok_scan(
                    lambda ht, st: slstm_decode_step(p["slstm"], ht, st,
                                                     cfg.xlstm),
                    _slot_state(sb_c[f"pos{i}"]), h, x.dtype)
                x = x + y
                new_c[f"pos{i}"] = _put_slot(sb_c[f"pos{i}"], st)
            if cfg.pos_has_ffn(i):
                x, _ = _ffn_apply(p, x, cfg, ctx, i, policy=pol)
        return x, new_c

    if cfg.scan_layers:
        _, new_blocks = jax.lax.scan(
            sb_step, x, (params["blocks"], blocks_in))
    else:
        outs = []
        for sbi in range(cfg.n_superblocks):
            sb = jax.tree.map(lambda a: a[sbi], params["blocks"])
            cb = jax.tree.map(lambda a: a[sbi], blocks_in)
            x, nb = sb_step(x, (sb, cb))
            outs.append(nb)
        new_blocks = jax.tree.map(lambda *xs: jnp.stack(xs), *outs)

    new_pos = pos.at[slot].set(jnp.minimum(start + count, seq))
    return {"pos": new_pos, "table": table, "free": free,
            "free_top": free_top, "ref": ref, "blocks": new_blocks}


def paged_truncate(cfg: ModelConfig, cache: dict, new_pos) -> dict:
    """Truncate every slot's position DOWN to ``new_pos`` (B,) and free
    the pages past the new extent — the draft-cache sync of speculative
    decoding (the draft ran ahead on tokens the target then rejected).

    Pages at logical indices >= ceil(new_pos / page_size) lose one
    reference and return to the free stack when their count hits zero.
    The truncated range must not be prefix-shared ACROSS slots (ids fed
    to the free-stack push must be distinct) — the serving scheduler
    only truncates the draft pool, which never runs the prefix trie.
    Beats left in the surviving tail page beyond ``new_pos`` are
    unreachable (``eff_len`` masking) and overwritten in place by the
    next append.  Recurrent leaves are untouched (the draft stack is
    validated attention-only by the scheduler).  jit-safe."""
    attn_pos, ps, n_seq = _paged_geometry(cfg, cache)
    table, free, free_top = cache["table"], cache["free"], cache["free_top"]
    pos = cache["pos"]
    new_pos = jnp.minimum(jnp.clip(jnp.asarray(new_pos, jnp.int32),
                                   0, n_seq * ps), pos)
    ext = (new_pos + ps - 1) // ps                   # surviving extent
    idx = jnp.arange(n_seq)[None, :]
    roll = (table >= 0) & (idx >= ext[:, None])
    ids = jnp.where(roll, table, -1).reshape(-1)
    ref, free, free_top = _deref_push(cache["ref"], free, free_top, ids)
    table = jnp.where(roll, -1, table)
    return {"pos": new_pos, "table": table, "free": free,
            "free_top": free_top, "ref": ref, "blocks": cache["blocks"]}


def paged_verify_step(params, cache: dict, tokens: jax.Array,
                      cfg: ModelConfig, ctx, *, n_draft, active=None,
                      fuse: bool | None = None, pool_shard=None):
    """Speculative K-token verify over the paged cache.

    ``tokens`` is ``(B, K)`` int32 — column 0 is each slot's CURRENT
    token (the last committed sample), columns 1..K-1 the draft model's
    proposals.  ``n_draft`` (B,) in [1, K] is the number of REAL columns
    per slot (traced: one jit trace serves every mixture of per-request
    speculation widths, so `vx.PLANS` sees one spec).  Returns
    ``(logits (B, K, V), out_tok (B, K), commit (B,), new_cache)``:
    ``out_tok[:, j] = argmax(logits[:, j])`` and ``commit`` is the
    greedy accept count — 1 (the token column 0 would have produced
    anyway) plus the number of LEADING drafts that match the argmax of
    the previous column.  The committed stream ``out_tok[b, :commit]``
    is exactly the token stream the non-speculative greedy oracle
    produces (K=1 degenerates to :func:`paged_decode_step` plus argmax).

    Access shape: the K draft positions stack along the BEAT axis of the
    existing ``vx.Paged`` programs — the append flattens ``(B, K)`` rows
    into ``(B*K,)`` scatter rows through the SAME paged-scatter arm the
    chunked prefill uses (table rows repeated K times), and the read is
    the SAME one fused page-gather + one fused FIELD=2 split as the
    single-token step: one spec, one gather eqn, one pinned launch,
    regardless of K.  Attention gathers PRE-append pages and the K fresh
    beats are inserted as floats (a scatter, not a gather), so fused /
    per-access / quantized arms all see bit-identical attention inputs,
    and float pools match the single-token oracle bitwise.

    Rejection rolls back through the page table ONLY: pages allocated
    this step at logical indices past the accept extent are guaranteed
    refcount-1 (a slot's pre-step pages never extend past its position
    — the invariant audit's occupancy rule), so the rollback clears
    their table entries and pushes them straight back on the free stack
    — no pool copy, no CoW trigger, and the refcount-conservation audit
    holds at every step boundary.  Beats written past the accept point
    (including the surviving tail page's rejected beats) become
    unreachable stale storage, overwritten before they are ever
    attendable.  QUANTIZED pools: rejected beats may have widened a
    surviving tail page's scale (the widen is monotone and never
    narrows), so speculation on int8/fp8 pools is bounded-error rather
    than bit-exact — same bound class as the quantized pool itself.

    Recurrent blocks (mamba/xlstm) advance token-by-token under a scan
    with every intermediate state collected; the state at the accept
    point is selected post-hoc (a K-way where, no gather), so rejected
    tokens never contaminate the carry.
    """
    from repro.models.transformer import cast_params
    params = cast_params(params, cfg)
    if cfg.encoder is not None:
        raise NotImplementedError("paged serving covers decoder-only "
                                  "models; use encdec.decode_step")
    fuse = cfg.step_fusion if fuse is None else fuse
    pol = cfg.vx_policy
    B, K = tokens.shape
    pos = cache["pos"]
    if active is None:
        active = jnp.ones((B,), bool)
    else:
        active = jnp.asarray(active, bool)
    n_draft = jnp.clip(jnp.asarray(n_draft, jnp.int32), 1, K)
    attn_pos, ps, n_seq = _paged_geometry(cfg, cache)
    table, free, free_top = cache["table"], cache["free"], cache["free_top"]
    ref = cache["ref"]
    quantized = _pool_quantized(cache, attn_pos)
    blocks_in = cache["blocks"]
    seq = n_seq * ps if attn_pos else (1 << 30)
    num_pages = free.shape[0] if attn_pos else 0

    offs = jnp.arange(K)[None, :]
    tpos = pos[:, None] + offs                       # (B, K) positions
    valid = active[:, None] & (offs < n_draft[:, None])
    have = newp_grid = None
    spec = None
    if attn_pos:
        # batched multi-page allocation: exactly the pages the oracle
        # would allocate crossing boundaries in [pos, pos + n_draft - 1]
        # (fresh pages start at a boundary >= pos, so a degraded missing
        # mid-page tail is never re-allocated — oracle behavior).
        # Exhaustion degrades locally, as in the single-token step.
        idx = jnp.arange(n_seq)[None, :]
        startp = (pos + ps - 1) // ps
        lastp = (pos + n_draft - 1) // ps
        need = (active[:, None] & (idx >= startp[:, None])
                & (idx <= lastp[:, None]) & (table < 0))
        flat = need.reshape(-1)
        rank = jnp.cumsum(flat.astype(jnp.int32)) - flat
        have_f = flat & (rank < free_top)
        newp_f = free[jnp.clip(free_top - 1 - rank, 0, num_pages - 1)]
        have = have_f.reshape(B, n_seq)
        newp_grid = jnp.where(have, newp_f.reshape(B, n_seq), -1)
        table = jnp.where(have, newp_grid, table)
        free_top = free_top - jnp.sum(have_f.astype(jnp.int32))
        ref = ref.at[jnp.where(have_f, newp_f, num_pages)].add(
            1, mode="drop")
        if quantized:
            rst = jnp.where(have_f, newp_f, num_pages)
            blocks_in = dict(blocks_in)
            for i in attn_pos:
                blocks_in[f"scl{i}"] = blocks_in[f"scl{i}"].at[
                    :, rst].set(0.0, mode="drop")
        spec = vx.Paged(page_size=ps, pages=n_seq, trail=2)
    # K-beat append plumbing: (B*K,) scatter rows through the table rows
    # repeated K times — the chunked-prefill shape, one program per layer
    wpos = jnp.where(valid & (tpos < seq), tpos, -1)       # (B, K)
    wpos_flat = wpos.reshape(-1)
    table_flat = jnp.repeat(table, K, axis=0) if attn_pos else None
    qpos = jnp.where(valid, tpos, -1)                      # pad queries
    b_idx = jnp.arange(B)[:, None]
    wp_ins = jnp.where(valid & (tpos < seq), tpos, seq)    # drop pads

    x = layers.embed(tokens, params["embed"]).astype(cfg.cdtype)  # (B,K,d)

    pre_split: dict[str, Any] = {}
    if fuse and attn_pos:
        gathered = kv_interleaved.gather_paged_kv(
            [blocks_in[f"pos{i}"] for i in attn_pos], table, ps,
            policy=pol, shard=pool_shard,
            scales=([blocks_in[f"scl{i}"] for i in attn_pos]
                    if quantized else None))
        splits = kv_interleaved.split_kv_step(gathered, policy=pol)
        pre_split = {f"pos{i}": splits[a] for a, i in enumerate(attn_pos)}
    beat_pol = (pol.for_elems(B * K * cfg.n_kv_heads * 2 * cfg.hd)
                if fuse else pol)
    ffn_pol = pol.for_elems(B * K * 2 * cfg.d_ff) if fuse else pol

    def _tok_scan_b(step_fn, state0, h, keep_dtype):
        """Advance B slots' recurrent state over the K tokens, collecting
        every intermediate state for the post-hoc accept-point select."""
        def tok(st, inp):
            ht, on = inp                             # ht (B, d), on (B,)
            y, st2 = step_fn(ht, st)
            st2 = _keep_active(st2, st, on)
            return st2, (st2, jnp.where(on[:, None], y, 0.0))
        _, (sts, ys) = jax.lax.scan(
            tok, state0, (jnp.swapaxes(h, 0, 1), jnp.swapaxes(valid, 0, 1)))
        return sts, jnp.swapaxes(ys, 0, 1).astype(keep_dtype)

    def sb_step(x, inp):
        sb_p, sb_c, sb_pre = inp
        new_c = {}
        for i, kind in enumerate(cfg.block_pattern):
            p = sb_p[f"pos{i}"]
            if kind == "attn":
                h = layers.rms_norm(x, p["ln1"], cfg.norm_eps)
                q, k, v, kv = attention.qkv_project(
                    p["attn"], h, cfg.n_heads, cfg.n_kv_heads, cfg.hd,
                    tpos, cfg.rope_theta, policy=beat_pol)
                pool = sb_c[f"pos{i}"]               # (P, ps, Kh, 2D)
                if not fuse:
                    # per-access oracle reads PRE-append too (then the
                    # fresh-beat insert below), so every arm sees
                    # bit-identical attention inputs
                    full = vx.gather(
                        spec, pool, table=table,
                        scales=(sb_c[f"scl{i}"] if quantized else None),
                        policy=pol, shard=pool_shard)  # (B, S, Kh, 2D)
                    pre = vx.transpose(
                        vx.Segment(n=full.shape[-1], fields=2), full,
                        policy=pol)
                kv_flat = kv.reshape(B * K, cfg.n_kv_heads, 2 * cfg.hd)
                if quantized:
                    pool, scl = vx.scatter(spec, pool, kv_flat,
                                           table=table_flat,
                                           pos=wpos_flat,
                                           scales=sb_c[f"scl{i}"],
                                           policy=pol)
                    new_c[f"scl{i}"] = scl
                else:
                    pool = vx.scatter(spec, pool, kv_flat,
                                      table=table_flat, pos=wpos_flat,
                                      policy=pol)
                k_pre, v_pre = (sb_pre[f"pos{i}"] if fuse else pre)
                # insert ALL K fresh beats as floats (a scatter eqn —
                # the gather gate stays at one); rows past n_draft drop
                k_all = k_pre.at[b_idx, wp_ins].set(
                    k.astype(k_pre.dtype), mode="drop")
                v_all = v_pre.at[b_idx, wp_ins].set(
                    v.astype(v_pre.dtype), mode="drop")
                out = attention.chunk_attention(
                    q, k_all, v_all, qpos, window=cfg.window_pattern[i])
                x = x + (out.reshape(B, K, cfg.n_heads * cfg.hd)
                         @ p["attn"]["wo"]).astype(x.dtype)
                new_c[f"pos{i}"] = pool
            elif kind == "mamba":
                h = layers.rms_norm(x, p["ln1"], cfg.norm_eps)
                pm = dict(p["mamba"])
                pm["in_proj"] = pm["in_proj"].reshape(cfg.d_model,
                                                      2 * cfg.mamba.ed)
                sts, y = _tok_scan_b(
                    lambda ht, st: mamba_decode_step(pm, ht, st,
                                                     cfg.mamba),
                    sb_c[f"pos{i}"], h, x.dtype)
                x = x + y
                new_c[f"pos{i}"] = sts
            elif kind == "mlstm":
                h = layers.rms_norm(x, p["ln1"], cfg.norm_eps)
                px = dict(p["xl"])
                px["up"] = px["up"].reshape(cfg.d_model,
                                            2 * cfg.xlstm.m_inner)
                sts, y = _tok_scan_b(
                    lambda ht, st: mlstm_decode_step(px, ht, st,
                                                     cfg.xlstm),
                    sb_c[f"pos{i}"], h, x.dtype)
                x = x + y
                new_c[f"pos{i}"] = sts
            elif kind == "slstm":
                h = layers.rms_norm(x, p["ln1"], cfg.norm_eps)
                sts, y = _tok_scan_b(
                    lambda ht, st: slstm_decode_step(p["slstm"], ht, st,
                                                     cfg.xlstm),
                    sb_c[f"pos{i}"], h, x.dtype)
                x = x + y
                new_c[f"pos{i}"] = sts
            if cfg.pos_has_ffn(i):
                x, _ = _ffn_apply(p, x, cfg, ctx, i, policy=ffn_pol)
        return x, new_c

    if cfg.scan_layers:
        x, new_blocks = jax.lax.scan(
            sb_step, x, (params["blocks"], blocks_in, pre_split))
    else:
        outs = []
        for sbi in range(cfg.n_superblocks):
            sb = jax.tree.map(lambda a: a[sbi], params["blocks"])
            cb = jax.tree.map(lambda a: a[sbi], blocks_in)
            pb = jax.tree.map(lambda a: a[sbi], pre_split)
            x, nb = sb_step(x, (sb, cb, pb))
            outs.append(nb)
        new_blocks = jax.tree.map(lambda *xs: jnp.stack(xs), *outs)

    x = layers.rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    logits = layers.unembed(x, head.astype(cfg.cdtype))    # (B, K, V)

    # greedy accept recurrence: column j's argmax is the oracle token at
    # position pos + j given columns 0..j were fed correctly; commit =
    # 1 + (# leading drafts matching the previous column's argmax)
    out_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    if K > 1:
        match = ((tokens[:, 1:] == out_tok[:, :-1])
                 & (jnp.arange(1, K)[None, :] < n_draft[:, None]))
        m = jnp.sum(jnp.cumprod(match.astype(jnp.int32), axis=1), axis=1)
    else:
        m = jnp.zeros((B,), jnp.int32)
    commit = jnp.where(active, 1 + m, 0)
    new_pos = jnp.where(active, jnp.minimum(pos + commit, seq), pos)

    # accept-point select for recurrent leaves: state after `commit`
    # tokens is stacked index commit-1 (inactive slots carried their old
    # state through the gated scan, so any index reads it back)
    ci = jnp.clip(commit, 1, K) - 1

    def _sel(a):                                     # (NS, K, B, ...)
        out = a[:, 0]
        for kk in range(1, K):
            mkk = (ci == kk).reshape((1, -1) + (1,) * (out.ndim - 2))
            out = jnp.where(mkk, a[:, kk], out)
        return out

    fixed = dict(new_blocks)
    for i, kind in enumerate(cfg.block_pattern):
        if kind != "attn":
            fixed[f"pos{i}"] = jax.tree.map(_sel, new_blocks[f"pos{i}"])
    new_blocks = fixed

    if attn_pos:
        # rollback via the page table only: pages allocated THIS step at
        # logical indices past the accept extent are refcount-1 by
        # construction — clear the entries and push them back
        ext = (new_pos + ps - 1) // ps
        roll = have & (jnp.arange(n_seq)[None, :] >= ext[:, None])
        ids = jnp.where(roll, newp_grid, -1).reshape(-1)
        ref, free, free_top = _deref_push(ref, free, free_top, ids)
        table = jnp.where(roll, -1, table)

    return logits, out_tok, commit, {
        "pos": new_pos, "table": table, "free": free,
        "free_top": free_top, "ref": ref, "blocks": new_blocks}


def paged_decode_step(params, cache: dict, token: jax.Array,
                      cfg: ModelConfig, ctx, *, active=None,
                      fuse: bool | None = None,
                      pool_shard=None) -> tuple[jax.Array, dict]:
    """One decode step over the paged cache.  token: (B,) int32 with B =
    slots.  Returns (logits (B, V), updated cache).

    Differences from :func:`decode_step` (which remains the dense-cache
    oracle): positions are PER-SLOT (``cache["pos"]``), the step takes an
    ``active`` mask (idle slots append nothing and advance nothing — the
    scheduler's active-set batching), appends allocate a page off the
    device free stack when a slot crosses a page boundary, and attention
    reads go through ``vx.Paged`` — with ``fuse=True`` ALL layers' page
    gathers run as ONE fused page-granular program (the table encodes the
    heterogeneous per-slot lengths; the compiled program is keyed only by
    page geometry) followed by the usual ONE fused FIELD=2 split.
    ``fuse=False`` is the per-access paged oracle.  Sliding-window layers
    mask at attention time instead of ring-overwriting.

    ``pool_shard`` (a ``vx.Shard`` on the pool page axis, ``axis=-4``)
    lowers every page gather shard-locally — the pool, sharded over the
    mesh on its page axis, is never sliced globally (the PR 4 invariant
    applied to the serving pool).

    QUANTIZED pools (``scl{i}`` side tensors present, see
    :func:`init_paged_cache`) dequantize inside the same fused gather
    program, quantize the appended beat on write (page scale widens
    monotonically), and attention always reads the pre-append pages
    plus the fresh FLOAT beat — fused and per-access paths stay
    bit-identical, and the beat is only quantized for the NEXT step's
    read.
    """
    from repro.models.transformer import cast_params
    params = cast_params(params, cfg)
    if cfg.encoder is not None:
        raise NotImplementedError("paged serving covers decoder-only "
                                  "models; use encdec.decode_step")
    fuse = cfg.step_fusion if fuse is None else fuse
    pol = cfg.vx_policy
    B = token.shape[0]
    pos = cache["pos"]
    if active is None:
        active = jnp.ones((B,), bool)
    else:
        active = jnp.asarray(active, bool)
    attn_pos, ps, n_seq = _paged_geometry(cfg, cache)
    table, free, free_top = cache["table"], cache["free"], cache["free_top"]
    ref = cache["ref"]
    quantized = _pool_quantized(cache, attn_pos)
    blocks_in = cache["blocks"]
    # logical capacity; recurrent-only stacks carry O(1) state, no cap
    seq = n_seq * ps if attn_pos else (1 << 30)

    if attn_pos:
        # allocate on page-boundary crossing — one shared table update for
        # every layer (all layers append in lockstep).  An exhausted free
        # stack degrades LOCALLY: slots whose pop rank exceeds the free
        # count get no page (table entry stays -1, their appends drop and
        # their reads return zeros) — never an aliased page shared with a
        # live slot, and free_top never goes negative.
        need = active & (pos % ps == 0) & (pos // ps < n_seq)
        rank = jnp.cumsum(need.astype(jnp.int32)) - need
        need = need & (rank < free_top)
        newp = free[jnp.clip(free_top - 1 - rank, 0, free.shape[0] - 1)]
        hit = need[:, None] & (jnp.arange(n_seq)[None, :]
                               == (pos // ps)[:, None])
        table = jnp.where(hit, newp[:, None], table)
        free_top = free_top - jnp.sum(need.astype(jnp.int32))
        ref = ref.at[jnp.where(need, newp, free.shape[0])].add(
            1, mode="drop")
        if quantized:
            # freshly allocated pages start at scale 0 (see the chunk
            # allocator): the widen-on-append then zeroes resident
            # garbage and the first beat sets the true scale
            rst = jnp.where(need, newp, free.shape[0])
            blocks_in = dict(blocks_in)
            for i in attn_pos:
                blocks_in[f"scl{i}"] = blocks_in[f"scl{i}"].at[
                    :, rst].set(0.0, mode="drop")
    # idle slots and full sequences append nothing (dropped scatter rows)
    write_pos = jnp.where(active & (pos < seq), pos, -1)
    spec = (vx.Paged(page_size=ps, pages=n_seq, trail=2)
            if attn_pos else None)

    x = layers.embed(token, params["embed"]).astype(cfg.cdtype)

    pre_split: dict[str, Any] = {}
    if fuse and attn_pos:
        # ONE fused page gather for all layers' pools (stacked over
        # superblocks AND over layers), then ONE fused FIELD=2 split.
        # Quantized pools stack their scale tensors the same way — the
        # dequant rides the same single program (zero extra launches).
        gathered = kv_interleaved.gather_paged_kv(
            [blocks_in[f"pos{i}"] for i in attn_pos], table, ps,
            policy=pol, shard=pool_shard,
            scales=([blocks_in[f"scl{i}"] for i in attn_pos]
                    if quantized else None))
        splits = kv_interleaved.split_kv_step(gathered, policy=pol)
        pre_split = {f"pos{i}": splits[a] for a, i in enumerate(attn_pos)}
    beat_pol = (pol.for_elems(B * cfg.n_kv_heads * 2 * cfg.hd)
                if fuse else pol)
    ffn_pol = pol.for_elems(B * 2 * cfg.d_ff) if fuse else pol
    eff = (pos + active.astype(jnp.int32))[:, None]      # (B, 1) per slot

    def sb_step(x, inp):
        sb_p, sb_c, sb_pre = inp
        new_c = {}
        for i, kind in enumerate(cfg.block_pattern):
            p = sb_p[f"pos{i}"]
            if kind == "attn":
                h = layers.rms_norm(x, p["ln1"], cfg.norm_eps)
                q, k, v, kv = attention.qkv_project(
                    p["attn"], h[:, None], cfg.n_heads, cfg.n_kv_heads,
                    cfg.hd, pos[:, None], cfg.rope_theta, policy=beat_pol)
                pool = sb_c[f"pos{i}"]                 # (P, ps, K, 2D)
                if quantized and not fuse:
                    # per-access quantized arm reads PRE-append (like
                    # the fused pre-gather) and inserts the fresh FLOAT
                    # beat below — attention then sees bit-identical
                    # inputs on both paths (the appended beat is only
                    # quantized for the NEXT step's read, exactly as in
                    # the fused arm)
                    full = vx.gather(spec, pool, table=table,
                                     scales=sb_c[f"scl{i}"], policy=pol,
                                     shard=pool_shard)  # (B, S, K, 2D)
                    pre = vx.transpose(
                        vx.Segment(n=full.shape[-1], fields=2), full,
                        policy=pol)
                if quantized:
                    pool, scl = vx.scatter(spec, pool, kv[:, 0],
                                           table=table, pos=write_pos,
                                           scales=sb_c[f"scl{i}"],
                                           policy=pol)
                    new_c[f"scl{i}"] = scl
                else:
                    pool = vx.scatter(spec, pool, kv[:, 0], table=table,
                                      pos=write_pos, policy=pol)
                if fuse or quantized:
                    k_pre, v_pre = (sb_pre[f"pos{i}"] if fuse else pre)
                    ins = (active[:, None]
                           & (jnp.arange(seq)[None, :] == pos[:, None]))
                    ins = ins[:, :, None, None]
                    # k/v are (B, 1, K, D): broadcast over the seq axis
                    k_all = jnp.where(ins, k.astype(k_pre.dtype), k_pre)
                    v_all = jnp.where(ins, v.astype(v_pre.dtype), v_pre)
                else:
                    full = vx.gather(spec, pool, table=table, policy=pol,
                                     shard=pool_shard)   # (B, S, K, 2D)
                    k_all, v_all = vx.transpose(
                        vx.Segment(n=full.shape[-1], fields=2), full,
                        policy=pol)
                out = attention.decode_attention(
                    q[:, 0], k_all, v_all, eff,
                    window=cfg.window_pattern[i])
                x = x + (out.reshape(B, cfg.n_heads * cfg.hd)
                         @ p["attn"]["wo"]).astype(x.dtype)
                new_c[f"pos{i}"] = pool
            elif kind == "mamba":
                h = layers.rms_norm(x, p["ln1"], cfg.norm_eps)
                pm = dict(p["mamba"])
                pm["in_proj"] = pm["in_proj"].reshape(cfg.d_model,
                                                      2 * cfg.mamba.ed)
                y, st = mamba_decode_step(pm, h, sb_c[f"pos{i}"], cfg.mamba)
                x = x + jnp.where(active[:, None], y, 0)
                new_c[f"pos{i}"] = _keep_active(st, sb_c[f"pos{i}"], active)
            elif kind == "mlstm":
                h = layers.rms_norm(x, p["ln1"], cfg.norm_eps)
                px = dict(p["xl"])
                px["up"] = px["up"].reshape(cfg.d_model,
                                            2 * cfg.xlstm.m_inner)
                y, st = mlstm_decode_step(px, h, sb_c[f"pos{i}"], cfg.xlstm)
                x = x + jnp.where(active[:, None], y, 0)
                new_c[f"pos{i}"] = _keep_active(st, sb_c[f"pos{i}"], active)
            elif kind == "slstm":
                h = layers.rms_norm(x, p["ln1"], cfg.norm_eps)
                y, st = slstm_decode_step(p["slstm"], h, sb_c[f"pos{i}"],
                                          cfg.xlstm)
                x = x + jnp.where(active[:, None], y, 0)
                new_c[f"pos{i}"] = _keep_active(st, sb_c[f"pos{i}"], active)
            if cfg.pos_has_ffn(i):
                x2, _ = _ffn_apply(p, x[:, None], cfg, ctx, i,
                                   policy=ffn_pol)
                x = x2[:, 0]
        return x, new_c

    if cfg.scan_layers:
        x, new_blocks = jax.lax.scan(
            sb_step, x, (params["blocks"], blocks_in, pre_split))
    else:
        outs = []
        for sbi in range(cfg.n_superblocks):
            sb = jax.tree.map(lambda a: a[sbi], params["blocks"])
            cb = jax.tree.map(lambda a: a[sbi], blocks_in)
            pb = jax.tree.map(lambda a: a[sbi], pre_split)
            x, nb = sb_step(x, (sb, cb, pb))
            outs.append(nb)
        new_blocks = jax.tree.map(lambda *xs: jnp.stack(xs), *outs)

    x = layers.rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    logits = layers.unembed(x, head.astype(cfg.cdtype))
    new_pos = pos + (active & (pos < seq)).astype(jnp.int32)
    return logits, {"pos": new_pos, "table": table, "free": free,
                    "free_top": free_top, "ref": ref,
                    "blocks": new_blocks}


def decode_step(params, cache: dict, token: jax.Array, cfg: ModelConfig,
                ctx, *, fuse: bool | None = None,
                kv_shard=None) -> tuple[jax.Array, dict]:
    """token: (B,) int32. Returns (logits (B, V), updated cache).

    ``fuse`` (default cfg.step_fusion) enables whole-step access fusion:
    the attention-time cache splits of EVERY layer — the step's dominant
    shift-routed traffic — are hoisted to the top of the step (they read
    the pre-append cache, which depends on nothing computed this step) and
    merged into ONE fused FIELD=2 segment load: one kernel launch and one
    mask operand per decode step instead of one per layer.  The current
    token's (k, v) is then written into the pre-split arrays at its slot
    (two one-beat updates), which is bit-exact with splitting the
    post-append cache because the segment op is a pure lane permutation.
    Single-token reorganizations (QKV beat pack/split, GLU field split)
    are inlined on the XLA path by the scheduler's launch policy.
    ``fuse=False`` keeps the per-access path (the equivalence oracle).

    ``kv_shard`` (a ``vx.Shard`` with ``axis=-3``, the cache sequence
    axis) marks the KV leaves as sequence-sharded: the fused split then
    lowers SHARD-LOCALLY under ``shard_map`` (repro.vx.lower), which is
    what lets long-context seq-parallel serving keep step fusion — the
    global split of a sharded leaf that used to force SPMD
    rematerialization is gone.  Leaves whose sequence extent does not
    divide across the shards (short sliding windows) fall back to the
    replicated lowering, each group still one fused launch.
    """
    from repro.models.transformer import cast_params
    params = cast_params(params, cfg)
    if cfg.encoder is not None:
        from repro.models import encdec
        return encdec.decode_step(params, cache, token, cfg, ctx)
    fuse = cfg.step_fusion if fuse is None else fuse
    pol = cfg.vx_policy
    B = token.shape[0]
    pos = cache["len"]
    x = layers.embed(token, params["embed"]).astype(cfg.cdtype)

    attn_pos = [i for i, k in enumerate(cfg.block_pattern) if k == "attn"]
    pre_split: dict[str, Any] = {}
    if fuse and attn_pos:
        # One fused split for all layers: leaves are stacked over
        # superblocks ((NS, B, Sc, K, 2D)), so this single call covers the
        # full depth; same-shape positions share one launch.  Sharded and
        # replicated leaves lower separately (the scheduler groups by
        # placement as well as shape).
        leaves = {i: cache["blocks"][f"pos{i}"] for i in attn_pos}
        sharded = [i for i, leaf in leaves.items()
                   if kv_shard is not None
                   and kv_shard.divides(leaf.shape[-3])]
        local = [i for i in attn_pos if i not in sharded]
        splits: dict[int, Any] = {}
        if sharded:
            outs = kv_interleaved.split_kv_step(
                [leaves[i] for i in sharded], policy=pol, shard=kv_shard)
            splits.update(dict(zip(sharded, outs)))
        if local:
            outs = kv_interleaved.split_kv_step(
                [leaves[i] for i in local], policy=pol)
            splits.update(dict(zip(local, outs)))
        pre_split = {f"pos{i}": splits[i] for i in attn_pos}
    # single-token reorganizations (QKV beat split, GLU field split) ride
    # the XLA path below the policy's fusion threshold during fused decode
    beat_pol = (pol.for_elems(B * cfg.n_kv_heads * 2 * cfg.hd)
                if fuse else pol)
    ffn_pol = pol.for_elems(B * 2 * cfg.d_ff) if fuse else pol

    def sb_step(x, inp):
        sb_p, sb_c, sb_pre = inp
        new_c = {}
        for i, kind in enumerate(cfg.block_pattern):
            p = sb_p[f"pos{i}"]
            if kind == "attn":
                h = layers.rms_norm(x, p["ln1"], cfg.norm_eps)
                positions = jnp.broadcast_to(pos, (B, 1))
                q, k, v, kv = attention.qkv_project(
                    p["attn"], h[:, None], cfg.n_heads, cfg.n_kv_heads,
                    cfg.hd, positions, cfg.rope_theta, policy=beat_pol)
                kvc = sb_c[f"pos{i}"]                      # (B, Sc, K, 2D)
                sc = kvc.shape[1]
                slot = jax.lax.rem(pos, sc)
                kvc = jax.lax.dynamic_update_slice_in_dim(
                    kvc, kv.astype(kvc.dtype), slot, axis=1)
                if fuse:
                    k_pre, v_pre = sb_pre[f"pos{i}"]
                    k_all = jax.lax.dynamic_update_slice_in_dim(
                        k_pre, k.astype(kvc.dtype), slot, axis=1)
                    v_all = jax.lax.dynamic_update_slice_in_dim(
                        v_pre, v.astype(kvc.dtype), slot, axis=1)
                else:
                    k_all, v_all = vx.transpose(
                        vx.Segment(n=kvc.shape[-1], fields=2), kvc,
                        policy=pol)
                eff_len = jnp.minimum(pos + 1, sc)
                out = attention.decode_attention(
                    q[:, 0], k_all, v_all, eff_len, window=None)
                x = x + (out.reshape(B, cfg.n_heads * cfg.hd)
                         @ p["attn"]["wo"]).astype(x.dtype)
                new_c[f"pos{i}"] = kvc
            elif kind == "mamba":
                h = layers.rms_norm(x, p["ln1"], cfg.norm_eps)
                pm = dict(p["mamba"])
                pm["in_proj"] = pm["in_proj"].reshape(cfg.d_model,
                                                      2 * cfg.mamba.ed)
                y, st = mamba_decode_step(pm, h, sb_c[f"pos{i}"], cfg.mamba)
                x = x + y
                new_c[f"pos{i}"] = st
            elif kind == "mlstm":
                h = layers.rms_norm(x, p["ln1"], cfg.norm_eps)
                px = dict(p["xl"])
                px["up"] = px["up"].reshape(cfg.d_model,
                                            2 * cfg.xlstm.m_inner)
                y, st = mlstm_decode_step(px, h, sb_c[f"pos{i}"], cfg.xlstm)
                x = x + y
                new_c[f"pos{i}"] = st
            elif kind == "slstm":
                h = layers.rms_norm(x, p["ln1"], cfg.norm_eps)
                y, st = slstm_decode_step(p["slstm"], h, sb_c[f"pos{i}"],
                                          cfg.xlstm)
                x = x + y
                new_c[f"pos{i}"] = st
            if cfg.pos_has_ffn(i):
                x2, _ = _ffn_apply(p, x[:, None], cfg, ctx, i,
                                   policy=ffn_pol)
                x = x2[:, 0]
        return x, new_c

    if cfg.scan_layers:
        x, new_blocks = jax.lax.scan(
            sb_step, x, (params["blocks"], cache["blocks"], pre_split))
    else:
        outs = []
        for sbi in range(cfg.n_superblocks):
            sb = jax.tree.map(lambda a: a[sbi], params["blocks"])
            cb = jax.tree.map(lambda a: a[sbi], cache["blocks"])
            pb = jax.tree.map(lambda a: a[sbi], pre_split)
            x, nb = sb_step(x, (sb, cb, pb))
            outs.append(nb)
        new_blocks = jax.tree.map(lambda *xs: jnp.stack(xs), *outs)

    x = layers.rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    logits = layers.unembed(x, head.astype(cfg.cdtype))
    return logits, {"len": pos + 1, "blocks": new_blocks}
