"""Mamba (selective SSM) block — the state-space half of Jamba.

Training/prefill run a chunked recurrent scan: lax.scan over time inside a
jax.checkpoint'd chunk, outer scan over chunks. Memory is O(state) at chunk
boundaries + O(chunk x state / remat) — the only formulation that fits at
Jamba scale (ed=16384, N=16) without the paper's CUDA kernel; the ed axis is
sharded over the model axis by the distribution layer.

Decode carries (conv_state, h) in the cache: O(1) per token — why Jamba runs
the long_500k cell that full-attention archs skip.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class MambaSpec(NamedTuple):
    d_model: int
    expand: int = 2
    state_dim: int = 16
    conv_width: int = 4
    dt_rank: int = 0  # 0 -> d_model // 16

    @property
    def ed(self) -> int:
        return self.expand * self.d_model

    @property
    def rank(self) -> int:
        return self.dt_rank or max(1, self.d_model // 16)


def init_mamba(key, spec: MambaSpec, dtype) -> dict:
    ks = jax.random.split(key, 7)
    d, ed, N, r = spec.d_model, spec.ed, spec.state_dim, spec.rank
    s = d ** -0.5
    return {
        "in_proj": jax.random.normal(ks[0], (d, 2 * ed), dtype) * s,
        "conv_w": jax.random.normal(ks[1], (spec.conv_width, ed), dtype) * 0.1,
        "conv_b": jnp.zeros((ed,), dtype),
        "x_proj": jax.random.normal(ks[2], (ed, r + 2 * N), dtype) * ed ** -0.5,
        "dt_proj": jax.random.normal(ks[3], (r, ed), dtype) * r ** -0.5,
        "dt_bias": jnp.log(jnp.expm1(  # softplus^-1 of U(1e-3, 1e-1)
            jax.random.uniform(ks[4], (ed,), jnp.float32, 1e-3, 1e-1))
        ).astype(dtype),
        "A_log": jnp.log(jnp.broadcast_to(
            jnp.arange(1, N + 1, dtype=jnp.float32), (ed, N))).astype(dtype),
        "D": jnp.ones((ed,), dtype),
        "out_proj": jax.random.normal(ks[5], (ed, d), dtype) * ed ** -0.5,
    }


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv via static shifts. x: (B,S,ed); w: (W,ed)."""
    W = w.shape[0]
    out = jnp.zeros_like(x)
    for i in range(W):  # tap i sees x shifted back by (W-1-i)
        lag = W - 1 - i
        shifted = jnp.pad(x, ((0, 0), (lag, 0), (0, 0)))[:, :x.shape[1]]
        out = out + shifted * w[i]
    return out + b


def _ssm_inputs(params, x: jax.Array, spec: MambaSpec):
    """Common projections. x: (B,S,ed) post-conv. Returns dt,(B,S,ed) B,C (B,S,N)."""
    N, r = spec.state_dim, spec.rank
    proj = x @ params["x_proj"]
    dt_in, Bmat, Cmat = jnp.split(proj, [r, r + N], axis=-1)
    dt = jax.nn.softplus(dt_in @ params["dt_proj"]
                         + params["dt_bias"].astype(jnp.float32))
    return dt, Bmat, Cmat


def selective_scan(dt, Bm, Cm, x, A, D, h0, *, chunk: int = 128):
    """h_t = exp(dt*A) h_{t-1} + dt*B_t x_t ; y_t = C_t.h_t + D x_t.

    dt, x: (B,S,ed); Bm, Cm: (B,S,N); A: (ed,N); h0: (B,ed,N) fp32.
    Returns (y (B,S,ed), h_final). Chunked + remat (see module docstring).
    """
    Bsz, S, ed = x.shape
    chunk = min(chunk, S)
    main = (S // chunk) * chunk
    nc = main // chunk

    def step(h, inp):
        dt_t, B_t, C_t, x_t = inp             # (B,ed),(B,N),(B,N),(B,ed)
        da = jnp.exp(dt_t[..., None] * A)     # (B,ed,N)
        h = da * h + (dt_t * x_t)[..., None] * B_t[:, None, :]
        y = jnp.einsum("ben,bn->be", h, C_t)
        return h, y

    @jax.checkpoint
    def chunk_fn(h, inputs):
        return jax.lax.scan(step, h, inputs)

    def outer(h, cidx):
        sl = lambda a: jax.lax.dynamic_slice_in_dim(a, cidx * chunk, chunk, 1)
        inputs = tuple(jnp.moveaxis(sl(a), 1, 0)
                       for a in (dt, Bm, Cm, x))  # time-major (chunk,B,...)
        h, ys = chunk_fn(h, inputs)
        return h, jnp.moveaxis(ys, 0, 1)

    h, ys = jax.lax.scan(outer, h0.astype(jnp.float32), jnp.arange(nc))
    y = jnp.moveaxis(ys, 0, 1).reshape(Bsz, main, ed)
    if main < S:  # exact ragged tail (one extra short chunk)
        tail = tuple(jnp.moveaxis(a[:, main:], 1, 0)
                     for a in (dt, Bm, Cm, x))
        h, yt = chunk_fn(h, tail)
        y = jnp.concatenate([y, jnp.moveaxis(yt, 0, 1)], axis=1)
    return (y + x * D).astype(x.dtype), h


def mamba_forward(params, x: jax.Array, spec: MambaSpec, *,
                  chunk: int = 128) -> tuple[jax.Array, "MambaCache"]:
    """Full block forward (train/prefill). x: (B,S,d) -> ((B,S,d), cache).

    The returned cache (final conv window + SSM state) is free in training —
    XLA dead-code-eliminates it when unused."""
    xz = x @ params["in_proj"]
    xin, z = jnp.split(xz, 2, axis=-1)
    xc = jax.nn.silu(_causal_conv(xin, params["conv_w"], params["conv_b"]))
    dt, Bm, Cm = _ssm_inputs(params, xc, spec)
    A = -jnp.exp(params["A_log"].astype(jnp.float32))
    h0 = jnp.zeros((x.shape[0], spec.ed, spec.state_dim), jnp.float32)
    y, hf = selective_scan(dt.astype(jnp.float32), Bm.astype(jnp.float32),
                           Cm.astype(jnp.float32), xc.astype(jnp.float32),
                           A, params["D"].astype(jnp.float32), h0, chunk=chunk)
    out = (y.astype(x.dtype) * jax.nn.silu(z)) @ params["out_proj"]
    W = spec.conv_width
    S = x.shape[1]
    conv = xin[:, -(W - 1):] if S >= W - 1 else jnp.pad(
        xin, ((0, 0), (W - 1 - S, 0), (0, 0)))
    return out, MambaCache(conv=conv, h=hf)


class MambaCache(NamedTuple):
    conv: jax.Array   # (B, W-1, ed) last inputs
    h: jax.Array      # (B, ed, N) fp32 SSM state


def init_mamba_cache(batch: int, spec: MambaSpec, dtype) -> MambaCache:
    return MambaCache(
        conv=jnp.zeros((batch, spec.conv_width - 1, spec.ed), dtype),
        h=jnp.zeros((batch, spec.ed, spec.state_dim), jnp.float32))


def mamba_decode_step(params, x_t: jax.Array, cache: MambaCache,
                      spec: MambaSpec) -> tuple[jax.Array, MambaCache]:
    """One-token step. x_t: (B, d). O(1) state update."""
    xz = x_t @ params["in_proj"]
    xin, z = jnp.split(xz, 2, axis=-1)          # (B, ed)
    window = jnp.concatenate([cache.conv, xin[:, None]], axis=1)  # (B,W,ed)
    xc = jnp.einsum("bwe,we->be", window, params["conv_w"]) + params["conv_b"]
    xc = jax.nn.silu(xc)
    dt, Bm, Cm = _ssm_inputs(params, xc[:, None], spec)
    dt, Bm, Cm = dt[:, 0], Bm[:, 0], Cm[:, 0]
    A = -jnp.exp(params["A_log"].astype(jnp.float32))
    da = jnp.exp(dt.astype(jnp.float32)[..., None] * A)
    h = da * cache.h + (dt * xc).astype(jnp.float32)[..., None] \
        * Bm.astype(jnp.float32)[:, None, :]
    y = jnp.einsum("ben,bn->be", h, Cm.astype(jnp.float32))
    y = (y + xc.astype(jnp.float32) * params["D"]).astype(x_t.dtype)
    out = (y * jax.nn.silu(z)) @ params["out_proj"]
    return out, MambaCache(conv=window[:, 1:], h=h)
