"""Shared neural-net layers (pure JAX, no flax): norms, RoPE, embeddings,
MLP/GLU with the EARTH interleaved fused projection option."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro import vx


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return ((x * jax.lax.rsqrt(var + eps)) * scale.astype(jnp.float32)).astype(dtype)


def layer_norm(x: jax.Array, scale: jax.Array, bias: jax.Array,
               eps: float = 1e-5) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dtype)


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotary embedding. x: (..., S, H, D), positions: (..., S)."""
    d = x.shape[-1]
    half = d // 2
    freqs = jnp.exp(-jnp.arange(0, half, dtype=jnp.float32)
                    * (jnp.log(theta) / half))
    ang = positions.astype(jnp.float32)[..., None] * freqs  # (..., S, half)
    cos = jnp.cos(ang)[..., None, :]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def embed(tokens: jax.Array, table: jax.Array) -> jax.Array:
    return jnp.take(table, tokens, axis=0)


def unembed(x: jax.Array, table: jax.Array) -> jax.Array:
    """Logits = x @ table^T (tied or untied head)."""
    return jnp.einsum("...d,vd->...v", x, table)


# ---------------------------------------------------------------------------
# Feed-forward. The fused GLU path emits gate/up INTERLEAVED along the feature
# dim ([g0,u0,g1,u1,...]) from a single matmul — one contiguous write — and
# de-interleaves with the EARTH segment op (FIELD=2 segment load).
# ---------------------------------------------------------------------------

def glu_ffn(params, x: jax.Array, *, fused: bool = False,
            policy=None) -> jax.Array:
    """SwiGLU. params: {'wi': (d, 2f) or {'wg','wu'}: (d, f), 'wo': (f, d)}."""
    if fused:
        gu = x @ params["wi"]               # (..., 2f) interleaved AoS
        gate, up = vx.transpose(vx.Segment(n=gu.shape[-1], fields=2), gu,
                                policy=policy)
    else:
        gate = x @ params["wg"]
        up = x @ params["wu"]
    return (jax.nn.silu(gate) * up) @ params["wo"]


def mlp_ffn(params, x: jax.Array) -> jax.Array:
    """2-matmul GELU MLP (GPT-BigCode / whisper style)."""
    return jax.nn.gelu(x @ params["wi"], approximate=True) @ params["wo"]


def init_glu(key, d: int, f: int, *, fused: bool, dtype) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    s_in = d ** -0.5
    s_out = f ** -0.5
    if fused:
        wg = jax.random.normal(k1, (d, f), dtype) * s_in
        wu = jax.random.normal(k2, (d, f), dtype) * s_in
        # interleave columns -> [g0,u0,g1,u1,...]
        wi = jnp.stack([wg, wu], axis=-1).reshape(d, 2 * f)
        return {"wi": wi, "wo": jax.random.normal(k3, (f, d), dtype) * s_out}
    return {"wg": jax.random.normal(k1, (d, f), dtype) * s_in,
            "wu": jax.random.normal(k2, (d, f), dtype) * s_in,
            "wo": jax.random.normal(k3, (f, d), dtype) * s_out}


def init_mlp(key, d: int, f: int, dtype) -> dict:
    k1, k2 = jax.random.split(key)
    return {"wi": jax.random.normal(k1, (d, f), dtype) * d ** -0.5,
            "wo": jax.random.normal(k2, (f, d), dtype) * f ** -0.5}
