"""Model zoo: composable transformer (dense/GQA/SWA/MoE), Mamba hybrid,
xLSTM, whisper enc-dec, VLM backbone — pure JAX, scan-over-layers."""
from repro.models.transformer import ModelConfig, init_params, forward, loss_fn  # noqa: F401
