"""xLSTM blocks (mLSTM matrix-memory + sLSTM scalar-memory), arXiv:2405.04517.

Both use exponential gating with the max-stabilizer. mLSTM has no hidden-to-
hidden dependency (the C update is associative-ish), but we keep the exact
recurrent form with chunked remat scans (same pattern as models/ssm.py);
sLSTM is inherently serial through h_{t-1} (recurrent R matrix).

Decode carries (C, n, m) / (c, n, m, h) — O(1) per token, which is why the
xlstm arch runs the long_500k cell.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class XLSTMSpec(NamedTuple):
    d_model: int
    n_heads: int
    m_proj_factor: float = 2.0   # mLSTM up-projection
    s_ffn_factor: float = 4.0 / 3.0

    @property
    def m_inner(self) -> int:
        return int(self.d_model * self.m_proj_factor)

    @property
    def m_head(self) -> int:
        return self.m_inner // self.n_heads

    @property
    def s_head(self) -> int:
        return self.d_model // self.n_heads


def _logsig(x):
    return -jax.nn.softplus(-x)


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------

def init_mlstm(key, spec: XLSTMSpec, dtype) -> dict:
    ks = jax.random.split(key, 8)
    d, ed, H = spec.d_model, spec.m_inner, spec.n_heads
    s, si = d ** -0.5, ed ** -0.5
    return {
        "up": jax.random.normal(ks[0], (d, 2 * ed), dtype) * s,
        "wq": jax.random.normal(ks[1], (ed, ed), dtype) * si,
        "wk": jax.random.normal(ks[2], (ed, ed), dtype) * si,
        "wv": jax.random.normal(ks[3], (ed, ed), dtype) * si,
        "wi": jax.random.normal(ks[4], (ed, H), dtype) * si,
        "wf": jax.random.normal(ks[5], (ed, H), dtype) * si,
        "fb": jnp.full((H,), 3.0, dtype),  # forget bias -> long memory at init
        "down": jax.random.normal(ks[6], (ed, d), dtype) * si,
        "ogate": jax.random.normal(ks[7], (d, ed), dtype) * s,
    }


class MLSTMState(NamedTuple):
    C: jax.Array   # (B, H, dh, dh) fp32
    n: jax.Array   # (B, H, dh) fp32
    m: jax.Array   # (B, H) fp32


def init_mlstm_state(batch: int, spec: XLSTMSpec) -> MLSTMState:
    H, dh = spec.n_heads, spec.m_head
    return MLSTMState(C=jnp.zeros((batch, H, dh, dh), jnp.float32),
                      n=jnp.zeros((batch, H, dh), jnp.float32),
                      m=jnp.full((batch, H), -1e30, jnp.float32))


def _mlstm_step(state: MLSTMState, qkvif):
    q, k, v, i, f = qkvif  # (B,H,dh) x3, (B,H) x2, all fp32
    dh = q.shape[-1]
    ft = _logsig(f)
    m_new = jnp.maximum(ft + state.m, i)
    fg = jnp.exp(ft + state.m - m_new)
    ig = jnp.exp(i - m_new)
    C = fg[..., None, None] * state.C + ig[..., None, None] \
        * (v[..., :, None] * k[..., None, :])
    n = fg[..., None] * state.n + ig[..., None] * k
    qs = q * dh ** -0.5
    num = jnp.einsum("bhij,bhj->bhi", C, qs)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhj,bhj->bh", n, qs)),
                      jnp.exp(-m_new))
    h = num / den[..., None]
    return MLSTMState(C, n, m_new), h


def mlstm_forward(params, x: jax.Array, spec: XLSTMSpec, *,
                  chunk: int = 64) -> jax.Array:
    """x: (B, S, d) -> (B, S, d)."""
    B, S, _ = x.shape
    H, dh = spec.n_heads, spec.m_head
    xu, z = jnp.split(x @ params["up"], 2, axis=-1)        # (B,S,ed) x2
    og = jax.nn.sigmoid(x @ params["ogate"])
    q = (xu @ params["wq"]).reshape(B, S, H, dh).astype(jnp.float32)
    k = (xu @ params["wk"]).reshape(B, S, H, dh).astype(jnp.float32)
    v = (xu @ params["wv"]).reshape(B, S, H, dh).astype(jnp.float32)
    i = (xu @ params["wi"]).astype(jnp.float32)            # (B,S,H)
    f = (xu @ params["wf"] + params["fb"]).astype(jnp.float32)

    chunk = min(chunk, S)
    main = (S // chunk) * chunk

    @jax.checkpoint
    def chunk_fn(state, inputs):
        return jax.lax.scan(_mlstm_step, state, inputs)

    def outer(state, cidx):
        sl = lambda a: jnp.moveaxis(
            jax.lax.dynamic_slice_in_dim(a, cidx * chunk, chunk, 1), 1, 0)
        state, hs = chunk_fn(state, (sl(q), sl(k), sl(v), sl(i), sl(f)))
        return state, hs

    state0 = init_mlstm_state(B, spec)
    state, hs = jax.lax.scan(outer, state0, jnp.arange(main // chunk))
    hs = hs.reshape(main, B, H, dh)
    if main < S:  # exact ragged tail
        tl = lambda a: jnp.moveaxis(a[:, main:], 1, 0)
        state, ht = chunk_fn(state, (tl(q), tl(k), tl(v), tl(i), tl(f)))
        hs = jnp.concatenate([hs, ht], axis=0)
    h = jnp.moveaxis(hs, 0, 1).reshape(B, S, H * dh)
    out = (h.astype(x.dtype) * og * jax.nn.silu(z)) @ params["down"]
    return out, state


def mlstm_decode_step(params, x_t: jax.Array, state: MLSTMState,
                      spec: XLSTMSpec) -> tuple[jax.Array, MLSTMState]:
    """x_t: (B, d)."""
    B = x_t.shape[0]
    H, dh = spec.n_heads, spec.m_head
    xu, z = jnp.split(x_t @ params["up"], 2, axis=-1)
    og = jax.nn.sigmoid(x_t @ params["ogate"])
    q = (xu @ params["wq"]).reshape(B, H, dh).astype(jnp.float32)
    k = (xu @ params["wk"]).reshape(B, H, dh).astype(jnp.float32)
    v = (xu @ params["wv"]).reshape(B, H, dh).astype(jnp.float32)
    i = (xu @ params["wi"]).astype(jnp.float32)
    f = (xu @ params["wf"] + params["fb"]).astype(jnp.float32)
    state, h = _mlstm_step(state, (q, k, v, i, f))
    out = (h.reshape(B, H * dh).astype(x_t.dtype) * og
           * jax.nn.silu(z)) @ params["down"]
    return out, state


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------

def init_slstm(key, spec: XLSTMSpec, dtype) -> dict:
    ks = jax.random.split(key, 4)
    d, H, dh = spec.d_model, spec.n_heads, spec.s_head
    f = int(spec.d_model * spec.s_ffn_factor)
    return {
        "wx": jax.random.normal(ks[0], (d, 4 * d), dtype) * d ** -0.5,
        # recurrent R: block-diagonal per head, stored (H, dh, 4*dh)
        "r": jax.random.normal(ks[1], (H, dh, 4 * dh), dtype) * dh ** -0.5,
        "fb": jnp.full((d,), 3.0, dtype),
        "ffn_wi": jax.random.normal(ks[2], (d, 2 * f), dtype) * d ** -0.5,
        "ffn_wo": jax.random.normal(ks[3], (f, d), dtype) * f ** -0.5,
    }


class SLSTMState(NamedTuple):
    c: jax.Array   # (B, d) fp32
    n: jax.Array   # (B, d) fp32
    m: jax.Array   # (B, d) fp32
    h: jax.Array   # (B, d) fp32


def init_slstm_state(batch: int, spec: XLSTMSpec) -> SLSTMState:
    d = spec.d_model
    z = jnp.zeros((batch, d), jnp.float32)
    return SLSTMState(c=z, n=z, m=jnp.full((batch, d), -1e30, jnp.float32),
                      h=z)


def _slstm_step(params, spec: XLSTMSpec, state: SLSTMState, wx_t):
    """wx_t: (B, 4d) precomputed input projection for step t."""
    B = wx_t.shape[0]
    H, dh = spec.n_heads, spec.s_head
    hr = state.h.reshape(B, H, dh)
    rec = jnp.einsum("bhi,hij->bhj", hr,
                     params["r"].astype(jnp.float32)).reshape(B, 4 * H * dh)
    pre = wx_t + rec
    zi, ii, fi, oi = jnp.split(pre, 4, axis=-1)
    fb = params["fb"].astype(jnp.float32)
    ft = _logsig(fi + fb)
    m_new = jnp.maximum(ft + state.m, ii)
    fg = jnp.exp(ft + state.m - m_new)
    ig = jnp.exp(ii - m_new)
    c = fg * state.c + ig * jnp.tanh(zi)
    n = fg * state.n + ig
    h = jax.nn.sigmoid(oi) * c / jnp.maximum(n, 1e-6)
    return SLSTMState(c, n, m_new, h), h


def slstm_forward(params, x: jax.Array, spec: XLSTMSpec, *,
                  chunk: int = 64) -> jax.Array:
    B, S, d = x.shape
    wx = (x @ params["wx"]).astype(jnp.float32)  # (B,S,4d)
    chunk = min(chunk, S)
    main = (S // chunk) * chunk

    @jax.checkpoint
    def chunk_fn(state, inputs):
        return jax.lax.scan(lambda s, i: _slstm_step(params, spec, s, i),
                            state, inputs)

    def outer(state, cidx):
        inp = jnp.moveaxis(
            jax.lax.dynamic_slice_in_dim(wx, cidx * chunk, chunk, 1), 1, 0)
        return chunk_fn(state, inp)

    state, hs = jax.lax.scan(outer, init_slstm_state(B, spec),
                             jnp.arange(main // chunk))
    hs = hs.reshape(main, B, d)
    if main < S:  # exact ragged tail
        state, ht = chunk_fn(state, jnp.moveaxis(wx[:, main:], 1, 0))
        hs = jnp.concatenate([hs, ht], axis=0)
    h = jnp.moveaxis(hs, 0, 1).astype(x.dtype)
    # gated FFN (paper: post-sLSTM up/down with pf 4/3)
    g, u = jnp.split(h @ params["ffn_wi"], 2, axis=-1)
    return jax.nn.gelu(g, approximate=True) * u @ params["ffn_wo"], state


def slstm_decode_step(params, x_t: jax.Array, state: SLSTMState,
                      spec: XLSTMSpec) -> tuple[jax.Array, SLSTMState]:
    wx = (x_t @ params["wx"]).astype(jnp.float32)
    state, h = _slstm_step(params, spec, state, wx)
    h = h.astype(x_t.dtype)
    g, u = jnp.split(h @ params["ffn_wi"], 2, axis=-1)
    return jax.nn.gelu(g, approximate=True) * u @ params["ffn_wo"], state
