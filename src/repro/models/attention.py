"""Attention: GQA/MQA + RoPE + optional qk-norm + sliding window + cross.

Training/prefill use a chunked flash formulation (lax.scan over KV chunks,
lax.map over Q chunks, running log-sum-exp) so the S x S score matrix is
never materialized — required for the 32k prefill cells to fit HBM.
Sliding-window layers iterate only the diagonal band (O(S*W), not O(S^2)).

EARTH integration: the fused KV projection emits the K/V of each head
INTERLEAVED along features ([k0,v0,k1,v1,...]) — one contiguous AoS beat per
token that is written to the interleaved KV cache in a single transaction;
decode splits it with the segment kernel (see kernels/kv_interleaved.py).
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro import vx
from repro.models import layers

NEG_INF = -1e30


class AttnParams(NamedTuple):
    wq: jax.Array          # (d, H*D)
    wkv: jax.Array         # (d, K*2D) feature-interleaved [k|v] per head
    wo: jax.Array          # (H*D, d)
    q_norm: jax.Array | None
    k_norm: jax.Array | None


def init_attention(key, d_model: int, n_heads: int, n_kv: int, head_dim: int,
                   *, qk_norm: bool, dtype) -> dict:
    kq, kk, kv, ko = jax.random.split(key, 4)
    s = d_model ** -0.5
    wk = jax.random.normal(kk, (d_model, n_kv, head_dim), dtype) * s
    wv = jax.random.normal(kv, (d_model, n_kv, head_dim), dtype) * s
    # interleave K/V output features -> one coalesced beat per token/head
    wkv = jnp.stack([wk, wv], axis=-1).reshape(d_model, n_kv * 2 * head_dim)
    p = {
        "wq": jax.random.normal(kq, (d_model, n_heads * head_dim), dtype) * s,
        "wkv": wkv,
        "wo": jax.random.normal(ko, (n_heads * head_dim, d_model), dtype)
              * (n_heads * head_dim) ** -0.5,
    }
    if qk_norm:
        p["q_norm"] = jnp.ones((head_dim,), dtype)
        p["k_norm"] = jnp.ones((head_dim,), dtype)
    return p


def qkv_project(params, x: jax.Array, n_heads: int, n_kv: int, head_dim: int,
                positions: jax.Array, rope_theta: float, *,
                policy=None):
    """x: (B, S, d) -> q (B,S,H,D), and the interleaved kv beat (B,S,K,2D).

    The kv beat is cache-layout-ready (AoS); splitting for use in attention
    is a FIELD=2 segment load.
    """
    B, S, _ = x.shape
    q = (x @ params["wq"]).reshape(B, S, n_heads, head_dim)
    kv = (x @ params["wkv"]).reshape(B, S, n_kv, 2 * head_dim)
    k, v = vx.transpose(vx.Segment(n=kv.shape[-1], fields=2), kv,
                        policy=policy)
    if params.get("q_norm") is not None:
        q = layers.rms_norm(q, params["q_norm"])
        k = layers.rms_norm(k, params["k_norm"])
    q = layers.rope(q, positions, rope_theta)
    k = layers.rope(k, positions, rope_theta)
    kv = vx.transpose(vx.Segment(n=kv.shape[-1], fields=2), [k, v],
                      policy=policy)  # re-pack post-RoPE beat
    return q, k, v, kv


def _flash_body(q, k, v, *, q_pos, kv_pos, causal, window, scale, kv_len):
    """One (Q-chunk x KV-chunk) tile. q: (B,K,G,Qc,D); k,v: (B,Kc,K,D).

    Masking is an ADDITIVE (Qc, Kc) fp32 bias, not a broadcasted where-pred:
    XLA hoists loop-invariant mask tensors out of the chunk scans, and a
    full-rank pred stacked over all (q, kv) tiles is ~25 GiB/device at
    granite train scale (measured); the 2-D bias hoists to ~0.5 MiB/tile
    and fuses into the score add."""
    s = jnp.einsum("bkgqd,bskd->bkgqs", q, k,
                   preferred_element_type=jnp.float32) * scale
    dq = q_pos[:, None]
    dk = kv_pos[None, :]
    mask = dk < kv_len  # padded KV tail is invalid
    if causal:
        mask &= dq >= dk
    if window is not None:
        mask &= (dq - dk) < window
    bias = jnp.where(mask, 0.0, NEG_INF).astype(jnp.float32)  # (Qc, Kc)
    return s + bias[None, None, None]


def _constrain_bkgsd(t, ctx):
    """Pin the batch dim of a (B, K, G, S, D) tensor to the data axes.

    Without this, XLA's sharding propagation replicates scan-invariant
    captures of the flash backward over the data axis — measured as
    24 GiB/device full-global-batch buffers at granite train scale."""
    if ctx is None or ctx.mesh is None or not ctx.data_axes:
        return t
    from jax.sharding import PartitionSpec as P
    return ctx.constrain(t, P(ctx.data_axes, None, None, None, None))


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int | None = None,
                    q_offset: int = 0, q_chunk: int = 512,
                    kv_chunk: int = 512, ctx=None) -> jax.Array:
    """Chunked flash attention with a memory-safe custom VJP.

    q: (B, Sq, H, D); k, v: (B, Sk, K, D) with H = K*G. Returns (B, Sq, H, D).
    Sliding-window layers only visit the diagonal band of KV chunks in the
    forward. The backward recomputes score tiles (never materializes the
    S x S exp-weights), saving only (q, k, v, out, lse).
    ``ctx`` (ShardCtx) pins batch/head shardings of the big intermediates.
    """
    B, Sq0, H, D = q.shape
    Sk0, K = k.shape[1], k.shape[2]
    q_chunk = min(q_chunk, Sq0)
    kv_chunk = min(kv_chunk, Sk0)
    if ctx is not None and ctx.mesh is not None:
        from jax.sharding import PartitionSpec as P
        ba = ctx.data_axes or None
        q = ctx.constrain(q, P(ba, None, ctx.model_if_divisible(H), None))
        k = ctx.constrain(k, P(ba, None, ctx.model_if_divisible(K), None))
        v = ctx.constrain(v, P(ba, None, ctx.model_if_divisible(K), None))
    # ragged sequences: pad to chunk multiples; padded KV masked by kv_len,
    # padded Q rows sliced off the output (pad/slice autodiff is exact)
    pad_q = (-Sq0) % q_chunk
    pad_k = (-Sk0) % kv_chunk
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    out = _flash(q, k, v, causal, window, q_offset, q_chunk, kv_chunk,
                 Sq0, Sk0, ctx)
    return out[:, :Sq0]


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7, 8, 9, 10))
def _flash(q, k, v, causal, window, q_offset, q_chunk, kv_chunk, Sq0, Sk0,
           ctx):
    out, _ = _flash_fwd(q, k, v, causal, window, q_offset, q_chunk,
                        kv_chunk, Sq0, Sk0, ctx)
    return out


def _flash_fwd(q, k, v, causal, window, q_offset, q_chunk, kv_chunk,
               Sq0, Sk0, ctx):
    B, Sq, H, D = q.shape
    Sk, K = k.shape[1], k.shape[2]
    G = H // K
    scale = D ** -0.5
    qr = q.reshape(B, Sq // q_chunk, q_chunk, K, G, D)

    banded = window is not None and Sk > window + q_chunk
    if banded:
        band = ((window + q_chunk + kv_chunk - 1) // kv_chunk) * kv_chunk
        band = min(band, Sk)

    def do_q_chunk(qi, qc):
        qt = jnp.moveaxis(qc, 1, 3).reshape(B, K, G, q_chunk, D)
        q_pos = q_offset + qi * q_chunk + jnp.arange(q_chunk)
        if banded:
            start = jnp.clip(q_offset + qi * q_chunk + q_chunk - band, 0,
                             Sk - band)
            kb = jax.lax.dynamic_slice_in_dim(k, start, band, axis=1)
            vb = jax.lax.dynamic_slice_in_dim(v, start, band, axis=1)
            kv_pos0 = start
            n_kv_chunks = band // kv_chunk
        else:
            kb, vb, kv_pos0, n_kv_chunks = k, v, 0, Sk // kv_chunk

        def kv_step(carry, si):
            m, l, acc = carry
            ks = jax.lax.dynamic_slice_in_dim(kb, si * kv_chunk, kv_chunk, 1)
            vs = jax.lax.dynamic_slice_in_dim(vb, si * kv_chunk, kv_chunk, 1)
            kv_pos = kv_pos0 + si * kv_chunk + jnp.arange(kv_chunk)
            s = _flash_body(qt, ks, vs, q_pos=q_pos, kv_pos=kv_pos,
                            causal=causal, window=window, scale=scale,
                            kv_len=Sk0)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1)
            pv = jnp.einsum("bkgqs,bskd->bkgqd", p, vs,
                            preferred_element_type=jnp.float32)
            acc_new = acc * corr[..., None] + pv
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, K, G, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, K, G, q_chunk), jnp.float32)
        a0 = jnp.zeros((B, K, G, q_chunk, D), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0),
                                      jnp.arange(n_kv_chunks))
        l_safe = jnp.maximum(l, 1e-30)
        out = acc / l_safe[..., None]
        lse = m + jnp.log(l_safe)                       # (B,K,G,Qc)
        return jnp.moveaxis(out.reshape(B, H, q_chunk, D), 1, 2), lse

    outs, lses = jax.lax.map(lambda args: do_q_chunk(*args),
                             (jnp.arange(Sq // q_chunk),
                              jnp.moveaxis(qr, 1, 0)))
    out = jnp.moveaxis(outs, 0, 1).reshape(B, Sq, H, D).astype(q.dtype)
    lse = jnp.moveaxis(lses, 0, 3).reshape(B, K, G, Sq)  # (B,K,G,Sq)
    return out, (q, k, v, out, lse)


def _flash_bwd(causal, window, q_offset, q_chunk, kv_chunk, Sq0, Sk0, ctx,
               res, g):
    """Tile-recomputing backward: dq via q-chunk scan, dk/dv accumulated
    across q chunks. Never materializes more than one score tile."""
    q, k, v, out, lse = res
    B, Sq, H, D = q.shape
    Sk, K = k.shape[1], k.shape[2]
    G = H // K
    scale = D ** -0.5
    qt = _constrain_bkgsd(
        jnp.moveaxis(q.reshape(B, Sq, K, G, D), 1, 3), ctx)  # (B,K,G,Sq,D)
    gt = _constrain_bkgsd(
        jnp.moveaxis(g.reshape(B, Sq, K, G, D), 1, 3).astype(jnp.float32),
        ctx)
    ot = _constrain_bkgsd(
        jnp.moveaxis(out.reshape(B, Sq, K, G, D), 1, 3).astype(jnp.float32),
        ctx)
    delta = jnp.sum(gt * ot, axis=-1)                        # (B,K,G,Sq)
    n_q = Sq // q_chunk
    n_kv = Sk // kv_chunk

    def q_step(carry, qi):
        dk, dv = carry
        sl = lambda a: jax.lax.dynamic_slice_in_dim(a, qi * q_chunk,
                                                    q_chunk, 3)
        q_i = sl(qt).astype(jnp.float32)
        g_i = sl(gt)
        lse_i = jax.lax.dynamic_slice_in_dim(lse, qi * q_chunk, q_chunk, 3)
        delta_i = jax.lax.dynamic_slice_in_dim(delta, qi * q_chunk,
                                               q_chunk, 3)
        q_pos = q_offset + qi * q_chunk + jnp.arange(q_chunk)

        def kv_step(c, si):
            dq_i, dk, dv = c
            ks = jax.lax.dynamic_slice_in_dim(k, si * kv_chunk, kv_chunk,
                                              1).astype(jnp.float32)
            vs = jax.lax.dynamic_slice_in_dim(v, si * kv_chunk, kv_chunk,
                                              1).astype(jnp.float32)
            kv_pos = si * kv_chunk + jnp.arange(kv_chunk)
            s = _flash_body(q_i, ks, vs, q_pos=q_pos, kv_pos=kv_pos,
                            causal=causal, window=window, scale=scale,
                            kv_len=Sk0)
            p = jnp.exp(s - lse_i[..., None])                # (B,K,G,Qc,Kc)
            dv_c = jnp.einsum("bkgqs,bkgqd->bskd", p, g_i)
            dp = jnp.einsum("bkgqd,bskd->bkgqs", g_i, vs)
            ds = p * (dp - delta_i[..., None]) * scale
            dq_i = dq_i + jnp.einsum("bkgqs,bskd->bkgqd", ds, ks)
            dk_c = jnp.einsum("bkgqs,bkgqd->bskd", ds, q_i)
            upd = lambda acc, c_: jax.lax.dynamic_update_slice_in_dim(
                acc, jax.lax.dynamic_slice_in_dim(
                    acc, si * kv_chunk, kv_chunk, 1) + c_,
                si * kv_chunk, 1)
            return (dq_i, upd(dk, dk_c), upd(dv, dv_c)), None

        dq0 = jnp.zeros((B, K, G, q_chunk, D), jnp.float32)
        (dq_i, dk, dv), _ = jax.lax.scan(kv_step, (dq0, dk, dv),
                                         jnp.arange(n_kv))
        return (dk, dv), dq_i

    dk0 = jnp.zeros((B, Sk, K, D), jnp.float32)
    dv0 = jnp.zeros((B, Sk, K, D), jnp.float32)
    if ctx is not None and ctx.mesh is not None and ctx.data_axes:
        from jax.sharding import PartitionSpec as P
        spec = P(ctx.data_axes, None, None, None)
        dk0, dv0 = ctx.constrain(dk0, spec), ctx.constrain(dv0, spec)
    (dk, dv), dq_chunks = jax.lax.scan(q_step, (dk0, dv0), jnp.arange(n_q))
    # dq_chunks: (n_q, B, K, G, Qc, D) -> (B, Sq, H, D)
    dq = jnp.moveaxis(dq_chunks, 0, 3).reshape(B, K, G, Sq, D)
    dq = jnp.moveaxis(dq, 3, 1).reshape(B, Sq, H, D)
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


_flash.defvjp(_flash_fwd, _flash_bwd)


def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     cache_len, *, window: int | None = None) -> jax.Array:
    """Single-token decode. q: (B, H, D); caches: (B, S, K, D).

    Masks positions >= cache_len (and outside the sliding window). This is
    the per-shard body of the sequence-parallel long-context path — callers
    may psum-merge the returned (out, lse) across a mesh axis.
    """
    B, H, D = q.shape
    S, K = k_cache.shape[1], k_cache.shape[2]
    G = H // K
    qt = q.reshape(B, K, G, D)
    s = jnp.einsum("bkgd,bskd->bkgs", qt, k_cache.astype(q.dtype),
                   preferred_element_type=jnp.float32) * D ** -0.5
    pos = jnp.arange(S)
    mask = pos[None, :] < cache_len  # (B?, S) cache_len scalar or (B,1)
    if window is not None:
        mask &= pos[None, :] >= (cache_len - window)
    s = jnp.where(mask[:, None, None, :] if mask.ndim == 2
                  else mask[None, None, None, :], s, NEG_INF)
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    l = jnp.sum(p, axis=-1, keepdims=True)
    out = jnp.einsum("bkgs,bskd->bkgd", p / jnp.maximum(l, 1e-30),
                     v_cache.astype(q.dtype),
                     preferred_element_type=jnp.float32)
    return out.reshape(B, H, D).astype(q.dtype)


def chunk_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                    q_pos: jax.Array, *,
                    window: int | None = None) -> jax.Array:
    """C-query prefill-chunk attention.  q: (B, C, H, D); caches:
    (B, S, K, D); ``q_pos``: (C,) absolute positions of the queries,
    or (B, C) when each batch row sits at its own offset (the
    speculative K-token verify step; negative entries mark pad queries
    that attend to nothing real — their outputs are garbage and must
    be gated by the caller).

    Query ``i`` attends to cache positions ``j <= q_pos[i]`` (causal
    over the already-written cache, which includes the chunk's own
    beats) and, for sliding-window layers, only within
    ``q_pos[i] - j < window`` — the same attended set
    :func:`decode_attention` masks one query at a time.  Numerics
    mirror decode_attention (fp32 scores, NEG_INF mask, max/exp/sum
    softmax), so a chunked prefill agrees with forced token-by-token
    decode to float tolerance."""
    B, C, H, D = q.shape
    S, K = k_cache.shape[1], k_cache.shape[2]
    G = H // K
    qt = q.reshape(B, C, K, G, D)
    s = jnp.einsum("bckgd,bskd->bkgcs", qt, k_cache.astype(q.dtype),
                   preferred_element_type=jnp.float32) * D ** -0.5
    j = jnp.arange(S)
    if q_pos.ndim == 2:                              # (B, C) per-row offsets
        mask = j[None, None, :] <= q_pos[:, :, None]  # (B, C, S)
        if window is not None:
            mask &= (q_pos[:, :, None] - j[None, None, :]) < window
        s = jnp.where(mask[:, None, None], s, NEG_INF)
    else:
        mask = j[None, :] <= q_pos[:, None]          # (C, S)
        if window is not None:
            mask &= (q_pos[:, None] - j[None, :]) < window
        s = jnp.where(mask[None, None, None], s, NEG_INF)
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    l = jnp.sum(p, axis=-1, keepdims=True)
    out = jnp.einsum("bkgcs,bskd->bkgcd", p / jnp.maximum(l, 1e-30),
                     v_cache.astype(q.dtype),
                     preferred_element_type=jnp.float32)
    return jnp.moveaxis(out, 3, 1).reshape(B, C, H, D).astype(q.dtype)


def cross_attention(params, x: jax.Array, enc_k: jax.Array, enc_v: jax.Array,
                    n_heads: int, n_kv: int, head_dim: int,
                    ctx=None) -> jax.Array:
    """Decoder cross-attention over encoder output (whisper). No RoPE."""
    B, S, _ = x.shape
    q = (x @ params["wq"]).reshape(B, S, n_heads, head_dim)
    out = flash_attention(q, enc_k, enc_v, causal=False, window=None,
                          q_chunk=min(512, S),
                          kv_chunk=min(512, enc_k.shape[1]), ctx=ctx)
    return out.reshape(B, S, n_heads * head_dim) @ params["wo"]


def init_cross_attention(key, d_model, n_heads, n_kv, head_dim, dtype) -> dict:
    kq, kk, kv, ko = jax.random.split(key, 4)
    s = d_model ** -0.5
    wk = jax.random.normal(kk, (d_model, n_kv, head_dim), dtype) * s
    wv = jax.random.normal(kv, (d_model, n_kv, head_dim), dtype) * s
    return {
        "wq": jax.random.normal(kq, (d_model, n_heads * head_dim), dtype) * s,
        "wkv": jnp.stack([wk, wv], axis=-1).reshape(d_model,
                                                    n_kv * 2 * head_dim),
        "wo": jax.random.normal(ko, (n_heads * head_dim, d_model), dtype)
              * (n_heads * head_dim) ** -0.5,
    }


def encoder_kv(params, enc_out: jax.Array, n_kv: int, head_dim: int,
               *, policy=None):
    """Project encoder output once per decode session (whisper)."""
    B, S, _ = enc_out.shape
    kv = (enc_out @ params["wkv"]).reshape(B, S, n_kv, 2 * head_dim)
    k, v = vx.transpose(vx.Segment(n=kv.shape[-1], fields=2), kv,
                        policy=policy)
    return k, v
