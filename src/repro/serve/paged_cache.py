"""Paged KV-cache state for serving: page pool + page table + free stack.

The device state itself lives in the cache pytree built by
``models/decode.init_paged_cache`` (pos / table / free / free_top /
blocks); this module wraps it with the HOST bookkeeping a scheduler needs
— capacity checks before admission, page accounting, jit'd release /
prefill-insert entry points — so `serve/scheduler.py` never touches the
pytree layout directly.

Memory model: attention layers share one pool of ``num_pages`` physical
pages per layer, so cache memory scales with ACTIVE tokens
(``pages_in_use * page_bytes``), not with ``slots * max_len`` the way the
dense fixed-slot cache does.  ``num_pages`` defaults to full
provisioning (every slot can reach ``max_len``).  Sizing it smaller
OVERCOMMITS the pool: the scheduler's admission check
(`serve/scheduler.py`) reserves pages for every live request's current
tokens plus headroom (not the max_len worst case), so long-running
decodes can still exhaust the stack mid-flight — when they do, the
decode step degrades locally (the starved slot's appends drop, no page
is ever aliased between slots) and the condition is observable as
``free_pages() == 0``; ``insert_prefill`` refuses outright rather than
starve a prompt.

PR 8 adds PREFIX SHARING on top of the same pool: pages carry a device
refcount (``state["ref"]``), a slot can ADOPT another request's pages
(``adopt_prefix`` points its table row at a shared run — the fused
gather already reads through the table, so sharing costs zero new
device work), a partial tail page is FORKED copy-on-write
(``fork_page``) before the borrower ever writes into it, and the
radix trie (serve/prefix_cache.py) holds an external +1 pin per
published page (``addref`` / ``deref_pages``).  ``check_invariants``
audits refcount conservation — every page's refcount equals the number
of table entries referencing it plus the trie pin — via the
``external_ref`` provider hook the scheduler installs.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels._common import pytree_nbytes
from repro.models import decode as dec
from repro.models.transformer import ModelConfig


class InvariantViolation(AssertionError):
    """A structural page-pool invariant broke on a live engine (page
    aliasing, free-stack corruption, pos/table divergence).  This is a
    state-management bug, never load: admission pressure degrades
    locally by design and must NOT trip this."""


class PagedCache:
    """Page pool + page-table state for a fixed-slot serving loop.

    ``debug_invariants=True`` audits the pool's structural invariants
    (:func:`repro.models.decode.paged_invariants`) after every mutation
    — one small device fetch per check, intended for debugging and the
    chaos harness (serve/chaos.py), which forces it ON for every step;
    the production fast path defaults to off and pays nothing."""

    def __init__(self, cfg: ModelConfig, slots: int, max_len: int,
                 page_size: int, *, cache_dtype=jnp.float32,
                 num_pages: int | None = None,
                 kv_quant: str | None = None,
                 debug_invariants: bool = False):
        self.cfg = cfg
        self.slots = slots
        self.max_len = max_len
        self.page_size = page_size
        self.kv_quant = kv_quant
        self.pages_per_seq = dec.pages_per_seq(max_len, page_size)
        self.num_pages = (slots * self.pages_per_seq
                          if num_pages is None else num_pages)
        self.state = dec.init_paged_cache(cfg, slots, max_len, page_size,
                                          cache_dtype,
                                          num_pages=self.num_pages,
                                          quantize=kv_quant)
        # state donated on every mutation: release/insert return a full
        # new pytree, and the pool is the big buffer — without donation
        # each finish()/admission would pay a pool copy
        self._release = jax.jit(
            lambda c, s: dec.paged_release_slot(cfg, c, s),
            donate_argnums=0)
        # one jit entry per PADDED prompt length (a page multiple): the
        # true length rides in as a traced operand, so mixed-length
        # traffic costs at most pages_per_seq distinct traces
        self._insert = {}
        # prefix-sharing entry points (PR 8): page-run adoption, CoW tail
        # fork, and the trie's external refcount pin — all donate the
        # state like release/insert do
        self._adopt = jax.jit(
            lambda c, s, ids: dec.paged_adopt_prefix(cfg, c, s, ids),
            donate_argnums=0)
        self._fork = jax.jit(
            lambda c, s, i, src, p: dec.paged_fork_page(
                cfg, c, s, i, src, pos_to=p),
            donate_argnums=0)
        self._addref = jax.jit(
            lambda c, ids: dec.paged_addref(cfg, c, ids),
            donate_argnums=0)
        self._deref = jax.jit(
            lambda c, ids: dec.paged_deref_pages(cfg, c, ids),
            donate_argnums=0)
        # external refcount provider (set by the scheduler to the prefix
        # trie's page_refs): pages pinned OUTSIDE any slot's table that
        # the conservation audit must account for
        self.external_ref = None
        self.debug_invariants = debug_invariants
        self.invariant_checks = 0

    # -- invariants ---------------------------------------------------------
    def check_invariants(self) -> None:
        """Audit page aliasing / refcount conservation / free-stack
        conservation / pos-vs-table occupancy on the LIVE device state
        (one small fetch — table, free stack, refcounts, positions;
        never the pool).  Raises :class:`InvariantViolation` listing
        every violation found."""
        self.invariant_checks += 1
        ext = self.external_ref() if self.external_ref is not None else None
        bad = dec.paged_invariants(self.cfg, self.state, external_ref=ext)
        if bad:
            raise InvariantViolation(
                "paged pool invariants violated:\n  " + "\n  ".join(bad))

    def _maybe_check(self) -> None:
        if self.debug_invariants:
            self.check_invariants()

    # -- capacity -----------------------------------------------------------
    def pages_needed(self, length: int) -> int:
        return -(-max(length, 1) // self.page_size)

    def free_pages(self) -> int:
        return int(self.state["free_top"])

    # -- accounting ---------------------------------------------------------
    def pages_in_use(self) -> int:
        return self.num_pages - self.free_pages()

    def active_tokens(self) -> int:
        return int(jnp.sum(self.state["pos"]))

    @staticmethod
    def _is_page_leaf(name: str, leaf) -> bool:
        """Pool leaves (rank-5 page pools) and their per-page scale side
        tensors (``scl*``) — everything whose axis 1 is the physical page
        axis and whose bytes scale with pages in use."""
        return hasattr(leaf, "ndim") and (
            leaf.ndim == 5 or name.startswith("scl"))

    def page_bytes(self) -> int:
        """Bytes of ONE page across every attention layer's pool — the
        quantized element type AND the per-page scale side tensor both
        count (dtype-aware: an int8 pool page is ~1/4 of a float32 one
        plus its float32 scale row)."""
        total = 0
        for name, leaf in self.state["blocks"].items():
            if self._is_page_leaf(name, leaf):
                total += (leaf.size // leaf.shape[1]) * leaf.dtype.itemsize
        return total

    def used_cache_bytes(self) -> int:
        """Bytes of cache state actually BACKING live requests: pages in
        use across all layer pools (including the per-page scale side
        tensors of a quantized pool), the page table, and the recurrent
        state — the number that scales with active tokens (the pool
        allocation itself is ``num_pages`` pages; size it to the traffic
        peak)."""
        recurrent = sum(
            pytree_nbytes(leaf)
            for name, leaf in self.state["blocks"].items()
            if not self._is_page_leaf(name, leaf))
        return (self.pages_in_use() * self.page_bytes()
                + self.state["table"].size
                * self.state["table"].dtype.itemsize + recurrent)

    def total_cache_bytes(self) -> int:
        """Full allocation footprint of the cache pytree."""
        return pytree_nbytes(self.state)

    # -- mutation (jit'd, slot-traced: no retrace per slot) -----------------
    def release(self, slot: int) -> None:
        self.state = self._release(self.state, jnp.int32(slot))
        self._maybe_check()

    def insert_prefill(self, slot: int, cache_states, length: int,
                       state_len: int | None = None) -> None:
        """Embed prefill states (computed over ``state_len`` tokens —
        defaults to ``length``) into the slot's pages."""
        state_len = length if state_len is None else state_len
        n_pg = self.pages_needed(state_len)
        if self.free_pages() < n_pg:
            raise RuntimeError(
                f"page pool exhausted: prompt needs {n_pg} pages, "
                f"{self.free_pages()} free")
        fn = self._insert.get(state_len)
        if fn is None:
            fn = self._insert[state_len] = jax.jit(functools.partial(
                dec.paged_insert_prefill, self.cfg, state_len=state_len),
                donate_argnums=0)
        self.state = fn(self.state, jnp.int32(slot), cache_states,
                        jnp.int32(length))
        self._maybe_check()

    # -- prefix sharing (jit'd, fixed-width operands: no retrace) -----------
    def _padded_ids(self, page_ids) -> jax.Array:
        arr = np.full((self.pages_per_seq,), -1, np.int32)
        arr[:len(page_ids)] = np.asarray(page_ids, np.int32)
        return jnp.asarray(arr)

    def adopt_prefix(self, slot: int, page_ids) -> None:
        """Point ``slot``'s table row at a run of SHARED pages (each gets
        +1 refcount) and set its position past them.  The pages are
        read-only to this slot until released — the partial tail, if
        any, must be forked (:meth:`fork_page`) before any write."""
        if len(page_ids) > self.pages_per_seq:
            raise ValueError(f"prefix run of {len(page_ids)} pages "
                             f"exceeds pages_per_seq={self.pages_per_seq}")
        self.state = self._adopt(self.state, jnp.int32(slot),
                                 self._padded_ids(page_ids))
        self._maybe_check()

    def fork_page(self, slot: int, logical_idx: int, src_page: int,
                  pos_to: int) -> None:
        """Copy-on-write fork: pop a fresh page, copy ``src_page``'s
        beats into it across every layer pool, and point ``slot``'s
        ``logical_idx`` table entry at the COPY (position set to
        ``pos_to``).  The shared source is never written in place."""
        if self.free_pages() < 1:
            raise RuntimeError("page pool exhausted: no free page to "
                               "fork the shared tail into")
        self.state = self._fork(self.state, jnp.int32(slot),
                                jnp.int32(logical_idx),
                                jnp.int32(src_page), jnp.int32(pos_to))
        self._maybe_check()

    def addref(self, page_ids) -> None:
        """External +1 pin per page (the trie publishing pages)."""
        for i in range(0, len(page_ids), self.pages_per_seq):
            self.state = self._addref(
                self.state,
                self._padded_ids(page_ids[i:i + self.pages_per_seq]))
        self._maybe_check()

    def deref_pages(self, page_ids) -> None:
        """Drop one reference per page; orphans (refcount hits zero) go
        back on the free stack — the trie-eviction release path."""
        for i in range(0, len(page_ids), self.pages_per_seq):
            self.state = self._deref(
                self.state,
                self._padded_ids(page_ids[i:i + self.pages_per_seq]))
        self._maybe_check()

    def page_refcounts(self) -> np.ndarray:
        """Host copy of the device refcounts (tests / stats)."""
        return np.asarray(self.state["ref"])

    def table_row(self, slot: int) -> np.ndarray:
        """Host copy of one slot's page-table row (publish path)."""
        return np.asarray(self.state["table"][slot])
