"""Serving: jit'd prefill/decode with sharded interleaved KV caches +
a paged continuous-batching runtime (scheduler / paged cache / executor)
hardened by a typed request lifecycle (admission backpressure,
preemption-and-restore, runtime guards) and a deterministic chaos
harness that proves it.
"""
from repro.serve.chaos import (ChaosConfig, ChaosReport,  # noqa: F401
                               FaultPlan, run_plan)
from repro.serve.engine import (BatchedServer, ServeConfig,  # noqa: F401
                                jit_decode_step, jit_prefill)
from repro.serve.lifecycle import (AdmissionError,  # noqa: F401
                                   AdmissionQueue, LifecycleError,
                                   Request, RequestState,
                                   TERMINAL_STATES, retry_with_backoff)
from repro.serve.paged_cache import (InvariantViolation,  # noqa: F401
                                     PagedCache)
from repro.serve.scheduler import Scheduler, sample_tokens  # noqa: F401
