"""Serving: jit'd prefill/decode with sharded interleaved KV caches +
a paged continuous-batching runtime (scheduler / paged cache / executor).
"""
from repro.serve.engine import (BatchedServer, ServeConfig,  # noqa: F401
                                jit_decode_step, jit_prefill)
from repro.serve.paged_cache import PagedCache  # noqa: F401
from repro.serve.scheduler import Scheduler, sample_tokens  # noqa: F401
