"""Serving: jit'd prefill/decode with sharded interleaved KV caches +
continuous batching."""
from repro.serve.engine import BatchedServer, ServeConfig, jit_decode_step  # noqa: F401
