"""Serving: jit'd prefill/decode with sharded interleaved KV caches +
a paged continuous-batching runtime (scheduler / paged cache / executor)
hardened by a typed request lifecycle (admission backpressure,
preemption-and-restore, runtime guards), a fault-tolerant replica fleet
(health-checked router, replay-based request migration), and a
deterministic chaos harness that proves both layers.
"""
from repro.serve.chaos import (ChaosConfig, ChaosReport,  # noqa: F401
                               FaultPlan, FleetChaosConfig,
                               FleetChaosReport, FleetFaultPlan,
                               StepClock, run_fleet_plan, run_plan)
from repro.serve.engine import (BatchedServer, ServeConfig,  # noqa: F401
                                jit_decode_step, jit_prefill, make_fleet)
from repro.serve.fleet import (FleetAuditError, FleetRouter,  # noqa: F401
                               Replica, ReplicaState)
from repro.serve.lifecycle import (AdmissionError,  # noqa: F401
                                   AdmissionQueue, LifecycleError,
                                   Request, RequestState,
                                   TERMINAL_STATES, retry_with_backoff)
from repro.serve.paged_cache import (InvariantViolation,  # noqa: F401
                                     PagedCache)
from repro.serve.scheduler import Scheduler, sample_tokens  # noqa: F401
