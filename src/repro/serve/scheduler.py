"""Continuous-batching scheduler over the paged KV runtime.

Split out of the old monolithic ``serve/engine.BatchedServer`` (which
survives there as a thin compat wrapper): this module owns ADMISSION
(free-slot + free-page checks, multi-token prompt prefill through the
existing jit'd prefill), the PER-STEP ACTIVE SET (one jit'd
``paged_decode_step`` over all slots with an ``active`` mask — idle
slots append nothing and advance nothing), SAMPLING (greedy argmax by
default; temperature / top-k with seeded per-slot PRNG keys), and
RECLAMATION (``finish`` releases the slot's pages back to the device
free stack and clears its per-slot state, so a reused slot can never
attend to the previous occupant's cache).

The hardened REQUEST LIFECYCLE (serve/lifecycle.py) layers on top:

  * ``submit`` places typed :class:`~repro.serve.lifecycle.Request`
    objects on a bounded admission queue (backpressure raises
    ``AdmissionError`` with a retry-after hint instead of crashing);
  * ``tick`` pumps the queue, steps the active set, and retires
    finished / expired requests — every admitted request ends in a
    terminal typed state;
  * PREEMPTION-AND-RESTORE: under page pressure a victim slot (lowest
    priority, then most pages held) is released and its request
    requeued carrying the accumulated tokens.  Resume re-runs the
    ORIGINAL prompt through the one jit'd prefill (bit-identical to
    first admission — same ``state_len``, same computation) and then
    REPLAYS the generated tokens through the ordinary jit'd decode step
    (inputs come from the replay cursor, sampled outputs are
    discarded), so post-catch-up decode is BIT-EXACT vs an
    uninterrupted run for every stack — the replay is literally the
    same computation the uninterrupted engine performed (prefill-based
    fast restore would only be allclose: prefill KV != decode KV at the
    ULP level).  Greedy decode preserves determinism across preemption;
    temperature sampling consumes extra PRNG splits during replay.
  * RUNTIME GUARDS (off by default — the steady-state fast path is one
    fused step, zero retraces, zero extra device work): per-slot
    NaN/Inf logit detection that fails ONLY the offending slot (pages
    reclaimed, request -> FAILED; neighbours are bit-unaffected — rows
    of the batched step are independent), a step wall-time watchdog
    reusing ``ft/straggler`` deadline logic, and per-mutation pool
    invariant auditing (``PagedCache.check_invariants``), always-on
    under the chaos harness (serve/chaos.py).

PR 8 — PREFIX SHARING and CHUNKED PREFILL:

  * Prompts now prefill in PAGE-SIZED CHUNKS through ONE fixed-width
    jit (``models/decode.paged_prefill_chunk`` — token width is the
    page size, the true count and slot ride in as traced operands, so
    every chunk of every prompt reuses the same trace and the same
    access plans).  ``tick`` advances each mid-prefill slot by
    ``chunk_pages`` chunks BETWEEN decode steps, so a long prompt no
    longer monopolizes the engine before the first decode token: the
    active set keeps stepping while admission streams pages in.  A
    mid-prefill slot is preemptible (``PREFILLING -> PREEMPTED``) and
    migratable — resume re-runs the chunks, which are bit-identical.
  * With ``prefix_cache=True`` (attention-only stacks) a radix trie
    (serve/prefix_cache.py) maps token prefixes to refcounted page
    runs: admission ADOPTS shared full pages (the slot's table points
    at them — zero new device work), FORKS a copy-on-write private
    tail when the match ends mid-page, and completed prefills PUBLISH
    their prompt pages back to the trie.  Release reclaims only
    orphaned pages; under page pressure the trie evicts LRU unpinned
    leaves before any running slot is preempted.  Decode over adopted
    pages is BIT-EXACT vs a private copy — the gather reads the same
    bits through the same table mechanism.
  * ``AdmissionError.retry_after`` now folds in the pending prefill
    backlog (queued + in-flight chunks, measured in chunk budgets per
    tick) on top of the decode-step EWMA, so backpressure hints stay
    honest when long prompts are queued.

Everything device-side is jit'd ONCE: per-step membership changes ride
in as array operands (token vector, active mask, page table), so steady
state pays zero retraces and zero plan-cache misses
(tests/test_serve.py asserts this).
"""
from __future__ import annotations

import functools
import time
from typing import Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.ft.straggler import StepWatchdog
from repro.models import decode as dec
from repro.models.transformer import ModelConfig
from repro.serve.lifecycle import (AdmissionError, AdmissionQueue, Request,
                                   RequestState)
from repro.serve.paged_cache import PagedCache
from repro.serve.prefix_cache import PrefixCache


def sample_tokens(logits: jax.Array, keys, *, temperature: float = 0.0,
                  top_k: int | None = None) -> jax.Array:
    """Per-slot sampling.  logits: (B, V); keys: (B,) PRNG keys.

    ``temperature <= 0`` (the default) is greedy argmax; otherwise
    categorical over ``logits / temperature``, restricted to the top-k
    logits when ``top_k`` is set (``top_k=1`` degenerates to argmax).
    """
    lg = logits.astype(jnp.float32)
    if temperature <= 0.0:
        return jnp.argmax(lg, axis=-1).astype(jnp.int32)
    lg = lg / temperature
    if top_k is not None and top_k < lg.shape[-1]:
        kth = jnp.sort(lg, axis=-1)[:, -top_k][:, None]
        lg = jnp.where(lg >= kth, lg, -jnp.inf)
    return jax.vmap(jax.random.categorical)(keys, lg).astype(jnp.int32)


class Scheduler:
    """Fixed-slot continuous batching over a shared page pool.

    ``page_size`` / ``num_pages`` size the pool (``num_pages=None`` fully
    provisions ``slots * pages_per_seq``); ``kv_quant`` ("int8" / "fp8")
    selects the QUANTIZED page pool — pages store narrow KV with
    per-page scales, dequant fused into the one page-gather program
    (models/decode.py), ~4x cache memory at bounded logit error;
    ``temperature`` / ``top_k`` /
    ``seed`` configure sampling (greedy by default, deterministic);
    ``prefill_pad`` pads prompts before prefill to bound jit retraces
    (defaults to the page size, so prompt caches always land on whole
    pages — a requirement of the paged insert).

    Lifecycle knobs: ``queue_depth`` bounds the admission queue
    (backpressure beyond it), ``preemption`` lets ``tick`` evict a
    victim under page pressure instead of stalling admission,
    ``guard_nan`` enables the per-slot NaN/Inf logit guard,
    ``watchdog`` (a :class:`~repro.ft.straggler.StepWatchdog`) tracks
    step wall-time deadline breaches, ``debug_invariants`` audits the
    page pool after every mutation, and ``clock`` is the injectable
    time source deadlines are measured against (chaos tests drive a
    fake clock).

    Prefix / prefill knobs (PR 8): ``prefix_cache=True`` enables the
    radix prefix cache (attention-only stacks; silently off elsewhere
    — recurrent state cannot ride in shared pages), ``chunk_pages``
    is the per-tick prefill budget in pages (``tick`` advances each
    mid-prefill slot by that many chunks between decode steps; the
    legacy ``add_request`` still prefills to completion before
    returning, through the same chunk jit).

    Speculative decode knobs (PR 10): ``speculate=K`` with a
    ``draft_cfg`` / ``draft_params`` small model turns decode into a
    K-token verify — the draft proposes K-1 tokens and the target
    checks all K through ONE fused page-gather/verify program per step
    (models/decode.paged_verify_step), with rejected tokens rolled
    back via page table + pos only.  Requires greedy sampling and an
    attention-only draft.  ``submit(..., speculate=k)`` sets a
    per-request width (clamped to the scheduler K; ``speculate=1``
    opts a request out), so speculative and normal slots mix in the
    same verify launch.
    """

    def __init__(self, cfg: ModelConfig, params, *, slots: int,
                 max_len: int, page_size: int | None = None,
                 num_pages: int | None = None, cache_dtype=jnp.float32,
                 kv_quant: str | None = None,
                 fuse_step: bool = True, temperature: float = 0.0,
                 top_k: int | None = None, seed: int = 0,
                 queue_depth: int | None = None, preemption: bool = True,
                 guard_nan: bool = False,
                 watchdog: StepWatchdog | None = None,
                 debug_invariants: bool = False,
                 prefix_cache: bool = False, chunk_pages: int = 1,
                 speculate: int = 1,
                 draft_cfg: ModelConfig | None = None, draft_params=None,
                 clock: Callable[[], float] = time.monotonic):
        if cfg.encoder is not None:
            raise NotImplementedError("paged serving covers decoder-only "
                                      "models")
        # speculation knobs are validated at construction like sampling:
        # a bad combination must fail loudly here, not at the first
        # verify step deep inside a serving loop
        if speculate < 1:
            raise ValueError(f"speculate must be >= 1, got {speculate}")
        if speculate > 1:
            if draft_cfg is None or draft_params is None:
                raise ValueError("speculate > 1 requires draft_cfg and "
                                 "draft_params (the small draft model)")
            if temperature > 0.0:
                raise ValueError(
                    "speculative decode requires greedy sampling "
                    "(temperature=0): verify accepts a draft iff it equals "
                    "the target argmax — a sampled target has no single "
                    "token to match against")
            if draft_cfg.encoder is not None or \
                    any(k != "attn" for k in draft_cfg.block_pattern):
                raise ValueError(
                    "draft model must be an attention-only decoder: the "
                    "draft cache rolls back rejected tokens via "
                    "paged_truncate (page table + pos only) and recurrent "
                    "draft state cannot be truncated that way")
        # sampling knobs are validated HERE, not inside the jit'd sampler
        # — a bad value must fail loudly at construction, not propagate
        # silently through sample_tokens (top_k <= 0 made the top-k mask
        # drop every logit; negative temperature inverted the
        # distribution)
        if temperature < 0.0:
            raise ValueError(f"temperature must be >= 0 (0 = greedy), "
                             f"got {temperature}")
        if top_k is not None and top_k <= 0:
            raise ValueError(f"top_k must be a positive int or None, "
                             f"got {top_k}")
        if chunk_pages < 1:
            raise ValueError(f"chunk_pages must be >= 1, got {chunk_pages}")
        from repro import vx
        self.cfg, self.params = cfg, params
        self.slots, self.max_len = slots, max_len
        page_size = min(page_size or 16, max_len)
        self.cache = PagedCache(cfg, slots, max_len, page_size,
                                cache_dtype=cache_dtype,
                                num_pages=num_pages, kv_quant=kv_quant,
                                debug_invariants=debug_invariants)
        self.temperature, self.top_k = float(temperature), top_k
        vx.warm(2 * cfg.hd, strided=False, fields=(2,),
                policy=cfg.vx_policy)
        # cache donated: the pool is the big buffer and the step replaces
        # it wholesale — without donation every append pays a pool copy
        self._step = jax.jit(
            lambda p, c, t, a: dec.paged_decode_step(
                p, c, t, cfg, None, active=a, fuse=fuse_step),
            donate_argnums=1)
        self._sample = jax.jit(functools.partial(
            sample_tokens, temperature=self.temperature, top_k=top_k))
        # guard variant: sampling fused with the per-slot finite check so
        # the guard costs one extra reduction, not a second step
        self._sample_guarded = jax.jit(functools.partial(
            self._sample_and_check, temperature=self.temperature,
            top_k=top_k))
        self._split_keys = jax.jit(
            lambda ks: jnp.swapaxes(jax.vmap(
                lambda k: jax.random.split(k, 2))(ks), 0, 1))
        self._keys = jax.random.split(jax.random.key(seed), slots)
        # chunked prefill: ONE fixed-width jit (token width = page size;
        # slot and true count are traced operands) covers every chunk of
        # every prompt — the same trace and the same vx access plans,
        # so prefill adds nothing to the steady-state plan-cache
        # footprint.  State donated like the decode step.
        self._chunk = jax.jit(
            lambda p, c, t, s, n: dec.paged_prefill_chunk(
                p, c, t, cfg, None, slot=s, count=n),
            donate_argnums=1)
        self.chunk_pages = int(chunk_pages)
        self._prefilling: dict[int, int] = {}   # slot -> prefilled tokens
        self.prefill_chunks = 0
        # -- speculative decode (PR 10) --------------------------------------
        # The verify width is STATIC (= ``speculate``): the toks operand is
        # always (slots, K) and per-slot effective widths ride in as the
        # traced ``n_draft`` vector, so mixed speculative/normal slots and
        # replay catch-up all reuse ONE verify trace and ONE set of access
        # plans (tests assert zero PLANS misses across mixed K).  The
        # draft model runs in its OWN page pool (fully provisioned — the
        # draft is small) through the same chunk/step jits as the target.
        self.speculate = int(speculate)
        self.draft_cfg, self.draft_params = draft_cfg, draft_params
        self.draft_cache: PagedCache | None = None
        if self.speculate > 1:
            self.draft_cache = PagedCache(
                draft_cfg, slots, max_len, self.cache.page_size,
                cache_dtype=cache_dtype,
                debug_invariants=debug_invariants)
            vx.warm(2 * draft_cfg.hd, strided=False, fields=(2,),
                    policy=draft_cfg.vx_policy)
            self._verify = jax.jit(
                lambda p, c, t, n, a: dec.paged_verify_step(
                    p, c, t, cfg, None, n_draft=n, active=a,
                    fuse=fuse_step),
                donate_argnums=1)
            self._verify_finite = jax.jit(
                lambda lg: jnp.all(jnp.isfinite(lg.astype(jnp.float32)),
                                   axis=-1))
            self._dstep = jax.jit(
                lambda p, c, t, a: dec.paged_decode_step(
                    p, c, t, draft_cfg, None, active=a, fuse=fuse_step),
                donate_argnums=1)
            self._dchunk = jax.jit(
                lambda p, c, t, s, n: dec.paged_prefill_chunk(
                    p, c, t, draft_cfg, None, slot=s, count=n),
                donate_argnums=1)
            self._dtrunc = jax.jit(
                lambda c, np_: dec.paged_truncate(draft_cfg, c, np_),
                donate_argnums=0)
        self._spec_k = [1] * slots   # per-slot verify width (request K)
        self._dpos = [0] * slots     # draft tokens consumed (host mirror)
        self.spec_steps = 0          # verify steps taken
        self.spec_proposed = 0       # draft tokens proposed to verify
        self.spec_accepted = 0       # draft tokens accepted by verify
        # prefix sharing is only sound when every layer's state lives in
        # the page pool: recurrent blocks fold the prefix into per-slot
        # state that pages cannot carry, so the trie is gated to
        # attention-only stacks (windowed included — pages hold full KV)
        self.prefix: PrefixCache | None = None
        if prefix_cache and all(k == "attn" for k in cfg.block_pattern):
            self.prefix = PrefixCache(self.cache.page_size,
                                      self.cache.num_pages)
            self.cache.external_ref = self.prefix.page_refs
        self.active = [False] * slots
        self.tokens: list[list[int]] = [[] for _ in range(slots)]
        self.last_logits = None      # (slots, V) of the latest step
        # -- lifecycle state ------------------------------------------------
        self.clock = clock
        self.preemption = preemption
        self.guard_nan = guard_nan
        self.watchdog = watchdog
        self.queue = AdmissionQueue(
            queue_depth if queue_depth is not None else 4 * slots,
            retry_after_hint=self._retry_after)
        self.requests: dict[int, Request] = {}     # rid -> Request
        self._slot_req: list[Request | None] = [None] * slots
        # replay cursor: index into tokens[s] of the NEXT input token.
        # Normal decode keeps it at len(tokens[s]) - 1; a resumed slot
        # starts behind and catches up one token per step, discarding
        # the (re-)sampled outputs until it does.
        self._fed = [0] * slots
        self._pos = [0] * slots      # host mirror of cache.state["pos"]
        self._taint: np.ndarray | None = None   # chaos NaN-injection hook
        self._newly_terminal: list[Request] = []   # failed outside tick
        self._step_ewma = 0.0
        self.nan_failures = 0
        self.preemptions = 0
        # per-request latency accounting (host clock, zero device work):
        # TTFT = first decoded token minus submit; inter-token latency is
        # the per-token gap between appends (a K-token speculative commit
        # records gap/K for each — that is exactly the latency win the
        # bench row has to show).  Samples aggregate to p50/p99 in stats().
        self._submit_t: dict[int, float] = {}     # rid -> submit time
        self._last_tok_t: dict[int, float] = {}   # rid -> last append time
        self._ttft: list[float] = []
        self._itl: list[float] = []

    @staticmethod
    def _sample_and_check(logits, keys, *, temperature, top_k):
        lg32 = logits.astype(jnp.float32)
        return (sample_tokens(logits, keys, temperature=temperature,
                              top_k=top_k),
                jnp.all(jnp.isfinite(lg32), axis=-1))

    # -- admission ----------------------------------------------------------
    def free_slot(self) -> int | None:
        for s in range(self.slots):
            if not self.active[s]:
                return s
        return None

    def _reserved_pages(self) -> int:
        """Pages live requests will need for their CURRENT tokens — plus
        the K-token worst case for speculative slots: a verify step may
        append up to ``_spec_k[s]`` tokens before any rollback, so those
        pages must be admissible even if every draft is accepted."""
        return sum(self.cache.pages_needed(len(self.tokens[s])
                                           + self._spec_k[s] - 1)
                   for s in range(self.slots) if self.active[s])

    def _pages_for(self, toks: Sequence[int], k: int = 1) -> int:
        return self.cache.pages_needed(max(len(toks) - 1, 1) + k - 1) + 1

    def _req_k(self, req: Request) -> int:
        """Effective verify width for a request: its own ``speculate``
        clamped into [1, scheduler K]."""
        return max(1, min(int(getattr(req, "speculate", 1)),
                          self.speculate))

    def add_request(self, prompt: int | Sequence[int]) -> int:
        """Admit a request immediately (the legacy surface).  ``prompt``
        is a full token list (or a single int); all but the last token
        are prefilled into the slot's pages through the jit'd prefill,
        and the last token is fed to the next decode step (so
        ``tokens[slot]`` stays prompt + generated).  Raises
        :class:`AdmissionError` (a ``RuntimeError``) with a retry-after
        hint when no slot or not enough free pages — use ``submit`` for
        queued admission with backpressure and preemption."""
        toks = [int(prompt)] if isinstance(prompt, int) else \
            [int(t) for t in prompt]
        if not toks:
            raise ValueError("empty prompt")
        if len(toks) > self.max_len:
            raise ValueError(f"prompt of {len(toks)} tokens exceeds "
                             f"max_len={self.max_len}")
        req = Request(prompt=toks, speculate=self.speculate)
        req.arrival_seq = next(self.queue._seq)
        self.requests[req.rid] = req
        self._submit_t[req.rid] = self.clock()
        try:
            return self._admit_into(req, sync=True)
        except AdmissionError as e:
            if not req.terminal:
                req.to(RequestState.FAILED, error=str(e))
            raise

    def _admit_into(self, req: Request, *, sync: bool = False) -> int:
        """Place a QUEUED request into a free slot and start its
        CHUNKED prefill: the prefix trie serves any shared full pages
        (adopted, +1 refcount each) and a copy-on-write fork of a
        partially-matching tail; the rest streams in page-sized chunks
        — synchronously to completion when ``sync`` (the legacy
        ``add_request`` surface), otherwise one ``chunk_pages`` budget
        per ``tick`` interleaved with decode steps.  Resume after
        preemption re-runs the SAME chunks (one fixed jit — bit-exact
        restart state) and arms the replay cursor over previously
        generated tokens.  Raises AdmissionError when capacity is
        missing; the caller (tick) may preempt and retry."""
        toks = req.tokens
        slot = self.free_slot()
        if slot is None:
            raise AdmissionError("no free slot",
                                 retry_after=self._retry_after())
        # pages are allocated lazily (prefill now, decode appends later):
        # admit against RESERVED pages — what live requests will need for
        # their current tokens plus pages locked in the trie — not just
        # the instantaneous free count.  Trie orphans are evictable, so
        # under pressure LRU leaves are dropped before refusing.
        need = self._pages_for(toks, self._req_k(req))
        avail = self.cache.num_pages - self._reserved_pages()
        if avail < need:
            avail += self._evict_prefix(need - avail)
        if avail < need:
            raise AdmissionError(
                "page pool exhausted; finish a request or grow num_pages",
                retry_after=self._retry_after())
        req.to(RequestState.PREFILLING)
        self.active[slot] = True
        self.tokens[slot] = list(toks)
        self._fed[slot] = 0
        self._pos[slot] = 0
        self._spec_k[slot] = self._req_k(req)
        self._dpos[slot] = 0     # draft catches up lazily via the pump
        self._slot_req[slot] = req
        req.slot = slot
        try:
            self._begin_prefill(slot, req)
            if sync:
                while slot in self._prefilling:
                    if not self._advance_prefill(slot, self.chunk_pages):
                        raise AdmissionError(
                            "page pool exhausted mid-prefill; finish a "
                            "request or grow num_pages",
                            retry_after=self._retry_after())
        except AdmissionError:
            self._release_slot(slot)
            raise
        except Exception as e:       # noqa: BLE001 — typed terminal state
            req.to(RequestState.FAILED, error=f"prefill: {e}")
            self._release_slot(slot)
            raise
        return slot

    # -- chunked prefill ----------------------------------------------------
    def _begin_prefill(self, slot: int, req: Request) -> None:
        """Arm the prefill cursor: serve whatever prefix the trie holds
        (full-page run adopted; partial tail forked CoW when a free
        page exists — otherwise the tail is simply recomputed), then
        leave the remainder to ``_advance_prefill``.  Single-token
        prompts have nothing to prefill and go straight to RUNNING."""
        prompt = req.prompt
        pre = prompt[:-1]
        if not pre:
            self._finish_prefill(slot)
            return
        done = 0
        if self.prefix is not None:
            m = self.prefix.acquire(slot, pre)
            if m.run:
                self.cache.adopt_prefix(slot, list(m.run))
                done = len(m.run) * self.cache.page_size
            if m.fork_src >= 0 and self.cache.free_pages() >= 1:
                self.cache.fork_page(slot, len(m.run), m.fork_src,
                                     done + m.fork_len)
                done += m.fork_len
        self._prefilling[slot] = done
        self._pos[slot] = done
        if done >= len(pre):
            self._finish_prefill(slot)

    def _advance_prefill(self, slot: int, chunks: int) -> bool:
        """Run up to ``chunks`` page-sized prefill chunks for ``slot``
        through the ONE fixed-width chunk jit.  Returns False when the
        pool cannot back the next chunk even after trie eviction — the
        caller preempts the slot (PREFILLING -> PREEMPTED) rather than
        let the device allocator starve the prompt silently."""
        req = self._slot_req[slot]
        pre = req.prompt[:-1]
        ps = self.cache.page_size
        c = self._prefilling[slot]
        for _ in range(chunks):
            if c >= len(pre):
                break
            n = min(ps, len(pre) - c)
            newp = self.cache.pages_needed(c + n) - \
                (0 if c == 0 else -(-c // ps))
            if self.cache.free_pages() < newp:
                self._evict_prefix(newp - self.cache.free_pages())
            if self.cache.free_pages() < newp:
                return False
            tok = jnp.asarray(pre[c:c + n] + [0] * (ps - n), jnp.int32)
            self.cache.state = self._chunk(self.params, self.cache.state,
                                           tok, jnp.int32(slot),
                                           jnp.int32(n))
            self.cache._maybe_check()
            c += n
            self._prefilling[slot] = c
            self._pos[slot] = c
            self.prefill_chunks += 1
        if c >= len(pre):
            self._finish_prefill(slot)
        return True

    def _finish_prefill(self, slot: int) -> None:
        """Prefill complete: publish the prompt's full pages to the trie
        (newly inserted ones take the trie's +1 device pin), arm the
        replay cursor, and mark the request RUNNING — the next decode
        step feeds the last prompt token through the ordinary jit."""
        req = self._slot_req[slot]
        self._prefilling.pop(slot, None)
        pre = req.prompt[:-1]
        if self.prefix is not None and pre:
            new = self.prefix.publish(slot, pre,
                                      self.cache.table_row(slot))
            if new:
                self.cache.addref(new)
        self._fed[slot] = len(req.prompt) - 1
        self._pos[slot] = len(req.prompt) - 1
        req.to(RequestState.RUNNING)

    def _evict_prefix(self, n_pages: int) -> int:
        """Drop up to ``n_pages`` LRU unpinned trie leaves and return
        how many pages that freed — the page-pressure valve that runs
        BEFORE any running slot is preempted."""
        if self.prefix is None or n_pages <= 0:
            return 0
        ids = self.prefix.evict(n_pages)
        if ids:
            self.cache.deref_pages(ids)
        return len(ids)

    def _pending_prefill_pages(self) -> int:
        """Prefill chunks still owed: in-flight cursors plus every
        queued prompt — what a newly refused client is waiting behind."""
        ps = self.cache.page_size
        pend = 0
        for s, c in self._prefilling.items():
            req = self._slot_req[s]
            if req is not None:
                pend += -(-max(len(req.prompt) - 1 - c, 0) // ps)
        for r in self.queue._q:
            pend += -(-max(len(r.prompt) - 1, 0) // ps)
        return pend

    def _retry_after(self) -> float:
        """Honest backpressure hint: decode-step EWMA scaled by the
        pending prefill backlog (in per-tick chunk budgets) — a long
        queued prompt delays capacity by its chunk count, not by one
        decode step."""
        ew = self._step_ewma or 0.0
        return ew * (1.0 + self._pending_prefill_pages()
                     / max(self.chunk_pages, 1))

    def submit(self, prompt: Sequence[int], *, max_new_tokens: int | None
               = None, priority: int = 0, deadline: float | None = None,
               ttl: float | None = None,
               speculate: int | None = None) -> Request:
        """Queue a typed request for admission by ``tick``.

        Malformed requests (empty / oversized prompt, non-positive
        ``max_new_tokens``) come back already FAILED — a terminal typed
        state, not an exception, so chaos traffic can always account
        for them.  A full queue raises :class:`AdmissionError`
        (backpressure; pair with
        :func:`repro.serve.lifecycle.retry_with_backoff`)."""
        if ttl is not None:
            deadline = self.clock() + ttl if deadline is None else \
                min(deadline, self.clock() + ttl)
        req = Request(prompt=list(prompt), max_new_tokens=max_new_tokens,
                      priority=priority, deadline=deadline,
                      speculate=self.speculate if speculate is None
                      else int(speculate))
        self.requests[req.rid] = req
        self._submit_t[req.rid] = self.clock()
        if req.speculate < 1:
            req.to(RequestState.FAILED,
                   error=f"speculate must be >= 1, got {req.speculate}")
            return req
        if not req.prompt:
            req.to(RequestState.FAILED, error="empty prompt")
            return req
        if len(req.prompt) > self.max_len:
            req.to(RequestState.FAILED,
                   error=f"prompt of {len(req.prompt)} tokens exceeds "
                         f"max_len={self.max_len}")
            return req
        if max_new_tokens is not None and max_new_tokens <= 0:
            req.to(RequestState.FAILED,
                   error=f"max_new_tokens must be positive, "
                         f"got {max_new_tokens}")
            return req
        try:
            self.queue.push(req)
        except AdmissionError:
            del self.requests[req.rid]       # never admitted: no zombie
            raise
        return req

    # -- preemption ---------------------------------------------------------
    def _victim(self, *, below_priority: int | None = None) -> int | None:
        """Victim slot by policy: lowest priority first, then MOST pages
        held (frees the most), then highest slot id (deterministic)."""
        best = None
        for s in range(self.slots):
            req = self._slot_req[s]
            if not self.active[s] or req is None:
                continue
            if below_priority is not None and \
                    req.priority >= below_priority:
                continue
            key = (-req.priority,
                   self.cache.pages_needed(max(len(self.tokens[s]), 1)),
                   s)
            if best is None or key > best[0]:
                best = (key, s)
        return best[1] if best else None

    def preempt(self, slot: int) -> Request:
        """Evict a running OR mid-prefill slot: release its pages back
        to the free stack (shared prefix pages survive under the trie's
        refcount pin) and requeue its request carrying prompt +
        generated so far.  ``tick`` will resume it (prompt re-prefilled
        bit-exactly through the same chunk jit, generated tokens
        replayed through the ordinary decode step)."""
        req = self._slot_req[slot]
        if req is None or not self.active[slot]:
            raise ValueError(f"slot {slot} is not running a request")
        req.tokens = list(self.tokens[slot])
        req.to(RequestState.PREEMPTED)
        req.slot = None
        self._release_slot(slot)
        self.preemptions += 1
        self.queue.push(req, force=True)
        return req

    def fail_slot(self, slot: int, error: str) -> Request | None:
        """Fail ONLY this slot (NaN guard, chaos slot-death): pages are
        reclaimed, the request goes terminal, neighbours keep stepping
        — the per-slot analogue of the pool's local degradation."""
        req = self._slot_req[slot]
        if req is not None and not req.terminal:
            req.tokens = list(self.tokens[slot])
            req.to(RequestState.FAILED, error=error)
            self._newly_terminal.append(req)
        self._release_slot(slot)
        return req

    def _release_slot(self, slot: int) -> None:
        if self.active[slot]:
            self.cache.release(slot)
            if self.draft_cache is not None:
                self.draft_cache.release(slot)
        if self.prefix is not None:
            self.prefix.release(slot)
        self._prefilling.pop(slot, None)
        self.active[slot] = False
        self.tokens[slot] = []
        self._fed[slot] = 0
        self._pos[slot] = 0
        self._spec_k[slot] = 1
        self._dpos[slot] = 0
        self._slot_req[slot] = None

    # -- decode -------------------------------------------------------------
    def step(self) -> list[int]:
        """Advance every ACTIVE slot; idle slots report -1.

        Slots behind their replay cursor (resumed after preemption) feed
        the next REPLAYED token and discard the sampled output until
        they catch up — same jit'd step, zero retraces.  Mid-prefill
        slots are masked out exactly like idle ones (they occupy a slot
        but decode nothing until their chunks complete).  When any slot
        speculates this step the whole active set goes through the ONE
        fused K-wide verify program instead (``_step_speculative``):
        normal slots ride along at width 1, so mixed speculative/normal
        batches still pay one launch per step."""
        t0 = time.perf_counter()
        decoding = [self.active[s] and s not in self._prefilling
                    for s in range(self.slots)]
        if self.speculate > 1 and any(
                decoding[s] and self._spec_k[s] > 1
                for s in range(self.slots)):
            out = self._step_speculative(decoding)
        else:
            out = self._step_plain(decoding)
        self.cache._maybe_check()
        dt = time.perf_counter() - t0
        self._step_ewma = dt if self._step_ewma == 0.0 else \
            0.8 * self._step_ewma + 0.2 * dt
        if self.watchdog is not None:
            self.watchdog.observe(dt)
        return out

    def _step_plain(self, decoding: list[bool]) -> list[int]:
        """The single-token decode step (pre-PR 10 semantics, verbatim)."""
        cur = jnp.asarray([self.tokens[s][self._fed[s]]
                           if decoding[s] else 0
                           for s in range(self.slots)], jnp.int32)
        act = jnp.asarray(decoding)
        logits, self.cache.state = self._step(self.params,
                                              self.cache.state, cur, act)
        if self._taint is not None:      # chaos-only NaN injection hook
            mask = jnp.asarray(self._taint)[:, None]
            logits = jnp.where(mask, jnp.float32(jnp.nan),
                               logits.astype(jnp.float32)).astype(
                                   logits.dtype)
            self._taint = None
        self.last_logits = logits
        if self.temperature > 0.0:
            self._keys, sub = self._split_keys(self._keys)
        else:
            sub = self._keys
        if self.guard_nan:
            nxt, fin = self._sample_guarded(logits, sub)
            nxt, fin = np.asarray(nxt), np.asarray(fin)
        else:
            nxt = np.asarray(self._sample(logits, sub))
            fin = None                 # ONE host sync for all slots
        out = []
        t_now = self.clock()
        seq_cap = self.cache.pages_per_seq * self.cache.page_size
        for s in range(self.slots):
            t = int(nxt[s])
            if not decoding[s]:
                out.append(-1)
                continue
            if fin is not None and not fin[s]:
                self.nan_failures += 1
                self.fail_slot(s, "non-finite logits")
                out.append(-1)
                continue
            if self._pos[s] < seq_cap:
                self._pos[s] += 1
            if self._fed[s] < len(self.tokens[s]) - 1:
                self._fed[s] += 1      # replay: discard the sample
            else:
                self.tokens[s].append(t)
                self._fed[s] += 1
                self._note_tokens(s, t_now, 1)
            out.append(t)
        return out

    def _step_speculative(self, decoding: list[bool]) -> list[int]:
        """One K-wide verify step over the whole active set.

        Per slot the verify batch is: up to ``_spec_k`` recorded tokens
        when the slot is behind its replay cursor (recorded tokens are
        perfect drafts under greedy decode — replay catches up K tokens
        per launch), otherwise the head token plus ``_spec_k - 1``
        draft-model tokens from :meth:`_draft_pump`.  Commit ``c``
        advances the cursor / appends exactly the tokens the
        non-speculative oracle would produce; rejected pages were
        already rolled back inside the verify jit (page table + pos
        only).  The draft cache is then truncated to the committed
        position the same page-table way."""
        K = self.speculate
        toks = np.zeros((self.slots, K), np.int32)
        nd = np.ones((self.slots,), np.int32)
        recorded = [0] * self.slots
        need = [0] * self.slots
        for s in range(self.slots):
            if not decoding[s]:
                continue
            k = self._spec_k[s]
            req = self._slot_req[s]
            if req is not None and req.max_new_tokens is not None:
                # a commit may append at most the request's remaining
                # budget: K columns past it would overshoot max_new_tokens
                # by up to K-1 tokens vs the non-speculative oracle
                behind = len(self.tokens[s]) - 1 - self._fed[s]
                done = len(self.tokens[s]) - len(req.prompt)
                rem = max(req.max_new_tokens - done, 0)
                k = max(1, min(k, behind + rem))
            avail = len(self.tokens[s]) - self._fed[s]
            r = min(avail, k)
            toks[s, :r] = self.tokens[s][self._fed[s]:self._fed[s] + r]
            recorded[s] = r
            nd[s] = r
            if r == avail and k > r:
                need[s] = k - r          # top up with draft-model tokens
        if any(need):
            drafts = self._draft_pump(need)
            for s in range(self.slots):
                if need[s]:
                    got = drafts[s]
                    toks[s, recorded[s]:recorded[s] + len(got)] = got
                    nd[s] = recorded[s] + len(got)
        act = jnp.asarray(decoding)
        logits, o, commit, self.cache.state = self._verify(
            self.params, self.cache.state, jnp.asarray(toks),
            jnp.asarray(nd), act)
        if self._taint is not None:      # chaos-only NaN injection hook
            mask = jnp.asarray(self._taint)[:, None, None]
            logits = jnp.where(mask, jnp.float32(jnp.nan),
                               logits.astype(jnp.float32)).astype(
                                   logits.dtype)
            self._taint = None
        self.last_logits = logits[:, 0, :]
        if self.guard_nan:
            fin = np.asarray(self._verify_finite(logits))   # (B, K)
        else:
            fin = None
        o_np, cm = np.asarray(o), np.asarray(commit)
        out = []
        t_now = self.clock()
        seq_cap = self.cache.pages_per_seq * self.cache.page_size
        drafted = False
        for s in range(self.slots):
            if not decoding[s]:
                out.append(-1)
                continue
            c = max(int(cm[s]), 1)
            if fin is not None and not np.all(fin[s, :c]):
                self.nan_failures += 1
                self.fail_slot(s, "non-finite logits")
                out.append(-1)
                continue
            fresh = 0
            for j in range(c):
                if self._fed[s] < len(self.tokens[s]) - 1:
                    self._fed[s] += 1    # replay: record already has it
                else:
                    self.tokens[s].append(int(o_np[s, j]))
                    self._fed[s] += 1
                    fresh += 1
            self._pos[s] = min(self._pos[s] + c, seq_cap)
            if need[s]:
                drafted = True
                self.spec_proposed += need[s]
                self.spec_accepted += max(0, c - recorded[s])
            if fresh:
                self._note_tokens(s, t_now, fresh)
            out.append(int(o_np[s, c - 1]))
        self.spec_steps += 1
        if drafted:
            # rejected draft-cache tail rolls back via page table + pos;
            # a fully-accepted step leaves the draft one token behind,
            # which the next pump's catch-up singles cover
            self.draft_cache.state = self._dtrunc(
                self.draft_cache.state, jnp.asarray(self._pos, jnp.int32))
            self.draft_cache._maybe_check()
            for s in range(self.slots):
                self._dpos[s] = min(self._dpos[s], self._pos[s])
        return out

    def _draft_pump(self, need: list[int]) -> list[list[int]]:
        """Produce ``need[s]`` draft tokens per slot from the draft model.

        First catch the draft cache up to the slot's recorded tokens —
        bulk full pages through the ONE draft chunk jit (a freshly
        admitted or migrated slot replays its whole prompt here), then
        per-token singles — then autoregress the drafts by feeding the
        head token and the draft's own argmaxes.  Singles are batched
        across slots through one draft step jit with an active mask, so
        the steady state (deficit <= 1) costs ``need`` draft launches
        regardless of slot count."""
        dc = self.draft_cache
        ps = dc.page_size
        for s in range(self.slots):
            if need[s] <= 0:
                continue
            target = len(self.tokens[s]) - 1     # tokens before the head
            while self._dpos[s] % ps == 0 and \
                    target - self._dpos[s] >= ps:
                tok = jnp.asarray(
                    self.tokens[s][self._dpos[s]:self._dpos[s] + ps],
                    jnp.int32)
                dc.state = self._dchunk(self.draft_params, dc.state, tok,
                                        jnp.int32(s), jnp.int32(ps))
                self._dpos[s] += ps
        drafts: list[list[int]] = [[] for _ in range(self.slots)]
        pend = {s for s in range(self.slots) if need[s] > 0}
        while pend:
            feed = np.zeros((self.slots,), np.int32)
            act = np.zeros((self.slots,), bool)
            for s in pend:
                i = self._dpos[s]
                feed[s] = self.tokens[s][i] if i < len(self.tokens[s]) \
                    else drafts[s][i - len(self.tokens[s])]
                act[s] = True
            lg, dc.state = self._dstep(self.draft_params, dc.state,
                                       jnp.asarray(feed), jnp.asarray(act))
            nxt = np.asarray(jnp.argmax(lg, axis=-1))
            for s in list(pend):
                keep = self._dpos[s] >= len(self.tokens[s]) - 1
                self._dpos[s] += 1
                if keep:
                    drafts[s].append(int(nxt[s]))
                    if len(drafts[s]) >= need[s]:
                        pend.discard(s)
        dc._maybe_check()
        return drafts

    def _note_tokens(self, slot: int, t_now: float, n: int) -> None:
        """Record latency samples for ``n`` tokens appended to ``slot``:
        TTFT on the first decoded token, per-token gaps after (a K-token
        speculative commit records gap/K per token)."""
        req = self._slot_req[slot]
        if req is None:
            return
        rid = req.rid
        last = self._last_tok_t.get(rid)
        if last is None:
            t0 = self._submit_t.get(rid)
            if t0 is not None:
                self._ttft.append(max(t_now - t0, 0.0))
        else:
            self._itl.append(max(t_now - last, 0.0) / n)
        self._last_tok_t[rid] = t_now

    # -- lifecycle pump ------------------------------------------------------
    def tick(self) -> list[Request]:
        """One engine iteration: expire stale queued work, pump
        admission (preempting a lower-priority victim under page
        pressure when ``preemption`` is on), advance each mid-prefill
        slot by ``chunk_pages`` chunks, step the active set, retire
        finished / expired requests.  Returns requests that went
        TERMINAL this tick."""
        now = self.clock()
        done: list[Request] = list(self.queue.expire(now))
        # admission pump: highest priority first; under pressure, evict
        # strictly-lower-priority victims (equal priority never preempts
        # equal priority — no livelock)
        while True:
            req = self.queue.pop()
            if req is None:
                break
            try:
                self._admit_into(req)
                continue
            except AdmissionError:
                if self.preemption:
                    victim = self._victim(below_priority=req.priority)
                    if victim is not None:
                        self.preempt(victim)
                        try:
                            self._admit_into(req)
                            continue
                        except AdmissionError:
                            pass       # still starved: requeue, stop
                self.queue.push(req, force=True)   # retry next tick
                break
        # chunked-prefill pump: each mid-prefill slot advances by the
        # per-tick chunk budget, interleaved with the decode step below
        # — a long prompt streams in while the active set keeps
        # generating.  A slot the pool cannot back even after trie
        # eviction is preempted (PREFILLING -> PREEMPTED) and resumes
        # when pages free up, rather than silently starving.
        for s in list(self._prefilling):
            if not self.active[s]:
                continue
            if not self._advance_prefill(s, self.chunk_pages):
                if self.preemption:
                    self.preempt(s)
                else:
                    self.fail_slot(s, "page pool exhausted mid-prefill")
        # in-step page-pressure guard: if this step's page-boundary
        # crossers outnumber the free stack, the device allocator would
        # degrade locally (starved appends drop).  Evict trie orphans
        # first (they free pages without killing work), then preempt
        # victims to keep every surviving slot's stream intact.
        if self.preemption and any(self.active):
            ps = self.cache.page_size
            n_seq = self.cache.pages_per_seq

            def _step_new_pages(s: int) -> int:
                # pages this step may allocate for slot s: a plain slot
                # crosses at most one boundary, a speculative slot may
                # append up to _spec_k tokens before rollback
                p = self._pos[s]
                first = -(-p // ps)
                last = min((p + self._spec_k[s] - 1) // ps, n_seq - 1)
                return max(0, last - first + 1)

            crossers = {s: _step_new_pages(s) for s in range(self.slots)
                        if self.active[s] and s not in self._prefilling
                        and _step_new_pages(s) > 0}
            short = sum(crossers.values()) - self.cache.free_pages()
            if short > 0:
                self._evict_prefix(short)
            for _ in range(self.slots):
                live = {s: n for s, n in crossers.items()
                        if self.active[s]}
                if sum(live.values()) <= self.cache.free_pages():
                    break
                victim = self._victim()
                if victim is None or (victim in live and len(live) == 1):
                    break              # nothing to gain: degrade locally
                self.preempt(victim)
        if any(self.active[s] and s not in self._prefilling
               for s in range(self.slots)):
            self.step()
        # retire: generation budget reached, or running past deadline
        for s in range(self.slots):
            req = self._slot_req[s]
            if req is None or not self.active[s]:
                continue
            caught_up = self._fed[s] >= len(self.tokens[s]) - 1
            if req.max_new_tokens is not None and caught_up and \
                    len(self.tokens[s]) - len(req.prompt) >= \
                    req.max_new_tokens:
                req.tokens = list(self.tokens[s])
                req.to(RequestState.FINISHED)
                self._release_slot(s)
                done.append(req)
            elif req.expired(self.clock()):
                req.tokens = list(self.tokens[s])
                req.to(RequestState.TIMED_OUT,
                       error="deadline expired while running")
                self._release_slot(s)
                done.append(req)
        # requests failed mid-step (NaN guard, chaos slot death)
        done.extend(self._newly_terminal)
        self._newly_terminal.clear()
        return done

    def drained(self) -> bool:
        """True when nothing is queued or running."""
        return not any(self.active) and len(self.queue) == 0

    # -- fleet surface (serve/fleet.py) -------------------------------------
    def load(self) -> int:
        """Admission-routing load signal: queued + occupying a slot."""
        return len(self.queue) + sum(self.active)

    def resident_rids(self) -> set[int]:
        """rids RESIDENT on this replica right now: waiting in the
        admission queue or occupying a slot.  The fleet audit asserts
        every live request is resident on EXACTLY one replica."""
        out = {r.rid for r in self.queue._q}
        out |= {r.rid for r in self._slot_req if r is not None}
        return out

    def migrate_queued(self) -> list[Request]:
        """Lift every QUEUED request off this replica (graceful drain of
        a DEGRADED replica: stop admitting, let running finish, move the
        waiting work elsewhere).  Each comes back MIGRATING, carrying
        whatever tokens it had accumulated before a prior preemption."""
        out = self.queue.drain()
        for r in out:
            r.to(RequestState.MIGRATING)
            self.requests.pop(r.rid, None)
        return out

    def adopt(self, req: Request) -> None:
        """Accept a MIGRATING request from another replica: force-queued
        (migration must never be dropped by the admission bound — that
        would turn failover into data loss) and admitted by the next
        tick through the ordinary preemption-resume path."""
        self.requests[req.rid] = req
        self.queue.push(req, force=True)

    def evacuate(self) -> list[Request]:
        """Lift EVERY resident request off this replica — the failover
        path when the replica is declared dead.  Running slots carry
        their accumulated tokens (prompt + generated) into MIGRATING;
        queued work follows.  HOST bookkeeping only: the device pool is
        never touched — a dead replica's pool is discarded wholesale at
        respawn, and resume on the target replica re-prefills the
        original prompt and replays generated tokens through the
        ordinary decode step (the PR 6 replay cursor), so no pool copy
        or KV serialization ever crosses replicas."""
        out: list[Request] = []
        for s in range(self.slots):
            req = self._slot_req[s]
            if req is not None and not req.terminal:
                req.tokens = list(self.tokens[s])
                req.to(RequestState.MIGRATING)   # mid-prefill slots too
                req.slot = None
                out.append(req)
                self.requests.pop(req.rid, None)
            self.active[s] = False
            self.tokens[s] = []
            self._fed[s] = 0
            self._pos[s] = 0
            self._spec_k[s] = 1
            self._dpos[s] = 0
            self._slot_req[s] = None
        self._prefilling.clear()   # cursors die with the replica's pool
        out.extend(self.migrate_queued())
        return out

    def stats(self) -> dict:
        from repro.serve.lifecycle import summarize
        out = summarize(list(self.requests.values()))
        out.update(queue_depth=len(self.queue),
                   queue_rejected=self.queue.rejected,
                   pages_in_use=self.cache.pages_in_use(),
                   free_pages=self.cache.free_pages(),
                   nan_failures=self.nan_failures,
                   invariant_checks=self.cache.invariant_checks,
                   step_ewma_s=self._step_ewma,
                   prefilling=len(self._prefilling),
                   prefill_chunks=self.prefill_chunks)
        if self.prefix is not None:
            out["prefix"] = self.prefix.stats()
            out["shared_pages"] = int(
                np.sum(self.cache.page_refcounts() > 1))
        if self.watchdog is not None:
            out["watchdog_breaches"] = self.watchdog.breaches
        out["latency"] = self.latency_stats()
        if self.speculate > 1:
            out["speculative"] = {
                "k": self.speculate,
                "verify_steps": self.spec_steps,
                "proposed": self.spec_proposed,
                "accepted": self.spec_accepted,
                "acceptance": (self.spec_accepted / self.spec_proposed
                               if self.spec_proposed else 0.0),
            }
        return out

    def latency_samples(self) -> dict[str, list[float]]:
        """Raw per-request latency samples (seconds) — the fleet router
        concatenates these across replicas before taking percentiles
        (percentiles of percentiles are not percentiles)."""
        return {"ttft": list(self._ttft), "itl": list(self._itl)}

    def latency_stats(self) -> dict[str, float]:
        """TTFT and inter-token latency p50/p99 over every token this
        scheduler has decoded (seconds, host clock)."""
        out: dict[str, float] = {}
        for name, xs in (("ttft", self._ttft), ("itl", self._itl)):
            if xs:
                out[f"{name}_p50_s"] = float(np.percentile(xs, 50))
                out[f"{name}_p99_s"] = float(np.percentile(xs, 99))
        return out

    # -- reclamation --------------------------------------------------------
    def finish(self, slot: int) -> list[int]:
        """Release the slot: pages back on the free stack, per-slot state
        cleared (position, page-table row, recurrent state, token list).
        Finishing an already-idle slot is explicit: returns ``[]`` —
        never the previous occupant's stale tokens."""
        if not self.active[slot]:
            return []
        toks = self.tokens[slot]
        req = self._slot_req[slot]
        if req is not None and not req.terminal:
            req.tokens = list(toks)
            req.to(RequestState.FINISHED)
        self._release_slot(slot)
        return toks
