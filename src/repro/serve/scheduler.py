"""Continuous-batching scheduler over the paged KV runtime.

Split out of the old monolithic ``serve/engine.BatchedServer`` (which
survives there as a thin compat wrapper): this module owns ADMISSION
(free-slot + free-page checks, multi-token prompt prefill through the
existing jit'd prefill), the PER-STEP ACTIVE SET (one jit'd
``paged_decode_step`` over all slots with an ``active`` mask — idle
slots append nothing and advance nothing), SAMPLING (greedy argmax by
default; temperature / top-k with seeded per-slot PRNG keys), and
RECLAMATION (``finish`` releases the slot's pages back to the device
free stack and clears its per-slot state, so a reused slot can never
attend to the previous occupant's cache).

Everything device-side is jit'd ONCE: per-step membership changes ride
in as array operands (token vector, active mask, page table), so steady
state pays zero retraces and zero plan-cache misses
(tests/test_serve.py asserts this).
"""
from __future__ import annotations

import functools
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import decode as dec
from repro.models.transformer import ModelConfig
from repro.serve.paged_cache import PagedCache


def sample_tokens(logits: jax.Array, keys, *, temperature: float = 0.0,
                  top_k: int | None = None) -> jax.Array:
    """Per-slot sampling.  logits: (B, V); keys: (B,) PRNG keys.

    ``temperature <= 0`` (the default) is greedy argmax; otherwise
    categorical over ``logits / temperature``, restricted to the top-k
    logits when ``top_k`` is set (``top_k=1`` degenerates to argmax).
    """
    lg = logits.astype(jnp.float32)
    if temperature <= 0.0:
        return jnp.argmax(lg, axis=-1).astype(jnp.int32)
    lg = lg / temperature
    if top_k is not None and top_k < lg.shape[-1]:
        kth = jnp.sort(lg, axis=-1)[:, -top_k][:, None]
        lg = jnp.where(lg >= kth, lg, -jnp.inf)
    return jax.vmap(jax.random.categorical)(keys, lg).astype(jnp.int32)


class Scheduler:
    """Fixed-slot continuous batching over a shared page pool.

    ``page_size`` / ``num_pages`` size the pool (``num_pages=None`` fully
    provisions ``slots * pages_per_seq``); ``temperature`` / ``top_k`` /
    ``seed`` configure sampling (greedy by default, deterministic);
    ``prefill_pad`` pads prompts before prefill to bound jit retraces
    (defaults to the page size, so prompt caches always land on whole
    pages — a requirement of the paged insert).
    """

    def __init__(self, cfg: ModelConfig, params, *, slots: int,
                 max_len: int, page_size: int | None = None,
                 num_pages: int | None = None, cache_dtype=jnp.float32,
                 fuse_step: bool = True, temperature: float = 0.0,
                 top_k: int | None = None, seed: int = 0):
        if cfg.encoder is not None:
            raise NotImplementedError("paged serving covers decoder-only "
                                      "models")
        from repro import vx
        self.cfg, self.params = cfg, params
        self.slots, self.max_len = slots, max_len
        page_size = min(page_size or 16, max_len)
        self.cache = PagedCache(cfg, slots, max_len, page_size,
                                cache_dtype=cache_dtype,
                                num_pages=num_pages)
        self.temperature, self.top_k = float(temperature), top_k
        vx.warm(2 * cfg.hd, strided=False, fields=(2,),
                policy=cfg.vx_policy)
        # cache donated: the pool is the big buffer and the step replaces
        # it wholesale — without donation every append pays a pool copy
        self._step = jax.jit(
            lambda p, c, t, a: dec.paged_decode_step(
                p, c, t, cfg, None, active=a, fuse=fuse_step),
            donate_argnums=1)
        self._sample = jax.jit(functools.partial(
            sample_tokens, temperature=self.temperature, top_k=top_k))
        self._split_keys = jax.jit(
            lambda ks: jnp.swapaxes(jax.vmap(
                lambda k: jax.random.split(k, 2))(ks), 0, 1))
        self._keys = jax.random.split(jax.random.key(seed), slots)
        from repro.dist.sharding import local_ctx
        from repro.serve.engine import jit_prefill
        self._prefill = jit_prefill(cfg, local_ctx(), None, None)
        self.active = [False] * slots
        self.tokens: list[list[int]] = [[] for _ in range(slots)]
        self.last_logits = None      # (slots, V) of the latest step

    # -- admission ----------------------------------------------------------
    def free_slot(self) -> int | None:
        for s in range(self.slots):
            if not self.active[s]:
                return s
        return None

    def add_request(self, prompt: int | Sequence[int]) -> int:
        """Admit a request.  ``prompt`` is a full token list (or a single
        int); all but the last token are prefilled into the slot's pages
        through the jit'd prefill, and the last token is fed to the next
        decode step (so ``tokens[slot]`` stays prompt + generated).
        Raises RuntimeError when no slot or not enough free pages."""
        toks = [int(prompt)] if isinstance(prompt, int) else \
            [int(t) for t in prompt]
        if not toks:
            raise ValueError("empty prompt")
        if len(toks) > self.max_len:
            raise ValueError(f"prompt of {len(toks)} tokens exceeds "
                             f"max_len={self.max_len}")
        slot = self.free_slot()
        if slot is None:
            raise RuntimeError("no free slot")
        # pages are allocated lazily (prefill now, decode appends later):
        # admit against RESERVED pages — what live requests will need for
        # their current tokens — not just the instantaneous free count
        reserved = sum(self.cache.pages_needed(len(self.tokens[s]))
                       for s in range(self.slots) if self.active[s])
        need = self.cache.pages_needed(max(len(toks) - 1, 1)) + 1
        if self.cache.num_pages - reserved < need:
            raise RuntimeError("page pool exhausted; finish a request or "
                               "grow num_pages")
        if len(toks) > 1:
            self._prefill_into(slot, toks[:-1])
        self.active[slot] = True
        self.tokens[slot] = list(toks)
        return slot

    def _prefill_into(self, slot: int, toks: list[int]) -> None:
        # The ONE jit'd prefill (engine.jit_prefill, mesh-less ctx).
        # Windowless attention-only stacks pad the prompt to a page
        # multiple so the prefill retraces at most pages_per_seq shapes
        # (the padded tail beats are masked by eff_len and overwritten in
        # place).  Anything else prefills at the TRUE length: a ring
        # window would be trimmed at the padded length (losing real
        # in-window beats) and recurrent state would absorb the pad
        # tokens irreversibly.
        cfg = self.cfg
        pad_safe = (all(k == "attn" for k in cfg.block_pattern)
                    and all(w is None for w in cfg.window_pattern))
        if pad_safe:
            ps = self.cache.page_size
            state_len = -(-len(toks) // ps) * ps
        else:
            state_len = len(toks)
        tokens = jnp.asarray(toks + [0] * (state_len - len(toks)),
                             jnp.int32)[None]
        _, states = self._prefill(self.params, {"tokens": tokens})
        self.cache.insert_prefill(slot, states, len(toks),
                                  state_len=state_len)

    # -- decode -------------------------------------------------------------
    def step(self) -> list[int]:
        """Advance every ACTIVE slot one token; idle slots report -1."""
        cur = jnp.asarray([self.tokens[s][-1] if self.active[s] else 0
                           for s in range(self.slots)], jnp.int32)
        act = jnp.asarray(self.active)
        logits, self.cache.state = self._step(self.params,
                                              self.cache.state, cur, act)
        self.last_logits = logits
        if self.temperature > 0.0:
            self._keys, sub = self._split_keys(self._keys)
            nxt = self._sample(logits, sub)
        else:
            nxt = self._sample(logits, self._keys)
        nxt = np.asarray(nxt)          # ONE host sync for all slots
        out = []
        for s in range(self.slots):
            t = int(nxt[s])
            if self.active[s]:
                self.tokens[s].append(t)
                out.append(t)
            else:
                out.append(-1)
        return out

    # -- reclamation --------------------------------------------------------
    def finish(self, slot: int) -> list[int]:
        """Release the slot: pages back on the free stack, per-slot state
        cleared (position, page-table row, recurrent state)."""
        toks = self.tokens[slot]
        if self.active[slot]:
            self.cache.release(slot)
            self.active[slot] = False
        return toks
