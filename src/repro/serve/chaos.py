"""Deterministic chaos harness over the paged serve runtime.

A :class:`FaultPlan` is drawn ONCE from a seed — every fault (what,
when, to whom) is decided before the run starts, so a failing chaos
test replays bit-for-bit from its seed.  :func:`run_plan` drives a
:class:`~repro.serve.scheduler.Scheduler` through a seeded workload
while injecting the plan's faults, auditing the page pool's structural
invariants (``PagedCache.check_invariants``) after EVERY tick — always
on under chaos, whatever the scheduler's debug flag — and asserting
the lifecycle contract: every submitted request reaches a terminal
typed state (FINISHED / TIMED_OUT / FAILED), and no request is ever
lost or stuck.

Fault vocabulary (all host-side — the jit'd step is never retraced):

  * ``preempt``   — force-evict a running slot (preemption-and-restore:
                    the request requeues with its accumulated tokens and
                    resumes bit-exactly);
  * ``nan``       — taint one slot's logits with NaN for one step (the
                    guard must fail ONLY that slot);
  * ``kill``      — slot death mid-decode (``fail_slot``: pages
                    reclaimed, request -> FAILED, neighbours unharmed);
  * ``spike``     — pool-pressure spike: a burst of high-priority
                    requests slams the admission queue, forcing
                    preemption of lower-priority work;
  * ``bad_prompt``— malformed traffic (empty / oversized prompts) that
                    must come back typed-FAILED, never crash the engine;
  * ``evict``     — force prefix-trie eviction (PR 8, ``p_evict``; no-op
                    without the trie): pages leave the radix cache while
                    slots may still share them — refcount conservation
                    is audited the same tick.

With chunked prefill (PR 8) the ``preempt`` and ``kill`` draws land on
mid-PREFILL slots too, exercising the ``PREFILLING -> PREEMPTED`` edge
and chunk-resume under the same audit.

The plan also mixes oversized-vs-pool prompts and zero-TTL requests so
deadline and backpressure paths run under the same audit.

FLEET chaos (PR 7) lifts the same discipline to the replica fleet
(serve/fleet.py): a :class:`FleetFaultPlan` adds replica-scoped faults —

  * ``kill``      — replica death mid-decode (every resident request
                    migrates through MIGRATING and resumes elsewhere;
                    the replica respawns with an empty pool);
  * ``hang``      — a replica stalls for N ticks: past the heartbeat
                    bound it is declared DEAD mid-hang, shorter hangs
                    wake up and the watchdog books the stall as one
                    giant hard-limit-breaching step (DEGRADED drain);
  * ``storm``     — an admission storm PINNED to one replica (priority
                    burst via ``submit(replica=...)``), forcing local
                    backpressure while the rest of the fleet is idle;

plus the per-replica faults above (preempt, nan, bad_prompt), and
:func:`run_fleet_plan` audits the FLEET contract every tick
(:meth:`FleetRouter.audit`): no request lost or double-resident across
replicas, nothing stuck MIGRATING, per-replica pool invariants intact.
Determinism holds fleet-wide: faults are materialized from the seed up
front and the router is driven on an injected :class:`StepClock`, so a
failing fleet run replays bit-for-bit.
"""
from __future__ import annotations

import dataclasses
from collections import Counter

import numpy as np

from repro.serve.fleet import FleetRouter, ReplicaState
from repro.serve.lifecycle import (AdmissionError, Request, RequestState,
                                   TERMINAL_STATES)
from repro.serve.scheduler import Scheduler


class StepClock:
    """Deterministic clock: each call advances a fixed quantum, so
    deadline / heartbeat / watchdog logic runs without wall time and a
    chaos run replays exactly.  The default quantum is LARGE (10s) so a
    hang observed through it dwarfs any real hard limit while real step
    wall-times stay far below it."""

    def __init__(self, dt: float = 10.0):
        self.t, self.dt = 0.0, dt

    def __call__(self) -> float:
        self.t += self.dt
        return self.t


@dataclasses.dataclass(frozen=True)
class ChaosConfig:
    seed: int = 0
    steps: int = 64              # fault-injection horizon (ticks)
    max_ticks: int = 512         # hard cap: the run must DRAIN before it
    requests: int = 8            # background workload size
    max_prompt: int = 6
    max_new_tokens: int = 8
    p_preempt: float = 0.15
    p_nan: float = 0.08
    p_kill: float = 0.05
    p_spike: float = 0.08
    p_bad_prompt: float = 0.08
    # appended AFTER the original fields so a 0.0 default preserves the
    # seeded draw sequence of pre-PR 8 plans bit-for-bit
    p_evict: float = 0.0         # force prefix-trie eviction (PR 8)


@dataclasses.dataclass(frozen=True)
class Fault:
    tick: int
    kind: str                    # preempt | nan | kill | spike | bad_prompt
    arg: int = 0                 # slot draw / burst size / prompt variant
    arg2: int = 0                # fleet: hang duration / storm burst size


class FaultPlan:
    """The full fault schedule, materialized from a seed up front."""

    def __init__(self, cfg: ChaosConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        self.faults: list[Fault] = []
        for t in range(cfg.steps):
            r = rng.random()
            if r < cfg.p_preempt:
                self.faults.append(Fault(t, "preempt",
                                         int(rng.integers(0, 1 << 16))))
            elif r < cfg.p_preempt + cfg.p_nan:
                self.faults.append(Fault(t, "nan",
                                         int(rng.integers(0, 1 << 16))))
            elif r < cfg.p_preempt + cfg.p_nan + cfg.p_kill:
                self.faults.append(Fault(t, "kill",
                                         int(rng.integers(0, 1 << 16))))
            elif r < cfg.p_preempt + cfg.p_nan + cfg.p_kill + cfg.p_spike:
                self.faults.append(Fault(t, "spike",
                                         int(rng.integers(1, 3))))
            elif r < (cfg.p_preempt + cfg.p_nan + cfg.p_kill
                      + cfg.p_spike + cfg.p_bad_prompt):
                self.faults.append(Fault(t, "bad_prompt",
                                         int(rng.integers(0, 2))))
            elif r < (cfg.p_preempt + cfg.p_nan + cfg.p_kill
                      + cfg.p_spike + cfg.p_bad_prompt + cfg.p_evict):
                self.faults.append(Fault(t, "evict",
                                         int(rng.integers(1, 4))))
        # background workload: (arrival tick, prompt, gen budget)
        self.workload: list[tuple[int, list[int], int]] = []
        for i in range(cfg.requests):
            plen = int(rng.integers(1, cfg.max_prompt + 1))
            prompt = rng.integers(0, 97, plen).tolist()
            gen = int(rng.integers(1, cfg.max_new_tokens + 1))
            arrive = int(rng.integers(0, max(cfg.steps // 2, 1)))
            self.workload.append((arrive, [int(t) for t in prompt], gen))
        self.workload.sort(key=lambda w: w[0])

    def at(self, tick: int) -> list[Fault]:
        return [f for f in self.faults if f.tick == tick]


@dataclasses.dataclass
class ChaosReport:
    submitted: list[Request]
    ticks: int
    states: dict[str, int]
    preemptions: int
    nan_failures: int
    invariant_checks: int
    backpressured: int

    @property
    def all_terminal(self) -> bool:
        return all(r.state in TERMINAL_STATES for r in self.submitted)


def _running_slots(sched: Scheduler) -> list[int]:
    return [s for s in range(sched.slots)
            if sched.active[s] and sched._slot_req[s] is not None]


def run_plan(sched: Scheduler, plan: FaultPlan) -> ChaosReport:
    """Drive the scheduler through the plan's workload + faults until it
    drains (or the tick cap trips — which the caller should treat as a
    liveness failure).  Invariants are audited EVERY tick regardless of
    the scheduler's ``debug_invariants`` flag."""
    cfg = plan.cfg
    submitted: list[Request] = []
    pending = list(plan.workload)
    backpressured = 0
    tick = 0
    while tick < cfg.max_ticks:
        # scheduled arrivals (backpressure requeues for the next tick —
        # the client-side retry loop, without wall-clock sleeps)
        while pending and pending[0][0] <= tick:
            arrive, prompt, gen = pending[0]
            try:
                submitted.append(
                    sched.submit(prompt, max_new_tokens=gen))
                pending.pop(0)
            except AdmissionError:
                backpressured += 1
                pending[0] = (tick + 1, prompt, gen)
                break
        for fault in plan.at(tick):
            running = _running_slots(sched)
            if fault.kind == "preempt" and running:
                sched.preempt(running[fault.arg % len(running)])
            elif fault.kind == "nan" and running:
                taint = np.zeros(sched.slots, bool)
                taint[running[fault.arg % len(running)]] = True
                sched._taint = taint
            elif fault.kind == "kill" and running:
                sched.fail_slot(running[fault.arg % len(running)],
                                "chaos: slot death mid-decode")
            elif fault.kind == "spike":
                for b in range(fault.arg):
                    try:
                        submitted.append(sched.submit(
                            [1 + b, 2, 3], max_new_tokens=2,
                            priority=10))
                    except AdmissionError:
                        backpressured += 1
            elif fault.kind == "bad_prompt":
                bad = [] if fault.arg == 0 else \
                    [0] * (sched.max_len + 1)
                submitted.append(sched.submit(bad, max_new_tokens=2))
            elif fault.kind == "evict":
                # force prefix-trie eviction (no-op without the trie):
                # refcount conservation must survive pages leaving the
                # trie while slots still share them
                sched._evict_prefix(fault.arg)
        sched.tick()
        sched.cache.check_invariants()      # ALWAYS on under chaos
        tick += 1
        if not pending and tick > cfg.steps and sched.drained():
            break
    return ChaosReport(
        submitted=submitted, ticks=tick,
        states=dict(Counter(r.state.value for r in submitted)),
        preemptions=sched.preemptions,
        nan_failures=sched.nan_failures,
        invariant_checks=sched.cache.invariant_checks,
        backpressured=backpressured)


# ---------------------------------------------------------------------------
# Fleet-level chaos (serve/fleet.py)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class FleetChaosConfig:
    seed: int = 0
    replicas: int = 3
    steps: int = 48              # fault-injection horizon (ticks)
    max_ticks: int = 768         # hard cap: the fleet must DRAIN before it
    requests: int = 10           # background workload size
    max_prompt: int = 6
    max_new_tokens: int = 8
    p_kill: float = 0.06
    p_hang: float = 0.05
    p_storm: float = 0.08
    p_preempt: float = 0.08
    p_nan: float = 0.05
    p_bad_prompt: float = 0.05
    max_hang: int = 6            # hang duration draw (ticks, >= 1)


class FleetFaultPlan:
    """The full fleet fault schedule, materialized from a seed up
    front — replica-scoped faults (kill / hang / storm) on top of the
    per-slot vocabulary (preempt / nan / bad_prompt)."""

    def __init__(self, cfg: FleetChaosConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        kinds = (("kill", cfg.p_kill), ("hang", cfg.p_hang),
                 ("storm", cfg.p_storm), ("preempt", cfg.p_preempt),
                 ("nan", cfg.p_nan), ("bad_prompt", cfg.p_bad_prompt))
        self.faults: list[Fault] = []
        for t in range(cfg.steps):
            r = rng.random()
            acc = 0.0
            for kind, p in kinds:
                acc += p
                if r < acc:
                    self.faults.append(Fault(
                        t, kind, int(rng.integers(0, 1 << 16)),
                        int(rng.integers(1, max(cfg.max_hang, 1) + 1))))
                    break
        self.workload: list[tuple[int, list[int], int]] = []
        for _ in range(cfg.requests):
            plen = int(rng.integers(1, cfg.max_prompt + 1))
            prompt = rng.integers(0, 97, plen).tolist()
            gen = int(rng.integers(1, cfg.max_new_tokens + 1))
            arrive = int(rng.integers(0, max(cfg.steps // 2, 1)))
            self.workload.append((arrive, [int(t) for t in prompt], gen))
        self.workload.sort(key=lambda w: w[0])

    def at(self, tick: int) -> list[Fault]:
        return [f for f in self.faults if f.tick == tick]


@dataclasses.dataclass
class FleetChaosReport:
    submitted: list[Request]
    ticks: int
    states: dict[str, int]
    deaths: int
    respawns: int
    migrated: int
    drains: int
    backpressured: int
    audits: int

    @property
    def all_terminal(self) -> bool:
        return all(r.state in TERMINAL_STATES for r in self.submitted)

    @property
    def recovered(self) -> int:
        """Requests that survived at least one migration to FINISH."""
        return sum(1 for r in self.submitted
                   if r.migrations > 0
                   and r.state is RequestState.FINISHED)


def run_fleet_plan(router: FleetRouter,
                   plan: FleetFaultPlan) -> FleetChaosReport:
    """Drive the fleet through the plan's workload + faults until it
    drains (or the tick cap trips — a liveness failure for the caller to
    assert on).  :meth:`FleetRouter.audit` — residency, MIGRATING
    completion, per-replica pool invariants — runs after EVERY tick."""
    cfg = plan.cfg
    submitted: list[Request] = []
    pending = list(plan.workload)
    backpressured = 0
    audits = 0
    tick = 0
    while tick < cfg.max_ticks:
        while pending and pending[0][0] <= tick:
            arrive, prompt, gen = pending[0]
            try:
                submitted.append(
                    router.submit(prompt, max_new_tokens=gen))
                pending.pop(0)
            except AdmissionError:
                backpressured += 1
                pending[0] = (tick + 1, prompt, gen)
                break
        for fault in plan.at(tick):
            live = [r for r in router.replicas if r.alive]
            healthy = [r for r in router.replicas
                       if r.state is ReplicaState.HEALTHY]
            if fault.kind == "kill" and live:
                router.kill_replica(live[fault.arg % len(live)].idx,
                                    reason="chaos kill")
            elif fault.kind == "hang" and live:
                router.hang_replica(live[fault.arg % len(live)].idx,
                                    fault.arg2)
            elif fault.kind == "storm" and healthy:
                target = healthy[fault.arg % len(healthy)].idx
                for b in range(1 + fault.arg2 % 3):
                    try:
                        submitted.append(router.submit(
                            [1 + b, 2, 3], max_new_tokens=2,
                            priority=10, replica=target))
                    except AdmissionError:
                        backpressured += 1
            elif fault.kind == "preempt" and live:
                rep = live[fault.arg % len(live)]
                running = _running_slots(rep.sched)
                if running:
                    rep.sched.preempt(running[fault.arg2 % len(running)])
            elif fault.kind == "nan" and live:
                rep = live[fault.arg % len(live)]
                running = _running_slots(rep.sched)
                if running:
                    taint = np.zeros(rep.sched.slots, bool)
                    taint[running[fault.arg2 % len(running)]] = True
                    rep.sched._taint = taint
            elif fault.kind == "bad_prompt":
                bad = [] if fault.arg % 2 == 0 else \
                    [0] * (router.max_len + 1)
                try:
                    submitted.append(router.submit(bad, max_new_tokens=2))
                except AdmissionError:   # whole fleet dead/backpressured
                    backpressured += 1
        router.tick()
        router.audit()                     # ALWAYS on under fleet chaos
        audits += 1
        tick += 1
        if not pending and tick > cfg.steps and router.drained():
            break
    return FleetChaosReport(
        submitted=submitted, ticks=tick,
        states=dict(Counter(r.state.value for r in submitted)),
        deaths=router.deaths, respawns=router.respawns,
        migrated=router.migrated, drains=router.drains,
        backpressured=backpressured, audits=audits)
