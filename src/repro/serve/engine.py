"""Serving engine: jit'd prefill / decode steps with cache shardings,
plus a simple continuous-batching session manager.

Cache sharding policy (see DESIGN.md §6):
  * decode_32k (B=128): batch over data axes, KV heads over model when
    divisible, else sequence over model.
  * long_500k (B=1): batch cannot shard — the KV sequence axis is sharded
    over (data, model) (sequence parallelism). Distributed softmax over the
    sharded axis is handled by XLA SPMD (max/sum all-reduces); the
    shard_map log-sum-exp merge is the §Perf optimization.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.dist.sharding import ShardCtx, tree_param_specs
from repro.models import decode as dec
from repro.models import encdec
from repro.models.transformer import ModelConfig, forward


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    max_len: int
    cache_dtype: str = "bfloat16"
    long_context: bool = False     # sequence-parallel KV sharding
    # Whole-step access fusion (core/accessfuse.py): one fused KV split
    # per decode step.  Costs one transient cache-sized pre-split copy
    # (k_pre/v_pre live across the step, ~+1x KV memory at peak); set
    # False when the cache is the memory ceiling.  Applies to long_context
    # too (PR 4): seq-parallel caches split SHARD-LOCALLY through the vx
    # sharding-aware lowering instead of being sliced globally.
    step_fusion: bool = True


def cache_specs(cfg: ModelConfig, ctx: ShardCtx, scfg: ServeConfig,
                cache_template: Any):
    """PartitionSpec pytree for the cache."""
    if ctx.mesh is None:
        return None

    batch_axes = ctx.data_axes if ctx.data_axes else None

    def _seq_divisible(s: int, axes) -> bool:
        n = 1
        for a in (axes if isinstance(axes, tuple) else (axes,)):
            n *= ctx.mesh.shape[a]
        return s % n == 0

    def spec_for(path: str, leaf) -> P:
        if path.endswith("len"):
            return P()
        if scfg.long_context and leaf.ndim == 5:
            # (NS, B, S, K, 2D): B=1 — shard the sequence over every axis
            axes = ctx.seq_axes or (ctx.data_axes
                                    + ((ctx.model_axis,)
                                       if ctx.model_axis else ()))
            if axes and _seq_divisible(leaf.shape[2], axes):
                return P(None, None, axes)
            return P()
        if leaf.ndim == 5:  # (NS, B, S, K, 2D)
            k, s = leaf.shape[3], leaf.shape[2]
            if ctx.model_axis and k % ctx.model_size == 0:
                return P(None, batch_axes, None, ctx.model_axis)
            if ctx.model_axis and s % ctx.model_size == 0:
                return P(None, batch_axes, ctx.model_axis)  # seq-sharded
            return P(None, batch_axes)
        return _state_spec(leaf)

    def _state_spec(leaf) -> P:
        # (NS, B, inner...) recurrent state: batch over data, biggest inner
        # dim over model if divisible
        parts = [None, batch_axes] + [None] * (leaf.ndim - 2)
        if ctx.model_axis:
            for i in range(2, leaf.ndim):
                if leaf.shape[i] % ctx.model_size == 0 and \
                        leaf.shape[i] >= ctx.model_size:
                    parts[i] = ctx.model_axis
                    break
        return P(*parts)

    flat = jax.tree_util.tree_flatten_with_path(cache_template)[0]
    specs = []
    for kp, leaf in flat:
        path = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                        for k in kp)
        specs.append(spec_for(path, leaf))
    treedef = jax.tree_util.tree_structure(cache_template)
    return jax.tree_util.tree_unflatten(treedef, specs)


def serve_param_shardings(params_template, cfg: ModelConfig, ctx: ShardCtx):
    if ctx.mesh is None:
        return None
    specs = tree_param_specs(params_template, ctx)
    return jax.tree.map(lambda s: ctx.sharding(s), specs,
                        is_leaf=lambda x: isinstance(x, P))


def jit_decode_step(cfg: ModelConfig, ctx: ShardCtx, scfg: ServeConfig,
                    params_template, cache_template, *, param_ctx=None):
    """serve_step(params, cache, token) -> (logits, cache), fully sharded.

    ``param_ctx``: optional separate ShardCtx for WEIGHT placement — huge
    models (Jamba-398B) shard weights 2D over (data x model) even though
    the serving batch only uses the model axis (weights are gathered
    layer-by-layer under the superblock scan)."""
    from repro import vx
    # one-time host compile of the FIELD=2 segment plans the fused KV
    # split consults (decode takes no runtime-stride path: skip those).
    # Resolved through the model's policy so prewarming compiles exactly
    # the plans the serve path will hit (nothing under impl="ref").
    vx.warm(2 * cfg.hd, strided=False, fields=(2,), policy=cfg.vx_policy)

    if cfg.encoder is not None:
        def serve_step(params, cache, token):
            return encdec.decode_step(params, cache, token, cfg, ctx)
    else:
        # Step fusion holds for long_500k too (PR 4): the seq-sharded
        # cache leaves are annotated with their placement and the fused
        # FIELD=2 split lowers shard-locally under shard_map (offset
        # space is untouched — the lane permutation is elementwise over
        # the sequence), so SPMD never rematerializes the pre-split
        # leaves the way the old global slice did.
        fuse = scfg.step_fusion
        # axis=-3: the sequence dim of the (NS, B, Sc, K, 2D) leaves,
        # counted from the end (stack-stable)
        kv_shard = ctx.vx_seq_shard(-3) if scfg.long_context else None

        def serve_step(params, cache, token):
            # one fused append/split for all layers per decode step
            return dec.decode_step(params, cache, token, cfg, ctx,
                                   fuse=fuse, kv_shard=kv_shard)

    if ctx.mesh is None:
        return jax.jit(serve_step, donate_argnums=1)
    psh = serve_param_shardings(params_template, cfg, param_ctx or ctx)
    cspecs = cache_specs(cfg, ctx, scfg, cache_template)
    csh = jax.tree.map(lambda s: ctx.sharding(s), cspecs,
                       is_leaf=lambda x: isinstance(x, P))
    tsh = ctx.sharding(ctx.batch_spec())
    osh = (ctx.sharding(ctx.batch_spec(ctx.model_if_divisible(cfg.vocab))),
           csh)
    return jax.jit(serve_step, in_shardings=(psh, csh, tsh),
                   out_shardings=osh, donate_argnums=1)


def jit_prefill(cfg: ModelConfig, ctx: ShardCtx, params_template,
                batch_template, *, param_ctx=None):
    def prefill(params, batch):
        logits, _, cache_states = forward(params, batch, cfg, ctx,
                                          mode="prefill")
        return logits, cache_states

    if ctx.mesh is None:
        return jax.jit(prefill)
    psh = serve_param_shardings(params_template, cfg, param_ctx or ctx)
    bsh = jax.tree.map(
        lambda x: ctx.sharding(ctx.batch_spec(*([None] * (x.ndim - 1)))),
        batch_template)
    return jax.jit(prefill, in_shardings=(psh, bsh))


# ---------------------------------------------------------------------------
# Compat wrapper: the old fixed-slot dense server API over the paged
# runtime (serve/scheduler.py + serve/paged_cache.py).
# ---------------------------------------------------------------------------

class BatchedServer:
    """Fixed-slot continuous batching — thin wrapper over
    :class:`repro.serve.scheduler.Scheduler`.

    Since PR 5 the backing runtime is the PAGED cache: per-slot position
    vectors, a shared page pool per layer, device free-list reclamation on
    ``finish`` (a reused slot can never attend to the previous occupant's
    cache — the old dense server left stale KV and a shared position
    counter behind), multi-token prompts through the jit'd prefill, and
    optional temperature / top-k sampling.  The old single-token
    ``add_request(int)`` / ``step()`` / ``finish()`` surface is unchanged;
    ``cache`` is presented in the legacy ``{"len", "blocks"}`` shape with
    ``len`` = the furthest active position.
    """

    def __init__(self, cfg: ModelConfig, params, *, slots: int, max_len: int,
                 ctx: ShardCtx | None = None, cache_dtype=jnp.float32,
                 fuse_step: bool = True, page_size: int | None = None,
                 num_pages: int | None = None, temperature: float = 0.0,
                 top_k: int | None = None, seed: int = 0, **lifecycle_kw):
        from repro.serve.scheduler import Scheduler
        self.cfg, self.params = cfg, params
        self.slots, self.max_len, self.ctx = slots, max_len, ctx
        # lifecycle_kw passes the hardened-runtime knobs through
        # unchanged (queue_depth / preemption / guard_nan / watchdog /
        # debug_invariants / clock, the PR 8 prefix_cache / chunk_pages
        # prefill knobs, and the PR 9 kv_quant quantized-pool selector —
        # see serve/scheduler.py)
        self.scheduler = Scheduler(
            cfg, params, slots=slots, max_len=max_len, page_size=page_size,
            num_pages=num_pages, cache_dtype=cache_dtype,
            fuse_step=fuse_step, temperature=temperature, top_k=top_k,
            seed=seed, **lifecycle_kw)

    @property
    def active(self) -> list:
        return self.scheduler.active

    @property
    def tokens(self) -> list:
        return self.scheduler.tokens

    @property
    def cache(self) -> dict:
        st = self.scheduler.cache.state
        return {"len": jnp.max(st["pos"]), "blocks": st["blocks"]}

    def add_request(self, prompt_token=None, *, prompt=None) -> int:
        """Admit a request: a single first token (legacy form) or a full
        prompt list (prefilled through ``jit_prefill``)."""
        req = prompt if prompt is not None else prompt_token
        if req is None:
            raise ValueError("pass a prompt token or prompt= list")
        return self.scheduler.add_request(req)

    def step(self) -> list[int]:
        """Advance every active slot one token."""
        return self.scheduler.step()

    def submit(self, prompt, **kw):
        """Queue a typed request (lifecycle surface — see Scheduler)."""
        return self.scheduler.submit(prompt, **kw)

    def tick(self):
        """One lifecycle iteration: admit / step / retire."""
        return self.scheduler.tick()

    def finish(self, slot: int) -> list[int]:
        """Release the slot (pages reclaimed, per-slot state cleared)."""
        return self.scheduler.finish(slot)


def make_fleet(cfg: ModelConfig, params, *, replicas: int, slots: int,
               max_len: int, **fleet_kw):
    """N scheduler replicas behind the health-checked fleet router —
    the multi-replica counterpart of :class:`BatchedServer`.  Each
    replica is the full PR 6 hardened runtime (own page pool, own
    admission queue) over ONE shared params pytree; the router does
    least-loaded admission, heartbeat/watchdog health tracking, and
    replay-based failover (serve/fleet.py, DESIGN.md §8.2).  A
    ``replicas=1`` fleet is exactly one ``BatchedServer.scheduler``
    behind a router."""
    from repro.serve.fleet import FleetRouter
    return FleetRouter(cfg, params, replicas=replicas, slots=slots,
                       max_len=max_len, **fleet_kw)
