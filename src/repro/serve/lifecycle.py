"""Request lifecycle for the hardened serve runtime.

The paged scheduler (serve/scheduler.py) owns device state: slots, the
page pool, the jit'd step.  This module owns everything a request goes
through AROUND that device state:

  * :class:`Request` — a typed request record with a validated state
    machine::

        QUEUED -> PREFILLING -> RUNNING -> FINISHED
                                        -> TIMED_OUT
                                        -> FAILED
                                        -> PREEMPTED -> QUEUED (again)
                                        -> MIGRATING -> QUEUED (elsewhere)

    plus the admission-time edges QUEUED -> {FAILED, TIMED_OUT,
    MIGRATING} for rejected / expired / relocated requests, and the
    PR 8 mid-prefill edges PREFILLING -> {PREEMPTED, MIGRATING,
    TIMED_OUT}: chunked prefill interleaves with decode ticks, so a
    request can be preempted, migrated, or expire BETWEEN chunks —
    it no longer has to fail or hold pages to completion.  Illegal
    transitions raise — the chaos harness (serve/chaos.py) relies on
    this: "every admitted request terminates in a typed state" is only
    meaningful if states cannot be corrupted silently.

    MIGRATING (PR 7) is the fleet failover edge: when a replica dies,
    the router lifts every resident request off it — running slots AND
    queued work — through MIGRATING and re-queues them on a surviving
    replica.  Resume there is the ordinary preemption-and-restore path
    (re-prefill the ORIGINAL prompt, replay generated tokens through
    the jit'd decode step), so a migrated request's post-catch-up
    stream is bit-exact vs an uninterrupted run on pad-safe stacks —
    migration IS preemption pointed at a different page pool.

  * :class:`AdmissionQueue` — a BOUNDED priority queue.  A full queue is
    backpressure, not a crash: ``push`` raises :class:`AdmissionError`
    carrying a ``retry_after`` hint instead of the bare ``RuntimeError``
    the PR 5 scheduler raised on pool exhaustion.  Pop order is priority
    (higher first), then arrival order; a preempted request keeps its
    original arrival sequence so it resumes ahead of later arrivals of
    equal priority.

  * :func:`retry_with_backoff` — client-side exponential backoff with
    deterministic (seeded) jitter, honouring the server's ``retry_after``
    floor.  The clock and sleep are injectable so the policy is
    unit-testable without wall time.

Everything here is pure host Python — no jax, no device work — so the
steady-state decode fast path is untouched by construction.
"""
from __future__ import annotations

import dataclasses
import enum
import itertools
import random
import time
from collections import deque
from typing import Callable, Sequence


class RequestState(enum.Enum):
    QUEUED = "queued"
    PREFILLING = "prefilling"
    RUNNING = "running"
    PREEMPTED = "preempted"
    MIGRATING = "migrating"
    FINISHED = "finished"
    TIMED_OUT = "timed_out"
    FAILED = "failed"


TERMINAL_STATES = frozenset({RequestState.FINISHED, RequestState.TIMED_OUT,
                             RequestState.FAILED})

# the full legal edge set; Request.to() enforces it
_TRANSITIONS: dict[RequestState, frozenset[RequestState]] = {
    RequestState.QUEUED: frozenset({RequestState.PREFILLING,
                                    RequestState.FAILED,
                                    RequestState.TIMED_OUT,
                                    RequestState.MIGRATING}),
    # PREFILLING -> PREEMPTED / MIGRATING (PR 8): prefill now runs in
    # page-sized chunks interleaved with decode ticks, so a mid-prefill
    # request is preemptible under page pressure and migratable off a
    # dying replica — resume re-runs the chunks (bit-identical: same
    # fixed-width jit) instead of holding pages through the outage.
    # TIMED_OUT covers a deadline expiring between chunks.
    RequestState.PREFILLING: frozenset({RequestState.RUNNING,
                                        RequestState.FAILED,
                                        RequestState.PREEMPTED,
                                        RequestState.MIGRATING,
                                        RequestState.TIMED_OUT}),
    RequestState.RUNNING: frozenset({RequestState.FINISHED,
                                     RequestState.TIMED_OUT,
                                     RequestState.FAILED,
                                     RequestState.PREEMPTED,
                                     RequestState.MIGRATING}),
    RequestState.PREEMPTED: frozenset({RequestState.QUEUED}),
    # MIGRATING -> FAILED: the fleet has nowhere left to re-admit
    # (every replica dead) — still a typed terminal, never a lost request
    RequestState.MIGRATING: frozenset({RequestState.QUEUED,
                                       RequestState.FAILED,
                                       RequestState.TIMED_OUT}),
    RequestState.FINISHED: frozenset(),
    RequestState.TIMED_OUT: frozenset(),
    RequestState.FAILED: frozenset(),
}

_rid_counter = itertools.count()


class AdmissionError(RuntimeError):
    """Typed backpressure: the queue (or the page pool behind it) cannot
    take the request NOW.  ``retry_after`` is the server's estimate (in
    clock seconds) of when capacity may free up — a floor for client
    backoff, not a promise."""

    def __init__(self, msg: str, *, retry_after: float = 0.0):
        super().__init__(msg)
        self.retry_after = float(retry_after)


class LifecycleError(RuntimeError):
    """An illegal state-machine transition was attempted."""


@dataclasses.dataclass
class Request:
    """One serving request and its full lifecycle record.

    ``tokens`` is prompt + generated so far — on preemption it carries
    the accumulated stream back to the queue, and resume replays it
    (see Scheduler._admit_into).  ``deadline`` is ABSOLUTE in the
    scheduler's injectable clock; pass ``ttl`` (relative) at submit and
    the queue resolves it.  ``max_new_tokens=None`` means "until
    finish() is called" (the legacy surface).
    """
    prompt: list[int]
    max_new_tokens: int | None = None
    priority: int = 0                   # higher = more important
    deadline: float | None = None       # absolute, scheduler clock
    speculate: int = 1                  # verify width K (1 = no drafts)
    rid: int = dataclasses.field(default_factory=lambda: next(_rid_counter))
    state: RequestState = RequestState.QUEUED
    tokens: list[int] = dataclasses.field(default_factory=list)
    arrival_seq: int = -1               # stamped by AdmissionQueue.push
    preemptions: int = 0
    migrations: int = 0                 # replica-to-replica relocations
    error: str | None = None
    slot: int | None = None
    replica: int | None = None          # stamped by the fleet router

    def __post_init__(self):
        self.prompt = [int(t) for t in self.prompt]
        if not self.tokens:
            self.tokens = list(self.prompt)

    # -- state machine ------------------------------------------------------
    def to(self, state: RequestState, *, error: str | None = None) -> None:
        if state not in _TRANSITIONS[self.state]:
            raise LifecycleError(
                f"request {self.rid}: illegal transition "
                f"{self.state.value} -> {state.value}")
        self.state = state
        if error is not None:
            self.error = error
        if state is RequestState.PREEMPTED:
            self.preemptions += 1
        elif state is RequestState.MIGRATING:
            self.migrations += 1

    @property
    def terminal(self) -> bool:
        return self.state in TERMINAL_STATES

    @property
    def generated(self) -> int:
        return len(self.tokens) - len(self.prompt)

    def expired(self, now: float) -> bool:
        return self.deadline is not None and now >= self.deadline


class AdmissionQueue:
    """Bounded priority queue with typed backpressure.

    ``retry_after_hint`` is a callable returning the current estimate of
    seconds-per-admission-opportunity (the scheduler wires its step-time
    EWMA in); the hint scales with queue depth so a deeper queue tells
    clients to back off longer.
    """

    def __init__(self, maxsize: int, *,
                 retry_after_hint: Callable[[], float] | None = None):
        if maxsize < 1:
            raise ValueError(f"queue maxsize must be >= 1, got {maxsize}")
        self.maxsize = maxsize
        self._q: deque[Request] = deque()
        self._seq = itertools.count()
        self._hint = retry_after_hint or (lambda: 0.0)
        self.rejected = 0

    def __len__(self) -> int:
        return len(self._q)

    def push(self, req: Request, *, force: bool = False) -> None:
        """Enqueue (or re-enqueue a preempted request).  Raises
        :class:`AdmissionError` when full — backpressure, not a crash.
        ``force=True`` bypasses the bound: preempted requests carry
        accumulated tokens and dropping them would turn backpressure
        into data loss."""
        if not force and len(self._q) >= self.maxsize:
            self.rejected += 1
            raise AdmissionError(
                f"admission queue full ({self.maxsize} waiting)",
                retry_after=max(self._hint(), 0.0) * (len(self._q) + 1))
        if req.state in (RequestState.PREEMPTED, RequestState.MIGRATING):
            req.to(RequestState.QUEUED)      # keeps its arrival_seq
        if req.arrival_seq < 0:
            req.arrival_seq = next(self._seq)
        self._q.append(req)

    def pop(self) -> Request | None:
        """Highest priority first, then earliest arrival."""
        if not self._q:
            return None
        best = max(self._q, key=lambda r: (r.priority, -r.arrival_seq))
        self._q.remove(best)
        return best

    def peek(self) -> Request | None:
        if not self._q:
            return None
        return max(self._q, key=lambda r: (r.priority, -r.arrival_seq))

    def expire(self, now: float) -> list[Request]:
        """Drop queued requests past their deadline (-> TIMED_OUT)."""
        dead = [r for r in self._q if r.expired(now)]
        for r in dead:
            self._q.remove(r)
            r.to(RequestState.TIMED_OUT, error="deadline expired in queue")
        return dead

    def drain(self) -> list[Request]:
        out = list(self._q)
        self._q.clear()
        return out


def retry_with_backoff(fn: Callable[[], object], *, retries: int = 5,
                       base: float = 0.05, cap: float = 2.0,
                       jitter: float = 0.5, seed: int = 0,
                       sleep: Callable[[float], None] = time.sleep,
                       exceptions: tuple = (AdmissionError,)):
    """Call ``fn`` until it stops raising backpressure.

    Delay for attempt ``k`` is ``min(cap, base * 2**k)`` scaled by a
    deterministic jitter factor in ``[1 - jitter, 1]`` (seeded — two
    clients with different seeds desynchronize, the same seed replays
    exactly), floored at the server's ``retry_after`` hint when the
    exception carries one.  ``sleep`` is injectable for tests."""
    rng = random.Random(seed)
    for attempt in range(retries + 1):
        try:
            return fn()
        except exceptions as e:
            if attempt == retries:
                raise
            delay = min(cap, base * (2.0 ** attempt))
            delay *= 1.0 - jitter * rng.random()
            delay = max(delay, getattr(e, "retry_after", 0.0))
            sleep(delay)


def backoff_delays(attempts: int, *, base: float = 0.05, cap: float = 2.0,
                   jitter: float = 0.5, seed: int = 0) -> list[float]:
    """The deterministic delay schedule retry_with_backoff would use
    (before retry_after flooring) — for tests and capacity planning."""
    rng = random.Random(seed)
    return [min(cap, base * (2.0 ** k)) * (1.0 - jitter * rng.random())
            for k in range(attempts)]


def summarize(requests: Sequence[Request]) -> dict[str, int]:
    """State histogram of a batch of requests (chaos reports, CLI)."""
    out: dict[str, int] = {s.value: 0 for s in RequestState}
    for r in requests:
        out[r.state.value] += 1
    out["preemptions"] = sum(r.preemptions for r in requests)
    out["migrations"] = sum(r.migrations for r in requests)
    return out
