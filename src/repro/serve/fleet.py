"""Fault-tolerant serve fleet: a health-checked replica router with
replay-based request migration.

PR 6 hardened ONE scheduler; this module lifts the control plane one
level: a :class:`FleetRouter` owns N in-process
:class:`~repro.serve.scheduler.Scheduler` replica workers — each with
its OWN :class:`~repro.serve.paged_cache.PagedCache` page pool and its
own admission queue, all sharing one params pytree — and does

  * LEAST-LOADED ADMISSION: ``submit`` routes to the healthy replica
    with the fewest resident requests (queued + slotted), falling
    through the candidates on per-replica backpressure; when every
    replica backpressures, the per-replica ``AdmissionError``\\ s are
    AGGREGATED into one carrying the MINIMUM retry-after hint (the
    soonest any replica expects capacity).

  * HEALTH TRACKING: a replica is HEALTHY, DEGRADED, or DEAD.  The
    router stamps a heartbeat after every successful replica tick;
    staleness beyond ``heartbeat_ticks`` router ticks (a hung step, a
    crashed tick) declares the replica DEAD.  A replica whose
    :class:`~repro.ft.straggler.StepWatchdog` accumulates
    ``hard_breach_limit`` hard-limit breaches goes DEGRADED: it stops
    admitting, its QUEUED work migrates to healthy replicas, its
    running requests finish in place, and once drained it rejoins as
    HEALTHY (watchdog breach mark reset — a slow patch is a reason to
    shed load, not to discard a working pool).

  * FAILOVER: when a replica dies (``kill_replica``, a crashed tick, a
    stale heartbeat), every resident request transitions through the
    MIGRATING lifecycle edge and is re-admitted on a surviving replica.
    Resume there is the ordinary PR 6 preemption-and-restore path: the
    ORIGINAL prompt re-prefills bit-identically and the accumulated
    tokens replay through the one jit'd decode step, so a migrated
    request's post-catch-up stream is BIT-EXACT vs an uninterrupted
    single-replica oracle on pad-safe stacks (allclose for windowed /
    recurrent) — migration IS preemption pointed at a different page
    pool; no pool copy, no KV serialization crosses replicas.  EARTH's
    thesis (routing is cheap once compiled) is what makes this cheap:
    the target replica's jit'd step and plans are already compiled, the
    request is just a replay cursor.  Dead replicas RESPAWN on the next
    tick from the shared params with an empty pool and rejoin HEALTHY.

The whole fleet is deterministic under an injected clock: routing ties
break on replica index, death/respawn happen on tick boundaries, and
greedy decode makes per-request streams independent of batch
composition — ``tests/test_fleet.py`` gates 1-replica vs N-replica
trace equivalence and migration == preemption bit-exactness, and
``serve/chaos.py``'s fleet plans drive kill / hang / storm faults with
the fleet audit (no request lost or double-resident, per-replica pool
invariants) every tick.
"""
from __future__ import annotations

import dataclasses
import enum
import time
from typing import Callable, Sequence

import numpy as np

from repro.ft.straggler import StepWatchdog, StragglerConfig
from repro.models.transformer import ModelConfig
from repro.serve.lifecycle import AdmissionError, Request, RequestState
from repro.serve.scheduler import Scheduler


class ReplicaState(enum.Enum):
    HEALTHY = "healthy"
    DEGRADED = "degraded"       # draining: no admission, running finish
    DEAD = "dead"               # evacuated; respawns next tick


class FleetAuditError(AssertionError):
    """The fleet-level residency contract broke: a live request resident
    on zero or multiple replicas, or a terminal request still resident.
    Like :class:`~repro.serve.paged_cache.InvariantViolation`, this is a
    control-plane bug, never load — backpressure and failover must not
    trip it."""


@dataclasses.dataclass
class Replica:
    """One scheduler worker plus the router's view of its health."""
    idx: int
    sched: Scheduler
    state: ReplicaState = ReplicaState.HEALTHY
    generation: int = 0                 # respawn count for this index
    heartbeat_tick: int = 0             # last successful sched.tick()
    heartbeat_time: float = 0.0
    hung_until_tick: int | None = None  # chaos: ticks are skipped until
    hang_started: float | None = None
    breach_mark: int = 0                # hard_breaches at last health reset
    death_reason: str | None = None

    @property
    def alive(self) -> bool:
        return self.state is not ReplicaState.DEAD

    def hard_breaches_since_mark(self) -> int:
        wd = self.sched.watchdog
        return 0 if wd is None else wd.hard_breaches - self.breach_mark


class FleetRouter:
    """N in-process Scheduler replicas behind one admission surface.

    Geometry/sampling kwargs (``slots`` / ``max_len`` / ``page_size`` /
    ``num_pages`` / ``temperature`` / ``top_k`` / ``seed`` / lifecycle
    knobs) are passed through to every replica's Scheduler, so a
    1-replica fleet is exactly one PR 6 scheduler behind a router — the
    determinism oracle tests rely on this.

    Health knobs: ``heartbeat_ticks`` is the staleness bound (a replica
    that has not completed a tick for more than this many router ticks
    is DEAD — deterministic under test clocks, unlike wall-time);
    ``hard_breach_limit`` is how many watchdog hard-limit breaches turn
    a replica DEGRADED; ``watchdog_hard_limit`` (seconds) arms each
    replica's :class:`StepWatchdog` hard limit; ``respawn`` controls
    whether dead replicas are rebuilt (fresh Scheduler from the shared
    params, empty pool) on the tick after death.
    """

    def __init__(self, cfg: ModelConfig, params, *, replicas: int,
                 slots: int, max_len: int,
                 heartbeat_ticks: int = 4, hard_breach_limit: int = 3,
                 watchdog_hard_limit: float | None = None,
                 watchdog_cfg: StragglerConfig | None = None,
                 respawn: bool = True,
                 clock: Callable[[], float] = time.monotonic,
                 **scheduler_kw):
        if replicas < 1:
            raise ValueError(f"need at least one replica, got {replicas}")
        if heartbeat_ticks < 1:
            raise ValueError(f"heartbeat_ticks must be >= 1, "
                             f"got {heartbeat_ticks}")
        self.cfg, self.params = cfg, params
        self.n_replicas = replicas
        self.slots, self.max_len = slots, max_len
        self.heartbeat_ticks = heartbeat_ticks
        self.hard_breach_limit = hard_breach_limit
        self.watchdog_hard_limit = watchdog_hard_limit
        self.watchdog_cfg = watchdog_cfg
        self.respawn = respawn
        self.clock = clock
        self._sched_kw = dict(scheduler_kw)
        self.tick_no = 0
        self.requests: dict[int, Request] = {}      # fleet-wide registry
        self._newly_terminal: list[Request] = []    # failed in failover
        self.deaths = 0
        self.respawns = 0
        self.drains = 0          # DEGRADED transitions
        self.rejoins = 0         # DEGRADED -> HEALTHY recoveries
        self.migrated = 0        # requests moved between replicas
        self.replicas = [self._spawn(i, 0) for i in range(replicas)]

    # -- spawning ------------------------------------------------------------
    def _make_watchdog(self) -> StepWatchdog:
        cfg = self.watchdog_cfg or StragglerConfig()
        return StepWatchdog(cfg, hard_limit=self.watchdog_hard_limit)

    def _spawn(self, idx: int, generation: int) -> Replica:
        sched = Scheduler(self.cfg, self.params, slots=self.slots,
                          max_len=self.max_len, clock=self.clock,
                          watchdog=self._make_watchdog(),
                          **self._sched_kw)
        return Replica(idx=idx, sched=sched, generation=generation,
                       heartbeat_tick=self.tick_no,
                       heartbeat_time=self.clock())

    # -- admission -----------------------------------------------------------
    def _healthy(self) -> list[Replica]:
        return [r for r in self.replicas
                if r.state is ReplicaState.HEALTHY]

    def _by_load(self, reps: Sequence[Replica]) -> list[Replica]:
        return sorted(reps, key=lambda r: (r.sched.load(), r.idx))

    def submit(self, prompt: Sequence[int], *, replica: int | None = None,
               **kw) -> Request:
        """Route to the least-loaded healthy replica (ties break on
        index — deterministic), falling through candidates on
        per-replica backpressure.  ``replica=`` pins the target (chaos
        storms, affinity tests); a pinned unhealthy replica is
        backpressure, not an error class of its own.  When every
        candidate refuses, the per-replica errors aggregate into one
        :class:`AdmissionError` with the MINIMUM retry-after."""
        if replica is not None:
            rep = self.replicas[replica]
            if rep.state is not ReplicaState.HEALTHY:
                raise AdmissionError(
                    f"replica {replica} is {rep.state.value}",
                    retry_after=float(self.heartbeat_ticks))
            candidates = [rep]
        else:
            candidates = self._by_load(self._healthy())
        if not candidates:
            raise AdmissionError("no healthy replica",
                                 retry_after=float(self.heartbeat_ticks))
        errors: list[tuple[int, AdmissionError]] = []
        for rep in candidates:
            try:
                req = rep.sched.submit(prompt, **kw)
            except AdmissionError as e:
                errors.append((rep.idx, e))
                continue
            req.replica = rep.idx
            self.requests[req.rid] = req
            return req
        raise AdmissionError(
            "all replicas backpressured: " + "; ".join(
                f"r{i}: {e}" for i, e in errors),
            retry_after=min(e.retry_after for _, e in errors))

    # -- failover ------------------------------------------------------------
    def kill_replica(self, idx: int, *, reason: str = "killed") -> None:
        """Declare a replica dead NOW: every resident request migrates
        (MIGRATING -> re-queued elsewhere, resumed via the replay
        cursor); the replica respawns with an empty pool on the next
        tick (when ``respawn`` is on)."""
        rep = self.replicas[idx]
        if rep.state is ReplicaState.DEAD:
            return
        self._mark_dead(rep, reason)

    def hang_replica(self, idx: int, ticks: int) -> None:
        """Chaos: stall a replica for ``ticks`` router ticks — its step
        never completes, so its heartbeat goes stale.  A hang longer
        than ``heartbeat_ticks`` is declared DEAD mid-hang; a shorter
        one wakes up, its watchdog observes the stall as one giant step
        (a hard-limit breach when armed), and the DEGRADED drain path
        takes over."""
        rep = self.replicas[idx]
        if not rep.alive or ticks < 1:
            return
        rep.hung_until_tick = self.tick_no + ticks
        if rep.hang_started is None:
            rep.hang_started = self.clock()

    def _mark_dead(self, rep: Replica, reason: str) -> None:
        rep.state = ReplicaState.DEAD
        rep.death_reason = reason
        rep.hung_until_tick = None
        rep.hang_started = None
        self.deaths += 1
        self._reassign(rep.sched.evacuate(), reason)

    def _reassign(self, evacuees: list[Request], reason: str) -> None:
        """Re-admit MIGRATING requests on surviving replicas.  With no
        healthy replica left they fail TYPED (never silently lost) —
        the audit counts them, the chaos gate accepts them."""
        for req in evacuees:
            targets = self._by_load(self._healthy())
            if not targets:
                req.to(RequestState.FAILED,
                       error=f"no live replica to migrate to ({reason})")
                self._newly_terminal.append(req)
                continue
            target = targets[0]
            target.sched.adopt(req)
            req.replica = target.idx
            self.migrated += 1

    def _degrade(self, rep: Replica) -> None:
        rep.state = ReplicaState.DEGRADED
        self.drains += 1
        self._reassign(rep.sched.migrate_queued(),
                       f"replica {rep.idx} degraded")

    # -- the fleet pump ------------------------------------------------------
    def tick(self) -> list[Request]:
        """One fleet iteration: respawn dead replicas, tick live ones
        (hung replicas skip — their heartbeat stales), then run the
        health pass (staleness -> DEAD + failover, hard-limit breaches
        -> DEGRADED + drain, drained DEGRADED -> rejoin).  Returns every
        request that went terminal this tick, fleet-wide."""
        self.tick_no += 1
        done: list[Request] = []
        for rep in self.replicas:
            if rep.state is ReplicaState.DEAD:
                if self.respawn:
                    self.replicas[rep.idx] = self._spawn(
                        rep.idx, rep.generation + 1)
                    self.respawns += 1
                continue
            if rep.hung_until_tick is not None:
                if self.tick_no <= rep.hung_until_tick:
                    continue            # stalled: no tick, no heartbeat
                # the hang ended: the watchdog sees it as ONE giant step
                stall = self.clock() - (rep.hang_started or 0.0)
                if rep.sched.watchdog is not None:
                    rep.sched.watchdog.observe(stall)
                rep.hung_until_tick = None
                rep.hang_started = None
            try:
                done.extend(rep.sched.tick())
            except Exception as e:      # noqa: BLE001 — replica crash
                self._mark_dead(rep, f"tick crashed: {type(e).__name__}: "
                                     f"{e}")
                continue
            rep.heartbeat_tick = self.tick_no
            rep.heartbeat_time = self.clock()
        # -- health pass ----------------------------------------------------
        for rep in self.replicas:
            if rep.state is ReplicaState.DEAD:
                continue
            if self.tick_no - rep.heartbeat_tick > self.heartbeat_ticks:
                self._mark_dead(rep, "heartbeat stale")
                continue
            if (rep.state is ReplicaState.HEALTHY
                    and self.hard_breach_limit is not None
                    and rep.hard_breaches_since_mark()
                    >= self.hard_breach_limit):
                self._degrade(rep)
            if rep.state is ReplicaState.DEGRADED and \
                    not any(rep.sched.active):
                rep.state = ReplicaState.HEALTHY
                if rep.sched.watchdog is not None:
                    rep.breach_mark = rep.sched.watchdog.hard_breaches
                self.rejoins += 1
        done.extend(self._newly_terminal)
        self._newly_terminal.clear()
        return done

    def drained(self) -> bool:
        """Nothing queued or running on any live replica (dead replicas
        hold nothing by construction — death evacuates)."""
        return all(rep.sched.drained() for rep in self.replicas
                   if rep.alive)

    # -- audit ---------------------------------------------------------------
    def audit(self) -> None:
        """The fleet residency contract, checked on a tick boundary:

        * no rid resident on more than one live replica (double
          residency would decode one request twice — and bill twice);
        * every non-terminal fleet-admitted request resident on EXACTLY
          one live replica (zero = a lost request);
        * no terminal request still resident;
        * nothing stuck in MIGRATING between ticks (migration completes
          within the call that started it);
        * every live replica's pool invariants hold
          (:meth:`PagedCache.check_invariants`).

        Raises :class:`FleetAuditError` (pool problems raise their own
        :class:`InvariantViolation`)."""
        owner: dict[int, int] = {}
        for rep in self.replicas:
            if not rep.alive:
                continue
            for rid in rep.sched.resident_rids():
                if rid in owner:
                    raise FleetAuditError(
                        f"request {rid} double-resident: replicas "
                        f"{owner[rid]} and {rep.idx}")
                owner[rid] = rep.idx
        for req in self.requests.values():
            if req.state is RequestState.MIGRATING:
                raise FleetAuditError(
                    f"request {req.rid} stuck MIGRATING at tick boundary")
            if req.terminal:
                if req.rid in owner:
                    raise FleetAuditError(
                        f"terminal request {req.rid} "
                        f"({req.state.value}) still resident on replica "
                        f"{owner[req.rid]}")
            elif req.rid not in owner:
                raise FleetAuditError(
                    f"request {req.rid} ({req.state.value}) lost: "
                    f"resident on no live replica")
        for rep in self.replicas:
            if rep.alive:
                rep.sched.cache.check_invariants()

    # -- observability -------------------------------------------------------
    def stats(self) -> dict:
        from repro.serve.lifecycle import summarize
        out = summarize(list(self.requests.values()))
        out.update(
            replicas={rep.idx: {
                "state": rep.state.value,
                "generation": rep.generation,
                "load": rep.sched.load() if rep.alive else 0,
                "hard_breaches": (0 if rep.sched.watchdog is None
                                  else rep.sched.watchdog.hard_breaches),
                "pages_in_use": (rep.sched.cache.pages_in_use()
                                 if rep.alive else 0),
            } for rep in self.replicas},
            deaths=self.deaths, respawns=self.respawns,
            drains=self.drains, rejoins=self.rejoins,
            migrated=self.migrated, ticks=self.tick_no)
        # prefix-cache observability (PR 8): per-replica tries, rolled
        # up fleet-wide — hit rate over all admissions, live shared pages
        live_tries = [rep.sched for rep in self.replicas
                      if rep.alive and rep.sched.prefix is not None]
        if live_tries:
            hits = sum(s.prefix.hits for s in live_tries)
            misses = sum(s.prefix.misses for s in live_tries)
            out.update(
                prefix_hits=hits, prefix_misses=misses,
                prefix_hit_rate=hits / (hits + misses)
                if hits + misses else 0.0,
                prefix_tokens_reused=sum(s.prefix.tokens_reused
                                         for s in live_tries),
                shared_pages=sum(s.stats().get("shared_pages", 0)
                                 for s in live_tries))
        # fleet-wide latency percentiles (PR 10): RAW samples concatenate
        # across live replicas before taking percentiles — percentiles of
        # per-replica percentiles are not percentiles
        ttft: list[float] = []
        itl: list[float] = []
        for rep in self.replicas:
            if rep.alive:
                samp = rep.sched.latency_samples()
                ttft.extend(samp["ttft"])
                itl.extend(samp["itl"])
        lat: dict[str, float] = {}
        for name, xs in (("ttft", ttft), ("itl", itl)):
            if xs:
                lat[f"{name}_p50_s"] = float(np.percentile(xs, 50))
                lat[f"{name}_p99_s"] = float(np.percentile(xs, 99))
        out["latency"] = lat
        # speculative rollup: acceptance over every verify step fleet-wide
        proposed = sum(rep.sched.spec_proposed for rep in self.replicas
                       if rep.alive)
        accepted = sum(rep.sched.spec_accepted for rep in self.replicas
                       if rep.alive)
        if any(rep.sched.speculate > 1 for rep in self.replicas
               if rep.alive):
            out["speculative"] = {
                "k": max(rep.sched.speculate for rep in self.replicas
                         if rep.alive),
                "proposed": proposed, "accepted": accepted,
                "acceptance": accepted / proposed if proposed else 0.0,
            }
        return out
