"""Prefix-sharing radix cache over the paged KV pool.

Production decode traffic is dominated by shared prompt prefixes —
system prompts, few-shot templates — yet the PR 5 paged runtime
materialized a private copy of every prompt's KV into every slot's
pages.  EARTH's thesis says the expensive part of the pool is the
ROUTING (compiled once into the fused page-gather), not the pages: a
slot that POINTS its table row at pages another request already filled
pays zero new device work per step.  This module owns the host side of
that sharing:

  * a RADIX TRIE at page granularity: each node is keyed by exactly
    ``page_size`` tokens and owns ONE physical page in the pool whose
    beats are the KV for those tokens at that depth.  A node's page
    contents are a deterministic function of the token prefix from the
    root (the chunked prefill is one fixed jit — every producer computes
    bit-identical beats), so adopting the page is BIT-EXACT vs
    recomputing it.
  * ADMISSION walks the trie along the prompt's full pages:
    :meth:`acquire` returns the matched page run (the scheduler points
    the new slot's table at it via ``PagedCache.adopt_prefix``, +1
    refcount per page) plus, when a child matches only the first ``m``
    tokens of the next page, a copy-on-write FORK descriptor — the
    borrower gets a private copy of that page truncated at ``m``
    (``PagedCache.fork_page``) so a SHARED page is never written in
    place.  Forking at admission is the CoW trigger: it is the only
    point where a slot could otherwise append into a refcount>1 page,
    so the decode step's jit stays untouched.
  * PUBLISH: when a prompt finishes prefilling, its full PROMPT pages
    are inserted into the trie (dedup against existing nodes) and each
    newly published page gets an EXTERNAL +1 device refcount
    (``PagedCache.addref``) — the trie's pin.  Partial tail pages and
    generated tokens are never published: they are slot-private and an
    audit invariant (``paged_invariants``) enforces that any slot whose
    position is mid-page holds a refcount-1 tail.
  * RELEASE / EVICTION: releasing a slot only unpins its nodes (the
    device-side table deref happens in ``paged_release_slot``; shared
    pages survive at refcount >= 1 under the trie pin).  Under page
    pressure the scheduler evicts LRU LEAVES whose pin count is zero —
    evicting an interior node or a pinned leaf would free nothing (the
    page survives under table references), so eviction is exact: every
    evicted node's deref returns precisely one page to the free stack.

``page_refs`` exports the trie's per-page pin counts so the pool
auditor can check refcount CONSERVATION on the live device state:
``ref[p] == (# table entries naming p) + (# trie nodes naming p)``.
Everything here is pure host Python — device mutation goes through the
``PagedCache`` wrappers the scheduler calls with what this module
returns.

QUANTIZED pools (PR 9) need no trie changes: the trie names PHYSICAL
page ids, and a quantized page's scale row travels with its id — the
fused gather looks the scale up through the same table the pool is
read through, ``fork_page`` copies the source page's scale into the
CoW copy, and ``adopt_prefix`` shares scales implicitly by sharing the
page.  Sharing stays bit-exact at the INT level (same page, same scale,
same dequant); only the producer's quantize-on-write was lossy.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np


@dataclasses.dataclass
class _Node:
    """One trie node: ``page_size`` tokens -> one physical page."""
    key: tuple          # exactly page_size tokens (root: empty tuple)
    page: int           # physical page id in the pool (-1 for the root)
    parent: "_Node | None"
    children: dict = dataclasses.field(default_factory=dict)
    users: int = 0      # live slots whose table references this page
    last_used: int = 0  # logical LRU clock


@dataclasses.dataclass(frozen=True)
class PrefixMatch:
    """Result of an admission walk.

    ``run`` is the matched full-page run (adopt these, in order);
    ``fork_src`` / ``fork_len`` describe a partial tail match — the
    borrower's next page shares its first ``fork_len`` tokens with an
    existing page, so fork a truncated private copy — or (-1, 0) when
    the match ended exactly on a page boundary.  ``matched_tokens`` is
    the total prefix length served from the cache."""
    run: tuple
    fork_src: int
    fork_len: int
    matched_tokens: int


class PrefixCache:
    """Radix cache mapping token prefixes to refcounted page runs."""

    def __init__(self, page_size: int, num_pages: int):
        self.page_size = page_size
        self.num_pages = num_pages
        self.root = _Node(key=(), page=-1, parent=None)
        self._clock = 0
        self._pins: dict[int, list[_Node]] = {}   # slot -> pinned nodes
        self.hits = 0
        self.misses = 0
        self.inserted = 0
        self.evicted = 0
        self.tokens_reused = 0

    def _tick(self) -> int:
        self._clock += 1
        return self._clock

    # -- admission walk -----------------------------------------------------
    def acquire(self, slot: int, tokens: Sequence[int]) -> PrefixMatch:
        """Walk the trie along ``tokens`` (the prefill portion of a
        prompt), pinning every matched node under ``slot``.  Full-page
        matches extend ``run``; at the first divergence, the child
        sharing the longest proper prefix of the next page (if any)
        becomes the fork source.  Pins are dropped by :meth:`release`."""
        ps = self.page_size
        node, run, pins = self.root, [], []
        i = 0
        while len(tokens) - i >= ps:
            child = node.children.get(tuple(tokens[i:i + ps]))
            if child is None:
                break
            run.append(child.page)
            child.users += 1
            child.last_used = self._tick()
            pins.append(child)
            node = child
            i += ps
        fork_src, fork_len = -1, 0
        rem = list(tokens[i:i + ps])
        if rem:
            for child in node.children.values():
                m = 0
                for a, b in zip(rem, child.key):
                    if a != b:
                        break
                    m += 1
                if m > fork_len:
                    fork_src, fork_len = child.page, m
        if pins:
            self._pins.setdefault(slot, []).extend(pins)
        matched = i + fork_len
        if matched:
            self.hits += 1
        else:
            self.misses += 1
        self.tokens_reused += matched
        return PrefixMatch(run=tuple(run), fork_src=fork_src,
                           fork_len=fork_len, matched_tokens=matched)

    # -- publish ------------------------------------------------------------
    def publish(self, slot: int, tokens: Sequence[int],
                table_row: np.ndarray) -> list[int]:
        """Insert ``slot``'s full PROMPT pages into the trie after its
        prefill completed.  ``table_row`` maps logical page index ->
        physical page.  Pages already published by another request are
        skipped (the slot keeps its private duplicate — correct, just
        not shared); newly inserted pages are returned so the caller
        can take the trie's device refcount pin (``addref``)."""
        ps = self.page_size
        node, new = self.root, []
        pins = self._pins.setdefault(slot, [])
        for j in range(len(tokens) // ps):
            key = tuple(tokens[j * ps:(j + 1) * ps])
            child = node.children.get(key)
            if child is None:
                page = int(table_row[j])
                if page < 0:       # starved prefill: nothing to publish
                    break
                child = _Node(key=key, page=page, parent=node)
                node.children[key] = child
                child.users += 1
                pins.append(child)
                new.append(page)
                self.inserted += 1
            child.last_used = self._tick()
            node = child
        return new

    # -- release / eviction -------------------------------------------------
    def release(self, slot: int) -> None:
        """Unpin every node ``slot`` acquired or published.  Host-side
        only: the slot's own table deref reclaims its references; trie
        pages stay alive under the trie's external pin until evicted."""
        for node in self._pins.pop(slot, []):
            node.users -= 1

    def evict(self, n_pages: int) -> list[int]:
        """Detach up to ``n_pages`` LRU leaves with zero pins and return
        their page ids — the caller MUST ``deref_pages`` them (each
        returns exactly one page to the free stack, because an unpinned
        leaf's only remaining reference is the trie's own)."""
        out: list[int] = []
        while len(out) < n_pages:
            victim = None
            for node in self._iter_nodes():
                if node is self.root or node.children or node.users > 0:
                    continue
                if victim is None or node.last_used < victim.last_used:
                    victim = node
            if victim is None:
                break
            del victim.parent.children[victim.key]
            out.append(victim.page)
            self.evicted += 1
        return out

    # -- accounting ---------------------------------------------------------
    def _iter_nodes(self):
        stack = [self.root]
        while stack:
            node = stack.pop()
            yield node
            stack.extend(node.children.values())

    def page_refs(self) -> np.ndarray:
        """Per-page external pin counts (one per trie node) — the
        ``external_ref`` term of the pool's conservation audit."""
        ext = np.zeros((self.num_pages,), np.int64)
        for node in self._iter_nodes():
            if node.page >= 0:
                ext[node.page] += 1
        return ext

    def pages(self) -> int:
        """Pages currently held by the trie."""
        return sum(1 for n in self._iter_nodes() if n.page >= 0)

    def orphan_pages(self) -> int:
        """Trie pages no live slot references (pin count zero) — held
        memory the scheduler's admission math must reserve for, and
        exactly what :meth:`evict` can hand back under pressure."""
        return sum(1 for n in self._iter_nodes()
                   if n.page >= 0 and n.users == 0)

    def stats(self) -> dict:
        total = self.hits + self.misses
        return {
            "hits": self.hits,
            "misses": self.misses,
            "hit_rate": self.hits / total if total else 0.0,
            "tokens_reused": self.tokens_reused,
            "pages": self.pages(),
            "inserted": self.inserted,
            "evicted": self.evicted,
        }
