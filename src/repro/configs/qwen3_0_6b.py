"""qwen3-0.6b — 28L d1024 16H (GQA kv=8) ff3072 vocab 151936.

qk-norm + GQA, head_dim 128 [hf:Qwen/Qwen3-0.6B]. Full attention ->
long_500k skipped.
"""
from repro.configs.base import ArchConfig
from repro.models.transformer import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-0.6b", d_model=1024, n_layers=28, n_heads=16,
        n_kv_heads=8, head_dim=128, d_ff=3072, vocab=151936,
        mlp="swiglu", qk_norm=True, rope_theta=1e6,
        param_dtype="float32", compute_dtype="bfloat16", remat="full")


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-0.6b-smoke", d_model=128, n_layers=2, n_heads=4,
        n_kv_heads=2, head_dim=32, d_ff=256, vocab=512,
        mlp="swiglu", qk_norm=True)


def arch() -> ArchConfig:
    return ArchConfig(model=config(), smoke=smoke_config(),
                      runs_long_context=False, family="dense")
