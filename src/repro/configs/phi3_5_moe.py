"""phi3.5-moe-42b-a6.6b — 32L d4096 32H (GQA kv=8), MoE 16e top-2 ff6400,
vocab 32064 [hf:microsoft/Phi-3.5-MoE-instruct]. Every layer MoE.
Full attention -> long_500k skipped.
"""
from repro.configs.base import ArchConfig
from repro.models.moe import MoESpec
from repro.models.transformer import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="phi3.5-moe-42b", d_model=4096, n_layers=32, n_heads=32,
        n_kv_heads=8, head_dim=128, d_ff=6400, vocab=32064,
        block_pattern=("attn",), moe_pattern=(True,), mlp="swiglu",
        moe=MoESpec(n_experts=16, top_k=2, d_ff=6400),
        rope_theta=1e4, tie_embeddings=False,
        param_dtype="float32", compute_dtype="bfloat16", remat="full")


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="phi3.5-moe-smoke", d_model=64, n_layers=2, n_heads=4,
        n_kv_heads=2, head_dim=16, d_ff=128, vocab=512,
        block_pattern=("attn",), moe_pattern=(True,), mlp="swiglu",
        moe=MoESpec(n_experts=4, top_k=2, d_ff=128), tie_embeddings=False)


def arch() -> ArchConfig:
    return ArchConfig(model=config(), smoke=smoke_config(),
                      runs_long_context=False, family="moe",
                      notes="16 experts / 16-way model axis -> exactly one "
                            "expert per device (EP sweet spot).")
