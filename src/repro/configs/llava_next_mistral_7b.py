"""llava-next-mistral-7b — 32L d4096 32H (GQA kv=8) ff14336 vocab 32000.

Mistral-7B backbone; anyres vision tiling is a STUB: input_specs provides
precomputed patch embeddings (B, 2880, 4096) that overwrite the first image
token positions. Full attention -> long_500k skipped.
"""
from repro.configs.base import ArchConfig
from repro.models.transformer import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="llava-next-mistral-7b", d_model=4096, n_layers=32, n_heads=32,
        n_kv_heads=8, head_dim=128, d_ff=14336, vocab=32000,
        mlp="swiglu", rope_theta=1e6, vlm_patches=2880, tie_embeddings=False,
        param_dtype="float32", compute_dtype="bfloat16", remat="full")


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="llava-smoke", d_model=128, n_layers=2, n_heads=4,
        n_kv_heads=2, head_dim=32, d_ff=256, vocab=512,
        mlp="swiglu", vlm_patches=16, tie_embeddings=False)


def arch() -> ArchConfig:
    return ArchConfig(model=config(), smoke=smoke_config(),
                      runs_long_context=False, family="vlm",
                      notes="anyres tiling stub = 5 x 576 patches.")
