"""Config substrate: shape cells, arch registry, and input spec builders.

Every assigned architecture ships as ``configs/<id>.py`` exposing:
  * ``config()``       — the exact published geometry (dry-run only;
                          full params are never materialized on CPU),
  * ``smoke_config()`` — a reduced same-family config for CPU smoke tests.

Shape cells follow the assignment: train_4k / prefill_32k / decode_32k /
long_500k; ``long_500k`` only runs for sub-quadratic archs (see
``runs_long_context`` and DESIGN.md §Arch-applicability).
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Any

import jax
import jax.numpy as jnp

from repro.models.transformer import ModelConfig


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    seq_len: int
    global_batch: int
    kind: str                  # "train" | "prefill" | "decode"


SHAPES: tuple[ShapeCell, ...] = (
    ShapeCell("train_4k", 4096, 256, "train"),
    ShapeCell("prefill_32k", 32768, 32, "prefill"),
    ShapeCell("decode_32k", 32768, 128, "decode"),
    ShapeCell("long_500k", 524288, 1, "decode"),
)


def shape_by_name(name: str) -> ShapeCell:
    for s in SHAPES:
        if s.name == name:
            return s
    raise KeyError(name)


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    model: ModelConfig
    smoke: ModelConfig
    runs_long_context: bool       # sub-quadratic family?
    family: str                   # dense|moe|hybrid|ssm|audio|vlm
    notes: str = ""


ARCH_IDS = (
    "granite-34b", "gemma3-12b", "qwen3-0.6b", "starcoder2-3b",
    "jamba-1.5-large-398b", "whisper-tiny", "llava-next-mistral-7b",
    "phi3.5-moe-42b-a6.6b", "qwen3-moe-30b-a3b", "xlstm-125m",
)

_MODULES = {
    "granite-34b": "granite_34b",
    "gemma3-12b": "gemma3_12b",
    "qwen3-0.6b": "qwen3_0_6b",
    "starcoder2-3b": "starcoder2_3b",
    "jamba-1.5-large-398b": "jamba_1_5_large",
    "whisper-tiny": "whisper_tiny",
    "llava-next-mistral-7b": "llava_next_mistral_7b",
    "phi3.5-moe-42b-a6.6b": "phi3_5_moe",
    "qwen3-moe-30b-a3b": "qwen3_moe_30b",
    "xlstm-125m": "xlstm_125m",
}


def get_arch(name: str) -> ArchConfig:
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[name]}")
    return mod.arch()


def cells_for(name: str) -> list[ShapeCell]:
    a = get_arch(name)
    out = []
    for s in SHAPES:
        if s.name == "long_500k" and not a.runs_long_context:
            continue
        out.append(s)
    return out


# ---------------------------------------------------------------------------
# Input builders. ``specs=True`` returns ShapeDtypeStructs (dry-run, no
# allocation); otherwise concrete arrays (smoke tests).
# ---------------------------------------------------------------------------

def _maybe_struct(shape, dtype, specs: bool, key=None, vocab: int = 0):
    if specs:
        return jax.ShapeDtypeStruct(shape, dtype)
    if key is None:
        key = jax.random.key(0)
    if jnp.issubdtype(dtype, jnp.integer):
        return jax.random.randint(key, shape, 0, max(vocab, 2), dtype)
    return jax.random.normal(key, shape, dtype)


def train_batch(cfg: ModelConfig, seq: int, batch: int, *,
                specs: bool = True, key=None) -> dict[str, Any]:
    key = jax.random.key(0) if key is None else key
    ks = jax.random.split(key, 4)
    b = {
        "tokens": _maybe_struct((batch, seq), jnp.int32, specs, ks[0],
                                cfg.vocab),
        "labels": _maybe_struct((batch, seq), jnp.int32, specs, ks[1],
                                cfg.vocab),
        "loss_weight": _maybe_struct((batch, seq), jnp.float32, specs, ks[2]),
    }
    if cfg.vlm_patches:
        n = min(cfg.vlm_patches, seq)
        b["patch_embeds"] = _maybe_struct((batch, n, cfg.d_model),
                                          cfg.cdtype, specs, ks[3])
    if cfg.encoder is not None:
        b["audio_frames"] = _maybe_struct(
            (batch, cfg.encoder.context, cfg.d_model), cfg.cdtype, specs,
            ks[3])
    return b


def prefill_batch(cfg: ModelConfig, seq: int, batch: int, *,
                  specs: bool = True, key=None) -> dict[str, Any]:
    b = train_batch(cfg, seq, batch, specs=specs, key=key)
    b.pop("labels", None)
    b.pop("loss_weight", None)
    return b


def decode_inputs(cfg: ModelConfig, seq: int, batch: int, *,
                  specs: bool = True, cache_dtype=jnp.bfloat16, key=None):
    """(cache, token) for a one-token serve_step with a seq-length cache."""
    from repro.models import decode as dec
    from repro.models import encdec
    init = (encdec.init_cache if cfg.encoder is not None
            else dec.init_cache)
    if specs:
        # never allocate the (possibly huge) cache on host: eval_shape only
        cache = jax.eval_shape(lambda: init(cfg, batch, seq, cache_dtype))
        token = jax.ShapeDtypeStruct((batch,), jnp.int32)
    else:
        cache = init(cfg, batch, seq, cache_dtype)
        cache["len"] = jnp.asarray(seq // 2, jnp.int32)
        token = jax.random.randint(
            jax.random.key(1) if key is None else key, (batch,), 0,
            cfg.vocab, jnp.int32)
    return cache, token
