"""gemma3-12b — 48L d3840 16H (GQA kv=8) ff15360 vocab 262144.

5:1 local:global attention interleave (window 1024), qk-norm, head_dim 256
[hf:google/gemma-3-12b]. Local layers are O(S*W) -> runs long_500k.
"""
from repro.configs.base import ArchConfig
from repro.models.transformer import ModelConfig

_PATTERN = ("attn",) * 6
_WINDOWS = (1024, 1024, 1024, 1024, 1024, None)   # 5 local : 1 global


def config() -> ModelConfig:
    return ModelConfig(
        name="gemma3-12b", d_model=3840, n_layers=48, n_heads=16,
        n_kv_heads=8, head_dim=256, d_ff=15360, vocab=262144,
        block_pattern=_PATTERN, window_pattern=_WINDOWS,
        moe_pattern=(False,) * 6, mlp="swiglu", qk_norm=True,
        rope_theta=1e6,  # global theta; local layers use 10k in HF (noted)
        param_dtype="float32", compute_dtype="bfloat16", remat="full")


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="gemma3-12b-smoke", d_model=96, n_layers=6, n_heads=4,
        n_kv_heads=2, head_dim=24, d_ff=192, vocab=512,
        block_pattern=_PATTERN, window_pattern=(16, 16, 16, 16, 16, None),
        moe_pattern=(False,) * 6, mlp="swiglu", qk_norm=True)


def arch() -> ArchConfig:
    return ArchConfig(model=config(), smoke=smoke_config(),
                      runs_long_context=True, family="dense",
                      notes="long_500k: local layers keep W=1024 ring "
                            "caches; global layers' 500k KV is sharded "
                            "over (data, model) sequence axes.")
