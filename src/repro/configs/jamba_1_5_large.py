"""jamba-1.5-large-398b — 72L d8192 64H (GQA kv=8) ff24576 vocab 65536.

Hybrid Mamba+attention 1:7 interleave with MoE (16e top-2) every second
layer [arXiv:2403.19887]: superblock of 8 = 7 mamba + 1 attn (position 4),
MoE on odd positions. SSM state is O(1) per token -> runs long_500k.
"""
from repro.configs.base import ArchConfig
from repro.models.moe import MoESpec
from repro.models.ssm import MambaSpec
from repro.models.transformer import ModelConfig

_PATTERN = ("mamba", "mamba", "mamba", "mamba", "attn", "mamba", "mamba",
            "mamba")
_MOE = (False, True, False, True, False, True, False, True)


def config() -> ModelConfig:
    return ModelConfig(
        name="jamba-1.5-large-398b", d_model=8192, n_layers=72, n_heads=64,
        n_kv_heads=8, head_dim=128, d_ff=24576, vocab=65536,
        block_pattern=_PATTERN, window_pattern=(None,) * 8,
        moe_pattern=_MOE, mlp="swiglu",
        moe=MoESpec(n_experts=16, top_k=2, d_ff=24576),
        mamba=MambaSpec(d_model=8192, expand=2, state_dim=16, conv_width=4),
        rope_theta=1e4, param_dtype="float32", compute_dtype="bfloat16",
        remat="full", ssm_chunk=256)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="jamba-smoke", d_model=64, n_layers=8, n_heads=4,
        n_kv_heads=2, head_dim=16, d_ff=128,
        vocab=512, block_pattern=_PATTERN, window_pattern=(None,) * 8,
        moe_pattern=_MOE, mlp="swiglu",
        moe=MoESpec(n_experts=4, top_k=2, d_ff=128),
        mamba=MambaSpec(d_model=64, expand=2, state_dim=8, conv_width=4),
        ssm_chunk=32)


def arch() -> ArchConfig:
    return ArchConfig(model=config(), smoke=smoke_config(),
                      runs_long_context=True, family="hybrid",
                      notes="~398B total via 36 MoE layers x 16e x "
                            "swiglu(8192->24576); ~94B active (top-2).")
