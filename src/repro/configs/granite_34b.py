"""granite-34b — 88L d6144 48H (MQA kv=1) ff24576 vocab 49152.

GPT-BigCode-lineage code model [arXiv:2405.04324]: MQA + 2-matmul GELU MLP
(param count lands at ~34B with the non-GLU MLP). Pure full attention ->
long_500k skipped (DESIGN.md §Arch-applicability).
"""
from repro.configs.base import ArchConfig
from repro.models.transformer import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="granite-34b", d_model=6144, n_layers=88, n_heads=48,
        n_kv_heads=1, head_dim=128, d_ff=24576, vocab=49152,
        mlp="mlp", fused_glu=False, rope_theta=1e4,
        param_dtype="float32", compute_dtype="bfloat16", remat="full")


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="granite-34b-smoke", d_model=128, n_layers=2, n_heads=4,
        n_kv_heads=1, head_dim=32, d_ff=256, vocab=512,
        mlp="mlp", fused_glu=False, rope_theta=1e4)


def arch() -> ArchConfig:
    return ArchConfig(model=config(), smoke=smoke_config(),
                      runs_long_context=False, family="dense",
                      notes="MQA kv=1: KV replicated over model axis; "
                            "Q heads sharded.")
