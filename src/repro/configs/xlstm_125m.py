"""xlstm-125m — 12L d768 4H, sLSTM + mLSTM blocks, vocab 50304, d_ff=0
[arXiv:2405.04517]. Superblock of 6 = 5 mLSTM + 1 sLSTM (the paper's
m:s ratio family). Attention-free, O(1) state -> runs long_500k;
the interleaved-KV technique is inapplicable (no KV cache) — noted in
DESIGN.md §Arch-applicability.
"""
from repro.configs.base import ArchConfig
from repro.models.transformer import ModelConfig
from repro.models.xlstm import XLSTMSpec

_PATTERN = ("mlstm", "mlstm", "mlstm", "mlstm", "mlstm", "slstm")


def config() -> ModelConfig:
    return ModelConfig(
        name="xlstm-125m", d_model=768, n_layers=12, n_heads=4,
        n_kv_heads=4, head_dim=192, d_ff=0, vocab=50304,
        block_pattern=_PATTERN, window_pattern=(None,) * 6,
        moe_pattern=(False,) * 6, mlp="none",
        xlstm=XLSTMSpec(d_model=768, n_heads=4),
        param_dtype="float32", compute_dtype="bfloat16", remat="full",
        ssm_chunk=128)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="xlstm-smoke", d_model=64, n_layers=6, n_heads=4,
        n_kv_heads=4, head_dim=16, d_ff=0, vocab=512,
        block_pattern=_PATTERN, window_pattern=(None,) * 6,
        moe_pattern=(False,) * 6, mlp="none",
        xlstm=XLSTMSpec(d_model=64, n_heads=4), ssm_chunk=16)


def arch() -> ArchConfig:
    return ArchConfig(model=config(), smoke=smoke_config(),
                      runs_long_context=True, family="ssm")
