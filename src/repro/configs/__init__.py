"""Arch registry: configs for the 10 assigned architectures + shape cells."""
from repro.configs.base import (ARCH_IDS, SHAPES, ArchConfig, ShapeCell,  # noqa: F401
                                cells_for, get_arch, shape_by_name)
