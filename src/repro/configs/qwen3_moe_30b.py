"""qwen3-moe-30b-a3b — 48L d2048 32H (GQA kv=4), MoE 128e top-8 expert-ff
768, vocab 151936, qk-norm [hf:Qwen/Qwen3-30B-A3B]. The EARTH dispatch
stress case: 128 experts, top-8 (1M routed units per train step).
Full attention -> long_500k skipped.
"""
from repro.configs.base import ArchConfig
from repro.models.moe import MoESpec
from repro.models.transformer import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-moe-30b-a3b", d_model=2048, n_layers=48, n_heads=32,
        n_kv_heads=4, head_dim=128, d_ff=768, vocab=151936,
        block_pattern=("attn",), moe_pattern=(True,), mlp="swiglu",
        moe=MoESpec(n_experts=128, top_k=8, d_ff=768), qk_norm=True,
        rope_theta=1e6, tie_embeddings=False,
        param_dtype="float32", compute_dtype="bfloat16", remat="full")


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-moe-smoke", d_model=64, n_layers=2, n_heads=4,
        n_kv_heads=2, head_dim=16, d_ff=64, vocab=512,
        block_pattern=("attn",), moe_pattern=(True,), mlp="swiglu",
        moe=MoESpec(n_experts=8, top_k=2, d_ff=64), qk_norm=True,
        tie_embeddings=False)


def arch() -> ArchConfig:
    return ArchConfig(model=config(), smoke=smoke_config(),
                      runs_long_context=False, family="moe",
                      notes="128e/16 shards -> 8 experts per device; "
                            "ragged grouped GEMM after EARTH compaction.")
