"""starcoder2-3b — 30L d3072 24H (GQA kv=2) ff12288 vocab 49152.

GQA + RoPE, 2-matmul GELU MLP [arXiv:2402.19173]. Full attention ->
long_500k skipped.
"""
from repro.configs.base import ArchConfig
from repro.models.transformer import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="starcoder2-3b", d_model=3072, n_layers=30, n_heads=24,
        n_kv_heads=2, head_dim=128, d_ff=12288, vocab=49152,
        mlp="mlp", fused_glu=False, rope_theta=999999.0,
        param_dtype="float32", compute_dtype="bfloat16", remat="full")


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="starcoder2-3b-smoke", d_model=96, n_layers=2, n_heads=6,
        n_kv_heads=2, head_dim=16, d_ff=192, vocab=512,
        mlp="mlp", fused_glu=False)


def arch() -> ArchConfig:
    return ArchConfig(model=config(), smoke=smoke_config(),
                      runs_long_context=False, family="dense")
