"""whisper-tiny — enc-dec 4+4L d384 6H ff1536 vocab 51865 [arXiv:2212.04356].

Conv/mel frontend is a STUB: input_specs provides precomputed frame
embeddings (B, 1500, 384). Backbone exercised per the assignment; decoder
has causal self-attn (interleaved KV cache) + cross-attn over cached
encoder K/V. Full attention -> long_500k skipped.
"""
from repro.configs.base import ArchConfig
from repro.models.transformer import EncoderSpec, ModelConfig


def config() -> ModelConfig:
    # vocab padded 51865 -> 51968 (multiple of 256) so the unembedding can
    # shard over the 16-way model axis — standard Megatron-style padding.
    return ModelConfig(
        name="whisper-tiny", d_model=384, n_layers=4, n_heads=6,
        n_kv_heads=6, head_dim=64, d_ff=1536, vocab=51968,
        mlp="mlp", fused_glu=False, rope_theta=1e4,
        encoder=EncoderSpec(n_layers=4, context=1500),
        param_dtype="float32", compute_dtype="bfloat16", remat="full")


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="whisper-tiny-smoke", d_model=64, n_layers=2, n_heads=4,
        n_kv_heads=4, head_dim=16, d_ff=128, vocab=512,
        mlp="mlp", fused_glu=False,
        encoder=EncoderSpec(n_layers=2, context=64))


def arch() -> ArchConfig:
    return ArchConfig(model=config(), smoke=smoke_config(),
                      runs_long_context=False, family="audio",
                      notes="RMSNorm + sinusoidal positions instead of "
                            "whisper's LayerNorm/learned-pos (backbone "
                            "stub; noted deviation).")
