"""Roofline-term extraction from compiled XLA artifacts (TPU v5e target).

Three terms per (arch x shape x mesh), in seconds-per-step per chip:

    compute    = HLO_FLOPs / peak_bf16_flops
    memory     = HLO_bytes / hbm_bandwidth
    collective = wire_bytes / ici_link_bandwidth      (assignment formula)

Corrections applied (measured on this repo's JAX/XLA, see DESIGN.md §11):

* ``cost_analysis()`` counts a scanned loop body ONCE, not x trip-count.
  We therefore lower the model UNROLLED with 1 and 2 superblocks (D1, D2):
  body = D2 - D1, fixed = D1 - body, total = fixed + NS * body.
  The same correction applies to collective bytes parsed from HLO.
* Costs are PER DEVICE (post-SPMD shapes), which is what the per-chip
  roofline wants.
* Wire factors: all-reduce counts 2x its bytes (reduce-scatter +
  all-gather phases); others 1x (asymptotic ring factors).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any

from repro import hw

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
}

_COLL_OPS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
             "collective-permute")
_WIRE_FACTOR = {"all-reduce": 2.0, "all-gather": 1.0, "reduce-scatter": 1.0,
                "all-to-all": 1.0, "collective-permute": 1.0}
_SHAPE_RE = re.compile(r"(pred|[sfu](?:8|16|32|64)|bf16)\[([0-9,]*)\]")


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict[str, float]:
    """Wire bytes per collective op kind, parsed from (per-device) HLO."""
    out = {k: 0.0 for k in _COLL_OPS}
    for line in hlo_text.splitlines():
        for op in _COLL_OPS:
            marker = f" {op}("
            if marker in line and "=" in line:
                # "-start(" variants (async) — count starts, skip "-done"
                lhs = line.split(marker)[0]
                shape_part = lhs.split("=", 1)[-1]
                out[op] += _shape_bytes(shape_part) * _WIRE_FACTOR[op]
                break
        else:
            # async forms: all-reduce-start / all-gather-start etc.
            for op in _COLL_OPS:
                if f" {op}-start(" in line and "=" in line:
                    lhs = line.split(f" {op}-start(")[0]
                    shape_part = lhs.split("=", 1)[-1]
                    out[op] += _shape_bytes(shape_part) * _WIRE_FACTOR[op]
                    break
    return out


@dataclasses.dataclass
class CostBundle:
    flops: float            # per device
    bytes_accessed: float   # per device
    coll_bytes: float       # per device wire bytes
    coll_breakdown: dict[str, float]

    def __sub__(self, o: "CostBundle") -> "CostBundle":
        return CostBundle(
            self.flops - o.flops, self.bytes_accessed - o.bytes_accessed,
            self.coll_bytes - o.coll_bytes,
            {k: self.coll_breakdown.get(k, 0) - o.coll_breakdown.get(k, 0)
             for k in set(self.coll_breakdown) | set(o.coll_breakdown)})

    def scaled_add(self, o: "CostBundle", k: float) -> "CostBundle":
        return CostBundle(
            self.flops + k * o.flops,
            self.bytes_accessed + k * o.bytes_accessed,
            self.coll_bytes + k * o.coll_bytes,
            {key: self.coll_breakdown.get(key, 0)
             + k * o.coll_breakdown.get(key, 0)
             for key in set(self.coll_breakdown) | set(o.coll_breakdown)})


def bundle_from_compiled(compiled) -> CostBundle:
    ca = compiled.cost_analysis()
    if not isinstance(ca, dict):  # older jax returns [dict]
        ca = ca[0]
    text = compiled.as_text()
    colls = collective_bytes(text)
    return CostBundle(
        flops=float(ca.get("flops", 0.0)),
        bytes_accessed=float(ca.get("bytes accessed", 0.0)),
        coll_bytes=float(sum(colls.values())),
        coll_breakdown=colls)


@dataclasses.dataclass
class RooflineTerms:
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    model_flops: float          # analytic 6ND (train) / 2ND (serve), global
    hlo_flops_global: float
    useful_ratio: float         # model_flops / hlo_flops_global

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


def roofline(bundle: CostBundle, *, chips: int, model_flops: float,
             chip: hw.ChipSpec = hw.DEFAULT_CHIP) -> RooflineTerms:
    compute = bundle.flops / chip.peak_bf16_flops
    memory = bundle.bytes_accessed / chip.hbm_bandwidth
    coll = bundle.coll_bytes / chip.ici_link_bandwidth
    terms = {"compute": compute, "memory": memory, "collective": coll}
    dominant = max(terms, key=terms.get)
    hlo_global = bundle.flops * chips
    return RooflineTerms(
        compute_s=compute, memory_s=memory, collective_s=coll,
        dominant=dominant, model_flops=model_flops,
        hlo_flops_global=hlo_global,
        useful_ratio=(model_flops / hlo_global) if hlo_global else 0.0)


# ---------------------------------------------------------------------------
# Analytic MODEL_FLOPS (6 N_active D for train, 2 N_active D for serving).
# ---------------------------------------------------------------------------

def active_param_count(cfg) -> int:
    """Parameters touched per token: MoE experts counted top_k/E."""
    import jax
    from repro.models.transformer import init_params
    shapes = jax.eval_shape(lambda: init_params(cfg, jax.random.key(0)))
    total = 0
    flat = jax.tree_util.tree_flatten_with_path(shapes)[0]
    for kp, leaf in flat:
        path = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                        for k in kp)
        n = 1
        for d in leaf.shape:
            n *= d
        if "/moe/w" in path:   # expert weights: only top_k of n_experts run
            n = n * cfg.moe.top_k // cfg.moe.n_experts
        if path.startswith("embed") or path.startswith("lm_head"):
            continue           # lookup + head counted separately if desired
        total += n
    return total


def model_flops(cfg, *, tokens: int, kind: str) -> float:
    n = active_param_count(cfg)
    per_token = 6 * n if kind == "train" else 2 * n
    return float(per_token) * tokens
