"""Roofline extraction from compiled HLO (TPU v5e target constants)."""
from repro.roofline.analysis import (CostBundle, RooflineTerms,  # noqa: F401
                                     bundle_from_compiled, collective_bytes,
                                     model_flops, roofline)
