"""Error-feedback gradient compression for data-parallel reduction.

Two compressors (both with per-tensor error feedback, the standard fix that
keeps compressed SGD convergent):

* int8: symmetric per-tensor quantization (32x -> 8x bytes on the wire,
  4x reduction of DP all-reduce bytes),
* topk: keep the largest |g| fraction, zero the rest (sparse push).

On a real fleet these wrap the DP all-reduce inside shard_map (compress ->
psum -> decompress); here the compressors + EF state are exercised by the
microbatch-accumulation loop in train/step.py and property-tested
(EF residual => unbiased over time) in tests/test_optim.py.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.core import quant


@dataclasses.dataclass(frozen=True)
class CompressionConfig:
    kind: str = "none"        # none | int8 | topk
    topk_frac: float = 0.05


def init_error_state(params: Any) -> Any:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def _int8_roundtrip(g: jax.Array) -> jax.Array:
    # per-tensor symmetric int8 wire format (shared with the quantized
    # paged KV pool — repro/core/quant.py)
    return quant.roundtrip(g, jnp.int8, eps=1e-12)


def _topk_roundtrip(g: jax.Array, frac: float) -> jax.Array:
    flat = g.reshape(-1)
    k = max(1, int(flat.shape[0] * frac))
    thresh = jax.lax.top_k(jnp.abs(flat), k)[0][-1]
    return jnp.where(jnp.abs(g) >= thresh, g, 0.0)


def compress_with_feedback(grads: Any, err: Any, cfg: CompressionConfig
                           ) -> tuple[Any, Any, dict]:
    """Returns (decompressed grads as sent on the wire, new error state,
    metrics). Identity when kind == 'none'."""
    if cfg.kind == "none":
        return grads, err, {"compression_error": jnp.zeros(())}

    def one(g, e):
        g32 = g.astype(jnp.float32) + e
        if cfg.kind == "int8":
            sent = _int8_roundtrip(g32)
        elif cfg.kind == "topk":
            sent = _topk_roundtrip(g32, cfg.topk_frac)
        else:
            raise ValueError(cfg.kind)
        return sent, g32 - sent

    pairs = jax.tree.map(one, grads, err)
    sent = jax.tree.map(lambda t: t[0], pairs,
                        is_leaf=lambda t: isinstance(t, tuple))
    new_err = jax.tree.map(lambda t: t[1], pairs,
                           is_leaf=lambda t: isinstance(t, tuple))
    err_norm = jnp.sqrt(sum(jnp.sum(jnp.square(x))
                            for x in jax.tree.leaves(new_err)))
    return sent, new_err, {"compression_error": err_norm}


def wire_bytes_ratio(cfg: CompressionConfig) -> float:
    """Bytes-on-wire ratio vs fp32 all-reduce (for the roofline collective
    term when compression is enabled)."""
    if cfg.kind == "int8":
        return 0.25
    if cfg.kind == "topk":
        return cfg.topk_frac * 2.0  # value + index
    return 1.0
