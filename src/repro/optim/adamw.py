"""AdamW + schedules + global-norm clipping, pure JAX (no optax).

Optimizer state is a pytree mirroring params; ZeRO-1 sharding is applied by
the train-step builder by giving m/v (and fp32 master params) extra
data-axis sharding (see train/step.py)."""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


def schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    """Linear warmup + cosine decay to min_lr_ratio."""
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 \
        * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, cos)


def global_norm(tree: Any) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(grads: Any, max_norm: float) -> tuple[Any, jax.Array]:
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), grads), norm


def init_opt_state(params: Any) -> dict:
    zeros = lambda p: jax.tree.map(
        lambda x: jnp.zeros(x.shape, jnp.float32), p)
    return {"m": zeros(params), "v": zeros(params),
            "step": jnp.zeros((), jnp.int32)}


def _decay_mask(path: str) -> bool:
    """No weight decay on norms/biases/1-D scales."""
    return not any(t in path for t in ("norm", "scale", "bias", "ln", "fb",
                                       "dt_bias", "D"))


def apply_updates(params: Any, grads: Any, opt: dict, cfg: AdamWConfig
                  ) -> tuple[Any, dict, dict]:
    """Returns (new_params, new_opt_state, metrics)."""
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    step = opt["step"] + 1
    lr = schedule(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    flat_p = jax.tree_util.tree_flatten_with_path(params)[0]
    paths = ["/".join(getattr(k, "key", str(k)) for k in kp)
             for kp, _ in flat_p]
    treedef = jax.tree_util.tree_structure(params)
    wd_tree = jax.tree_util.tree_unflatten(
        treedef, [cfg.weight_decay if _decay_mask(p) else 0.0 for p in paths])

    def upd(p, g, m, v, wd):
        g = g.astype(jnp.float32)
        p32 = p.astype(jnp.float32)
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        mhat = m / b1c
        vhat = v / b2c
        step_dir = mhat / (jnp.sqrt(vhat) + cfg.eps) + wd * p32
        return (p32 - lr * step_dir).astype(p.dtype), m, v

    new = jax.tree.map(upd, params, grads, opt["m"], opt["v"], wd_tree)
    new_params = jax.tree.map(lambda t: t[0], new,
                              is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree.map(lambda t: t[1], new,
                         is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree.map(lambda t: t[2], new,
                         is_leaf=lambda t: isinstance(t, tuple))
    return (new_params, {"m": new_m, "v": new_v, "step": step},
            {"grad_norm": gnorm, "lr": lr})
