"""Optimizer substrate: AdamW, schedules, gradient compression."""
from repro.optim.adamw import (AdamWConfig, apply_updates, init_opt_state,  # noqa: F401
                               schedule)
from repro.optim.compression import CompressionConfig, compress_with_feedback  # noqa: F401
