"""Sharded, atomic, async checkpointing.

Layout: ``<dir>/step_<N>/`` containing one ``proc<k>.npz`` per host process
plus ``manifest.json`` (step, tree structure, shapes/dtypes, partition
specs, data-pipeline state). Writes go to ``step_<N>.tmp`` and are renamed
only after fsync — a crash mid-save never corrupts the latest checkpoint.
Saving runs on a background thread (off the training critical path);
``wait()`` joins it. Restore is mesh-agnostic: arrays are re-placed with the
CURRENT mesh's NamedShardings (elastic rescale — see ft/elastic.py).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np


class CheckpointError(RuntimeError):
    """A checkpoint step dir is torn or corrupted (missing
    ``manifest.json``, truncated ``.npz``, missing arrays), or no intact
    checkpoint exists at all.  Typed so callers can catch restore
    failures without fishing ``JSONDecodeError`` / ``OSError`` /
    ``KeyError`` out of the storage layer."""


def _flatten(tree: Any) -> dict[str, np.ndarray]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for kp, leaf in flat:
        path = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                        for k in kp)
        out[path] = np.asarray(leaf)
    return out


def _paths_and_treedef(tree: Any):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    paths = ["/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                      for k in kp) for kp, _ in flat]
    return paths, treedef


class CheckpointManager:
    def __init__(self, directory: str, *, process_index: int = 0,
                 keep: int = 3):
        self.dir = directory
        self.process_index = process_index
        self.keep = keep
        self._thread: threading.Thread | None = None
        os.makedirs(directory, exist_ok=True)

    # -- save ---------------------------------------------------------------
    def save(self, step: int, tree: Any, *, extra: dict | None = None,
             blocking: bool = False) -> None:
        """Snapshot to host memory synchronously, write asynchronously."""
        arrays = _flatten(tree)  # device->host copy happens here
        self.wait()
        self._thread = threading.Thread(
            target=self._write, args=(step, arrays, extra or {}), daemon=True)
        self._thread.start()
        if blocking:
            self.wait()

    def _write(self, step: int, arrays: dict[str, np.ndarray],
               extra: dict) -> None:
        final = os.path.join(self.dir, f"step_{step:08d}")
        tmp = final + f".tmp{self.process_index}"
        os.makedirs(tmp, exist_ok=True)
        np.savez(os.path.join(tmp, f"proc{self.process_index}.npz"), **arrays)
        manifest = {
            "step": step,
            "paths": sorted(arrays),
            "shapes": {k: list(v.shape) for k, v in arrays.items()},
            "dtypes": {k: str(v.dtype) for k, v in arrays.items()},
            "extra": extra,
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        if os.path.isdir(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        self._gc()

    def wait(self) -> None:
        if self._thread is not None and self._thread.is_alive():
            self._thread.join()
        self._thread = None

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"),
                          ignore_errors=True)

    # -- restore --------------------------------------------------------------
    def all_steps(self) -> list[int]:
        out = []
        for d in os.listdir(self.dir):
            if d.startswith("step_") and not d.endswith(
                    tuple(f".tmp{i}" for i in range(1024))):
                try:
                    out.append(int(d.split("_")[1]))
                except ValueError:
                    pass
        return sorted(out)

    def latest_step(self, *, intact: bool = False) -> int | None:
        """Newest step by directory listing; ``intact=True`` additionally
        verifies the step dir is readable (manifest parses, this
        process's ``.npz`` loads) and skips torn ones."""
        steps = self.all_steps()
        if not intact:
            return steps[-1] if steps else None
        for s in reversed(steps):
            try:
                self._read_step(s)
            except CheckpointError:
                continue
            return s
        return None

    def _read_step(self, step: int) -> tuple[dict, Any]:
        """(manifest, npz) of one step dir; :class:`CheckpointError` on a
        torn or corrupted dir instead of raw ``JSONDecodeError`` /
        ``OSError`` / ``zipfile.BadZipFile``."""
        d = os.path.join(self.dir, f"step_{step:08d}")
        try:
            with open(os.path.join(d, "manifest.json")) as f:
                manifest = json.load(f)
            data = np.load(os.path.join(d, f"proc{self.process_index}.npz"))
            # touch the member list now: a truncated zip can open fine
            # and only fail when an array is first read
            data.files  # noqa: B018
        except CheckpointError:
            raise
        except Exception as e:  # noqa: BLE001 — typed storage boundary
            raise CheckpointError(
                f"checkpoint step {step} at {d} is torn or corrupted: "
                f"{type(e).__name__}: {e}") from e
        return manifest, data

    def restore(self, template: Any, *, step: int | None = None,
                shardings: Any = None) -> tuple[Any, dict]:
        """Restore into the structure of ``template``; optionally place with
        per-leaf ``shardings`` (pytree of NamedSharding) — the elastic path.

        ``step=None`` restores the newest INTACT step: a torn latest dir
        (crash mid-write that survived the atomic-rename discipline, a
        bad disk) falls back to the next-newest step that loads cleanly.
        An explicit ``step`` is restored exactly or raises
        :class:`CheckpointError` — falling back silently from a step the
        caller named would be wrong."""
        if step is not None:
            manifest, data = self._read_step(step)
        else:
            steps = self.all_steps()
            if not steps:
                raise CheckpointError(f"no checkpoint found in {self.dir}")
            last_err: CheckpointError | None = None
            for s in reversed(steps):
                try:
                    manifest, data = self._read_step(s)
                    break
                except CheckpointError as e:
                    last_err = e
            else:
                raise CheckpointError(
                    f"no intact checkpoint in {self.dir} "
                    f"(tried steps {steps})") from last_err
        paths, treedef = _paths_and_treedef(template)
        shard_leaves = (jax.tree_util.tree_flatten(shardings)[0]
                        if shardings is not None else [None] * len(paths))
        leaves = []
        try:
            for p, s in zip(paths, shard_leaves):
                arr = data[p]
                leaves.append(jax.device_put(arr, s) if s is not None
                              else jnp.asarray(arr))
        except Exception as e:  # noqa: BLE001 — truncated member payload
            raise CheckpointError(
                f"checkpoint step {manifest.get('step', '?')} is torn or "
                f"corrupted: {type(e).__name__}: {e}") from e
        return jax.tree_util.tree_unflatten(treedef, leaves), manifest["extra"]
