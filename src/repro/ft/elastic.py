"""Elastic rescale: resume a run on a different mesh.

Checkpoints store *logical* (unsharded) arrays + the sharding RULES live in
code (dist/sharding.py), so restoring onto a new mesh is: rebuild specs for
the new mesh -> device_put each leaf with its new NamedSharding. Data-
parallel degree changes freely; the data pipeline state (two ints) is
host-count independent (each host re-derives its slice of the global
batch). Tested 8-dev (2,4) -> (4,2) in tests/test_ft.py.
"""
from __future__ import annotations

from typing import Any

import jax

from repro.dist.sharding import ShardCtx, tree_param_specs
from repro.ft.checkpoint import CheckpointManager


def reshard_template(template: Any, ctx: ShardCtx) -> Any:
    """Pytree of NamedShardings for ``template`` under ``ctx``'s mesh."""
    if ctx.mesh is None:
        return None
    specs = tree_param_specs(template, ctx)
    return jax.tree.map(lambda s: ctx.sharding(s), specs,
                        is_leaf=lambda x: isinstance(
                            x, jax.sharding.PartitionSpec))


def restore_elastic(mgr: CheckpointManager, template: Any, ctx: ShardCtx,
                    *, step: int | None = None) -> tuple[Any, dict]:
    """Restore a checkpoint onto (possibly different) mesh ``ctx.mesh``."""
    shardings = reshard_template(template, ctx)
    return mgr.restore(template, step=step, shardings=shardings)
