"""Deadline-based straggler mitigation (coordinator-side logic).

At fleet scale the slowest host sets the step time (synchronous SPMD). The
policy here implements the standard mitigation: track per-host step
durations, declare hosts exceeding ``factor x`` the rolling median as
stragglers, and exclude them for a cooldown window — on a real fleet the
exclusion maps to (a) skipping their gradient contribution (scaling the DP
denominator) or (b) triggering elastic restart without them (ft/elastic).

Pure Python with an injectable clock so the logic is unit-testable without
hardware; tests/test_ft.py simulates straggling hosts and asserts
detection, cooldown, and recovery.
"""
from __future__ import annotations

import dataclasses
import statistics
from collections import defaultdict, deque


@dataclasses.dataclass(frozen=True)
class StragglerConfig:
    window: int = 16           # rolling history per host
    factor: float = 2.0        # slow if > factor * median
    cooldown_steps: int = 8    # exclusion length
    min_history: int = 4       # steps before judging
    max_excluded_frac: float = 0.25


class StragglerPolicy:
    def __init__(self, n_hosts: int, cfg: StragglerConfig = StragglerConfig()):
        self.n_hosts = n_hosts
        self.cfg = cfg
        self._hist: dict[int, deque] = defaultdict(
            lambda: deque(maxlen=cfg.window))
        self._excluded_until: dict[int, int] = {}
        self._step = 0

    def record_step(self, durations: dict[int, float]) -> None:
        """durations: host -> seconds for this step (missing = no report,
        treated as infinitely slow)."""
        self._step += 1
        for h in range(self.n_hosts):
            if h in durations:
                self._hist[h].append(durations[h])
            else:
                self._hist[h].append(float("inf"))
        self._update_exclusions()

    def _update_exclusions(self) -> None:
        cfg = self.cfg
        meds = []
        for h in range(self.n_hosts):
            if len(self._hist[h]) >= cfg.min_history:
                finite = [d for d in self._hist[h] if d != float("inf")]
                if finite:
                    meds.append(statistics.median(finite))
        if not meds:
            return
        global_med = statistics.median(meds)
        budget = int(self.n_hosts * cfg.max_excluded_frac)
        current = {h for h, until in self._excluded_until.items()
                   if until > self._step}
        for h in range(self.n_hosts):
            if len(self._hist[h]) < cfg.min_history or h in current:
                continue
            recent = list(self._hist[h])[-cfg.min_history:]
            slow = all(d > cfg.factor * global_med for d in recent)
            if slow and len(current) < budget:
                self._excluded_until[h] = self._step + cfg.cooldown_steps
                current.add(h)

    def excluded(self) -> set[int]:
        return {h for h, until in self._excluded_until.items()
                if until > self._step}

    def active_hosts(self) -> list[int]:
        ex = self.excluded()
        return [h for h in range(self.n_hosts) if h not in ex]

    def gradient_scale(self) -> float:
        """Rescale factor for the DP mean when hosts are excluded."""
        return self.n_hosts / max(1, len(self.active_hosts()))
