"""Deadline-based straggler mitigation (coordinator-side logic).

At fleet scale the slowest host sets the step time (synchronous SPMD). The
policy here implements the standard mitigation: track per-host step
durations, declare hosts exceeding ``factor x`` the rolling median as
stragglers, and exclude them for a cooldown window — on a real fleet the
exclusion maps to (a) skipping their gradient contribution (scaling the DP
denominator) or (b) triggering elastic restart without them (ft/elastic).

Pure Python with an injectable clock so the logic is unit-testable without
hardware; tests/test_ft.py simulates straggling hosts and asserts
detection, cooldown, and recovery.
"""
from __future__ import annotations

import dataclasses
import statistics
from collections import defaultdict, deque


@dataclasses.dataclass(frozen=True)
class StragglerConfig:
    window: int = 16           # rolling history per host
    factor: float = 2.0        # slow if > factor * median
    cooldown_steps: int = 8    # exclusion length
    min_history: int = 4       # steps before judging
    max_excluded_frac: float = 0.25


class StragglerPolicy:
    def __init__(self, n_hosts: int, cfg: StragglerConfig = StragglerConfig()):
        self.n_hosts = n_hosts
        self.cfg = cfg
        self._hist: dict[int, deque] = defaultdict(
            lambda: deque(maxlen=cfg.window))
        self._excluded_until: dict[int, int] = {}
        self._step = 0

    def record_step(self, durations: dict[int, float]) -> None:
        """durations: host -> seconds for this step (missing = no report,
        treated as infinitely slow)."""
        self._step += 1
        for h in range(self.n_hosts):
            if h in durations:
                self._hist[h].append(durations[h])
            else:
                self._hist[h].append(float("inf"))
        self._update_exclusions()

    def _update_exclusions(self) -> None:
        cfg = self.cfg
        meds = []
        for h in range(self.n_hosts):
            if len(self._hist[h]) >= cfg.min_history:
                finite = [d for d in self._hist[h] if d != float("inf")]
                if finite:
                    meds.append(statistics.median(finite))
        if not meds:
            return
        global_med = statistics.median(meds)
        budget = int(self.n_hosts * cfg.max_excluded_frac)
        current = {h for h, until in self._excluded_until.items()
                   if until > self._step}
        for h in range(self.n_hosts):
            if len(self._hist[h]) < cfg.min_history or h in current:
                continue
            recent = list(self._hist[h])[-cfg.min_history:]
            slow = all(d > cfg.factor * global_med for d in recent)
            if slow and len(current) < budget:
                self._excluded_until[h] = self._step + cfg.cooldown_steps
                current.add(h)

    def excluded(self) -> set[int]:
        return {h for h, until in self._excluded_until.items()
                if until > self._step}

    def active_hosts(self) -> list[int]:
        ex = self.excluded()
        return [h for h in range(self.n_hosts) if h not in ex]

    def gradient_scale(self) -> float:
        """Rescale factor for the DP mean when hosts are excluded."""
        return self.n_hosts / max(1, len(self.active_hosts()))


class StepWatchdog:
    """The StragglerPolicy deadline rule applied to ONE serving replica's
    step wall times: a step is a BREACH when it exceeds ``factor`` x the
    rolling median of recent steps (after ``min_history`` observations),
    or ``hard_limit`` seconds outright.  The serve scheduler records each
    decode step's duration; breaches are counted and surfaced (stats /
    chaos reports) rather than raised — a slow step is a symptom to act
    on (preempt, shed load), not a crash.  The fleet router
    (serve/fleet.py) reads ``hard_breaches`` as a health signal: a
    replica repeatedly blowing the hard limit is DEGRADED and drained.

    Breaching steps are excluded from the history so a stall cannot drag
    the median up and mask itself — but a replica that LEGITIMATELY
    settles into a slower regime (longer contexts, a busier host) would
    then breach forever: the median never sees the new regime.  After
    ``rebaseline_after`` CONSECUTIVE median breaches the window is
    re-baselined onto those breaching durations (the new regime becomes
    the baseline) and ``regime_shifts`` counts the event.  Hard-limit
    breaches never re-baseline — the hard limit is an absolute SLO, not
    a relative one.

    Pure host Python with the same injectable-measurement design as
    :class:`StragglerPolicy` (callers time the step and pass the
    duration), so the policy is unit-testable without wall time.
    """

    def __init__(self, cfg: StragglerConfig = StragglerConfig(), *,
                 hard_limit: float | None = None,
                 rebaseline_after: int = 8):
        if rebaseline_after < 1:
            raise ValueError(f"rebaseline_after must be >= 1, "
                             f"got {rebaseline_after}")
        self.cfg = cfg
        self.hard_limit = hard_limit
        self.rebaseline_after = rebaseline_after
        self._hist: deque = deque(maxlen=cfg.window)
        # the last run of consecutive median-breaching durations — the
        # candidate new baseline if the run reaches rebaseline_after
        self._breach_run: deque = deque(maxlen=max(cfg.window,
                                                   rebaseline_after))
        self.breaches = 0
        self.hard_breaches = 0
        self.observations = 0
        self.regime_shifts = 0
        self.last_breach: float | None = None

    def median(self) -> float | None:
        finite = [d for d in self._hist if d != float("inf")]
        return statistics.median(finite) if finite else None

    def deadline(self) -> float | None:
        """The current per-step budget, or None before enough history."""
        if self.hard_limit is not None:
            return self.hard_limit
        return self._median_deadline()

    def _median_deadline(self) -> float | None:
        if len(self._hist) < self.cfg.min_history:
            return None
        med = self.median()
        return self.cfg.factor * med if med is not None else None

    def observe(self, duration: float) -> bool:
        """Record one step's wall time; True when it breached the
        deadline (hard limit or factor x rolling median)."""
        self.observations += 1
        hard = self.hard_limit is not None and duration > self.hard_limit
        med_limit = self._median_deadline()
        med_breach = med_limit is not None and duration > med_limit
        if hard:
            self.hard_breaches += 1
        if hard or med_breach:
            self.breaches += 1
            self.last_breach = duration
        if med_breach:
            self._breach_run.append(duration)
            if len(self._breach_run) >= self.rebaseline_after:
                # the "stall" is the steady state now: adopt it
                self.regime_shifts += 1
                self._hist.clear()
                self._hist.extend(self._breach_run)
                self._breach_run.clear()
        else:
            self._breach_run.clear()
            if not hard:
                self._hist.append(duration)
        return hard or med_breach
