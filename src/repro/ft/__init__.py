"""Fault tolerance: atomic async checkpointing, elastic rescale,
straggler mitigation, per-replica step watchdog."""
from repro.ft.checkpoint import (CheckpointError,  # noqa: F401
                                 CheckpointManager)
from repro.ft.elastic import restore_elastic  # noqa: F401
from repro.ft.straggler import (StepWatchdog, StragglerConfig,  # noqa: F401
                                StragglerPolicy)
