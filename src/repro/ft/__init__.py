"""Fault tolerance: atomic async checkpointing, elastic rescale,
straggler mitigation."""
from repro.ft.checkpoint import CheckpointManager  # noqa: F401
from repro.ft.elastic import restore_elastic  # noqa: F401
from repro.ft.straggler import StragglerConfig, StragglerPolicy  # noqa: F401
