"""Training CLI: end-to-end driver over the full substrate.

Runs any ``--arch`` (full or smoke geometry) with the synthetic AoS data
pipeline, AdamW, checkpointing (async, atomic), straggler policy hooks and
optional gradient compression / microbatching.

Recommended XLA flags on real TPU fleets (overlap compute/collectives):
  --xla_tpu_enable_latency_hiding_scheduler=true
  --xla_tpu_enable_async_collective_fusion=true
  --xla_tpu_overlap_compute_collective_tc=true

Example (CPU, reduced geometry):
  PYTHONPATH=src python -m repro.launch.train --arch qwen3-0.6b --smoke \
      --steps 20 --batch 8 --seq 128
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_arch
from repro.data.pipeline import DataConfig, SyntheticAoSPipeline
from repro.dist.sharding import local_ctx
from repro.ft.checkpoint import CheckpointManager
from repro.ft.straggler import StragglerPolicy
from repro.launch.mesh import make_ctx
from repro.optim.adamw import AdamWConfig
from repro.optim.compression import CompressionConfig
from repro.train.step import (TrainConfig, init_full_state, jit_train_step)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced same-family geometry (CPU-sized)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--compression", default="none",
                    choices=["none", "int8", "topk"])
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--mesh", default="none",
                    choices=["none", "single-pod", "multi-pod"],
                    help="production meshes need 256/512 devices (dry-run)")
    args = ap.parse_args()

    arch = get_arch(args.arch)
    cfg = arch.smoke if args.smoke else arch.model
    if args.mesh == "none":
        ctx = local_ctx()
    else:
        from repro.launch.mesh import make_production_mesh
        ctx = make_ctx(make_production_mesh(
            multi_pod=args.mesh == "multi-pod"))

    tcfg = TrainConfig(
        optimizer=AdamWConfig(lr=args.lr, total_steps=args.steps,
                              warmup_steps=max(1, args.steps // 10)),
        microbatches=args.microbatches,
        compression=CompressionConfig(kind=args.compression))

    pipe = SyntheticAoSPipeline(
        DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                   global_batch=args.batch),
        process_index=jax.process_index(),
        process_count=jax.process_count())
    straggler = StragglerPolicy(n_hosts=jax.process_count())
    mgr = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None

    state = init_full_state(cfg, tcfg, jax.random.key(0))
    start_step = 0
    if mgr and args.resume and mgr.latest_step() is not None:
        state, extra = mgr.restore(state)
        pipe.load_state_dict(extra["pipeline"])
        start_step = extra["step"]
        print(f"resumed from step {start_step}")

    batch0 = pipe.next_batch()
    step_fn = jit_train_step(cfg, tcfg, ctx, state, batch0)
    pipe.load_state_dict({"step": pipe.state.step - 1,
                          "seed": pipe.state.seed})  # rewind the peek

    for step in range(start_step, args.steps):
        t0 = time.time()
        batch = pipe.next_batch()
        state, metrics = step_fn(state, batch)
        dt = time.time() - t0
        straggler.record_step({jax.process_index(): dt})
        if step % 5 == 0 or step == args.steps - 1:
            print(f"step {step:5d} loss={float(metrics['loss']):.4f} "
                  f"gnorm={float(metrics['grad_norm']):.3f} "
                  f"lr={float(metrics['lr']):.2e} {dt*1e3:.0f}ms "
                  f"excluded_hosts={sorted(straggler.excluded())}",
                  flush=True)
        if mgr and (step + 1) % args.ckpt_every == 0:
            mgr.save(step + 1, state,
                     extra={"step": step + 1,
                            "pipeline": pipe.state_dict()})
    if mgr:
        mgr.save(args.steps, state,
                 extra={"step": args.steps, "pipeline": pipe.state_dict()},
                 blocking=True)
    print("done; final loss", float(metrics["loss"]))


if __name__ == "__main__":
    main()
