"""Serving CLI: paged continuous batching (prefill + decode + sampling)
through the hardened request lifecycle (typed requests, deadlines,
preemption-and-restore, runtime guards), with an optional chaos mode.

Example (CPU, reduced geometry):
  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b --smoke \
      --requests 4 --prompt-len 16 --gen 12 --page-size 16 \
      --temperature 0.8 --top-k 40

Chaos smoke (seeded fault plan, invariants audited every tick):
  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b --smoke \
      --chaos 0 --requests 6 --gen 6
"""
from __future__ import annotations

import argparse
import time

import jax

from repro.configs import get_arch
from repro.ft.straggler import StepWatchdog
from repro.models.transformer import init_params
from repro.serve.engine import BatchedServer


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="0 = greedy (default)")
    ap.add_argument("--top-k", type=int, default=None)
    ap.add_argument("--deadline", type=float, default=None,
                    help="per-request TTL in seconds (TIMED_OUT beyond)")
    ap.add_argument("--queue-depth", type=int, default=None,
                    help="admission queue bound (backpressure beyond)")
    ap.add_argument("--check-invariants", action="store_true",
                    help="audit the page pool after every mutation")
    ap.add_argument("--guard-nan", action="store_true",
                    help="fail (only) slots producing non-finite logits")
    ap.add_argument("--chaos", type=int, default=None, metavar="SEED",
                    help="run a seeded fault plan instead of clean serving")
    args = ap.parse_args()

    arch = get_arch(args.arch)
    cfg = arch.smoke if args.smoke else arch.model
    if cfg.encoder is not None:
        raise SystemExit("use whisper example for enc-dec serving")
    params = init_params(cfg, jax.random.key(0))
    server = BatchedServer(cfg, params, slots=args.requests,
                           max_len=args.max_len, page_size=args.page_size,
                           temperature=args.temperature, top_k=args.top_k,
                           queue_depth=args.queue_depth,
                           guard_nan=args.guard_nan or args.chaos is not None,
                           debug_invariants=args.check_invariants,
                           watchdog=StepWatchdog())
    sched = server.scheduler

    if args.chaos is not None:
        from repro.serve.chaos import ChaosConfig, FaultPlan, run_plan
        plan = FaultPlan(ChaosConfig(seed=args.chaos,
                                     requests=args.requests,
                                     max_prompt=min(args.prompt_len,
                                                    args.max_len // 2),
                                     max_new_tokens=args.gen))
        t0 = time.time()
        rep = run_plan(sched, plan)
        dt = time.time() - t0
        print(f"chaos seed {args.chaos}: {rep.ticks} ticks in {dt:.2f}s — "
              f"states={rep.states} preemptions={rep.preemptions} "
              f"nan_failures={rep.nan_failures} "
              f"invariant_checks={rep.invariant_checks} "
              f"backpressured={rep.backpressured}")
        if not rep.all_terminal:
            raise SystemExit("chaos run left non-terminal requests")
        print("every request reached a terminal typed state; "
              "invariants never tripped")
        return

    key = jax.random.key(42)
    reqs = []
    for r in range(args.requests):
        toks = jax.random.randint(jax.random.fold_in(key, r),
                                  (max(args.prompt_len, 1),), 0, cfg.vocab)
        reqs.append(server.submit([int(t) for t in toks],
                                  max_new_tokens=args.gen,
                                  ttl=args.deadline))

    t0 = time.time()
    steps = 0
    while not sched.drained() and steps < 4 * (args.gen + args.requests):
        server.tick()
        steps += 1
    dt = time.time() - t0
    generated = sum(r.generated for r in reqs)
    cache = sched.cache
    print(f"pages: {cache.pages_in_use()} in use of {cache.num_pages} "
          f"({cache.used_cache_bytes()} cache bytes backing live "
          f"requests)")
    for r in reqs:
        print(f"req {r.rid}: {r.state.value:>9} {r.tokens[:12]} ...")
    stats = sched.stats()
    print(f"{steps} ticks, {generated} tokens in {dt:.2f}s "
          f"({generated / max(dt, 1e-9):.1f} tok/s on CPU interpret); "
          f"preemptions={stats['preemptions']} "
          f"watchdog_breaches={stats.get('watchdog_breaches', 0)}")


if __name__ == "__main__":
    main()
