"""Serving CLI: prefill + batched decode with the interleaved KV cache.

Example (CPU, reduced geometry):
  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b --smoke \
      --requests 4 --prompt-len 16 --gen 12
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_arch
from repro.models.transformer import init_params
from repro.serve.engine import BatchedServer


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=128)
    args = ap.parse_args()

    arch = get_arch(args.arch)
    cfg = arch.smoke if args.smoke else arch.model
    if cfg.encoder is not None:
        raise SystemExit("use whisper example for enc-dec serving")
    params = init_params(cfg, jax.random.key(0))
    server = BatchedServer(cfg, params, slots=args.requests,
                           max_len=args.max_len)

    key = jax.random.key(42)
    for r in range(args.requests):
        tok = int(jax.random.randint(jax.random.fold_in(key, r), (), 0,
                                     cfg.vocab))
        server.add_request(tok)

    t0 = time.time()
    for _ in range(args.gen):
        toks = server.step()
    dt = time.time() - t0
    tps = args.requests * args.gen / dt
    for s in range(args.requests):
        print(f"slot {s}: {server.finish(s)[:12]} ...")
    print(f"{args.gen} steps x {args.requests} slots in {dt:.2f}s "
          f"({tps:.1f} tok/s on CPU interpret)")


if __name__ == "__main__":
    main()
