"""Serving CLI: paged continuous batching (prefill + decode + sampling).

Example (CPU, reduced geometry):
  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b --smoke \
      --requests 4 --prompt-len 16 --gen 12 --page-size 16 \
      --temperature 0.8 --top-k 40
"""
from __future__ import annotations

import argparse
import time

import jax

from repro.configs import get_arch
from repro.models.transformer import init_params
from repro.serve.engine import BatchedServer


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="0 = greedy (default)")
    ap.add_argument("--top-k", type=int, default=None)
    args = ap.parse_args()

    arch = get_arch(args.arch)
    cfg = arch.smoke if args.smoke else arch.model
    if cfg.encoder is not None:
        raise SystemExit("use whisper example for enc-dec serving")
    params = init_params(cfg, jax.random.key(0))
    server = BatchedServer(cfg, params, slots=args.requests,
                           max_len=args.max_len, page_size=args.page_size,
                           temperature=args.temperature, top_k=args.top_k)

    key = jax.random.key(42)
    for r in range(args.requests):
        toks = jax.random.randint(jax.random.fold_in(key, r),
                                  (max(args.prompt_len, 1),), 0, cfg.vocab)
        server.add_request(prompt=[int(t) for t in toks])

    t0 = time.time()
    for _ in range(args.gen):
        server.step()
    dt = time.time() - t0
    tps = args.requests * args.gen / dt
    cache = server.scheduler.cache
    print(f"pages: {cache.pages_in_use()} in use of {cache.num_pages} "
          f"({cache.used_cache_bytes()} cache bytes backing live "
          f"requests)")
    for s in range(args.requests):
        print(f"slot {s}: {server.finish(s)[:12]} ...")
    print(f"{args.gen} steps x {args.requests} slots in {dt:.2f}s "
          f"({tps:.1f} tok/s on CPU interpret)")


if __name__ == "__main__":
    main()
