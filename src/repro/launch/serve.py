"""Serving CLI: paged continuous batching (prefill + decode + sampling)
through the hardened request lifecycle (typed requests, deadlines,
preemption-and-restore, runtime guards), an optional in-process replica
FLEET (least-loaded routing, health tracking, replay-based failover),
and chaos modes for both layers.

Example (CPU, reduced geometry):
  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b --smoke \
      --requests 4 --prompt-len 16 --gen 12 --page-size 16 \
      --temperature 0.8 --top-k 40

Fleet failover smoke (3 replicas, kill one mid-decode, work migrates):
  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b --smoke \
      --replicas 3 --kill-replica 4 --requests 6 --gen 8

Chaos smoke (seeded fault plan, invariants audited every tick; with
--replicas > 1 the plan adds replica kills / hangs / admission storms
and the fleet residency audit).  Exits NONZERO when the audit trips or
any request ends non-typed — CI gates on the exit code:
  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b --smoke \
      --chaos 0 --requests 6 --gen 6 [--replicas 3]
"""
from __future__ import annotations

import argparse
import time

import jax

from repro.configs import get_arch
from repro.ft.straggler import StepWatchdog
from repro.models.transformer import init_params
from repro.serve.engine import BatchedServer
from repro.serve.lifecycle import (LifecycleError, RequestState,
                                   TERMINAL_STATES)
from repro.serve.paged_cache import InvariantViolation

EXIT_CHAOS = 2          # audit tripped / non-typed termination / livelock


def _check_typed(requests) -> list[str]:
    """Every request must sit in a TERMINAL typed state, and FAILED ones
    must carry an error string — anything else is a lifecycle escape."""
    problems = []
    for r in requests:
        if r.state not in TERMINAL_STATES:
            problems.append(f"req {r.rid} non-terminal: {r.state.value}")
        elif r.state is RequestState.FAILED and not r.error:
            problems.append(f"req {r.rid} FAILED without a typed error")
    return problems


def _print_stats(stats: dict) -> None:
    """--stats: latency percentiles + speculation acceptance."""
    lat = stats.get("latency") or {}
    if lat:
        print(f"latency: ttft p50={lat.get('ttft_p50_s', 0.0):.4f}s "
              f"p99={lat.get('ttft_p99_s', 0.0):.4f}s; "
              f"inter-token p50={lat.get('itl_p50_s', 0.0):.5f}s "
              f"p99={lat.get('itl_p99_s', 0.0):.5f}s")
    else:
        print("latency: no samples recorded")
    sp = stats.get("speculative")
    if sp:
        print(f"speculative: K={sp.get('k', '?')} "
              f"acceptance={sp['acceptance']:.2f} "
              f"({sp['accepted']}/{sp['proposed']} drafts accepted)")


def _run_chaos_single(sched, args) -> int:
    from repro.serve.chaos import ChaosConfig, FaultPlan, run_plan
    plan = FaultPlan(ChaosConfig(seed=args.chaos, requests=args.requests,
                                 max_prompt=min(args.prompt_len,
                                                args.max_len // 2),
                                 max_new_tokens=args.gen))
    t0 = time.time()
    try:
        rep = run_plan(sched, plan)
    except (InvariantViolation, LifecycleError) as e:
        print(f"CHAOS FAIL: audit tripped: {type(e).__name__}: {e}")
        return EXIT_CHAOS
    dt = time.time() - t0
    print(f"chaos seed {args.chaos}: {rep.ticks} ticks in {dt:.2f}s — "
          f"states={rep.states} preemptions={rep.preemptions} "
          f"nan_failures={rep.nan_failures} "
          f"invariant_checks={rep.invariant_checks} "
          f"backpressured={rep.backpressured}")
    problems = _check_typed(rep.submitted)
    if problems:
        print("CHAOS FAIL: " + "; ".join(problems))
        return EXIT_CHAOS
    print("every request reached a terminal typed state; "
          "invariants never tripped")
    if args.stats:
        _print_stats(sched.stats())
    return 0


def _run_chaos_fleet(router, args) -> int:
    from repro.serve.chaos import (FleetChaosConfig, FleetFaultPlan,
                                   run_fleet_plan)
    from repro.serve.fleet import FleetAuditError
    plan = FleetFaultPlan(FleetChaosConfig(
        seed=args.chaos, replicas=args.replicas, requests=args.requests,
        max_prompt=min(args.prompt_len, args.max_len // 2),
        max_new_tokens=args.gen))
    t0 = time.time()
    try:
        rep = run_fleet_plan(router, plan)
    except (FleetAuditError, InvariantViolation, LifecycleError) as e:
        print(f"FLEET CHAOS FAIL: audit tripped: "
              f"{type(e).__name__}: {e}")
        return EXIT_CHAOS
    dt = time.time() - t0
    print(f"fleet chaos seed {args.chaos}: {rep.ticks} ticks in "
          f"{dt:.2f}s — states={rep.states} deaths={rep.deaths} "
          f"respawns={rep.respawns} migrated={rep.migrated} "
          f"drains={rep.drains} recovered={rep.recovered} "
          f"audits={rep.audits} backpressured={rep.backpressured}")
    if rep.ticks >= plan.cfg.max_ticks:
        print("FLEET CHAOS FAIL: fleet never drained (livelock)")
        return EXIT_CHAOS
    problems = _check_typed(rep.submitted)
    if problems:
        print("FLEET CHAOS FAIL: " + "; ".join(problems))
        return EXIT_CHAOS
    print("every request reached a terminal typed state; the fleet "
          "audit held every tick")
    if args.stats:
        _print_stats(router.stats())
    return 0


def _run_fleet(router, cfg, args) -> int:
    key = jax.random.key(42)
    sys_prefix = []
    if args.prefix_cache:   # shared system prompt: see main()
        sys_prefix = [int(t) for t in jax.random.randint(
            jax.random.fold_in(key, 999983),
            (args.prompt_len // 2,), 0, cfg.vocab)]
    reqs = []
    for r in range(args.requests):
        n = max(args.prompt_len - len(sys_prefix), 1)
        toks = jax.random.randint(jax.random.fold_in(key, r),
                                  (n,), 0, cfg.vocab)
        reqs.append(router.submit(sys_prefix + [int(t) for t in toks],
                                  max_new_tokens=args.gen,
                                  ttl=args.deadline))
    t0 = time.time()
    cap = 8 * (args.gen + args.requests)
    while not (router.drained() and all(r.terminal for r in reqs)) \
            and router.tick_no < cap:
        if args.kill_replica is not None and \
                router.tick_no + 1 == args.kill_replica:
            print(f"killing replica 0 at tick {args.kill_replica}")
            router.kill_replica(0, reason="--kill-replica")
        router.tick()
        router.audit()
    dt = time.time() - t0
    for r in reqs:
        print(f"req {r.rid}: {r.state.value:>9} on r{r.replica} "
              f"(migrations={r.migrations}) {r.tokens[:12]} ...")
    stats = router.stats()
    generated = sum(r.generated for r in reqs)
    recovered = sum(1 for r in reqs if r.migrations > 0
                    and r.state is RequestState.FINISHED)
    print(f"fleet: {stats['ticks']} ticks, {generated} tokens in "
          f"{dt:.2f}s ({generated / max(dt, 1e-9):.1f} tok/s); "
          f"deaths={stats['deaths']} respawns={stats['respawns']} "
          f"migrated={stats['migrated']} recovered={recovered} "
          f"drains={stats['drains']} rejoins={stats['rejoins']}")
    for idx, rs in stats["replicas"].items():
        print(f"  r{idx}: {rs['state']:>8} gen={rs['generation']} "
              f"load={rs['load']} hard_breaches={rs['hard_breaches']} "
              f"pages_in_use={rs['pages_in_use']}")
    if "prefix_hit_rate" in stats:
        print(f"fleet prefix cache: hit_rate={stats['prefix_hit_rate']:.2f} "
              f"({stats['prefix_hits']}/"
              f"{stats['prefix_hits'] + stats['prefix_misses']}), "
              f"{stats['prefix_tokens_reused']} tokens reused, "
              f"{stats['shared_pages']} shared pages fleet-wide")
    if args.stats:
        _print_stats(stats)
    problems = _check_typed(reqs)
    if problems:
        print("FLEET FAIL: " + "; ".join(problems))
        return EXIT_CHAOS
    return 0


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--kv-dtype", choices=("float32", "int8", "fp8"),
                    default="float32",
                    help="page-pool element type; int8/fp8 store "
                         "quantized pages with per-page scales, dequant "
                         "fused into the page-gather program (~4x cache "
                         "memory at bounded logit error)")
    ap.add_argument("--speculate", type=int, default=1, metavar="K",
                    help="speculative decode width: a draft model "
                         "proposes K-1 tokens and the target verifies "
                         "all K in ONE fused page-gather/verify launch "
                         "per step (requires greedy sampling)")
    ap.add_argument("--draft", default=None, metavar="ARCH",
                    help="draft model arch for --speculate (defaults to "
                         "--arch; must be attention-only)")
    ap.add_argument("--stats", action="store_true",
                    help="print per-request latency percentiles (TTFT / "
                         "inter-token p50/p99) and speculation "
                         "acceptance after the run")
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="0 = greedy (default)")
    ap.add_argument("--top-k", type=int, default=None)
    ap.add_argument("--deadline", type=float, default=None,
                    help="per-request TTL in seconds (TIMED_OUT beyond)")
    ap.add_argument("--queue-depth", type=int, default=None,
                    help="admission queue bound (backpressure beyond)")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="share prompt-prefix KV pages across requests "
                         "through the radix prefix cache (attention-only "
                         "stacks); the clean-serve workload gets a "
                         "shared system prefix so the hit rate is "
                         "observable")
    ap.add_argument("--chunk-pages", type=int, default=1,
                    help="prefill chunk budget per tick, in pages — "
                         "long prompts stream in between decode steps "
                         "instead of monopolizing admission")
    ap.add_argument("--check-invariants", action="store_true",
                    help="audit the page pool after every mutation")
    ap.add_argument("--guard-nan", action="store_true",
                    help="fail (only) slots producing non-finite logits")
    ap.add_argument("--chaos", type=int, default=None, metavar="SEED",
                    help="run a seeded fault plan instead of clean "
                         "serving (fleet faults when --replicas > 1); "
                         "exits nonzero on audit trip / non-typed end")
    ap.add_argument("--replicas", type=int, default=1,
                    help="in-process scheduler replicas behind the "
                         "fleet router (least-loaded admission, "
                         "health-checked failover)")
    ap.add_argument("--kill-replica", type=int, default=None,
                    metavar="TICK",
                    help="kill replica 0 at this fleet tick — its work "
                         "migrates and resumes elsewhere (needs "
                         "--replicas > 1)")
    args = ap.parse_args()

    if args.replicas < 1:
        raise SystemExit("--replicas must be >= 1")
    if args.kill_replica is not None and args.replicas < 2:
        raise SystemExit("--kill-replica needs --replicas > 1 "
                         "(killing the only replica strands the work)")

    arch = get_arch(args.arch)
    cfg = arch.smoke if args.smoke else arch.model
    if cfg.encoder is not None:
        raise SystemExit("use whisper example for enc-dec serving")
    params = init_params(cfg, jax.random.key(0))
    guard_nan = args.guard_nan or args.chaos is not None
    kv_quant = None if args.kv_dtype == "float32" else args.kv_dtype
    spec_kw = {}
    if args.speculate > 1:
        if args.temperature > 0.0:
            raise SystemExit("--speculate requires greedy sampling "
                             "(drop --temperature)")
        draft_arch = get_arch(args.draft or args.arch)
        draft_cfg = draft_arch.smoke if args.smoke else draft_arch.model
        draft_params = init_params(draft_cfg, jax.random.key(1))
        spec_kw = dict(speculate=args.speculate, draft_cfg=draft_cfg,
                       draft_params=draft_params)

    if args.replicas > 1:
        from repro.serve.chaos import StepClock
        from repro.serve.engine import make_fleet
        fleet_kw = dict(temperature=args.temperature, top_k=args.top_k,
                        queue_depth=args.queue_depth, guard_nan=guard_nan,
                        debug_invariants=args.check_invariants,
                        prefix_cache=args.prefix_cache,
                        chunk_pages=args.chunk_pages, kv_quant=kv_quant,
                        **spec_kw)
        if args.chaos is not None:
            # a quantized clock + a hard limit it dwarfs: determinism
            fleet_kw.update(clock=StepClock(),
                            watchdog_hard_limit=30.0,
                            hard_breach_limit=1)
        router = make_fleet(cfg, params, replicas=args.replicas,
                            slots=args.requests, max_len=args.max_len,
                            page_size=args.page_size, **fleet_kw)
        if args.chaos is not None:
            raise SystemExit(_run_chaos_fleet(router, args) or None)
        raise SystemExit(_run_fleet(router, cfg, args) or None)

    server = BatchedServer(cfg, params, slots=args.requests,
                           max_len=args.max_len, page_size=args.page_size,
                           temperature=args.temperature, top_k=args.top_k,
                           queue_depth=args.queue_depth,
                           guard_nan=guard_nan,
                           debug_invariants=args.check_invariants,
                           prefix_cache=args.prefix_cache,
                           chunk_pages=args.chunk_pages,
                           kv_quant=kv_quant,
                           watchdog=StepWatchdog(), **spec_kw)
    sched = server.scheduler

    if args.chaos is not None:
        raise SystemExit(_run_chaos_single(sched, args) or None)

    key = jax.random.key(42)
    # with --prefix-cache the workload models production traffic: every
    # prompt opens with the SAME system prefix (half the prompt length),
    # so the radix cache has something to share and the printed hit
    # rate / shared-page counts are meaningful
    sys_prefix = []
    if args.prefix_cache:
        sys_prefix = [int(t) for t in jax.random.randint(
            jax.random.fold_in(key, 999983),
            (args.prompt_len // 2,), 0, cfg.vocab)]
    reqs = []
    for r in range(args.requests):
        n = max(args.prompt_len - len(sys_prefix), 1)
        toks = jax.random.randint(jax.random.fold_in(key, r),
                                  (n,), 0, cfg.vocab)
        reqs.append(server.submit(sys_prefix + [int(t) for t in toks],
                                  max_new_tokens=args.gen,
                                  ttl=args.deadline))

    t0 = time.time()
    steps = 0
    while not sched.drained() and steps < 4 * (args.gen + args.requests):
        server.tick()
        steps += 1
    dt = time.time() - t0
    generated = sum(r.generated for r in reqs)
    cache = sched.cache
    print(f"pages: {cache.pages_in_use()} in use of {cache.num_pages} "
          f"({cache.used_cache_bytes()} cache bytes backing live "
          f"requests)")
    for r in reqs:
        print(f"req {r.rid}: {r.state.value:>9} {r.tokens[:12]} ...")
    stats = sched.stats()
    print(f"{steps} ticks, {generated} tokens in {dt:.2f}s "
          f"({generated / max(dt, 1e-9):.1f} tok/s on CPU interpret); "
          f"preemptions={stats['preemptions']} "
          f"prefill_chunks={stats['prefill_chunks']} "
          f"watchdog_breaches={stats.get('watchdog_breaches', 0)}")
    if "prefix" in stats:
        px = stats["prefix"]
        print(f"prefix cache: hit_rate={px['hit_rate']:.2f} "
              f"({px['hits']}/{px['hits'] + px['misses']}), "
              f"{px['tokens_reused']} tokens reused, "
              f"{stats['shared_pages']} shared pages, "
              f"{px['pages']} trie pages ({px['evicted']} evicted)")
    if args.stats:
        _print_stats(stats)


if __name__ == "__main__":
    main()
