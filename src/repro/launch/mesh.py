"""Production meshes.

Defined as FUNCTIONS so importing this module never touches jax device
state (the dry-run sets XLA_FLAGS before any jax init; smoke tests must see
1 device).

Single pod: 256 chips as (16, 16) = ("data", "model") — v5e pod, 2D torus.
Multi-pod : 512 chips as (2, 16, 16) = ("pod", "data", "model"); the "pod"
axis is data-parallel by default (gradient reduction over DCI), or the
pipeline axis when pipeline parallelism is enabled (dist/pipeline_par.py).
"""
from __future__ import annotations

from repro.dist.sharding import ShardCtx, make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_ctx(mesh, *, long_context: bool = False,
             fsdp: bool = False) -> ShardCtx:
    """ShardCtx for a production mesh (or None mesh for local tests)."""
    if mesh is None:
        return ShardCtx(mesh=None, data_axes=(), model_axis=None)
    names = mesh.axis_names
    data_axes = tuple(a for a in ("pod", "data") if a in names)
    if long_context:
        # batch=1: the batch dim cannot shard — activations replicate over
        # the data axes and the KV sequence dim is sharded instead.
        return ShardCtx(mesh=mesh, data_axes=(),
                        model_axis="model" if "model" in names else None,
                        seq_axes=tuple(a for a in ("data", "model")
                                       if a in names))
    return ShardCtx(
        mesh=mesh,
        data_axes=data_axes,
        model_axis="model" if "model" in names else None,
        seq_axes=(),
        fsdp=fsdp,
    )


def make_test_mesh(shape=(2, 4), axes=("data", "model")):
    """Small mesh over however many fake devices tests configured."""
    return make_mesh(shape, axes)
