import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this driver:
  1. builds the production mesh ((16,16) single-pod / (2,16,16) multi-pod),
  2. lowers the REAL step function (train_step / prefill / serve_step) with
     full in/out shardings on ShapeDtypeStructs (no allocation),
  3. compiles it — sharding mismatches, unsupported collectives, or
     compile-time OOM are failures,
  4. records memory_analysis / cost_analysis / per-op collective bytes,
  5. lowers 1- and 2-superblock UNROLLED probes to correct for scan bodies
     being counted once by cost_analysis (see roofline/analysis.py),
  6. emits a JSON artifact consumed by benchmarks/roofline_table.py and
     EXPERIMENTS.md.

Usage:
  python -m repro.launch.dryrun --arch granite-34b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--out experiments/...]
"""
import argparse  # noqa: E402
import dataclasses
import gc
import json
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, cells_for, get_arch, shape_by_name
from repro.configs.base import decode_inputs, prefill_batch, train_batch
from repro.launch.mesh import make_ctx, make_production_mesh
from repro.roofline.analysis import (CostBundle, bundle_from_compiled,
                                     model_flops, roofline)
from repro.serve.engine import ServeConfig, jit_decode_step, jit_prefill
from repro.train.step import TrainConfig, full_state_shardings, jit_train_step

ARTIFACT_DIR = "experiments/artifacts"

# Converged adaptive-microbatch values (from escalation runs) — a hint
# cache, not a config: removing an entry re-enables escalation.
MB_HINTS = {
    "granite-34b": 2,
    "gemma3-12b": 4,
    "qwen3-0.6b": 1,
    "starcoder2-3b": 1,
    "jamba-1.5-large-398b": 8,
    "whisper-tiny": 2,
    "llava-next-mistral-7b": 1,
    "phi3.5-moe-42b-a6.6b": 2,
    "qwen3-moe-30b-a3b": 4,
    "xlstm-125m": 1,
}


def _bf16_params_struct(cfg):
    from repro.models.transformer import init_params
    shapes = jax.eval_shape(lambda: init_params(cfg, jax.random.key(0)))
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(
            s.shape, jnp.bfloat16 if jnp.issubdtype(s.dtype, jnp.floating)
            else s.dtype), shapes)


def _lower_cell(cfg, shape, ctx, *, tcfg=None):
    """Returns (lowered, lower_seconds) for one step function."""
    t0 = time.time()
    if shape.kind == "train":
        tcfg = tcfg or TrainConfig()
        from repro.train.step import init_full_state
        state = jax.eval_shape(
            lambda: init_full_state(cfg, tcfg, jax.random.key(0)))
        batch = train_batch(cfg, shape.seq_len, shape.global_batch,
                            specs=True)
        jitted = jit_train_step(cfg, tcfg, ctx, state, batch)
        lowered = jitted.lower(state, batch)
    elif shape.kind == "prefill":
        params = _bf16_params_struct(cfg)
        batch = prefill_batch(cfg, shape.seq_len, shape.global_batch,
                              specs=True)
        jitted = jit_prefill(cfg, ctx, params, batch,
                             param_ctx=_serve_param_ctx(cfg, ctx))
        lowered = jitted.lower(params, batch)
    else:  # decode
        params = _bf16_params_struct(cfg)
        cache, token = decode_inputs(cfg, shape.seq_len, shape.global_batch,
                                     specs=True)
        scfg = ServeConfig(max_len=shape.seq_len,
                           long_context=shape.name == "long_500k")
        jitted = jit_decode_step(cfg, ctx, scfg, params, cache,
                                 param_ctx=_serve_param_ctx(cfg, ctx))
        lowered = jitted.lower(params, cache, token)
    return lowered, time.time() - t0


def _serve_param_ctx(cfg, ctx):
    """2D (data x model) weight sharding for models whose bf16 weights
    exceed ~4 GiB/device under TP-only (e.g. Jamba-398B)."""
    if ctx.mesh is None:
        return None
    bf16_bytes = 2 * _total_params(cfg)
    if bf16_bytes / max(ctx.model_size, 1) <= 4 * 2**30:
        return None
    from repro.launch.mesh import make_ctx
    return make_ctx(ctx.mesh, fsdp=True)


def _total_params(cfg) -> int:
    import math
    from repro.models.transformer import init_params
    shapes = jax.eval_shape(lambda: init_params(cfg, jax.random.key(0)))
    return sum(math.prod(s.shape) if s.shape else 1
               for s in jax.tree.leaves(shapes))


def run_cell(arch_name: str, shape_name: str, *, multi_pod: bool,
             cfg_override=None, tcfg=None, probes: bool = True,
             ctx_override=None) -> dict:
    arch = get_arch(arch_name)
    cfg = cfg_override or arch.model
    shape = shape_by_name(shape_name)
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = 512 if multi_pod else 256
    # FSDP for training when the fp32 master copy would not fit TP-only
    # (> 8B params on a 16-way model axis ~ >2 GiB/device just for params)
    fsdp = shape.kind == "train" and _total_params(cfg) > 8e9
    ctx = ctx_override or make_ctx(
        mesh, long_context=shape.name == "long_500k", fsdp=fsdp)

    result = {"arch": arch_name, "shape": shape_name,
              "mesh": "2x16x16" if multi_pod else "16x16", "chips": chips,
              "status": "ok"}
    # adaptive microbatching: escalate until the train step fits HBM
    # (exactly what the production launcher does on real fleets).
    # MB_HINTS record the converged values to skip re-escalation.
    if shape.kind == "train" and tcfg is None:
        hint = MB_HINTS.get(arch_name)
        mb_plan = [hint] if hint else [1, 2, 4, 8]
    else:
        mb_plan = [None]
    compiled = lowered = mem = None
    for mb in mb_plan:
        if mb is not None:
            tcfg = TrainConfig(microbatches=mb)
        lowered, t_lower = _lower_cell(cfg, shape, ctx, tcfg=tcfg)
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0
        mem = compiled.memory_analysis()
        total = (mem.argument_size_in_bytes + mem.temp_size_in_bytes
                 + mem.output_size_in_bytes)
        if total < 15.5 * 2**30 or mb == mb_plan[-1]:
            result["microbatches"] = mb or (tcfg.microbatches if tcfg else 1)
            break
        del compiled, lowered
        gc.collect()
    full = bundle_from_compiled(compiled)
    result.update({
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "bytes_per_device": {
            "arguments": mem.argument_size_in_bytes,
            "output": mem.output_size_in_bytes,
            "temp": mem.temp_size_in_bytes,
            "peak_est": mem.argument_size_in_bytes + max(
                mem.output_size_in_bytes, 0) + mem.temp_size_in_bytes,
        },
        "fits_hbm": (mem.argument_size_in_bytes + mem.temp_size_in_bytes
                     + mem.output_size_in_bytes) < 16 * 2**30,
        "raw": {"flops_per_dev": full.flops,
                "bytes_per_dev": full.bytes_accessed,
                "coll_bytes_per_dev": full.coll_bytes,
                "coll_breakdown": full.coll_breakdown},
    })
    del compiled, lowered
    gc.collect()

    if probes:
        # ---- scan-body correction probes (unrolled 1 and 2 superblocks) ---
        sb = cfg.sb_len
        enc_n = cfg.encoder.n_layers if cfg.encoder is not None else 0
        c1 = dataclasses.replace(cfg, n_layers=sb, scan_layers=False)
        c2 = dataclasses.replace(cfg, n_layers=2 * sb, scan_layers=False)
        bundles = []
        for ck in (c1, c2):
            lw, _ = _lower_cell(ck, shape, ctx, tcfg=tcfg)
            cp = lw.compile()
            bundles.append(bundle_from_compiled(cp))
            del cp, lw
            gc.collect()
        body = bundles[1] - bundles[0]
        fixed = bundles[0] - body
        ns = cfg.n_superblocks
        corrected = fixed.scaled_add(body, ns)
        # the microbatch accumulation loop is ALSO a scan counted once by
        # cost_analysis: scale by mb (slightly over-counts the optimizer
        # epilogue, which is negligible next to the model body)
        mb = result.get("microbatches", 1) or 1
        if shape.kind == "train" and mb > 1:
            zero = CostBundle(0.0, 0.0, 0.0, {})
            corrected = zero.scaled_add(corrected, mb)
        result["corrected"] = {
            "flops_per_dev": corrected.flops,
            "bytes_per_dev": corrected.bytes_accessed,
            "coll_bytes_per_dev": corrected.coll_bytes,
            "coll_breakdown": corrected.coll_breakdown,
            "method": f"fixed + {ns} * body (unrolled 1/2-superblock probes)",
        }
        tokens = shape.global_batch * (shape.seq_len
                                       if shape.kind != "decode" else 1)
        mf = model_flops(cfg, tokens=tokens,
                         kind="train" if shape.kind == "train" else "serve")
        terms = roofline(corrected, chips=chips, model_flops=mf)
        result["roofline"] = terms.as_dict()
    return result


def save_artifact(result: dict, out_dir: str = ARTIFACT_DIR) -> str:
    os.makedirs(out_dir, exist_ok=True)
    name = (f"{result['arch']}__{result['shape']}__"
            f"{result['mesh'].replace('x', '_')}.json")
    path = os.path.join(out_dir, name)
    with open(path, "w") as f:
        json.dump(result, f, indent=1)
    return path


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--no-probes", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--out", default=ARTIFACT_DIR)
    args = ap.parse_args()

    cells: list[tuple[str, str, bool]] = []
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    if args.all:
        for mp in meshes:           # single-pod pass first (roofline table)
            for a in ARCH_IDS:
                for s in cells_for(a):
                    cells.append((a, s.name, mp))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        for mp in meshes:
            cells.append((args.arch, args.shape, mp))
    if args.skip_existing:
        def done(a, s, mp):
            name = (f"{a}__{s}__{'2_16_16' if mp else '16_16'}.json")
            return os.path.exists(os.path.join(args.out, name))
        cells = [c for c in cells if not done(*c)]

    failures = 0
    for a, s, mp in cells:
        tag = f"{a} x {s} x {'2x16x16' if mp else '16x16'}"
        t0 = time.time()
        try:
            # roofline probes on the single-pod mesh only (the assignment's
            # roofline table is single-pod; multi-pod proves the pod axis)
            res = run_cell(a, s, multi_pod=mp,
                           probes=not args.no_probes and not mp)
            path = save_artifact(res, args.out)
            r = res.get("roofline", {})
            print(f"[ok]   {tag}: peak/dev="
                  f"{res['bytes_per_device']['peak_est']/2**30:.2f}GiB "
                  f"fits={res['fits_hbm']} dominant={r.get('dominant', '-')} "
                  f"({time.time()-t0:.0f}s) -> {path}", flush=True)
        except Exception as e:  # noqa: BLE001
            failures += 1
            print(f"[FAIL] {tag}: {type(e).__name__}: {e}", flush=True)
            traceback.print_exc()
        gc.collect()
    print(f"done: {len(cells) - failures}/{len(cells)} cells ok", flush=True)
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
