"""repro — EARTH-JAX: TPU-native vector memory access framework.

A production-grade JAX training/inference framework whose data-movement
substrate implements the EARTH paper (shift-network gather/scatter, LSDO
strided coalescing, RCVRF skewed layouts) adapted from a RISC-V VLSU to the
TPU memory hierarchy. See DESIGN.md.
"""
__version__ = "1.0.0"
