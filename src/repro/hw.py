"""Hardware constants for the roofline target: TPU v5e (per chip).

The container is CPU-only; these constants describe the TARGET used for the
roofline terms in EXPERIMENTS.md (see the assignment: 197 TFLOP/s bf16,
819 GB/s HBM, ~50 GB/s per ICI link).
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ChipSpec:
    name: str
    peak_bf16_flops: float      # FLOP/s
    hbm_bandwidth: float        # bytes/s
    ici_link_bandwidth: float   # bytes/s per link (one direction)
    ici_links: int              # links per chip (2D torus on v5e)
    hbm_bytes: int              # HBM capacity per chip
    vmem_bytes: int             # VMEM per core


TPU_V5E = ChipSpec(
    name="tpu_v5e",
    peak_bf16_flops=197e12,
    hbm_bandwidth=819e9,
    ici_link_bandwidth=50e9,
    ici_links=4,
    hbm_bytes=16 * 2**30,
    vmem_bytes=128 * 2**20,
)

# The roofline formulas in the assignment divide collective bytes by
# (chips x link_bw); we follow that convention (single-link, conservative).
DEFAULT_CHIP = TPU_V5E

# MXU-friendly tiling constants (bf16): last dim multiples of 128 lanes,
# second-minor multiples of 8 sublanes (16 for bf16 packing).
LANES = 128
SUBLANES = 8
