"""Static shift-plan compiler — EARTH's DROM routing folded at trace time.

The dynamic networks in ``core/shiftnet.py`` carry (payload, shiftcnt,
valid) through every one of ``log2(n)`` layers and re-derive the per-layer
routing decision with runtime arithmetic.  But almost every call site in
this repo routes a pattern that is *fully determined by static Python ints*
(stride, offset, vl, field count).  This module simulates the network once
in NumPy at trace time and emits a :class:`ShiftPlan`:

* per-layer **constant boolean take-masks** (folded into the kernel as
  literals — Mosaic/XLA see them as constants),
* **layer pruning**: layers in which no element moves are dropped entirely
  (a stride-2 gather needs about half the layers; single-transaction
  patterns often need 1-2),
* the per-layer *triple* shift (payload + shiftcnt + valid) collapses to
  **one static shift + one select per active layer**,
* the final occupancy mask and source map are compile-time constants.

Three plan families:

1. monotone gather/scatter (closed-form SCG counts — the §4.2 paths),
2. batched gather/scatter — one plan routing a stacked ``(T, n)`` block of
   coalesced transactions (per-row constant masks; used by core/lsdo.py),
3. arbitrary permutations (the fused segment transposition): bit-fixing
   butterfly routing when it is conflict-free, else a Benes network
   (2*log2(n)-1 exchange stages, conflict-free for ANY permutation by the
   looping algorithm).

The dynamic-count network remains the runtime-stride fallback and the
property-test oracle (tests/test_property_shiftnet.py).

Every plan constructor is memoized in the unified spec-keyed LRU
(``repro.vx.cache.PLANS``) — one cache for shift plans, the runtime-stride
bank, and vx dispatch executors.
"""
from __future__ import annotations

import dataclasses
import functools
import math

import numpy as np

from repro.vx.cache import memoize as _memoize


def num_layers(n: int) -> int:
    """Layers needed so any shift in [0, n-1] is representable."""
    if n <= 1:
        return 0
    return max(1, math.ceil(math.log2(n)))


def _np_shift(x: np.ndarray, k: int, fill) -> np.ndarray:
    """NumPy mirror of shiftnet.shift_static: result[i] = x[i + k]."""
    n = x.shape[-1]
    if k == 0:
        return x.copy()
    out = np.full_like(x, fill)
    if abs(k) >= n:
        return out
    if k > 0:
        out[..., : n - k] = x[..., k:]
    else:
        out[..., -k:] = x[..., : n + k]
    return out


@dataclasses.dataclass(frozen=True, eq=False)
class PlanLayer:
    """One network layer: ``out = select(masks, statically shifted copies)``.

    All (shift, mask) pairs read the SAME input snapshot (masks are
    disjoint); slots covered by no mask keep their value.  Monotone plans
    have a single pair per layer; Benes exchange stages have two (+d / -d).
    """
    shifts: tuple[int, ...]
    masks: tuple[np.ndarray, ...]          # bool, broadcastable to payload


@dataclasses.dataclass(frozen=True, eq=False)
class ShiftPlan:
    n: int                                 # routed width
    kind: str                              # gather|scatter|permute|counts
    layers: tuple[PlanLayer, ...]          # pruned: only active layers
    valid: np.ndarray                      # occupancy after routing
    source: np.ndarray                     # source[slot] = input idx or -1
    conflict: bool                         # compile-time §4.1.4 violation

    @property
    def active_layers(self) -> int:
        return len(self.layers)

    @property
    def total_layers(self) -> int:
        return num_layers(self.n)

    @property
    def num_shifts(self) -> int:
        """Static shift op count."""
        return sum(len(l.shifts) for l in self.layers)

    @property
    def wide_ops(self) -> int:
        """Full-width ops per application: each layer pays its shifts plus
        one (multi-way) select on the wide payload."""
        return sum(len(l.shifts) + 1 for l in self.layers)


# ---------------------------------------------------------------------------
# NumPy closed-form SCG counts (mirrors core/scg.py, host-side)
# ---------------------------------------------------------------------------

def gather_counts_np(n, stride, offset, vl):
    p = np.arange(n, dtype=np.int64)
    s = max(int(stride), 1)
    rel = p - int(offset)
    dest = rel // s
    valid = (rel >= 0) & (rel % s == 0) & (dest < int(vl))
    shift = np.where(valid, p - dest, 0)
    return shift, valid


def scatter_counts_np(n, stride, offset, vl):
    i = np.arange(n, dtype=np.int64)
    valid = i < int(vl)
    shift = np.where(valid, int(offset) + i * (int(stride) - 1), 0)
    return shift, valid


# ---------------------------------------------------------------------------
# Monotone network simulation (the compile-time twin of shiftnet._route)
# ---------------------------------------------------------------------------

def _simulate_route(shift, valid, *, toward_zero: bool, lsb_first: bool):
    """Run the layer loop in NumPy; returns (bit->take-mask dict, valid,
    source, conflict).  The take-mask of layer ``l`` is the network's
    ``cand_valid`` — a constant once (shift, valid) are static."""
    shift = np.asarray(shift, np.int64)
    valid = np.asarray(valid, bool)
    n = shift.shape[-1]
    layers = num_layers(n)
    order = range(layers) if lsb_first else range(layers - 1, -1, -1)
    direction = 1 if toward_zero else -1
    source = np.where(valid, np.arange(n), -1)
    conflict = False
    n_valid0 = int(valid.sum())

    masks: dict[int, np.ndarray] = {}
    for l in order:
        k = 1 << l
        bit = (shift >> l) & 1
        stay = valid & (bit == 0)
        cand_shift = _np_shift(shift, direction * k, 0)
        cand_valid = (_np_shift(valid, direction * k, False)
                      & (((cand_shift >> l) & 1) == 1))
        conflict = conflict or bool(np.any(cand_valid & stay))
        masks[l] = cand_valid
        source = np.where(cand_valid, _np_shift(source, direction * k, -1),
                          np.where(stay, source, -1))
        shift = np.where(cand_valid, cand_shift, shift)
        valid = cand_valid | stay
    conflict = conflict or int(valid.sum()) != n_valid0
    return masks, valid, source, conflict


def _monotone_plan(shift, valid, *, kind: str, toward_zero: bool,
                   lsb_first: bool) -> ShiftPlan:
    n = np.asarray(shift).shape[-1]
    masks, out_valid, source, conflict = _simulate_route(
        shift, valid, toward_zero=toward_zero, lsb_first=lsb_first)
    direction = 1 if toward_zero else -1
    layers = []
    order = (sorted(masks) if lsb_first else sorted(masks, reverse=True))
    for l in order:
        if masks[l].any():                 # prune no-op layers
            layers.append(PlanLayer((direction * (1 << l),), (masks[l],)))
    return ShiftPlan(n, kind, tuple(layers), out_valid, source, conflict)


@_memoize("plan.gather")
def gather_plan(n: int, stride: int, offset: int, vl: int) -> ShiftPlan:
    """Compiled GSN for a strided load window (§4.2 closed form)."""
    shift, valid = gather_counts_np(n, stride, offset, vl)
    return _monotone_plan(shift, valid, kind="gather", toward_zero=True,
                          lsb_first=True)


@_memoize("plan.scatter")
def scatter_plan(n: int, stride: int, offset: int, vl: int) -> ShiftPlan:
    """Compiled SSN for a strided store window."""
    shift, valid = scatter_counts_np(n, stride, offset, vl)
    return _monotone_plan(shift, valid, kind="scatter", toward_zero=False,
                          lsb_first=False)


@_memoize("plan.counts")
def counts_plan(shift: tuple, valid: tuple, *, gather: bool) -> ShiftPlan:
    """Compiled network for arbitrary *static* per-lane counts (the
    shift_gather/shift_scatter fast path when the SCG output is host data)."""
    return _monotone_plan(np.asarray(shift), np.asarray(valid),
                          kind="counts", toward_zero=gather,
                          lsb_first=gather)


# ---------------------------------------------------------------------------
# Batched transaction plans (LSDO: route all coalesced requests in one call)
# ---------------------------------------------------------------------------

def _batched_plan(count_fn, n: int, rows: tuple, *, kind: str,
                  toward_zero: bool, lsb_first: bool) -> ShiftPlan:
    """One plan routing a stacked (T, n) block: row t carries transaction
    t's window, described by a (stride, offset, count) triple — rows may
    come from DIFFERENT accesses (the whole-step super-transaction).  Layer
    masks are (T, n) constants; a layer survives pruning if ANY row moves
    an element in it, so depth is the union of the per-row active sets
    (still <= log2(n))."""
    T = len(rows)
    per_bit: dict[int, list[np.ndarray]] = {}
    valid = np.zeros((T, n), bool)
    source = np.full((T, n), -1)
    conflict = False
    for t, (stride, off, cnt) in enumerate(rows):
        shift_t, valid_t = count_fn(n, stride, off, cnt)
        masks, v, s, c = _simulate_route(shift_t, valid_t,
                                         toward_zero=toward_zero,
                                         lsb_first=lsb_first)
        conflict = conflict or c
        valid[t], source[t] = v, s
        for l, m in masks.items():
            per_bit.setdefault(l, [np.zeros(n, bool)] * T)
            per_bit[l] = [m if i == t else x
                          for i, x in enumerate(per_bit[l])]
    direction = 1 if toward_zero else -1
    layers = []
    order = sorted(per_bit) if lsb_first else sorted(per_bit, reverse=True)
    for l in order:
        stacked = np.stack(per_bit[l])
        if stacked.any():
            layers.append(PlanLayer((direction * (1 << l),), (stacked,)))
    return ShiftPlan(n, kind, tuple(layers), valid, source, conflict)


@_memoize("plan.batched_gather")
def batched_gather_plan(n: int, stride: int, offsets: tuple,
                        counts: tuple) -> ShiftPlan:
    rows = tuple((stride, o, c) for o, c in zip(offsets, counts))
    return _batched_plan(gather_counts_np, n, rows,
                         kind="gather", toward_zero=True, lsb_first=True)


@_memoize("plan.batched_scatter")
def batched_scatter_plan(n: int, stride: int, offsets: tuple,
                         counts: tuple) -> ShiftPlan:
    rows = tuple((stride, o, c) for o, c in zip(offsets, counts))
    return _batched_plan(scatter_counts_np, n, rows,
                         kind="scatter", toward_zero=False, lsb_first=False)


@_memoize("plan.multi_gather")
def multi_gather_plan(n: int, rows: tuple) -> ShiftPlan:
    """Whole-step super-transaction plan: one (T, n) batched plan whose rows
    are the concatenated transactions of SEVERAL accesses — each row its
    own (stride, offset, count).  One network application and one mask
    operand cover every strided load a step issues at this mlen."""
    return _batched_plan(gather_counts_np, n, rows,
                         kind="gather", toward_zero=True, lsb_first=True)


@_memoize("plan.multi_scatter")
def multi_scatter_plan(n: int, rows: tuple) -> ShiftPlan:
    """Scatter twin of :func:`multi_gather_plan`."""
    return _batched_plan(scatter_counts_np, n, rows,
                         kind="scatter", toward_zero=False, lsb_first=False)


# ---------------------------------------------------------------------------
# Arbitrary permutations (fused segment transposition)
# ---------------------------------------------------------------------------

def _bitfix_stages(dest: np.ndarray, order) -> list | None:
    """Butterfly bit-fixing: at stage l an element whose position disagrees
    with its destination in bit l hops by +-2^l.  Conflict-free only for
    some permutations — returns None on collision (caller falls to Benes)."""
    n = dest.shape[0]
    stages = []
    d = dest.copy()
    for l in order:
        k = 1 << l
        new = np.full(n, -1)
        take_hi = np.zeros(n, bool)        # out[i] = in[i + k]
        take_lo = np.zeros(n, bool)        # out[i] = in[i - k]
        for slot in range(n):
            t = d[slot]
            if t < 0:
                continue
            ns = slot ^ k if ((slot ^ t) >> l) & 1 else slot
            if new[ns] != -1:
                return None
            new[ns] = t
            if ns < slot:
                take_hi[ns] = True
            elif ns > slot:
                take_lo[ns] = True
        d = new
        stages.append((k, take_hi, take_lo))
    assert all(d[s] in (-1, s) for s in range(n))
    return stages


def _benes_exchanges(perm: np.ndarray) -> list:
    """Benes looping decomposition: list of (distance, swap_flags) stages,
    outer distance n/2 first and last, distance-1 switches in the middle.
    ``swap_flags[i]`` (i in the low half of a pair) marks pair (i, i+d)."""
    n = perm.shape[0]
    stages_pre: list = []
    stages_post: list = []

    def route(sub_perm: np.ndarray, base: int, depth: int,
              pre: list, post: list):
        m = sub_perm.shape[0]
        if m == 1:
            return
        h = m // 2
        if m == 2:
            pre.append((1, base, np.array([sub_perm[0] == 1])))
            return
        inv = np.empty(m, dtype=np.int64)
        inv[sub_perm] = np.arange(m)
        color = np.full(m, -1)
        for s0 in range(m):
            if color[s0] != -1:
                continue
            stack = [(s0, 0)]
            while stack:
                s, c = stack.pop()
                if color[s] != -1:
                    continue
                color[s] = c
                stack.append((s ^ h, 1 - c))
                stack.append((int(inv[sub_perm[s] ^ h]), 1 - c))
        # entry switches: low slot of each pair gets the color-0 element
        swap_in = np.array([color[i] == 1 for i in range(h)])
        # exit switches: output pair (j, j+h) — swap iff the element
        # destined for low output j routed through the bottom half
        swap_out = np.array(
            [color[int(inv[j])] == 1 for j in range(h)])
        # positions after the entry stage
        top_src = np.where(swap_in, np.arange(h) + h, np.arange(h))
        bot_src = np.where(swap_in, np.arange(h), np.arange(h) + h)
        top_perm = np.array([sub_perm[s] % h for s in top_src])
        bot_perm = np.array([sub_perm[s] % h for s in bot_src])
        pre.append((h, base, swap_in))
        post.append((h, base, swap_out))
        route(top_perm, base, depth + 1, pre, post)
        route(bot_perm, base + h, depth + 1, pre, post)

    pre: list = []
    post: list = []
    route(perm, 0, 0, pre, post)
    return pre, post


def _merge_exchange_stages(raw: list, n: int) -> dict:
    """Group (distance, base, swap_flags) entries of the same distance into
    full-width swap masks (independent subnetworks share stages)."""
    by_d: dict[int, np.ndarray] = {}
    for d, base, flags in raw:
        m = by_d.setdefault(d, np.zeros(n, bool))
        idx = base + np.nonzero(flags)[0]
        m[idx] = True
    return by_d


def _exchange_layers(by_d: dict, order: list) -> list:
    layers = []
    for d in order:
        swap = by_d.get(d)
        if swap is None or not swap.any():
            continue
        take_hi = np.zeros(swap.shape[0], bool)
        take_lo = np.zeros(swap.shape[0], bool)
        lo_idx = np.nonzero(swap)[0]
        take_hi[lo_idx] = True             # out[i]   = in[i + d]
        take_lo[lo_idx + d] = True         # out[i+d] = in[i]
        layers.append(PlanLayer((d, -d), (take_hi, take_lo)))
    return layers


def apply_np(plan: ShiftPlan, x: np.ndarray) -> np.ndarray:
    """Host-side plan application (used for compile-time verification and
    as a test oracle). x: (..., plan.n)."""
    for layer in plan.layers:
        y = x.copy()
        for s, m in zip(layer.shifts, layer.masks):
            y = np.where(m, _np_shift(x, s, 0), y)
        x = y
    return x


def _checked(plan: ShiftPlan) -> ShiftPlan:
    """Assert the compiled routing delivers source[t] to every valid slot."""
    lane = np.arange(plan.n)
    x = np.broadcast_to(lane, plan.valid.shape).copy()
    out = apply_np(plan, x)
    ok = np.where(plan.valid, out == plan.source, True)
    assert bool(np.all(ok)), f"mis-routed {plan.kind} plan (n={plan.n})"
    return plan


@_memoize("plan.permutation")
def permutation_plan(dest: tuple) -> ShiftPlan:
    """Plan routing input slot p to slot dest[p] (-1 = don't-care lane).

    Tries single-butterfly bit-fixing both bit orders (log2 stages, often
    fewer after pruning); falls back to a Benes decomposition (always
    routable, 2*log2-1 exchange stages).  Width is padded to a power of two
    internally — callers pad the payload to ``plan.n`` lanes.
    """
    d = np.asarray(dest, np.int64)
    n0 = d.shape[0]
    n = 1 << num_layers(n0) if n0 > 1 else 1
    full = np.concatenate([d, np.arange(n0, n)]) if n > n0 else d.copy()
    L = num_layers(n)
    valid = np.zeros(n, bool)
    source = np.full(n, -1)
    for p, t in enumerate(full):
        if t >= 0:
            valid[t] = True
            source[t] = p

    for order in (range(L - 1, -1, -1), range(L)):
        stages = _bitfix_stages(full, order)
        if stages is None:
            continue
        layers = []
        for k, hi, lo in stages:
            shifts, masks = [], []
            if hi.any():
                shifts.append(k)
                masks.append(hi)
            if lo.any():
                shifts.append(-k)
                masks.append(lo)
            if shifts:
                layers.append(PlanLayer(tuple(shifts), tuple(masks)))
        return _checked(
            ShiftPlan(n, "permute", tuple(layers), valid, source, False))

    # Benes: complete don't-care lanes into a full permutation first
    perm = full.copy()
    used = set(int(t) for t in perm if t >= 0)
    free = iter([t for t in range(n) if t not in used])
    for p in range(n):
        if perm[p] < 0:
            perm[p] = next(free)
    pre, post = _benes_exchanges(perm)
    by_d_pre = _merge_exchange_stages(pre, n)
    by_d_post = _merge_exchange_stages(post, n)
    dists = sorted(by_d_pre, reverse=True)
    layers = _exchange_layers(by_d_pre, dists)
    layers += _exchange_layers(by_d_post, sorted(by_d_post))
    return _checked(
        ShiftPlan(n, "permute", tuple(layers), valid, source, False))


# A Benes pass is one long dependency chain of exchange stages, while
# per-field passes are ``fields`` independent chains the backend can
# overlap.  Measured on this repo's XLA CPU (see DESIGN.md §3) a permute
# wide-op costs ~6x a monotone-plan wide-op (no cross-op overlap inside
# the chain); on TPU the VPU runs both at vector-op cost, ~2x for the
# extra select operand.  Strategy selection weights by platform.
@functools.lru_cache(maxsize=None)
def _permute_penalty() -> int:
    try:
        import jax
        platform = jax.devices()[0].platform
    except Exception:  # pragma: no cover
        platform = "cpu"
    return 2 if platform == "tpu" else 6


@_memoize("plan.segment_deint")
def segment_deinterleave_plans(n: int, fields: int
                               ) -> tuple[str, tuple[ShiftPlan, ...]]:
    """Cost-modeled segment-load routing: ('fused', (permutation_plan,)) —
    ONE O(log n) pass emitting every field — when its wide-op count beats
    ``fields`` compiled per-field passes, else ('per_field', plans).

    The crossover is real: a Benes pass costs ~3*(2*log2(n)-1) wide ops
    regardless of ``fields``, while per-field compiled passes cost
    ~2*fields*log2(n) — so small field counts route per-field and large
    ones fuse.  Either way the masks are constants and the whole op is one
    kernel."""
    fused = deinterleave_plan(n, fields)
    per = tuple(gather_plan(n, fields, f, n // fields)
                for f in range(fields))
    if fused.wide_ops * _permute_penalty() <= sum(p.wide_ops for p in per):
        return "fused", (fused,)
    return "per_field", per


@_memoize("plan.segment_int")
def segment_interleave_plans(n: int, fields: int
                             ) -> tuple[str, tuple[ShiftPlan, ...]]:
    """Segment-store twin of :func:`segment_deinterleave_plans` (per-field
    passes pay one extra merge select each)."""
    fused = interleave_plan(n, fields)
    per = tuple(scatter_plan(n, fields, f, n // fields)
                for f in range(fields))
    if fused.wide_ops * _permute_penalty() <= \
            sum(p.wide_ops + 1 for p in per):
        return "fused", (fused,)
    return "per_field", per


@_memoize("plan.deinterleave")
def deinterleave_plan(n: int, fields: int) -> ShiftPlan:
    """AoS (f0 f1 .. f0 f1 ..) -> concatenated SoA fields, one fused pass."""
    assert n % fields == 0
    m = n // fields
    p = np.arange(n)
    dest = (p % fields) * m + p // fields
    return permutation_plan(tuple(int(x) for x in dest))


@_memoize("plan.interleave")
def interleave_plan(n: int, fields: int) -> ShiftPlan:
    """Concatenated SoA fields -> AoS beat (inverse fused transposition)."""
    assert n % fields == 0
    m = n // fields
    p = np.arange(n)
    dest = (p % m) * fields + p // m
    return permutation_plan(tuple(int(x) for x in dest))


# ---------------------------------------------------------------------------
# Shard-local rebasing (the SPMD arm of the plan layer)
# ---------------------------------------------------------------------------

@_memoize("plan.shard_rows")
def shard_strided_rows(n: int, stride: int, offset: int, vl: int,
                       nshards: int) -> tuple:
    """Per-shard rebased sub-accesses of a strided pattern over a window
    sharded into ``nshards`` contiguous equal blocks.

    For shard ``r`` owning global lanes ``[r*nl, (r+1)*nl)`` (with
    ``nl = n // nshards``), returns ``(out_lo, count, local_offset)``:
    output lanes ``[out_lo, out_lo + count)`` of the global access land in
    shard ``r``, and inside the shard they are the plain strided pattern
    ``local[local_offset + i*stride]`` — i.e. the shard-local program is
    the SAME plan family with a rebased offset, so sharded lowering reuses
    the unsharded plan compiler per shard.  ``count == 0`` marks a shard
    the access never touches (its branch is dead).

    Requires ``stride > 0`` (callers normalize negative strides with the
    Reverser first) and ``n % nshards == 0``.
    """
    if stride <= 0:
        raise ValueError(f"shard rebasing needs stride > 0, got {stride}")
    if nshards <= 0 or n % nshards:
        raise ValueError(f"window of {n} lanes does not split into "
                         f"{nshards} equal shards")
    nl = n // nshards
    rows = []
    for r in range(nshards):
        lo_lane, hi_lane = r * nl, (r + 1) * nl
        i_lo = max(0, -(-(lo_lane - offset) // stride))     # ceil div
        i_hi = min(vl, (hi_lane - 1 - offset) // stride + 1)
        if i_hi <= i_lo:
            rows.append((0, 0, 0))
            continue
        rows.append((i_lo, i_hi - i_lo, offset + i_lo * stride - lo_lane))
    return tuple(rows)
