"""EARTH core: shift networks, shift-count generation, LSDO coalescing,
and the row/column-accessible register-file layout — the paper's
contribution as composable JAX modules."""
from repro.core import drom, lsdo, rcvrf, scg, shiftnet  # noqa: F401
