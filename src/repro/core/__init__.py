"""EARTH core: shift networks, shift-count generation, LSDO coalescing,
and the row/column-accessible register-file layout — the paper's
contribution as composable JAX modules.  High-level dispatch lives in
``repro.vx`` (``drom`` remains only as a deprecated shim)."""
from repro.core import drom, lsdo, rcvrf, scg, shiftnet  # noqa: F401
