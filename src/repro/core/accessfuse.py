"""Whole-step access fusion — the step-level memory scheduler.

PR 1's compiler (core/shiftplan.py) folds EARTH's DROM routing at trace
time, but every ``gather/scatter/segment`` call still plans, uploads masks,
and launches in isolation, and any *runtime* stride falls back to the slow
dynamic-count network.  This module lifts the plan compiler from per-access
to per-step (the TROOP observation: low-intensity vector workloads only
reach the roofline when memory accesses are scheduled across operations):

* :class:`StepScheduler` — collects every shift-routed access issued by one
  decode/train step (multi-layer KV split, AoS pack/unpack, GLU field
  splits, strided windows), merges same-shape plans into ONE stacked
  ``(A, T, mlen)`` super-transaction with a single concatenated mask
  operand: one kernel launch and one mask upload per step instead of one
  per access.  Groups below :data:`MIN_FUSED_ELEMS` are inlined on the XLA
  path instead — a scheduler does not issue a wide transaction for one
  beat.
* **runtime-stride plan bank** — a small precompiled set of plans for the
  strides that actually occur (±1..8, the §3.2.2 Reverser for the negative
  half; segment field counts 2/4 via :func:`warm`) dispatched with
  ``lax.switch``, so runtime strides hit compiled constant masks instead of
  the dynamic triple-shift network.  Out-of-bank strides take the dynamic
  fallback branch (bit-exact, property-tested).
* :func:`compact_indices` — the bank's runtime-count member (MoE
  compaction): per-layer take-masks are derived ONCE from the prefix-sum
  counts, the id payload pays one static shift + one select per layer, and
  the dynamic network's conflict reductions are dropped (compaction is
  GSN-safe by construction).
* :func:`jaxpr_access_counts` — the launch/mask-upload accounting used by
  the CI regression gate and benchmarks/bench_step.py (counted on the
  jaxpr, no timing flakiness).

Since PR 3 this module sits BELOW the public ``repro.vx`` API: the
scheduler's launch/platform policies read the active ``vx.Policy``
(fusion threshold, platform lowering), group execution routes through the
vx verbs, and the plan banks are memoized in the unified spec-keyed LRU
(``vx.PLANS``).  Callers reach the bank via
``vx.gather(vx.Strided(stride=vx.BANK, ...), w, stride=s)`` and compaction
via ``vx.compact(vx.Compact(n, cap), mask)``.
"""
from __future__ import annotations

import contextlib
import dataclasses
from typing import Any, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import scg, shiftnet, shiftplan
from repro.vx import cache as vxcache
from repro.vx import policy as vxpolicy

# Frozen defaults re-exported from the policy layer (repro/vx/policy.py).
# These are NOT runtime knobs: the scheduler reads fusion_threshold (and
# platform_lowering) from its governing vx.Policy, so tune via
# vx.Policy(fusion_threshold=...) / vx.use(...), not by rebinding these.
MIN_FUSED_ELEMS = vxpolicy.MIN_FUSED_ELEMS
BANK_STRIDES = vxpolicy.BANK_STRIDES
BANK_FIELDS = vxpolicy.BANK_FIELDS


def pick_impl(total_elems: int, impl: str,
              policy: "vxpolicy.Policy | None" = None) -> str:
    """Scheduler launch policy: tiny accesses ride the XLA path.  The
    threshold comes from ``policy`` (default: the active ``vx.Policy``)."""
    pol = vxpolicy.current() if policy is None else policy
    return pol.with_impl(impl).for_elems(total_elems).impl


_PIN_KERNEL_LOWERING = False


def platform_impl(impl: str,
                  policy: "vxpolicy.Policy | None" = None) -> str:
    """Platform arm of the lowering policy: on TPU a merged group is ONE
    Mosaic launch; off-TPU the interpret-mode kernels are a correctness
    vehicle, not a dispatch win (grid steps lower to full-buffer copies),
    so merged groups lower to the XLA path instead.  Disabled while
    :func:`pinned_kernel_lowering` is active or when the governing policy
    (``policy``, default the active one) sets ``platform_lowering=False``."""
    pol = vxpolicy.current() if policy is None else policy
    if impl == "pallas" and not _PIN_KERNEL_LOWERING \
            and pol.platform_lowering:
        from repro.kernels import _common
        if _common.interpret_mode():
            return "ref"
    return impl


@contextlib.contextmanager
def pinned_kernel_lowering():
    """Accounting aid: pin merged groups to the kernel lowering (the TPU
    decision) regardless of platform, so jaxpr launch/mask counts taken
    off-TPU reflect the dispatch story (benchmarks, CI gate)."""
    global _PIN_KERNEL_LOWERING
    prev = _PIN_KERNEL_LOWERING
    _PIN_KERNEL_LOWERING = True
    try:
        yield
    finally:
        _PIN_KERNEL_LOWERING = prev


# ---------------------------------------------------------------------------
# Step scheduler: merge same-shape accesses into one super-transaction
# ---------------------------------------------------------------------------

class Handle:
    """Result slot filled by :meth:`StepScheduler.flush`."""
    __slots__ = ("value",)

    def __init__(self):
        self.value = None


@dataclasses.dataclass
class _Req:
    key: tuple
    payload: Any
    handle: Handle


class StepScheduler:
    """Collects a step's shift-routed accesses and executes them merged.

    Since PR 4 the scheduler is a PROGRAM-LEVEL FUSION PASS over the one
    vx pipeline (spec -> plan -> program): each registered access lowers
    to a single-transaction program, same-key programs are merged by
    ``vx.program.fuse`` into ONE wide transaction (payloads stacked along
    a new leading axis; one kernel launch whose mask operand is the single
    shared plan or the concatenation of the group's plans), and the fused
    program executes through ``vx.lower.executor`` — the whole-step
    analogue of LSDO's batched (T, mlen) transaction block, with no
    parallel execution path.

    Grouping keys include the access PLACEMENT: a ``vx.Shard``-annotated
    deinterleave lowers shard-locally under ``shard_map`` and never merges
    with a replicated one.

    Lowering is governed by ONE ``vx.Policy``: ``policy`` (or the ambient
    one) with ``impl`` pinned on top when given — an explicitly passed
    Policy keeps ALL its fields (fusion threshold, platform lowering),
    never just the impl string.  ``platform_policy=False`` is sugar for
    ``platform_lowering=False`` on that policy (pins merged groups to the
    requested impl — used by the launch-accounting tests to exercise the
    kernel lowering off-TPU).
    """

    def __init__(self, impl: str | None = None, *,
                 platform_policy: bool = True,
                 policy: "vxpolicy.Policy | None" = None):
        pol = vxpolicy.resolve(policy).with_impl(impl)
        if not platform_policy:
            pol = dataclasses.replace(pol, platform_lowering=False)
        self.policy = pol
        self.impl = pol.impl
        self._reqs: list[_Req] = []

    def _impl_for(self, total_elems: int) -> str:
        return platform_impl(self.policy.for_elems(total_elems).impl,
                             self.policy)

    # -- access registration ------------------------------------------------
    def deinterleave(self, aos: jax.Array, fields: int,
                     shard=None) -> Handle:
        h = Handle()
        from repro.vx.program import layout_of
        self._reqs.append(_Req(("deint", fields, aos.shape, str(aos.dtype),
                                layout_of(shard)), (aos, shard), h))
        return h

    def interleave(self, parts: Sequence[jax.Array],
                   shard=None) -> Handle:
        parts = list(parts)
        h = Handle()
        from repro.vx.program import layout_of
        key = ("int", len(parts), parts[0].shape, str(parts[0].dtype),
               layout_of(shard))
        self._reqs.append(_Req(key, (parts, shard), h))
        return h

    def gather_strided(self, window: jax.Array, stride: int, offset: int,
                       vl: int) -> Handle:
        h = Handle()
        key = ("gather", window.shape, str(window.dtype), vl)
        self._reqs.append(_Req(key, (window, int(stride), int(offset)), h))
        return h

    # -- execution ----------------------------------------------------------
    def flush(self) -> None:
        groups: dict[tuple, list[_Req]] = {}
        for r in self._reqs:
            groups.setdefault(r.key, []).append(r)
        for key, reqs in groups.items():
            self._run_group(key, reqs)
        self._reqs = []

    def _fused(self, op: str, specs: list, impl: str, shard=None):
        """lower each access -> fuse the programs -> compile ONE executor."""
        from repro.vx import lower as vxlower
        from repro.vx import program as vxprogram
        progs = [vxlower.lower(op, s, impl, shard) for s in specs]
        prog = progs[0] if len(progs) == 1 else vxprogram.fuse(progs)
        return vxlower.executor(prog, tuple(specs), shard)

    def _run_group(self, key: tuple, reqs: list[_Req]) -> None:
        from repro import vx
        pol = self.policy
        kind = key[0]
        if kind == "deint":
            fields = key[1]
            shard = reqs[0].payload[1]
            stack = (reqs[0].payload[0] if len(reqs) == 1
                     else jnp.stack([r.payload[0] for r in reqs]))
            impl = self._impl_for(stack.size)
            spec = vx.Segment(n=stack.shape[-1],
                              fields=fields).bind(stack.dtype)
            outs = self._fused("seg.deint", [spec] * len(reqs), impl,
                               shard)(stack)
            for a, r in enumerate(reqs):
                r.handle.value = (list(outs) if len(reqs) == 1
                                  else [o[a] for o in outs])
        elif kind == "int":
            nf = key[1]
            shard = reqs[0].payload[1]
            if len(reqs) == 1:
                parts = list(reqs[0].payload[0])
            else:
                parts = [jnp.stack([r.payload[0][f] for r in reqs])
                         for f in range(nf)]
            impl = self._impl_for(parts[0].size * nf)
            spec = vx.Segment(n=nf * parts[0].shape[-1],
                              fields=nf).bind(parts[0].dtype)
            out = self._fused("seg.int", [spec] * len(reqs), impl,
                              shard)(parts)
            for a, r in enumerate(reqs):
                r.handle.value = out if len(reqs) == 1 else out[a]
        elif kind == "gather":
            vl = key[3]
            n = key[1][-1]
            stack = (reqs[0].payload[0] if len(reqs) == 1
                     else jnp.stack([r.payload[0] for r in reqs]))
            specs = [vx.Strided(n=n, stride=r.payload[1], offset=r.payload[2],
                                vl=vl).bind(stack.dtype) for r in reqs]
            impl = self._impl_for(stack.size)
            out = self._fused("gather.plan", specs, impl)(stack)
            for a, r in enumerate(reqs):
                r.handle.value = out if len(reqs) == 1 else out[a]
        else:  # pragma: no cover
            raise ValueError(kind)


# -- convenience wrappers (the shapes models actually issue) ----------------

def fuse_deinterleave(arrays: Sequence[jax.Array], fields: int, *,
                      impl: str | None = None,
                      platform_policy: bool = True,
                      policy: "vxpolicy.Policy | None" = None,
                      shard=None) -> list[list[jax.Array]]:
    """One fused segment load for a whole step's same-shape AoS arrays.

    ``shard`` (a ``vx.Shard`` on an outer axis) executes the merged
    transaction shard-locally — seq-sharded serving caches split in place
    instead of being sliced globally."""
    sched = StepScheduler(impl=impl, platform_policy=platform_policy,
                          policy=policy)
    hs = [sched.deinterleave(a, fields, shard=shard) for a in arrays]
    sched.flush()
    return [h.value for h in hs]


def fuse_split_kv(kvs: Sequence[jax.Array], *, impl: str | None = None,
                  platform_policy: bool = True,
                  policy: "vxpolicy.Policy | None" = None,
                  shard=None) -> list[tuple[jax.Array, jax.Array]]:
    """All layers' (…, 2d) KV-cache splits in one launch (FIELD=2)."""
    return [tuple(pair) for pair in
            fuse_deinterleave(kvs, 2, impl=impl,
                              platform_policy=platform_policy,
                              policy=policy, shard=shard)]


def fuse_interleave(groups: Sequence[Sequence[jax.Array]], *,
                    impl: str | None = None,
                    policy: "vxpolicy.Policy | None" = None
                    ) -> list[jax.Array]:
    """One fused segment store for a step's same-shape SoA groups."""
    sched = StepScheduler(impl=impl, policy=policy)
    hs = [sched.interleave(g) for g in groups]
    sched.flush()
    return [h.value for h in hs]


# ---------------------------------------------------------------------------
# Runtime-stride plan bank (lax.switch over compiled plans)
# ---------------------------------------------------------------------------

def _flip(x: jax.Array) -> jax.Array:
    return jnp.flip(x, axis=-1)


@vxcache.memoize("bank.gather")
def _gather_bank(n: int, offset: int, vl: int):
    """16 bank slots: strides 1..8, then -1..-8 (Reverser: plan on the
    reversed element order — a positive-stride plan from the window's low
    end, output reversed).  None marks a (stride, offset, vl) that does not
    fit the window; its slot dispatches to the dynamic fallback."""
    slots = []
    for s in BANK_STRIDES:
        ok = 0 <= offset and offset + (vl - 1) * s < n
        slots.append(shiftplan.gather_plan(n, s, offset, vl) if ok else None)
    for s in BANK_STRIDES:
        base = offset - (vl - 1) * s
        ok = base >= 0 and offset < n
        slots.append(shiftplan.gather_plan(n, s, base, vl) if ok else None)
    return tuple(slots)


@vxcache.memoize("bank.scatter")
def _scatter_bank(n: int, offset: int, vl: int):
    slots = []
    for s in BANK_STRIDES:
        ok = 0 <= offset and offset + (vl - 1) * s < n
        slots.append(shiftplan.scatter_plan(n, s, offset, vl) if ok else None)
    for s in BANK_STRIDES:
        base = offset - (vl - 1) * s
        ok = base >= 0 and offset < n
        slots.append(shiftplan.scatter_plan(n, s, base, vl) if ok else None)
    return tuple(slots)


def _bank_index(stride, lut: np.ndarray) -> jax.Array:
    """stride -> switch branch index (banked slot or 16 = dynamic)."""
    s = jnp.asarray(stride, jnp.int32)
    raw = jnp.where((s >= 1) & (s <= BANK_STRIDES[-1]), s - 1,
                    jnp.where((s <= -1) & (s >= -BANK_STRIDES[-1]),
                              7 - s, 16))
    return jnp.take(jnp.asarray(lut), raw)


def _dynamic_gather(window: jax.Array, stride, offset: int,
                    vl: int) -> jax.Array:
    """Fully dynamic fallback: traced stride of either sign (Reverser by
    output flip), the oracle the bank must match bit-exactly."""
    n = window.shape[-1]
    s = jnp.asarray(stride, jnp.int32)
    s_abs = jnp.maximum(jnp.abs(s), 1)
    base = jnp.where(s < 0, offset + (vl - 1) * s, offset)
    shift, valid = scg.gather_counts(n, s_abs, base, vl)
    res = shiftnet.gather_network(window, shift, valid, axis=-1)
    dense = jax.lax.slice_in_dim(res.payload, 0, vl, axis=-1)
    return jnp.where(s < 0, _flip(dense), dense)


def bank_gather_strided(window: jax.Array, stride, offset: int,
                        vl: int) -> jax.Array:
    """out[..., i] = window[..., offset + i*stride]; stride may be TRACED.

    Banked strides hit compiled constant-mask plans via one ``lax.switch``;
    anything else (or a spec that does not fit the window) routes to the
    dynamic-count network.  Static Python strides skip the dispatch.
    """
    n = window.shape[-1]
    if isinstance(stride, (int, np.integer)):
        stride = int(stride)
        if stride == 0:
            raise ValueError("stride 0 is a broadcast, not a strided access")
        s, rev = abs(stride), stride < 0
        base = offset + (vl - 1) * stride if rev else offset
        plan = shiftplan.gather_plan(n, s, base, vl)
        out = shiftnet.apply_plan(window, plan, axis=-1)
        out = jax.lax.slice_in_dim(out, 0, vl, axis=-1)
        return _flip(out) if rev else out

    slots = _gather_bank(n, offset, vl)
    lut = np.array([i if p is not None else 16
                    for i, p in enumerate(slots)] + [16], np.int32)

    def mk(plan, rev):
        def br(w):
            out = shiftnet.apply_plan(w, plan, axis=-1)
            out = jax.lax.slice_in_dim(out, 0, vl, axis=-1)
            return _flip(out) if rev else out
        return br

    def dead(w):
        return jnp.zeros(w.shape[:-1] + (vl,), w.dtype)

    branches = [mk(p, i >= len(BANK_STRIDES)) if p is not None else dead
                for i, p in enumerate(slots)]
    branches.append(lambda w: _dynamic_gather(w, stride, offset, vl))
    return jax.lax.switch(_bank_index(stride, lut), branches, window)


def _dynamic_scatter(window: jax.Array, values: jax.Array, stride,
                     offset: int) -> jax.Array:
    n = window.shape[-1]
    vl = values.shape[-1]
    s = jnp.asarray(stride, jnp.int32)
    s_abs = jnp.maximum(jnp.abs(s), 1)
    base = jnp.where(s < 0, offset + (vl - 1) * s, offset)
    vals = jnp.where(s < 0, _flip(values), values)
    pad = [(0, 0)] * (values.ndim - 1) + [(0, n - vl)]
    shift, valid = scg.scatter_counts(n, s_abs, base, vl)
    res = shiftnet.scatter_network(jnp.pad(vals, pad), shift, valid, axis=-1)
    return jnp.where(res.valid, res.payload, window)


def bank_scatter_strided(window: jax.Array, values: jax.Array, stride,
                         offset: int) -> jax.Array:
    """window[..., offset + i*stride] = values[..., i]; traced stride OK."""
    n = window.shape[-1]
    vl = values.shape[-1]
    pad = [(0, 0)] * (values.ndim - 1) + [(0, n - vl)]
    if isinstance(stride, (int, np.integer)):
        stride = int(stride)
        if stride == 0:
            raise ValueError("stride 0 is a broadcast, not a strided access")
        s, rev = abs(stride), stride < 0
        base = offset + (vl - 1) * stride if rev else offset
        plan = shiftplan.scatter_plan(n, s, base, vl)
        vals = _flip(values) if rev else values
        routed = shiftnet.apply_plan(jnp.pad(vals, pad), plan, axis=-1)
        return jnp.where(shiftnet._broadcast_const(plan.valid, routed, -1),
                         routed, window)

    slots = _scatter_bank(n, offset, vl)
    lut = np.array([i if p is not None else 16
                    for i, p in enumerate(slots)] + [16], np.int32)

    def mk(plan, rev):
        def br(w, v):
            vals = _flip(v) if rev else v
            routed = shiftnet.apply_plan(jnp.pad(vals, pad), plan, axis=-1)
            return jnp.where(
                shiftnet._broadcast_const(plan.valid, routed, -1), routed, w)
        return br

    def dead(w, v):
        return w

    branches = [mk(p, i >= len(BANK_STRIDES)) if p is not None else dead
                for i, p in enumerate(slots)]
    branches.append(lambda w, v: _dynamic_scatter(w, v, stride, offset))
    return jax.lax.switch(_bank_index(stride, lut), branches, window, values)


def warm(n: int, *, offset: int = 0, vl: int | None = None,
         strided: bool = True, fields: tuple = BANK_FIELDS) -> None:
    """Precompile bank plans for a window width (one-time host cost, so
    the first step never pays plan compilation).  ``strided=False`` skips
    the ±stride gather/scatter slots — serving only consults the segment
    plans (the KV FIELD=2 split), so the engine warms just those."""
    if strided:
        vl = vl if vl is not None else n // BANK_STRIDES[-1]
        _gather_bank(n, offset, vl)
        _scatter_bank(n, offset, vl)
    for f in fields:
        if n % f == 0:
            shiftplan.segment_deinterleave_plans(n, f)
            shiftplan.segment_interleave_plans(n, f)


# ---------------------------------------------------------------------------
# Runtime-count member of the bank: MoE compaction
# ---------------------------------------------------------------------------

def compact_indices(mask: jax.Array, cap: int) -> jax.Array:
    """Pack the indices of set bits of ``mask`` (n,) to the front, first
    ``cap`` kept.  Routing decisions are derived once from the prefix-sum
    counts (shiftnet.layer_masks); the id payload then pays ONE static
    shift + ONE select per layer — no triple-shift, no conflict reductions
    (compaction is order-preserving and separation-non-increasing, hence
    GSN-safe by construction)."""
    n = mask.shape[0]
    ids = jnp.arange(n, dtype=jnp.int32)
    shift, valid = scg.compaction_counts(mask)
    masks, _ = shiftnet.layer_masks(shift, valid, toward_zero=True,
                                    lsb_first=True)
    if masks.shape[0]:
        ids = shiftnet.apply_layer_masks(ids, masks, axis=0,
                                         toward_zero=True, lsb_first=True)
    return jax.lax.slice(ids, (0,), (min(cap, n),))


# ---------------------------------------------------------------------------
# Launch / mask-upload accounting (jaxpr-level; no timing flakiness)
# ---------------------------------------------------------------------------

def _child_jaxprs(v) -> list:
    if hasattr(v, "eqns"):                     # core.Jaxpr
        return [v]
    if hasattr(v, "jaxpr") and hasattr(v.jaxpr, "eqns"):   # ClosedJaxpr
        return [v.jaxpr]
    if isinstance(v, (list, tuple)):
        out = []
        for x in v:
            out.extend(_child_jaxprs(x))
        return out
    return []


def _count_jaxpr(jaxpr) -> tuple[int, int]:
    launches = mask_ops = 0
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == "pallas_call":
            launches += 1
            for v in eqn.invars:
                aval = getattr(v, "aval", None)
                if aval is not None and jnp.issubdtype(aval.dtype,
                                                       jnp.integer):
                    mask_ops += 1
        for v in eqn.params.values():
            for sub in _child_jaxprs(v):
                l, m = _count_jaxpr(sub)
                launches += l
                mask_ops += m
    return launches, mask_ops


def jaxpr_access_counts(fn, *args) -> tuple[int, int]:
    """(kernel_launches, mask_operands) of ``fn(*args)``.

    Launches = pallas_call equations anywhere in the jaxpr (scan/cond/pjit
    bodies included).  Mask operands = integer-dtype inputs feeding those
    calls — the stacked take-mask / occupancy uploads (payloads in the
    counted paths are floating point).

    A fresh wrapper defeats the pjit trace cache (keyed on function
    identity): counts must reflect the CURRENT lowering policy (e.g.
    :func:`pinned_kernel_lowering`), not a previously cached trace."""
    closed = jax.make_jaxpr(lambda *a: fn(*a))(*args)
    return _count_jaxpr(closed.jaxpr)
