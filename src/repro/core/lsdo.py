"""LSDO — Load/Store Data Organization (EARTH §4.4, §5.1).

The Load/Store Address Sequencer (LAS/SAS) splits a strided vector access
into the *minimum* number of aligned-MLEN transactions: all elements falling
into the same aligned region are coalesced into one memory request (the
paper's headline win: 32 one-byte requests -> 1 cache-line request).

TPU adaptation: an "aligned MLEN region" is a contiguous tile of the source
buffer (one HBM->VMEM block transfer); the per-transaction reorganization is
the GSN/SSN shift network.  The planner below is static Python (strides and
vector lengths are compile-time at our call sites), producing a plan the JAX
apply functions consume — mirroring how LAS produces LIFQ entries consumed by
the datapath.

Negative strides are handled by the Reverser (EARTH §3.2.2): plan on the
reversed element order, then reverse the assembled output.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp

from repro.core import scg, shiftnet


@dataclasses.dataclass(frozen=True)
class Transaction:
    """One coalesced memory request (a LIFQ/SIFQ entry)."""
    region: int        # aligned region index (region_start = region * mlen)
    first_elem: int    # index of the first vector element served
    count: int         # number of vector elements served by this request
    offset: int        # in-region offset of the first element


@dataclasses.dataclass(frozen=True)
class AccessPlan:
    base: int
    stride: int        # in elements; may be negative (Reverser engaged)
    vl: int
    mlen: int          # elements per aligned region / transaction
    reversed: bool
    transactions: tuple[Transaction, ...]

    @property
    def num_transactions(self) -> int:
        return len(self.transactions)

    @property
    def element_wise_transactions(self) -> int:
        """What Saturn-style element-wise access would issue."""
        return self.vl

    @property
    def coalescing_factor(self) -> float:
        return self.vl / max(1, len(self.transactions))


def plan_strided(base: int, stride: int, vl: int, mlen: int) -> AccessPlan:
    """LAS/SAS split: group elements by aligned region (order-preserving)."""
    if vl <= 0:
        return AccessPlan(base, stride, vl, mlen, False, ())
    rev = stride < 0
    b, s = (base + (vl - 1) * stride, -stride) if rev else (base, stride)
    s = max(s, 1) if stride == 0 else s  # stride 0 == broadcast: one region
    txs: list[Transaction] = []
    cur_region, first, count, off = None, 0, 0, 0
    for i in range(vl):
        addr = b + i * s
        region, in_off = addr // mlen, addr % mlen
        if region != cur_region:
            if count:
                txs.append(Transaction(cur_region, first, count, off))
            cur_region, first, count, off = region, i, 1, in_off
        else:
            count += 1
    txs.append(Transaction(cur_region, first, count, off))
    return AccessPlan(base, stride, vl, mlen, rev, tuple(txs))


def load_strided(buffer: jax.Array, plan: AccessPlan) -> jax.Array:
    """Gather ``vl`` strided elements via coalesced regions + GSN.

    buffer: flat (N,) array. Returns (vl,) dense elements.
    """
    s = abs(plan.stride) if plan.stride != 0 else 1
    pieces = []
    for tx in plan.transactions:
        region = jax.lax.dynamic_slice(buffer, (tx.region * plan.mlen,),
                                       (plan.mlen,))
        shift, valid = scg.gather_counts(plan.mlen, s, tx.offset, tx.count)
        routed = shiftnet.gather_network(region, shift, valid)
        pieces.append(jax.lax.slice(routed.payload, (0,), (tx.count,)))
    out = jnp.concatenate(pieces) if len(pieces) > 1 else pieces[0]
    if plan.reversed:
        out = out[::-1]
    return out


def store_strided(buffer: jax.Array, values: jax.Array, plan: AccessPlan) -> jax.Array:
    """Scatter ``vl`` dense elements to strided positions via SSN + coalesced
    region writes. Returns the updated buffer (functional)."""
    s = abs(plan.stride) if plan.stride != 0 else 1
    vals = values[::-1] if plan.reversed else values
    for tx in plan.transactions:
        piece = jax.lax.dynamic_slice(vals, (tx.first_elem,), (tx.count,))
        piece = jnp.pad(piece, (0, plan.mlen - tx.count))
        shift, valid = scg.scatter_counts(plan.mlen, s, tx.offset, tx.count)
        routed = shiftnet.scatter_network(piece, shift, valid)
        start = tx.region * plan.mlen
        old = jax.lax.dynamic_slice(buffer, (start,), (plan.mlen,))
        merged = jnp.where(routed.valid, routed.payload, old)
        buffer = jax.lax.dynamic_update_slice(buffer, merged, (start,))
    return buffer


def plan_segment_unit(base: int, fields: int, vl: int, mlen: int) -> list[AccessPlan]:
    """Field-wise segment unit-stride access (EARTH §5.2): FIELDS strided
    plans, one per field, each with stride=FIELDS, offset advanced by EEWB."""
    return [plan_strided(base + f, fields, vl, mlen) for f in range(fields)]


def transactions_saved(plans: Sequence[AccessPlan]) -> tuple[int, int]:
    """(coalesced, element_wise) request counts — the Fig. 12 x-axis quantity."""
    co = sum(p.num_transactions for p in plans)
    ew = sum(p.element_wise_transactions for p in plans)
    return co, ew
