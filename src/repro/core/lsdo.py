"""LSDO — Load/Store Data Organization (EARTH §4.4, §5.1).

The Load/Store Address Sequencer (LAS/SAS) splits a strided vector access
into the *minimum* number of aligned-MLEN transactions: all elements falling
into the same aligned region are coalesced into one memory request (the
paper's headline win: 32 one-byte requests -> 1 cache-line request).

TPU adaptation: an "aligned MLEN region" is a contiguous tile of the source
buffer (one HBM->VMEM block transfer); the per-transaction reorganization is
the GSN/SSN shift network.  The planner below is static Python (strides and
vector lengths are compile-time at our call sites), producing a plan the JAX
apply functions consume — mirroring how LAS produces LIFQ entries consumed by
the datapath.

Negative strides are handled by the Reverser (EARTH §3.2.2): plan on the
reversed element order, then reverse the assembled output.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import scg, shiftnet, shiftplan


@dataclasses.dataclass(frozen=True)
class Transaction:
    """One coalesced memory request (a LIFQ/SIFQ entry)."""
    region: int        # aligned region index (region_start = region * mlen)
    first_elem: int    # index of the first vector element served
    count: int         # number of vector elements served by this request
    offset: int        # in-region offset of the first element


@dataclasses.dataclass(frozen=True)
class AccessPlan:
    base: int
    stride: int        # in elements; may be negative (Reverser engaged)
    vl: int
    mlen: int          # elements per aligned region / transaction
    reversed: bool
    transactions: tuple[Transaction, ...]

    @property
    def num_transactions(self) -> int:
        return len(self.transactions)

    @property
    def element_wise_transactions(self) -> int:
        """What Saturn-style element-wise access would issue."""
        return self.vl

    @property
    def coalescing_factor(self) -> float:
        return self.vl / max(1, len(self.transactions))


def plan_strided(base: int, stride: int, vl: int, mlen: int) -> AccessPlan:
    """LAS/SAS split: group elements by aligned region (order-preserving)."""
    if vl <= 0:
        return AccessPlan(base, stride, vl, mlen, False, ())
    rev = stride < 0
    b, s = (base + (vl - 1) * stride, -stride) if rev else (base, stride)
    s = max(s, 1) if stride == 0 else s  # stride 0 == broadcast: one region
    txs: list[Transaction] = []
    cur_region, first, count, off = None, 0, 0, 0
    for i in range(vl):
        addr = b + i * s
        region, in_off = addr // mlen, addr % mlen
        if region != cur_region:
            if count:
                txs.append(Transaction(cur_region, first, count, off))
            cur_region, first, count, off = region, i, 1, in_off
        else:
            count += 1
    txs.append(Transaction(cur_region, first, count, off))
    return AccessPlan(base, stride, vl, mlen, rev, tuple(txs))


def _tx_meta(plan: AccessPlan):
    txs = plan.transactions
    starts = np.array([tx.region * plan.mlen for tx in txs])
    offsets = tuple(tx.offset for tx in txs)
    counts = tuple(tx.count for tx in txs)
    firsts = tuple(tx.first_elem for tx in txs)
    return starts, offsets, counts, firsts


def load_strided(buffer: jax.Array, plan: AccessPlan, *,
                 batched: bool = True) -> jax.Array:
    """Gather ``vl`` strided elements via coalesced regions + GSN.

    buffer: flat (N,) array. Returns (vl,) dense elements.

    The default path stacks ALL transactions into one (T, mlen) block
    (a single constant-index gather), routes the whole block through ONE
    compiled batched shift plan, and reassembles with one static take —
    replacing the per-transaction Python loop of dynamic_slice + network
    passes.  ``batched=False`` keeps the loop/dynamic-count fallback (the
    property-test oracle and the shape runtime-stride callers use).
    """
    if plan.vl <= 0:
        return jnp.zeros((0,), buffer.dtype)
    if not batched:
        return _load_strided_loop(buffer, plan)
    s = abs(plan.stride) if plan.stride != 0 else 1
    mlen = plan.mlen
    starts, offsets, counts, _ = _tx_meta(plan)
    idx = starts[:, None] + np.arange(mlen)[None, :]          # (T, mlen)
    block = jnp.take(buffer, jnp.asarray(np.minimum(idx, buffer.shape[0] - 1)))
    bplan = shiftplan.batched_gather_plan(mlen, s, offsets, counts)
    routed = shiftnet.apply_plan(block, bplan, axis=-1)
    flat_idx = np.concatenate([t * mlen + np.arange(c)
                               for t, c in enumerate(counts)])
    out = jnp.take(routed.reshape(-1), jnp.asarray(flat_idx))
    if plan.reversed:
        out = out[::-1]
    return out


def load_strided_many(buffer: jax.Array,
                      plans: Sequence[AccessPlan]) -> list[jax.Array]:
    """Whole-step LSDO fusion: ALL transactions of several same-mlen
    accesses stacked into one (sum_T, mlen) block, routed by ONE
    multi-access plan (core/shiftplan.multi_gather_plan) with a single
    constant mask operand — one gather, one network application, one
    reassembly per step instead of one per access.

    Returns one dense (vl,) output per plan (Reverser applied per access).
    """
    plans = list(plans)
    live = [p for p in plans if p.vl > 0]
    if not live:
        return [jnp.zeros((0,), buffer.dtype) for _ in plans]
    mlen = live[0].mlen
    assert all(p.mlen == mlen for p in live), "fusion needs one mlen"
    rows: list[tuple[int, int, int]] = []
    row_starts: list[int] = []
    for p in live:
        s = abs(p.stride) if p.stride != 0 else 1
        starts, offsets, counts, _ = _tx_meta(p)
        rows.extend((s, o, c) for o, c in zip(offsets, counts))
        row_starts.extend(int(x) for x in starts)
    mplan = shiftplan.multi_gather_plan(mlen, tuple(rows))
    idx = np.asarray(row_starts)[:, None] + np.arange(mlen)[None, :]
    block = jnp.take(buffer, jnp.asarray(np.minimum(idx, buffer.shape[0] - 1)))
    routed = shiftnet.apply_plan(block, mplan, axis=-1).reshape(-1)
    outs: list[jax.Array] = []
    row0 = 0
    for p in plans:
        if p.vl <= 0:
            outs.append(jnp.zeros((0,), buffer.dtype))
            continue
        counts = [tx.count for tx in p.transactions]
        flat_idx = np.concatenate([(row0 + t) * mlen + np.arange(c)
                                   for t, c in enumerate(counts)])
        out = jnp.take(routed, jnp.asarray(flat_idx))
        outs.append(out[::-1] if p.reversed else out)
        row0 += len(counts)
    return outs


def _region_lanes(buffer: jax.Array, start: int, mlen: int) -> jax.Array:
    """Read one aligned region with per-lane clipping: a region whose tail
    hangs past the buffer end must NOT be start-clamped (dynamic_slice
    would silently shift the whole window and mis-align every lane); the
    clipped tail lanes hold garbage but are invalid by construction."""
    idx = np.minimum(start + np.arange(mlen), buffer.shape[0] - 1)
    return jnp.take(buffer, jnp.asarray(idx))


def _load_strided_loop(buffer: jax.Array, plan: AccessPlan) -> jax.Array:
    """Per-transaction dynamic-count fallback."""
    s = abs(plan.stride) if plan.stride != 0 else 1
    pieces = []
    for tx in plan.transactions:
        region = _region_lanes(buffer, tx.region * plan.mlen, plan.mlen)
        shift, valid = scg.gather_counts(plan.mlen, s, tx.offset, tx.count)
        routed = shiftnet.gather_network(region, shift, valid)
        pieces.append(jax.lax.slice(routed.payload, (0,), (tx.count,)))
    out = jnp.concatenate(pieces) if len(pieces) > 1 else pieces[0]
    if plan.reversed:
        out = out[::-1]
    return out


def store_strided(buffer: jax.Array, values: jax.Array, plan: AccessPlan,
                  *, batched: bool = True) -> jax.Array:
    """Scatter ``vl`` dense elements to strided positions via SSN + coalesced
    region writes. Returns the updated buffer (functional).

    Default path mirrors :func:`load_strided`: one stacked (T, mlen) block
    built with a static take, ONE batched scatter-plan pass, one merged
    constant-index region writeback (aligned regions are disjoint by
    construction, so the scatter has no duplicate targets)."""
    if plan.vl <= 0:
        return buffer
    if not batched:
        return _store_strided_loop(buffer, values, plan)
    s = abs(plan.stride) if plan.stride != 0 else 1
    mlen = plan.mlen
    vals = values[::-1] if plan.reversed else values
    starts, offsets, counts, firsts = _tx_meta(plan)
    T = len(counts)
    src = np.array(firsts)[:, None] + np.arange(mlen)[None, :]
    lane_valid = np.arange(mlen)[None, :] < np.array(counts)[:, None]
    src = np.clip(src, 0, plan.vl - 1)
    block = jnp.where(jnp.asarray(lane_valid),
                      jnp.take(vals, jnp.asarray(src)),
                      jnp.zeros((T, mlen), vals.dtype))
    bplan = shiftplan.batched_scatter_plan(mlen, s, offsets, counts)
    routed = shiftnet.apply_plan(block, bplan, axis=-1)
    idx = starts[:, None] + np.arange(mlen)[None, :]
    old = jnp.take(buffer, jnp.asarray(np.minimum(idx, buffer.shape[0] - 1)))
    merged = jnp.where(jnp.asarray(bplan.valid), routed, old)
    return buffer.at[jnp.asarray(idx)].set(merged, mode="drop")


def _store_strided_loop(buffer: jax.Array, values: jax.Array,
                        plan: AccessPlan) -> jax.Array:
    s = abs(plan.stride) if plan.stride != 0 else 1
    vals = values[::-1] if plan.reversed else values
    for tx in plan.transactions:
        piece = jax.lax.dynamic_slice(vals, (tx.first_elem,), (tx.count,))
        piece = jnp.pad(piece, (0, plan.mlen - tx.count))
        shift, valid = scg.scatter_counts(plan.mlen, s, tx.offset, tx.count)
        routed = shiftnet.scatter_network(piece, shift, valid)
        start = tx.region * plan.mlen
        old = _region_lanes(buffer, start, plan.mlen)
        merged = jnp.where(routed.valid, routed.payload, old)
        idx = start + np.arange(plan.mlen)
        buffer = buffer.at[jnp.asarray(idx)].set(merged, mode="drop")
    return buffer


def plan_segment_unit(base: int, fields: int, vl: int, mlen: int) -> list[AccessPlan]:
    """Field-wise segment unit-stride access (EARTH §5.2): FIELDS strided
    plans, one per field, each with stride=FIELDS, offset advanced by EEWB."""
    return [plan_strided(base + f, fields, vl, mlen) for f in range(fields)]


def transactions_saved(plans: Sequence[AccessPlan]) -> tuple[int, int]:
    """(coalesced, element_wise) request counts — the Fig. 12 x-axis quantity."""
    co = sum(p.num_transactions for p in plans)
    ew = sum(p.element_wise_transactions for p in plans)
    return co, ew
