"""DROM — the unified Data ReOrganization Module API (EARTH §4.3).

High-level, batched entry points used by the rest of the framework.  Each
op dispatches to either the pure-JAX reference (XLA path — also what the
512-device dry-run lowers) or the Pallas TPU kernels (validated in
interpret mode on CPU, compiled for real TPUs).

Semantics are defined by kernels/ref.py; this module only routes.
"""
from __future__ import annotations

from typing import Literal, Sequence

import jax

Impl = Literal["ref", "pallas"]
_DEFAULT: Impl = "ref"


def default_impl() -> Impl:
    try:
        platform = jax.devices()[0].platform
    except Exception:  # pragma: no cover
        platform = "cpu"
    return "pallas" if platform == "tpu" else _DEFAULT


def gather_strided(window: jax.Array, stride: int, offset: int, vl: int,
                   *, impl: Impl | None = None) -> jax.Array:
    """Dense (..., vl) from strided positions of a coalesced (..., n) window."""
    from repro.kernels import ops
    return ops.gather_strided(window, stride, offset, vl,
                              impl=impl or default_impl())


def scatter_strided(window: jax.Array, values: jax.Array, stride: int,
                    offset: int, *, impl: Impl | None = None) -> jax.Array:
    """Place (..., vl) dense values at strided positions of (..., n) window."""
    from repro.kernels import ops
    return ops.scatter_strided(window, values, stride, offset,
                               impl=impl or default_impl())


def deinterleave(aos: jax.Array, fields: int, *,
                 impl: Impl | None = None) -> list[jax.Array]:
    """AoS (..., fields*m) -> [ (..., m) ] * fields   (segment load)."""
    from repro.kernels import ops
    return ops.deinterleave(aos, fields, impl=impl or default_impl())


def interleave(soa: Sequence[jax.Array], *, impl: Impl | None = None) -> jax.Array:
    """[ (..., m) ] * fields -> AoS (..., fields*m)   (segment store)."""
    from repro.kernels import ops
    return ops.interleave(list(soa), impl=impl or default_impl())


def gather_strided_rt(window: jax.Array, stride, offset: int, vl: int,
                      *, impl: Impl | None = None) -> jax.Array:
    """Runtime-stride gather via the plan bank (core/accessfuse.py):
    traced strides ±1..8 hit compiled masks through ``lax.switch``."""
    from repro.kernels import ops
    return ops.gather_strided_rt(window, stride, offset, vl,
                                 impl=impl or default_impl())


def scatter_strided_rt(window: jax.Array, values: jax.Array, stride,
                       offset: int, *, impl: Impl | None = None) -> jax.Array:
    from repro.kernels import ops
    return ops.scatter_strided_rt(window, values, stride, offset,
                                  impl=impl or default_impl())


def deinterleave_many(aos_list: Sequence[jax.Array], fields: int, *,
                      impl: Impl | None = None) -> list[list[jax.Array]]:
    """Step-fused segment load: A same-shape AoS arrays, ONE launch."""
    from repro.kernels import ops
    return ops.deinterleave_many(list(aos_list), fields,
                                 impl=impl or default_impl())


def interleave_many(groups: Sequence[Sequence[jax.Array]], *,
                    impl: Impl | None = None) -> list[jax.Array]:
    """Step-fused segment store: A same-shape SoA groups, ONE launch."""
    from repro.kernels import ops
    return ops.interleave_many([list(g) for g in groups],
                               impl=impl or default_impl())


def compact_rows(rows: jax.Array, mask: jax.Array, *,
                 impl: Impl | None = None) -> tuple[jax.Array, jax.Array]:
    """Pack masked (n, d) rows to the front, order preserved.

    Returns (packed_rows, packed_valid). The EARTH gather network with
    prefix-sum SCG — the MoE dispatch primitive."""
    from repro.kernels import ops
    return ops.compact_rows(rows, mask, impl=impl or default_impl())


def expand_rows(packed: jax.Array, mask: jax.Array, *,
                impl: Impl | None = None) -> jax.Array:
    """Inverse of compact_rows: scatter packed rows back to mask positions
    (zeros elsewhere)."""
    from repro.kernels import ops
    return ops.expand_rows(packed, mask, impl=impl or default_impl())
