"""DEPRECATED — legacy DROM batched entry points, superseded by ``repro.vx``.

This module was the high-level routing layer of PRs 0-2.  Its job —
choosing a lowering per call via ``impl=`` strings and a platform probe —
now belongs to ``vx.Policy`` (explicit arg > ``with vx.use(...)`` scope >
``REPRO_VX_IMPL`` env var > platform default).  Each wrapper below emits a
:class:`DeprecationWarning` and delegates to the vx verbs; internal code
must call ``vx`` directly (CI escalates the shim warnings to errors).
See DESIGN.md §10 for the migration map.
"""
from __future__ import annotations

import warnings
from typing import Literal, Sequence

import jax

from repro import vx

Impl = Literal["ref", "pallas"]


def _warn(name: str, repl: str) -> None:
    warnings.warn(
        f"repro.core.drom.{name} is deprecated; use {repl} "
        f"(see DESIGN.md §10)", DeprecationWarning, stacklevel=3)


def default_impl() -> Impl:
    """One knob for the whole stack: resolves through
    :meth:`vx.Policy.default` (``REPRO_VX_IMPL`` env var, else platform)."""
    _warn("default_impl", "vx.Policy.default().impl")
    return vx.Policy.default().impl


def gather_strided(window: jax.Array, stride: int, offset: int, vl: int,
                   *, impl: Impl | None = None) -> jax.Array:
    """Dense (..., vl) from strided positions of a coalesced (..., n) window."""
    _warn("gather_strided", "vx.gather(vx.Strided(...), window)")
    spec = vx.Strided(n=window.shape[-1], stride=stride, vl=vl,
                      offset=offset)
    return vx.gather(spec, window, policy=impl)


def scatter_strided(window: jax.Array, values: jax.Array, stride: int,
                    offset: int, *, impl: Impl | None = None) -> jax.Array:
    """Place (..., vl) dense values at strided positions of (..., n) window."""
    _warn("scatter_strided", "vx.scatter(vx.Strided(...), window, values)")
    spec = vx.Strided(n=window.shape[-1], stride=stride,
                      vl=values.shape[-1], offset=offset)
    return vx.scatter(spec, window, values, policy=impl)


def deinterleave(aos: jax.Array, fields: int, *,
                 impl: Impl | None = None) -> list[jax.Array]:
    """AoS (..., fields*m) -> [ (..., m) ] * fields   (segment load)."""
    _warn("deinterleave", "vx.transpose(vx.Segment(...), aos)")
    return vx.transpose(vx.Segment(n=aos.shape[-1], fields=fields), aos,
                        policy=impl)


def interleave(soa: Sequence[jax.Array], *, impl: Impl | None = None) -> jax.Array:
    """[ (..., m) ] * fields -> AoS (..., fields*m)   (segment store)."""
    _warn("interleave", "vx.transpose(vx.Segment(...), [fields...])")
    parts = list(soa)
    spec = vx.Segment(n=len(parts) * parts[0].shape[-1], fields=len(parts))
    return vx.transpose(spec, parts, policy=impl)


def gather_strided_rt(window: jax.Array, stride, offset: int, vl: int,
                      *, impl: Impl | None = None) -> jax.Array:
    """Runtime-stride gather via the plan bank (core/accessfuse.py)."""
    _warn("gather_strided_rt",
          "vx.gather(vx.Strided(stride=vx.BANK, ...), window, stride=s)")
    spec = vx.Strided(n=window.shape[-1], stride=vx.BANK, vl=vl,
                      offset=offset)
    return vx.gather(spec, window, stride=stride, policy=impl)


def scatter_strided_rt(window: jax.Array, values: jax.Array, stride,
                       offset: int, *, impl: Impl | None = None) -> jax.Array:
    _warn("scatter_strided_rt",
          "vx.scatter(vx.Strided(stride=vx.BANK, ...), window, values, "
          "stride=s)")
    spec = vx.Strided(n=window.shape[-1], stride=vx.BANK,
                      vl=values.shape[-1], offset=offset)
    return vx.scatter(spec, window, values, stride=stride,
                      policy=impl)


def deinterleave_many(aos_list: Sequence[jax.Array], fields: int, *,
                      impl: Impl | None = None) -> list[list[jax.Array]]:
    """Step-fused segment load: A same-shape AoS arrays, ONE launch."""
    _warn("deinterleave_many", "vx.gather_many(vx.Segment(...), aos_list)")
    spec = vx.Segment(n=aos_list[0].shape[-1], fields=fields)
    return vx.gather_many(spec, list(aos_list), policy=impl)


def interleave_many(groups: Sequence[Sequence[jax.Array]], *,
                    impl: Impl | None = None) -> list[jax.Array]:
    """Step-fused segment store: A same-shape SoA groups, ONE launch."""
    _warn("interleave_many", "vx.scatter_many(vx.Segment(...), groups)")
    nf = len(groups[0])
    spec = vx.Segment(n=nf * groups[0][0].shape[-1], fields=nf)
    return vx.scatter_many(spec, [list(g) for g in groups],
                           policy=impl)


def compact_rows(rows: jax.Array, mask: jax.Array, *,
                 impl: Impl | None = None) -> tuple[jax.Array, jax.Array]:
    """Pack masked (n, d) rows to the front, order preserved."""
    _warn("compact_rows", "vx.compact(vx.Compact(...), mask, rows)")
    return vx.compact(vx.Compact(n=rows.shape[0]), mask, rows,
                      policy=impl)


def expand_rows(packed: jax.Array, mask: jax.Array, *,
                impl: Impl | None = None) -> jax.Array:
    """Inverse of compact_rows: scatter packed rows back to mask positions."""
    _warn("expand_rows", "vx.scatter(vx.Compact(...), mask, packed)")
    return vx.scatter(vx.Compact(n=mask.shape[0]), mask, packed,
                      policy=impl)
