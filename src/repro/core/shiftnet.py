"""EARTH shift networks (GSN / SSN) as pure-JAX algorithms.

The paper's DROM routes elements through ``log2(n)`` layers, each performing a
power-of-two shift when the corresponding bit of a per-element *shift count*
is set (EARTH §4.1).  For mappings that are order-preserving and
separation-monotone the routing is conflict-free (EARTH §4.1.4), i.e. at no
layer do two elements land in the same slot.

TPU adaptation: a layer is a *static* lane shift by ``2**l`` (compile-time
constant — cheap VREG data movement on TPU) plus a ``jnp.where`` select with a
dynamic mask.  ``log2(n)`` such passes replace an arbitrary gather, exactly as
EARTH's layered network replaces a byte crossbar.

Conventions
-----------
* GSN ("gather"): elements move toward LOWER indices; bits are consumed
  LSB -> MSB (paper Fig. 6, top-down).
* SSN ("scatter"): elements move toward HIGHER indices; bits are consumed
  MSB -> LSB (the mirrored network, bottom-up).
* ``shiftcnt`` is carried alongside the payload so each layer can test its
  bit after previous moves.
* All shifts are non-circular (EARTH's diagonal links do not wrap).
"""
from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp


def _num_layers(n: int) -> int:
    """Layers needed so any shift in [0, n-1] is representable."""
    if n <= 1:
        return 0
    return max(1, math.ceil(math.log2(n)))


def shift_static(x: jax.Array, k: int, axis: int, *, fill=0) -> jax.Array:
    """Non-circular static shift: result[i] = x[i + k] (k may be negative).

    Vacated slots are filled with ``fill``.  ``k`` is a Python int so the op
    lowers to slice+pad (static lane movement on TPU, no gather).
    """
    if k == 0:
        return x
    n = x.shape[axis]
    if abs(k) >= n:
        return jnp.full_like(x, fill)
    pad = [(0, 0)] * x.ndim
    idx = [slice(None)] * x.ndim
    if k > 0:  # pull from higher indices; pad at the high end
        idx[axis] = slice(k, None)
        pad[axis] = (0, k)
    else:  # pull from lower indices; pad at the low end
        idx[axis] = slice(0, n + k)
        pad[axis] = (-k, 0)
    return jnp.pad(x[tuple(idx)], pad, constant_values=fill)


class RouteResult(NamedTuple):
    payload: jax.Array
    valid: jax.Array
    conflict: jax.Array  # scalar bool: any slot collision or element loss


def _route(
    payload: jax.Array,
    shiftcnt: jax.Array,
    valid: jax.Array,
    *,
    axis: int,
    toward_zero: bool,
    lsb_first: bool,
) -> RouteResult:
    """Shared GSN/SSN layer loop.

    payload : (..., n, ...) data to route along ``axis``.
    shiftcnt: int32, broadcastable to payload along ``axis`` (commonly shaped
              like payload or with trailing singleton dims for row payloads).
    valid   : bool, same broadcast rule.
    """
    n = payload.shape[axis]
    layers = _num_layers(n)
    order = range(layers) if lsb_first else range(layers - 1, -1, -1)
    direction = 1 if toward_zero else -1  # arg to shift_static

    shiftcnt = shiftcnt.astype(jnp.int32)
    valid = valid.astype(bool)
    conflict = jnp.zeros((), dtype=bool)
    n_valid0 = jnp.sum(valid.astype(jnp.int32))

    for l in order:
        k = 1 << l
        bit = (shiftcnt >> l) & 1
        stay = valid & (bit == 0)
        cand_payload = shift_static(payload, direction * k, axis)
        cand_shift = shift_static(shiftcnt, direction * k, axis)
        cand_valid = (
            shift_static(valid, direction * k, axis, fill=False)
            & (((cand_shift >> l) & 1) == 1)
        )
        conflict = conflict | jnp.any(cand_valid & stay)
        payload = jnp.where(cand_valid, cand_payload, payload)
        shiftcnt = jnp.where(cand_valid, cand_shift, shiftcnt)
        valid = cand_valid | stay

    # Element loss (shifted off the edge) also shows up as a count drop.
    conflict = conflict | (jnp.sum(valid.astype(jnp.int32)) != n_valid0)
    return RouteResult(payload, valid, conflict)


# ---------------------------------------------------------------------------
# Compiled-plan path (core/shiftplan.py): constant masks, pruned layers,
# ONE static shift + ONE select per active layer.  The dynamic _route above
# stays as the runtime-count fallback and the property-test oracle.
# ---------------------------------------------------------------------------

def _broadcast_const(mask, x: jax.Array, axis: int) -> jax.Array:
    """Lift a compile-time (n,) or (..., n) mask to x's rank along axis."""
    m = jnp.asarray(mask)
    axis = axis % x.ndim
    if m.ndim == 1:
        shape = [1] * x.ndim
        shape[axis] = m.shape[0]
        return m.reshape(shape)
    # stacked (T, n) masks: align trailing dims against (..., T, n)
    shape = [1] * (x.ndim - m.ndim) + list(m.shape)
    return m.reshape(shape)


def _apply_layer(x: jax.Array, shifts, masks, axis: int) -> jax.Array:
    """One plan layer: all (shift, mask) pairs read the same snapshot.

    Exchange layers (two shifts, e.g. Benes stages) lower to a single
    3-way ``lax.select_n`` — measurably cheaper than chained wheres."""
    if len(shifts) == 2:
        idx = (masks[0].astype(jnp.int32) + 2 * masks[1].astype(jnp.int32))
        idx = jnp.broadcast_to(idx, x.shape)
        return jax.lax.select_n(idx, x,
                                shift_static(x, shifts[0], axis),
                                shift_static(x, shifts[1], axis))
    y = x
    for s, m in zip(shifts, masks):
        y = jnp.where(m, shift_static(x, s, axis), y)
    return y


def apply_plan(x: jax.Array, plan, *, axis: int = -1) -> jax.Array:
    """Run a compiled ShiftPlan: each active layer selects between the
    input snapshot and statically shifted copies under constant masks."""
    assert not plan.conflict, "conflicting plan (illegal mapping)"
    axis = axis % x.ndim
    assert x.shape[axis] == plan.n, (x.shape, axis, plan.n)
    for layer in plan.layers:
        masks = [_broadcast_const(m, x, axis) for m in layer.masks]
        x = _apply_layer(x, layer.shifts, masks, axis)
    return x


def plan_mask_stack(plan) -> "np.ndarray":
    """Stack a plan's take-masks into one (S, n) host array.

    Pallas kernels cannot close over non-scalar constants, so the masks
    ride in as ONE stacked operand (constant at the jit boundary — XLA
    still folds it); the shift amounts and layer structure stay static
    Python in the kernel closure."""
    import numpy as np
    rows = [m for layer in plan.layers for m in layer.masks]
    if not rows:
        return np.zeros((0, plan.n), bool)
    return np.stack(rows)


def apply_plan_operand(x: jax.Array, masks: jax.Array, plan, *,
                       axis: int = -1) -> jax.Array:
    """apply_plan with the masks as a traced (S, n) operand (kernel use).

    Converts the operand to bool ONCE (a per-op ``!= 0`` defeats fusion,
    5x slower measured) and uses the same select_n lowering as apply_plan.
    """
    assert not plan.conflict, "conflicting plan (illegal mapping)"
    axis = axis % x.ndim
    assert x.shape[axis] == plan.n, (x.shape, axis, plan.n)
    shape = [1] * x.ndim
    shape[axis] = plan.n
    if masks.dtype != jnp.bool_:
        masks = masks != 0
    i = 0
    for layer in plan.layers:
        rows = [masks[i + j].reshape(shape)
                for j in range(len(layer.shifts))]
        i += len(layer.shifts)
        x = _apply_layer(x, layer.shifts, rows, axis)
    return x


# ---------------------------------------------------------------------------
# Precomputed-mask path for RUNTIME counts (MoE compaction): the per-layer
# routing decisions are computed ONCE on the (n,)-wide counts, then the wide
# payload pays one shift + one select per layer instead of the triple shift.
# ---------------------------------------------------------------------------

def layer_masks(shiftcnt: jax.Array, valid: jax.Array, *, toward_zero: bool,
                lsb_first: bool) -> tuple[jax.Array, jax.Array]:
    """(L, n) bool take-masks + final (n,) occupancy for runtime counts."""
    n = shiftcnt.shape[-1]
    layers = _num_layers(n)
    order = range(layers) if lsb_first else range(layers - 1, -1, -1)
    direction = 1 if toward_zero else -1
    sc = shiftcnt.astype(jnp.int32)
    val = valid.astype(bool)
    masks = []
    for l in order:
        k = 1 << l
        bit = (sc >> l) & 1
        stay = val & (bit == 0)
        cand_shift = shift_static(sc, direction * k, -1)
        cand_valid = (shift_static(val, direction * k, -1, fill=False)
                      & (((cand_shift >> l) & 1) == 1))
        masks.append(cand_valid)
        sc = jnp.where(cand_valid, cand_shift, sc)
        val = cand_valid | stay
    if not masks:
        return jnp.zeros((0, n), bool), val
    return jnp.stack(masks), val


def apply_layer_masks(x: jax.Array, masks: jax.Array, *, axis: int,
                      toward_zero: bool, lsb_first: bool) -> jax.Array:
    """Route a wide payload with masks from :func:`layer_masks`.

    masks: (L, n) where n = x.shape[axis]; each mask broadcasts across the
    remaining dims of x (the d-tile), so the wide data pays exactly one
    static shift + one select per layer.
    """
    axis = axis % x.ndim
    n = x.shape[axis]
    layers = _num_layers(n)
    order = range(layers) if lsb_first else range(layers - 1, -1, -1)
    direction = 1 if toward_zero else -1
    for i, l in enumerate(order):
        k = 1 << l
        m = masks[i]
        shape = [1] * x.ndim
        shape[axis] = n
        m = m.reshape(shape)
        x = jnp.where(m, shift_static(x, direction * k, axis), x)
    return x


def gather_network(payload, shiftcnt, valid, *, axis: int = -1) -> RouteResult:
    """GSN: move valid elements toward lower indices by ``shiftcnt`` slots.

    Conflict-free iff the induced mapping is order-preserving and
    separation-non-increasing (EARTH §4.1.4).
    """
    return _route(payload, shiftcnt, valid, axis=axis, toward_zero=True,
                  lsb_first=True)


def scatter_network(payload, shiftcnt, valid, *, axis: int = -1) -> RouteResult:
    """SSN: move valid elements toward higher indices by ``shiftcnt`` slots.

    Conflict-free iff order-preserving and separation-non-decreasing.
    Bits are consumed MSB->LSB (mirrored network) — LSB-first would collide,
    e.g. {0,1} -> {1,3}.
    """
    return _route(payload, shiftcnt, valid, axis=axis, toward_zero=False,
                  lsb_first=False)


# ---------------------------------------------------------------------------
# Row routing: payload rows of shape (n, d) move as units along axis 0.
# Used by MoE token compaction (each row = a token embedding).
# ---------------------------------------------------------------------------

def gather_rows(rows: jax.Array, shiftcnt: jax.Array, valid: jax.Array) -> RouteResult:
    """Route (n, d) rows toward index 0; shiftcnt/valid are (n,)."""
    sc = shiftcnt.reshape(shiftcnt.shape + (1,) * (rows.ndim - 1))
    vd = valid.reshape(valid.shape + (1,) * (rows.ndim - 1))
    out = _route(rows, jnp.broadcast_to(sc, rows.shape),
                 jnp.broadcast_to(vd, rows.shape),
                 axis=0, toward_zero=True, lsb_first=True)
    return RouteResult(out.payload, out.valid[..., 0] if out.valid.ndim > 1
                       else out.valid, out.conflict)


def scatter_rows(rows: jax.Array, shiftcnt: jax.Array, valid: jax.Array) -> RouteResult:
    """Route (n, d) rows toward higher indices; shiftcnt/valid are (n,)."""
    sc = shiftcnt.reshape(shiftcnt.shape + (1,) * (rows.ndim - 1))
    vd = valid.reshape(valid.shape + (1,) * (rows.ndim - 1))
    out = _route(rows, jnp.broadcast_to(sc, rows.shape),
                 jnp.broadcast_to(vd, rows.shape),
                 axis=0, toward_zero=False, lsb_first=False)
    return RouteResult(out.payload, out.valid[..., 0] if out.valid.ndim > 1
                       else out.valid, out.conflict)
