"""EARTH shift networks (GSN / SSN) as pure-JAX algorithms.

The paper's DROM routes elements through ``log2(n)`` layers, each performing a
power-of-two shift when the corresponding bit of a per-element *shift count*
is set (EARTH §4.1).  For mappings that are order-preserving and
separation-monotone the routing is conflict-free (EARTH §4.1.4), i.e. at no
layer do two elements land in the same slot.

TPU adaptation: a layer is a *static* lane shift by ``2**l`` (compile-time
constant — cheap VREG data movement on TPU) plus a ``jnp.where`` select with a
dynamic mask.  ``log2(n)`` such passes replace an arbitrary gather, exactly as
EARTH's layered network replaces a byte crossbar.

Conventions
-----------
* GSN ("gather"): elements move toward LOWER indices; bits are consumed
  LSB -> MSB (paper Fig. 6, top-down).
* SSN ("scatter"): elements move toward HIGHER indices; bits are consumed
  MSB -> LSB (the mirrored network, bottom-up).
* ``shiftcnt`` is carried alongside the payload so each layer can test its
  bit after previous moves.
* All shifts are non-circular (EARTH's diagonal links do not wrap).
"""
from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp


def _num_layers(n: int) -> int:
    """Layers needed so any shift in [0, n-1] is representable."""
    if n <= 1:
        return 0
    return max(1, math.ceil(math.log2(n)))


def shift_static(x: jax.Array, k: int, axis: int, *, fill=0) -> jax.Array:
    """Non-circular static shift: result[i] = x[i + k] (k may be negative).

    Vacated slots are filled with ``fill``.  ``k`` is a Python int so the op
    lowers to slice+pad (static lane movement on TPU, no gather).
    """
    if k == 0:
        return x
    n = x.shape[axis]
    if abs(k) >= n:
        return jnp.full_like(x, fill)
    pad = [(0, 0)] * x.ndim
    idx = [slice(None)] * x.ndim
    if k > 0:  # pull from higher indices; pad at the high end
        idx[axis] = slice(k, None)
        pad[axis] = (0, k)
    else:  # pull from lower indices; pad at the low end
        idx[axis] = slice(0, n + k)
        pad[axis] = (-k, 0)
    return jnp.pad(x[tuple(idx)], pad, constant_values=fill)


class RouteResult(NamedTuple):
    payload: jax.Array
    valid: jax.Array
    conflict: jax.Array  # scalar bool: any slot collision or element loss


def _route(
    payload: jax.Array,
    shiftcnt: jax.Array,
    valid: jax.Array,
    *,
    axis: int,
    toward_zero: bool,
    lsb_first: bool,
) -> RouteResult:
    """Shared GSN/SSN layer loop.

    payload : (..., n, ...) data to route along ``axis``.
    shiftcnt: int32, broadcastable to payload along ``axis`` (commonly shaped
              like payload or with trailing singleton dims for row payloads).
    valid   : bool, same broadcast rule.
    """
    n = payload.shape[axis]
    layers = _num_layers(n)
    order = range(layers) if lsb_first else range(layers - 1, -1, -1)
    direction = 1 if toward_zero else -1  # arg to shift_static

    shiftcnt = shiftcnt.astype(jnp.int32)
    valid = valid.astype(bool)
    conflict = jnp.zeros((), dtype=bool)
    n_valid0 = jnp.sum(valid.astype(jnp.int32))

    for l in order:
        k = 1 << l
        bit = (shiftcnt >> l) & 1
        stay = valid & (bit == 0)
        cand_payload = shift_static(payload, direction * k, axis)
        cand_shift = shift_static(shiftcnt, direction * k, axis)
        cand_valid = (
            shift_static(valid, direction * k, axis, fill=False)
            & (((cand_shift >> l) & 1) == 1)
        )
        conflict = conflict | jnp.any(cand_valid & stay)
        payload = jnp.where(cand_valid, cand_payload, payload)
        shiftcnt = jnp.where(cand_valid, cand_shift, shiftcnt)
        valid = cand_valid | stay

    # Element loss (shifted off the edge) also shows up as a count drop.
    conflict = conflict | (jnp.sum(valid.astype(jnp.int32)) != n_valid0)
    return RouteResult(payload, valid, conflict)


def gather_network(payload, shiftcnt, valid, *, axis: int = -1) -> RouteResult:
    """GSN: move valid elements toward lower indices by ``shiftcnt`` slots.

    Conflict-free iff the induced mapping is order-preserving and
    separation-non-increasing (EARTH §4.1.4).
    """
    return _route(payload, shiftcnt, valid, axis=axis, toward_zero=True,
                  lsb_first=True)


def scatter_network(payload, shiftcnt, valid, *, axis: int = -1) -> RouteResult:
    """SSN: move valid elements toward higher indices by ``shiftcnt`` slots.

    Conflict-free iff order-preserving and separation-non-decreasing.
    Bits are consumed MSB->LSB (mirrored network) — LSB-first would collide,
    e.g. {0,1} -> {1,3}.
    """
    return _route(payload, shiftcnt, valid, axis=axis, toward_zero=False,
                  lsb_first=False)


# ---------------------------------------------------------------------------
# Row routing: payload rows of shape (n, d) move as units along axis 0.
# Used by MoE token compaction (each row = a token embedding).
# ---------------------------------------------------------------------------

def gather_rows(rows: jax.Array, shiftcnt: jax.Array, valid: jax.Array) -> RouteResult:
    """Route (n, d) rows toward index 0; shiftcnt/valid are (n,)."""
    sc = shiftcnt.reshape(shiftcnt.shape + (1,) * (rows.ndim - 1))
    vd = valid.reshape(valid.shape + (1,) * (rows.ndim - 1))
    out = _route(rows, jnp.broadcast_to(sc, rows.shape),
                 jnp.broadcast_to(vd, rows.shape),
                 axis=0, toward_zero=True, lsb_first=True)
    return RouteResult(out.payload, out.valid[..., 0] if out.valid.ndim > 1
                       else out.valid, out.conflict)


def scatter_rows(rows: jax.Array, shiftcnt: jax.Array, valid: jax.Array) -> RouteResult:
    """Route (n, d) rows toward higher indices; shiftcnt/valid are (n,)."""
    sc = shiftcnt.reshape(shiftcnt.shape + (1,) * (rows.ndim - 1))
    vd = valid.reshape(valid.shape + (1,) * (rows.ndim - 1))
    out = _route(rows, jnp.broadcast_to(sc, rows.shape),
                 jnp.broadcast_to(vd, rows.shape),
                 axis=0, toward_zero=False, lsb_first=False)
    return RouteResult(out.payload, out.valid[..., 0] if out.valid.ndim > 1
                       else out.valid, out.conflict)
