"""Shift Count Generation (EARTH §4.2), element-granularity TPU adaptation.

The paper computes ``shiftCnt_i = (stride - EEWB) * floor(i/EEWB) + offset``
at byte granularity.  We reorganize *elements in lanes* (EEWB == 1 element),
so the closed forms below are the same formula with EEWB folded into the
dtype.

Two views of a strided access over a coalesced window of n elements:

* gather (strided LOAD): input position ``p`` holds output element
  ``(p - offset) / stride`` when it divides exactly; its GSN shift count is
  ``p - dest(p)``.
* scatter (strided STORE): dense input element ``i`` must land at
  ``offset + i*stride``; its SSN shift count is ``offset + i*(stride-1)``.

All functions are jit-traceable in ``stride``/``offset`` (jnp arithmetic);
``n``/``vl`` are static shapes.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def gather_counts(n: int, stride, offset, vl) -> tuple[jax.Array, jax.Array]:
    """(shiftcnt, valid) over input window positions 0..n-1 for a strided load.

    valid[p] marks positions that hold one of the ``vl`` strided elements.
    """
    p = jnp.arange(n, dtype=jnp.int32)
    stride = jnp.asarray(stride, jnp.int32)
    offset = jnp.asarray(offset, jnp.int32)
    rel = p - offset
    dest = rel // jnp.maximum(stride, 1)
    valid = (rel >= 0) & (rel % jnp.maximum(stride, 1) == 0) & (dest < vl)
    shift = jnp.where(valid, p - dest, 0)
    return shift, valid


def scatter_counts(n: int, stride, offset, vl) -> tuple[jax.Array, jax.Array]:
    """(shiftcnt, valid) over dense input positions 0..n-1 for a strided store."""
    i = jnp.arange(n, dtype=jnp.int32)
    stride = jnp.asarray(stride, jnp.int32)
    offset = jnp.asarray(offset, jnp.int32)
    valid = i < vl
    shift = jnp.where(valid, offset + i * (stride - 1), 0)
    return shift, valid


def segment_gather_counts(n: int, fields, field, vl) -> tuple[jax.Array, jax.Array]:
    """Field-wise segment load (EARTH §5.2): field ``field`` of an AoS window
    is a strided gather with stride=FIELDS, offset=field."""
    return gather_counts(n, fields, field, vl)


def segment_scatter_counts(n: int, fields, field, vl) -> tuple[jax.Array, jax.Array]:
    return scatter_counts(n, fields, field, vl)


def column_access_counts(n: int, emul_elen_elems, vl) -> tuple[jax.Array, jax.Array]:
    """RCVRF column access (EARTH §4.5.2): after the block rotate, collecting
    element j of registers V0..V7 is a constant-stride gather with
    stride = EMUL*ELEN expressed in elements."""
    return gather_counts(n, emul_elen_elems, 0, vl)


def compaction_counts(mask: jax.Array) -> tuple[jax.Array, jax.Array]:
    """SCG for mask compaction (the routing analogue used by MoE dispatch).

    Selected positions move to ``rank(p) = #selected before p`` — an
    order-preserving, separation-non-increasing mapping, hence GSN-safe.
    """
    mask = mask.astype(bool)
    rank = jnp.cumsum(mask.astype(jnp.int32)) - 1
    pos = jnp.arange(mask.shape[0], dtype=jnp.int32)
    shift = jnp.where(mask, pos - rank, 0)
    return shift, mask


def expansion_counts(mask: jax.Array) -> tuple[jax.Array, jax.Array]:
    """SCG for the inverse (scatter packed rows back to masked positions).

    Packed element k must land at the k-th set position of ``mask``; its SSN
    shift count is ``target(k) - k`` (order-preserving, separation-non-
    decreasing, hence SSN-safe).
    """
    mask = mask.astype(bool)
    n = mask.shape[0]
    total = jnp.sum(mask.astype(jnp.int32))
    # target[k] = index of k-th set bit: scatter ranks then do a masked argmax
    pos = jnp.arange(n, dtype=jnp.int32)
    rank = jnp.cumsum(mask.astype(jnp.int32)) - 1
    # one-hot-free: for each k, target = sum over p of p * [rank==k and mask]
    # computed with a segment trick: place p at slot rank(p).
    targets = jnp.zeros((n,), jnp.int32).at[jnp.where(mask, rank, n)].set(
        jnp.where(mask, pos, 0), mode="drop")
    valid = pos < total
    shift = jnp.where(valid, targets - pos, 0)
    return shift, valid
