"""RCVRF — Row/Column-accessible Vector Register File (EARTH §4.5).

The paper skews register blocks diagonally across banks::

    (VREG_i, Block_j)  ->  Bank_k, Row_r
    k = (i + j) mod nBanks
    r = (floor(i / nBanks) * VLEN/ELEN + i mod nBanks) mod nRows

so both a whole register (row access) and "block j of registers
V_b..V_{b+7}" (column access) touch all banks exactly once — conflict-free
parallel access without a segment buffer.

TPU adaptation: banks become lane groups of a VMEM tile.  A "bank conflict"
on TPU is a gather across lanes; the skew turns column access into a row
access plus a *rotate* (static per row / cheap dynamic lane rotate), which is
exactly the Block Circular Shifter of Fig. 5 (c1).  The same trick is used by
the Pallas segment kernel to transpose AoS beats in place.

This module keeps the mapping math and a functional reference VRF; it is the
oracle for kernels/segment.py and the basis of the Fig. 13/14 analogues.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core import scg, shiftnet


@dataclasses.dataclass(frozen=True)
class VRFSpec:
    vlen: int = 256          # bits per architectural register
    elen: int = 64           # bits per block
    n_regs: int = 32
    n_banks: int = 8
    elem_bits: int = 8       # granularity we route at (one "element")

    @property
    def blocks_per_reg(self) -> int:
        return self.vlen // self.elen

    @property
    def n_rows(self) -> int:
        return self.vlen * self.n_regs // (self.elen * self.n_banks)

    @property
    def elems_per_block(self) -> int:
        return self.elen // self.elem_bits


def bank_of(spec: VRFSpec, reg: int, block: int) -> int:
    return (reg + block) % spec.n_banks


def row_of(spec: VRFSpec, reg: int, block: int) -> int:
    del block  # row depends only on the register (paper §4.5.1)
    return ((reg // spec.n_banks) * spec.blocks_per_reg
            + reg % spec.n_banks) % spec.n_rows


def locate(spec: VRFSpec, reg: int, block: int) -> tuple[int, int]:
    return bank_of(spec, reg, block), row_of(spec, reg, block)


def empty_vrf(spec: VRFSpec, dtype=jnp.uint8) -> jax.Array:
    """Physical storage: (n_rows, n_banks, elems_per_block)."""
    return jnp.zeros((spec.n_rows, spec.n_banks, spec.elems_per_block), dtype)


# ---------------------------------------------------------------------------
# Row access (single architectural register) — Block Shifter only.
# ---------------------------------------------------------------------------

def write_row(spec: VRFSpec, vrf: jax.Array, reg: int, data: jax.Array) -> jax.Array:
    """Write one architectural register. data: (blocks_per_reg * elems_per_block,)."""
    blocks = data.reshape(spec.blocks_per_reg, spec.elems_per_block)
    # Block Circular Shifter: rotate so block j lands in bank (reg+j)%nB.
    row = row_of(spec, reg, 0)
    banked = jnp.zeros((spec.n_banks, spec.elems_per_block), blocks.dtype)
    banked = banked.at[jnp.arange(spec.blocks_per_reg)].set(blocks)
    banked = jnp.roll(banked, shift=reg % spec.n_banks, axis=0)
    if spec.blocks_per_reg == spec.n_banks:
        return vrf.at[row].set(banked)
    # partial-row registers: only touch this register's banks
    mask = jnp.zeros((spec.n_banks, 1), bool)
    mask = mask.at[jnp.arange(spec.blocks_per_reg)].set(True)
    mask = jnp.roll(mask, shift=reg % spec.n_banks, axis=0)
    return vrf.at[row].set(jnp.where(mask, banked, vrf[row]))


def read_row(spec: VRFSpec, vrf: jax.Array, reg: int) -> jax.Array:
    row = row_of(spec, reg, 0)
    banked = jnp.roll(vrf[row], shift=-(reg % spec.n_banks), axis=0)
    return banked[: spec.blocks_per_reg].reshape(-1)


# ---------------------------------------------------------------------------
# Column access (same block of consecutive registers) — Block Shifter + DROM.
# Used by segment ops: one memory beat per segment touches all banks once.
# ---------------------------------------------------------------------------

def read_column(spec: VRFSpec, vrf: jax.Array, base_reg: int, block: int,
                byte: int, count: int) -> jax.Array:
    """Collect element ``byte`` of block ``block`` from registers
    base_reg .. base_reg+count-1 (count <= n_banks).

    Reads every bank once (conflict-free), rotates (Block Shifter), then a
    GSN pass with stride = elems_per_block consolidates the target bytes —
    EARTH §4.5.2's "const stride value of EMUL x ELEN/8".
    """
    rows = jnp.array([row_of(spec, base_reg + i, 0) for i in range(count)])
    banks = jnp.array([bank_of(spec, base_reg + i, block) for i in range(count)])
    beats = vrf[rows, banks]                     # (count, elems_per_block)
    flat = beats.reshape(-1)
    # gather element ``byte`` of each beat: stride=elems_per_block, offset=byte
    # (the paper's "const stride value of EMUL x ELEN/8", element granularity)
    shift, valid = scg.gather_counts(flat.shape[0], spec.elems_per_block,
                                     byte, count)
    routed = shiftnet.gather_network(flat, shift, valid)
    return jax.lax.slice(routed.payload, (0,), (count,))


def write_column(spec: VRFSpec, vrf: jax.Array, base_reg: int, block: int,
                 byte: int, values: jax.Array) -> jax.Array:
    """Scatter values[i] into element ``byte`` of block ``block`` of register
    base_reg+i — one conflict-free parallel bank write (segment load beat)."""
    count = values.shape[0]
    n = spec.n_banks * spec.elems_per_block
    vals = jnp.pad(values, (0, n - count))
    shift, valid = scg.scatter_counts(n, spec.elems_per_block, byte, count)
    routed = shiftnet.scatter_network(vals, shift, valid)
    spread = routed.payload.reshape(spec.n_banks, spec.elems_per_block)
    vmask = routed.valid.reshape(spec.n_banks, spec.elems_per_block)
    rows = jnp.array([row_of(spec, base_reg + i, 0) for i in range(count)])
    banks = jnp.array([bank_of(spec, base_reg + i, block) for i in range(count)])
    idx = jnp.arange(count)
    return vrf.at[rows, banks].set(
        jnp.where(vmask[idx], spread[idx], vrf[rows, banks]))


def column_banks_distinct(spec: VRFSpec, base_reg: int, block: int,
                          count: int) -> bool:
    """Conflict-freeness invariant: a column access touches distinct banks."""
    banks = [bank_of(spec, base_reg + i, block) for i in range(count)]
    return len(set(banks)) == len(banks)
