"""Shared symmetric quantization helpers.

One module, two consumers:

* ``optim/compression.py`` — per-TENSOR int8 round-trip for gradient
  compression (error feedback keeps the bias bounded),
* the quantized paged KV pool (``vx/lower.py`` + ``models/decode.py``) —
  per-PAGE-per-head scales stored in a side tensor, dequant fused into
  the page-gather program, quantize-on-write in the append/prefill
  scatter.

All quantization here is symmetric (no zero point): ``q = x / scale``
clipped to ``[-qmax, qmax]`` and rounded for integer targets, ``x' =
q * scale``.  A scale of exactly 0 means "nothing written yet" — the
safe-divide in :func:`quantize` writes 0 (never NaN — fp8 HAS NaN
encodings and a NaN page poisons every later gather), and dequant
multiplies garbage ints by 0.

fp8 is feature-gated: ``float8_e4m3fn`` when the installed jax exposes
it, otherwise :func:`supported` returns False and callers must fall
back or raise — nothing here imports optional packages.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

# Largest representable magnitude per quantized dtype.  int8 uses the
# symmetric range [-127, 127] (not -128: symmetry keeps dequant
# unbiased).  float8_e4m3fn's max finite is 448 (the "fn" variant trades
# inf for range); e5m2 listed for completeness.
_QMAX = {
    "int8": 127.0,
    "float8_e4m3fn": 448.0,
    "float8_e5m2": 57344.0,
}

# Worst-case round-to-nearest error of a value at magnitude ``qmax *
# scale`` quantized into the dtype, as a fraction of that magnitude:
#   int8            : half a step  => (1/127) / 2
#   float8_e4m3fn   : 3 mantissa bits => half-ulp relative 2**-4
#   float8_e5m2     : 2 mantissa bits => half-ulp relative 2**-3
_REL_ERR = {
    "int8": 0.5 / 127.0,
    "float8_e4m3fn": 2.0 ** -4,
    "float8_e5m2": 2.0 ** -3,
}

_ALIASES = {"fp8": "float8_e4m3fn", "e4m3": "float8_e4m3fn",
            "e5m2": "float8_e5m2"}


def canonical(name) -> str:
    """Canonical dtype string for a user-facing name or dtype object."""
    s = str(name)
    s = _ALIASES.get(s, s)
    if s not in _QMAX:
        raise ValueError(f"unsupported quantized dtype {name!r}; "
                         f"known: {sorted(_QMAX) + sorted(_ALIASES)}")
    return s


def supported(name) -> bool:
    """Whether this jax build can materialize the dtype (fp8 is gated)."""
    try:
        s = canonical(name)
    except ValueError:
        return False
    return s == "int8" or hasattr(jnp, s)


def pool_dtype(name):
    """jnp dtype object for a canonical/user-facing quantized dtype name."""
    s = canonical(name)
    if s == "int8":
        return jnp.int8
    if not hasattr(jnp, s):
        raise ValueError(f"{s} unavailable in this jax build "
                         f"(gate with quant.supported)")
    return getattr(jnp, s)


def qmax(dtype) -> float:
    """Largest encodable magnitude of a quantized dtype."""
    return _QMAX[canonical(np.dtype(dtype).name
                           if not isinstance(dtype, str) else dtype)]


def scale_for(x, dtype, *, axis=None, keepdims: bool = False,
              eps: float = 0.0):
    """Symmetric max-abs scale so that |x| maps into [-qmax, qmax]."""
    amax = jnp.max(jnp.abs(x), axis=axis, keepdims=keepdims)
    if eps:
        amax = jnp.maximum(amax, eps)
    return amax / qmax(dtype)


def quantize(x, scale, dtype):
    """``round(clip(x / scale))`` cast to ``dtype``; scale==0 writes 0."""
    qd = pool_dtype(dtype) if isinstance(dtype, str) else dtype
    safe = jnp.where(scale > 0, scale, 1.0)
    y = jnp.where(scale > 0, x / safe, 0.0)
    return requantize(y, qd)


def requantize(y, dtype):
    """Clip+round a value already in the quantized domain and cast."""
    qd = pool_dtype(dtype) if isinstance(dtype, str) else dtype
    qm = qmax(qd)
    y = jnp.clip(y, -qm, qm)
    if jnp.issubdtype(jnp.dtype(qd), jnp.integer):
        y = jnp.round(y)
    return y.astype(qd)


def dequantize(q, scale, dtype=jnp.float32):
    return q.astype(dtype) * scale.astype(dtype)


def roundtrip(x, dtype=jnp.int8, *, eps: float = 0.0):
    """Per-tensor symmetric quantize->dequantize (compression wire sim)."""
    s = scale_for(x, dtype, eps=eps)
    return dequantize(quantize(x, s, dtype), s, jnp.float32)


def error_bound(dtype, amax):
    """Worst-case |x - roundtrip(x)| for |x| <= amax under a per-tensor
    max-abs scale.  int8: half a quantization step.  fp8: half-ulp
    relative error at the top binade dominates the subnormal floor."""
    return float(amax) * _REL_ERR[canonical(
        np.dtype(dtype).name if not isinstance(dtype, str) else dtype)]
