"""AoS training-record format — EARTH segment access in the input path.

A record packs FIELDS=4 int32 fields per token position, interleaved
(Array-of-Structures):  [token, label, weight_q, doc_id] x S.
One record is therefore a single contiguous (4*S,) buffer: writing it is one
sequential transaction (the coalescing win), and unpacking to SoA batch
arrays is a FIELD=4 segment load (``vx.transpose`` with a Segment spec).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro import vx

FIELDS = 4
WEIGHT_SCALE = 1024  # loss weights quantized to int32 / WEIGHT_SCALE


def pack_records(tokens: jax.Array, labels: jax.Array, weights: jax.Array,
                 doc_ids: jax.Array, *, policy=None) -> jax.Array:
    """(B,S) x4 -> (B, 4S) interleaved AoS buffer (segment store)."""
    wq = jnp.round(weights * WEIGHT_SCALE).astype(jnp.int32)
    spec = vx.Segment(n=FIELDS * tokens.shape[-1], fields=FIELDS)
    return vx.transpose(
        spec, [tokens.astype(jnp.int32), labels.astype(jnp.int32), wq,
               doc_ids.astype(jnp.int32)], policy=policy)


def unpack_records(aos: jax.Array, *, policy=None) -> dict:
    """(B, 4S) AoS -> SoA batch dict (segment load)."""
    tokens, labels, wq, doc_ids = vx.transpose(
        vx.Segment(n=aos.shape[-1], fields=FIELDS), aos, policy=policy)
    return {
        "tokens": tokens,
        "labels": labels,
        "loss_weight": wq.astype(jnp.float32) / WEIGHT_SCALE,
        "doc_id": doc_ids,
    }


def pack_unpack_fused(tokens: jax.Array, labels: jax.Array,
                      weights: jax.Array, doc_ids: jax.Array) -> dict:
    """``unpack_records(pack_records(...))`` with the segment round trip
    ELIDED by the step scheduler's plan-composition rule:
    ``interleave_plan(n, 4)`` followed by ``deinterleave_plan(n, 4)`` is the
    identity permutation (property-tested in tests/test_step_fusion.py), so
    when one step issues both, neither network pass is launched — only the
    field dtype conversions of the round trip remain (bit-exact with the
    unfused path, including the loss-weight quantization)."""
    wq = jnp.round(weights * WEIGHT_SCALE).astype(jnp.int32)
    return {
        "tokens": tokens.astype(jnp.int32),
        "labels": labels.astype(jnp.int32),
        "loss_weight": wq.astype(jnp.float32) / WEIGHT_SCALE,
        "doc_id": doc_ids.astype(jnp.int32),
    }
