"""Data pipeline: AoS record format (EARTH segment ops) + deterministic
host-sharded synthetic loader."""
from repro.data.aos import pack_records, unpack_records  # noqa: F401
from repro.data.pipeline import DataConfig, SyntheticAoSPipeline  # noqa: F401
