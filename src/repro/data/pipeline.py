"""Deterministic, checkpointable, host-sharded synthetic data pipeline.

Semantics match a production loader: every (step, host) pair maps to a
unique, reproducible slice of the global batch; state is two integers, so
checkpoint/restore and elastic rescale (different host count on restart)
are exact — property-tested in tests/test_data.py.

Records flow through the AoS format (data/aos.py): the loader materializes
the interleaved buffer, the model side performs the EARTH segment load via
the vx API (lowering picked by the active vx.Policy).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.data import aos


@dataclasses.dataclass
class PipelineState:
    step: int
    seed: int

    def to_dict(self) -> dict:
        return {"step": self.step, "seed": self.seed}

    @classmethod
    def from_dict(cls, d: dict) -> "PipelineState":
        return cls(step=int(d["step"]), seed=int(d["seed"]))


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0


class SyntheticAoSPipeline:
    """Yields per-host AoS shards of a deterministic global batch."""

    def __init__(self, cfg: DataConfig, *, process_index: int = 0,
                 process_count: int = 1):
        assert cfg.global_batch % process_count == 0
        self.cfg = cfg
        self.process_index = process_index
        self.process_count = process_count
        self.state = PipelineState(step=0, seed=cfg.seed)

    @property
    def host_batch(self) -> int:
        return self.cfg.global_batch // self.process_count

    def _global_fields_np(self, step: int):
        """Deterministic SoA fields for ``step`` (numpy host arrays)."""
        cfg = self.cfg
        rng = np.random.default_rng((self.state.seed << 20) + step)
        toks = rng.integers(0, cfg.vocab, (cfg.global_batch, cfg.seq_len),
                            dtype=np.int32)
        labels = np.roll(toks, -1, axis=1)
        weights = np.ones((cfg.global_batch, cfg.seq_len), np.float32)
        weights[:, -1] = 0.0  # no loss on the rolled-around label
        docs = np.full((cfg.global_batch, cfg.seq_len), step, np.int32)
        return toks, labels, weights, docs

    def _global_batch_np(self, step: int) -> np.ndarray:
        """The full deterministic AoS global batch for ``step`` (numpy)."""
        toks, labels, weights, docs = self._global_fields_np(step)
        buf = aos.pack_records(jnp.asarray(toks), jnp.asarray(labels),
                               jnp.asarray(weights), jnp.asarray(docs))
        return np.asarray(buf)

    def next_host_aos(self) -> np.ndarray:
        """(host_batch, 4*S) AoS shard for this host; advances state."""
        full = self._global_batch_np(self.state.step)
        lo = self.process_index * self.host_batch
        shard = full[lo:lo + self.host_batch]
        self.state.step += 1
        return shard

    def next_batch(self, *, fused: bool = True, policy=None) -> dict:
        """SoA batch dict for this host; advances state.

        ``fused=True`` routes through the step scheduler's pack+unpack
        elision (data/aos.pack_unpack_fused): the producer-side segment
        store and the consumer-side segment load of the SAME step cancel
        (inverse permutation plans), skipping the AoS materialization
        entirely — no segment op runs, so ``policy`` only affects the
        ``fused=False`` path.  Bit-exact with ``fused=False`` (the AoS interface,
        unchanged, still backs `next_host_aos` for checkpoint/restore
        determinism) — property-tested in tests/test_step_fusion.py.
        """
        if not fused:
            shard = jnp.asarray(self.next_host_aos())
            batch = aos.unpack_records(shard, policy=policy)
            batch.pop("doc_id")
            return batch
        toks, labels, weights, docs = self._global_fields_np(self.state.step)
        lo = self.process_index * self.host_batch
        hi = lo + self.host_batch
        self.state.step += 1
        batch = aos.pack_unpack_fused(
            jnp.asarray(toks[lo:hi]), jnp.asarray(labels[lo:hi]),
            jnp.asarray(weights[lo:hi]), jnp.asarray(docs[lo:hi]))
        batch.pop("doc_id")
        return batch

    # -- checkpointing ------------------------------------------------------
    def state_dict(self) -> dict:
        return self.state.to_dict()

    def load_state_dict(self, d: dict) -> None:
        self.state = PipelineState.from_dict(d)
