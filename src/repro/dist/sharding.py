"""Sharding rules: one ShardCtx object carries the mesh + axis roles; every
PartitionSpec in the system is derived here (params, activations, optimizer
state) so that elastic restore / dry-run / serving all agree on placement.

Axis roles
----------
* ``data_axes``  : batch dimension of activations; gradient all-reduce.
* ``model_axis`` : tensor parallelism (Megatron column/row splits, expert
                   parallelism, vocab-sharded logits).
* ``seq_axes``   : long-context serving only (B=1): KV sequence dim sharded.

Spec derivation is *rule-based on leaf path + shape* (not stored per-leaf),
so checkpoints hold logical arrays and any mesh can rebuild placements
(ft/elastic.py).

This module also provides version-compat wrappers (``shard_map``,
``make_mesh``) because the public JAX surface for these moved across the
versions this repo must run on.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P


# ---------------------------------------------------------------------------
# Version-compat wrappers
# ---------------------------------------------------------------------------

def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True):
    """``jax.shard_map`` where available, else the experimental spelling
    (mapping ``check_vma`` onto the older ``check_rep``)."""
    if hasattr(jax, "shard_map"):
        try:
            return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                                 out_specs=out_specs, check_vma=check_vma)
        except TypeError:  # pre-check_vma signature
            return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                                 out_specs=out_specs)
    from jax.experimental.shard_map import shard_map as _sm
    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=check_vma)


def make_mesh(shape: Sequence[int], axes: Sequence[str]):
    """``jax.make_mesh`` with Auto axis types when the installed JAX
    supports them, plain otherwise."""
    try:
        from jax.sharding import AxisType
        return jax.make_mesh(tuple(shape), tuple(axes),
                             axis_types=(AxisType.Auto,) * len(axes))
    except (ImportError, TypeError):
        return jax.make_mesh(tuple(shape), tuple(axes))


# ---------------------------------------------------------------------------
# ShardCtx
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ShardCtx:
    """Mesh + axis-role bundle threaded through models/train/serve."""
    mesh: Any
    data_axes: tuple = ()
    model_axis: str | None = None
    seq_axes: tuple = ()
    fsdp: bool = False

    # -- sizes ---------------------------------------------------------------
    def _axis_size(self, axis) -> int:
        if self.mesh is None or axis is None:
            return 1
        return self.mesh.shape[axis]

    @property
    def model_size(self) -> int:
        return self._axis_size(self.model_axis)

    @property
    def data_size(self) -> int:
        return math.prod(self._axis_size(a) for a in self.data_axes) \
            if self.data_axes else 1

    @property
    def seq_shard_acts(self) -> bool:
        """Megatron-SP: sequence-shard the residual stream between blocks."""
        return self.mesh is not None and self.model_axis is not None

    # -- spec helpers --------------------------------------------------------
    def model_if_divisible(self, dim: int):
        """model_axis iff ``dim`` splits evenly across it, else None."""
        if (self.mesh is None or self.model_axis is None or dim is None
                or dim % self.model_size or dim < self.model_size):
            return None
        return self.model_axis

    def batch_spec(self, *rest) -> P:
        """P for a batch-leading activation: (B over data axes, *rest)."""
        return P(self.data_axes if self.data_axes else None, *rest)

    def sharding(self, spec: P) -> NamedSharding:
        assert self.mesh is not None, "sharding() needs a mesh"
        return NamedSharding(self.mesh, spec)

    def constrain(self, x, spec: P):
        if self.mesh is None:
            return x
        return jax.lax.with_sharding_constraint(x, self.sharding(spec))

    def vx_seq_shard(self, axis: int = -3):
        """``vx.Shard`` placement annotation for a buffer axis sharded
        over this context's sequence axes (long-context serving: B=1, the
        KV sequence dim takes every axis).  ``axis`` counts from the end
        (the default -3 is the sequence dim of an (NS, B, Sc, K, 2D)
        cache leaf).  None when mesh-less or no axis plays the sequence
        role — callers then take the replicated lowering."""
        if self.mesh is None:
            return None
        axes = self.seq_axes or (self.data_axes
                                 + ((self.model_axis,)
                                    if self.model_axis else ()))
        if not axes:
            return None
        from repro.vx.program import Shard
        return Shard(axes=tuple(axes), axis=axis, mesh=self.mesh)

    def vx_pool_shard(self, axis: int = -4):
        """``vx.Shard`` annotation for a PAGED-POOL leaf sharded on its
        page axis (serving: the shared KV page pool is the memory
        ceiling, so its physical pages spread across the mesh and
        ``vx.Paged`` gathers run shard-locally on the owned page block —
        the pool is never sliced globally).  Same axis-role selection as
        :meth:`vx_seq_shard`; the default -4 is the page axis of an
        ``(NS, P, page_size, K, 2D)`` pool leaf."""
        return self.vx_seq_shard(axis)


def local_ctx() -> ShardCtx:
    """Single-process / single-device context (mesh-less no-op specs)."""
    return ShardCtx(mesh=None, data_axes=(), model_axis=None)


# ---------------------------------------------------------------------------
# Parameter spec rules
# ---------------------------------------------------------------------------

def _kp_str(kp) -> str:
    return "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in kp)


def _pick_model_dim(path: str, shape: tuple, start: int, ctx: ShardCtx):
    """Dim index to place the model axis on, or None.

    Rules (checked in order):
      * MoE expert banks (wg/wu/wo under a moe subtree, >= 3 trailing dims):
        shard the EXPERT dim — expert parallelism, matching the shard_map
        in_specs of models/moe.py.
      * attention/FFN output projections named ``wo``: shard the INPUT
        (row-parallel — the matching all-reduce is the FFN psum).
      * otherwise: the largest trailing dim divisible by the model size
        (column-parallel default; embed/lm_head land vocab-sharded, which
        is what the sharded cross-entropy in models/transformer.py expects).
    """
    ms = ctx.model_size
    nd = len(shape)
    if nd - start < 2:          # vectors (norm gains, biases): replicate
        return None

    def ok(i):
        return shape[i] % ms == 0 and shape[i] >= ms

    leaf = path.rsplit("/", 1)[-1]
    if "moe" in path and leaf in ("wg", "wu", "wo") and nd - start >= 3:
        if ok(start):
            return start
    if leaf == "wo" and nd - start == 2 and ok(start):
        return start
    if leaf == "wkv" and nd - start == 2:
        # the interleaved [k|v] beat (EARTH AoS unit) must stay contiguous
        # per device — shard the INPUT dim instead of splitting the beat
        # (splitting it also trips an XLA SPMD partitioner miscompile with
        # the strided deinterleave reshape on some backends; measured)
        return start if ok(start) else None
    best = None
    for i in range(start, nd):
        if ok(i) and (best is None or shape[i] >= shape[best]):
            best = i
    return best


def param_spec(path: str, shape: tuple, ctx: ShardCtx) -> P:
    """PartitionSpec for one parameter leaf."""
    if ctx.mesh is None:
        return P()
    # block stacks carry a leading superblock dim that must never shard
    # (it is the lax.scan carry axis)
    stacked = path.startswith("blocks") or "/blocks/" in path
    start = 1 if (stacked and len(shape) >= 2) else 0
    parts: list = [None] * len(shape)
    if ctx.model_axis is not None:
        md = _pick_model_dim(path, shape, start, ctx)
        if md is not None:
            parts[md] = ctx.model_axis
    spec = P(*parts)
    if ctx.fsdp:
        spec = add_data_sharding(spec, shape, ctx, start=start)
    return spec


def tree_param_specs(params, ctx: ShardCtx):
    """Pytree of PartitionSpecs mirroring ``params``."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    specs = [param_spec(_kp_str(kp), tuple(leaf.shape), ctx)
             for kp, leaf in flat]
    return jax.tree_util.tree_unflatten(treedef, specs)


def add_data_sharding(spec: P, shape: tuple, ctx: ShardCtx, *,
                      start: int = 0) -> P:
    """Additionally shard ``spec`` over the data axes (ZeRO-1 / FSDP).

    Picks the first dim >= ``start`` that is unsharded and splits evenly
    across the combined data axes; returns ``spec`` unchanged when none fits.
    """
    if ctx.mesh is None or not ctx.data_axes:
        return spec
    ds = ctx.data_size
    parts = list(spec) + [None] * (len(shape) - len(spec))
    for i in range(start, len(shape)):
        if parts[i] is None and shape[i] % ds == 0 and shape[i] >= ds:
            parts[i] = ctx.data_axes if len(ctx.data_axes) > 1 \
                else ctx.data_axes[0]
            return P(*parts)
    return spec


# ---------------------------------------------------------------------------
# Misc helpers used by launch / tests
# ---------------------------------------------------------------------------

def replicate(x, ctx: ShardCtx):
    """Fully replicate a pytree on ctx's mesh (no-op mesh-less)."""
    if ctx.mesh is None:
        return x
    return jax.tree.map(
        lambda a: jax.device_put(a, ctx.sharding(P())), x)


def spec_tree_shardings(specs, ctx: ShardCtx):
    """Map a PartitionSpec pytree to NamedShardings."""
    if ctx.mesh is None:
        return None
    return jax.tree.map(lambda s: ctx.sharding(s), specs,
                        is_leaf=lambda x: isinstance(x, P))
