"""Distributed substrate: sharding contexts, spec builders, pipeline
parallelism, and version-compat wrappers for the JAX SPMD APIs.

Modules
-------
sharding     : ShardCtx + PartitionSpec rules for params/activations/state,
               plus `shard_map` / `make_mesh` compat shims.
pipeline_par : GPipe-style pipeline parallelism over a mesh axis.
"""
from repro.dist import sharding  # noqa: F401
