"""GPipe pipeline parallelism over one mesh axis (the multi-pod "pod" axis).

The superblock stack is split into ``mesh.shape[axis]`` contiguous stages;
microbatches stream through the stages with activations handed forward by
``lax.ppermute`` (whose transpose carries gradients backward, so a plain
``jax.grad`` through ``pipeline_loss_fn`` trains correctly).

The schedule is the classic GPipe fill/steady/drain loop: with M
microbatches and S stages, tick t has stage s working on microbatch
``t - s`` (when in range). Every device executes the identical program
(SPMD); out-of-range ticks compute on don't-care data and are masked out of
the loss accumulators, which keeps the body shard_map-uniform.

Numerics match ``models.transformer.loss_fn`` (same per-token terms,
microbatch-partitioned sums combined before the division), verified to
rtol 2e-3 by tests/_dist_checks.py::check_pipeline_equivalence.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.dist.sharding import ShardCtx, shard_map


@dataclasses.dataclass(frozen=True)
class PipelineConfig:
    axis: str = "pod"
    n_microbatches: int = 2


def _stage_blocks(blocks, stage, per: int):
    """Slice this stage's ``per`` superblocks out of the (NS, ...) stacks."""
    return jax.tree.map(
        lambda a: jax.lax.dynamic_slice_in_dim(a, stage * per, per, 0),
        blocks)


def pipeline_loss_fn(params, batch, cfg, ctx: ShardCtx,
                     pcfg: PipelineConfig):
    """Pipelined equivalent of ``loss_fn(params, batch, cfg, None)``.

    params/batch enter replicated; the pipeline axis is used for stage
    placement and activation hand-off only. Returns (loss + aux, metrics).
    """
    from repro.models import layers
    from repro.models.transformer import (cast_params, label_logprob_terms,
                                          superblock_apply)
    assert ctx.mesh is not None, "pipeline parallelism needs a mesh"
    n_stages = ctx.mesh.shape[pcfg.axis]
    M = pcfg.n_microbatches
    NS = cfg.n_superblocks
    assert NS % n_stages == 0, (NS, n_stages)
    per = NS // n_stages
    B = batch["tokens"].shape[0]
    assert B % M == 0, (B, M)

    def body(params, batch):
        stage = jax.lax.axis_index(pcfg.axis)
        cparams = cast_params(params, cfg, None)
        mb = jax.tree.map(
            lambda x: x.reshape((M, B // M) + x.shape[1:]), batch)
        bm, S = B // M, batch["tokens"].shape[1]
        positions = jnp.broadcast_to(jnp.arange(S), (bm, S))
        my_blocks = _stage_blocks(cparams["blocks"], stage, per)
        head = params["embed"] if cfg.tie_embeddings else params["lm_head"]
        head = head.astype(cfg.cdtype)

        def stage_apply(x):
            def sb_fn(x, sb_p):
                x, aux_d, _ = superblock_apply(sb_p, x, cfg, None, positions,
                                               mode="train")
                return x, aux_d
            x, auxs = jax.lax.scan(sb_fn, x, my_blocks)
            return x, jnp.sum(auxs)

        fwd = [(i, (i + 1) % n_stages) for i in range(n_stages)]
        is_last = (stage == n_stages - 1).astype(jnp.float32)
        buf = jnp.zeros((bm, S, cfg.d_model), cfg.cdtype)
        num = den = aux_sum = jnp.zeros((), jnp.float32)
        for t in range(M + n_stages - 1):
            m_in = min(t, M - 1)
            x0 = layers.embed(mb["tokens"][m_in],
                              cparams["embed"]).astype(cfg.cdtype)
            x_in = jnp.where(stage == 0, x0, buf)
            y, aux_t = stage_apply(x_in)
            m_out = t - (n_stages - 1)
            if 0 <= m_out < M:
                h = layers.rms_norm(y, cparams["final_norm"], cfg.norm_eps)
                logits = layers.unembed(h, head)
                lse, ll = label_logprob_terms(logits, mb["labels"][m_out])
                w = mb.get("loss_weight")
                w = (jnp.ones((bm, S), jnp.float32) if w is None
                     else w[m_out].astype(jnp.float32))
                num = num + jnp.sum((lse - ll) * w) * is_last
                den = den + jnp.sum(w) * is_last
            # every stage contributes its superblocks' aux once per REAL
            # microbatch it processed (ticks stage..stage+M-1)
            in_range = jnp.logical_and(t - stage >= 0, t - stage < M)
            aux_sum = aux_sum + aux_t * in_range.astype(jnp.float32)
            buf = jax.lax.ppermute(y, pcfg.axis, perm=fwd)
        num = jax.lax.psum(num, pcfg.axis)
        den = jax.lax.psum(den, pcfg.axis)
        aux = jax.lax.psum(aux_sum, pcfg.axis) / M
        loss = num / jnp.maximum(den, 1.0)
        return loss + aux, {"loss": loss, "aux": aux}

    sm = shard_map(body, mesh=ctx.mesh,
                   in_specs=(P(), P()), out_specs=(P(), P()),
                   check_vma=False)
    return sm(params, batch)
