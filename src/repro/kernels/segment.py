"""Segment (AoS <-> SoA) Pallas kernels — the RCVRF path, buffer-free.

A segment load with FIELDS=f is f field-wise strided gathers (stride=f,
offset=field) over the same VMEM-resident AoS beat; a segment store is the
mirrored scatter.  No scratch "segment buffer" is allocated: each field's
routed lanes are written straight to its output block, matching EARTH's
immediate-writeback timeline (Fig. 4c).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import scg, shiftnet
from repro.kernels import _common


def _deint_kernel(aos_ref, *o_refs, fields: int):
    aos = aos_ref[...]                    # (rt, f*m)
    n = aos.shape[-1]
    m = n // fields
    for f in range(fields):
        shift, valid = scg.gather_counts(n, fields, f, m)
        res = shiftnet.gather_network(aos, shift[None, :], valid[None, :],
                                      axis=-1)
        o_refs[f][...] = jax.lax.slice(res.payload, (0, 0), (aos.shape[0], m))


def deinterleave(aos: jax.Array, fields: int) -> list[jax.Array]:
    """(..., fields*m) -> fields x (..., m)   (segment load)."""
    n = aos.shape[-1]
    assert n % fields == 0
    m = n // fields
    flat, lead = _common.flatten_rows(aos)
    flat, r0 = _common.pad_rows(flat)
    rt = _common.ROW_TILE
    outs = _common.call(
        functools.partial(_deint_kernel, fields=fields),
        out_shape=tuple(jax.ShapeDtypeStruct((flat.shape[0], m), aos.dtype)
                        for _ in range(fields)),
        grid=(_common.row_grid(flat.shape[0]),),
        in_specs=[pl.BlockSpec((rt, n), lambda i: (i, 0))],
        out_specs=tuple(pl.BlockSpec((rt, m), lambda i: (i, 0))
                        for _ in range(fields)),
    )(flat)
    return [o[:r0].reshape(lead + (m,)) for o in outs]


def _int_kernel(*refs, fields: int):
    f_refs, o_ref = refs[:-1], refs[-1]
    rt, m = f_refs[0].shape
    n = m * fields
    acc = jnp.zeros((rt, n), f_refs[0].dtype)
    for f in range(fields):
        padded = jnp.pad(f_refs[f][...], ((0, 0), (0, n - m)))
        shift, valid = scg.scatter_counts(n, fields, f, m)
        res = shiftnet.scatter_network(padded, shift[None, :], valid[None, :],
                                       axis=-1)
        acc = jnp.where(res.valid, res.payload, acc)
    o_ref[...] = acc


def interleave(soa: list[jax.Array]) -> jax.Array:
    """fields x (..., m) -> (..., fields*m)   (segment store)."""
    fields = len(soa)
    m = soa[0].shape[-1]
    n = m * fields
    flats = []
    r0 = lead = None
    for t in soa:
        f, lead = _common.flatten_rows(t)
        f, r0 = _common.pad_rows(f)
        flats.append(f)
    rt = _common.ROW_TILE
    out = _common.call(
        functools.partial(_int_kernel, fields=fields),
        out_shape=jax.ShapeDtypeStruct((flats[0].shape[0], n), soa[0].dtype),
        grid=(_common.row_grid(flats[0].shape[0]),),
        in_specs=[pl.BlockSpec((rt, m), lambda i: (i, 0))
                  for _ in range(fields)],
        out_specs=pl.BlockSpec((rt, n), lambda i: (i, 0)),
    )(*flats)
    return out[:r0].reshape(lead + (n,))
