"""Segment (AoS <-> SoA) Pallas kernels — compiled bulk transposition.

A segment access with FIELDS=f over an n-lane beat is ONE lane permutation
(AoS -> concatenated SoA fields, or back).  The static-plan compiler
(core/shiftplan.py) routes it in a SINGLE kernel either as

  * a FUSED permutation pass — one O(log n) Benes/butterfly sweep of static
    shifts + constant-mask selects handling ALL fields at once (the RCVRF
    shifted-register-bank bulk transposition, EARTH §4.5), or
  * ``fields`` compiled per-field passes when the cost model says that is
    cheaper (small field counts) — still pruned single-shift layers with
    constant masks, never the dynamic triple-shift loop.

No scratch "segment buffer" is allocated: each field's lanes are sliced
straight out of the routed beat into its output block (immediate writeback,
Fig. 4c).  ``fused=False`` keeps the per-field dynamic-count networks as
the fallback/oracle.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.core import scg, shiftnet, shiftplan
from repro.kernels import _common


# One concatenated (S, n) mask operand for several plans (shared helper).
_stack_masks = _common.stack_plan_masks


# ---------------------------------------------------------------------------
# Routing bodies (pure jnp — shared by the Pallas kernels and benchmarks)
# ---------------------------------------------------------------------------

def route_deinterleave(aos, masks, mode: str, plans, spans, fields: int):
    """(rows, n) AoS -> list of (rows, m) fields via compiled plans."""
    n = aos.shape[-1]
    m = n // fields
    if mode == "fused":
        plan = plans[0]
        x = aos if plan.n == n else jnp.pad(aos, ((0, 0), (0, plan.n - n)))
        lo, hi = spans[0]
        routed = shiftnet.apply_plan_operand(x, masks[lo:hi], plan, axis=-1)
        return [jax.lax.slice(routed, (0, f * m), (aos.shape[0], (f + 1) * m))
                for f in range(fields)]
    outs = []
    for f, plan in enumerate(plans):
        lo, hi = spans[f]
        routed = shiftnet.apply_plan_operand(aos, masks[lo:hi], plan,
                                             axis=-1)
        outs.append(jax.lax.slice(routed, (0, 0), (aos.shape[0], m)))
    return outs


def route_interleave(x, masks, valid, mode: str, plans, spans, fields: int):
    """(rows, n) concatenated SoA -> (rows, n) AoS beat."""
    rows, n = x.shape
    if mode == "fused":
        plan = plans[0]
        xp = x if plan.n == n else jnp.pad(x, ((0, 0), (0, plan.n - n)))
        lo, hi = spans[0]
        routed = shiftnet.apply_plan_operand(xp, masks[lo:hi], plan, axis=-1)
        return jax.lax.slice(routed, (0, 0), (rows, n))
    m = n // fields
    acc = jnp.zeros((rows, n), x.dtype)
    for f, plan in enumerate(plans):
        lo, hi = spans[f]
        fx = jax.lax.slice(x, (0, f * m), (rows, (f + 1) * m))
        padded = jnp.pad(fx, ((0, 0), (0, n - m)))
        routed = shiftnet.apply_plan_operand(padded, masks[lo:hi], plan,
                                             axis=-1)
        acc = jnp.where(valid[f][None, :] != 0, routed, acc)
    return acc


# ---------------------------------------------------------------------------
# Deinterleave (segment load)
# ---------------------------------------------------------------------------

def _deint_plan_kernel(masks_ref, aos_ref, *o_refs, mode, plans, spans,
                       fields: int):
    outs = route_deinterleave(aos_ref[...], masks_ref[...], mode, plans,
                              spans, fields)
    for f in range(fields):
        o_refs[f][...] = outs[f]


def _deint_dyn_kernel(aos_ref, *o_refs, fields: int):
    aos = aos_ref[...]
    n = aos.shape[-1]
    m = n // fields
    for f in range(fields):
        shift, valid = scg.gather_counts(n, fields, f, m)
        res = shiftnet.gather_network(aos, shift[None, :], valid[None, :],
                                      axis=-1)
        o_refs[f][...] = jax.lax.slice(res.payload, (0, 0), (aos.shape[0], m))


def deinterleave(aos: jax.Array, fields: int, *,
                 fused: bool = True) -> list[jax.Array]:
    """(..., fields*m) -> fields x (..., m)   (segment load)."""
    n = aos.shape[-1]
    assert n % fields == 0
    m = n // fields
    flat, lead = _common.flatten_rows(aos)
    flat, r0, rt = _common.tile_rows(flat)
    grid = (_common.row_grid(flat.shape[0], rt),)
    out_shape = tuple(jax.ShapeDtypeStruct((flat.shape[0], m), aos.dtype)
                      for _ in range(fields))
    out_specs = tuple(pl.BlockSpec((rt, m), lambda i: (i, 0))
                      for _ in range(fields))
    if fused:
        mode, plans = shiftplan.segment_deinterleave_plans(n, fields)
        masks, spans = _stack_masks(plans)
        S, W = masks.shape
        outs = _common.call(
            functools.partial(_deint_plan_kernel, mode=mode, plans=plans,
                              spans=spans, fields=fields),
            out_shape=out_shape,
            grid=grid,
            in_specs=[pl.BlockSpec((S, W), lambda i: (0, 0)),
                      pl.BlockSpec((rt, n), lambda i: (i, 0))],
            out_specs=out_specs,
        )(jnp.asarray(masks), flat)
    else:
        outs = _common.call(
            functools.partial(_deint_dyn_kernel, fields=fields),
            out_shape=out_shape,
            grid=grid,
            in_specs=[pl.BlockSpec((rt, n), lambda i: (i, 0))],
            out_specs=out_specs,
        )(flat)
    return [o[:r0].reshape(lead + (m,)) for o in outs]


def deinterleave_many(aos_list: list[jax.Array], fields: int, *,
                      fused: bool = True) -> list[list[jax.Array]]:
    """Step-fused segment load: A same-shape AoS arrays in ONE launch.

    The stack rides through :func:`deinterleave` as a new leading dim, so
    the whole group shares one kernel launch and one mask upload (the
    whole-step analogue of the batched LSDO transaction block)."""
    outs = deinterleave(jnp.stack(aos_list), fields, fused=fused)
    return [[o[a] for o in outs] for a in range(len(aos_list))]


# ---------------------------------------------------------------------------
# Interleave (segment store)
# ---------------------------------------------------------------------------

def _int_plan_kernel(masks_ref, valid_ref, *refs, mode, plans, spans,
                     fields: int):
    f_refs, o_ref = refs[:-1], refs[-1]
    x = jnp.concatenate([r[...] for r in f_refs], axis=-1)  # (rt, n)
    o_ref[...] = route_interleave(x, masks_ref[...], valid_ref[...], mode,
                                  plans, spans, fields)


def _int_dyn_kernel(*refs, fields: int):
    f_refs, o_ref = refs[:-1], refs[-1]
    rt, m = f_refs[0].shape
    n = m * fields
    acc = jnp.zeros((rt, n), f_refs[0].dtype)
    for f in range(fields):
        padded = jnp.pad(f_refs[f][...], ((0, 0), (0, n - m)))
        shift, valid = scg.scatter_counts(n, fields, f, m)
        res = shiftnet.scatter_network(padded, shift[None, :], valid[None, :],
                                       axis=-1)
        acc = jnp.where(res.valid, res.payload, acc)
    o_ref[...] = acc


def interleave(soa: list[jax.Array], *, fused: bool = True) -> jax.Array:
    """fields x (..., m) -> (..., fields*m)   (segment store)."""
    fields = len(soa)
    m = soa[0].shape[-1]
    n = m * fields
    flats = []
    r0 = lead = rt = None
    for t in soa:
        f, lead = _common.flatten_rows(t)
        f, r0, rt = _common.tile_rows(f)
        flats.append(f)
    grid = (_common.row_grid(flats[0].shape[0], rt),)
    out_shape = jax.ShapeDtypeStruct((flats[0].shape[0], n), soa[0].dtype)
    f_specs = [pl.BlockSpec((rt, m), lambda i: (i, 0))
               for _ in range(fields)]
    if fused:
        mode, plans = shiftplan.segment_interleave_plans(n, fields)
        masks, spans = _stack_masks(plans)
        S, W = masks.shape
        valid = np.stack([p.valid for p in plans]).astype(np.int32) \
            if mode == "per_field" else np.zeros((1, n), np.int32)
        out = _common.call(
            functools.partial(_int_plan_kernel, mode=mode, plans=plans,
                              spans=spans, fields=fields),
            out_shape=out_shape,
            grid=grid,
            in_specs=[pl.BlockSpec((S, W), lambda i: (0, 0)),
                      pl.BlockSpec(valid.shape, lambda i: (0, 0))] + f_specs,
            out_specs=pl.BlockSpec((rt, n), lambda i: (i, 0)),
        )(jnp.asarray(masks), jnp.asarray(valid), *flats)
    else:
        out = _common.call(
            functools.partial(_int_dyn_kernel, fields=fields),
            out_shape=out_shape,
            grid=grid,
            in_specs=f_specs,
            out_specs=pl.BlockSpec((rt, n), lambda i: (i, 0)),
        )(*flats)
    return out[:r0].reshape(lead + (n,))
