"""SSN as a Pallas TPU kernel: route lanes toward higher indices.

Mirror of shift_gather (bits consumed MSB->LSB, diagonal links point up).
Returns both routed payload and routed validity so callers can merge into an
existing buffer (the store path of LSDO).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.core import shiftnet, shiftplan
from repro.kernels import _common


def _plan_kernel(masks_ref, valid_ref, x_ref, o_ref, ov_ref, *, plan):
    x = x_ref[...]
    routed = shiftnet.apply_plan_operand(x, masks_ref[...], plan, axis=-1)
    keep = valid_ref[...] != 0
    o_ref[...] = jnp.where(keep, routed, jnp.zeros_like(routed))
    ov_ref[...] = jnp.broadcast_to(keep, x.shape).astype(jnp.int32)


def shift_scatter_static(x: jax.Array, plan) -> tuple[jax.Array, jax.Array]:
    """Compiled-plan SSN: (payload, occupancy) with constant masks."""
    n = x.shape[-1]
    assert plan.n == n, (plan.n, n)
    flat, lead = _common.flatten_rows(x)
    flat, r0 = _common.pad_rows(flat)
    rt = _common.ROW_TILE
    masks, valid, S = _common.plan_operands(plan)
    out, outv = _common.call(
        functools.partial(_plan_kernel, plan=plan),
        out_shape=(jax.ShapeDtypeStruct(flat.shape, x.dtype),
                   jax.ShapeDtypeStruct(flat.shape, jnp.int32)),
        grid=(_common.row_grid(flat.shape[0]),),
        in_specs=[pl.BlockSpec((S, n), lambda i: (0, 0)),
                  pl.BlockSpec((1, n), lambda i: (0, 0)),
                  pl.BlockSpec((rt, n), lambda i: (i, 0))],
        out_specs=(pl.BlockSpec((rt, n), lambda i: (i, 0)),
                   pl.BlockSpec((rt, n), lambda i: (i, 0))),
    )(masks, valid, flat)
    return (out[:r0].reshape(lead + (n,)),
            (outv[:r0] != 0).reshape(lead + (n,)))


def _kernel(shift_ref, valid_ref, x_ref, o_ref, ov_ref):
    x = x_ref[...]
    shift = shift_ref[...]
    valid = valid_ref[...] != 0
    res = shiftnet.scatter_network(x, shift, valid, axis=-1)
    o_ref[...] = jnp.where(res.valid, res.payload, jnp.zeros_like(res.payload))
    ov_ref[...] = jnp.broadcast_to(res.valid, x.shape).astype(jnp.int32)


def shift_scatter(x: jax.Array, shift: jax.Array, valid: jax.Array
                  ) -> tuple[jax.Array, jax.Array]:
    """Route (..., n) lanes up by ``shift`` where ``valid``.

    Returns (payload, valid_mask) with zeros / False in unoccupied lanes.
    Host-data (shift, valid) compile to a pruned static plan.
    """
    if isinstance(shift, (np.ndarray, tuple, list)) and \
            isinstance(valid, (np.ndarray, tuple, list)):
        plan = shiftplan.counts_plan(
            tuple(int(s) for s in np.asarray(shift)),
            tuple(bool(v) for v in np.asarray(valid)), gather=False)
        return shift_scatter_static(x, plan)
    n = x.shape[-1]
    flat, lead = _common.flatten_rows(x)
    flat, r0 = _common.pad_rows(flat)
    rt = _common.ROW_TILE
    grid = (_common.row_grid(flat.shape[0]),)
    out, outv = _common.call(
        _kernel,
        out_shape=(jax.ShapeDtypeStruct(flat.shape, x.dtype),
                   jax.ShapeDtypeStruct(flat.shape, jnp.int32)),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, n), lambda i: (0, 0)),
            pl.BlockSpec((1, n), lambda i: (0, 0)),
            pl.BlockSpec((rt, n), lambda i: (i, 0)),
        ],
        out_specs=(pl.BlockSpec((rt, n), lambda i: (i, 0)),
                   pl.BlockSpec((rt, n), lambda i: (i, 0))),
    )(shift.reshape(1, n).astype(jnp.int32),
      valid.reshape(1, n).astype(jnp.int32), flat)
    return (out[:r0].reshape(lead + (n,)),
            (outv[:r0] != 0).reshape(lead + (n,)))
