"""LSDO strided load/store as Pallas TPU kernels — compiled-plan routing.

The BlockSpec load of the contiguous window IS the coalesced transaction
(one HBM->VMEM block move per aligned region, replacing ``vl`` element-wise
requests).  Since stride/offset/vl are static Python ints here, the in-kernel
reorganization is a ShiftPlan compiled by core/shiftplan.py: layer-pruned
constant take-masks (stacked into one small operand — Pallas kernels cannot
close over array constants) and ONE static lane shift + ONE select per
active layer — no runtime shift-count arithmetic in the kernel at all.

``compiled=False`` keeps the dynamic-count network in the kernel body (the
runtime-stride fallback, and the oracle the property tests compare against).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.core import scg, shiftnet, shiftplan
from repro.kernels import _common


def _gather_plan_kernel(masks_ref, x_ref, o_ref, *, plan, vl: int):
    x = x_ref[...]                        # (rt, n) coalesced window tile
    routed = shiftnet.apply_plan_operand(x, masks_ref[...], plan, axis=-1)
    o_ref[...] = jax.lax.slice(routed, (0, 0), (x.shape[0], vl))


def _gather_dyn_kernel(x_ref, o_ref, *, stride: int, offset: int, vl: int):
    x = x_ref[...]
    n = x.shape[-1]
    shift, valid = scg.gather_counts(n, stride, offset, vl)
    res = shiftnet.gather_network(x, shift[None, :], valid[None, :], axis=-1)
    o_ref[...] = jax.lax.slice(res.payload, (0, 0), (x.shape[0], vl))


def gather_strided(window: jax.Array, stride: int, offset: int, vl: int,
                   *, compiled: bool = True) -> jax.Array:
    """(..., n) -> (..., vl): out[..., i] = window[..., offset + i*stride]."""
    n = window.shape[-1]
    assert offset + (vl - 1) * stride < n
    flat, lead = _common.flatten_rows(window)
    flat, r0, rt = _common.tile_rows(flat)
    out_shape = jax.ShapeDtypeStruct((flat.shape[0], vl), window.dtype)
    grid = (_common.row_grid(flat.shape[0], rt),)
    if compiled:
        plan = shiftplan.gather_plan(n, stride, offset, vl)
        masks, _, S = _common.plan_operands(plan)
        out = _common.call(
            functools.partial(_gather_plan_kernel, plan=plan, vl=vl),
            out_shape=out_shape,
            grid=grid,
            in_specs=[pl.BlockSpec((S, n), lambda i: (0, 0)),
                      pl.BlockSpec((rt, n), lambda i: (i, 0))],
            out_specs=pl.BlockSpec((rt, vl), lambda i: (i, 0)),
        )(masks, flat)
    else:
        out = _common.call(
            functools.partial(_gather_dyn_kernel, stride=stride,
                              offset=offset, vl=vl),
            out_shape=out_shape,
            grid=grid,
            in_specs=[pl.BlockSpec((rt, n), lambda i: (i, 0))],
            out_specs=pl.BlockSpec((rt, vl), lambda i: (i, 0)),
        )(flat)
    return out[:r0].reshape(lead + (vl,))


def _gather_fused_kernel(masks_ref, x_ref, o_ref, *, plans, spans, vl: int):
    x = x_ref[...]                        # (A, rt, n) super-transaction tile
    masks = masks_ref[...] != 0
    for a, plan in enumerate(plans):
        lo, hi = spans[a]
        routed = shiftnet.apply_plan_operand(x[a], masks[lo:hi], plan,
                                             axis=-1)
        o_ref[a, ...] = jax.lax.slice(routed, (0, 0), (x.shape[1], vl))


def gather_strided_fused(windows: jax.Array, specs, vl: int, *,
                         compiled: bool = True) -> jax.Array:
    """Whole-step fused gather: A same-shape windows, possibly DIFFERENT
    (stride, offset) specs, routed in ONE kernel launch whose mask operand
    is the concatenation of every access's compiled plan.  Rows are tiled
    like every other kernel (one grid step off-TPU; VMEM-capped tiles on
    TPU, with the cap shared across the A stacked accesses).

    windows: (A, ..., n); specs: A pairs (stride, offset).
    Returns (A, ..., vl).
    """
    A = windows.shape[0]
    assert A == len(specs)
    n = windows.shape[-1]
    lead = windows.shape[1:-1]
    R = 1
    for d in lead:
        R *= d
    flat = windows.reshape(A, R, n)
    if not compiled:
        outs = [gather_strided(flat[a], s, o, vl, compiled=False)
                for a, (s, o) in enumerate(specs)]
        return jnp.stack(outs).reshape((A,) + lead + (vl,))
    plans = tuple(shiftplan.gather_plan(n, s, o, vl) for s, o in specs)
    masks, spans = _common.stack_plan_masks(plans)
    S, W = masks.shape
    # tile rows within each access; the A axis stays whole per tile, so
    # the per-tile VMEM budget is divided across the stacked accesses
    if _common.interpret_mode():
        rt = max(_common.ROW_TILE, 1 << max(R - 1, 1).bit_length())
    else:
        rt = _common.row_tile(R + (-R) % _common.ROW_TILE,
                              cap=max(_common.ROW_TILE, 256 // A))
    pad = (-R) % rt
    if pad:
        flat = jnp.pad(flat, ((0, 0), (0, pad), (0, 0)))
    Rp = flat.shape[1]
    out = _common.call(
        functools.partial(_gather_fused_kernel, plans=plans, spans=spans,
                          vl=vl),
        out_shape=jax.ShapeDtypeStruct((A, Rp, vl), windows.dtype),
        grid=(_common.row_grid(Rp, rt),),
        in_specs=[pl.BlockSpec((S, W), lambda i: (0, 0)),
                  pl.BlockSpec((A, rt, n), lambda i: (0, i, 0)),],
        out_specs=pl.BlockSpec((A, rt, vl), lambda i: (0, i, 0)),
    )(jnp.asarray(masks), flat)
    return out[:, :R].reshape((A,) + lead + (vl,))


def _scatter_plan_kernel(masks_ref, valid_ref, vals_ref, win_ref, o_ref, *,
                         plan):
    vals = vals_ref[...]                  # (rt, vl)
    win = win_ref[...]                    # (rt, n)
    n = win.shape[-1]
    padded = jnp.pad(vals, ((0, 0), (0, n - vals.shape[-1])))
    routed = shiftnet.apply_plan_operand(padded, masks_ref[...], plan,
                                         axis=-1)
    o_ref[...] = jnp.where(valid_ref[...] != 0, routed, win)


def _scatter_dyn_kernel(vals_ref, win_ref, o_ref, *, stride: int,
                        offset: int):
    vals = vals_ref[...]
    win = win_ref[...]
    n = win.shape[-1]
    vl = vals.shape[-1]
    padded = jnp.pad(vals, ((0, 0), (0, n - vl)))
    shift, valid = scg.scatter_counts(n, stride, offset, vl)
    res = shiftnet.scatter_network(padded, shift[None, :], valid[None, :],
                                   axis=-1)
    o_ref[...] = jnp.where(res.valid, res.payload, win)


def scatter_strided(window: jax.Array, values: jax.Array, stride: int,
                    offset: int, *, compiled: bool = True) -> jax.Array:
    """Merge dense values into strided positions of window (read-modify-write,
    the SIFQ store path)."""
    n = window.shape[-1]
    vl = values.shape[-1]
    assert offset + (vl - 1) * stride < n
    fw, lead = _common.flatten_rows(window)
    fv, _ = _common.flatten_rows(values)
    fw, r0, rt = _common.tile_rows(fw)
    fv, _ = _common.pad_rows(fv, rt)
    grid = (_common.row_grid(fw.shape[0], rt),)
    if compiled:
        plan = shiftplan.scatter_plan(n, stride, offset, vl)
        masks, valid, S = _common.plan_operands(plan)
        out = _common.call(
            functools.partial(_scatter_plan_kernel, plan=plan),
            out_shape=jax.ShapeDtypeStruct(fw.shape, window.dtype),
            grid=grid,
            in_specs=[pl.BlockSpec((S, n), lambda i: (0, 0)),
                      pl.BlockSpec((1, n), lambda i: (0, 0)),
                      pl.BlockSpec((rt, vl), lambda i: (i, 0)),
                      pl.BlockSpec((rt, n), lambda i: (i, 0))],
            out_specs=pl.BlockSpec((rt, n), lambda i: (i, 0)),
        )(masks, valid, fv, fw)
    else:
        out = _common.call(
            functools.partial(_scatter_dyn_kernel, stride=stride,
                              offset=offset),
            out_shape=jax.ShapeDtypeStruct(fw.shape, window.dtype),
            grid=grid,
            in_specs=[pl.BlockSpec((rt, vl), lambda i: (i, 0)),
                      pl.BlockSpec((rt, n), lambda i: (i, 0))],
            out_specs=pl.BlockSpec((rt, n), lambda i: (i, 0)),
        )(fv, fw)
    return out[:r0].reshape(lead + (n,))
