"""LSDO strided load/store as Pallas TPU kernels.

The BlockSpec load of the contiguous window IS the coalesced transaction
(one HBM->VMEM block move per aligned region, replacing ``vl`` element-wise
requests); the in-kernel shift network is the DROM reorganization.  Shift
counts use the EARTH §4.2 closed form, computed with static stride/offset so
the layer masks are constants folded by Mosaic.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import scg, shiftnet
from repro.kernels import _common


def _gather_kernel(x_ref, o_ref, *, stride: int, offset: int, vl: int):
    x = x_ref[...]                        # (rt, n) coalesced window tile
    n = x.shape[-1]
    shift, valid = scg.gather_counts(n, stride, offset, vl)
    res = shiftnet.gather_network(x, shift[None, :], valid[None, :], axis=-1)
    o_ref[...] = jax.lax.slice(res.payload, (0, 0), (x.shape[0], vl))


def gather_strided(window: jax.Array, stride: int, offset: int, vl: int
                   ) -> jax.Array:
    """(..., n) -> (..., vl): out[..., i] = window[..., offset + i*stride]."""
    n = window.shape[-1]
    assert offset + (vl - 1) * stride < n
    flat, lead = _common.flatten_rows(window)
    flat, r0 = _common.pad_rows(flat)
    rt = _common.ROW_TILE
    out = _common.call(
        functools.partial(_gather_kernel, stride=stride, offset=offset, vl=vl),
        out_shape=jax.ShapeDtypeStruct((flat.shape[0], vl), window.dtype),
        grid=(_common.row_grid(flat.shape[0]),),
        in_specs=[pl.BlockSpec((rt, n), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((rt, vl), lambda i: (i, 0)),
    )(flat)
    return out[:r0].reshape(lead + (vl,))


def _scatter_kernel(vals_ref, win_ref, o_ref, *, stride: int, offset: int):
    vals = vals_ref[...]                  # (rt, vl)
    win = win_ref[...]                    # (rt, n)
    n = win.shape[-1]
    vl = vals.shape[-1]
    padded = jnp.pad(vals, ((0, 0), (0, n - vl)))
    shift, valid = scg.scatter_counts(n, stride, offset, vl)
    res = shiftnet.scatter_network(padded, shift[None, :], valid[None, :],
                                   axis=-1)
    o_ref[...] = jnp.where(res.valid, res.payload, win)


def scatter_strided(window: jax.Array, values: jax.Array, stride: int,
                    offset: int) -> jax.Array:
    """Merge dense values into strided positions of window (read-modify-write,
    the SIFQ store path)."""
    n = window.shape[-1]
    vl = values.shape[-1]
    assert offset + (vl - 1) * stride < n
    fw, lead = _common.flatten_rows(window)
    fv, _ = _common.flatten_rows(values)
    fw, r0 = _common.pad_rows(fw)
    fv, _ = _common.pad_rows(fv)
    rt = _common.ROW_TILE
    out = _common.call(
        functools.partial(_scatter_kernel, stride=stride, offset=offset),
        out_shape=jax.ShapeDtypeStruct(fw.shape, window.dtype),
        grid=(_common.row_grid(fw.shape[0]),),
        in_specs=[pl.BlockSpec((rt, vl), lambda i: (i, 0)),
                  pl.BlockSpec((rt, n), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((rt, n), lambda i: (i, 0)),
    )(fv, fw)
    return out[:r0].reshape(lead + (n,))
