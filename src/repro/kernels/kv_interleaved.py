"""Interleaved (AoS) KV-cache ops — EARTH segment access applied to serving.

Layout: cache[..., t, 2*d] holds [k0, v0, k1, v1, ...] per token — K and V
of a token are ONE contiguous beat, so a decode-step append is a single
coalesced write (the paper's one-transaction-per-segment), and attention-time
splitting is a FIELD=2 segment load.  All routing goes through the
declarative vx API: a ``Segment(fields=2)`` spec, a policy (the model's
``cfg.vx_policy``) picking the lowering — under ``pallas`` the split/pack
run the FUSED segment kernel (one compiled-permutation pass producing both
K and V, core/shiftplan.py), never two sequential gather networks.
"""
from __future__ import annotations

import jax

from repro import vx


def _spec(n: int) -> vx.Segment:
    return vx.Segment(n=n, fields=2)


def interleave_kv(k: jax.Array, v: jax.Array, *, policy=None) -> jax.Array:
    """(..., d) x2 -> (..., 2d) AoS beat."""
    return vx.transpose(_spec(2 * k.shape[-1]), [k, v], policy=policy)


def split_kv(kv: jax.Array, *, policy=None) -> tuple[jax.Array, jax.Array]:
    """(..., 2d) -> (k, v)."""
    k, v = vx.transpose(_spec(kv.shape[-1]), kv, policy=policy)
    return k, v


def split_kv_step(kvs: list[jax.Array], *, policy=None, shard=None
                  ) -> list[tuple[jax.Array, jax.Array]]:
    """Whole-step KV split: EVERY layer's (…, 2d) cache in one fused
    FIELD=2 segment load — one kernel launch and one mask upload per decode
    step instead of one per layer (core/accessfuse.py groups same-shape
    caches; mixed window sizes form one group per shape).

    ``shard`` (a ``vx.Shard`` on the cache's sequence axis) lowers the
    merged split shard-locally under ``shard_map`` — the seq-parallel
    long-context cache transposes in place, never gathered or sliced
    globally (the PR 4 sharding-aware lowering)."""
    from repro.core import accessfuse
    return accessfuse.fuse_split_kv(kvs, policy=vx.resolve(policy),
                                    shard=shard)


def gather_paged_kv(pools: list[jax.Array], table: jax.Array,
                    page_size: int, *, policy=None, shard=None,
                    fused: bool = True, scales=None) -> list[jax.Array]:
    """Whole-step paged KV read: every layer's page pool gathered through
    ONE shared page table.

    ``pools``: same-shape ``(NS, P, page_size, K, 2d)`` pool leaves (all
    layers append in lockstep, so one ``(B, pages)`` table serves them
    all).  ``fused=True`` stacks the pools and runs ONE page-granular
    gather program (``vx.gather_many`` + ``vx.program.fuse``); the
    heterogeneous per-request lengths live in the runtime table, so the
    compiled program is keyed only by the page geometry and is reused
    across every request and step.  ``shard`` (a ``vx.Shard`` on the pool
    page axis, ``axis=-4``) gathers shard-locally from the owned page
    block — the sharded pool is never sliced globally.

    Returns the gathered interleaved ``(NS, B, pages*page_size, K, 2d)``
    sequences, one per pool; split K/V with :func:`split_kv_step` (still
    one fused FIELD=2 launch for the whole step).

    QUANTIZED pools (int8/fp8) pass their per-page ``(NS, P, K)`` scale
    tensors as ``scales=`` (one per pool, stacked like the pools) — the
    dequant rides the same single gather program and the returned
    sequences are float.
    """
    spec = vx.Paged(page_size=page_size, pages=table.shape[-1], trail=2)
    if fused:
        return vx.gather_many(spec, pools, table=table, scales=scales,
                              policy=policy, shard=shard)
    if scales is None:
        scales = [None] * len(pools)
    return [vx.gather(spec, p, table=table, scales=s, policy=policy,
                      shard=shard)
            for p, s in zip(pools, scales)]


def append_paged_token(pool: jax.Array, k: jax.Array, v: jax.Array,
                       table: jax.Array, pos, *, policy=None, scales=None):
    """Write one token's interleaved KV beat through the page table.

    pool: (..., P, page_size, H, 2d); k, v: (B, H, d); pos: (B,) int32
    per-slot positions (rows with ``pos < 0`` or an unallocated page are
    dropped — an idle serving slot appends nothing).  One page-routed
    scatter per layer, same coalescing as :func:`append_token`.

    A QUANTIZED pool passes its per-page scales and gets back
    ``(pool, scales)`` — the beat quantizes on write, the page scale
    widens monotonically (vx/lower.py).
    """
    beat = interleave_kv(k, v, policy=policy)             # (B, H, 2d)
    spec = vx.Paged(page_size=pool.shape[-3], pages=table.shape[-1],
                    trail=2)
    return vx.scatter(spec, pool, beat, table=table, pos=pos,
                      scales=scales, policy=policy)


def append_token(cache: jax.Array, k: jax.Array, v: jax.Array, pos,
                 *, policy=None) -> jax.Array:
    """Write one token's interleaved KV beat at position ``pos``.

    cache: (B, S, H, 2d); k, v: (B, H, d); pos: scalar int (same for batch).
    One dynamic_update_slice per layer instead of two (K and V) — the
    coalescing win, measured in benchmarks/bench_segment.py.
    """
    beat = interleave_kv(k, v, policy=policy)             # (B, H, 2d)
    beat = beat[:, None]                                  # (B, 1, H, 2d)
    return jax.lax.dynamic_update_slice_in_dim(cache, beat.astype(cache.dtype),
                                               pos, axis=1)
