"""Interleaved (AoS) KV-cache ops — EARTH segment access applied to serving.

Layout: cache[..., t, 2*d] holds [k0, v0, k1, v1, ...] per token — K and V
of a token are ONE contiguous beat, so a decode-step append is a single
coalesced write (the paper's one-transaction-per-segment), and attention-time
splitting is a FIELD=2 segment load.  With impl="pallas" the split/pack go
through the FUSED segment kernel: one compiled-permutation pass (static
shifts + constant masks, core/shiftplan.py) produces both K and V — not two
sequential gather networks.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ref as _ref
from repro.kernels import segment as _segment


def interleave_kv(k: jax.Array, v: jax.Array, *, impl: str = "ref") -> jax.Array:
    """(..., d) x2 -> (..., 2d) AoS beat."""
    if impl == "pallas":
        return _segment.interleave([k, v])
    return _ref.kv_interleave(k, v)


def split_kv(kv: jax.Array, *, impl: str = "ref") -> tuple[jax.Array, jax.Array]:
    """(..., 2d) -> (k, v)."""
    if impl == "pallas":
        k, v = _segment.deinterleave(kv, 2)
        return k, v
    return _ref.kv_split(kv)


def split_kv_step(kvs: list[jax.Array], *, impl: str = "ref"
                  ) -> list[tuple[jax.Array, jax.Array]]:
    """Whole-step KV split: EVERY layer's (…, 2d) cache in one fused
    FIELD=2 segment load — one kernel launch and one mask upload per decode
    step instead of one per layer (core/accessfuse.py groups same-shape
    caches; mixed window sizes form one group per shape)."""
    from repro.core import accessfuse
    return accessfuse.fuse_split_kv(kvs, impl=impl)


def append_token(cache: jax.Array, k: jax.Array, v: jax.Array, pos,
                 *, impl: str = "ref") -> jax.Array:
    """Write one token's interleaved KV beat at position ``pos``.

    cache: (B, S, H, 2d); k, v: (B, H, d); pos: scalar int (same for batch).
    One dynamic_update_slice per layer instead of two (K and V) — the
    coalescing win, measured in benchmarks/bench_segment.py.
    """
    beat = interleave_kv(k, v, impl=impl)                 # (B, H, 2d)
    beat = beat[:, None]                                  # (B, 1, H, 2d)
    return jax.lax.dynamic_update_slice_in_dim(cache, beat.astype(cache.dtype),
                                               pos, axis=1)
