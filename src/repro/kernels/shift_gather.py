"""GSN as a Pallas TPU kernel: route lanes toward lower indices.

This is the raw DROM gather entry point: callers provide per-lane shift
counts and a validity mask (the SCG output); the kernel runs the log-depth
layer loop on a VMEM-resident tile.  Each layer is a STATIC lane shift
(compile-time ``2**l``) + select — the TPU-native form of EARTH's
straight/diagonal link layers.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.core import shiftnet, shiftplan
from repro.kernels import _common


def _kernel(shift_ref, valid_ref, x_ref, o_ref):
    x = x_ref[...]                       # (rt, n) VMEM tile
    shift = shift_ref[...]               # (1, n) int32, shared across rows
    valid = valid_ref[...] != 0          # (1, n)
    res = shiftnet.gather_network(x, shift, valid, axis=-1)
    o_ref[...] = jnp.where(res.valid, res.payload, jnp.zeros_like(res.payload))


def _plan_kernel(masks_ref, valid_ref, x_ref, o_ref, *, plan):
    x = x_ref[...]
    routed = shiftnet.apply_plan_operand(x, masks_ref[...], plan, axis=-1)
    o_ref[...] = jnp.where(valid_ref[...] != 0, routed,
                           jnp.zeros_like(routed))


def _is_static(a) -> bool:
    return isinstance(a, (np.ndarray, tuple, list))


def shift_gather_static(x: jax.Array, plan) -> jax.Array:
    """Route lanes through a compiled ShiftPlan (pruned constant masks)."""
    n = x.shape[-1]
    assert plan.n == n, (plan.n, n)
    flat, lead = _common.flatten_rows(x)
    flat, r0 = _common.pad_rows(flat)
    rt = _common.ROW_TILE
    masks, valid, S = _common.plan_operands(plan)
    out = _common.call(
        functools.partial(_plan_kernel, plan=plan),
        out_shape=jax.ShapeDtypeStruct(flat.shape, x.dtype),
        grid=(_common.row_grid(flat.shape[0]),),
        in_specs=[pl.BlockSpec((S, n), lambda i: (0, 0)),
                  pl.BlockSpec((1, n), lambda i: (0, 0)),
                  pl.BlockSpec((rt, n), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((rt, n), lambda i: (i, 0)),
    )(masks, valid, flat)
    return out[:r0].reshape(lead + (n,))


def shift_gather(x: jax.Array, shift: jax.Array, valid: jax.Array) -> jax.Array:
    """Route (..., n) lanes down by ``shift`` where ``valid``; zero elsewhere.

    shift, valid: (n,) — one routing program shared by all rows (matching
    DROM: one SCG feeds the whole beat).  When both are HOST data (NumPy /
    tuples) the routing is compiled to a pruned static plan; traced arrays
    take the dynamic-count network.
    """
    if _is_static(shift) and _is_static(valid):
        plan = shiftplan.counts_plan(
            tuple(int(s) for s in np.asarray(shift)),
            tuple(bool(v) for v in np.asarray(valid)), gather=True)
        return shift_gather_static(x, plan)
    n = x.shape[-1]
    flat, lead = _common.flatten_rows(x)
    flat, r0 = _common.pad_rows(flat)
    rt = _common.ROW_TILE
    grid = (_common.row_grid(flat.shape[0]),)
    out = _common.call(
        _kernel,
        out_shape=jax.ShapeDtypeStruct(flat.shape, x.dtype),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, n), lambda i: (0, 0)),
            pl.BlockSpec((1, n), lambda i: (0, 0)),
            pl.BlockSpec((rt, n), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((rt, n), lambda i: (i, 0)),
    )(shift.reshape(1, n).astype(jnp.int32),
      valid.reshape(1, n).astype(jnp.int32), flat)
    return out[:r0].reshape(lead + (n,))
