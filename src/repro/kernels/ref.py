"""Pure-jnp oracles for every Pallas kernel in this package.

These define the semantics; kernels must match them bit-exactly (allclose for
floating point).  They are also the XLA fallback path lowered by the
512-device dry-run (kernels are per-device local ops there).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# Strided access (LSDO semantics)
# ---------------------------------------------------------------------------

def gather_strided(window: jax.Array, stride: int, offset: int, vl: int) -> jax.Array:
    """(..., n) -> (..., vl): out[..., i] = window[..., offset + i*stride]."""
    n = window.shape[-1]
    start = [0] * (window.ndim - 1) + [offset]
    limit = list(window.shape[:-1]) + [offset + (vl - 1) * stride + 1]
    strides = [1] * (window.ndim - 1) + [stride]
    assert limit[-1] <= n, f"strided read past window: {limit[-1]} > {n}"
    return jax.lax.slice(window, start, limit, strides)


def scatter_strided(window: jax.Array, values: jax.Array, stride: int,
                    offset: int) -> jax.Array:
    """Inverse of gather_strided: place values at strided positions."""
    vl = values.shape[-1]
    assert offset + (vl - 1) * stride + 1 <= window.shape[-1]
    idx = (Ellipsis, slice(offset, offset + (vl - 1) * stride + 1, stride))
    return window.at[idx].set(values)


# ---------------------------------------------------------------------------
# Segment access (RCVRF semantics): AoS <-> SoA
# ---------------------------------------------------------------------------

def deinterleave(aos: jax.Array, fields: int) -> list[jax.Array]:
    """(..., fields*m) AoS -> fields tensors (..., m)."""
    assert aos.shape[-1] % fields == 0
    m = aos.shape[-1] // fields
    r = aos.reshape(aos.shape[:-1] + (m, fields))
    return [r[..., f] for f in range(fields)]


def interleave(soa: list[jax.Array]) -> jax.Array:
    """fields tensors (..., m) -> (..., fields*m) AoS."""
    stacked = jnp.stack(soa, axis=-1)  # (..., m, fields)
    return stacked.reshape(stacked.shape[:-2] + (-1,))


# ---------------------------------------------------------------------------
# Row compaction / expansion (MoE dispatch semantics)
# ---------------------------------------------------------------------------

def compact_rows(rows: jax.Array, mask: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Pack rows where mask is set to the front (stable); zero-fill the rest.

    rows: (n, d...); mask: (n,) bool. Returns (packed, packed_valid)."""
    n = rows.shape[0]
    mask = mask.astype(bool)
    order = jnp.argsort(~mask, stable=True)
    packed = rows[order]
    total = jnp.sum(mask.astype(jnp.int32))
    packed_valid = jnp.arange(n) < total
    zeros = jnp.zeros_like(packed)
    keep = packed_valid.reshape((n,) + (1,) * (rows.ndim - 1))
    return jnp.where(keep, packed, zeros), packed_valid


def expand_rows(packed: jax.Array, mask: jax.Array) -> jax.Array:
    """Inverse of compact_rows: packed[k] -> position of k-th set bit."""
    n = packed.shape[0]
    mask = mask.astype(bool)
    rank = jnp.cumsum(mask.astype(jnp.int32)) - 1
    src = jnp.where(mask, rank, n)  # n = out-of-bounds -> dropped
    out = jnp.zeros_like(packed)
    return out.at[jnp.arange(n)].set(
        jnp.where(mask.reshape((n,) + (1,) * (packed.ndim - 1)),
                  packed.at[src, ...].get(mode="fill", fill_value=0),
                  jnp.zeros_like(packed)))


# ---------------------------------------------------------------------------
# Interleaved KV cache (segment FIELD=2 over the feature dim)
# ---------------------------------------------------------------------------

def kv_interleave(k: jax.Array, v: jax.Array) -> jax.Array:
    """(..., d), (..., d) -> (..., 2d) with [k0, v0, k1, v1, ...] layout."""
    return interleave([k, v])


def kv_split(kv: jax.Array) -> tuple[jax.Array, jax.Array]:
    k, v = deinterleave(kv, 2)
    return k, v
