"""Shared plumbing for the EARTH Pallas kernels.

Kernels are written for TPU (pl.pallas_call + BlockSpec VMEM tiling) and
validated on CPU with ``interpret=True`` — the kernel bodies use only
static-shape slice/pad/where ops, which lower to cheap VREG data movement on
real TPUs (see DESIGN.md §2).  Static-pattern kernels route via compiled
ShiftPlans (DESIGN.md §3): take-masks ride in as one stacked operand
(Pallas kernels cannot close over array constants) while shift amounts and
layer structure stay static Python in the kernel closure.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Row-tile height for 2-D kernels: one sublane group.
ROW_TILE = 8


@functools.cache
def interpret_mode() -> bool:
    """True when no TPU is present (CI / this container)."""
    try:
        return jax.devices()[0].platform != "tpu"
    except Exception:  # pragma: no cover
        return True


def flatten_rows(x: jax.Array) -> tuple[jax.Array, tuple[int, ...]]:
    """(..., n) -> (R, n) plus the leading shape for unflattening."""
    lead = x.shape[:-1]
    r = 1
    for d in lead:
        r *= d
    return x.reshape(r, x.shape[-1]), lead


def pad_rows(x: jax.Array, tile: int = ROW_TILE) -> tuple[jax.Array, int]:
    """Pad axis 0 to a multiple of ``tile``; returns (padded, original_rows)."""
    r = x.shape[0]
    pad = (-r) % tile
    if pad:
        x = jnp.pad(x, [(0, pad)] + [(0, 0)] * (x.ndim - 1))
    return x, r


def row_grid(rows: int, tile: int = ROW_TILE) -> int:
    assert rows % tile == 0
    return rows // tile


def plan_operands(plan):
    """(masks, valid, S) kernel operands for a compiled ShiftPlan.

    masks: (S, plan.n) int32 stacked take-masks, padded to one dummy row
    for empty plans (Pallas rejects zero-size blocks — apply_plan_operand
    consumes zero rows in that case).  valid: (1, plan.n) int32 occupancy.
    """
    import numpy as np

    from repro.core import shiftnet
    masks = shiftnet.plan_mask_stack(plan).astype(np.int32)
    if not masks.shape[0]:
        masks = np.zeros((1, plan.n), np.int32)
    valid = plan.valid.astype(np.int32).reshape(1, plan.n)
    return jnp.asarray(masks), jnp.asarray(valid), masks.shape[0]


def call(kernel, *, out_shape, grid, in_specs, out_specs, **kwargs):
    """pallas_call with the platform-appropriate interpret flag."""
    return pl.pallas_call(
        kernel,
        out_shape=out_shape,
        grid=grid,
        in_specs=in_specs,
        out_specs=out_specs,
        interpret=interpret_mode(),
        **kwargs,
    )
