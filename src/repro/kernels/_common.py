"""Shared plumbing for the EARTH Pallas kernels.

Kernels are written for TPU (pl.pallas_call + BlockSpec VMEM tiling) and
validated on CPU with ``interpret=True`` — the kernel bodies use only
static-shape slice/pad/where ops, which lower to cheap VREG data movement on
real TPUs (see DESIGN.md §2).  Static-pattern kernels route via compiled
ShiftPlans (DESIGN.md §3): take-masks ride in as one stacked operand
(Pallas kernels cannot close over array constants) while shift amounts and
layer structure stay static Python in the kernel closure.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Row-tile height for 2-D kernels: one sublane group.
ROW_TILE = 8


@functools.cache
def interpret_mode() -> bool:
    """True when no TPU is present (CI / this container)."""
    try:
        return jax.devices()[0].platform != "tpu"
    except Exception:  # pragma: no cover
        return True


def flatten_rows(x: jax.Array) -> tuple[jax.Array, tuple[int, ...]]:
    """(..., n) -> (R, n) plus the leading shape for unflattening."""
    lead = x.shape[:-1]
    r = 1
    for d in lead:
        r *= d
    return x.reshape(r, x.shape[-1]), lead


def pad_rows(x: jax.Array, tile: int = ROW_TILE) -> tuple[jax.Array, int]:
    """Pad axis 0 to a multiple of ``tile``; returns (padded, original_rows)."""
    r = x.shape[0]
    pad = (-r) % tile
    if pad:
        x = jnp.pad(x, [(0, pad)] + [(0, 0)] * (x.ndim - 1))
    return x, r


def row_grid(rows: int, tile: int = ROW_TILE) -> int:
    assert rows % tile == 0
    return rows // tile


def row_tile(rows: int, cap: int = 256) -> int:
    """Largest power-of-two multiple of ROW_TILE dividing ``rows``, capped.

    Whole-step fused super-transactions stack many accesses into one tall
    block; with a fixed 8-row tile the grid step count grows with the
    stack and both interpret-mode grid iteration and TPU grid dispatch
    scale with it.  A (cap, n) block stays far inside VMEM."""
    t = ROW_TILE
    while rows % (t * 2) == 0 and t * 2 <= cap:
        t *= 2
    return t


def tile_rows(x: jax.Array, cap: int = 256) -> tuple[jax.Array, int, int]:
    """Pad axis 0 and pick the row tile: (padded, original_rows, tile).

    On TPU: pad to ROW_TILE and tile up to ``cap`` rows (fewer grid
    dispatches, still pipelined).  Off-TPU (interpret mode) a grid step
    costs a full-buffer copy regardless of block height, so the whole
    padded block becomes ONE grid step (tile = rows padded to a power of
    two — at most 2x routing work, instead of rows/8 buffer copies)."""
    r = x.shape[0]
    if interpret_mode():
        tile = max(ROW_TILE, 1 << max(r - 1, 1).bit_length())
        pad = tile - r
        if pad:
            x = jnp.pad(x, [(0, pad)] + [(0, 0)] * (x.ndim - 1))
        return x, r, tile
    x, r = pad_rows(x)
    return x, r, row_tile(x.shape[0], cap)


def stack_plan_masks(plans) -> tuple:
    """Concat several plans' mask rows into ONE (S, n) int32 operand plus
    per-plan row spans — the single concatenated mask upload of a fused
    super-transaction (used by segment and multi-access strided kernels)."""
    import numpy as np

    from repro.core import shiftnet
    rows, spans = [], []
    for p in plans:
        r = shiftnet.plan_mask_stack(p)
        spans.append((len(rows), len(rows) + r.shape[0]))
        rows.extend(r)
    if not rows:
        return np.zeros((1, plans[0].n), np.int32), spans
    return np.stack(rows).astype(np.int32), spans


def plan_operands(plan):
    """(masks, valid, S) kernel operands for a compiled ShiftPlan.

    masks: (S, plan.n) int32 stacked take-masks, padded to one dummy row
    for empty plans (Pallas rejects zero-size blocks — apply_plan_operand
    consumes zero rows in that case).  valid: (1, plan.n) int32 occupancy.
    """
    import numpy as np

    from repro.core import shiftnet
    masks = shiftnet.plan_mask_stack(plan).astype(np.int32)
    if not masks.shape[0]:
        masks = np.zeros((1, plan.n), np.int32)
    valid = plan.valid.astype(np.int32).reshape(1, plan.n)
    return jnp.asarray(masks), jnp.asarray(valid), masks.shape[0]


def pytree_nbytes(tree) -> int:
    """Total payload bytes of a pytree of arrays (cache-memory accounting
    for the serving benchmarks and the paged-pool stats)."""
    return sum(leaf.size * leaf.dtype.itemsize
               for leaf in jax.tree_util.tree_leaves(tree)
               if hasattr(leaf, "dtype"))


def call(kernel, *, out_shape, grid, in_specs, out_specs, **kwargs):
    """pallas_call with the platform-appropriate interpret flag."""
    return pl.pallas_call(
        kernel,
        out_shape=out_shape,
        grid=grid,
        in_specs=in_specs,
        out_specs=out_specs,
        interpret=interpret_mode(),
        **kwargs,
    )
