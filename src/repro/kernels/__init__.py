"""EARTH Pallas TPU kernels: shift-network gather/scatter, segment
(AoS<->SoA), LSDO strided load/store, MoE compaction, interleaved KV cache.

Each kernel has a pure-jnp oracle in ref.py; dispatch happens in the
declarative ``repro.vx`` API (spec + verb + policy).  ``ops.py`` survives
only as a deprecated delegating shim.
"""
from repro.kernels import ops, ref  # noqa: F401
