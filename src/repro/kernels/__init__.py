"""EARTH Pallas TPU kernels: shift-network gather/scatter, segment
(AoS<->SoA), LSDO strided load/store, MoE compaction, interleaved KV cache.

Each kernel has a pure-jnp oracle in ref.py and a jit-friendly wrapper in
ops.py; tests sweep shapes/dtypes and assert allclose against the oracle.
"""
from repro.kernels import ops, ref  # noqa: F401
