"""Public wrappers for the EARTH kernels with impl dispatch.

impl="ref"    -> pure-jnp oracle (XLA path; used by the dry-run lowering)
impl="pallas" -> Pallas TPU kernel (interpret mode off-TPU)

Strides / offsets / field counts are static Python ints (they parameterize
shift tables and block shapes); callers jit around these wrappers.
"""
from __future__ import annotations

from typing import Sequence

import jax

from repro.kernels import ref as _ref


def _pick(impl: str, ref_fn, pallas_fn):
    if impl == "pallas":
        return pallas_fn
    if impl == "ref":
        return ref_fn
    raise ValueError(f"unknown impl {impl!r} (want 'ref' or 'pallas')")


def gather_strided(window: jax.Array, stride: int, offset: int, vl: int,
                   *, impl: str = "ref") -> jax.Array:
    from repro.kernels import strided as _strided
    fn = _pick(impl, _ref.gather_strided, _strided.gather_strided)
    return fn(window, stride, offset, vl)


def scatter_strided(window: jax.Array, values: jax.Array, stride: int,
                    offset: int, *, impl: str = "ref") -> jax.Array:
    from repro.kernels import strided as _strided
    fn = _pick(impl, _ref.scatter_strided, _strided.scatter_strided)
    return fn(window, values, stride, offset)


def deinterleave(aos: jax.Array, fields: int, *, impl: str = "ref"
                 ) -> list[jax.Array]:
    from repro.kernels import segment as _segment
    fn = _pick(impl, _ref.deinterleave, _segment.deinterleave)
    return fn(aos, fields)


def interleave(soa: Sequence[jax.Array], *, impl: str = "ref") -> jax.Array:
    from repro.kernels import segment as _segment
    fn = _pick(impl, _ref.interleave, _segment.interleave)
    return fn(list(soa))


def compact_rows(rows: jax.Array, mask: jax.Array, *, impl: str = "ref"
                 ) -> tuple[jax.Array, jax.Array]:
    from repro.kernels import moe_compact as _mc
    fn = _pick(impl, _ref.compact_rows, _mc.compact_rows)
    return fn(rows, mask)


def expand_rows(packed: jax.Array, mask: jax.Array, *, impl: str = "ref"
                ) -> jax.Array:
    from repro.kernels import moe_compact as _mc
    fn = _pick(impl, _ref.expand_rows, _mc.expand_rows)
    return fn(packed, mask)


def shift_gather(x: jax.Array, shift: jax.Array, valid: jax.Array,
                 *, impl: str = "pallas") -> jax.Array:
    """Raw DROM gather (no closed-form SCG) — pallas-only primitive."""
    from repro.kernels import shift_gather as _sg
    from repro.core import shiftnet
    if impl == "pallas":
        return _sg.shift_gather(x, shift, valid)
    res = shiftnet.gather_network(x, shift, valid, axis=-1)
    import jax.numpy as jnp
    return jnp.where(res.valid, res.payload, jnp.zeros_like(res.payload))


def shift_scatter(x: jax.Array, shift: jax.Array, valid: jax.Array,
                  *, impl: str = "pallas") -> tuple[jax.Array, jax.Array]:
    """Raw DROM scatter — returns (payload, occupancy mask)."""
    from repro.kernels import shift_scatter as _ss
    from repro.core import shiftnet
    if impl == "pallas":
        return _ss.shift_scatter(x, shift, valid)
    res = shiftnet.scatter_network(x, shift, valid, axis=-1)
    import jax.numpy as jnp
    return (jnp.where(res.valid, res.payload, jnp.zeros_like(res.payload)),
            jnp.broadcast_to(res.valid, x.shape))
