"""DEPRECATED — legacy impl-string wrappers, superseded by ``repro.vx``.

Every function here delegates to the declarative vx API (one spec type,
four verbs, policy-driven dispatch — see ``src/repro/vx/__init__.py`` and
DESIGN.md §10) and emits a :class:`DeprecationWarning`.  Internal code
(src/, examples/, benchmarks/) must call ``vx`` directly; CI escalates
these shim warnings to errors (``-W "error:repro.:DeprecationWarning"``)
to keep it that way.

Migration map (old -> new):

    gather_strided(w, s, o, vl, impl=i)   vx.gather(vx.Strided(n, s, vl, o), w, policy=i)
    scatter_strided(w, v, s, o, impl=i)   vx.scatter(vx.Strided(n, s, vl, o), w, v, policy=i)
    gather_strided_rt(w, s, o, vl)        vx.gather(vx.Strided(n, vx.BANK, vl, o), w, stride=s)
    scatter_strided_rt(w, v, s, o)        vx.scatter(vx.Strided(n, vx.BANK, vl, o), w, v, stride=s)
    gather_strided_many(ws, specs, vl)    vx.gather_many([vx.Strided(...)], ws)
    deinterleave(a, f, impl=i)            vx.transpose(vx.Segment(n, f), a, policy=i)
    interleave(soa, impl=i)               vx.transpose(vx.Segment(n, f), soa, policy=i)
    deinterleave_many(aos_list, f)        vx.gather_many(vx.Segment(n, f), aos_list)
    interleave_many(groups)               vx.scatter_many(vx.Segment(n, f), groups)
    compact_rows(rows, mask, impl=i)      vx.compact(vx.Compact(n), mask, rows, policy=i)
    expand_rows(packed, mask, impl=i)     vx.scatter(vx.Compact(n), mask, packed, policy=i)
    shift_gather(x, shift, valid)         vx.gather(vx.Indexed(n), x, shift=.., valid=..)
    shift_scatter(x, shift, valid)        vx.scatter(vx.Indexed(n), None, x, shift=.., valid=..)
"""
from __future__ import annotations

import warnings
from typing import Sequence

import jax

from repro import vx


def _warn(name: str, repl: str) -> None:
    warnings.warn(
        f"repro.kernels.ops.{name} is deprecated; use {repl} "
        f"(see DESIGN.md §10)", DeprecationWarning, stacklevel=3)


def gather_strided(window: jax.Array, stride: int, offset: int, vl: int,
                   *, impl: str = "ref") -> jax.Array:
    _warn("gather_strided", "vx.gather(vx.Strided(...), window)")
    spec = vx.Strided(n=window.shape[-1], stride=stride, vl=vl,
                      offset=offset)
    return vx.gather(spec, window, policy=impl)


def scatter_strided(window: jax.Array, values: jax.Array, stride: int,
                    offset: int, *, impl: str = "ref") -> jax.Array:
    _warn("scatter_strided", "vx.scatter(vx.Strided(...), window, values)")
    spec = vx.Strided(n=window.shape[-1], stride=stride,
                      vl=values.shape[-1], offset=offset)
    return vx.scatter(spec, window, values, policy=impl)


def gather_strided_rt(window: jax.Array, stride, offset: int, vl: int,
                      *, impl: str = "ref") -> jax.Array:
    _warn("gather_strided_rt",
          "vx.gather(vx.Strided(stride=vx.BANK, ...), window, stride=s)")
    spec = vx.Strided(n=window.shape[-1], stride=vx.BANK, vl=vl,
                      offset=offset)
    return vx.gather(spec, window, stride=stride, policy=impl)


def scatter_strided_rt(window: jax.Array, values: jax.Array, stride,
                       offset: int, *, impl: str = "ref") -> jax.Array:
    _warn("scatter_strided_rt",
          "vx.scatter(vx.Strided(stride=vx.BANK, ...), window, values, "
          "stride=s)")
    spec = vx.Strided(n=window.shape[-1], stride=vx.BANK,
                      vl=values.shape[-1], offset=offset)
    return vx.scatter(spec, window, values, stride=stride, policy=impl)


def gather_strided_many(windows: jax.Array, specs, vl: int,
                        *, impl: str = "ref") -> jax.Array:
    _warn("gather_strided_many", "vx.gather_many([vx.Strided(...)], windows)")
    n = windows.shape[-1]
    vspecs = [vx.Strided(n=n, stride=s, vl=vl, offset=o) for s, o in specs]
    return vx.gather_many(vspecs, windows, policy=impl)


def deinterleave_many(aos_list: Sequence[jax.Array], fields: int, *,
                      impl: str = "ref") -> list[list[jax.Array]]:
    _warn("deinterleave_many", "vx.gather_many(vx.Segment(...), aos_list)")
    spec = vx.Segment(n=aos_list[0].shape[-1], fields=fields)
    return vx.gather_many(spec, list(aos_list), policy=impl)


def interleave_many(groups: Sequence[Sequence[jax.Array]], *,
                    impl: str = "ref") -> list[jax.Array]:
    _warn("interleave_many", "vx.scatter_many(vx.Segment(...), groups)")
    nf = len(groups[0])
    spec = vx.Segment(n=nf * groups[0][0].shape[-1], fields=nf)
    return vx.scatter_many(spec, [list(g) for g in groups], policy=impl)


def deinterleave(aos: jax.Array, fields: int, *, impl: str = "ref"
                 ) -> list[jax.Array]:
    _warn("deinterleave", "vx.transpose(vx.Segment(...), aos)")
    return vx.transpose(vx.Segment(n=aos.shape[-1], fields=fields), aos,
                        policy=impl)


def interleave(soa: Sequence[jax.Array], *, impl: str = "ref") -> jax.Array:
    _warn("interleave", "vx.transpose(vx.Segment(...), [fields...])")
    parts = list(soa)
    spec = vx.Segment(n=len(parts) * parts[0].shape[-1], fields=len(parts))
    return vx.transpose(spec, parts, policy=impl)


def compact_rows(rows: jax.Array, mask: jax.Array, *, impl: str = "ref"
                 ) -> tuple[jax.Array, jax.Array]:
    _warn("compact_rows", "vx.compact(vx.Compact(...), mask, rows)")
    return vx.compact(vx.Compact(n=rows.shape[0]), mask, rows, policy=impl)


def expand_rows(packed: jax.Array, mask: jax.Array, *, impl: str = "ref"
                ) -> jax.Array:
    _warn("expand_rows", "vx.scatter(vx.Compact(...), mask, packed)")
    return vx.scatter(vx.Compact(n=mask.shape[0]), mask, packed,
                      policy=impl)


def shift_gather(x: jax.Array, shift: jax.Array, valid: jax.Array,
                 *, impl: str = "pallas") -> jax.Array:
    _warn("shift_gather", "vx.gather(vx.Indexed(...), x, shift=, valid=)")
    return vx.gather(vx.Indexed(n=x.shape[-1]), x, shift=shift,
                     valid=valid, policy=impl)


def shift_scatter(x: jax.Array, shift: jax.Array, valid: jax.Array,
                  *, impl: str = "pallas") -> tuple[jax.Array, jax.Array]:
    _warn("shift_scatter", "vx.scatter(vx.Indexed(...), None, x, shift=, "
          "valid=)")
    return vx.scatter(vx.Indexed(n=x.shape[-1]), None, x, shift=shift,
                      valid=valid, policy=impl)
