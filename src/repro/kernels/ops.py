"""Public wrappers for the EARTH kernels with impl dispatch.

impl="ref"            -> pure-jnp oracle (XLA path; the dry-run lowering)
impl="pallas"         -> Pallas TPU kernel routed by a COMPILED ShiftPlan
                         (constant masks, pruned layers; interpret off-TPU)
impl="pallas_dynamic" -> Pallas kernel with the dynamic-count network in
                         the body (the runtime-stride fallback; kept as the
                         in-kernel oracle for the compiled path)

Strides / offsets / field counts are static Python ints (they parameterize
shift plans and block shapes); callers jit around these wrappers.
"""
from __future__ import annotations

from typing import Sequence

import jax

from repro.kernels import ref as _ref

_IMPLS = ("ref", "pallas", "pallas_dynamic")


def _check_impl(impl: str) -> None:
    if impl not in _IMPLS:
        raise ValueError(f"unknown impl {impl!r} (want one of {_IMPLS})")


def _pick(impl: str, ref_fn, pallas_fn):
    _check_impl(impl)
    return ref_fn if impl == "ref" else pallas_fn


def gather_strided(window: jax.Array, stride: int, offset: int, vl: int,
                   *, impl: str = "ref") -> jax.Array:
    _check_impl(impl)
    if impl == "ref":
        return _ref.gather_strided(window, stride, offset, vl)
    from repro.kernels import strided as _strided
    return _strided.gather_strided(window, stride, offset, vl,
                                   compiled=impl == "pallas")


def scatter_strided(window: jax.Array, values: jax.Array, stride: int,
                    offset: int, *, impl: str = "ref") -> jax.Array:
    _check_impl(impl)
    if impl == "ref":
        return _ref.scatter_strided(window, values, stride, offset)
    from repro.kernels import strided as _strided
    return _strided.scatter_strided(window, values, stride, offset,
                                    compiled=impl == "pallas")


def gather_strided_rt(window: jax.Array, stride, offset: int, vl: int,
                      *, impl: str = "ref") -> jax.Array:
    """Runtime-stride gather: static Python strides take the normal impl
    dispatch; TRACED strides dispatch through the plan bank's ``lax.switch``
    (core/accessfuse.py) — compiled constant masks for banked strides
    (±1..8), dynamic-count network otherwise.  Either sign engages the
    Reverser."""
    import numpy as _np
    if isinstance(stride, (int, _np.integer)) and int(stride) > 0:
        return gather_strided(window, int(stride), offset, vl, impl=impl)
    from repro.core import accessfuse
    return accessfuse.bank_gather_strided(window, stride, offset, vl)


def scatter_strided_rt(window: jax.Array, values: jax.Array, stride,
                       offset: int, *, impl: str = "ref") -> jax.Array:
    """Runtime-stride scatter twin of :func:`gather_strided_rt`."""
    import numpy as _np
    if isinstance(stride, (int, _np.integer)) and int(stride) > 0:
        return scatter_strided(window, values, int(stride), offset,
                               impl=impl)
    from repro.core import accessfuse
    return accessfuse.bank_scatter_strided(window, values, stride, offset)


def gather_strided_many(windows: jax.Array, specs, vl: int,
                        *, impl: str = "ref") -> jax.Array:
    """A same-shape gathers with per-access (stride, offset) specs in ONE
    launch with one concatenated mask operand.  windows: (A, ..., n)."""
    _check_impl(impl)
    if impl == "ref":
        import jax.numpy as jnp
        return jnp.stack([_ref.gather_strided(windows[a], s, o, vl)
                          for a, (s, o) in enumerate(specs)])
    from repro.kernels import strided as _strided
    return _strided.gather_strided_fused(windows, tuple(specs), vl,
                                         compiled=impl == "pallas")


def deinterleave_many(aos_list: Sequence[jax.Array], fields: int, *,
                      impl: str = "ref") -> list[list[jax.Array]]:
    """A same-shape segment loads in ONE launch (stacked leading axis)."""
    _check_impl(impl)
    if impl != "ref":
        from repro.kernels import segment as _segment
        return _segment.deinterleave_many(list(aos_list), fields,
                                          fused=impl == "pallas")
    import jax.numpy as jnp
    outs = deinterleave(jnp.stack(list(aos_list)), fields, impl="ref")
    return [[o[a] for o in outs] for a in range(len(aos_list))]


def interleave_many(groups: Sequence[Sequence[jax.Array]], *,
                    impl: str = "ref") -> list[jax.Array]:
    """A same-shape segment stores in ONE launch (stacked leading axis)."""
    _check_impl(impl)
    import jax.numpy as jnp
    nf = len(groups[0])
    stacked = [jnp.stack([g[f] for g in groups]) for f in range(nf)]
    out = interleave(stacked, impl=impl)
    return [out[a] for a in range(len(groups))]


def deinterleave(aos: jax.Array, fields: int, *, impl: str = "ref"
                 ) -> list[jax.Array]:
    _check_impl(impl)
    if impl == "ref":
        return _ref.deinterleave(aos, fields)
    from repro.kernels import segment as _segment
    return _segment.deinterleave(aos, fields, fused=impl == "pallas")


def interleave(soa: Sequence[jax.Array], *, impl: str = "ref") -> jax.Array:
    _check_impl(impl)
    if impl == "ref":
        return _ref.interleave(list(soa))
    from repro.kernels import segment as _segment
    return _segment.interleave(list(soa), fused=impl == "pallas")


def compact_rows(rows: jax.Array, mask: jax.Array, *, impl: str = "ref"
                 ) -> tuple[jax.Array, jax.Array]:
    from repro.kernels import moe_compact as _mc
    fn = _pick(impl, _ref.compact_rows, _mc.compact_rows)
    return fn(rows, mask)


def expand_rows(packed: jax.Array, mask: jax.Array, *, impl: str = "ref"
                ) -> jax.Array:
    from repro.kernels import moe_compact as _mc
    fn = _pick(impl, _ref.expand_rows, _mc.expand_rows)
    return fn(packed, mask)


def shift_gather(x: jax.Array, shift: jax.Array, valid: jax.Array,
                 *, impl: str = "pallas") -> jax.Array:
    """Raw DROM gather (no closed-form SCG) — pallas-only primitive."""
    from repro.kernels import shift_gather as _sg
    from repro.core import shiftnet
    if impl == "pallas":
        return _sg.shift_gather(x, shift, valid)
    res = shiftnet.gather_network(x, shift, valid, axis=-1)
    import jax.numpy as jnp
    return jnp.where(res.valid, res.payload, jnp.zeros_like(res.payload))


def shift_scatter(x: jax.Array, shift: jax.Array, valid: jax.Array,
                  *, impl: str = "pallas") -> tuple[jax.Array, jax.Array]:
    """Raw DROM scatter — returns (payload, occupancy mask)."""
    from repro.kernels import shift_scatter as _ss
    from repro.core import shiftnet
    if impl == "pallas":
        return _ss.shift_scatter(x, shift, valid)
    res = shiftnet.scatter_network(x, shift, valid, axis=-1)
    import jax.numpy as jnp
    return (jnp.where(res.valid, res.payload, jnp.zeros_like(res.payload)),
            jnp.broadcast_to(res.valid, x.shape))
