"""MoE token compaction via the EARTH gather network (row routing).

Packing the tokens routed to an expert to the front of a tile is an
order-preserving, separation-non-increasing mapping — exactly the GSN-safe
class.  Shift counts are a prefix sum of the routing mask (the "SCG" of
dispatch), computed once outside.

Routing-mask precomputation (the static-plan compiler's runtime-count
sibling): the per-layer take-masks depend only on the (n,)-wide shift
counts, so they are derived ONCE outside the kernel (cheap vector
arithmetic) and fed in as an (L, n) operand.  The kernel then pays exactly
one static sublane shift + one select per layer on the wide (n, d) payload
— for every d-tile — instead of re-routing the (shiftcnt, valid) triple
inside each tile (3x the shifted arrays, duplicated per grid step).

The inverse (expansion) scatters expert outputs back to token slots (SSN).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import scg, shiftnet
from repro.kernels import _common

COL_TILE = 128


def _route_kernel(masks_ref, valid_ref, rows_ref, o_ref, *,
                  toward_zero: bool, lsb_first: bool):
    rows = rows_ref[...]                  # (n, dt)
    masks = masks_ref[...] != 0           # (L, n)
    routed = shiftnet.apply_layer_masks(rows, masks, axis=0,
                                        toward_zero=toward_zero,
                                        lsb_first=lsb_first)
    keep = valid_ref[...] != 0            # (n, 1)
    o_ref[...] = jnp.where(keep, routed, jnp.zeros_like(routed))


def _route_rows(rows: jax.Array, shift: jax.Array, valid: jax.Array,
                out_valid: jax.Array, *, toward_zero: bool,
                lsb_first: bool) -> jax.Array:
    """Shared compact/expand driver: precompute (L, n) masks, tile over d."""
    n, d = rows.shape
    masks, _ = shiftnet.layer_masks(shift, valid, toward_zero=toward_zero,
                                    lsb_first=lsb_first)
    L = masks.shape[0]
    if L == 0:                            # n <= 1: nothing can move
        return jnp.where(out_valid[:, None], rows, jnp.zeros_like(rows))
    dpad = (-d) % COL_TILE
    rp = jnp.pad(rows, ((0, 0), (0, dpad))) if dpad else rows
    dt = min(COL_TILE, rp.shape[1])
    out = _common.call(
        functools.partial(_route_kernel, toward_zero=toward_zero,
                          lsb_first=lsb_first),
        out_shape=jax.ShapeDtypeStruct(rp.shape, rows.dtype),
        grid=(rp.shape[1] // dt,),
        in_specs=[pl.BlockSpec((L, n), lambda j: (0, 0)),
                  pl.BlockSpec((n, 1), lambda j: (0, 0)),
                  pl.BlockSpec((n, dt), lambda j: (0, j))],
        out_specs=pl.BlockSpec((n, dt), lambda j: (0, j)),
    )(masks.astype(jnp.int32), out_valid.reshape(n, 1).astype(jnp.int32), rp)
    return out[:, :d]


def compact_rows(rows: jax.Array, mask: jax.Array
                 ) -> tuple[jax.Array, jax.Array]:
    """Pack masked (n, d) rows to the front (stable). Returns (packed, valid)."""
    n, _ = rows.shape
    shift, valid = scg.compaction_counts(mask)
    packed_valid = jnp.arange(n) < jnp.sum(mask.astype(jnp.int32))
    out = _route_rows(rows, shift, valid, packed_valid,
                      toward_zero=True, lsb_first=True)
    return out, packed_valid


def expand_rows(packed: jax.Array, mask: jax.Array) -> jax.Array:
    """Scatter packed rows back to the set positions of mask (zeros elsewhere)."""
    shift, valid = scg.expansion_counts(mask)
    return _route_rows(packed, shift, valid, mask.astype(bool),
                       toward_zero=False, lsb_first=False)
