"""MoE token compaction via the EARTH gather network (row routing).

Packing the tokens routed to an expert to the front of a tile is an
order-preserving, separation-non-increasing mapping — exactly the GSN-safe
class.  Shift counts are a prefix sum of the routing mask (the "SCG" of
dispatch), computed once outside; the kernel then routes (n, d) token rows
with log2(n) static sublane shifts per d-tile, replacing a gather/sort.

The inverse (expansion) scatters expert outputs back to token slots (SSN).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import scg, shiftnet
from repro.kernels import _common

COL_TILE = 128


def _compact_kernel(shift_ref, valid_ref, rows_ref, o_ref):
    rows = rows_ref[...]                  # (n, dt)
    shift = shift_ref[...]                # (n, 1)
    valid = valid_ref[...] != 0           # (n, 1)
    res = shiftnet._route(rows, jnp.broadcast_to(shift, rows.shape),
                          jnp.broadcast_to(valid, rows.shape),
                          axis=0, toward_zero=True, lsb_first=True)
    o_ref[...] = jnp.where(res.valid, res.payload, jnp.zeros_like(res.payload))


def compact_rows(rows: jax.Array, mask: jax.Array
                 ) -> tuple[jax.Array, jax.Array]:
    """Pack masked (n, d) rows to the front (stable). Returns (packed, valid)."""
    n, d = rows.shape
    shift, valid = scg.compaction_counts(mask)
    dpad = (-d) % COL_TILE
    rp = jnp.pad(rows, ((0, 0), (0, dpad))) if dpad else rows
    dt = min(COL_TILE, rp.shape[1])
    out = _common.call(
        _compact_kernel,
        out_shape=jax.ShapeDtypeStruct(rp.shape, rows.dtype),
        grid=(rp.shape[1] // dt,),
        in_specs=[pl.BlockSpec((n, 1), lambda j: (0, 0)),
                  pl.BlockSpec((n, 1), lambda j: (0, 0)),
                  pl.BlockSpec((n, dt), lambda j: (0, j))],
        out_specs=pl.BlockSpec((n, dt), lambda j: (0, j)),
    )(shift.reshape(n, 1), valid.reshape(n, 1).astype(jnp.int32), rp)
    packed_valid = jnp.arange(n) < jnp.sum(mask.astype(jnp.int32))
    return out[:, :d], packed_valid


def _expand_kernel(shift_ref, valid_ref, rows_ref, o_ref):
    rows = rows_ref[...]
    shift = shift_ref[...]
    valid = valid_ref[...] != 0
    res = shiftnet._route(rows, jnp.broadcast_to(shift, rows.shape),
                          jnp.broadcast_to(valid, rows.shape),
                          axis=0, toward_zero=False, lsb_first=False)
    o_ref[...] = jnp.where(res.valid, res.payload, jnp.zeros_like(res.payload))


def expand_rows(packed: jax.Array, mask: jax.Array) -> jax.Array:
    """Scatter packed rows back to the set positions of mask (zeros elsewhere)."""
    n, d = packed.shape
    shift, valid = scg.expansion_counts(mask)
    dpad = (-d) % COL_TILE
    pp = jnp.pad(packed, ((0, 0), (0, dpad))) if dpad else packed
    dt = min(COL_TILE, pp.shape[1])
    out = _common.call(
        _expand_kernel,
        out_shape=jax.ShapeDtypeStruct(pp.shape, packed.dtype),
        grid=(pp.shape[1] // dt,),
        in_specs=[pl.BlockSpec((n, 1), lambda j: (0, 0)),
                  pl.BlockSpec((n, 1), lambda j: (0, 0)),
                  pl.BlockSpec((n, dt), lambda j: (0, j))],
        out_specs=pl.BlockSpec((n, dt), lambda j: (0, j)),
    )(shift.reshape(n, 1), valid.reshape(n, 1).astype(jnp.int32), pp)
    return out[:, :d]
